//! Property-based invariants (via the in-crate `testkit` Gen/shrink
//! framework) for the two pillars the paper rests on:
//!
//! 1. every generated graph yields a *valid Laplacian* — symmetric PSD with
//!    zero row sums (eq 1: `L = XᵀWX` ⪰ 0, `L·1 = 0`);
//! 2. every Table-2 transform is a *monotone spectrum map*: it reshapes
//!    eigenvalues without reordering them, so the bottom-k eigenvectors —
//!    the object spectral clustering needs — are preserved.

use sped::graph::gen::{
    barbell, cliques, erdos_renyi, grid2d, path, ring, ring_of_cliques, sbm, CliqueSpec,
};
use sped::graph::Graph;
use sped::linalg::eigh;
use sped::linalg::metrics::subspace_error;
use sped::linalg::sparse::spmm;
use sped::linalg::DMat;
use sped::testkit::{check, SizeGen};
use sped::transforms::TransformKind;

/// Zero row sums + symmetry + PSD, checked exactly the way the paper's
/// algebra requires them.
fn assert_valid_laplacian(g: &Graph, context: &str) -> Result<(), String> {
    let l = g.laplacian();
    for i in 0..l.rows() {
        let s: f64 = l.row(i).iter().sum();
        if s.abs() > 1e-9 {
            return Err(format!("{context}: row {i} sums to {s}"));
        }
    }
    if !l.is_symmetric(1e-12) {
        return Err(format!("{context}: Laplacian not symmetric"));
    }
    let e = eigh(&l).map_err(|e| format!("{context}: eigh failed: {e}"))?;
    match e.values.first() {
        Some(&lo) if lo < -1e-9 => Err(format!("{context}: negative eigenvalue {lo}")),
        _ => Ok(()),
    }
}

#[test]
fn property_every_generator_yields_psd_zero_rowsum_laplacian() {
    check(101, 10, &SizeGen { lo: 6, hi: 28 }, |&n| {
        let seed = n as u64;
        let cases: Vec<(&str, Graph)> = vec![
            (
                "cliques",
                cliques(&CliqueSpec { n, k: (n / 6).max(1), max_short_circuit: 3, seed }).graph,
            ),
            ("sbm", sbm(&[n / 2, n - n / 2], 0.8, 0.05, seed).graph),
            ("erdos_renyi", erdos_renyi(n, 0.3, seed).graph),
            ("grid2d", grid2d(n / 3 + 1, 3).graph),
            ("path", path(n).graph),
            ("ring", ring(n.max(3)).graph),
            ("barbell", barbell(n / 2 + 2).graph),
            ("ring_of_cliques", ring_of_cliques(3, n / 3 + 2, seed).graph),
        ];
        for (name, g) in cases {
            assert_valid_laplacian(&g, name)?;
        }
        Ok(())
    });
}

#[test]
fn property_weighted_laplacians_also_valid() {
    // Link-prediction completion produces *weighted* graphs; the Laplacian
    // invariants must survive arbitrary positive weights.
    check(102, 10, &SizeGen { lo: 8, hi: 30 }, |&n| {
        let gg = cliques(&CliqueSpec { n, k: 2, max_short_circuit: 3, seed: n as u64 });
        let mut rng = sped::util::rng::Rng::new(n as u64 ^ 0xBEEF);
        let weights: Vec<f64> = (0..gg.graph.num_edges()).map(|_| rng.uniform(0.05, 2.0)).collect();
        let weighted = gg.graph.with_weights(&weights).map_err(|e| e.to_string())?;
        assert_valid_laplacian(&weighted, "reweighted cliques")
    });
}

#[test]
fn property_spmm_bitwise_matches_dense_matmul_across_generators() {
    // The sparse-kernel contract behind OpMode::MatrixFree: for every graph
    // generator, both Laplacian variants, random bundles on both sides of
    // the dense skinny/blocked kernel split, and 1/2/8 workers, the CSR
    // product is bit-for-bit the dense product.
    check(105, 8, &SizeGen { lo: 6, hi: 26 }, |&n| {
        let seed = n as u64;
        let cases: Vec<(&str, Graph)> = vec![
            (
                "cliques",
                cliques(&CliqueSpec { n, k: (n / 6).max(1), max_short_circuit: 3, seed }).graph,
            ),
            ("sbm", sbm(&[n / 2, n - n / 2], 0.8, 0.05, seed).graph),
            ("erdos_renyi", erdos_renyi(n, 0.3, seed).graph),
            ("grid2d", grid2d(n / 3 + 1, 3).graph),
            ("path", path(n).graph),
            ("ring", ring(n.max(3)).graph),
            ("barbell", barbell(n / 2 + 2).graph),
            ("ring_of_cliques", ring_of_cliques(3, n / 3 + 2, seed).graph),
        ];
        for (name, g) in cases {
            let nn = g.num_nodes();
            for (variant, dense, sparse) in [
                ("laplacian", g.laplacian(), g.laplacian_csr()),
                ("normalized", g.normalized_laplacian(), g.normalized_laplacian_csr()),
            ] {
                for k in [3usize, 20] {
                    let mut rng = sped::util::rng::Rng::new(seed ^ (k as u64) << 8);
                    let v = DMat::from_fn(nn, k, |_, _| rng.normal());
                    let want = sped::linalg::matmul::matmul(&dense, &v);
                    for workers in [1usize, 2, 8] {
                        let got = spmm(&sparse, &v, workers);
                        let identical = want
                            .data()
                            .iter()
                            .zip(got.data().iter())
                            .all(|(a, b)| a.to_bits() == b.to_bits());
                        if !identical {
                            return Err(format!(
                                "{name}/{variant}: spmm diverged from matmul at n={nn}, k={k}, {workers} workers"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn property_minibatch_estimator_unbiased_with_tolerance_shrinking_in_batch() {
    // Eq 8's stochastic model: each MinibatchLaplacianOp application is an
    // unbiased draw of (λ*I − L)·V, so the average of many applications
    // converges to the exact product — and with a fixed number of
    // applications, the Monte-Carlo error shrinks as the batch grows
    // (σ ∝ 1/√(reps·B)).
    use sped::solvers::stochastic::MinibatchLaplacianOp;
    use sped::solvers::MatVecOp;
    let gg = cliques(&CliqueSpec { n: 18, k: 2, max_short_circuit: 1, seed: 2 });
    let l = gg.graph.laplacian();
    let lam_star = 1.1 * sped::linalg::funcs::power_lambda_max(&l, 100).unwrap();
    let v = sped::solvers::random_init(18, 3, 7);
    let mut expect = v.clone();
    expect.scale(lam_star);
    expect.axpy(-1.0, &sped::linalg::matmul::matmul(&l, &v));
    let reps = 2000usize;
    let mut errs = Vec::new();
    for (i, &batch) in [4usize, 16, 64].iter().enumerate() {
        let mut op = MinibatchLaplacianOp::new(&gg.graph, lam_star, batch, 100 + i as u64);
        let mut acc = DMat::zeros(18, 3);
        for _ in 0..reps {
            acc.axpy(1.0 / reps as f64, &op.apply(&v));
        }
        let rel = (&acc - &expect).max_abs() / expect.max_abs();
        // Tolerance calibrated against the B=8 × reps=3000 bound of 0.12
        // in `solvers::stochastic`'s unit test, scaled by 1/√(reps·B) and
        // doubled for slack: the bound itself shrinks as the batch grows.
        let tol = 0.24 * ((3000.0 * 8.0) / (reps as f64 * batch as f64)).sqrt();
        assert!(rel < tol, "B={batch}: rel err {rel} ≥ tol {tol}");
        errs.push(rel);
    }
    assert!(
        errs[2] < errs[0],
        "error did not shrink with batch size: {errs:?}"
    );
}

/// The Table-2 transform set, on a spectrum pre-scaled into [0, 1] (the
/// regime where every series in the table converges; pre-scaling is itself
/// eigenvector-preserving).
fn table2_transforms() -> Vec<TransformKind> {
    vec![
        TransformKind::Identity,
        TransformKind::MatrixLog { eps: 0.05 },
        TransformKind::NegExp,
        TransformKind::TaylorNegExp { ell: 31 },
        TransformKind::TaylorLog { ell: 61, eps: 0.05 },
        TransformKind::LimitNegExp { ell: 51 },
    ]
}

#[test]
fn property_table2_transforms_are_monotone_spectrum_maps() {
    check(103, 8, &SizeGen { lo: 8, hi: 24 }, |&n| {
        let gg = cliques(&CliqueSpec { n, k: 2, max_short_circuit: 2, seed: n as u64 + 7 });
        let l_raw = gg.graph.laplacian();
        let e_raw = eigh(&l_raw).map_err(|e| e.to_string())?;
        let lam_max = e_raw.lambda_max().max(1e-9);
        let mut l = l_raw.clone();
        l.scale(1.0 / lam_max);
        let e_l = eigh(&l).map_err(|e| e.to_string())?;
        for t in table2_transforms() {
            // (a) the scalar map is monotone non-decreasing on [0, 1].
            let mut prev = f64::NEG_INFINITY;
            for i in 0..=40 {
                let y = t.scalar_map(i as f64 / 40.0);
                if y < prev - 1e-9 {
                    return Err(format!("{t}: scalar map decreases at x={}", i as f64 / 40.0));
                }
                prev = y;
            }
            // (b) the matrix spectrum is the elementwise image, in the same
            // ascending order — i.e. no eigenvalue reordering.
            let fl = t.build(&l).map_err(|e| e.to_string())?;
            let e_f = eigh(&fl).map_err(|e| e.to_string())?;
            for i in 0..n {
                let want = t.scalar_map(e_l.values[i]);
                let got = e_f.values[i];
                if (got - want).abs() > 1e-6 * (1.0 + want.abs()) {
                    return Err(format!("{t}: λ_{i} mapped to {got}, want {want}"));
                }
            }
            // (c) the bottom-k eigenvectors (k = #clusters) span the same
            // subspace — the object spectral clustering consumes.
            let err = subspace_error(&e_l.bottom_k(2), &e_f.bottom_k(2));
            if err > 1e-6 {
                return Err(format!("{t}: bottom-2 subspace err {err}"));
            }
        }
        Ok(())
    });
}

#[test]
fn property_ritz_residuals_decay_and_honestly_bound_eigenpair_error() {
    // Two invariants of the block Rayleigh–Ritz solver:
    //
    // (a) the per-iteration max residual is (numerically) non-increasing —
    //     filtered subspace iteration contracts the unwanted components
    //     every sweep, so a residual rise beyond rounding jitter means the
    //     solver is lying about its own convergence;
    // (b) the returned residuals honestly bound the eigenvalue error: for
    //     symmetric M and a unit Ritz pair (θ, x), some exact eigenvalue
    //     of M lies within ‖Mx − θx‖ of θ (Weyl) — checked against the
    //     full `eigh` spectrum of the materialized operator.
    use sped::solvers::ritz::{ritz_solve, RitzConfig};
    check(106, 8, &SizeGen { lo: 12, hi: 30 }, |&n| {
        let gg = cliques(&CliqueSpec { n, k: 2, max_short_circuit: 2, seed: n as u64 + 13 });
        let l = gg.graph.laplacian();
        let kind = TransformKind::LimitNegExp { ell: 31 };
        let sm = sped::transforms::build_solver_matrix(
            &l,
            kind,
            &sped::transforms::BuildOptions::default(),
        )
        .map_err(|e| e.to_string())?;
        let e_m = eigh(&sm.m).map_err(|e| e.to_string())?;
        let scale = e_m.values.iter().fold(0.0f64, |m, &v| m.max(v.abs())).max(1e-300);
        let mut op = sped::solvers::DenseOp::new(sm.m.clone());
        let cfg = RitzConfig { k: 2, tol: 1e-10, max_iters: 500, ..Default::default() };
        let res = ritz_solve(&mut op, &cfg).map_err(|e| e.to_string())?;
        if !res.converged {
            return Err(format!("n={n}: not converged in {} iters", res.iterations));
        }
        // (a) monotone decay, with a small multiplicative slack plus a
        //     rounding floor for the final near-machine-precision steps.
        for w in res.history.windows(2) {
            let (prev, next) = (w[0].max_residual, w[1].max_residual);
            if next > prev * 1.25 + 1e-12 * scale {
                return Err(format!(
                    "n={n}: residual rose {prev:.3e} -> {next:.3e} at iter {}",
                    w[1].iter
                ));
            }
        }
        // (b) Weyl honesty against the exact spectrum of M.
        for i in 0..2 {
            let theta = res.values[i];
            let r = res.residuals[i];
            let dist = e_m
                .values
                .iter()
                .map(|&lam| (lam - theta).abs())
                .fold(f64::INFINITY, f64::min);
            if dist > r + 1e-9 * (1.0 + scale) {
                return Err(format!(
                    "n={n}: θ_{i}={theta} sits {dist:.3e} from spec(M) but reported residual {r:.3e}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn property_transform_ordering_survives_reversal() {
    // After eq 8's reversal M = λ*I − f(L), the *top*-k eigenvectors of M
    // must be the bottom-k of L — order reversed, subspace intact.
    check(104, 8, &SizeGen { lo: 8, hi: 24 }, |&n| {
        let gg = cliques(&CliqueSpec { n, k: 2, max_short_circuit: 2, seed: n as u64 + 31 });
        let l = gg.graph.laplacian();
        let e_l = eigh(&l).map_err(|e| e.to_string())?;
        for t in [TransformKind::NegExp, TransformKind::LimitNegExp { ell: 51 }] {
            let sm = sped::transforms::build_solver_matrix(
                &l,
                t,
                &sped::transforms::BuildOptions::default(),
            )
            .map_err(|e| e.to_string())?;
            let e_m = eigh(&sm.m).map_err(|e| e.to_string())?;
            let top2 = sped::linalg::DMat::from_fn(n, 2, |i, j| e_m.vectors[(i, n - 1 - j)]);
            let err = subspace_error(&e_l.bottom_k(2), &top2);
            if err > 1e-6 {
                return Err(format!("{t}: reversed top-2 subspace err {err}"));
            }
        }
        Ok(())
    });
}
