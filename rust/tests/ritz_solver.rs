//! Acceptance harness for the matrix-free block Rayleigh–Ritz solver:
//!
//! 1. the solver recovers the dense-`eigh` bottom-k embedding (subspace
//!    angle ≤ 1e-6) for every graph generator × both Laplacian variants,
//!    driving nothing but `SparsePolyOp` SpMM sweeps;
//! 2. its output is **bitwise** identical across 1/2/8 workers, at the
//!    operator level and through the pipeline;
//! 3. the paper's core claim as an assertion: the dilated operator
//!    converges in strictly fewer outer iterations than the undilated
//!    Laplacian on well-clustered graphs, at equal relative tolerance;
//! 4. `--solver ritz --op sparse --no-ground-truth` reproduces the dense
//!    ground-truth partition on every clustered generator, dense-free.

use sped::graph::gen::{
    barabasi_albert, barbell, cliques, erdos_renyi, grid2d, path, ring, ring_of_cliques, sbm,
    CliqueSpec, GeneratedGraph,
};
use sped::graph::Graph;
use sped::linalg::eigh;
use sped::linalg::metrics::subspace_error;
use sped::pipeline::{Pipeline, PipelineConfig};
use sped::solvers::ritz::{ritz_solve, RitzConfig};
use sped::solvers::SparsePolyOp;
use sped::transforms::{BuildOptions, OpMode, TransformKind};

/// Every generator in the crate, at a size where the eigh oracle per
/// (generator × variant) stays cheap.
fn generator_zoo(n: usize, seed: u64) -> Vec<(&'static str, Graph)> {
    vec![
        (
            "cliques",
            cliques(&CliqueSpec { n, k: (n / 6).max(1), max_short_circuit: 3, seed }).graph,
        ),
        ("sbm", sbm(&[n / 2, n - n / 2], 0.8, 0.05, seed).graph),
        ("erdos_renyi", erdos_renyi(n, 0.3, seed).graph),
        ("grid2d", grid2d(n / 3 + 1, 3).graph),
        ("path", path(n).graph),
        ("ring", ring(n.max(3)).graph),
        ("barbell", barbell(n / 2 + 2).graph),
        ("ring_of_cliques", ring_of_cliques(3, n / 3 + 2, seed).graph),
        ("barabasi_albert", barabasi_albert(n.max(5), 3, seed).graph),
    ]
}

/// The subspace dimension with the widest relative spectral separation
/// among k ∈ {2, 3, 4} — keeps the harness off exactly-degenerate
/// boundaries (ring/grid eigenvalue pairs), where "the bottom-k subspace"
/// is not even well defined.
fn pick_k(values: &[f64]) -> usize {
    let lam_max = values.last().copied().unwrap_or(1.0).max(1e-12);
    let mut best = (2usize, f64::NEG_INFINITY);
    for k in 2..=4usize.min(values.len() - 1) {
        let gap = (values[k] - values[k - 1]) / lam_max;
        if gap > best.1 {
            best = (k, gap);
        }
    }
    best.0
}

#[test]
fn ritz_recovers_eigh_embedding_across_generator_zoo_and_both_variants() {
    for (name, g) in generator_zoo(22, 3) {
        for (variant, ld, lc) in [
            ("laplacian", g.laplacian(), g.laplacian_csr()),
            ("normalized", g.normalized_laplacian(), g.normalized_laplacian_csr()),
        ] {
            let e = eigh(&ld).unwrap();
            let k = pick_k(&e.values);
            let v_star = e.bottom_k(k);
            let mut op = SparsePolyOp::from_csr(
                lc,
                TransformKind::LimitNegExp { ell: 51 },
                &BuildOptions::default(),
            )
            .unwrap();
            let cfg = RitzConfig { k, tol: 1e-10, max_iters: 4000, ..Default::default() };
            let res = ritz_solve(&mut op, &cfg).unwrap();
            assert!(
                res.converged,
                "{name}/{variant}: k={k} not converged in {} iters (last residual {:.3e})",
                res.iterations,
                res.history.last().map(|p| p.max_residual).unwrap_or(f64::NAN)
            );
            let err = subspace_error(&v_star, &res.embedding);
            assert!(err <= 1e-6, "{name}/{variant}: k={k} subspace err {err}");
            // Ritz values of M map back to the bottom eigenvalues of L
            // through the operator's own scalar map (λ* − p(λ)).
            for (i, &theta) in res.values.iter().enumerate() {
                let want = op.lambda_star - op.poly_eval(e.values[i]);
                assert!(
                    (theta - want).abs() <= 1e-6 * (1.0 + want.abs()),
                    "{name}/{variant}: θ_{i}={theta} vs mapped λ={want}"
                );
            }
        }
    }
}

#[test]
fn ritz_output_is_bitwise_identical_across_worker_counts() {
    let gg = cliques(&CliqueSpec { n: 60, k: 3, max_short_circuit: 2, seed: 7 });
    let run = |threads: usize| {
        let opts = BuildOptions { threads, ..BuildOptions::default() };
        let mut op = SparsePolyOp::from_graph(
            &gg.graph,
            TransformKind::LimitNegExp { ell: 51 },
            &opts,
        )
        .unwrap();
        let cfg = RitzConfig { k: 3, tol: 1e-10, max_iters: 500, ..Default::default() };
        ritz_solve(&mut op, &cfg).unwrap()
    };
    let base = run(1);
    assert!(base.converged);
    for threads in [2usize, 8] {
        let other = run(threads);
        assert_eq!(base.iterations, other.iterations, "{threads} workers");
        assert!(
            base.embedding
                .data()
                .iter()
                .zip(other.embedding.data().iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "embedding diverged at {threads} workers"
        );
        for (a, b) in base.residuals.iter().zip(other.residuals.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{threads} workers");
        }
        for (a, b) in base.values.iter().zip(other.values.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{threads} workers");
        }
    }
    // Same through the pipeline (threads also shards the operator build).
    let pipe = |threads| {
        let cfg = PipelineConfig {
            k: 3,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "ritz".into(),
            ritz_tol: 1e-10,
            ritz_max_iters: 500,
            op_mode: OpMode::MatrixFree,
            ground_truth: false,
            threads,
            ..Default::default()
        };
        Pipeline::new(cfg).run(&gg.graph).unwrap()
    };
    let serial = pipe(1);
    for threads in [2usize, 8] {
        let par = pipe(threads);
        assert!(
            serial
                .embedding
                .data()
                .iter()
                .zip(par.embedding.data().iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "pipeline embedding diverged at {threads} workers"
        );
        assert_eq!(
            serial.clustering.as_ref().unwrap().assignments,
            par.clustering.as_ref().unwrap().assignments
        );
    }
}

#[test]
fn dilated_operator_needs_strictly_fewer_outer_iterations_than_undilated() {
    // The paper's Fig. 2/3 story as an assertion: same solver, same
    // relative tolerance, same block — the only change is the spectrum
    // map. On well-clustered graphs the dilated gap ratio collapses the
    // iteration count.
    let cases: Vec<(&str, GeneratedGraph, usize)> = vec![
        ("cliques", cliques(&CliqueSpec { n: 96, k: 3, max_short_circuit: 2, seed: 11 }), 3),
        ("ring_of_cliques", ring_of_cliques(4, 16, 5), 4),
    ];
    for (name, gg, k) in cases {
        let run = |kind| {
            let mut op = SparsePolyOp::from_graph(&gg.graph, kind, &BuildOptions::default())
                .unwrap();
            let cfg = RitzConfig { k, tol: 1e-8, max_iters: 2000, ..Default::default() };
            ritz_solve(&mut op, &cfg).unwrap()
        };
        let dilated = run(TransformKind::LimitNegExp { ell: 51 });
        let undilated = run(TransformKind::Identity);
        assert!(dilated.converged, "{name}: dilated run did not converge");
        assert!(
            dilated.iterations < undilated.iterations,
            "{name}: dilated {} iters !< undilated {} iters",
            dilated.iterations,
            undilated.iterations
        );
        // Both recover the same subspace when both converge.
        if undilated.converged {
            let err = subspace_error(&dilated.embedding, &undilated.embedding);
            assert!(err <= 1e-6, "{name}: dilated vs undilated subspace err {err}");
        }
    }
}

#[test]
fn ritz_sparse_dense_free_pipeline_matches_eigh_partition_on_clustered_generators() {
    // Acceptance: `--solver ritz --op sparse --no-ground-truth` yields the
    // same hard partition as clustering the exact dense-eigh embedding, on
    // every tier-1 clustered generator — while the solve path touches no
    // n×n buffer at all.
    let canon = |a: &[usize]| {
        let mut map = std::collections::HashMap::new();
        a.iter()
            .map(|&c| {
                let next = map.len();
                *map.entry(c).or_insert(next)
            })
            .collect::<Vec<usize>>()
    };
    let cases: Vec<(&str, GeneratedGraph, usize)> = vec![
        ("cliques", cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 9 }), 3),
        ("sbm", sbm(&[16, 16, 16], 0.8, 0.02, 5), 3),
        ("barbell", barbell(10), 2),
        ("ring_of_cliques", ring_of_cliques(3, 8, 7), 3),
    ];
    for (name, gg, k) in cases {
        let seed = 0u64;
        let cfg = PipelineConfig {
            k,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "ritz".into(),
            ritz_tol: 1e-10,
            ritz_max_iters: 1000,
            op_mode: OpMode::MatrixFree,
            ground_truth: false,
            seed,
            ..Default::default()
        };
        let out = Pipeline::new(cfg).run(&gg.graph).unwrap();
        let rz = out.ritz.as_ref().unwrap();
        assert!(rz.converged, "{name}: not converged in {} iters", rz.iterations);
        // Reference: cluster the exact bottom-k eigenvectors with the same
        // clustering seed the pipeline derives.
        let e = eigh(&gg.graph.laplacian()).unwrap();
        let v_star = e.bottom_k(k);
        let err = subspace_error(&v_star, &out.embedding);
        assert!(err <= 1e-6, "{name}: subspace err {err}");
        let reference = sped::cluster::cluster_embedding(&v_star, k, seed ^ 0xC1u64);
        let got = out.clustering.as_ref().unwrap();
        assert_eq!(
            canon(&got.assignments),
            canon(&reference.assignments),
            "{name}: ritz partition differs from the dense-eigh partition"
        );
        // And it is the planted partition.
        let ari = sped::cluster::adjusted_rand_index(&got.assignments, &gg.labels);
        assert!(ari > 0.9, "{name}: ARI {ari}");
    }
}

#[test]
fn direct_alias_and_ritz_step_interface_rejection() {
    // `--solver direct` is the subspace-iteration alias promised by the
    // CLI: identical trajectory (same code path, same seed), bit for bit.
    let gg = cliques(&CliqueSpec { n: 24, k: 2, max_short_circuit: 1, seed: 3 });
    let mk = |solver: &str| PipelineConfig {
        k: 2,
        transform: TransformKind::LimitNegExp { ell: 51 },
        solver: solver.into(),
        steps: 100,
        eval_every: 20,
        stop_error: 0.0,
        op_mode: OpMode::MatrixFree,
        ground_truth: false,
        ..Default::default()
    };
    let a = Pipeline::new(mk("subspace")).run(&gg.graph).unwrap();
    let b = Pipeline::new(mk("direct")).run(&gg.graph).unwrap();
    assert!(a
        .embedding
        .data()
        .iter()
        .zip(b.embedding.data().iter())
        .all(|(x, y)| x.to_bits() == y.to_bits()));
    assert!(a.ritz.is_none() && b.ritz.is_none());
    // The block solver is not a step-driven EigenSolver; the name table
    // says so instead of silently mis-dispatching.
    let err = sped::solvers::solver_by_name("ritz", 0.1).unwrap_err();
    assert!(format!("{err:#}").contains("ritz"), "{err:#}");
}
