//! Serve-mode integration harness (PR 8 acceptance): cached batched
//! answers are bitwise-equal to a fresh one-shot pipeline run for all
//! three query kinds across 1/2/8 workers; a weights-only delta keeps the
//! cached RCM order while a topology delta drops it; and the lazy
//! re-solve after invalidation warm-starts and matches a cold rebuild.

use sped::cluster::{nearest_centroid, row_normalize};
use sped::coordinator::serve::{Answer, Query, ServeConfig, ServeSession};
use sped::graph::delta::EdgeDelta;
use sped::graph::gen::{cliques, CliqueSpec};
use sped::graph::Reorder;
use sped::linkpred::embedding_score;
use sped::pipeline::{Pipeline, PipelineConfig, SolvePath};
use sped::transforms::{OpMode, TransformKind};

/// The same solve the stream-stability harness uses: Ritz on the
/// matrix-free dilated operator, tight tolerance, no O(n^3) ground truth.
fn base_pipeline(k: usize, threads: usize) -> PipelineConfig {
    PipelineConfig {
        k,
        transform: TransformKind::LimitNegExp { ell: 51 },
        solver: "ritz".into(),
        ritz_tol: 1e-8,
        ritz_max_iters: 2000,
        op_mode: OpMode::MatrixFree,
        ground_truth: false,
        threads,
        ..Default::default()
    }
}

fn serve_cfg(k: usize, threads: usize) -> ServeConfig {
    ServeConfig { pipeline: base_pipeline(k, threads), warm_volume_frac: 0.25 }
}

/// One batch exercising every query kind.
fn query_mix() -> Vec<Query> {
    vec![
        Query::LinkPred { u: 0, v: 1 },
        Query::LinkPred { u: 0, v: 47 },
        Query::NearestCluster { u: 0 },
        Query::NearestCluster { u: 30 },
        Query::TopK { u: 5, k: 4 },
        Query::TopK { u: 40, k: 7 },
    ]
}

/// Flatten an answer into comparable bits — bitwise equality, not
/// approximate equality, is the contract under test.
fn bits(a: &Answer) -> Vec<u64> {
    match a {
        Answer::Score(s) => vec![s.to_bits()],
        Answer::Cluster { cluster, distance } => vec![*cluster as u64, distance.to_bits()],
        Answer::Neighbors(nb) => {
            nb.iter().flat_map(|&(v, s)| [v as u64, s.to_bits()]).collect()
        }
    }
}

/// All three query kinds, answered from the serve cache, must be bitwise
/// identical to scoring a fresh one-shot [`Pipeline::run`] output with the
/// public kernels — at every worker count, and regardless of how the
/// batch is split.
#[test]
fn cached_answers_bitwise_match_one_shot_pipeline_across_workers() {
    let gg = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 11 });
    let queries = query_mix();

    // The oracle: one fresh end-to-end pipeline run plus the same public
    // scoring kernels the serve kernel is built from.
    let mut pcfg = base_pipeline(3, 1);
    pcfg.do_cluster = true;
    let out = Pipeline::new(pcfg).run(&gg.graph).unwrap();
    let norm = row_normalize(&out.embedding);
    let cl = out.clustering.as_ref().unwrap();
    let n = gg.graph.num_nodes();
    let expected: Vec<Vec<u64>> = queries
        .iter()
        .map(|q| match *q {
            Query::LinkPred { u, v } => vec![embedding_score(&norm, u, v).to_bits()],
            Query::NearestCluster { u } => {
                let (c, d2) = nearest_centroid(&cl.centroids, norm.row(u));
                assert_eq!(c, cl.assignments[u], "oracle lookup disagrees with k-means");
                vec![c as u64, d2.sqrt().to_bits()]
            }
            Query::TopK { u, k } => {
                let mut scored: Vec<(usize, f64)> = (0..n)
                    .filter(|&v| v != u)
                    .map(|v| (v, embedding_score(&norm, u, v)))
                    .collect();
                scored.sort_by(|a, b| {
                    b.1.partial_cmp(&a.1)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.0.cmp(&b.0))
                });
                scored.truncate(k);
                scored.iter().flat_map(|&(v, s)| [v as u64, s.to_bits()]).collect()
            }
        })
        .collect();

    for threads in [1usize, 2, 8] {
        let mut s = ServeSession::new(gg.graph.clone(), serve_cfg(3, threads));
        let answers = s.answer_batch(&queries).unwrap();
        assert_eq!(s.solves(), 1, "one lazy solve per session, not per query");
        for (i, (ans, exp)) in answers.iter().zip(expected.iter()).enumerate() {
            assert_eq!(&bits(ans), exp, "query {i} diverged from the oracle at {threads} workers");
        }
        // Splitting the same work into two batches must not change any
        // answer or trigger another solve.
        let head = s.answer_batch(&queries[..2]).unwrap();
        let tail = s.answer_batch(&queries[2..]).unwrap();
        assert_eq!(s.solves(), 1, "cache hits must not re-solve");
        for (i, ans) in head.iter().chain(tail.iter()).enumerate() {
            assert_eq!(&bits(ans), &expected[i], "batch split changed query {i}");
        }
    }

    // Semantic sanity on the oracle itself: same-clique pairs beat
    // cross-clique pairs, and nodes 0 and 30 sit in different clusters.
    assert!(
        embedding_score(&norm, 0, 1) > embedding_score(&norm, 0, 47) + 0.5,
        "same-clique cosine must dominate cross-clique"
    );
    assert_ne!(cl.assignments[0], cl.assignments[30]);
}

/// Invalidation follows the [`DeltaOutcome`] flags exactly: a weights-only
/// batch drops the embedding but keeps the RCM order; a topology batch
/// drops both; each invalidation triggers exactly one lazy re-solve.
#[test]
fn weights_only_delta_keeps_rcm_order_topology_delta_drops_it() {
    let gg = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 7 });
    let mut cfg = serve_cfg(3, 2);
    cfg.pipeline.reorder = Reorder::Rcm;
    let mut s = ServeSession::new(gg.graph.clone(), cfg);
    assert!(s.cached_order().is_none(), "no order before the first solve");

    s.answer_batch(&[Query::NearestCluster { u: 0 }]).unwrap();
    assert_eq!(s.solves(), 1);
    let order0 = s.cached_order().expect("an RCM solve caches the order").to_vec();

    // Weights-only delta: embedding cache drops, order survives.
    let (u, v, w) = {
        let e = &s.graph().edges()[0];
        (e.u as usize, e.v as usize, e.w)
    };
    let out = s.apply_batch(&[EdgeDelta::Reweight { u, v, w: w * 1.5 }]).unwrap();
    assert!(out.weights_changed && !out.topology_changed);
    assert!(!s.cache_valid(), "a weights delta must invalidate the embedding");
    assert_eq!(s.cached_order(), Some(&order0[..]), "a weights delta must keep the RCM order");

    s.answer_batch(&[Query::NearestCluster { u: 0 }]).unwrap();
    assert_eq!(s.solves(), 2, "the invalidated cache re-solves lazily, once");
    assert_eq!(s.cached_order(), Some(&order0[..]), "the re-solve reuses the cached order");

    // Topology delta: both caches drop. Pick a pair with no existing edge
    // so the Add is genuinely structural.
    let existing: std::collections::HashSet<(usize, usize)> =
        s.graph().edges().iter().map(|e| (e.u as usize, e.v as usize)).collect();
    let (mut a, mut b) = (usize::MAX, usize::MAX);
    'outer: for x in 0..48 {
        for y in (x + 1)..48 {
            if !existing.contains(&(x, y)) {
                (a, b) = (x, y);
                break 'outer;
            }
        }
    }
    let out = s.apply_batch(&[EdgeDelta::Add { u: a, v: b, w: 0.5 }]).unwrap();
    assert!(out.topology_changed);
    assert!(!s.cache_valid());
    assert!(s.cached_order().is_none(), "a topology delta must drop the RCM order");

    s.answer_batch(&[Query::NearestCluster { u: 0 }]).unwrap();
    assert_eq!(s.solves(), 3);
    assert!(s.cached_order().is_some(), "the re-solve recomputes the order for the new topology");
}

/// After a small-churn invalidation the next query warm-starts the
/// re-solve, and its answers match a cold rebuild on the mutated graph;
/// heavy churn degrades the lazy re-solve to cold up front.
#[test]
fn lazy_resolve_warm_starts_and_matches_cold_rebuild() {
    let gg = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 9 });
    let queries = query_mix();
    let mut s = ServeSession::new(gg.graph.clone(), serve_cfg(3, 1));
    s.answer_batch(&queries).unwrap();
    assert_eq!(s.last_solve_path(), Some(SolvePath::Cold), "first solve has no seed");

    // A reweight burst well under the churn threshold.
    let batch: Vec<EdgeDelta> = gg
        .graph
        .edges()
        .iter()
        .take(6)
        .map(|e| EdgeDelta::Reweight { u: e.u as usize, v: e.v as usize, w: e.w * 1.1 })
        .collect();
    s.apply_batch(&batch).unwrap();
    assert!(!s.cache_valid());

    let warm_answers = s.answer_batch(&queries).unwrap();
    assert_eq!(s.solves(), 2);
    assert_eq!(
        s.last_solve_path(),
        Some(SolvePath::Warm),
        "small churn must warm-start the lazy re-solve"
    );

    // Cold-rebuild oracle: a fresh session over the mutated graph.
    let mut cold = ServeSession::new(s.graph().clone(), serve_cfg(3, 1));
    let cold_answers = cold.answer_batch(&queries).unwrap();
    assert_eq!(cold.last_solve_path(), Some(SolvePath::Cold));

    for (i, (wa, ca)) in warm_answers.iter().zip(cold_answers.iter()).enumerate() {
        match (wa, ca) {
            (Answer::Score(a), Answer::Score(b)) => {
                assert!((a - b).abs() < 1e-6, "query {i}: warm score {a} vs cold {b}");
            }
            (Answer::Cluster { cluster: a, distance: da }, Answer::Cluster { cluster: b, distance: db }) => {
                assert_eq!(a, b, "query {i}: warm and cold disagree on the cluster");
                assert!((da - db).abs() < 1e-6, "query {i}: distance {da} vs {db}");
            }
            (Answer::Neighbors(na), Answer::Neighbors(nb)) => {
                assert_eq!(na.len(), nb.len(), "query {i}");
                // Near-ties inside a clique may reorder between two
                // independent solves; the semantic contract is that both
                // neighbor sets stay inside the query node's clique.
                let clique_of = |v: usize| gg.labels[v];
                let qu = match queries[i] {
                    Query::TopK { u, .. } => u,
                    _ => unreachable!(),
                };
                for &(v, score) in na.iter().chain(nb.iter()) {
                    assert_eq!(
                        clique_of(v),
                        clique_of(qu),
                        "query {i}: neighbor {v} (score {score}) left the clique"
                    );
                }
            }
            _ => panic!("query {i}: warm and cold answer kinds diverged"),
        }
    }

    // Heavy churn: reweight more than warm_volume_frac of the edges, and
    // the next lazy re-solve must run cold by policy.
    let m = s.graph().num_edges();
    let big: Vec<EdgeDelta> = s
        .graph()
        .edges()
        .iter()
        .take(m / 2 + 1)
        .map(|e| EdgeDelta::Reweight { u: e.u as usize, v: e.v as usize, w: e.w * 0.9 })
        .collect();
    s.apply_batch(&big).unwrap();
    s.answer_batch(&queries[..1]).unwrap();
    assert_eq!(s.solves(), 3);
    assert_eq!(
        s.last_solve_path(),
        Some(SolvePath::Cold),
        "churn above warm_volume_frac must degrade the lazy re-solve to cold"
    );
}
