//! Cross-layer integration: the AOT artifacts (L1 Pallas + L2 JAX, lowered
//! to HLO text) executed from the rust runtime must agree with the native
//! f64 implementations to f32 tolerance, and the XLA-backed pipeline must
//! converge end-to-end.
//!
//! Requires `make artifacts` (skipped with a notice when absent, so plain
//! `cargo test` works on a fresh checkout) **and** a build with the `xla`
//! cargo feature: without it `sped::runtime` is the API-identical offline
//! stub, whose `Runtime::load_dir` reports the missing feature instead of
//! executing artifacts.

use sped::graph::gen::{cliques, CliqueSpec};
use sped::linalg::dmat::DMat;
use sped::linalg::matmul::matmul;
use sped::runtime::{pad_matrix, Runtime};
use sped::transforms::TransformKind;
use sped::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    if !cfg!(feature = "xla") {
        eprintln!("[skip] built without the `xla` feature — rebuild with `--features xla` to run XLA integration tests");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.cfg").exists() {
        Some(dir.to_string_lossy().into_owned())
    } else {
        eprintln!("[skip] artifacts/ missing — run `make artifacts` first");
        None
    }
}

fn runtime() -> Option<Runtime> {
    artifacts_dir().map(|d| Runtime::load_dir(d).expect("artifacts load"))
}

fn random_mat(seed: u64, r: usize, c: usize) -> DMat {
    let mut rng = Rng::new(seed);
    DMat::from_fn(r, c, |_, _| rng.normal())
}

#[test]
fn manifest_lists_all_kinds() {
    let Some(rt) = runtime() else { return };
    for kind in ["oja_chunk", "eg_chunk", "poly_horner", "matpow", "matvec", "stoch_chunk"] {
        assert!(
            rt.best_fit(kind, 1).is_ok(),
            "missing artifact kind {kind}"
        );
    }
}

#[test]
fn xla_matvec_matches_native() {
    let Some(rt) = runtime() else { return };
    let art = rt.best_fit("matvec", 64).unwrap();
    let n = art.meta.n;
    let k = art.meta.k;
    let m = random_mat(1, n, n);
    let v = random_mat(2, n, k);
    let mut op = sped::runtime::XlaDenseOp::new(art, &m).unwrap();
    use sped::solvers::MatVecOp;
    let got = op.apply(&v);
    let want = matmul(&m, &v);
    let rel = (&got - &want).max_abs() / want.max_abs();
    assert!(rel < 1e-4, "rel err {rel}");
}

#[test]
fn xla_poly_build_matches_native_horner() {
    let Some(rt) = runtime() else { return };
    let art = rt.best_fit("poly_horner", 32).unwrap();
    let n = art.meta.n;
    // Small-spectral-radius symmetric matrix keeps f32 Horner well inside
    // tolerance even at degree 256 (padded coeffs are zero).
    let mut l = random_mat(3, n, n);
    l.symmetrize();
    l.scale(0.1);
    let coeffs = [0.3, -0.7, 0.2, 0.05];
    let shift = 0.1;
    let got = sped::runtime::xla_poly_build(&art, &l, shift, &coeffs).unwrap();
    let want = sped::transforms::SeriesForm { shift, coeffs: coeffs.to_vec() }.eval_matrix(&l);
    let rel = (&got - &want).max_abs() / want.max_abs().max(1e-9);
    assert!(rel < 1e-3, "rel err {rel}");
}

#[test]
fn xla_matpow_matches_native() {
    let Some(rt) = runtime() else { return };
    let art = rt.best_fit("matpow", 16).unwrap();
    let n = art.meta.n;
    let mut b = random_mat(4, n, n);
    b.symmetrize();
    b.scale(0.5 / n as f64); // ρ ≪ 1: powers stay tame in f32
    b.add_diag(0.9);
    for p in [1u64, 2, 7, 251] {
        let got = sped::runtime::xla_matpow(&art, &b, p).unwrap();
        let want = sped::linalg::funcs::matpow(&b, p);
        let rel = (&got - &want).max_abs() / want.max_abs().max(1e-12);
        assert!(rel < 2e-3, "p={p}: rel err {rel}");
    }
}

#[test]
fn xla_oja_chunk_converges() {
    let Some(rt) = runtime() else { return };
    let art = rt.best_fit("oja_chunk", 48).unwrap();
    let size = art.meta.n;
    let ak = art.meta.k;
    // Well-clustered graph, NegExp reversal → top-k problem for the chunk.
    let g = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 5 }).graph;
    let sm = sped::transforms::build_solver_matrix(
        &g.laplacian(),
        TransformKind::NegExp,
        &sped::transforms::BuildOptions::default(),
    )
    .unwrap();
    let m = pad_matrix(&sm.m, size, -1.0);
    let e = sped::linalg::eigh(&g.laplacian()).unwrap();
    let v_star = sped::runtime::pad_rows(&e.bottom_k(ak), size);
    let v0 = sped::runtime::pad_rows(&sped::solvers::random_init(48, ak, 11), size);
    let runner = sped::runtime::XlaChunkRunner::new(art.clone(), &m).unwrap();
    let mut v = v0;
    let mut in_graph_err = f64::INFINITY;
    for _ in 0..40 {
        let out = runner.run_chunk(&v, &v_star, 0.5).unwrap();
        v = out.v;
        in_graph_err = *out.errors.last().unwrap();
    }
    // k=3 restricted: eigenvectors 4..8 of a 3-clique graph live in a
    // near-degenerate eigenspace, so the full k=8 subspace error plateaus
    // by construction; the cluster subspace itself must be recovered.
    let v3 = DMat::from_fn(48, 3, |i, j| v[(i, j)]);
    let err3 = sped::linalg::metrics::subspace_error(&e.bottom_k(3), &v3);
    assert!(err3 < 1e-2, "k=3 subspace error {err3} (in-graph k=8: {in_graph_err})");
    assert!(in_graph_err < 0.7, "in-graph metric not even plateaued: {in_graph_err}");
}

#[test]
fn xla_eg_chunk_runs_and_improves() {
    let Some(rt) = runtime() else { return };
    let art = rt.best_fit("eg_chunk", 48).unwrap();
    let size = art.meta.n;
    let ak = art.meta.k;
    let g = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 7 }).graph;
    let sm = sped::transforms::build_solver_matrix(
        &g.laplacian(),
        TransformKind::NegExp,
        &sped::transforms::BuildOptions::default(),
    )
    .unwrap();
    let m = pad_matrix(&sm.m, size, -1.0);
    let e = sped::linalg::eigh(&g.laplacian()).unwrap();
    let v_star = sped::runtime::pad_rows(&e.bottom_k(ak), size);
    let runner = sped::runtime::XlaChunkRunner::new(art.clone(), &m).unwrap();
    let mut v = sped::runtime::pad_rows(&sped::solvers::random_init(48, ak, 13), size);
    let first = runner.run_chunk(&v, &v_star, 0.3).unwrap();
    let v3_0 = DMat::from_fn(48, 3, |i, j| first.v[(i, j)]);
    let e0 = sped::linalg::metrics::subspace_error(&e.bottom_k(3), &v3_0);
    v = first.v.clone();
    for _ in 0..30 {
        let out = runner.run_chunk(&v, &v_star, 0.3).unwrap();
        v = out.v;
    }
    let v3 = DMat::from_fn(48, 3, |i, j| v[(i, j)]);
    let last = sped::linalg::metrics::subspace_error(&e.bottom_k(3), &v3);
    assert!(last < e0 * 0.2 || last < 1e-2, "no improvement: {e0} -> {last}");
    // Alignment matrix has sane shape + range.
    assert!(first.aligns.rows() == art.meta.t);
    assert!(first.aligns.data().iter().all(|&a| (-1e-3..=1.0 + 1e-3).contains(&a)));
}

#[test]
fn xla_pipeline_end_to_end_clusters() {
    let Some(dir) = artifacts_dir() else { return };
    use sped::pipeline::{Backend, Pipeline, PipelineConfig};
    let gg = cliques(&CliqueSpec { n: 60, k: 3, max_short_circuit: 2, seed: 9 });
    let cfg = PipelineConfig {
        k: 3,
        transform: TransformKind::LimitNegExp { ell: 251 },
        solver: "oja".into(),
        eta: 0.5,
        steps: 2000,
        eval_every: 25,
        stop_error: 1e-4,
        backend: Backend::Xla { artifacts_dir: dir },
        ..Default::default()
    };
    let out = Pipeline::new(cfg).run(&gg.graph).unwrap();
    let last = out.history.last().unwrap();
    assert!(last.subspace_error < 1e-2, "err {}", last.subspace_error);
    let ari = sped::cluster::adjusted_rand_index(
        &out.clustering.as_ref().unwrap().assignments,
        &gg.labels,
    );
    assert!(ari > 0.9, "ARI {ari}");
}
