//! Acceptance harness for locked-convergence deflation and sharded
//! polynomial applies:
//!
//! 1. locked (`--ritz-lock on`) and fixed-block (`off`) solves agree to
//!    tolerance across every generator × both Laplacian variants ×
//!    1/2/8 workers, and the locked solve spends **strictly fewer** SpMM
//!    column sweeps — the whole point of deflation;
//! 2. the locked solve is bitwise worker-invariant, like everything else;
//! 3. the sharded matrix-free operator (`--shards N`) is **bitwise**
//!    identical to the unsharded one over S ∈ {1, 2, 7} × worker counts,
//!    including shard counts above the node count (empty shards) and
//!    warm-started solves, with honest halo-volume accounting.

use sped::graph::gen::{
    barabasi_albert, barbell, cliques, erdos_renyi, grid2d, path, ring, ring_of_cliques, sbm,
    CliqueSpec,
};
use sped::graph::Graph;
use sped::linalg::dmat::DMat;
use sped::linalg::eigh;
use sped::linalg::metrics::subspace_error;
use sped::pipeline::{Pipeline, PipelineConfig};
use sped::solvers::ritz::{ritz_solve, RitzConfig, RitzResult};
use sped::solvers::SparsePolyOp;
use sped::transforms::{BuildOptions, OpMode, TransformKind};

/// Every generator in the crate, at a size where the eigh oracle per
/// (generator × variant) stays cheap.
fn generator_zoo(n: usize, seed: u64) -> Vec<(&'static str, Graph)> {
    vec![
        (
            "cliques",
            cliques(&CliqueSpec { n, k: (n / 6).max(1), max_short_circuit: 3, seed }).graph,
        ),
        ("sbm", sbm(&[n / 2, n - n / 2], 0.8, 0.05, seed).graph),
        ("erdos_renyi", erdos_renyi(n, 0.3, seed).graph),
        ("grid2d", grid2d(n / 3 + 1, 3).graph),
        ("path", path(n).graph),
        ("ring", ring(n.max(3)).graph),
        ("barbell", barbell(n / 2 + 2).graph),
        ("ring_of_cliques", ring_of_cliques(3, n / 3 + 2, seed).graph),
        ("barabasi_albert", barabasi_albert(n.max(5), 3, seed).graph),
    ]
}

/// The subspace dimension with the widest relative spectral separation
/// among k ∈ {2, 3, 4} — keeps the harness off exactly-degenerate
/// boundaries, where "the bottom-k subspace" is not even well defined and
/// two converged solves may legitimately disagree.
fn pick_k(values: &[f64]) -> usize {
    let lam_max = values.last().copied().unwrap_or(1.0).max(1e-12);
    let mut best = (2usize, f64::NEG_INFINITY);
    for k in 2..=4usize.min(values.len() - 1) {
        let gap = (values[k] - values[k - 1]) / lam_max;
        if gap > best.1 {
            best = (k, gap);
        }
    }
    best.0
}

fn bitwise_eq(a: &DMat, b: &DMat) -> bool {
    a.rows() == b.rows()
        && a.cols() == b.cols()
        && a.data().iter().zip(b.data().iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn solve(
    lc: sped::linalg::sparse::CsrMat,
    k: usize,
    lock: bool,
    threads: usize,
    shards: usize,
    warm: Option<DMat>,
) -> RitzResult {
    let opts = BuildOptions { threads, shards, ..BuildOptions::default() };
    let mut op =
        SparsePolyOp::from_csr(lc, TransformKind::LimitNegExp { ell: 51 }, &opts).unwrap();
    let cfg = RitzConfig {
        k,
        tol: 1e-10,
        max_iters: 4000,
        lock,
        warm_start: warm,
        ..Default::default()
    };
    ritz_solve(&mut op, &cfg).unwrap()
}

#[test]
fn locked_beats_fixed_block_across_zoo_variants_and_workers() {
    for (name, g) in generator_zoo(22, 3) {
        for (variant, ld, mk_csr) in [
            ("laplacian", g.laplacian(), Graph::laplacian_csr as fn(&Graph) -> _),
            ("normalized", g.normalized_laplacian(), Graph::normalized_laplacian_csr),
        ] {
            let tag = format!("{name}/{variant}");
            let k = pick_k(&eigh(&ld).unwrap().values);
            let fixed = solve(mk_csr(&g), k, false, 1, 0, None);
            assert!(fixed.converged, "{tag}: fixed-block solve unconverged");
            assert_eq!(fixed.locked, 0, "{tag}: lock=off must never lock");
            // Fixed block: every sweep runs the full auto block (k + 2).
            assert_eq!(fixed.col_sweeps, fixed.total_sweeps * (k + 2), "{tag}");

            let locked = solve(mk_csr(&g), k, true, 1, 0, None);
            assert!(locked.converged, "{tag}: locked solve unconverged");
            assert_eq!(locked.locked, k, "{tag}: converged ⟺ all k pairs locked");
            assert_eq!(locked.locked_history.len(), locked.iterations, "{tag}");
            assert!(
                locked.locked_history.windows(2).all(|w| w[0] <= w[1]),
                "{tag}: locked count must be monotone"
            );
            // The acceptance claim: same subspace, strictly fewer SpMM
            // column sweeps than the fixed-block run paid.
            let err = subspace_error(&fixed.embedding, &locked.embedding);
            assert!(err < 1e-6, "{tag}: locked vs fixed subspace err {err:.3e}");
            assert!(
                locked.col_sweeps < fixed.col_sweeps,
                "{tag}: locked {} column sweeps vs fixed {}",
                locked.col_sweeps,
                fixed.col_sweeps
            );
            for (a, b) in fixed.values.iter().zip(locked.values.iter()) {
                assert!((a - b).abs() <= 1e-8 * a.abs().max(1.0), "{tag}: {a} vs {b}");
            }

            // Deflation keeps the bitwise worker-invariance contract.
            for threads in [2usize, 8] {
                let other = solve(mk_csr(&g), k, true, threads, 0, None);
                assert_eq!(locked.iterations, other.iterations, "{tag} @{threads}");
                assert_eq!(locked.col_sweeps, other.col_sweeps, "{tag} @{threads}");
                assert_eq!(locked.locked_history, other.locked_history, "{tag} @{threads}");
                assert!(
                    bitwise_eq(&locked.embedding, &other.embedding),
                    "{tag}: locked embedding diverged at {threads} workers"
                );
            }
        }
    }
}

#[test]
fn sharded_solves_are_bitwise_equal_to_unsharded() {
    // cliques(36): every shard non-empty at S ≤ 7. path(5): S = 7 exceeds
    // the node count, so partitioning yields empty shards — which must be
    // harmless, not special-cased.
    let graphs: Vec<(&str, Graph)> = vec![
        ("cliques", cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 7 }).graph),
        ("path5", path(5).graph),
    ];
    for (name, g) in &graphs {
        let k = 2usize;
        let base = solve(g.laplacian_csr(), k, true, 1, 0, None);
        assert_eq!(base.halo_volume, 0, "{name}: unsharded exchanges nothing");
        for shards in [1usize, 2, 7] {
            for threads in [1usize, 2, 8] {
                let sh = solve(g.laplacian_csr(), k, true, threads, shards, None);
                assert_eq!(base.iterations, sh.iterations, "{name} S={shards} @{threads}");
                assert_eq!(base.col_sweeps, sh.col_sweeps, "{name} S={shards} @{threads}");
                assert!(
                    bitwise_eq(&base.embedding, &sh.embedding),
                    "{name}: sharded embedding diverged at S={shards}, {threads} workers"
                );
                for (a, b) in base.residuals.iter().zip(sh.residuals.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{name} S={shards} @{threads}");
                }
                // Halo accounting: rows-per-sweep × column sweeps, zero
                // only when nothing crosses a shard boundary.
                let opts = BuildOptions { shards, ..BuildOptions::default() };
                let op = SparsePolyOp::from_csr(
                    g.laplacian_csr(),
                    TransformKind::LimitNegExp { ell: 51 },
                    &opts,
                )
                .unwrap();
                assert_eq!(op.shard_count(), shards, "{name}");
                assert_eq!(
                    sh.halo_volume,
                    op.halo_rows() * sh.col_sweeps,
                    "{name} S={shards} @{threads}"
                );
                if shards > 1 && g.num_edges() > 0 {
                    assert!(sh.halo_volume > 0, "{name} S={shards}: halo volume missing");
                }
            }
        }
    }
}

#[test]
fn sharded_warm_started_solves_stay_bitwise_and_compose_with_locking() {
    let g = cliques(&CliqueSpec { n: 48, k: 4, max_short_circuit: 2, seed: 13 }).graph;
    let k = 4usize;
    // A converged embedding from a looser solve seeds the warm runs.
    let seed_emb = {
        let opts = BuildOptions::default();
        let mut op = SparsePolyOp::from_csr(
            g.laplacian_csr(),
            TransformKind::LimitNegExp { ell: 51 },
            &opts,
        )
        .unwrap();
        let cfg = RitzConfig { k, tol: 1e-4, max_iters: 500, ..Default::default() };
        ritz_solve(&mut op, &cfg).unwrap().embedding
    };
    let cold = solve(g.laplacian_csr(), k, true, 1, 0, None);
    let warm = solve(g.laplacian_csr(), k, true, 1, 0, Some(seed_emb.clone()));
    assert!(warm.converged && cold.converged);
    assert!(
        warm.col_sweeps < cold.col_sweeps,
        "warm locked solve must be cheaper: {} vs {}",
        warm.col_sweeps,
        cold.col_sweeps
    );
    for shards in [2usize, 7] {
        for threads in [1usize, 2, 8] {
            let sh = solve(g.laplacian_csr(), k, true, threads, shards, Some(seed_emb.clone()));
            assert_eq!(warm.iterations, sh.iterations, "S={shards} @{threads}");
            assert_eq!(warm.col_sweeps, sh.col_sweeps, "S={shards} @{threads}");
            assert!(
                bitwise_eq(&warm.embedding, &sh.embedding),
                "warm sharded embedding diverged at S={shards}, {threads} workers"
            );
            assert!(sh.halo_volume > 0, "S={shards}: halo volume missing");
        }
    }
}

#[test]
fn pipeline_with_shards_matches_unsharded_end_to_end() {
    let g = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 5 }).graph;
    let run = |shards: usize, threads: usize| {
        let mut cfg = PipelineConfig {
            k: 3,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "ritz".into(),
            ritz_tol: 1e-10,
            ritz_max_iters: 500,
            op_mode: OpMode::MatrixFree,
            ground_truth: false,
            threads,
            ..Default::default()
        };
        cfg.build.shards = shards;
        Pipeline::new(cfg).run(&g).unwrap()
    };
    let base = run(0, 1);
    let rz = base.ritz.as_ref().unwrap();
    assert_eq!(rz.halo_volume, 0);
    for shards in [1usize, 2, 7] {
        for threads in [1usize, 2, 8] {
            let out = run(shards, threads);
            assert!(
                bitwise_eq(&base.embedding, &out.embedding),
                "pipeline embedding diverged at S={shards}, {threads} workers"
            );
            let srz = out.ritz.as_ref().unwrap();
            assert_eq!(rz.iterations, srz.iterations, "S={shards} @{threads}");
            assert_eq!(rz.col_sweeps, srz.col_sweeps, "S={shards} @{threads}");
            assert_eq!(
                base.clustering.as_ref().unwrap().assignments,
                out.clustering.as_ref().unwrap().assignments,
                "S={shards} @{threads}"
            );
            if shards > 1 {
                assert!(srz.halo_volume > 0, "S={shards}: halo volume missing");
            }
        }
    }
}
