//! Basis-equivalence harness (the contracts of the basis-generic
//! polynomial subsystem, exhaustively):
//!
//! 1. monomial↔Chebyshev coefficient conversion round-trips **exactly**
//!    for degrees 0..=8 (dyadic coefficients bit-for-bit, random
//!    coefficients ≤1e-12);
//! 2. `apply_bundle` in both bases agrees ≤1e-9 with the eigh-based
//!    scalar spectrum map on every graph generator × both Laplacian
//!    variants, at the acceptance degrees ℓ ∈ {15, 251};
//! 3. the fused `spmm_step_into` kernel is **bitwise** equal to the
//!    unfused SpMM + `scale` + `axpy` composition for every bundle width
//!    k ∈ 1..=17 × 1/2/8 workers — and therefore the refactored
//!    monomial-basis hot loops (Horner, NegPower) are bitwise-identical
//!    to the pre-refactor three-pass implementations;
//! 4. the Chebyshev pipeline is bitwise-deterministic across 1/2/8
//!    workers end to end.

use sped::graph::gen::{
    barabasi_albert, barbell, cliques, erdos_renyi, grid2d, path, ring, ring_of_cliques, sbm,
    CliqueSpec,
};
use sped::graph::Graph;
use sped::linalg::matmul::matmul;
use sped::linalg::sparse::{spmm_into, spmm_step, CsrMat};
use sped::linalg::DMat;
use sped::pipeline::{Pipeline, PipelineConfig};
use sped::transforms::{
    chebyshev_to_monomial, monomial_to_chebyshev, BuildOptions, OpMode, PolyBasis, SeriesForm,
    TransformKind,
};
use sped::util::rng::Rng;

/// Every generator in the crate, at a size small enough that the full
/// kind × variant × degree sweep stays cheap.
fn generator_zoo(n: usize, seed: u64) -> Vec<(&'static str, Graph)> {
    vec![
        (
            "cliques",
            cliques(&CliqueSpec { n, k: (n / 6).max(1), max_short_circuit: 3, seed }).graph,
        ),
        ("sbm", sbm(&[n / 2, n - n / 2], 0.8, 0.05, seed).graph),
        ("erdos_renyi", erdos_renyi(n, 0.3, seed).graph),
        ("grid2d", grid2d(n / 3 + 1, 3).graph),
        ("path", path(n).graph),
        ("ring", ring(n.max(3)).graph),
        ("barbell", barbell(n / 2 + 2).graph),
        ("ring_of_cliques", ring_of_cliques(3, n / 3 + 2, seed).graph),
        ("barabasi_albert", barabasi_albert(n.max(5), 3, seed).graph),
    ]
}

fn bitwise_eq(a: &DMat, b: &DMat) -> bool {
    a.rows() == b.rows()
        && a.cols() == b.cols()
        && a.data().iter().zip(b.data().iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn coefficient_roundtrip_exact_for_degrees_0_to_8() {
    // Dyadic coefficients: exact (bit-for-bit) both ways.
    for d in 0..=8usize {
        let mono: Vec<f64> = (0..=d).map(|i| (i as f64 - 2.0) * 0.25).collect();
        let rt = chebyshev_to_monomial(&monomial_to_chebyshev(&mono));
        for (a, b) in mono.iter().zip(rt.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "monomial round-trip, degree {d}");
        }
        let cheb: Vec<f64> = (0..=d).map(|i| 2.0 - i as f64 * 0.5).collect();
        let rt = monomial_to_chebyshev(&chebyshev_to_monomial(&cheb));
        for (a, b) in cheb.iter().zip(rt.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "chebyshev round-trip, degree {d}");
        }
    }
    // Random coefficients: round-trip to conversion rounding.
    let mut rng = Rng::new(3);
    for d in 0..=8usize {
        let mono: Vec<f64> = (0..=d).map(|_| rng.normal()).collect();
        let rt = chebyshev_to_monomial(&monomial_to_chebyshev(&mono));
        for (a, b) in mono.iter().zip(rt.iter()) {
            assert!((a - b).abs() <= 1e-12 * a.abs().max(1.0), "degree {d}: {a} vs {b}");
        }
    }
}

/// The polynomial each transform applies, evaluated in the requested basis
/// against the scaled CSR operator (spectrum in [0, 1]).
fn apply_in_basis(
    kind: TransformKind,
    basis: PolyBasis,
    l: &CsrMat,
    v: &DMat,
    threads: usize,
) -> DMat {
    match basis {
        PolyBasis::Chebyshev => {
            kind.cheb_series(0.0, 1.0).expect("polynomial kind").apply_bundle(l, v, threads)
        }
        PolyBasis::Monomial => match kind {
            TransformKind::LimitNegExp { ell } => {
                // The monomial path's repeated-multiply special case
                // (SparsePolyOp::NegPower): W ← (I − L/ℓ)·W, ℓ times.
                let inv = -1.0 / ell as f64;
                let mut w = v.clone();
                let mut t = DMat::zeros(v.rows(), v.cols());
                for _ in 0..ell {
                    sped::linalg::sparse::spmm_step_into(l, &w, v, 1.0, inv, 0.0, &mut t, threads);
                    std::mem::swap(&mut w, &mut t);
                }
                w.scale(-1.0);
                w
            }
            _ => kind.series().expect("series kind").apply_bundle(l, v, threads),
        },
    }
}

#[test]
fn both_bases_match_scalar_map_on_every_generator_and_laplacian() {
    // ≤1e-9 against the eigh-based spectrum map V·diag(f(λ))·Vᵀ·X, for
    // every generator × both Laplacian variants × every series kind, at
    // the acceptance degrees ℓ ∈ {15, 251}.
    for (name, g) in generator_zoo(20, 5) {
        let n = g.num_nodes();
        let mut rng = Rng::new(n as u64 ^ 0xBA);
        let x = DMat::from_fn(n, 4, |_, _| rng.normal());
        for (variant, dense, sparse) in [
            ("laplacian", g.laplacian(), g.laplacian_csr()),
            ("normalized", g.normalized_laplacian(), g.normalized_laplacian_csr()),
        ] {
            // Scale the spectrum into [0, 1] (the prescaled regime where
            // every series converges), identically on both representations.
            let e_raw = sped::linalg::eigh(&dense).unwrap();
            let lam = e_raw.lambda_max().max(1e-12) * 1.001;
            let mut dense = dense;
            dense.scale(1.0 / lam);
            let mut sparse = sparse;
            sparse.scale_values(1.0 / lam);
            let e = sped::linalg::eigh(&dense).unwrap();
            for ell in [15usize, 251] {
                for kind in [
                    TransformKind::TaylorNegExp { ell },
                    TransformKind::TaylorLog { ell, eps: 0.05 },
                    TransformKind::LimitNegExp { ell },
                ] {
                    // Ground truth: V·diag(f(λ))·(Vᵀ·X).
                    let mut vt_x = matmul(&e.vectors.t(), &x);
                    for (i, &lam_i) in e.values.iter().enumerate() {
                        let f = kind.scalar_map(lam_i);
                        for j in 0..vt_x.cols() {
                            vt_x[(i, j)] *= f;
                        }
                    }
                    let truth = matmul(&e.vectors, &vt_x);
                    for basis in [PolyBasis::Monomial, PolyBasis::Chebyshev] {
                        let got = apply_in_basis(kind, basis, &sparse, &x, 1);
                        let err = (&got - &truth).max_abs();
                        assert!(
                            err < 1e-9,
                            "{name}/{variant} {kind} {basis}: scalar-map divergence {err}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn fused_step_kernel_bitwise_equals_unfused_composition_everywhere() {
    // The satellite contract: spmm_step_into ≡ spmm + scale + axpy
    // (conditional skips included) bit for bit, across every blocked
    // width, the streaming fallback, and 1/2/8 workers, on a real
    // Laplacian with structural-zero diagonals.
    let g = cliques(&CliqueSpec { n: 29, k: 3, max_short_circuit: 2, seed: 9 }).graph;
    let l = g.laplacian_csr();
    let n = g.num_nodes();
    let cases: &[(f64, f64, f64)] = &[
        (-0.95, 1.0, 0.04),      // Horner step: α = −shift, β = 1, γ = cᵢ
        (1.0, -1.0 / 251.0, 0.0), // NegPower step: γ = 0
        (-1.3, 0.7, -1.0),       // Chebyshev step: α = 2b, β = 2a, γ = −1
        (0.0, 1.0, 0.0),         // bare SpMM
    ];
    for k in 1..=17usize {
        let mut rng = Rng::new(k as u64 + 1000);
        let w = DMat::from_fn(n, k, |_, _| rng.normal());
        let u = DMat::from_fn(n, k, |_, _| rng.normal());
        for &(alpha, beta, gamma) in cases {
            // Reference: the pre-refactor three-pass composition.
            let mut want = DMat::zeros(n, k);
            spmm_into(&l, &w, &mut want, 1);
            want.scale(beta);
            if alpha != 0.0 {
                want.axpy(alpha, &w);
            }
            if gamma != 0.0 {
                want.axpy(gamma, &u);
            }
            for workers in [1usize, 2, 8] {
                let got = spmm_step(&l, &w, &u, alpha, beta, gamma, workers);
                assert!(
                    bitwise_eq(&got, &want),
                    "k={k}, {workers} workers, (α,β,γ)=({alpha},{beta},{gamma})"
                );
            }
        }
    }
}

#[test]
fn monomial_hot_loops_bitwise_match_pre_refactor_composition() {
    // The refactored SeriesForm::apply_bundle (fused) must reproduce the
    // historical unfused Horner loop bit for bit — the monomial
    // bitwise-compat guarantee — across worker counts and widths.
    let g = cliques(&CliqueSpec { n: 30, k: 3, max_short_circuit: 2, seed: 4 }).graph;
    let mut l = g.laplacian_csr();
    l.scale_values(0.1); // keep high powers tame
    let series = TransformKind::TaylorNegExp { ell: 21 }.series().unwrap();
    for k in [1usize, 4, 8, 16, 17] {
        let mut rng = Rng::new(k as u64 + 77);
        let v = DMat::from_fn(30, k, |_, _| rng.normal());
        // Pre-refactor reference: SpMM, then conditional axpys.
        let d = series.coeffs.len() - 1;
        let mut r = v.clone();
        r.scale(series.coeffs[d]);
        let mut t = DMat::zeros(30, k);
        for i in (0..d).rev() {
            spmm_into(&l, &r, &mut t, 1);
            if series.shift != 0.0 {
                t.axpy(-series.shift, &r);
            }
            if series.coeffs[i] != 0.0 {
                t.axpy(series.coeffs[i], &v);
            }
            std::mem::swap(&mut r, &mut t);
        }
        for workers in [1usize, 2, 8] {
            let got = series.apply_bundle(&l, &v, workers);
            assert!(bitwise_eq(&got, &r), "Horner fused/unfused k={k}, {workers} workers");
        }
    }
}

#[test]
fn chebyshev_pipeline_bitwise_deterministic_across_workers() {
    let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 6 });
    let mk = |threads| PipelineConfig {
        k: 3,
        transform: TransformKind::LimitNegExp { ell: 51 },
        solver: "subspace".into(),
        steps: 200,
        eval_every: 20,
        stop_error: 0.0,
        op_mode: OpMode::MatrixFree,
        ground_truth: false,
        threads,
        build: BuildOptions { basis: PolyBasis::Chebyshev, ..BuildOptions::default() },
        ..Default::default()
    };
    let serial = Pipeline::new(mk(1)).run(&gg.graph).unwrap();
    for threads in [2usize, 8] {
        let par = Pipeline::new(mk(threads)).run(&gg.graph).unwrap();
        assert!(
            bitwise_eq(&serial.embedding, &par.embedding),
            "chebyshev pipeline diverged at {threads} workers"
        );
        assert_eq!(serial.lambda_star.to_bits(), par.lambda_star.to_bits());
    }
}

#[test]
fn series_form_chebyshev_conversion_consistency() {
    // SeriesForm → ChebSeries → SeriesForm preserves the polynomial: both
    // scalar evaluations agree across the domain for every Table-2 series
    // kind that has a monomial form, at a conversion-friendly degree.
    for kind in [
        TransformKind::TaylorNegExp { ell: 8 },
        TransformKind::TaylorLog { ell: 8, eps: 0.05 },
    ] {
        let sf = kind.series().unwrap();
        let cheb = sped::transforms::ChebSeries::from_series_form(&sf, 0.0, 1.0);
        let back = cheb.to_series_form();
        for i in 0..=32 {
            let x = i as f64 / 32.0;
            let a = sf.eval_scalar(x);
            let b = cheb.eval_scalar(x);
            let c = back.eval_scalar(x);
            assert!((a - b).abs() < 1e-10, "{kind} fwd at x={x}: {a} vs {b}");
            assert!((a - c).abs() < 1e-10, "{kind} round-trip at x={x}: {a} vs {c}");
        }
    }
    // And an explicitly-shifted form round-trips too.
    let sf = SeriesForm { shift: 0.3, coeffs: vec![1.0, -0.5, 0.25, 2.0] };
    let cheb = sped::transforms::ChebSeries::from_series_form(&sf, -1.0, 2.0);
    for i in 0..=30 {
        let x = -1.0 + 3.0 * i as f64 / 30.0;
        assert!((sf.eval_scalar(x) - cheb.eval_scalar(x)).abs() < 1e-11, "x={x}");
    }
}
