//! Kernel-equivalence + reordering-invariance harness (the determinism
//! contract of the matrix-free path, exhaustively):
//!
//! 1. the register-blocked SpMM family is **bitwise** equal to the
//!    streaming reference kernel and to the dense `matmul` of the
//!    densified matrix, for every bundle width k ∈ 1..=17 (all 16 blocked
//!    widths plus the first streaming-fallback width), every graph
//!    generator × both Laplacian variants × 1/2/8 workers, including
//!    empty rows and structural-zero diagonals — under a `--features simd`
//!    build the same sweep exercises the portable-SIMD kernels, since they
//!    ride the identical [`sped::linalg::sparse::spmm`] dispatch;
//! 2. the halo-exchange sharded SpMM ([`sped::linalg::shard::ShardedCsr`])
//!    is bitwise equal to the unsharded kernel at every shard count ×
//!    worker count, empty shards and isolated nodes included;
//! 3. RCM row reordering is a pure relabeling: permutations round-trip,
//!    bandwidth shrinks on a scrambled power-law sample, and the pipeline
//!    recovers the identical partition (after un-permutation) with the
//!    identical λ*.

use sped::graph::gen::{
    barabasi_albert, barbell, cliques, erdos_renyi, grid2d, path, ring, ring_of_cliques, sbm,
    CliqueSpec,
};
use sped::graph::{invert_permutation, Graph, Reorder};
use sped::linalg::sparse::{spmm, spmm_streaming, CsrMat};
use sped::linalg::DMat;
use sped::pipeline::{Pipeline, PipelineConfig};
use sped::transforms::{OpMode, TransformKind};
use sped::util::rng::Rng;

/// Every generator in the crate, at a size small enough that the full
/// width × variant × worker sweep stays cheap.
fn generator_zoo(n: usize, seed: u64) -> Vec<(&'static str, Graph)> {
    vec![
        (
            "cliques",
            cliques(&CliqueSpec { n, k: (n / 6).max(1), max_short_circuit: 3, seed }).graph,
        ),
        ("sbm", sbm(&[n / 2, n - n / 2], 0.8, 0.05, seed).graph),
        ("erdos_renyi", erdos_renyi(n, 0.3, seed).graph),
        ("grid2d", grid2d(n / 3 + 1, 3).graph),
        ("path", path(n).graph),
        ("ring", ring(n.max(3)).graph),
        ("barbell", barbell(n / 2 + 2).graph),
        ("ring_of_cliques", ring_of_cliques(3, n / 3 + 2, seed).graph),
        ("barabasi_albert", barabasi_albert(n.max(5), 3, seed).graph),
    ]
}

fn bitwise_eq(a: &DMat, b: &DMat) -> bool {
    a.rows() == b.rows()
        && a.cols() == b.cols()
        && a.data().iter().zip(b.data().iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn blocked_spmm_bitwise_equals_streaming_and_dense_everywhere() {
    for (name, g) in generator_zoo(22, 3) {
        let nn = g.num_nodes();
        for (variant, sparse) in [
            ("laplacian", g.laplacian_csr()),
            ("normalized", g.normalized_laplacian_csr()),
        ] {
            let dense = sparse.to_dense();
            for k in 1..=17usize {
                let mut rng = Rng::new((k as u64) << 8 ^ nn as u64);
                let v = DMat::from_fn(nn, k, |_, _| rng.normal());
                let want = sped::linalg::matmul::matmul(&dense, &v);
                let reference = spmm_streaming(&sparse, &v, 1);
                assert!(
                    bitwise_eq(&reference, &want),
                    "{name}/{variant}: streaming vs dense at k={k}"
                );
                for workers in [1usize, 2, 8] {
                    assert!(
                        bitwise_eq(&spmm(&sparse, &v, workers), &reference),
                        "{name}/{variant}: blocked vs streaming at k={k}, {workers} workers"
                    );
                    assert!(
                        bitwise_eq(&spmm_streaming(&sparse, &v, workers), &reference),
                        "{name}/{variant}: streaming not worker-invariant at k={k}, {workers} workers"
                    );
                }
            }
        }
    }
}

#[test]
fn blocked_spmm_empty_rows_and_structural_zero_diagonals() {
    // Rows 1 and 3 store nothing at all; row 0 stores only an explicit 0.0
    // diagonal (the isolated-node Laplacian shape); row 2 mixes signs.
    let m = CsrMat::from_triplets(
        6,
        6,
        &[
            (0, 0, 0.0),
            (2, 1, 1.5),
            (2, 2, 0.0),
            (2, 4, -2.0),
            (4, 0, 0.25),
            (4, 4, 3.0),
            (5, 5, -1.0),
        ],
    );
    let dense = m.to_dense();
    for k in 1..=17usize {
        let mut rng = Rng::new(k as u64 + 400);
        let v = DMat::from_fn(6, k, |_, _| rng.normal());
        let want = sped::linalg::matmul::matmul(&dense, &v);
        for workers in [1usize, 2, 8] {
            let got = spmm(&m, &v, workers);
            assert!(bitwise_eq(&got, &want), "k={k}, {workers} workers");
            assert!(bitwise_eq(&spmm_streaming(&m, &v, workers), &want));
            for row in [0usize, 1, 3] {
                assert!(got.row(row).iter().all(|x| x.to_bits() == 0), "row {row} not +0.0");
            }
        }
    }
}

#[test]
fn sharded_spmm_bitwise_equals_unsharded_across_the_zoo() {
    // The two-phase halo-exchange path must be indistinguishable — bit for
    // bit — from the unsharded kernel at every (shard count, worker count),
    // for every generator and both Laplacian variants. S = 7 does not
    // divide n = 22, so uneven shard sizes are always in play.
    use sped::linalg::shard::ShardedCsr;
    for (name, g) in generator_zoo(22, 5) {
        let nn = g.num_nodes();
        for (variant, sparse) in [
            ("laplacian", g.laplacian_csr()),
            ("normalized", g.normalized_laplacian_csr()),
        ] {
            for s in [1usize, 2, 7] {
                let sharded = ShardedCsr::partition(&sparse, s);
                assert_eq!(sharded.shard_count(), s);
                assert_eq!(sharded.shard_lens().iter().sum::<usize>(), nn);
                for k in [1usize, 8, 17] {
                    let mut rng = Rng::new((s as u64) << 16 ^ (k as u64) << 8 ^ nn as u64);
                    let v = DMat::from_fn(nn, k, |_, _| rng.normal());
                    let want = spmm(&sparse, &v, 1);
                    for workers in [1usize, 2, 8] {
                        assert!(
                            bitwise_eq(&sharded.apply(&v, workers), &want),
                            "{name}/{variant}: sharded S={s} vs unsharded at k={k}, {workers} workers"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn sharded_spmm_empty_shards_and_isolated_nodes() {
    // n = 5 under S = 7 leaves two shards owning zero rows; nodes 3 and 4
    // are fully isolated (structural-zero Laplacian diagonal). The sharded
    // apply must keep the empty shards addressable and the isolated rows
    // exactly +0.0, matching the unsharded kernel bitwise.
    use sped::linalg::shard::ShardedCsr;
    let g = Graph::from_edges(5, &[(0, 1, 1.0), (1, 2, 0.5)]).unwrap();
    for (variant, sparse) in [
        ("laplacian", g.laplacian_csr()),
        ("normalized", g.normalized_laplacian_csr()),
    ] {
        let sharded = ShardedCsr::partition(&sparse, 7);
        assert_eq!(sharded.shard_count(), 7);
        assert_eq!(sharded.shard_lens(), vec![1, 1, 1, 1, 1, 0, 0]);
        for k in [1usize, 4, 17] {
            let mut rng = Rng::new(k as u64 + 900);
            let v = DMat::from_fn(5, k, |_, _| rng.normal());
            let want = spmm(&sparse, &v, 1);
            for workers in [1usize, 2, 8] {
                let got = sharded.apply(&v, workers);
                assert!(bitwise_eq(&got, &want), "{variant}: k={k}, {workers} workers");
                for row in [3usize, 4] {
                    assert!(
                        got.row(row).iter().all(|x| x.to_bits() == 0),
                        "{variant}: isolated row {row} not +0.0"
                    );
                }
            }
        }
    }
}

#[test]
fn matrix_free_operator_rides_the_blocked_kernel_deterministically() {
    // SparsePolyOp (ℓ SpMMs per apply) end-to-end over the blocked widths
    // the solvers use: worker counts stay bitwise-invariant, and k > 16
    // (streaming fallback) behaves identically.
    use sped::solvers::{MatVecOp, SparsePolyOp};
    let g = cliques(&CliqueSpec { n: 30, k: 3, max_short_circuit: 2, seed: 9 }).graph;
    for k in [1usize, 4, 8, 16, 17] {
        let v = sped::solvers::random_init(30, k, 21);
        let mk = |threads| {
            let opts = sped::transforms::BuildOptions { threads, ..Default::default() };
            SparsePolyOp::from_graph(&g, TransformKind::LimitNegExp { ell: 31 }, &opts).unwrap()
        };
        let serial = mk(1).apply(&v);
        for threads in [2usize, 8] {
            assert!(
                bitwise_eq(&mk(threads).apply(&v), &serial),
                "k={k} diverged at {threads} workers"
            );
        }
    }
}

/// Mean edge span `Σ_e |u − v| / |E|` — the profile counterpart of
/// [`Graph::bandwidth`]; robust to the single widest hub edge.
fn mean_span(g: &Graph) -> f64 {
    g.edges().iter().map(|e| (e.v - e.u) as f64).sum::<f64>() / g.num_edges().max(1) as f64
}

#[test]
fn rcm_roundtrips_and_reduces_bandwidth_on_power_law() {
    // A power-law sample whose natural order is deliberately scrambled by
    // an affine relabeling, so the baseline carries no locality at all —
    // the seed-triangle edge (0, 1) alone is forced to span 379 of the 400
    // positions (19⁻¹ ≡ 379 mod 400), so the baseline bandwidth is pinned.
    let n = 400usize;
    let ba = barabasi_albert(n, 2, 11).graph;
    let scramble: Vec<usize> = (0..n).map(|i| (i * 19) % n).collect(); // gcd(19, 400) = 1
    let scrambled = ba.permute(&scramble).unwrap();
    assert!(scrambled.bandwidth() >= 379, "scramble too weak: {}", scrambled.bandwidth());

    let order = scrambled.rcm_permutation();
    // perm ∘ inv-perm = id, both ways.
    let inv = invert_permutation(&order);
    for i in 0..n {
        assert_eq!(inv[order[i]], i);
        assert_eq!(order[inv[i]], i);
    }
    // Applying the ordering and then its inverse recovers the graph.
    let rcm_graph = scrambled.permute(&order).unwrap();
    assert_eq!(rcm_graph.permute(&inv).unwrap().edges(), scrambled.edges());
    // Bandwidth shrinks (RCM edges only connect BFS-adjacent levels, so no
    // edge can span the whole ordering the way the scramble forces)...
    assert!(
        rcm_graph.bandwidth() < scrambled.bandwidth(),
        "rcm bandwidth {} !< scrambled {}",
        rcm_graph.bandwidth(),
        scrambled.bandwidth()
    );
    // ...and so does the mean span — the bulk-locality effect the SpMM
    // bundle accesses actually feel, by a wide margin.
    assert!(
        mean_span(&rcm_graph) < 0.75 * mean_span(&scrambled),
        "rcm mean span {:.1} !< 0.75 × scrambled {:.1}",
        mean_span(&rcm_graph),
        mean_span(&scrambled)
    );
}

#[test]
fn rcm_pipeline_recovers_identical_clusters_and_lambda_star() {
    // Pipeline-level invariance: cluster a *scrambled* clique graph with
    // --reorder rcm and with --reorder none; after the pipeline's internal
    // un-permutation both must yield the same partition of the same input
    // node ids, and the same λ* (exactly 0.0 for the negexp family).
    let gg = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 11 });
    let n = gg.graph.num_nodes();
    let scramble: Vec<usize> = (0..n).map(|i| (i * 7) % n).collect(); // gcd(7, 48) = 1
    let scrambled = gg.graph.permute(&scramble).unwrap();
    // Labels move with the nodes: scrambled node i is original node scramble[i].
    let scrambled_labels: Vec<usize> = scramble.iter().map(|&old| gg.labels[old]).collect();

    let mk = |reorder| PipelineConfig {
        k: 3,
        transform: TransformKind::LimitNegExp { ell: 51 },
        solver: "subspace".into(),
        steps: 400,
        eval_every: 20,
        stop_error: 0.0,
        op_mode: OpMode::MatrixFree,
        ground_truth: false,
        reorder,
        ..Default::default()
    };
    let plain = Pipeline::new(mk(Reorder::None)).run(&scrambled).unwrap();
    let rcm = Pipeline::new(mk(Reorder::Rcm)).run(&scrambled).unwrap();

    assert_eq!(plain.lambda_star.to_bits(), rcm.lambda_star.to_bits());
    assert_eq!(rcm.lambda_star, 0.0, "negexp family reverses with λ* = 0");

    // Identical partition up to cluster-id naming.
    let canon = |a: &[usize]| {
        let mut map = std::collections::HashMap::new();
        a.iter()
            .map(|&c| {
                let next = map.len();
                *map.entry(c).or_insert(next)
            })
            .collect::<Vec<usize>>()
    };
    let a_plain = &plain.clustering.as_ref().unwrap().assignments;
    let a_rcm = &rcm.clustering.as_ref().unwrap().assignments;
    assert_eq!(canon(a_plain), canon(a_rcm), "reordered partition differs");
    // And both recover the planted communities of the scrambled graph.
    let ari = sped::cluster::adjusted_rand_index(a_rcm, &scrambled_labels);
    assert!(ari > 0.9, "ARI {ari}");
    // Embeddings span the same converged subspace.
    let err = sped::linalg::metrics::subspace_error(&plain.embedding, &rcm.embedding);
    assert!(err < 1e-6, "subspace err {err}");
}
