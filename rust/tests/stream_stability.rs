//! Perturbation-stability harness for the streaming delta path (PR 7
//! acceptance): incremental CSR patching is bitwise-identical to a
//! from-scratch rebuild under random delta sequences, warm-started solves
//! beat cold ones on a community workload (bitwise-deterministically
//! across 1/2/8 workers), bounded edge noise produces bounded cluster
//! drift, and injected faults are rejected or degraded — never a panic.

use std::collections::HashMap;

use sped::cluster::adjusted_rand_index;
use sped::coordinator::pipeline::{PipelineConfig, SolvePath};
use sped::coordinator::stream::{StreamConfig, StreamSession};
use sped::graph::delta::EdgeDelta;
use sped::graph::gen::{cliques, CliqueSpec};
use sped::graph::Graph;
use sped::linalg::sparse::{power_lambda_max_csr, CsrMat};
use sped::transforms::{OpMode, TransformKind};
use sped::util::rng::Rng;

/// Canonical undirected key.
fn key(u: usize, v: usize) -> (usize, usize) {
    (u.min(v), u.max(v))
}

fn assert_csr_bitwise(a: &CsrMat, b: &CsrMat, what: &str) {
    assert_eq!(a.indptr(), b.indptr(), "{what}: indptr diverged");
    assert_eq!(a.indices(), b.indices(), "{what}: indices diverged");
    assert_eq!(a.values().len(), b.values().len(), "{what}: nnz diverged");
    for (i, (x, y)) in a.values().iter().zip(b.values().iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: value {i} diverged ({x} vs {y})");
    }
}

/// The tentpole identity, as a property test: any legal sequence of delta
/// batches — edge creation, deletion (down to isolated vertices), weight
/// bumps, rewrites, and node growth — leaves the patched graph with CSR
/// Laplacians bitwise identical to `Graph::from_edges` on the final edge
/// set, and with worker-count-invariant spectral estimates.
#[test]
fn random_delta_sequences_match_rebuild_bitwise() {
    let mut rng = Rng::new(0xD517);
    for case in 0..6u64 {
        let mut n = 16 + 8 * case as usize;
        // Random seed graph, mirrored in a (key → weight) model that
        // replays the exact fold `apply_deltas` performs.
        let mut model: HashMap<(usize, usize), f64> = HashMap::new();
        for _ in 0..3 * n {
            let u = rng.below(n);
            let v = rng.below(n);
            if u != v {
                model.insert(key(u, v), rng.uniform(0.5, 2.0));
            }
        }
        let raw: Vec<(usize, usize, f64)> = model.iter().map(|(&(u, v), &w)| (u, v, w)).collect();
        let mut g = Graph::from_edges(n, &raw).unwrap();

        for batch_idx in 0..8 {
            let mut batch: Vec<EdgeDelta> = Vec::new();
            if batch_idx == 3 {
                // Node growth mid-stream, with a new id used in-batch.
                batch.push(EdgeDelta::AddNodes { count: 2 });
                let u = rng.below(n);
                let w = rng.uniform(0.5, 2.0);
                batch.push(EdgeDelta::Add { u, v: n, w });
                *model.entry(key(u, n)).or_insert(0.0) += w;
                n += 2;
            } else if batch_idx == 6 {
                // Strip one node down to isolation.
                let victim = rng.below(n);
                let doomed: Vec<(usize, usize)> = model
                    .keys()
                    .filter(|&&(u, v)| u == victim || v == victim)
                    .copied()
                    .collect();
                for (u, v) in doomed {
                    batch.push(EdgeDelta::Remove { u, v });
                    model.remove(&key(u, v));
                }
                if batch.is_empty() {
                    // Already isolated: a reweight elsewhere keeps the
                    // batch non-trivial.
                    let (&(u, v), &w) = model.iter().next().unwrap();
                    batch.push(EdgeDelta::Reweight { u, v, w: w * 1.25 });
                    model.insert((u, v), w * 1.25);
                }
            } else {
                for _ in 0..6 {
                    match rng.below(3) {
                        0 => {
                            let u = rng.below(n);
                            let v = rng.below(n);
                            if u == v {
                                continue;
                            }
                            let w = rng.uniform(0.5, 2.0);
                            batch.push(EdgeDelta::Add { u, v, w });
                            *model.entry(key(u, v)).or_insert(0.0) += w;
                        }
                        1 if !model.is_empty() => {
                            let keys: Vec<(usize, usize)> = model.keys().copied().collect();
                            let (u, v) = keys[rng.below(keys.len())];
                            batch.push(EdgeDelta::Remove { u, v });
                            model.remove(&(u, v));
                        }
                        _ if !model.is_empty() => {
                            let keys: Vec<(usize, usize)> = model.keys().copied().collect();
                            let (u, v) = keys[rng.below(keys.len())];
                            let w = rng.uniform(0.5, 2.0);
                            batch.push(EdgeDelta::Reweight { u, v, w });
                            model.insert((u, v), w);
                        }
                        _ => {}
                    }
                }
                if batch.is_empty() {
                    continue;
                }
            }

            g.apply_deltas(&batch).unwrap();
            let raw: Vec<(usize, usize, f64)> =
                model.iter().map(|(&(u, v), &w)| (u, v, w)).collect();
            let rebuilt = Graph::from_edges(n, &raw).unwrap();
            assert_eq!(g.num_nodes(), rebuilt.num_nodes());
            assert_eq!(g.num_edges(), rebuilt.num_edges());
            assert_csr_bitwise(
                &g.laplacian_csr(),
                &rebuilt.laplacian_csr(),
                &format!("case {case} batch {batch_idx} laplacian"),
            );
            assert_csr_bitwise(
                &g.normalized_laplacian_csr(),
                &rebuilt.normalized_laplacian_csr(),
                &format!("case {case} batch {batch_idx} normalized laplacian"),
            );
        }
        // Worker-count invariance on the patched matrix: the spectral
        // estimate (the first consumer of a patched CSR in the streaming
        // path) is bitwise identical across 1/2/8 workers and identical
        // to the rebuilt matrix's.
        let lc = g.laplacian_csr();
        let raw: Vec<(usize, usize, f64)> = model.iter().map(|(&(u, v), &w)| (u, v, w)).collect();
        let lr = Graph::from_edges(n, &raw).unwrap().laplacian_csr();
        let base = power_lambda_max_csr(&lr, 25, 1).unwrap();
        for threads in [1usize, 2, 8] {
            let est = power_lambda_max_csr(&lc, 25, threads).unwrap();
            assert_eq!(
                est.to_bits(),
                base.to_bits(),
                "case {case}: patched-vs-rebuilt estimate diverged at {threads} workers"
            );
        }
    }
}

/// Community-expander workload from the bench suite: `c` expander-ish
/// ring+chord communities joined by two bridges per adjacent pair.
fn community_expander(n: usize, c: usize, chords: usize, seed: u64) -> Graph {
    let m = n / c;
    assert!(c >= 2 && m >= 8 && n % c == 0, "bad community-expander shape n={n}, c={c}");
    let mut rng = Rng::new(seed);
    let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(n * (1 + chords) + 2 * c);
    for comm in 0..c {
        let base = comm * m;
        for i in 0..m {
            pairs.push((base + i, base + (i + 1) % m));
            for _ in 0..chords {
                loop {
                    let t = base + rng.below(m);
                    if t != base + i {
                        pairs.push((base + i, t));
                        break;
                    }
                }
            }
        }
        let next = ((comm + 1) % c) * m;
        pairs.push((base, next));
        pairs.push((base + m / 2, next + m / 2));
    }
    Graph::from_pairs(n, &pairs).expect("community-expander edges")
}

fn ritz_cfg(k: usize, threads: usize) -> StreamConfig {
    StreamConfig {
        pipeline: PipelineConfig {
            k,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "ritz".into(),
            ritz_tol: 1e-8,
            ritz_max_iters: 2000,
            op_mode: OpMode::MatrixFree,
            ground_truth: false,
            threads,
            ..Default::default()
        },
        warm_volume_frac: 0.25,
    }
}

/// Warm-started re-solves after a small delta batch converge in strictly
/// fewer outer iterations than the cold solve, and the whole streaming
/// flow is bitwise identical across 1/2/8 workers.
#[test]
fn warm_beats_cold_on_community_expander_bitwise_across_workers() {
    let g = community_expander(512, 8, 2, 42);
    let mut embeddings: Vec<Vec<u64>> = Vec::new();
    for threads in [1usize, 2, 8] {
        let mut s = StreamSession::new(g.clone(), ritz_cfg(8, threads));
        let cold = s.publish().unwrap();
        assert_eq!(cold.path, SolvePath::Cold);
        assert!(cold.converged, "cold solve unconverged at {threads} workers");
        // A light touch: bump a few in-community edge weights.
        let batch: Vec<EdgeDelta> = g
            .edges()
            .iter()
            .take(8)
            .map(|e| EdgeDelta::Reweight { u: e.u as usize, v: e.v as usize, w: e.w * 1.1 })
            .collect();
        s.apply_batch(&batch).unwrap();
        let warm = s.publish().unwrap();
        assert_eq!(warm.path, SolvePath::Warm, "{threads} workers");
        assert!(warm.converged);
        assert!(
            warm.iterations < cold.iterations,
            "warm {} !< cold {} outer iterations at {threads} workers",
            warm.iterations,
            cold.iterations
        );
        embeddings.push(s.embedding().unwrap().data().iter().map(|x| x.to_bits()).collect());
    }
    assert_eq!(embeddings[0], embeddings[1], "1 vs 2 workers diverged");
    assert_eq!(embeddings[0], embeddings[2], "1 vs 8 workers diverged");
}

/// Bounded noise → bounded drift: rounds of small random edge
/// perturbations on a clustered generator keep both the publish-to-publish
/// ARI and the ARI against the planted labels high.
#[test]
fn bounded_noise_keeps_clusters_stable() {
    let gg = cliques(&CliqueSpec { n: 96, k: 4, max_short_circuit: 4, seed: 7 });
    let mut s = StreamSession::new(gg.graph.clone(), ritz_cfg(4, 1));
    let base = s.publish().unwrap();
    assert!(adjusted_rand_index(&base.assignments, &gg.labels) > 0.95);
    let mut rng = Rng::new(0xBEEF);
    for round in 0..5 {
        // Noise model: a few weak random cross/intra edges appear, a few
        // existing edges get mild weight jitter.
        let mut batch: Vec<EdgeDelta> = Vec::new();
        for _ in 0..4 {
            let u = rng.below(96);
            let v = rng.below(96);
            if u != v {
                batch.push(EdgeDelta::Add { u, v, w: 0.02 });
            }
        }
        let edges = s.graph().edges();
        for _ in 0..4 {
            let e = &edges[rng.below(edges.len())];
            batch.push(EdgeDelta::Reweight {
                u: e.u as usize,
                v: e.v as usize,
                w: e.w * rng.uniform(0.9, 1.1),
            });
        }
        s.apply_batch(&batch).unwrap();
        let rep = s.publish().unwrap();
        let drift = rep.ari_vs_previous.unwrap();
        assert!(drift > 0.85, "round {round}: drift ARI {drift}");
        let truth = adjusted_rand_index(&rep.assignments, &gg.labels);
        assert!(truth > 0.85, "round {round}: ARI vs labels {truth}");
    }
}

/// Regression (PR 8): after an `AddNodes` delta the publish used to feed a
/// grown assignment vector and the shorter previous one into the ARI —
/// now the metrics assert on length mismatch, the full-vector drift is
/// `None` with a reason, and the common prefix of pre-existing nodes is
/// compared instead.
#[test]
fn node_growth_reports_prefix_drift_not_misleading_full_ari() {
    let gg = cliques(&CliqueSpec { n: 48, k: 2, max_short_circuit: 2, seed: 5 });
    let mut s = StreamSession::new(gg.graph.clone(), ritz_cfg(2, 1));
    let first = s.publish().unwrap();
    assert!(first.ari_vs_previous.is_none());
    assert!(first.ari_prefix_vs_previous.is_none());
    assert!(first.ari_reason.unwrap().contains("no previous"), "{:?}", first.ari_reason);
    // Grow the graph by two leaf nodes hanging off the first clique.
    s.apply_batch(&[
        EdgeDelta::AddNodes { count: 2 },
        EdgeDelta::Add { u: 0, v: 48, w: 1.0 },
        EdgeDelta::Add { u: 1, v: 49, w: 1.0 },
    ])
    .unwrap();
    let rep = s.publish().unwrap();
    assert_eq!(rep.assignments.len(), 50);
    assert!(rep.ari_vs_previous.is_none(), "full-vector ARI is undefined across node sets");
    let prefix = rep
        .ari_prefix_vs_previous
        .expect("growth must still report the pre-existing-node drift");
    assert!(prefix > 0.9, "two leaves must not move the planted partition: prefix ARI {prefix}");
    assert!(rep.ari_reason.unwrap().contains("grew"), "{:?}", rep.ari_reason);
    // Steady state: the next publish is a same-length comparison again.
    let steady = s.publish().unwrap();
    assert!(steady.ari_vs_previous.is_some());
    assert!(steady.ari_prefix_vs_previous.is_none());
    assert!(steady.ari_reason.is_none());
}

/// Regression (PR 8): on a graph driven to zero edges the churn fraction's
/// `max(1)` denominator made the accumulated volume look tiny, so a later
/// publish silently took the warm path seeded from a meaningless subspace.
/// Zero-edge graphs are now always-cold by policy — and never panic.
#[test]
fn zero_edge_graph_publishes_cold_never_warm() {
    // Born empty: nodes but no edges at all.
    let g = Graph::from_edges(6, &[]).unwrap();
    let mut s = StreamSession::new(g, ritz_cfg(2, 1));
    match s.publish() {
        Ok(first) => {
            assert_eq!(first.path, SolvePath::Cold);
            assert_eq!(first.volume_frac, 0.0);
            // A previous embedding now exists and the accumulated volume
            // is 0 — exactly the state the old fraction logic warmed on.
            let second = s.publish().unwrap();
            assert_eq!(second.path, SolvePath::Cold, "zero-edge graphs must never warm-start");
        }
        // A clean error from the null-operator solve is acceptable (the
        // point is no panic and no warm path); the session stays usable.
        Err(e) => assert!(!format!("{e:#}").is_empty()),
    }

    // Driven to zero: a live session whose every edge is cut in one batch.
    let gg = cliques(&CliqueSpec { n: 24, k: 2, max_short_circuit: 1, seed: 3 });
    let mut s = StreamSession::new(gg.graph.clone(), ritz_cfg(2, 1));
    s.publish().unwrap();
    let cut: Vec<EdgeDelta> = s
        .graph()
        .edges()
        .iter()
        .map(|e| EdgeDelta::Remove { u: e.u as usize, v: e.v as usize })
        .collect();
    let out = s.apply_batch(&cut).unwrap();
    assert!(out.topology_changed);
    assert_eq!(s.graph().num_edges(), 0);
    if let Ok(rep) = s.publish() {
        assert_eq!(rep.path, SolvePath::Cold, "publish on the cut graph must run cold");
        // And so must every later publish while the graph stays empty.
        if let Ok(rep2) = s.publish() {
            assert_eq!(rep2.path, SolvePath::Cold);
        }
    }
}

/// Fault injection: malformed deltas are rejected transactionally with the
/// session left fully usable, and legal-but-brutal deltas (disconnecting a
/// community, isolating a node) degrade gracefully — solves still run,
/// nothing panics.
#[test]
fn faults_reject_or_degrade_never_panic() {
    let gg = cliques(&CliqueSpec { n: 96, k: 4, max_short_circuit: 4, seed: 7 });
    let mut s = StreamSession::new(gg.graph.clone(), ritz_cfg(4, 1));
    s.publish().unwrap();
    let edges_before = s.graph().num_edges();

    // Malformed: NaN / infinite weights, out-of-range ids, self-loops,
    // absent-edge removal. Every one rejected, graph untouched.
    let (u0, v0) = {
        let e = &s.graph().edges()[0];
        (e.u as usize, e.v as usize)
    };
    let bad: Vec<(Vec<EdgeDelta>, &str)> = vec![
        (vec![EdgeDelta::Add { u: 0, v: 1, w: f64::NAN }], "non-finite"),
        (vec![EdgeDelta::Reweight { u: u0, v: v0, w: f64::INFINITY }], "non-finite"),
        (vec![EdgeDelta::Add { u: 0, v: 4096, w: 1.0 }], "out of range"),
        (vec![EdgeDelta::Add { u: 5, v: 5, w: 1.0 }], "self-loop"),
        (vec![EdgeDelta::Remove { u: 0, v: 4095 }], "out of range"),
    ];
    for (batch, needle) in &bad {
        let err = s.apply_batch(batch).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(needle), "expected {needle:?} in {msg:?}");
        assert_eq!(s.graph().num_edges(), edges_before, "rejected batch mutated the graph");
    }
    // A NaN arriving through the text grammar is caught at apply time too.
    let d = EdgeDelta::parse("add 0 1 nan").unwrap();
    assert!(s.apply_batch(&[d]).is_err());

    // Legal but brutal #1: cut every cross-community edge. The graph
    // disconnects into the four planted cliques; the solve still runs.
    let cross: Vec<EdgeDelta> = s
        .graph()
        .edges()
        .iter()
        .filter(|e| gg.labels[e.u as usize] != gg.labels[e.v as usize])
        .map(|e| EdgeDelta::Remove { u: e.u as usize, v: e.v as usize })
        .collect();
    assert!(!cross.is_empty());
    let out = s.apply_batch(&cross).unwrap();
    assert!(out.topology_changed);
    let rep = s.publish().unwrap();
    assert!(rep.converged, "solve on the disconnected graph must still converge");
    assert!(
        adjusted_rand_index(&rep.assignments, &gg.labels) > 0.95,
        "fully separated communities should be recovered exactly"
    );

    // Legal but brutal #2: strip node 0 to isolation (null-space dimension
    // now exceeds k). Still no panic, still a successful publish.
    let doomed: Vec<EdgeDelta> = s
        .graph()
        .edges()
        .iter()
        .filter(|e| e.u == 0 || e.v == 0)
        .map(|e| EdgeDelta::Remove { u: e.u as usize, v: e.v as usize })
        .collect();
    assert!(!doomed.is_empty());
    s.apply_batch(&doomed).unwrap();
    let rep = s.publish().unwrap();
    assert_eq!(rep.assignments.len(), 96);
}
