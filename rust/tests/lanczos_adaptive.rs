//! Lanczos spectral-bound + adaptive-degree acceptance harness:
//!
//! 1. the `--domain lanczos` estimate **covers** the true `eigh` extremes
//!    (padded Ritz bounds clipped to the guaranteed Gershgorin interval)
//!    for every graph generator × both Laplacian variants, with the dense
//!    and CSR estimators **bitwise** equal and worker-invariant;
//! 2. `--degree auto` truncation reproduces the transforms' scalar maps to
//!    ≤ 1e-6 at the acceptance degrees ℓ ∈ {15, 251}, cutting the kept
//!    degree for the fast-decaying kinds;
//! 3. the `--domain power --degree native` defaults replicate the
//!    pre-refactor hand-rolled domain policy bit for bit;
//! 4. the pipeline opt-in (`--domain lanczos --degree auto`) recovers the
//!    identical partition with far fewer SpMM sweeps, and the non-native
//!    knobs are rejected on the XLA backend with clear errors.

use sped::graph::gen::{
    barabasi_albert, barbell, cliques, erdos_renyi, grid2d, path, ring, ring_of_cliques, sbm,
    CliqueSpec,
};
use sped::graph::Graph;
use sped::linalg::sparse::power_lambda_max_csr;
use sped::linalg::DMat;
use sped::pipeline::{Backend, Pipeline, PipelineConfig};
use sped::solvers::SparsePolyOp;
use sped::transforms::{
    cheb_domain, BuildOptions, Degree, DomainEstimate, OpMode, PolyBasis, TransformKind,
};

/// Every generator in the crate, at a size small enough that the full
/// variant × worker sweep (with an `eigh` oracle each) stays cheap.
fn generator_zoo(n: usize, seed: u64) -> Vec<(&'static str, Graph)> {
    vec![
        (
            "cliques",
            cliques(&CliqueSpec { n, k: (n / 6).max(1), max_short_circuit: 3, seed }).graph,
        ),
        ("sbm", sbm(&[n / 2, n - n / 2], 0.8, 0.05, seed).graph),
        ("erdos_renyi", erdos_renyi(n, 0.3, seed).graph),
        ("grid2d", grid2d(n / 3 + 1, 3).graph),
        ("path", path(n).graph),
        ("ring", ring(n.max(3)).graph),
        ("barbell", barbell(n / 2 + 2).graph),
        ("ring_of_cliques", ring_of_cliques(3, n / 3 + 2, seed).graph),
        ("barabasi_albert", barabasi_albert(n.max(5), 3, seed).graph),
    ]
}

#[test]
fn lanczos_estimate_covers_eigh_extremes_everywhere_bitwise_dense_vs_csr() {
    for (name, g) in generator_zoo(22, 3) {
        for (variant, ld, lc) in [
            ("laplacian", g.laplacian(), g.laplacian_csr()),
            ("normalized", g.normalized_laplacian(), g.normalized_laplacian_csr()),
        ] {
            let e = sped::linalg::eigh(&ld).unwrap();
            let lam_min = e.values[0];
            let lam_max = e.lambda_max();
            let est = DomainEstimate::Lanczos.estimate_csr(&lc, 0.0, 1).unwrap();
            // Padded bounds bracket the true extremes…
            assert!(
                est.lo <= lam_min + 1e-8,
                "{name}/{variant}: lo {} above λ_min {lam_min}",
                est.lo
            );
            assert!(
                est.hi >= lam_max - 1e-8,
                "{name}/{variant}: hi {} below λ_max {lam_max}",
                est.hi
            );
            // …inside the guaranteed Gershgorin interval…
            let (glo, ghi) = lc.gershgorin_interval();
            assert!(est.lo >= glo - 1e-12 && est.hi <= ghi + 1e-12, "{name}/{variant}");
            // …and never looser than the historical one-sided domain.
            let loose = DomainEstimate::Power.estimate_csr(&lc, 0.0, 1).unwrap();
            assert!(
                est.hi <= loose.hi + 1e-12,
                "{name}/{variant}: lanczos hi {} above power hi {}",
                est.hi,
                loose.hi
            );
            // Dense ≡ CSR, bitwise, and worker-count invariant.
            let dense = DomainEstimate::Lanczos.estimate_dense(&ld, 0.0, 1).unwrap();
            assert_eq!(dense.lo.to_bits(), est.lo.to_bits(), "{name}/{variant}");
            assert_eq!(dense.hi.to_bits(), est.hi.to_bits(), "{name}/{variant}");
            assert_eq!(dense.residual.to_bits(), est.residual.to_bits(), "{name}/{variant}");
            for workers in [2usize, 8] {
                let pc = DomainEstimate::Lanczos.estimate_csr(&lc, 0.0, workers).unwrap();
                let pd = DomainEstimate::Lanczos.estimate_dense(&ld, 0.0, workers).unwrap();
                assert_eq!(pc.lo.to_bits(), est.lo.to_bits(), "{name}/{variant}@{workers}w");
                assert_eq!(pc.hi.to_bits(), est.hi.to_bits(), "{name}/{variant}@{workers}w");
                assert_eq!(pd.lo.to_bits(), est.lo.to_bits(), "{name}/{variant}@{workers}w");
                assert_eq!(pd.hi.to_bits(), est.hi.to_bits(), "{name}/{variant}@{workers}w");
            }
        }
    }
}

#[test]
fn adaptive_degree_series_match_scalar_map_at_acceptance_degrees() {
    // Normalized-Laplacian domain from the Lanczos policy — the tight
    // interval the ≥2× sweep reduction is measured on.
    let g = cliques(&CliqueSpec { n: 64, k: 4, max_short_circuit: 2, seed: 9 }).graph;
    let lc = g.normalized_laplacian_csr();
    let est = DomainEstimate::Lanczos.estimate_csr(&lc, 0.0, 1).unwrap();
    let e = sped::linalg::eigh(&g.normalized_laplacian()).unwrap();
    for ell in [15usize, 251] {
        for kind in [
            TransformKind::TaylorNegExp { ell },
            TransformKind::TaylorLog { ell, eps: 0.05 },
            TransformKind::LimitNegExp { ell },
        ] {
            let full = kind.cheb_series(est.lo, est.hi).expect("polynomial kind");
            let auto = Degree::Auto { tol: 1e-9, max: usize::MAX }.shape(full.clone());
            assert!(auto.degree() <= ell, "{kind}");
            // On-domain grid plus the true eigenvalues: ≤ 1e-6 everywhere.
            let mut xs: Vec<f64> = (0..=80)
                .map(|i| est.lo + (est.hi - est.lo) * i as f64 / 80.0)
                .collect();
            xs.extend_from_slice(&e.values);
            for &x in &xs {
                let err = (auto.eval_scalar(x) - kind.scalar_map(x)).abs();
                assert!(err < 1e-6, "{kind} at x={x}: err {err}");
            }
            // The −e^{−x} family's tail decays fast on the tight interval:
            // at ℓ = 251 the truncation must cut ≥ 2× (the acceptance
            // floor — in practice it is ~10×).
            if ell == 251 && !matches!(kind, TransformKind::TaylorLog { .. }) {
                assert!(
                    auto.degree() * 2 <= ell,
                    "{kind}: kept degree {} not ≥2× below {ell}",
                    auto.degree()
                );
            }
        }
    }
}

#[test]
fn default_power_domain_replicates_the_historical_policy_bitwise() {
    // The pre-refactor hand-rolled flow, replayed: λ_max power estimate
    // (safety-padded), ρ-vs-Gershgorin fallback, cheb_domain widening.
    let g = cliques(&CliqueSpec { n: 40, k: 4, max_short_circuit: 3, seed: 13 }).graph;
    let lc = g.laplacian_csr();
    let kind = TransformKind::LimitNegExp { ell: 51 };
    let lam_est = power_lambda_max_csr(&lc, 100, 1).unwrap() * 1.01;
    let gersh = lc.gershgorin_bound();
    let rho_old = if lam_est > 0.0 { lam_est } else { gersh };
    let (lo_old, hi_old) = cheb_domain(rho_old, gersh);
    let opts = BuildOptions { basis: PolyBasis::Chebyshev, ..BuildOptions::default() };
    let op = SparsePolyOp::from_csr(lc.clone(), kind, &opts).unwrap();
    let (lo, hi) = op.fit_domain().expect("chebyshev op has a domain");
    assert_eq!(lo.to_bits(), lo_old.to_bits());
    assert_eq!(hi.to_bits(), hi_old.to_bits());
    assert_eq!(op.lambda_star.to_bits(), kind.lambda_star(rho_old).to_bits());
    assert_eq!(op.sweeps(), 51, "native degree honored");
    // And the defaults really are Power + Native.
    assert_eq!(BuildOptions::default().domain, DomainEstimate::Power);
    assert_eq!(BuildOptions::default().degree, Degree::Native);
    // The dense build agrees on λ* for the same policy (the shared-policy
    // contract across the dense and matrix-free paths).
    let sm = sped::transforms::build_solver_matrix(
        &g.laplacian(),
        kind,
        &BuildOptions { basis: PolyBasis::Chebyshev, ..BuildOptions::default() },
    )
    .unwrap();
    assert!((sm.lambda_star - op.lambda_star).abs() < 1e-12);
}

#[test]
fn pipeline_opt_in_recovers_identical_partition_with_fewer_sweeps() {
    let gg = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 11 });
    let mk = |domain, degree| PipelineConfig {
        k: 3,
        transform: TransformKind::LimitNegExp { ell: 251 },
        solver: "subspace".into(),
        steps: 300,
        eval_every: 20,
        stop_error: 0.0,
        op_mode: OpMode::MatrixFree,
        ground_truth: false,
        build: BuildOptions {
            basis: PolyBasis::Chebyshev,
            domain,
            degree,
            ..BuildOptions::default()
        },
        ..Default::default()
    };
    let full = Pipeline::new(mk(DomainEstimate::Power, Degree::Native))
        .run(&gg.graph)
        .unwrap();
    let auto = Pipeline::new(mk(
        DomainEstimate::Lanczos,
        Degree::Auto { tol: 1e-9, max: usize::MAX },
    ))
    .run(&gg.graph)
    .unwrap();
    assert_eq!(full.lambda_star, 0.0);
    assert_eq!(auto.lambda_star, 0.0);
    let err = sped::linalg::metrics::subspace_error(&full.embedding, &auto.embedding);
    assert!(err < 1e-6, "adaptive pipeline subspace err {err}");
    assert_eq!(
        full.clustering.as_ref().unwrap().assignments,
        auto.clustering.as_ref().unwrap().assignments,
        "partitions differ across domain/degree policies"
    );
    // The sweep reduction the pipeline just ran with, measured directly.
    let op_opts = |domain, degree| BuildOptions {
        basis: PolyBasis::Chebyshev,
        domain,
        degree,
        ..BuildOptions::default()
    };
    let full_op = SparsePolyOp::from_graph(
        &gg.graph,
        TransformKind::LimitNegExp { ell: 251 },
        &op_opts(DomainEstimate::Power, Degree::Native),
    )
    .unwrap();
    let auto_op = SparsePolyOp::from_graph(
        &gg.graph,
        TransformKind::LimitNegExp { ell: 251 },
        &op_opts(DomainEstimate::Lanczos, Degree::Auto { tol: 1e-9, max: usize::MAX }),
    )
    .unwrap();
    assert!(
        auto_op.sweeps() * 2 <= full_op.sweeps(),
        "no ≥2× sweep reduction: {} vs {}",
        auto_op.sweeps(),
        full_op.sweeps()
    );
}

#[test]
fn non_native_knobs_rejected_on_xla_backend_and_monomial_basis() {
    let gg = cliques(&CliqueSpec { n: 12, k: 2, max_short_circuit: 1, seed: 2 });
    let xla = |build| PipelineConfig {
        k: 2,
        build,
        backend: Backend::Xla { artifacts_dir: "artifacts".into() },
        ..Default::default()
    };
    let err = Pipeline::new(xla(BuildOptions {
        domain: DomainEstimate::Lanczos,
        ..BuildOptions::default()
    }))
    .run(&gg.graph)
    .unwrap_err();
    assert!(format!("{err:#}").contains("native backend"), "{err:#}");
    let err = Pipeline::new(xla(BuildOptions {
        degree: Degree::Fixed(31),
        ..BuildOptions::default()
    }))
    .run(&gg.graph)
    .unwrap_err();
    assert!(format!("{err:#}").contains("native backend"), "{err:#}");
    // Degree reshaping without the Chebyshev basis: clear error on both
    // operator paths.
    for op_mode in [OpMode::DenseMaterialized, OpMode::MatrixFree] {
        let cfg = PipelineConfig {
            k: 2,
            op_mode,
            ground_truth: op_mode == OpMode::DenseMaterialized,
            build: BuildOptions {
                degree: Degree::Auto { tol: 1e-9, max: usize::MAX },
                ..BuildOptions::default()
            },
            ..Default::default()
        };
        let err = Pipeline::new(cfg).run(&gg.graph).unwrap_err();
        assert!(format!("{err:#}").contains("--basis chebyshev"), "{op_mode:?}: {err:#}");
    }
}

#[test]
fn lanczos_bounds_are_deterministic_across_worker_counts_on_big_sparse() {
    // A larger CSR-only instance (no dense mirror): the estimate is
    // worker-invariant and the resulting adaptive operator is bitwise
    // deterministic end to end.
    let g = barabasi_albert(600, 4, 17).graph;
    let lc = g.laplacian_csr();
    let serial = DomainEstimate::Lanczos.estimate_csr(&lc, 0.0, 1).unwrap();
    for workers in [2usize, 8] {
        let par = DomainEstimate::Lanczos.estimate_csr(&lc, 0.0, workers).unwrap();
        assert_eq!(par.lo.to_bits(), serial.lo.to_bits());
        assert_eq!(par.hi.to_bits(), serial.hi.to_bits());
    }
    let v = sped::solvers::random_init(600, 4, 7);
    let mk = |threads| {
        let opts = BuildOptions {
            basis: PolyBasis::Chebyshev,
            domain: DomainEstimate::Lanczos,
            degree: Degree::Auto { tol: 1e-9, max: usize::MAX },
            threads,
            ..BuildOptions::default()
        };
        SparsePolyOp::from_csr(lc.clone(), TransformKind::LimitNegExp { ell: 251 }, &opts).unwrap()
    };
    let reference = mk(1).apply_ref(&v);
    for threads in [2usize, 8] {
        let par = mk(threads).apply_ref(&v);
        assert!(
            reference
                .data()
                .iter()
                .zip(par.data().iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "adaptive operator diverged at {threads} workers"
        );
    }
}

/// `MatVecOp::apply` needs `&mut self`; tiny adapter for one-shot use on a
/// temporary.
trait ApplyRef {
    fn apply_ref(self, v: &DMat) -> DMat;
}

impl ApplyRef for SparsePolyOp {
    fn apply_ref(mut self, v: &DMat) -> DMat {
        use sped::solvers::MatVecOp;
        self.apply(v)
    }
}
