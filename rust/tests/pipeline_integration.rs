//! Native-backend integration across modules: graph generators →
//! transforms → solvers → clustering → metrics, plus the stochastic
//! (walk-estimated) path. No artifacts required.

use sped::cluster::adjusted_rand_index;
use sped::graph::gen::{cliques, ring_of_cliques, CliqueSpec};
use sped::linkpred::{complete_graph, drop_edges};
use sped::mdp::{GridWorld, ThreeRoomSpec};
use sped::pipeline::{Pipeline, PipelineConfig};
use sped::transforms::{OpMode, TransformKind};

#[test]
fn full_native_pipeline_all_transforms() {
    let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 1 });
    for transform in [
        TransformKind::Identity,
        TransformKind::NegExp,
        TransformKind::LimitNegExp { ell: 51 },
        // ℓ must cover the raw spectrum (ρ(L) ≈ 14 here): a degree-31
        // Taylor of −e^{−x} diverges above x ≈ 12 and *fails* (the paper's
        // Fig 6 finding — exercised deliberately in fig6_series_terms).
        TransformKind::TaylorNegExp { ell: 101 },
        TransformKind::MatrixLog { eps: 0.05 },
    ] {
        let cfg = PipelineConfig {
            k: 3,
            transform,
            solver: "subspace".into(),
            steps: 800,
            eval_every: 20,
            stop_error: 1e-8,
            ..Default::default()
        };
        let out = Pipeline::new(cfg).run(&gg.graph).unwrap();
        let ari = adjusted_rand_index(
            &out.clustering.as_ref().unwrap().assignments,
            &gg.labels,
        );
        assert!(ari > 0.9, "{transform}: ARI {ari}");
    }
}

#[test]
fn threaded_pipeline_reproduces_serial_clustering_end_to_end() {
    // The user-facing contract of the `threads` knob: same graph, same
    // seed, any worker count → the same convergence history, embedding,
    // and hard clustering, bit for bit, while still recovering the
    // ground-truth communities. At this graph size the knob genuinely
    // parallelizes the transform build (matpow sharding); the solver's
    // M·V product stays serial under DenseOp's small-product guard — its
    // sharded determinism is pinned separately by the `linalg::par`
    // worker-count tests, which include solver-shaped skinny products.
    let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 6 });
    let mk = |threads| PipelineConfig {
        k: 3,
        transform: TransformKind::LimitNegExp { ell: 51 },
        solver: "subspace".into(),
        steps: 600,
        eval_every: 20,
        stop_error: 1e-8,
        threads,
        ..Default::default()
    };
    let serial = Pipeline::new(mk(1)).run(&gg.graph).unwrap();
    let par = Pipeline::new(mk(8)).run(&gg.graph).unwrap();
    assert!(serial
        .embedding
        .data()
        .iter()
        .zip(par.embedding.data().iter())
        .all(|(a, b)| a.to_bits() == b.to_bits()));
    assert_eq!(serial.lambda_star.to_bits(), par.lambda_star.to_bits());
    assert_eq!(serial.history.points.len(), par.history.points.len());
    for (a, b) in serial.history.points.iter().zip(par.history.points.iter()) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.subspace_error.to_bits(), b.subspace_error.to_bits());
        assert_eq!(a.streak, b.streak);
    }
    assert_eq!(
        serial.clustering.as_ref().unwrap().assignments,
        par.clustering.as_ref().unwrap().assignments
    );
    let ari = adjusted_rand_index(&par.clustering.as_ref().unwrap().assignments, &gg.labels);
    assert!(ari > 0.9, "ARI {ari}");
}

#[test]
fn matrix_free_pipeline_recovers_dense_clusters_on_cliques() {
    // The OpMode contract on the paper's §5.4 clique benchmark: the
    // matrix-free path (no ground truth, no dense anything) recovers the
    // same communities as the materialized-dense path.
    let gg = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 11 });
    let mk = |op_mode, ground_truth| PipelineConfig {
        k: 3,
        transform: TransformKind::LimitNegExp { ell: 51 },
        solver: "subspace".into(),
        steps: 400,
        eval_every: 20,
        stop_error: 0.0, // fixed step count in both modes
        op_mode,
        ground_truth,
        ..Default::default()
    };
    let dense = Pipeline::new(mk(OpMode::DenseMaterialized, true)).run(&gg.graph).unwrap();
    let sparse = Pipeline::new(mk(OpMode::MatrixFree, false)).run(&gg.graph).unwrap();
    let a_dense = &dense.clustering.as_ref().unwrap().assignments;
    let a_sparse = &sparse.clustering.as_ref().unwrap().assignments;
    let cross = adjusted_rand_index(a_sparse, a_dense);
    assert!(cross > 0.999, "dense vs matrix-free cluster ARI {cross}");
    let ari = adjusted_rand_index(a_sparse, &gg.labels);
    assert!(ari > 0.9, "matrix-free ARI vs ground truth {ari}");
}

#[test]
fn matrix_free_pipeline_runs_where_dense_would_blow_a_256mb_cap() {
    // n = 6000: the dense Laplacian alone (one DMat::zeros(n, n)) would be
    // 288 MB — over a 256 MB cap — before the transform build even starts.
    // The matrix-free pipeline handles the same graph in O(n + nnz): the
    // acceptance check that OpMode::MatrixFree performs zero n×n dense
    // allocations after graph load.
    let n = 6000usize;
    assert!(
        n * n * std::mem::size_of::<f64>() > 256 * 1024 * 1024,
        "cap sanity: dense n×n must exceed 256 MB"
    );
    let gg = ring_of_cliques(n / 20, 20, 0);
    assert_eq!(gg.graph.num_nodes(), n);
    let cfg = PipelineConfig {
        k: 4,
        transform: TransformKind::Identity,
        solver: "subspace".into(),
        steps: 20,
        eval_every: 10,
        stop_error: 0.0,
        op_mode: OpMode::MatrixFree,
        ground_truth: false,
        ..Default::default()
    };
    let out = Pipeline::new(cfg).run(&gg.graph).unwrap();
    assert_eq!(out.embedding.rows(), n);
    assert_eq!(out.embedding.cols(), 4);
    assert!(out.embedding.data().iter().all(|x| x.is_finite()));
    assert_eq!(out.clustering.unwrap().assignments.len(), n);
    // Dense-free: the oracle never ran, and the "transform build" stage is
    // just CSR assembly + a power iteration — no O(ℓn³) materialization.
    assert_eq!(out.timings.ground_truth, 0.0);
    assert!(out.history.points.is_empty());
}

#[test]
fn sparse_poly_op_direct_on_large_graph() {
    // SparsePolyOp itself (no pipeline) on a graph size where a single
    // dense n×n buffer would exceed the 256 MB cap: one operator apply is
    // O(ℓ·nnz·k) and touches nothing quadratic.
    use sped::solvers::{MatVecOp, SparsePolyOp};
    let n = 6000usize;
    assert!(n * n * std::mem::size_of::<f64>() > 256 * 1024 * 1024);
    let gg = ring_of_cliques(n / 20, 20, 0);
    let mut op = SparsePolyOp::from_graph(
        &gg.graph,
        TransformKind::LimitNegExp { ell: 15 },
        &sped::transforms::BuildOptions::default(),
    )
    .unwrap();
    assert_eq!(op.dim(), n);
    let v = sped::solvers::random_init(n, 4, 17);
    let out = op.apply(&v);
    assert_eq!((out.rows(), out.cols()), (n, 4));
    assert!(out.data().iter().all(|x| x.is_finite()));
}

#[test]
fn pipeline_on_mdp_pvfs() {
    let world = GridWorld::three_rooms(ThreeRoomSpec { s: 1, h: 10 }).unwrap();
    let cfg = PipelineConfig {
        k: 3,
        transform: TransformKind::NegExp,
        solver: "oja".into(),
        eta: 0.5,
        steps: 3000,
        eval_every: 50,
        stop_error: 1e-5,
        do_cluster: true,
        ..Default::default()
    };
    let out = Pipeline::new(cfg).run(&world.graph).unwrap();
    assert!(out.history.last().unwrap().subspace_error < 1e-2);
    // Spectral clustering of the 3-room world ≈ the rooms.
    let rooms: Vec<usize> = (0..world.num_states()).map(|s| world.room_of(s)).collect();
    let ari = adjusted_rand_index(
        &out.clustering.as_ref().unwrap().assignments,
        &rooms,
    );
    assert!(ari > 0.6, "room recovery ARI {ari}");
}

#[test]
fn pipeline_on_linkpred_completed_graph() {
    let gg = cliques(&CliqueSpec { n: 45, k: 3, max_short_circuit: 2, seed: 3 });
    let completed = complete_graph(&drop_edges(&gg.graph, 0.2, 7).unwrap()).unwrap();
    let cfg = PipelineConfig {
        k: 3,
        transform: TransformKind::LimitNegExp { ell: 251 },
        solver: "mu-eg".into(),
        eta: 0.5,
        steps: 6000,
        eval_every: 100,
        stop_error: 1e-4,
        ..Default::default()
    };
    let out = Pipeline::new(cfg).run(&completed).unwrap();
    let ari = adjusted_rand_index(
        &out.clustering.as_ref().unwrap().assignments,
        &gg.labels,
    );
    assert!(ari > 0.85, "ARI {ari}");
}

#[test]
fn stochastic_walk_oracle_drives_oja() {
    use sped::solvers::stochastic::StochasticPolyOp;
    use sped::solvers::{run_convergence, Oja, RunConfig};
    use sped::walks::SampleMethod;
    // p(x) = x (identity through the walk estimator), λ* from power iter.
    let gg = cliques(&CliqueSpec { n: 20, k: 2, max_short_circuit: 1, seed: 5 });
    let l = gg.graph.laplacian();
    let e = sped::linalg::eigh(&l).unwrap();
    let v_star = e.bottom_k(2);
    let lam_star = e.lambda_max() * 1.05;
    let mut op = StochasticPolyOp::new(
        &gg.graph,
        vec![0.0, 1.0],
        lam_star,
        400,
        SampleMethod::Importance,
        11,
    );
    let mut solver = Oja { eta: 0.01 / lam_star };
    let cfg = RunConfig { steps: 3000, eval_every: 100, ..Default::default() };
    let hist = run_convergence(&mut solver, &mut op, &v_star, &cfg);
    let err = hist.last().unwrap().subspace_error;
    assert!(err < 0.25, "stochastic-walk Oja err {err}");
}

#[test]
fn ring_of_cliques_multiway() {
    let gg = ring_of_cliques(4, 8, 0);
    let cfg = PipelineConfig {
        k: 4,
        transform: TransformKind::NegExp,
        solver: "subspace".into(),
        steps: 500,
        eval_every: 20,
        stop_error: 1e-8,
        ..Default::default()
    };
    let out = Pipeline::new(cfg).run(&gg.graph).unwrap();
    let ari = adjusted_rand_index(
        &out.clustering.as_ref().unwrap().assignments,
        &gg.labels,
    );
    assert!(ari > 0.9, "ARI {ari}");
}

#[test]
fn walker_fleet_feeds_transform_build() {
    // §4.3 end-to-end: estimate L and L² with the parallel fleet, assemble
    // p(L̂) = L̂ − 0.05·L̂², reverse, and check the Fiedler vector survives.
    use sped::coordinator::walkers::{WalkerPool, WalkerPoolConfig};
    use std::sync::Arc;
    let gg = cliques(&CliqueSpec { n: 16, k: 2, max_short_circuit: 1, seed: 9 });
    let g = Arc::new(gg.graph.clone());
    let pool = WalkerPool::spawn(g.clone(), WalkerPoolConfig::default());
    let (l1, _) = pool.estimate_power(1, 40_000, 8, 1);
    let (l2, _) = pool.estimate_power(2, 80_000, 8, 2);
    pool.shutdown();
    let mut p = l1.clone();
    p.axpy(-0.05, &l2);
    p.symmetrize();
    // M = λ*I − p(L̂)
    let lam = sped::linalg::funcs::power_lambda_max(&p, 100).unwrap() * 1.05;
    let mut m = p;
    m.scale(-1.0);
    m.add_diag(lam);
    let e_m = sped::linalg::eigh(&m).unwrap();
    let e_l = sped::linalg::eigh(&gg.graph.laplacian()).unwrap();
    // 2nd-from-top of M ≈ Fiedler vector of L (top is the ones vector).
    let est = e_m.vectors.col(m.rows() - 2);
    let truth = e_l.vectors.col(1);
    let align = sped::linalg::dmat::dot(&est, &truth).abs();
    assert!(align > 0.9, "Fiedler alignment {align}");
}
