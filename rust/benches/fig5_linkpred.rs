//! Figure 5 — link-prediction-completed probabilistic graphs: SPED
//! generalizes to weighted Laplacians (App A.1).
//!
//! Expected shape: same ordering as Figure 4 — the transform only sees the
//! spectrum, not the underlying (now weighted) graph object.

use sped::coordinator::experiments::{fig5_linkpred, summarize, ExperimentOptions};
use sped::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("fig5_linkpred");
    let opts = ExperimentOptions::default();
    let t0 = std::time::Instant::now();
    let curves = fig5_linkpred(&opts).expect("fig5 harness");
    suite.report(&format!(
        "figure 5 regenerated in {:.1}s → {}/fig5_linkpred.csv",
        t0.elapsed().as_secs_f64(),
        opts.out_dir
    ));
    for row in summarize(&curves, 3) {
        suite.report(&row);
    }
    suite.finish();
}
