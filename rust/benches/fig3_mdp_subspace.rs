//! Figure 3 — 3-room MDP: normalized subspace error (eq 15) over training.
//!
//! Shares the Figure-2 run (same curves, second metric — the paper plots
//! them as two figures). Prints the steps-to-error(0.01) summary and an
//! ASCII convergence plot of the µ-EG curves.

use sped::coordinator::experiments::{fig2_fig3_mdp, summarize, ExperimentOptions};
use sped::linalg::metrics::ConvergenceHistory;
use sped::util::bench::BenchSuite;

fn ascii_curve(c: &ConvergenceHistory, width: usize) -> String {
    // log-error sparkline: '#' = high error … '.' = low.
    let ramp: &[u8] = b"#%*+=-:. ";
    let pts: Vec<f64> = c.points.iter().map(|p| p.subspace_error.max(1e-8)).collect();
    if pts.is_empty() {
        return String::new();
    }
    let stride = (pts.len() as f64 / width as f64).max(1.0);
    let mut s = String::new();
    let (lo, hi) = (1e-6f64.ln(), 1.0f64.ln());
    let mut i = 0.0;
    while (i as usize) < pts.len() && s.len() < width {
        let e = pts[i as usize].ln().clamp(lo, hi);
        let t = (e - lo) / (hi - lo); // 0 = converged, 1 = bad
        let idx = ((1.0 - t) * (ramp.len() - 1) as f64).round() as usize;
        s.push(ramp[idx.min(ramp.len() - 1)] as char);
        i += stride;
    }
    s
}

fn main() {
    let mut suite = BenchSuite::new("fig3_mdp_subspace");
    let opts = ExperimentOptions::default();
    let curves = fig2_fig3_mdp(&opts).expect("fig3 harness");
    suite.report("subspace-error summaries (same runs as Figure 2):");
    for row in summarize(&curves, 8) {
        suite.report(&row);
    }
    suite.report("");
    suite.report("log-subspace-error over training ('#' high → ' ' converged):");
    for c in &curves {
        suite.report(&format!("  {:<42} |{}|", c.label, ascii_curve(c, 60)));
    }
    suite.finish();
}
