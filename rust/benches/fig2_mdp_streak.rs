//! Figure 2 — 3-room MDP: longest eigenvector streak over training for
//! µ-EG and Oja under {identity, exact −e^{−L}, limit series ℓ=251,
//! exact log(L+ε)}.
//!
//! Regenerates the figure's series as `results/fig2_fig3_mdp.csv` and
//! prints the steps-to-streak summary. Expected shape (paper): series
//! transform ≈ 10× fewer steps than identity, exact log ≈ 100×.
//!
//! `SPED_BENCH_FAST=1 cargo bench --bench fig2_mdp_streak` for a smoke run.

use sped::coordinator::experiments::{fig2_fig3_mdp, summarize, ExperimentOptions};
use sped::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("fig2_mdp_streak");
    let opts = ExperimentOptions::default();
    let t0 = std::time::Instant::now();
    let curves = fig2_fig3_mdp(&opts).expect("fig2 harness");
    suite.report(&format!(
        "figure 2 regenerated in {:.1}s → {}/fig2_fig3_mdp.csv",
        t0.elapsed().as_secs_f64(),
        opts.out_dir
    ));
    suite.report("");
    for row in summarize(&curves, 8) {
        suite.report(&row);
    }
    // The headline shape: any accelerated transform reaches streak 8 in
    // fewer steps than its identity counterpart on the same solver.
    suite.report("");
    for solver in ["mu-eg", "oja"] {
        let steps = |label_frag: &str| {
            curves
                .iter()
                .find(|c| c.label.starts_with(solver) && c.label.contains(label_frag))
                .and_then(|c| c.steps_to_streak(8))
        };
        let id = steps("identity");
        let exp = steps("-exp(-L)");
        let lim = steps("limit_negexp");
        let log = steps("log(");
        suite.report(&format!(
            "{solver}: steps→streak8  identity {:?}  exact-exp {:?}  limit-T251 {:?}  exact-log {:?}",
            id, exp, lim, log
        ));
    }
    suite.finish();
}
