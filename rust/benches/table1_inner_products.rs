//! Table 1 — edge-vector inner products.
//!
//! Verifies the five combinatorial cases (disconnected 0, serial −1,
//! converging +1, diverging +1, repeated +2) exhaustively against the dense
//! incidence-vector oracle, then times the classification hot path (it sits
//! inside every walk step of the §4.3 estimator).

use sped::graph::incidence::{classify_pair, inner_product, inner_product_dense, EdgePairKind};
use sped::graph::Edge;
use sped::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("table1_inner_products");

    // --- correctness: exhaustive over all canonical edge pairs on 8 nodes ---
    let mut edges = Vec::new();
    for u in 0..8u32 {
        for v in (u + 1)..8 {
            edges.push(Edge { u, v, w: 1.0 });
        }
    }
    let mut counts = [0usize; 5];
    let mut mismatches = 0;
    for &a in &edges {
        for &b in &edges {
            let fast = inner_product(a, b);
            let slow = inner_product_dense(a, b, 8);
            if (fast - slow).abs() > 1e-12 {
                mismatches += 1;
            }
            let idx = match classify_pair(a, b) {
                EdgePairKind::Disconnected => 0,
                EdgePairKind::Serial => 1,
                EdgePairKind::Converging => 2,
                EdgePairKind::Diverging => 3,
                EdgePairKind::Repeated => 4,
            };
            counts[idx] += 1;
        }
    }
    suite.report(&format!(
        "table 1 verification: {} pairs, {mismatches} mismatches vs dense oracle",
        edges.len() * edges.len()
    ));
    suite.report(&format!(
        "  case counts — disconnected {} | serial {} | converging {} | diverging {} | repeated {}",
        counts[0], counts[1], counts[2], counts[3], counts[4]
    ));
    suite.report("  values      —            0  |       -1  |         +1  |        +1  |       +2");
    assert_eq!(mismatches, 0);

    // --- throughput of the classification (walk-estimator hot path) ---
    let pairs: Vec<(Edge, Edge)> = edges
        .iter()
        .flat_map(|&a| edges.iter().map(move |&b| (a, b)))
        .collect();
    let npairs = pairs.len() as f64;
    suite.bench_units("inner_product (combinatorial)", npairs, "pairs", || {
        let mut acc = 0.0;
        for &(a, b) in &pairs {
            acc += inner_product(a, b);
        }
        std::hint::black_box(acc);
    });
    suite.bench_units("inner_product_dense (oracle)", npairs, "pairs", || {
        let mut acc = 0.0;
        for &(a, b) in &pairs {
            acc += inner_product_dense(a, b, 8);
        }
        std::hint::black_box(acc);
    });
    suite.finish();
}
