//! §4.3 — the stochastic, parallel walk estimator: unbiasedness, Monte-
//! Carlo convergence, rejection vs importance, walker-fleet throughput and
//! scaling, and the engine-construction overhead split.

use std::sync::Arc;

use sped::coordinator::walkers::{WalkerPool, WalkerPoolConfig};
use sped::graph::gen::{cliques, CliqueSpec};
use sped::linalg::funcs::matpow;
use sped::util::bench::{fast_mode, BenchSuite};
use sped::walks::{estimate_l_power, SampleMethod, WalkEngine, WalkSample};

fn main() {
    let mut suite = BenchSuite::new("walk_estimator");
    let gg = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 3, seed: 3 });
    let g = gg.graph;
    let l = g.laplacian();
    let l2 = matpow(&l, 2);
    let l3 = matpow(&l, 3);

    // --- Monte-Carlo convergence table ---
    suite.report("estimator error vs walk budget (rel max-abs error):");
    suite.report(&format!(
        "  {:<12} {:>5} {:>9} {:>9} {:>9}",
        "method", "len", "8k", "32k", "128k"
    ));
    let budgets: &[usize] = if fast_mode() { &[2_000, 4_000, 8_000] } else { &[8_000, 32_000, 128_000] };
    for method in [SampleMethod::Rejection, SampleMethod::Importance] {
        for (len, truth) in [(2usize, &l2), (3usize, &l3)] {
            let errs: Vec<String> = budgets
                .iter()
                .map(|&w| {
                    let (est, _) = estimate_l_power(&g, len, w, 4, method, w as u64);
                    format!("{:.4}", (&est - truth).max_abs() / truth.max_abs())
                })
                .collect();
            suite.report(&format!(
                "  {:<12} {:>5} {:>9} {:>9} {:>9}",
                format!("{method:?}"),
                len,
                errs[0],
                errs[1],
                errs[2]
            ));
        }
    }
    // Acceptance rates by length.
    let engine_stats: Vec<String> = (1..=5)
        .map(|len| {
            let (_, s) = estimate_l_power(&g, len, 4000, 2, SampleMethod::Rejection, len as u64);
            format!("len {len}: {:.3}", s.acceptance_rate())
        })
        .collect();
    suite.report(&format!("rejection acceptance rates — {}", engine_stats.join(", ")));

    // --- raw walk throughput (single engine) ---
    let engine = WalkEngine::new(&g);
    let mut rng = sped::util::rng::Rng::new(9);
    let mut walk = WalkSample { edges: vec![], alpha: vec![], prob: vec![] };
    suite.bench_units("sample_walk len=3 (single thread)", 1000.0, "walks", || {
        for _ in 0..1000 {
            engine.sample_walk_into(3, &mut rng, &mut walk);
        }
    });
    suite.bench("engine construction (|E| CSR build)", || {
        std::hint::black_box(WalkEngine::new(&g));
    });

    // --- fleet throughput vs worker count (structural on 1 core) ---
    let total = if fast_mode() { 20_000 } else { 100_000 };
    for workers in [1usize, 2, 4, 8] {
        let pool = WalkerPool::spawn(
            Arc::new(g.clone()),
            WalkerPoolConfig { workers, backlog: 8, method: SampleMethod::Importance },
        );
        let t0 = std::time::Instant::now();
        let (_, stats) = pool.estimate_power(3, total, workers * 4, 7);
        let dt = t0.elapsed().as_secs_f64();
        pool.shutdown();
        suite.report(&format!(
            "fleet {workers} workers: {:.0} walks/s ({} trials in {dt:.2}s)",
            stats.trials as f64 / dt,
            stats.trials
        ));
    }
    suite.finish();
}
