//! Figure 4 — well-clustered clique graphs: streak vs training step across
//! graph sizes and cluster counts.
//!
//! Paper shape to reproduce: transforms accelerate convergence everywhere;
//! the series approximation degrades when cliques get large (max degree ↑
//! → spectral radius ↑ → ℓ=251 no longer covers the spectrum), while with
//! more clusters (smaller cliques) it succeeds — the crossover discussed in
//! §5.4. `--full-size` (via `sped experiment`) runs the paper's n=1000/2000.

use sped::coordinator::experiments::{fig4_cliques, summarize, ExperimentOptions};
use sped::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("fig4_cliques");
    let opts = ExperimentOptions::default();
    let t0 = std::time::Instant::now();
    let curves = fig4_cliques(&opts).expect("fig4 harness");
    suite.report(&format!(
        "figure 4 regenerated in {:.1}s → {}/fig4_cliques.csv",
        t0.elapsed().as_secs_f64(),
        opts.out_dir
    ));
    for row in summarize(&curves, 3) {
        suite.report(&row);
    }
    // Crossover check: limit-T251 steps-to-streak as cliques grow denser
    // (fewer clusters at fixed n → larger max degree → series strain).
    // Each panel's streak target is its own cluster count (parsed from the
    // `nNNN_cC|` label prefix).
    let target_of = |label: &str| -> usize {
        label
            .split('|')
            .next()
            .and_then(|p| p.split("_c").nth(1))
            .and_then(|c| c.parse().ok())
            .unwrap_or(2)
            .max(2)
    };
    suite.report("");
    suite.report("series strain with clique density (limit_negexp_T251, oja):");
    for c in curves.iter().filter(|c| c.label.contains("oja|limit_negexp")) {
        let k = target_of(&c.label);
        let s = c
            .steps_to_streak(k)
            .map(|x| x.to_string())
            .unwrap_or_else(|| "never".into());
        suite.report(&format!("  {:<44} steps→streak{k}: {s}", c.label));
    }
    suite.finish();
}
