//! Whole-stack hot-path profile — the measurement side of EXPERIMENTS.md
//! §Perf. Times every layer's inner loops:
//!
//! * L3 native: blocked matmul (vs naive), the row-sharded parallel kernels
//!   (matmul / Horner polynomial apply at 1–`threads` workers, with a
//!   bitwise-equality check against the serial path), symmetric eigh, MGS,
//!   solver steps (Oja / µ-EG), transform builders (Horner vs matpow),
//!   k-means, walk sampling.
//! * Sparse vs dense operator crossover: the `OpMode::MatrixFree` path
//!   (CSR SpMM solver steps, no materialized `p(L)`) against the dense
//!   build + dense-step path on clique workloads, n ∈ {256, 1024, 4096} ×
//!   ℓ ∈ {15, 251} (shrunk under `SPED_BENCH_FAST=1`), with results also
//!   written to `BENCH_sparse_vs_dense.json` at the repo root.
//! * Blocked vs streaming skinny SpMM: the register-blocked kernel family
//!   against the streaming reference per bundle width (single SpMM and the
//!   ℓ-SpMM matrix-free solver step), plus the RCM reordering locality
//!   effect on a scrambled power-law graph — written to
//!   `BENCH_spmm_blocked.json`.
//! * Polynomial bases: the monomial solver step unfused (pre-refactor
//!   SpMM + scale + axpy) vs fused (`spmm_step_into`) vs the
//!   Chebyshev-basis three-term recurrence, with the max float divergence
//!   between the bases — written to `BENCH_poly_basis.json`.
//! * Adaptive degrees + Lanczos domains: the full-degree Chebyshev
//!   operator on the historical power/Gershgorin domain vs `--degree auto`
//!   truncation on the tight `--domain lanczos` interval — SpMM sweeps per
//!   operator application, wall time, scalar-map error at the true
//!   eigenvalues, and an end-to-end pipeline-convergence run — written to
//!   `BENCH_adaptive_degree.json` (asserts the ≥2× sweep reduction at
//!   ≤1e-6 map error).
//! * Ritz solver on the dilated operator: outer iterations-to-tolerance
//!   and total SpMM sweeps for the block Rayleigh–Ritz solver on the
//!   dilated (`limit_negexp`) operator vs the undilated reversed Laplacian
//!   (`identity`), on a sparse community-expander workload at
//!   n ∈ {4096, 65536} — written to `BENCH_ritz_solver.json` (asserts the
//!   dilated operator converges in strictly fewer outer iterations).
//! * Ritz deflation + sharded applies: locked-convergence vs fixed-block
//!   SpMM column-sweep volume at the same tolerance (asserts ≤0.7× outside
//!   fast mode at n ∈ {4096, 65536}), the sharded pipeline bitwise vs
//!   unsharded over every (shards, workers) pair, and — outside fast
//!   mode — the n = 10⁶ streamed power-law solve. Written to
//!   `BENCH_ritz_deflation.json`.
//! * SIMD + mixed precision + sharded SpMM: the width-dispatched kernel
//!   family (portable-SIMD under `--features simd`, unrolled otherwise)
//!   against the streaming reference, the f32-storage/f64-accumulator
//!   mixed ℓ-sweep against the fused f64 sweep at k = 8 (asserting the
//!   ≥1.5× throughput floor outside fast mode and the documented error
//!   budget always), the halo-exchange sharded apply against the unsharded
//!   kernel (bitwise), and the `--precision mixed --degree auto` operator
//!   map error at the true eigenvalues — written to
//!   `BENCH_spmm_simd.json`.
//! * XLA path (when artifacts exist): chunked solver steps, poly build,
//!   matpow, matvec round-trip — including the PJRT call overhead.
//!
//! The worker count for the parallel cases comes from `--threads=N`
//! (e.g. `cargo bench --bench perf_hotpath -- --threads=8`) or the
//! `SPED_THREADS` env var; default 4.

use sped::graph::gen::{barabasi_albert, cliques, CliqueSpec};
use sped::linalg::dmat::DMat;
use sped::linalg::matmul::{matmul, matmul_naive};
use sped::linalg::par::{matmul_par, poly_horner_par};
use sped::solvers::{DenseOp, EigenSolver, MatVecOp, SparsePolyOp};
use sped::transforms::{build_solver_matrix, BuildOptions, TransformKind};
use sped::util::bench::{fast_mode, fast_mode_scale, human, human_time, BenchSuite, JsonVal};
use sped::util::rng::Rng;

fn random_mat(seed: u64, r: usize, c: usize) -> DMat {
    let mut rng = Rng::new(seed);
    DMat::from_fn(r, c, |_, _| rng.normal())
}

/// Worker-count knob: `--threads=N` argument or `SPED_THREADS=N` env var
/// (flag form keeps it invisible to the bench-name filter).
fn threads_param() -> usize {
    for arg in std::env::args().skip(1) {
        if let Some(v) = arg.strip_prefix("--threads=") {
            return v.parse().expect("--threads=N needs an integer");
        }
    }
    std::env::var("SPED_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
}

/// Best-of-`reps` wall time of `f` (returns the last result for checking).
fn best_of<T>(reps: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    let mut best = f64::INFINITY;
    let mut out = f();
    for _ in 0..reps {
        let t = std::time::Instant::now();
        out = f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    (best, out)
}

fn bitwise_eq(a: &DMat, b: &DMat) -> bool {
    a.rows() == b.rows()
        && a.cols() == b.cols()
        && a.data().iter().zip(b.data().iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// One-shot wall time of `f` (builds that are too expensive to repeat).
fn timed<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t = std::time::Instant::now();
    let out = f();
    (t.elapsed().as_secs_f64(), out)
}

/// Sparse-vs-dense operator crossover (the `OpMode::MatrixFree` acceptance
/// measurement): for each (n, ℓ) on the §5.4 clique workload, time the
/// dense path (materialize `M = λ*I − p(L)`, then `M·V` per step) against
/// the matrix-free path (CSR build ≈ free, `ℓ` SpMMs per step), and emit
/// `BENCH_sparse_vs_dense.json` at the repo root for CI trend tracking.
///
/// `full_grid` adds the n = 4096 column, whose *dense* builds alone are
/// ~10¹² multiply-adds — only enabled when the group is selected by an
/// explicit filter (`cargo bench --bench perf_hotpath -- sparse-vs-dense`),
/// never as a side effect of an unfiltered full-suite run.
fn sparse_vs_dense_crossover(suite: &mut BenchSuite, threads: usize, full_grid: bool) {
    let ns: &[usize] = if fast_mode() {
        &[256, 1024]
    } else if full_grid {
        &[256, 1024, 4096]
    } else {
        &[256, 1024]
    };
    let ells: &[usize] = if fast_mode() { &[15] } else { &[15, 251] };
    let k = 8;
    let step_reps = if fast_mode() { 3 } else { 10 };
    // Steps a real solve takes before early stop on this workload — the
    // horizon over which the dense build must amortize.
    const AMORTIZE_STEPS: f64 = 100.0;
    let mut rows: Vec<Vec<(String, JsonVal)>> = Vec::new();
    for &n in ns {
        // 16-node cliques: a genuinely sparse community graph (nnz/n² ≈ 1%
        // at n=4096) rather than the dense 4-clique variant.
        let gg = cliques(&CliqueSpec { n, k: (n / 16).max(2), max_short_circuit: 2, seed: 42 });
        let l = gg.graph.laplacian();
        let v = sped::solvers::random_init(n, k, 7);
        for &ell in ells {
            let kind = TransformKind::LimitNegExp { ell };
            let opts = BuildOptions { threads, ..BuildOptions::default() };
            let (dense_build_s, sm) = timed(|| build_solver_matrix(&l, kind, &opts).unwrap());
            let mut dop = DenseOp { m: sm.m, threads };
            let (dense_step_s, dense_out) = best_of(step_reps, || dop.apply(&v));
            let (sparse_build_s, mut sop) =
                timed(|| SparsePolyOp::from_graph(&gg.graph, kind, &opts).unwrap());
            let (sparse_step_s, sparse_out) = best_of(step_reps, || sop.apply(&v));
            // Cross-path sanity: the two operators agree (tolerance, not
            // bitwise — different association of the same polynomial).
            let diff = (&dense_out - &sparse_out).max_abs();
            assert!(
                diff < 1e-6 * (1.0 + dense_out.max_abs()),
                "sparse/dense operator divergence {diff} at n={n}, ell={ell}"
            );
            let nnz = sop.nnz();
            let dense_total = dense_build_s + AMORTIZE_STEPS * dense_step_s;
            let sparse_total = sparse_build_s + AMORTIZE_STEPS * sparse_step_s;
            suite.report(&format!(
                "sparse-vs-dense n={n} ell={ell} nnz={} ({}): dense build {} + step {} | sparse build {} + step {} | {:.2}x total @{} steps",
                nnz,
                human(nnz as f64 / (n * n) as f64 * 100.0, "% fill"),
                human_time(dense_build_s),
                human_time(dense_step_s),
                human_time(sparse_build_s),
                human_time(sparse_step_s),
                dense_total / sparse_total.max(1e-12),
                AMORTIZE_STEPS as usize,
            ));
            rows.push(vec![
                ("n".into(), JsonVal::Int(n as u64)),
                ("ell".into(), JsonVal::Int(ell as u64)),
                ("k".into(), JsonVal::Int(k as u64)),
                ("nnz".into(), JsonVal::Int(nnz as u64)),
                ("threads".into(), JsonVal::Int(threads as u64)),
                ("workload".into(), JsonVal::Str("cliques16".into())),
                ("dense_build_s".into(), JsonVal::Num(dense_build_s)),
                ("dense_step_s".into(), JsonVal::Num(dense_step_s)),
                ("sparse_build_s".into(), JsonVal::Num(sparse_build_s)),
                ("sparse_step_s".into(), JsonVal::Num(sparse_step_s)),
                (
                    "step_speedup".into(),
                    JsonVal::Num(dense_step_s / sparse_step_s.max(1e-12)),
                ),
                (
                    "total_speedup_100_steps".into(),
                    JsonVal::Num(dense_total / sparse_total.max(1e-12)),
                ),
                ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
            ]);
        }
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_sparse_vs_dense.json");
    suite.write_json(&path, &rows).expect("write BENCH_sparse_vs_dense.json");
    suite.report(&format!("wrote {}", path.display()));
}

/// Blocked-vs-streaming skinny SpMM + RCM locality (the register-blocked
/// kernel acceptance measurement): per-SpMM and matrix-free solver-step
/// times per bundle width, streaming reference vs blocked dispatch (with a
/// bitwise-equality check — the determinism contract), plus the RCM
/// bandwidth/locality effect on a scrambled power-law graph. Emits
/// `BENCH_spmm_blocked.json` at the repo root for CI trend tracking.
fn spmm_blocked_group(suite: &mut BenchSuite, threads: usize) {
    use sped::linalg::sparse::{spmm_into, spmm_streaming_into};
    let ns: &[usize] = &[1024, 4096];
    let ks: &[usize] = if fast_mode() { &[8, 16] } else { &[4, 8, 16] };
    let ell = if fast_mode() { 15 } else { 251 };
    let reps = if fast_mode() { 3 } else { 10 };
    let step_reps = if fast_mode() { 2 } else { 5 };
    let mut rows: Vec<Vec<(String, JsonVal)>> = Vec::new();
    for &n in ns {
        // Same 16-node-clique community workload as the crossover group.
        let gg = cliques(&CliqueSpec { n, k: (n / 16).max(2), max_short_circuit: 2, seed: 42 });
        let l = gg.graph.laplacian_csr();
        let nnz = l.nnz();
        for &k in ks {
            let v = sped::solvers::random_init(n, k, 7);
            let mut c_streaming = DMat::zeros(n, k);
            let mut c_blocked = DMat::zeros(n, k);
            // Single-SpMM kernel comparison at 1 worker (register blocking
            // is a per-core effect; sharding multiplies both paths alike).
            let (t_stream, _) =
                best_of(reps, || spmm_streaming_into(&l, &v, &mut c_streaming, 1));
            let (t_block, _) = best_of(reps, || spmm_into(&l, &v, &mut c_blocked, 1));
            assert!(
                bitwise_eq(&c_blocked, &c_streaming),
                "blocked/streaming SpMM divergence at n={n}, k={k}"
            );
            // Matrix-free solver step: the ℓ-SpMM ping-pong of
            // SparsePolyOp's NegPower loop, per kernel, at the bench's
            // worker count.
            let step = |use_blocked: bool| {
                let inv = -1.0 / ell as f64;
                let mut w = v.clone();
                let mut t = DMat::zeros(n, k);
                for _ in 0..ell {
                    if use_blocked {
                        spmm_into(&l, &w, &mut t, threads);
                    } else {
                        spmm_streaming_into(&l, &w, &mut t, threads);
                    }
                    t.scale(inv);
                    t.axpy(1.0, &w);
                    std::mem::swap(&mut w, &mut t);
                }
                w
            };
            let (step_stream, w_s) = best_of(step_reps, || step(false));
            let (step_block, w_b) = best_of(step_reps, || step(true));
            assert!(
                bitwise_eq(&w_s, &w_b),
                "blocked/streaming solver-step divergence at n={n}, k={k}"
            );
            suite.report(&format!(
                "spmm-blocked n={n} k={k} nnz={nnz}: spmm streaming {} | blocked {} | {:.2}x; step(ell={ell}, {threads}w) streaming {} | blocked {} | {:.2}x",
                human_time(t_stream),
                human_time(t_block),
                t_stream / t_block.max(1e-12),
                human_time(step_stream),
                human_time(step_block),
                step_stream / step_block.max(1e-12),
            ));
            rows.push(vec![
                ("kind".into(), JsonVal::Str("width-sweep".into())),
                ("n".into(), JsonVal::Int(n as u64)),
                ("k".into(), JsonVal::Int(k as u64)),
                ("ell".into(), JsonVal::Int(ell as u64)),
                ("nnz".into(), JsonVal::Int(nnz as u64)),
                // spmm_* fields are measured at 1 worker (per-core kernel
                // effect); step_* fields at the bench's worker count.
                ("spmm_threads".into(), JsonVal::Int(1)),
                ("step_threads".into(), JsonVal::Int(threads as u64)),
                ("spmm_streaming_s".into(), JsonVal::Num(t_stream)),
                ("spmm_blocked_s".into(), JsonVal::Num(t_block)),
                ("spmm_speedup".into(), JsonVal::Num(t_stream / t_block.max(1e-12))),
                ("step_streaming_s".into(), JsonVal::Num(step_stream)),
                ("step_blocked_s".into(), JsonVal::Num(step_block)),
                ("step_speedup".into(), JsonVal::Num(step_stream / step_block.max(1e-12))),
                ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
            ]);
        }
    }
    // RCM locality: a scrambled Barabási–Albert power-law graph (no
    // locality in the baseline order), blocked kernel, k = 16 — the
    // --reorder rcm effect in isolation.
    {
        let n = 4096usize;
        let k = 16usize;
        let ba = barabasi_albert(n, 4, 7).graph;
        // Affine scramble (odd multiplier mod a power of two is bijective).
        let scramble: Vec<usize> =
            (0..n).map(|i| i.wrapping_mul(1103515245).wrapping_add(12345) % n).collect();
        let scrambled = ba.permute(&scramble).expect("scramble permutation");
        let order = scrambled.rcm_permutation();
        let rcm = scrambled.permute(&order).expect("rcm permutation");
        let v = sped::solvers::random_init(n, k, 3);
        let ls = scrambled.laplacian_csr();
        let lr = rcm.laplacian_csr();
        let mut c = DMat::zeros(n, k);
        let (t_scrambled, _) = best_of(reps, || spmm_into(&ls, &v, &mut c, 1));
        let (t_rcm, _) = best_of(reps, || spmm_into(&lr, &v, &mut c, 1));
        suite.report(&format!(
            "rcm-locality barabasi_albert n={n} m=4 k={k}: bandwidth {} -> {} | spmm scrambled {} | rcm {} | {:.2}x",
            scrambled.bandwidth(),
            rcm.bandwidth(),
            human_time(t_scrambled),
            human_time(t_rcm),
            t_scrambled / t_rcm.max(1e-12),
        ));
        rows.push(vec![
            ("kind".into(), JsonVal::Str("rcm-locality".into())),
            ("n".into(), JsonVal::Int(n as u64)),
            ("k".into(), JsonVal::Int(k as u64)),
            ("nnz".into(), JsonVal::Int(ls.nnz() as u64)),
            ("bandwidth_scrambled".into(), JsonVal::Int(scrambled.bandwidth() as u64)),
            ("bandwidth_rcm".into(), JsonVal::Int(rcm.bandwidth() as u64)),
            ("spmm_scrambled_s".into(), JsonVal::Num(t_scrambled)),
            ("spmm_rcm_s".into(), JsonVal::Num(t_rcm)),
            ("rcm_speedup".into(), JsonVal::Num(t_scrambled / t_rcm.max(1e-12))),
            ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
        ]);
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_spmm_blocked.json");
    suite.write_json(&path, &rows).expect("write BENCH_spmm_blocked.json");
    suite.report(&format!("wrote {}", path.display()));
}

/// Horner-vs-Chebyshev polynomial bases (the basis-generic operator
/// acceptance measurement): per (n, ℓ) on the clique workload at the
/// solver's k = 16 bundle width, time the `LimitNegExp` solver step three
/// ways — the pre-refactor **unfused** monomial composition
/// (SpMM + `scale` + `axpy` per degree), the **fused** monomial path
/// (one `spmm_step_into` pass per degree, bitwise-equal by contract), and
/// the **Chebyshev recurrence** through the fused kernel — and record the
/// max float divergence between the bases. Emits `BENCH_poly_basis.json`
/// at the repo root for CI trend tracking.
fn poly_basis_group(suite: &mut BenchSuite, threads: usize) {
    use sped::linalg::sparse::{spmm_into, spmm_step_into};
    let ns: &[usize] = &[1024, 4096];
    let ells: &[usize] = if fast_mode() { &[15] } else { &[15, 251] };
    let k = 16usize;
    let step_reps = if fast_mode() { 2 } else { 5 };
    let mut rows: Vec<Vec<(String, JsonVal)>> = Vec::new();
    for &n in ns {
        // Same 16-node-clique community workload as the other sparse
        // groups, prescaled so the spectrum sits in [0, ~1] — the regime
        // where both bases are numerically meaningful and the recorded
        // divergence is an accuracy signal, not overflow noise.
        let gg = cliques(&CliqueSpec { n, k: (n / 16).max(2), max_short_circuit: 2, seed: 42 });
        let mut l = gg.graph.laplacian_csr();
        let lam = sped::linalg::sparse::power_lambda_max_csr(&l, 100, threads).unwrap() * 1.01;
        l.scale_values(1.0 / lam);
        let nnz = l.nnz();
        let v = sped::solvers::random_init(n, k, 7);
        for &ell in ells {
            let kind = TransformKind::LimitNegExp { ell };
            // Monomial basis, unfused: the pre-refactor NegPower loop —
            // three passes over the bundle per degree.
            let unfused = || {
                let inv = -1.0 / ell as f64;
                let mut w = v.clone();
                let mut t = DMat::zeros(n, k);
                for _ in 0..ell {
                    spmm_into(&l, &w, &mut t, threads);
                    t.scale(inv);
                    t.axpy(1.0, &w);
                    std::mem::swap(&mut w, &mut t);
                }
                w.scale(-1.0);
                w
            };
            // Monomial basis, fused: one pass per degree.
            let fused = || {
                let inv = -1.0 / ell as f64;
                let mut w = v.clone();
                let mut t = DMat::zeros(n, k);
                for _ in 0..ell {
                    spmm_step_into(&l, &w, &v, 1.0, inv, 0.0, &mut t, threads);
                    std::mem::swap(&mut w, &mut t);
                }
                w.scale(-1.0);
                w
            };
            // Chebyshev basis: three-term recurrence, fused steps, on the
            // same safe domain policy as the production operator (rho = 1
            // after prescale, widened to the guaranteed Gershgorin bound).
            let (lo, hi) = sped::transforms::cheb_domain(1.0, l.gershgorin_bound());
            let cheb = kind.cheb_series(lo, hi).expect("polynomial kind");
            let (t_unfused, w_u) = best_of(step_reps, unfused);
            let (t_fused, w_f) = best_of(step_reps, fused);
            let (t_cheb, w_c) = best_of(step_reps, || cheb.apply_bundle(&l, &v, threads));
            assert!(
                bitwise_eq(&w_u, &w_f),
                "fused/unfused monomial divergence at n={n}, ell={ell} (bitwise contract broken)"
            );
            let divergence = (&w_c - &w_u).max_abs();
            assert!(
                divergence < 1e-6,
                "basis divergence {divergence} at n={n}, ell={ell}"
            );
            suite.report(&format!(
                "poly-basis n={n} ell={ell} k={k} nnz={nnz} ({threads}w): step unfused {} | fused {} | {:.2}x; cheb recurrence {} | {:.2}x vs unfused | max divergence {divergence:.2e}",
                human_time(t_unfused),
                human_time(t_fused),
                t_unfused / t_fused.max(1e-12),
                human_time(t_cheb),
                t_unfused / t_cheb.max(1e-12),
            ));
            rows.push(vec![
                ("kind".into(), JsonVal::Str("limit_negexp".into())),
                ("n".into(), JsonVal::Int(n as u64)),
                ("ell".into(), JsonVal::Int(ell as u64)),
                ("k".into(), JsonVal::Int(k as u64)),
                ("nnz".into(), JsonVal::Int(nnz as u64)),
                ("threads".into(), JsonVal::Int(threads as u64)),
                ("horner_unfused_s".into(), JsonVal::Num(t_unfused)),
                ("horner_fused_s".into(), JsonVal::Num(t_fused)),
                ("cheb_recurrence_s".into(), JsonVal::Num(t_cheb)),
                (
                    "fused_step_speedup".into(),
                    JsonVal::Num(t_unfused / t_fused.max(1e-12)),
                ),
                (
                    "cheb_vs_unfused_speedup".into(),
                    JsonVal::Num(t_unfused / t_cheb.max(1e-12)),
                ),
                ("max_divergence".into(), JsonVal::Num(divergence)),
                ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
            ]);
        }
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_poly_basis.json");
    suite.write_json(&path, &rows).expect("write BENCH_poly_basis.json");
    suite.report(&format!("wrote {}", path.display()));
}

/// Adaptive-degree + Lanczos-domain group (the PR 5 acceptance
/// measurement): on normalized-Laplacian clique workloads, compare the
/// full-degree Chebyshev operator on the loose power/Gershgorin domain
/// (today's `--basis chebyshev` default) against `--degree auto` truncation
/// on the loose domain and on the tight `--domain lanczos` interval.
/// Records SpMM sweeps per operator application (the quantity the tight
/// domain + truncation shrink), apply wall time, and the scalar-map error
/// at the true `eigh` eigenvalues (grid over the covered interval at sizes
/// where the dense oracle is too expensive), then times an end-to-end
/// matrix-free pipeline run fixed-vs-adaptive and checks the partitions
/// match. Emits `BENCH_adaptive_degree.json` at the repo root, asserting
/// the acceptance floor inline: ≥2× fewer sweeps at ≤1e-6 map error, with
/// the explicit power/native knobs bitwise-identical to the knob-free
/// defaults.
fn adaptive_degree_group(suite: &mut BenchSuite, threads: usize) {
    use sped::transforms::{Degree, DomainEstimate, PolyBasis};
    let ns: &[usize] = if fast_mode() { &[512] } else { &[1024, 4096] };
    let ells: &[usize] = &[15, 251];
    let k = 8usize;
    let step_reps = if fast_mode() { 2 } else { 5 };
    let auto_degree = Degree::Auto { tol: 1e-9, max: usize::MAX };
    let mut rows: Vec<Vec<(String, JsonVal)>> = Vec::new();
    for &n in ns {
        // Same 16-node-clique community workload as the other sparse
        // groups, but on the *normalized* Laplacian — the acceptance
        // configuration, where the spectrum ends well below the Gershgorin
        // bound of 2 and the tight domain pays off.
        let gg = cliques(&CliqueSpec { n, k: (n / 16).max(2), max_short_circuit: 2, seed: 42 });
        let l = gg.graph.normalized_laplacian_csr();
        let nnz = l.nnz();
        let v = sped::solvers::random_init(n, k, 7);
        // The dense eigh oracle is O(n³): exact eigenvalues up to n = 1024,
        // a grid over the covered interval beyond.
        let exact: Option<Vec<f64>> = if n <= 1024 {
            Some(sped::linalg::eigh(&gg.graph.normalized_laplacian()).unwrap().values)
        } else {
            None
        };
        for &ell in ells {
            let kind = TransformKind::LimitNegExp { ell };
            let mk = |domain, degree| {
                let opts = BuildOptions {
                    basis: PolyBasis::Chebyshev,
                    domain,
                    degree,
                    threads,
                    ..BuildOptions::default()
                };
                SparsePolyOp::from_csr(l.clone(), kind, &opts).unwrap()
            };
            let mut fixed_power = mk(DomainEstimate::Power, Degree::Native);
            let mut auto_power = mk(DomainEstimate::Power, auto_degree);
            let mut auto_lanczos = mk(DomainEstimate::Lanczos, auto_degree);
            // Bitwise default guard: the explicit power/native knobs are
            // the knob-free defaults, exactly.
            let mut default_op = SparsePolyOp::from_csr(
                l.clone(),
                kind,
                &BuildOptions {
                    basis: PolyBasis::Chebyshev,
                    threads,
                    ..BuildOptions::default()
                },
            )
            .unwrap();
            assert!(
                bitwise_eq(&fixed_power.apply(&v), &default_op.apply(&v)),
                "explicit --domain power --degree native diverged from defaults at n={n}, ell={ell}"
            );
            let (sw_fixed, sw_ap, sw_al) =
                (fixed_power.sweeps(), auto_power.sweeps(), auto_lanczos.sweeps());
            let reduction = sw_fixed as f64 / sw_al.max(1) as f64;
            // Map error at the true eigenvalues (or a grid over the covered
            // interval): the dilation the solver actually sees.
            let (alo, ahi) = auto_lanczos.fit_domain().unwrap();
            let xs: Vec<f64> = match &exact {
                Some(values) => values.clone(),
                None => (0..=400).map(|i| alo + (ahi - alo) * i as f64 / 400.0).collect(),
            };
            let map_err = |op: &SparsePolyOp| {
                xs.iter()
                    .map(|&x| (op.poly_eval(x) - kind.scalar_map(x)).abs())
                    .fold(0.0f64, f64::max)
            };
            let (err_fixed, err_ap, err_al) =
                (map_err(&fixed_power), map_err(&auto_power), map_err(&auto_lanczos));
            let (t_fixed, _) = best_of(step_reps, || fixed_power.apply(&v));
            let (t_ap, _) = best_of(step_reps, || auto_power.apply(&v));
            let (t_al, _) = best_of(step_reps, || auto_lanczos.apply(&v));
            // The acceptance floor, enforced where the numbers are made.
            // ℓ = 15 barely has a sub-tolerance tail to cut (the kept
            // degree is set by the map's smoothness, not by ℓ), so the
            // ≥2× floor binds at the paper-scale series degrees.
            if ell >= 51 {
                assert!(
                    reduction >= 2.0,
                    "sweep reduction {reduction:.2}x below the 2x floor at n={n}, ell={ell} \
                     ({sw_fixed} -> {sw_al} sweeps)"
                );
            }
            assert!(
                err_al <= 1e-6,
                "adaptive map error {err_al:.2e} above 1e-6 at n={n}, ell={ell}"
            );
            let (plo, phi) = fixed_power.fit_domain().unwrap();
            suite.report(&format!(
                "adaptive-degree n={n} ell={ell} k={k} nnz={nnz} ({threads}w): sweeps {sw_fixed} | auto/power {sw_ap} | auto/lanczos {sw_al} ({reduction:.1}x); apply {} | {} | {} ({:.2}x); domain [{plo:.3},{phi:.3}] -> [{alo:.3},{ahi:.3}]; map err {err_al:.1e}",
                human_time(t_fixed),
                human_time(t_ap),
                human_time(t_al),
                t_fixed / t_al.max(1e-12),
            ));
            rows.push(vec![
                ("kind".into(), JsonVal::Str("operator".into())),
                ("transform".into(), JsonVal::Str(format!("limit_negexp:{ell}"))),
                ("workload".into(), JsonVal::Str("cliques16-normalized".into())),
                ("n".into(), JsonVal::Int(n as u64)),
                ("ell".into(), JsonVal::Int(ell as u64)),
                ("k".into(), JsonVal::Int(k as u64)),
                ("nnz".into(), JsonVal::Int(nnz as u64)),
                ("threads".into(), JsonVal::Int(threads as u64)),
                ("sweeps_fixed_power".into(), JsonVal::Int(sw_fixed as u64)),
                ("sweeps_auto_power".into(), JsonVal::Int(sw_ap as u64)),
                ("sweeps_auto_lanczos".into(), JsonVal::Int(sw_al as u64)),
                ("sweep_reduction".into(), JsonVal::Num(reduction)),
                ("domain_power_hi".into(), JsonVal::Num(phi)),
                ("domain_lanczos_lo".into(), JsonVal::Num(alo)),
                ("domain_lanczos_hi".into(), JsonVal::Num(ahi)),
                ("apply_fixed_s".into(), JsonVal::Num(t_fixed)),
                ("apply_auto_power_s".into(), JsonVal::Num(t_ap)),
                ("apply_auto_lanczos_s".into(), JsonVal::Num(t_al)),
                ("apply_speedup".into(), JsonVal::Num(t_fixed / t_al.max(1e-12))),
                ("map_err_fixed".into(), JsonVal::Num(err_fixed)),
                ("map_err_auto_power".into(), JsonVal::Num(err_ap)),
                ("map_err_auto_lanczos".into(), JsonVal::Num(err_al)),
                ("exact_spectrum".into(), JsonVal::Int(u64::from(exact.is_some()))),
                ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
            ]);
        }
    }
    // End-to-end pipeline convergence: the same solve, fixed vs adaptive —
    // wall-time speedup with the identical resulting partition.
    {
        use sped::pipeline::{Pipeline, PipelineConfig};
        use sped::transforms::OpMode;
        let n = if fast_mode() { 512 } else { 1024 };
        let steps = if fast_mode() { 20 } else { 40 };
        // 8 communities matching the solve's k = 8: the recovered partition
        // is well-separated, so fixed-vs-adaptive equality is a clean
        // correctness check rather than a tie-break lottery.
        let gg = cliques(&CliqueSpec { n, k: 8, max_short_circuit: 2, seed: 42 });
        let mk = |domain, degree| PipelineConfig {
            k: 8,
            transform: TransformKind::LimitNegExp { ell: 251 },
            solver: "subspace".into(),
            eta: 0.5,
            steps,
            eval_every: steps,
            stop_error: 0.0,
            op_mode: OpMode::MatrixFree,
            ground_truth: false,
            threads,
            build: BuildOptions {
                basis: PolyBasis::Chebyshev,
                domain,
                degree,
                ..BuildOptions::default()
            },
            ..Default::default()
        };
        let (t_fixed, out_fixed) = timed(|| {
            Pipeline::new(mk(DomainEstimate::Power, Degree::Native)).run(&gg.graph).unwrap()
        });
        let (t_auto, out_auto) = timed(|| {
            Pipeline::new(mk(DomainEstimate::Lanczos, auto_degree)).run(&gg.graph).unwrap()
        });
        assert_eq!(
            out_fixed.clustering.as_ref().unwrap().assignments,
            out_auto.clustering.as_ref().unwrap().assignments,
            "adaptive pipeline changed the partition"
        );
        suite.report(&format!(
            "adaptive-degree pipeline n={n} steps={steps} ({threads}w): fixed {} | adaptive {} | {:.2}x, identical partition",
            human_time(t_fixed),
            human_time(t_auto),
            t_fixed / t_auto.max(1e-12),
        ));
        rows.push(vec![
            ("kind".into(), JsonVal::Str("pipeline".into())),
            ("n".into(), JsonVal::Int(n as u64)),
            ("steps".into(), JsonVal::Int(steps as u64)),
            ("threads".into(), JsonVal::Int(threads as u64)),
            ("pipeline_fixed_s".into(), JsonVal::Num(t_fixed)),
            ("pipeline_adaptive_s".into(), JsonVal::Num(t_auto)),
            ("pipeline_speedup".into(), JsonVal::Num(t_fixed / t_auto.max(1e-12))),
            ("partition_identical".into(), JsonVal::Int(1)),
            ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
        ]);
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_adaptive_degree.json");
    suite.write_json(&path, &rows).expect("write BENCH_adaptive_degree.json");
    suite.report(&format!("wrote {}", path.display()));
}

/// Sparse community-expander workload for the Ritz-solver group: `c`
/// communities of `n/c` nodes, each a ring plus `chords` random
/// intra-community chords per node (ring + random chords is an expander,
/// so the within-community algebraic connectivity stays O(1) as n grows),
/// joined by two bridge edges per adjacent community pair. Unlike the
/// clique workloads, nnz grows linearly in n — so n = 65536 stays a
/// genuinely sparse solve. Deterministic in `seed`.
fn community_expander(n: usize, c: usize, chords: usize, seed: u64) -> sped::graph::Graph {
    let m = n / c;
    assert!(
        c >= 2 && m >= 8 && n % c == 0,
        "bad community-expander shape n={n}, c={c}"
    );
    let mut rng = Rng::new(seed);
    let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(n * (1 + chords) + 2 * c);
    for comm in 0..c {
        let base = comm * m;
        for i in 0..m {
            pairs.push((base + i, base + (i + 1) % m));
            for _ in 0..chords {
                // Rejection keeps self-loops out; duplicates just sum to
                // weight 2 in `from_pairs`, which is fine for the bench.
                loop {
                    let t = base + rng.below(m);
                    if t != base + i {
                        pairs.push((base + i, t));
                        break;
                    }
                }
            }
        }
        let next = ((comm + 1) % c) * m;
        pairs.push((base, next));
        pairs.push((base + m / 2, next + m / 2));
    }
    sped::graph::Graph::from_pairs(n, &pairs).expect("community-expander edges")
}

/// Ritz-solver group (the PR 6 acceptance measurement): on the sparse
/// community-expander workload, run the block Rayleigh–Ritz solver to a
/// fixed relative tolerance twice — on the **dilated** operator
/// (`limit_negexp`, M ≈ e^{−L}, ℓ SpMM sweeps per outer iteration) and on
/// the **undilated** reversed Laplacian (`identity`, M = ρI − L, one sweep
/// per iteration) — and record outer iterations-to-tolerance, total SpMM
/// sweeps, and wall time for both. Asserts inline that dilation buys
/// strictly fewer outer iterations at equal tolerance (the quantity that
/// shrinks the orthogonalization / synchronization count in a distributed
/// solve; the JSON keeps the honest sweep totals showing what the larger
/// per-apply sweep cost pays for it). Emits `BENCH_ritz_solver.json` at
/// the repo root for CI trend tracking.
fn ritz_solver_group(suite: &mut BenchSuite, threads: usize) {
    use sped::linalg::metrics::subspace_error;
    use sped::solvers::ritz::{ritz_solve, RitzConfig};
    let ns: &[usize] = if fast_mode() { &[4096] } else { &[4096, 65536] };
    let communities = 8usize;
    let chords = 4usize;
    let ell = 51usize;
    let tol = 1e-8;
    let mut rows: Vec<Vec<(String, JsonVal)>> = Vec::new();
    for &n in ns {
        let g = community_expander(n, communities, chords, 42);
        let rcfg = RitzConfig { k: communities, block: 0, tol, max_iters: 2000, ..RitzConfig::default() };
        let opts = BuildOptions { threads, ..BuildOptions::default() };
        let solve = |kind: TransformKind| {
            let mut op = SparsePolyOp::from_graph(&g, kind, &opts).unwrap();
            let nnz = op.nnz();
            let (secs, res) = timed(|| ritz_solve(&mut op, &rcfg).unwrap());
            (secs, res, nnz)
        };
        let (t_dil, dilated, nnz) = solve(TransformKind::LimitNegExp { ell });
        let (t_und, undilated, _) = solve(TransformKind::Identity);
        // The acceptance floor, enforced where the numbers are made: the
        // dilated operator must actually converge, in strictly fewer outer
        // iterations than the undilated Laplacian at the same tolerance.
        assert!(
            dilated.converged,
            "dilated ritz solve failed to converge in {} iterations at n={n}",
            rcfg.max_iters
        );
        assert!(
            dilated.iterations < undilated.iterations,
            "dilation did not reduce outer iterations at n={n}: {} vs {}",
            dilated.iterations,
            undilated.iterations
        );
        // Cross-operator sanity: both paths chase the same bottom-k
        // Laplacian eigenspace, so when both converge the embeddings agree.
        if dilated.converged && undilated.converged {
            let gap = subspace_error(&dilated.embedding, &undilated.embedding);
            assert!(
                gap < 1e-5,
                "dilated/undilated embeddings diverged ({gap:.2e}) at n={n}"
            );
        }
        suite.report(&format!(
            "ritz-solver n={n} k={communities} ell={ell} nnz={nnz} ({threads}w): dilated {} iters / {} sweeps / {} | undilated {} iters{} / {} sweeps / {} | {:.1}x fewer iters",
            dilated.iterations,
            dilated.total_sweeps,
            human_time(t_dil),
            undilated.iterations,
            if undilated.converged { "" } else { " (hit max)" },
            undilated.total_sweeps,
            human_time(t_und),
            undilated.iterations as f64 / dilated.iterations.max(1) as f64,
        ));
        rows.push(vec![
            ("workload".into(), JsonVal::Str("community-expander".into())),
            ("n".into(), JsonVal::Int(n as u64)),
            ("k".into(), JsonVal::Int(communities as u64)),
            ("block".into(), JsonVal::Int((communities + 2) as u64)),
            ("ell".into(), JsonVal::Int(ell as u64)),
            ("nnz".into(), JsonVal::Int(nnz as u64)),
            ("threads".into(), JsonVal::Int(threads as u64)),
            ("tol".into(), JsonVal::Num(tol)),
            ("iters_dilated".into(), JsonVal::Int(dilated.iterations as u64)),
            ("iters_undilated".into(), JsonVal::Int(undilated.iterations as u64)),
            ("converged_dilated".into(), JsonVal::Int(u64::from(dilated.converged))),
            ("converged_undilated".into(), JsonVal::Int(u64::from(undilated.converged))),
            (
                "sweeps_per_apply_dilated".into(),
                JsonVal::Int(dilated.sweeps_per_apply as u64),
            ),
            (
                "sweeps_per_apply_undilated".into(),
                JsonVal::Int(undilated.sweeps_per_apply as u64),
            ),
            ("sweeps_dilated".into(), JsonVal::Int(dilated.total_sweeps as u64)),
            ("sweeps_undilated".into(), JsonVal::Int(undilated.total_sweeps as u64)),
            ("time_dilated_s".into(), JsonVal::Num(t_dil)),
            ("time_undilated_s".into(), JsonVal::Num(t_und)),
            (
                "iter_reduction".into(),
                JsonVal::Num(undilated.iterations as f64 / dilated.iterations.max(1) as f64),
            ),
            ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
        ]);
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_ritz_solver.json");
    suite.write_json(&path, &rows).expect("write BENCH_ritz_solver.json");
    suite.report(&format!("wrote {}", path.display()));
}

/// Ritz-deflation group (the locked-blocks + sharded-applies acceptance
/// measurement): on the community-expander workload, run the block
/// Rayleigh–Ritz solver to the same tolerance with deflation locking on
/// and off and record the SpMM **column**-sweep volume each paid — the
/// honest cost unit once the active block shrinks. Asserts inline
/// (non-fast mode) that the locked solve reaches the same subspace with
/// ≤ 0.7× the fixed-block column sweeps at n ∈ {4096, 65536}, and that
/// the sharded pipeline (`--shards`) is bitwise-equal to the unsharded
/// one at every (shard count, worker count) pair. Non-fast mode closes
/// with the n = 10⁶ power-law solve the streamed Barabási–Albert builder
/// exists for (the graph + CSR fit without any intermediate edge `Vec`).
/// Emits `BENCH_ritz_deflation.json` at the repo root.
fn ritz_deflation_group(suite: &mut BenchSuite, threads: usize) {
    use sped::linalg::metrics::subspace_error;
    use sped::pipeline::{Pipeline, PipelineConfig};
    use sped::solvers::ritz::{ritz_solve, RitzConfig};
    use sped::transforms::OpMode;
    let ns: &[usize] = if fast_mode() { &[4096] } else { &[4096, 65536] };
    let communities = 8usize;
    let ell = 51usize;
    let tol = 1e-8;
    let mut rows: Vec<Vec<(String, JsonVal)>> = Vec::new();
    for &n in ns {
        let g = community_expander(n, communities, 4, 42);
        let opts = BuildOptions { threads, ..BuildOptions::default() };
        let solve = |lock: bool| {
            let mut op =
                SparsePolyOp::from_graph(&g, TransformKind::LimitNegExp { ell }, &opts).unwrap();
            let rcfg = RitzConfig {
                k: communities,
                tol,
                max_iters: 2000,
                lock,
                ..RitzConfig::default()
            };
            let (secs, res) = timed(|| ritz_solve(&mut op, &rcfg).unwrap());
            (secs, res)
        };
        let (t_fix, fixed) = solve(false);
        let (t_lock, locked) = solve(true);
        assert!(fixed.converged && locked.converged, "unconverged at n={n}");
        let gap = subspace_error(&fixed.embedding, &locked.embedding);
        assert!(gap < 1e-5, "locked/fixed embeddings diverged ({gap:.2e}) at n={n}");
        let ratio = locked.col_sweeps as f64 / fixed.col_sweeps.max(1) as f64;
        // The acceptance floor, enforced where the numbers are made: the
        // shrinking active block must actually shrink the SpMM volume.
        if !fast_mode() {
            assert!(
                ratio <= 0.7,
                "deflation saved too little at n={n}: {} locked vs {} fixed column sweeps ({ratio:.2}x)",
                locked.col_sweeps,
                fixed.col_sweeps
            );
        } else {
            assert!(ratio < 1.0, "deflation saved nothing at n={n} ({ratio:.2}x)");
        }
        suite.report(&format!(
            "ritz-deflation n={n} k={communities} ell={ell} ({threads}w): locked {} iters / {} col-sweeps / {} | fixed {} iters / {} col-sweeps / {} | {:.2}x volume",
            locked.iterations,
            locked.col_sweeps,
            human_time(t_lock),
            fixed.iterations,
            fixed.col_sweeps,
            human_time(t_fix),
            ratio,
        ));
        rows.push(vec![
            ("workload".into(), JsonVal::Str("community-expander".into())),
            ("n".into(), JsonVal::Int(n as u64)),
            ("k".into(), JsonVal::Int(communities as u64)),
            ("ell".into(), JsonVal::Int(ell as u64)),
            ("threads".into(), JsonVal::Int(threads as u64)),
            ("tol".into(), JsonVal::Num(tol)),
            ("iters_locked".into(), JsonVal::Int(locked.iterations as u64)),
            ("iters_fixed".into(), JsonVal::Int(fixed.iterations as u64)),
            ("locked_pairs".into(), JsonVal::Int(locked.locked as u64)),
            ("col_sweeps_locked".into(), JsonVal::Int(locked.col_sweeps as u64)),
            ("col_sweeps_fixed".into(), JsonVal::Int(fixed.col_sweeps as u64)),
            ("col_sweep_ratio".into(), JsonVal::Num(ratio)),
            ("halo_volume".into(), JsonVal::Int(locked.halo_volume as u64)),
            ("time_locked_s".into(), JsonVal::Num(t_lock)),
            ("time_fixed_s".into(), JsonVal::Num(t_fix)),
            ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
        ]);
    }

    // Sharded pipeline: bitwise-equal to unsharded at every
    // (shards, workers) pair, with the halo volume reported per run.
    {
        let n = if fast_mode() { 1024 } else { 4096 };
        let g = community_expander(n, communities, 4, 42);
        let pipe = |shards: usize, workers: usize| {
            let mut cfg = PipelineConfig {
                k: communities,
                transform: TransformKind::LimitNegExp { ell },
                solver: "ritz".into(),
                ritz_tol: tol,
                ritz_max_iters: 2000,
                op_mode: OpMode::MatrixFree,
                ground_truth: false,
                threads: workers,
                ..Default::default()
            };
            cfg.build.shards = shards;
            Pipeline::new(cfg).run(&g).unwrap()
        };
        let base = pipe(0, 1);
        for &shards in &[1usize, 2, 7] {
            for &workers in &[1usize, 2, 8] {
                let out = pipe(shards, workers);
                assert!(
                    bitwise_eq(&base.embedding, &out.embedding),
                    "sharded pipeline diverged at S={shards}, {workers} workers (n={n})"
                );
                let rz = out.ritz.as_ref().unwrap();
                if shards > 1 {
                    assert!(rz.halo_volume > 0, "S={shards}: no halo volume reported");
                }
                rows.push(vec![
                    ("workload".into(), JsonVal::Str("sharded-pipeline".into())),
                    ("n".into(), JsonVal::Int(n as u64)),
                    ("shards".into(), JsonVal::Int(shards as u64)),
                    ("threads".into(), JsonVal::Int(workers as u64)),
                    ("col_sweeps_locked".into(), JsonVal::Int(rz.col_sweeps as u64)),
                    ("col_sweeps_fixed".into(), JsonVal::Int(0)),
                    ("halo_volume".into(), JsonVal::Int(rz.halo_volume as u64)),
                    ("bitwise_equal".into(), JsonVal::Int(1)),
                ]);
            }
        }
        suite.report(&format!(
            "ritz-deflation sharded pipeline n={n}: bitwise-equal over S x workers = {{1,2,7}} x {{1,2,8}}"
        ));
    }

    // The streamed-generator payoff: a power-law graph at n = 10⁶ whose
    // CSR is built without materializing any intermediate edge Vec. The
    // solve is capped, not chased to convergence — the acceptance here is
    // that the workload *fits and runs*; convergence is reported honestly.
    if !fast_mode() {
        let n = 1_000_000usize;
        let (t_gen, gg) = timed(|| barabasi_albert(n, 3, 7));
        let g = gg.graph;
        let opts = BuildOptions { threads, ..BuildOptions::default() };
        let mut op =
            SparsePolyOp::from_graph(&g, TransformKind::LimitNegExp { ell: 21 }, &opts).unwrap();
        let nnz = op.nnz();
        let rcfg = RitzConfig {
            k: 4,
            tol: 1e-6,
            max_iters: 40,
            lock: true,
            ..RitzConfig::default()
        };
        let (t_solve, res) = timed(|| ritz_solve(&mut op, &rcfg).unwrap());
        suite.report(&format!(
            "ritz-deflation power-law n=10^6 nnz={nnz} ({threads}w): generated in {} | {} iters ({}) / {} col-sweeps / {} locked / {}",
            human_time(t_gen),
            res.iterations,
            if res.converged { "converged" } else { "capped" },
            res.col_sweeps,
            res.locked,
            human_time(t_solve),
        ));
        rows.push(vec![
            ("workload".into(), JsonVal::Str("powerlaw-1e6".into())),
            ("n".into(), JsonVal::Int(n as u64)),
            ("nnz".into(), JsonVal::Int(nnz as u64)),
            ("threads".into(), JsonVal::Int(threads as u64)),
            ("iters".into(), JsonVal::Int(res.iterations as u64)),
            ("converged".into(), JsonVal::Int(u64::from(res.converged))),
            ("locked_pairs".into(), JsonVal::Int(res.locked as u64)),
            ("col_sweeps_locked".into(), JsonVal::Int(res.col_sweeps as u64)),
            ("col_sweeps_fixed".into(), JsonVal::Int(0)),
            ("halo_volume".into(), JsonVal::Int(res.halo_volume as u64)),
            ("gen_time_s".into(), JsonVal::Num(t_gen)),
            ("solve_time_s".into(), JsonVal::Num(t_solve)),
        ]);
    }

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_ritz_deflation.json");
    suite.write_json(&path, &rows).expect("write BENCH_ritz_deflation.json");
    suite.report(&format!("wrote {}", path.display()));
}

/// Streaming warm-vs-cold group (the PR 7 acceptance measurement): on the
/// community-expander workload, run a streaming session through several
/// delta batches, warm-starting each publish from the previous embedding,
/// and against every publish run the identical pipeline cold on the same
/// patched graph. Asserts inline that the warm solve converges in strictly
/// fewer outer iterations (the quantity warm-starting exists to shrink)
/// and emits `BENCH_stream_stability.json` with the per-batch warm/cold
/// iteration and SpMM-sweep accounting.
fn stream_stability_group(suite: &mut BenchSuite, threads: usize) {
    use sped::coordinator::stream::{StreamConfig, StreamSession};
    use sped::graph::delta::EdgeDelta;
    use sped::pipeline::{Pipeline, PipelineConfig, SolvePath};
    use sped::transforms::OpMode;
    let n = fast_mode_scale(4096);
    let communities = 8usize;
    let ell = 51usize;
    let batches = if fast_mode() { 2 } else { 5 };
    let g = community_expander(n, communities, 4, 42);
    let pcfg = PipelineConfig {
        k: communities,
        transform: TransformKind::LimitNegExp { ell },
        solver: "ritz".into(),
        ritz_tol: 1e-8,
        ritz_max_iters: 2000,
        op_mode: OpMode::MatrixFree,
        ground_truth: false,
        threads,
        ..Default::default()
    };
    let mut session = StreamSession::new(
        g.clone(),
        StreamConfig { pipeline: pcfg.clone(), warm_volume_frac: 0.25 },
    );
    let (t_base, base) = timed(|| session.publish().unwrap());
    assert_eq!(base.path, SolvePath::Cold);
    suite.report(&format!(
        "stream-stability n={n} k={communities} ell={ell} ({threads}w): baseline cold {} iters / {} sweeps / {}",
        base.iterations,
        base.sweeps,
        human_time(t_base),
    ));
    let mut rng = Rng::new(0x57AB);
    let mut rows: Vec<Vec<(String, JsonVal)>> = Vec::new();
    for batch_idx in 0..batches {
        // Bounded churn: mild weight jitter on a handful of edges plus a
        // few fresh in-community chords — enough to move the spectrum,
        // far below the warm/cold degradation threshold.
        let mut batch: Vec<EdgeDelta> = Vec::new();
        let edges = session.graph().edges();
        for _ in 0..16 {
            let e = &edges[rng.below(edges.len())];
            batch.push(EdgeDelta::Reweight {
                u: e.u as usize,
                v: e.v as usize,
                w: e.w * rng.uniform(0.8, 1.2),
            });
        }
        let m = n / communities;
        for _ in 0..4 {
            let comm = rng.below(communities);
            let (u, v) = loop {
                let a = comm * m + rng.below(m);
                let b = comm * m + rng.below(m);
                if a != b {
                    break (a, b);
                }
            };
            batch.push(EdgeDelta::Add { u, v, w: 1.0 });
        }
        session.apply_batch(&batch).unwrap();
        let (t_warm, warm) = timed(|| session.publish().unwrap());
        let (t_cold, cold) = timed(|| Pipeline::new(pcfg.clone()).run(session.graph()).unwrap());
        let cz = cold.ritz.as_ref().expect("cold ritz summary");
        // The acceptance floor, enforced where the numbers are made.
        assert_eq!(warm.path, SolvePath::Warm, "batch {batch_idx} did not run warm");
        assert!(warm.converged, "warm solve unconverged at batch {batch_idx}");
        assert!(cz.converged, "cold solve unconverged at batch {batch_idx}");
        assert!(
            warm.iterations < cz.iterations,
            "warm-start did not reduce outer iterations at batch {batch_idx}: {} vs {}",
            warm.iterations,
            cz.iterations
        );
        suite.report(&format!(
            "stream-stability batch {batch_idx}: warm {} iters / {} sweeps / {} | cold {} iters / {} sweeps / {} | {:.1}x fewer iters",
            warm.iterations,
            warm.sweeps,
            human_time(t_warm),
            cz.iterations,
            cz.total_sweeps,
            human_time(t_cold),
            cz.iterations as f64 / warm.iterations.max(1) as f64,
        ));
        rows.push(vec![
            ("workload".into(), JsonVal::Str("community-expander".into())),
            ("n".into(), JsonVal::Int(n as u64)),
            ("k".into(), JsonVal::Int(communities as u64)),
            ("ell".into(), JsonVal::Int(ell as u64)),
            ("threads".into(), JsonVal::Int(threads as u64)),
            ("batch".into(), JsonVal::Int(batch_idx as u64)),
            ("deltas".into(), JsonVal::Int(batch.len() as u64)),
            ("iters_baseline_cold".into(), JsonVal::Int(base.iterations as u64)),
            ("iters_warm".into(), JsonVal::Int(warm.iterations as u64)),
            ("iters_cold".into(), JsonVal::Int(cz.iterations as u64)),
            ("sweeps_warm".into(), JsonVal::Int(warm.sweeps as u64)),
            ("sweeps_cold".into(), JsonVal::Int(cz.total_sweeps as u64)),
            ("time_warm_s".into(), JsonVal::Num(t_warm)),
            ("time_cold_s".into(), JsonVal::Num(t_cold)),
            (
                "iter_reduction".into(),
                JsonVal::Num(cz.iterations as f64 / warm.iterations.max(1) as f64),
            ),
            ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
        ]);
    }
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_stream_stability.json");
    suite.write_json(&path, &rows).expect("write BENCH_stream_stability.json");
    suite.report(&format!("wrote {}", path.display()));
}

/// Serve-mode group (the PR 8 acceptance measurement): prime a
/// [`ServeSession`] with one Ritz solve on the community-expander
/// workload, then push the same deterministic query slab through it at
/// batch sizes 1 / 64 / 4096 (shrunk under `SPED_BENCH_FAST=1`). Records
/// throughput (qps) and p50/p99 per-call latency for every batch size,
/// checks the answers are bitwise identical regardless of how the slab is
/// split, and asserts inline that the largest batch sustains ≥5× the
/// unbatched throughput — batching amortizes the per-call `O(E)`
/// cache-key hash (plus call overhead) that batch-size-1 serving pays per
/// query. Emits `BENCH_serve.json` at the repo root for CI trend tracking.
fn serve_group(suite: &mut BenchSuite, threads: usize) {
    use sped::coordinator::serve::{Answer, Query, ServeConfig, ServeSession};
    use sped::pipeline::PipelineConfig;
    use sped::transforms::OpMode;
    let n = fast_mode_scale(4096);
    let communities = 8usize;
    let total = fast_mode_scale(4096);
    let sizes: [usize; 3] = if fast_mode() { [1, 32, 512] } else { [1, 64, 4096] };
    let g = community_expander(n, communities, 4, 42);
    let nnz_edges = g.num_edges();
    let pcfg = PipelineConfig {
        k: communities,
        transform: TransformKind::LimitNegExp { ell: 51 },
        solver: "ritz".into(),
        ritz_tol: 1e-8,
        ritz_max_iters: 2000,
        op_mode: OpMode::MatrixFree,
        ground_truth: false,
        threads,
        ..Default::default()
    };
    let mut session =
        ServeSession::new(g, ServeConfig { pipeline: pcfg, warm_volume_frac: 0.25 });

    // Deterministic query slab cycling through all three kinds.
    let mut rng = Rng::new(0x5E21E);
    let queries: Vec<Query> = (0..total)
        .map(|i| match i % 3 {
            0 => loop {
                let (u, v) = (rng.below(n), rng.below(n));
                if u != v {
                    break Query::LinkPred { u, v };
                }
            },
            1 => Query::NearestCluster { u: rng.below(n) },
            _ => Query::TopK { u: rng.below(n), k: communities },
        })
        .collect();

    // Prime the cache: the one solve every measurement below reads from.
    let (t_solve, _) = timed(|| session.answer_batch(&queries[..1]).unwrap());
    assert_eq!(session.solves(), 1);
    suite.report(&format!(
        "serve n={n} k={communities} edges={nnz_edges} ({threads}w): primed cache in {} (1 ritz solve)",
        human_time(t_solve),
    ));

    let flat = |a: &Answer| -> Vec<u64> {
        match a {
            Answer::Score(s) => vec![s.to_bits()],
            Answer::Cluster { cluster, distance } => vec![*cluster as u64, distance.to_bits()],
            Answer::Neighbors(nb) => nb.iter().flat_map(|&(v, s)| [v as u64, s.to_bits()]).collect(),
        }
    };
    let percentile = |sorted: &[f64], p: f64| -> f64 {
        sorted[((sorted.len() as f64 - 1.0) * p).round() as usize]
    };

    let mut rows: Vec<Vec<(String, JsonVal)>> = Vec::new();
    let mut reference: Option<Vec<Vec<u64>>> = None;
    let mut qps_unbatched = 0.0f64;
    let mut qps_batched = 0.0f64;
    for &bs in &sizes {
        let mut lat: Vec<f64> = Vec::with_capacity(total / bs + 1);
        let mut answers: Vec<Answer> = Vec::with_capacity(total);
        let t0 = std::time::Instant::now();
        for chunk in queries.chunks(bs) {
            let t = std::time::Instant::now();
            answers.extend(session.answer_batch(chunk).unwrap());
            lat.push(t.elapsed().as_secs_f64());
        }
        let total_s = t0.elapsed().as_secs_f64();
        let qps = total as f64 / total_s.max(1e-12);
        lat.sort_by(f64::total_cmp);
        let (p50, p99) = (percentile(&lat, 0.50), percentile(&lat, 0.99));
        // Every batch size is pure cache hits over the same slab, so the
        // answers must be bitwise identical however the slab is split.
        assert_eq!(session.solves(), 1, "read path must never re-solve");
        let bits: Vec<Vec<u64>> = answers.iter().map(flat).collect();
        match &reference {
            None => reference = Some(bits),
            Some(r) => assert_eq!(r, &bits, "batch size {bs} changed an answer (bitwise)"),
        }
        if bs == 1 {
            qps_unbatched = qps;
        }
        qps_batched = qps; // last (largest) size wins
        suite.report(&format!(
            "serve batch={bs}: {} queries in {} | {:.0} q/s | call p50 {} p99 {}",
            total,
            human_time(total_s),
            qps,
            human_time(p50),
            human_time(p99),
        ));
        rows.push(vec![
            ("workload".into(), JsonVal::Str("community-expander".into())),
            ("n".into(), JsonVal::Int(n as u64)),
            ("k".into(), JsonVal::Int(communities as u64)),
            ("edges".into(), JsonVal::Int(nnz_edges as u64)),
            ("threads".into(), JsonVal::Int(threads as u64)),
            ("batch".into(), JsonVal::Int(bs as u64)),
            ("queries".into(), JsonVal::Int(total as u64)),
            ("qps".into(), JsonVal::Num(qps)),
            ("p50_s".into(), JsonVal::Num(p50)),
            ("p99_s".into(), JsonVal::Num(p99)),
            ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
        ]);
    }

    // The acceptance floor, enforced where the numbers are made: batching
    // must buy at least 5× throughput over one-query-per-call serving.
    let batch_speedup = qps_batched / qps_unbatched.max(1e-12);
    assert!(
        batch_speedup >= 5.0,
        "batched serving must be >=5x unbatched throughput, got {batch_speedup:.2}x \
         ({qps_batched:.0} vs {qps_unbatched:.0} q/s)"
    );
    suite.report(&format!(
        "serve batch={}: {batch_speedup:.1}x the unbatched throughput (floor 5x)",
        sizes[sizes.len() - 1],
    ));
    rows.push(vec![
        ("workload".into(), JsonVal::Str("summary".into())),
        ("n".into(), JsonVal::Int(n as u64)),
        ("threads".into(), JsonVal::Int(threads as u64)),
        ("solve_s".into(), JsonVal::Num(t_solve)),
        ("qps_unbatched".into(), JsonVal::Num(qps_unbatched)),
        ("qps_batched".into(), JsonVal::Num(qps_batched)),
        ("batch_speedup".into(), JsonVal::Num(batch_speedup)),
        ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_serve.json");
    suite.write_json(&path, &rows).expect("write BENCH_serve.json");
    suite.report(&format!("wrote {}", path.display()));
}

/// SIMD + mixed-precision + sharded SpMM group (the PR 9 acceptance
/// measurement), on the community-expander workload:
///
/// * `speedup_simd` — the width-dispatched kernel family (portable-SIMD
///   under a nightly `--features simd` build, the stable unrolled
///   register-blocked kernels otherwise; `backend` records which) against
///   the streaming reference at k = 8, one worker, asserting the result
///   bitwise identical.
/// * `speedup_mixed` — the f32-storage/f64-accumulator mixed ℓ-sweep
///   against the fused f64 ℓ-sweep (the NegPower recurrence on the
///   prescaled Laplacian, the exact shape one dilated operator
///   application runs). Asserts inline outside fast mode that mixed buys
///   ≥1.5× throughput at k = 8, and always that its drift from the f64
///   sweep stays inside [`mixed_error_budget`].
/// * sharded halo-exchange apply vs the unsharded kernel at the same
///   worker count, asserting bitwise equality (the tentpole determinism
///   contract) and recording the halo fraction the partition pays.
/// * `map_err_mixed` — a `--precision mixed --degree auto` operator
///   applied to the true bottom-k eigenvectors (dense `eigh` oracle),
///   asserting the observed map error stays within the documented
///   Chebyshev truncation tolerance plus the operator's own
///   [`SparsePolyOp::mixed_budget`].
///
/// Emits `BENCH_spmm_simd.json` at the repo root for CI trend tracking.
fn spmm_simd_group(suite: &mut BenchSuite, threads: usize) {
    use sped::linalg::shard::ShardedCsr;
    use sped::linalg::simd::backend_name;
    use sped::linalg::sparse::{
        power_lambda_max_csr, spmm_into, spmm_step_into, spmm_step_mixed_into,
        spmm_streaming_into, CsrMatF32,
    };
    use sped::transforms::{mixed_error_budget, Degree, DomainEstimate, PolyBasis, Precision};
    let n = fast_mode_scale(65536);
    let communities = 8usize;
    let k = 8usize;
    let ell = if fast_mode() { 15 } else { 51 };
    let reps = if fast_mode() { 3 } else { 10 };
    let sweep_reps = if fast_mode() { 2 } else { 5 };
    let g = community_expander(n, communities, 4, 42);
    // Prescale to spectrum ⊂ [0, 1] so the NegPower factor (1 − λ/ℓ) is a
    // contraction — the same normalization every dilated build applies —
    // keeping the ℓ-sweep iterates bounded for the drift check below.
    let mut l = g.laplacian_csr();
    let lam = power_lambda_max_csr(&l, 100, threads).unwrap() * 1.01;
    l.scale_values(1.0 / lam);
    let nnz = l.nnz();
    let v = sped::solvers::random_init(n, k, 7);
    let mut rows: Vec<Vec<(String, JsonVal)>> = Vec::new();

    // Width-dispatched kernel vs the streaming reference: one SpMM at one
    // worker, so the ratio is the pure kernel effect with no sharding.
    let mut c_ref = DMat::zeros(n, k);
    let mut c_disp = DMat::zeros(n, k);
    let (t_stream, ()) = best_of(reps, || spmm_streaming_into(&l, &v, &mut c_ref, 1));
    let (t_disp, ()) = best_of(reps, || spmm_into(&l, &v, &mut c_disp, 1));
    assert!(
        bitwise_eq(&c_disp, &c_ref),
        "dispatched SpMM diverged bitwise from the streaming reference"
    );
    let speedup_simd = t_stream / t_disp.max(1e-12);

    // Mixed-precision ℓ-sweep vs the fused f64 ℓ-sweep at k = 8: the
    // NegPower recurrence w ← w + (−1/ℓ)·L·w, with f32 matrix values and
    // panels (f64 accumulators) on the mixed side.
    let inv = -1.0 / ell as f64;
    let f64_sweep = || {
        let mut w = v.clone();
        let mut t = DMat::zeros(n, k);
        for _ in 0..ell {
            spmm_step_into(&l, &w, &v, 1.0, inv, 0.0, &mut t, threads);
            std::mem::swap(&mut w, &mut t);
        }
        w
    };
    let l32 = CsrMatF32::from_f64(&l);
    let v32 = v.to_f32();
    let mixed_sweep = || {
        let mut w = v32.clone();
        let mut t = vec![0.0f32; n * k];
        for _ in 0..ell {
            spmm_step_mixed_into(&l32, &w, &v32, k, 1.0, inv, 0.0, &mut t, threads);
            std::mem::swap(&mut w, &mut t);
        }
        w
    };
    let (t_f64, w_f) = best_of(sweep_reps, f64_sweep);
    let (t_mixed, w_m) = best_of(sweep_reps, mixed_sweep);
    // Accuracy rides along with the speed claim: the mixed sweep must
    // track the f64 sweep within the documented budget (coefficient ℓ1
    // mass is 1 for this contraction recurrence), scaled by the iterate
    // magnitude.
    let budget = mixed_error_budget(ell, 1.0);
    let scale = w_f.max_abs().max(1.0);
    let drift = w_f
        .data()
        .iter()
        .zip(w_m.iter())
        .map(|(&a, &b)| (a - f64::from(b)).abs())
        .fold(0.0f64, f64::max);
    assert!(
        drift <= budget * scale,
        "mixed ell-sweep drift {drift:.2e} above the documented budget {budget:.2e} (scale {scale:.2e})"
    );
    let speedup_mixed = t_f64 / t_mixed.max(1e-12);
    // The acceptance floor, enforced where the numbers are made — but only
    // at the real workload size: fast-mode problems fit in cache, where
    // halving the memory traffic cannot show up as throughput.
    if !fast_mode() {
        assert!(
            speedup_mixed >= 1.5,
            "mixed bundle sweep must be >=1.5x the f64 throughput at k={k}, got {speedup_mixed:.2}x"
        );
    }

    // Sharded halo-exchange apply vs the unsharded kernel at the same
    // worker count: the tentpole contract is bitwise equality at every
    // (shard count, worker count), so the overhead ratio is the honest
    // price of the two-phase owned/halo schedule.
    let shards = threads.max(2);
    let sharded = ShardedCsr::partition(&l, shards);
    let halo = sharded.halo_plan.halo_rows();
    let mut c_shard = DMat::zeros(n, k);
    let mut c_unshard = DMat::zeros(n, k);
    let (t_shard, ()) = best_of(reps, || sharded.apply_into(&v, &mut c_shard, threads));
    let (t_unshard, ()) = best_of(reps, || spmm_into(&l, &v, &mut c_unshard, threads));
    assert!(
        bitwise_eq(&c_shard, &c_unshard),
        "sharded apply diverged bitwise from the unsharded kernel at S={shards}"
    );
    let sharded_overhead = t_shard / t_unshard.max(1e-12);

    suite.report(&format!(
        "spmm-simd n={n} k={k} ell={ell} nnz={nnz} backend={}: streaming {} | dispatched {} ({speedup_simd:.2}x); sweep f64 {} | mixed {} ({speedup_mixed:.2}x, drift {drift:.1e}); sharded S={shards} halo {halo} rows {} ({sharded_overhead:.2}x of unsharded @{threads}w)",
        backend_name(),
        human_time(t_stream),
        human_time(t_disp),
        human_time(t_f64),
        human_time(t_mixed),
        human_time(t_shard),
    ));
    rows.push(vec![
        ("kind".into(), JsonVal::Str("kernels".into())),
        ("workload".into(), JsonVal::Str("community-expander".into())),
        ("backend".into(), JsonVal::Str(backend_name().into())),
        ("n".into(), JsonVal::Int(n as u64)),
        ("k".into(), JsonVal::Int(k as u64)),
        ("ell".into(), JsonVal::Int(ell as u64)),
        ("nnz".into(), JsonVal::Int(nnz as u64)),
        ("threads".into(), JsonVal::Int(threads as u64)),
        ("spmm_streaming_s".into(), JsonVal::Num(t_stream)),
        ("spmm_dispatched_s".into(), JsonVal::Num(t_disp)),
        ("speedup_simd".into(), JsonVal::Num(speedup_simd)),
        ("sweep_f64_s".into(), JsonVal::Num(t_f64)),
        ("sweep_mixed_s".into(), JsonVal::Num(t_mixed)),
        ("speedup_mixed".into(), JsonVal::Num(speedup_mixed)),
        ("mixed_drift".into(), JsonVal::Num(drift)),
        ("mixed_drift_budget".into(), JsonVal::Num(budget * scale)),
        ("shards".into(), JsonVal::Int(shards as u64)),
        ("halo_rows".into(), JsonVal::Int(halo as u64)),
        ("sharded_s".into(), JsonVal::Num(t_shard)),
        ("unsharded_s".into(), JsonVal::Num(t_unshard)),
        ("sharded_overhead".into(), JsonVal::Num(sharded_overhead)),
        ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
    ]);

    // End-to-end contract for `--precision mixed --degree auto`: apply the
    // mixed operator to the true bottom-k eigenvectors of the normalized
    // Laplacian (dense eigh oracle, so a smaller clique workload) — each
    // column must come back as (λ* − map(λᵢ))·vᵢ within the Chebyshev
    // truncation tolerance plus the operator's own f32 budget.
    let nm = fast_mode_scale(512);
    let gg = cliques(&CliqueSpec { n: nm, k: (nm / 16).max(2), max_short_circuit: 2, seed: 42 });
    let lno = gg.graph.normalized_laplacian_csr();
    let kind = TransformKind::LimitNegExp { ell: 251 };
    let opts = BuildOptions {
        basis: PolyBasis::Chebyshev,
        domain: DomainEstimate::Lanczos,
        degree: Degree::Auto { tol: 1e-9, max: usize::MAX },
        precision: Precision::Mixed,
        threads,
        ..BuildOptions::default()
    };
    let (t_build, mut op) = timed(|| SparsePolyOp::from_csr(lno, kind, &opts).unwrap());
    let eig = sped::linalg::eigh(&gg.graph.normalized_laplacian()).unwrap();
    let kb = k.min(nm);
    let vb = eig.bottom_k(kb);
    let out = op.apply(&vb);
    // The same empirical ceiling the adaptive-degree group pins for the
    // tol = 1e-9 truncation, plus the operator's documented f32 term.
    let cheb_budget = 1e-6;
    let contract = cheb_budget + op.mixed_budget();
    let mut map_err_mixed = 0.0f64;
    for i in 0..kb {
        let want = op.lambda_star - kind.scalar_map(eig.values[i]);
        for r in 0..nm {
            map_err_mixed = map_err_mixed.max((out[(r, i)] - want * vb[(r, i)]).abs());
        }
    }
    assert!(
        map_err_mixed <= contract,
        "mixed --degree auto map error {map_err_mixed:.2e} above the contract {contract:.2e} \
         (cheb {cheb_budget:.1e} + f32 {:.1e}) at n={nm}",
        op.mixed_budget()
    );
    suite.report(&format!(
        "spmm-simd mixed pipeline n={nm} ell=251: build {} | {} sweeps | map err {map_err_mixed:.1e} (contract {contract:.1e})",
        human_time(t_build),
        op.sweeps(),
    ));
    rows.push(vec![
        ("kind".into(), JsonVal::Str("mixed-pipeline".into())),
        ("workload".into(), JsonVal::Str("cliques16-normalized".into())),
        ("n".into(), JsonVal::Int(nm as u64)),
        ("k".into(), JsonVal::Int(kb as u64)),
        ("ell".into(), JsonVal::Int(251)),
        ("threads".into(), JsonVal::Int(threads as u64)),
        ("sweeps".into(), JsonVal::Int(op.sweeps() as u64)),
        ("build_s".into(), JsonVal::Num(t_build)),
        ("map_err_mixed".into(), JsonVal::Num(map_err_mixed)),
        ("map_err_contract".into(), JsonVal::Num(contract)),
        ("fast_mode".into(), JsonVal::Int(u64::from(fast_mode()))),
    ]);

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_spmm_simd.json");
    suite.write_json(&path, &rows).expect("write BENCH_spmm_simd.json");
    suite.report(&format!("wrote {}", path.display()));
}

fn main() {
    let mut suite = BenchSuite::new("perf_hotpath");
    let threads = threads_param();
    let n = if fast_mode() { 128 } else { 256 };

    // ---- L3: matmul ----
    let a = random_mat(1, n, n);
    let b = random_mat(2, n, n);
    let flops = 2.0 * (n as f64).powi(3);
    suite.bench_units(&format!("matmul blocked {n}x{n}"), flops, "FLOP", || {
        std::hint::black_box(matmul(&a, &b));
    });
    if !fast_mode() {
        suite.bench_units(&format!("matmul naive {n}x{n}"), flops, "FLOP", || {
            std::hint::black_box(matmul_naive(&a, &b));
        });
    }
    let mut worker_sweep = vec![2usize];
    if threads.max(2) != 2 {
        worker_sweep.push(threads);
    }
    for workers in worker_sweep {
        suite.bench_units(
            &format!("matmul row-sharded {n}x{n} ({workers} workers)"),
            flops,
            "FLOP",
            || {
                std::hint::black_box(matmul_par(&a, &b, workers));
            },
        );
    }

    // ---- Tentpole measurement: parallel polynomial apply on the 512-node
    // clique workload (acceptance: ≥2× at 4 workers on multi-core hosts,
    // bitwise-identical to serial at any worker count) ----
    {
        let np = if fast_mode() { 256 } else { 512 };
        let gg = cliques(&CliqueSpec { n: np, k: 4, max_short_circuit: 25, seed: 9 });
        let l = gg.graph.laplacian();
        // Degree-8 shifted-Horner apply: 8 dense np³ multiplies per call —
        // the exact shape of a TaylorNegExp transform build term.
        let series = TransformKind::TaylorNegExp { ell: 8 }.series().expect("series kind");
        let mut shifted = l.clone();
        shifted.add_diag(-series.shift);
        let reps = if fast_mode() { 1 } else { 2 };
        let (t_serial, r_serial) =
            best_of(reps, || poly_horner_par(&shifted, &series.coeffs, 1));
        let (t_par, r_par) =
            best_of(reps, || poly_horner_par(&shifted, &series.coeffs, threads));
        assert!(
            bitwise_eq(&r_serial, &r_par),
            "parallel poly apply diverged from serial (determinism contract broken)"
        );
        suite.report(&format!(
            "poly apply deg-8, n={np} cliques: serial {} | {threads} workers {} | speedup {:.2}x | bitwise-identical: yes",
            human_time(t_serial),
            human_time(t_par),
            t_serial / t_par.max(1e-12),
        ));
    }

    // ---- L3: eigh ----
    let mut sym = random_mat(3, n, n);
    sym.symmetrize();
    suite.bench(&format!("eigh (tred2+tql2) {n}x{n}"), || {
        std::hint::black_box(sped::linalg::eigh(&sym).unwrap());
    });

    // ---- L3: solver steps ----
    let gg = cliques(&CliqueSpec { n, k: 4, max_short_circuit: 10, seed: 5 });
    let sm = sped::transforms::build_solver_matrix(
        &gg.graph.laplacian(),
        TransformKind::NegExp,
        &Default::default(),
    )
    .unwrap();
    let k = 8;
    let mut v = sped::solvers::random_init(n, k, 7);
    let mut op = sped::solvers::DenseOp { m: sm.m.clone(), threads: 1 };
    let step_flops = 2.0 * (n * n * k) as f64;
    let mut oja = sped::solvers::Oja { eta: 0.1 };
    suite.bench_units(&format!("oja step n={n} k={k}"), step_flops, "FLOP", || {
        oja.step(&mut op, &mut v);
    });
    let mut op_par = sped::solvers::DenseOp { m: sm.m.clone(), threads };
    suite.bench_units(
        &format!("oja step n={n} k={k} ({threads} workers)"),
        step_flops,
        "FLOP",
        || {
            oja.step(&mut op_par, &mut v);
        },
    );
    let mut eg = sped::solvers::MuEigenGame { eta: 0.1 };
    suite.bench_units(&format!("mu-eg step n={n} k={k}"), step_flops, "FLOP", || {
        eg.step(&mut op, &mut v);
    });
    suite.bench(&format!("mgs orthonormalize n={n} k={k}"), || {
        sped::linalg::qr::mgs_orthonormalize(&mut v);
    });

    // ---- L3: transform builders ----
    let l = gg.graph.laplacian();
    suite.bench("transform build: limit_negexp T251 (matpow, ~13 matmuls)", || {
        std::hint::black_box(TransformKind::LimitNegExp { ell: 251 }.build(&l).unwrap());
    });
    suite.bench(
        &format!("transform build: limit_negexp T251 ({threads} workers)"),
        || {
            std::hint::black_box(
                TransformKind::LimitNegExp { ell: 251 }
                    .build_threaded(&l, threads)
                    .unwrap(),
            );
        },
    );
    if !fast_mode() {
        suite.bench("transform build: taylor_negexp T51 (Horner, 51 matmuls)", || {
            std::hint::black_box(TransformKind::TaylorNegExp { ell: 51 }.build(&l).unwrap());
        });
        suite.bench("transform build: exact negexp (full eigh)", || {
            std::hint::black_box(TransformKind::NegExp.build(&l).unwrap());
        });
    }

    // ---- sparse vs dense operator crossover (OpMode::MatrixFree) ----
    // Honors the bench-name filter like every other case (CI selects it
    // with the literal filter "sparse-vs-dense"). The heavy n=4096 column
    // runs only under that explicit filter — neither unrelated filters nor
    // a plain unfiltered full-suite run should pay for ~10¹²-FLOP dense
    // builds incidentally.
    let case = "sparse-vs-dense crossover";
    if suite.selected(case) {
        let explicitly_selected = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'))
            .map(|f| case.contains(f.as_str()))
            .unwrap_or(false);
        sparse_vs_dense_crossover(&mut suite, threads, explicitly_selected);
    }

    // ---- blocked-vs-streaming skinny SpMM + RCM locality ----
    // No dense builds anywhere in the group, so unlike the crossover's
    // n=4096 column it is cheap enough to run unconditionally (CI selects
    // it with the literal filter "spmm-blocked").
    if suite.selected("spmm-blocked kernels + rcm locality") {
        spmm_blocked_group(&mut suite, threads);
    }

    // ---- polynomial bases: unfused vs fused Horner vs Chebyshev ----
    // CSR-only (prescale via CSR power iteration, no dense builds), so it
    // runs unconditionally like spmm-blocked (CI filter: "poly-basis").
    if suite.selected("poly-basis horner vs chebyshev recurrence") {
        poly_basis_group(&mut suite, threads);
    }

    // ---- adaptive degrees + Lanczos domains ----
    // CSR operators throughout; the only dense work is the n ≤ 1024 eigh
    // oracle for the map-error check (CI filter: "adaptive-degree").
    if suite.selected("adaptive-degree lanczos domains + truncation") {
        adaptive_degree_group(&mut suite, threads);
    }

    // ---- ritz solver: dilated vs undilated outer iterations ----
    // CSR operators and O(n·b) dense work only; the heavy n = 65536 column
    // is an O(nnz)-per-sweep iterative solve, not a dense build, so it runs
    // unconditionally outside fast mode (CI filter: "ritz-solver").
    if suite.selected("ritz-solver dilated vs undilated convergence") {
        ritz_solver_group(&mut suite, threads);
    }

    // ---- ritz-deflation: locked blocks + sharded applies ----
    // CSR operators only; the heavy columns (n = 65536 locked-vs-fixed,
    // n = 10⁶ power-law) run outside fast mode (CI filter:
    // "ritz-deflation").
    if suite.selected("ritz-deflation locked blocks + sharded applies") {
        ritz_deflation_group(&mut suite, threads);
    }

    // ---- stream-stability: warm-started vs cold re-solves per delta batch ----
    // Matrix-free ritz solves only (no dense builds), so it runs
    // unconditionally like ritz-solver (CI filter: "stream-stability").
    if suite.selected("stream-stability warm vs cold re-solves") {
        stream_stability_group(&mut suite, threads);
    }

    // ---- serve: batched queries over the cached embedding ----
    // One matrix-free ritz solve to prime the cache, then pure read-path
    // kernels — cheap, so it runs unconditionally (CI filter: "serve").
    if suite.selected("serve batched query throughput") {
        serve_group(&mut suite, threads);
    }

    // ---- spmm-simd: dispatched kernels, mixed precision, sharded apply ----
    // SpMM sweeps plus one small-n eigh oracle — no large dense builds, so
    // it runs unconditionally (CI filter: "spmm-simd").
    if suite.selected("spmm-simd kernels + mixed precision + sharded") {
        spmm_simd_group(&mut suite, threads);
    }

    // ---- L3: clustering + walks ----
    let emb = random_mat(11, n, 4);
    suite.bench(&format!("kmeans++ n={n} k=4"), || {
        std::hint::black_box(sped::cluster::kmeans(&emb, 4, 50, 3));
    });
    let engine = sped::walks::WalkEngine::new(&gg.graph);
    let mut rng = Rng::new(13);
    let mut walk = sped::walks::WalkSample { edges: vec![], alpha: vec![], prob: vec![] };
    suite.bench_units("walk sampling len=5", 1000.0, "walks", || {
        for _ in 0..1000 {
            engine.sample_walk_into(5, &mut rng, &mut walk);
        }
    });

    // ---- XLA path (artifacts optional) ----
    let art_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art_dir.join("manifest.cfg").exists() {
        let rt = sped::runtime::Runtime::load_dir(&art_dir).expect("artifacts");
        if let Ok(chunk_art) = rt.best_fit("oja_chunk", n) {
            let size = chunk_art.meta.n;
            let m_pad = sped::runtime::pad_matrix(&sm.m, size, -1.0);
            let runner = sped::runtime::XlaChunkRunner::new(chunk_art.clone(), &m_pad).unwrap();
            let vv = sped::runtime::pad_rows(&sped::solvers::random_init(n, chunk_art.meta.k, 5), size);
            let t = chunk_art.meta.t as f64;
            let mut cur = vv.clone();
            suite.bench_units(
                &format!("XLA oja_chunk n={size} (T={} steps/call)", chunk_art.meta.t),
                t,
                "steps",
                || {
                    let out = runner.run_chunk(&cur, &vv, 0.3).unwrap();
                    cur = out.v;
                },
            );
        }
        if let Ok(mv) = rt.best_fit("matvec", n) {
            let m_pad = sped::runtime::pad_matrix(&sm.m, mv.meta.n, -1.0);
            let mut xop = sped::runtime::XlaDenseOp::new(mv.clone(), &m_pad).unwrap();
            let vv = sped::solvers::random_init(mv.meta.n, mv.meta.k, 5);
            suite.bench_units(
                &format!("XLA matvec round-trip n={}", mv.meta.n),
                2.0 * (mv.meta.n * mv.meta.n * mv.meta.k) as f64,
                "FLOP",
                || {
                    std::hint::black_box(xop.apply(&vv));
                },
            );
        }
        if let Ok(mp) = rt.best_fit("matpow", n) {
            let mut bmat = sped::runtime::pad_matrix(&l, mp.meta.n, 0.0);
            bmat.scale(-1.0 / 251.0);
            bmat.add_diag(1.0);
            suite.bench("XLA matpow^251 (square-and-multiply)", || {
                std::hint::black_box(sped::runtime::xla_matpow(&mp, &bmat, 251).unwrap());
            });
        }
    } else {
        suite.report("(artifacts/ missing — XLA cases skipped; run `make artifacts`)");
    }
    suite.finish();
}
