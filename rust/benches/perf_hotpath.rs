//! Whole-stack hot-path profile — the measurement side of EXPERIMENTS.md
//! §Perf. Times every layer's inner loops:
//!
//! * L3 native: blocked matmul (vs naive), symmetric eigh, MGS, solver
//!   steps (Oja / µ-EG), transform builders (Horner vs matpow), k-means,
//!   walk sampling.
//! * XLA path (when artifacts exist): chunked solver steps, poly build,
//!   matpow, matvec round-trip — including the PJRT call overhead.

use sped::graph::gen::{cliques, CliqueSpec};
use sped::linalg::dmat::DMat;
use sped::linalg::matmul::{matmul, matmul_naive};
use sped::solvers::{EigenSolver, MatVecOp};
use sped::transforms::TransformKind;
use sped::util::bench::{fast_mode, BenchSuite};
use sped::util::rng::Rng;

fn random_mat(seed: u64, r: usize, c: usize) -> DMat {
    let mut rng = Rng::new(seed);
    DMat::from_fn(r, c, |_, _| rng.normal())
}

fn main() {
    let mut suite = BenchSuite::new("perf_hotpath");
    let n = if fast_mode() { 128 } else { 256 };

    // ---- L3: matmul ----
    let a = random_mat(1, n, n);
    let b = random_mat(2, n, n);
    let flops = 2.0 * (n as f64).powi(3);
    suite.bench_units(&format!("matmul blocked {n}x{n}"), flops, "FLOP", || {
        std::hint::black_box(matmul(&a, &b));
    });
    if !fast_mode() {
        suite.bench_units(&format!("matmul naive {n}x{n}"), flops, "FLOP", || {
            std::hint::black_box(matmul_naive(&a, &b));
        });
    }

    // ---- L3: eigh ----
    let mut sym = random_mat(3, n, n);
    sym.symmetrize();
    suite.bench(&format!("eigh (tred2+tql2) {n}x{n}"), || {
        std::hint::black_box(sped::linalg::eigh(&sym).unwrap());
    });

    // ---- L3: solver steps ----
    let gg = cliques(&CliqueSpec { n, k: 4, max_short_circuit: 10, seed: 5 });
    let sm = sped::transforms::build_solver_matrix(
        &gg.graph.laplacian(),
        TransformKind::NegExp,
        &Default::default(),
    )
    .unwrap();
    let k = 8;
    let mut v = sped::solvers::random_init(n, k, 7);
    let mut op = sped::solvers::DenseOp { m: sm.m.clone() };
    let step_flops = 2.0 * (n * n * k) as f64;
    let mut oja = sped::solvers::Oja { eta: 0.1 };
    suite.bench_units(&format!("oja step n={n} k={k}"), step_flops, "FLOP", || {
        oja.step(&mut op, &mut v);
    });
    let mut eg = sped::solvers::MuEigenGame { eta: 0.1 };
    suite.bench_units(&format!("mu-eg step n={n} k={k}"), step_flops, "FLOP", || {
        eg.step(&mut op, &mut v);
    });
    suite.bench(&format!("mgs orthonormalize n={n} k={k}"), || {
        sped::linalg::qr::mgs_orthonormalize(&mut v);
    });

    // ---- L3: transform builders ----
    let l = gg.graph.laplacian();
    suite.bench("transform build: limit_negexp T251 (matpow, ~13 matmuls)", || {
        std::hint::black_box(TransformKind::LimitNegExp { ell: 251 }.build(&l).unwrap());
    });
    if !fast_mode() {
        suite.bench("transform build: taylor_negexp T51 (Horner, 51 matmuls)", || {
            std::hint::black_box(TransformKind::TaylorNegExp { ell: 51 }.build(&l).unwrap());
        });
        suite.bench("transform build: exact negexp (full eigh)", || {
            std::hint::black_box(TransformKind::NegExp.build(&l).unwrap());
        });
    }

    // ---- L3: clustering + walks ----
    let emb = random_mat(11, n, 4);
    suite.bench(&format!("kmeans++ n={n} k=4"), || {
        std::hint::black_box(sped::cluster::kmeans(&emb, 4, 50, 3));
    });
    let engine = sped::walks::WalkEngine::new(&gg.graph);
    let mut rng = Rng::new(13);
    let mut walk = sped::walks::WalkSample { edges: vec![], alpha: vec![], prob: vec![] };
    suite.bench_units("walk sampling len=5", 1000.0, "walks", || {
        for _ in 0..1000 {
            engine.sample_walk_into(5, &mut rng, &mut walk);
        }
    });

    // ---- XLA path (artifacts optional) ----
    let art_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art_dir.join("manifest.cfg").exists() {
        let rt = sped::runtime::Runtime::load_dir(&art_dir).expect("artifacts");
        if let Ok(chunk_art) = rt.best_fit("oja_chunk", n) {
            let size = chunk_art.meta.n;
            let m_pad = sped::runtime::pad_matrix(&sm.m, size, -1.0);
            let runner = sped::runtime::XlaChunkRunner::new(chunk_art.clone(), &m_pad).unwrap();
            let vv = sped::runtime::pad_rows(&sped::solvers::random_init(n, chunk_art.meta.k, 5), size);
            let t = chunk_art.meta.t as f64;
            let mut cur = vv.clone();
            suite.bench_units(
                &format!("XLA oja_chunk n={size} (T={} steps/call)", chunk_art.meta.t),
                t,
                "steps",
                || {
                    let out = runner.run_chunk(&cur, &vv, 0.3).unwrap();
                    cur = out.v;
                },
            );
        }
        if let Ok(mv) = rt.best_fit("matvec", n) {
            let m_pad = sped::runtime::pad_matrix(&sm.m, mv.meta.n, -1.0);
            let mut xop = sped::runtime::XlaDenseOp::new(mv.clone(), &m_pad).unwrap();
            let vv = sped::solvers::random_init(mv.meta.n, mv.meta.k, 5);
            suite.bench_units(
                &format!("XLA matvec round-trip n={}", mv.meta.n),
                2.0 * (mv.meta.n * mv.meta.n * mv.meta.k) as f64,
                "FLOP",
                || {
                    std::hint::black_box(xop.apply(&vv));
                },
            );
        }
        if let Ok(mp) = rt.best_fit("matpow", n) {
            let mut bmat = sped::runtime::pad_matrix(&l, mp.meta.n, 0.0);
            bmat.scale(-1.0 / 251.0);
            bmat.add_diag(1.0);
            suite.bench("XLA matpow^251 (square-and-multiply)", || {
                std::hint::black_box(sped::runtime::xla_matpow(&mp, &bmat, 251).unwrap());
            });
        }
    } else {
        suite.report("(artifacts/ missing — XLA cases skipped; run `make artifacts`)");
    }
    suite.finish();
}
