//! Figure 6 — series-degree sweep ℓ ∈ {11, 51, 151, 251} across the three
//! series families (limit −e^{−L}, Taylor −e^{−L}, Taylor log).
//!
//! Expected shape (paper, App A.2): insufficient terms fail to accelerate
//! (or fail outright); the limit approximation outperforms the other series
//! at every ℓ; Taylor-log diverges at raw spectral radius (ρ ≥ 2).

use sped::coordinator::experiments::{fig6_series_terms, summarize, ExperimentOptions};
use sped::util::bench::BenchSuite;

fn main() {
    let mut suite = BenchSuite::new("fig6_series_terms");
    let opts = ExperimentOptions::default();
    let t0 = std::time::Instant::now();
    let curves = fig6_series_terms(&opts).expect("fig6 harness");
    suite.report(&format!(
        "figure 6 regenerated in {:.1}s → {}/fig6_series_terms.csv",
        t0.elapsed().as_secs_f64(),
        opts.out_dir
    ));
    for row in summarize(&curves, 3) {
        suite.report(&row);
    }
    suite.report("");
    suite.report("limit vs taylor at each ℓ (oja, steps→streak3; '-' = never):");
    for ell in [11usize, 51, 151, 251] {
        let get = |frag: &str| {
            curves
                .iter()
                .find(|c| c.label.starts_with("oja") && c.label.contains(frag))
                .and_then(|c| c.steps_to_streak(3))
                .map(|s| s.to_string())
                .unwrap_or_else(|| "-".into())
        };
        suite.report(&format!(
            "  ℓ={ell:<4} limit {:<8} taylor {:<8}",
            get(&format!("limit_negexp_T{ell}")),
            get(&format!("taylor_negexp_T{ell}")),
        ));
    }
    suite.finish();
}
