//! The incidence-matrix view of a graph (§2 of the paper) and the
//! edge-vector inner products of **Table 1**.
//!
//! Each edge `e = (i, j)` with `i < j` is a row `x_e ∈ ℝ^{|V|}` with
//! `x_e[i] = +1`, `x_e[j] = −1`, so `L = XᵀWX = Σ_e w_e x_e x_eᵀ`.
//! Inner products of edge vectors take values in `{0, −1, +1, 2}`
//! depending on how the two edges touch (Table 1) — the combinatorial fact
//! behind the random-walk estimator of `L^ℓ` (eq 12).

use super::{Edge, Graph};
use crate::linalg::DMat;

/// Dense incidence matrix `X` (|E| × |V|). Rows follow `graph.edges()`
/// order; weights are *not* folded in (use `weighted_incidence` for
/// `W^{1/2}X`).
pub fn incidence_matrix(g: &Graph) -> DMat {
    let mut x = DMat::zeros(g.num_edges(), g.num_nodes());
    for (r, e) in g.edges().iter().enumerate() {
        x[(r, e.u as usize)] = e.w.sqrt(); // canonical +1 at min index, scaled
        x[(r, e.v as usize)] = -e.w.sqrt();
    }
    x
}

/// Unweighted incidence matrix (entries exactly ±1).
pub fn incidence_matrix_unweighted(g: &Graph) -> DMat {
    let mut x = DMat::zeros(g.num_edges(), g.num_nodes());
    for (r, e) in g.edges().iter().enumerate() {
        x[(r, e.u as usize)] = 1.0;
        x[(r, e.v as usize)] = -1.0;
    }
    x
}

/// The five Table 1 cases for a pair of edges.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgePairKind {
    /// No shared endpoint → inner product 0.
    Disconnected,
    /// `i → j → l`: the head of one is the tail of the other → −1.
    Serial,
    /// `i → j ← l`: both heads coincide → +1.
    Converging,
    /// `i ← j → l`: both tails coincide → +1.
    Diverging,
    /// Identical edge `i ⇒ j` → +2.
    Repeated,
}

/// Classify an (unweighted) edge pair per Table 1. Edge direction is the
/// canonical one (low index → high index), *not* a walk direction.
pub fn classify_pair(a: Edge, b: Edge) -> EdgePairKind {
    if a.u == b.u && a.v == b.v {
        return EdgePairKind::Repeated;
    }
    let tail_shared = a.u == b.u; // both +1 at same node
    let head_shared = a.v == b.v; // both −1 at same node
    let a_head_b_tail = a.v == b.u;
    let a_tail_b_head = a.u == b.v;
    if tail_shared {
        EdgePairKind::Diverging
    } else if head_shared {
        EdgePairKind::Converging
    } else if a_head_b_tail || a_tail_b_head {
        EdgePairKind::Serial
    } else {
        EdgePairKind::Disconnected
    }
}

/// The Table 1 inner-product value `x_aᵀ x_b` for unit-weight edges.
pub fn inner_product(a: Edge, b: Edge) -> f64 {
    match classify_pair(a, b) {
        EdgePairKind::Disconnected => 0.0,
        EdgePairKind::Serial => -1.0,
        EdgePairKind::Converging | EdgePairKind::Diverging => 1.0,
        EdgePairKind::Repeated => 2.0,
    }
}

/// Brute-force inner product from the incidence definition (oracle used by
/// tests and the Table 1 bench).
pub fn inner_product_dense(a: Edge, b: Edge, n: usize) -> f64 {
    let mut xa = vec![0.0f64; n];
    let mut xb = vec![0.0f64; n];
    xa[a.u as usize] = 1.0;
    xa[a.v as usize] = -1.0;
    xb[b.u as usize] = 1.0;
    xb[b.v as usize] = -1.0;
    crate::linalg::dmat::dot(&xa, &xb)
}

/// The **edge-incidence graph** (footnote 1 of the paper): a new graph whose
/// nodes are the edges of `g`; two nodes are adjacent iff the corresponding
/// edges share an endpoint. Every node also carries a self-loop (the
/// `Repeated` case participates in walks). Stored in CSR form; adjacency
/// lists *include* the self-loop as the first entry.
#[derive(Clone, Debug)]
pub struct EdgeIncidenceGraph {
    /// Number of original-graph edges (= node count here).
    pub num_edges: usize,
    offsets: Vec<usize>,
    /// Adjacent edge ids (self-loop first, then proper neighbors).
    adjacency: Vec<u32>,
}

impl EdgeIncidenceGraph {
    pub fn build(g: &Graph) -> EdgeIncidenceGraph {
        let m = g.num_edges();
        // edge ids incident to each node of g
        let mut node_edges: Vec<Vec<u32>> = vec![Vec::new(); g.num_nodes()];
        for (idx, e) in g.edges().iter().enumerate() {
            node_edges[e.u as usize].push(idx as u32);
            node_edges[e.v as usize].push(idx as u32);
        }
        let mut offsets = Vec::with_capacity(m + 1);
        let mut adjacency: Vec<u32> = Vec::new();
        offsets.push(0);
        let mut scratch: Vec<u32> = Vec::new();
        for (idx, e) in g.edges().iter().enumerate() {
            scratch.clear();
            scratch.push(idx as u32); // self-loop
            for &other in node_edges[e.u as usize]
                .iter()
                .chain(node_edges[e.v as usize].iter())
            {
                if other != idx as u32 {
                    scratch.push(other);
                }
            }
            // Dedup (an edge sharing *both* endpoints can't occur in a simple
            // graph, but parallel edge ids from the two endpoint lists can't
            // either — keep the dedup for safety with future multigraphs).
            scratch[1..].sort_unstable();
            scratch.dedup();
            adjacency.extend_from_slice(&scratch);
            offsets.push(adjacency.len());
        }
        EdgeIncidenceGraph { num_edges: m, offsets, adjacency }
    }

    /// Neighbors of edge-node `e` in the incidence graph (self-loop
    /// included).
    pub fn neighbors(&self, e: usize) -> &[u32] {
        &self.adjacency[self.offsets[e]..self.offsets[e + 1]]
    }

    /// Degree in the incidence graph (self-loop counts once).
    pub fn degree(&self, e: usize) -> usize {
        self.offsets[e + 1] - self.offsets[e]
    }

    /// Max degree over all edge-nodes.
    pub fn max_degree(&self) -> usize {
        (0..self.num_edges).map(|e| self.degree(e)).max().unwrap_or(0)
    }
}

/// Upper bound on the edge-incidence-graph degree from the original graph's
/// max degree: `deg*_inc = 2·deg* − 1` (§4.3; both endpoints contribute at
/// most deg* incident edges, the edge itself is double-counted once, and the
/// self-loop replaces it).
pub fn incidence_degree_bound(max_degree_original: usize) -> usize {
    if max_degree_original == 0 {
        0
    } else {
        2 * max_degree_original - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(u: u32, v: u32) -> Edge {
        Edge { u, v, w: 1.0 }
    }

    #[test]
    fn table1_all_cases() {
        // disconnected: 0→1, 2→3
        assert_eq!(classify_pair(e(0, 1), e(2, 3)), EdgePairKind::Disconnected);
        assert_eq!(inner_product(e(0, 1), e(2, 3)), 0.0);
        // serial: 0→1, 1→2 (head of first is tail of second)
        assert_eq!(classify_pair(e(0, 1), e(1, 2)), EdgePairKind::Serial);
        assert_eq!(inner_product(e(0, 1), e(1, 2)), -1.0);
        // converging: 0→2, 1→2
        assert_eq!(classify_pair(e(0, 2), e(1, 2)), EdgePairKind::Converging);
        assert_eq!(inner_product(e(0, 2), e(1, 2)), 1.0);
        // diverging: 1→2, 1→3
        assert_eq!(classify_pair(e(1, 2), e(1, 3)), EdgePairKind::Diverging);
        assert_eq!(inner_product(e(1, 2), e(1, 3)), 1.0);
        // repeated
        assert_eq!(classify_pair(e(4, 7), e(4, 7)), EdgePairKind::Repeated);
        assert_eq!(inner_product(e(4, 7), e(4, 7)), 2.0);
    }

    #[test]
    fn inner_product_matches_dense_oracle() {
        // Exhaustive over all canonical edge pairs on 5 nodes.
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                edges.push(e(u, v));
            }
        }
        for &a in &edges {
            for &b in &edges {
                assert_eq!(
                    inner_product(a, b),
                    inner_product_dense(a, b, 5),
                    "a={a:?} b={b:?}"
                );
            }
        }
    }

    #[test]
    fn incidence_gram_is_laplacian() {
        let g = Graph::from_pairs(4, &[(0, 1), (1, 2), (2, 3), (0, 3), (0, 2)]).unwrap();
        let x = incidence_matrix_unweighted(&g);
        let l = crate::linalg::matmul::matmul(&x.t(), &x);
        assert!((&l - &g.laplacian()).max_abs() < 1e-12);
    }

    #[test]
    fn ones_vector_in_kernel() {
        let g = Graph::from_pairs(6, &[(0, 1), (1, 2), (3, 4), (4, 5), (2, 3)]).unwrap();
        let l = g.laplacian();
        let ones = vec![1.0; 6];
        let lv = crate::linalg::matmul::gemv(&l, &ones);
        assert!(lv.iter().all(|x| x.abs() < 1e-12));
    }

    #[test]
    fn edge_incidence_graph_structure() {
        // Path 0-1-2: edges e0=(0,1), e1=(1,2) share node 1.
        let g = Graph::from_pairs(3, &[(0, 1), (1, 2)]).unwrap();
        let eig = EdgeIncidenceGraph::build(&g);
        assert_eq!(eig.num_edges, 2);
        // Each edge-node: self-loop + the other edge → degree 2.
        assert_eq!(eig.degree(0), 2);
        assert_eq!(eig.neighbors(0), &[0, 1]);
        assert_eq!(eig.neighbors(1), &[1, 0]);
    }

    #[test]
    fn edge_incidence_self_loops_always_present() {
        let g = Graph::from_pairs(4, &[(0, 1), (2, 3)]).unwrap();
        let eig = EdgeIncidenceGraph::build(&g);
        // Disconnected edges: only self-loops.
        assert_eq!(eig.neighbors(0), &[0]);
        assert_eq!(eig.neighbors(1), &[1]);
    }

    #[test]
    fn degree_bound_holds() {
        use crate::graph::gen::{cliques, CliqueSpec};
        let g = cliques(&CliqueSpec { n: 60, k: 4, max_short_circuit: 10, seed: 3 }).graph;
        let eig = EdgeIncidenceGraph::build(&g);
        let bound = incidence_degree_bound(g.max_degree());
        assert!(eig.max_degree() <= bound, "{} > {}", eig.max_degree(), bound);
    }

    #[test]
    fn star_graph_incidence_degrees() {
        // Star K_{1,4}: every pair of edges shares the hub → complete
        // incidence graph + self-loops: degree = 4 each.
        let g = Graph::from_pairs(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]).unwrap();
        let eig = EdgeIncidenceGraph::build(&g);
        for ei in 0..4 {
            assert_eq!(eig.degree(ei), 4);
        }
        assert_eq!(incidence_degree_bound(4), 7);
    }
}
