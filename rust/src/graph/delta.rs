//! Streaming edge deltas: batched in-place mutation of a [`Graph`].
//!
//! Production graphs mutate constantly; rebuilding from scratch on every
//! edge event forces a cold solve each time. [`Graph::apply_deltas`] takes
//! a batch of [`EdgeDelta`] events, validates the whole batch up front
//! (transactional: a bad delta leaves the graph untouched), and patches
//! the canonical edge list + CSR adjacency in place. The patched state is
//! bitwise-identical to a from-scratch [`Graph::from_edges`] rebuild on
//! the final edge set — asserted in debug builds — so both CSR Laplacians
//! ([`Graph::laplacian_csr`] / [`Graph::normalized_laplacian_csr`])
//! inherit the dense-parity contract unchanged.
//!
//! The returned [`DeltaOutcome`] tells callers exactly which derived
//! state their batch invalidated: an RCM order depends only on topology
//! (`topology_changed`), cached spectral domain bounds on any Laplacian
//! entry (`weights_changed`). Reweight-only batches keep the CSR row
//! structure (`offsets`) valid and skip the degree/prefix-sum rebuild.

use super::{Edge, Graph};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// One edge event in a streaming batch. Endpoints are undirected and
/// canonicalized internally (`u < v`); weights must be finite.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeDelta {
    /// Add `w` to the weight of `(u, v)`, creating the edge (with weight
    /// `w`) if absent — the duplicate-merge semantics of
    /// [`Graph::from_edges`].
    Add { u: usize, v: usize, w: f64 },
    /// Remove `(u, v)` entirely. Removing an absent edge is an error.
    Remove { u: usize, v: usize },
    /// Set the weight of existing edge `(u, v)` to `w`. Reweighting an
    /// absent edge is an error.
    Reweight { u: usize, v: usize, w: f64 },
    /// Grow the node set by `count` fresh isolated nodes
    /// (`n .. n + count`). Takes effect immediately: later deltas in the
    /// same batch may reference the new ids.
    AddNodes { count: usize },
}

impl EdgeDelta {
    /// Parse one event-file line: `add u v w` | `remove u v` |
    /// `reweight u v w` | `addnodes k`. Weight syntax is permissive
    /// (`nan` parses); semantic validation happens in
    /// [`Graph::apply_deltas`] so fault injection exercises the batch
    /// validator, not the tokenizer.
    pub fn parse(line: &str) -> Result<EdgeDelta> {
        let toks: Vec<&str> = line.split_whitespace().collect();
        let usize_at = |i: usize| -> Result<usize> {
            toks.get(i)
                .ok_or_else(|| anyhow::anyhow!("delta {line:?}: missing field {i}"))?
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("delta {line:?}: bad integer field {i}"))
        };
        let f64_at = |i: usize| -> Result<f64> {
            toks.get(i)
                .ok_or_else(|| anyhow::anyhow!("delta {line:?}: missing field {i}"))?
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("delta {line:?}: bad weight field {i}"))
        };
        let want = |n: usize| -> Result<()> {
            if toks.len() != n {
                bail!("delta {line:?}: expected {n} fields, got {}", toks.len());
            }
            Ok(())
        };
        match toks.first().copied() {
            Some("add") => {
                want(4)?;
                Ok(EdgeDelta::Add { u: usize_at(1)?, v: usize_at(2)?, w: f64_at(3)? })
            }
            Some("remove") => {
                want(3)?;
                Ok(EdgeDelta::Remove { u: usize_at(1)?, v: usize_at(2)? })
            }
            Some("reweight") => {
                want(4)?;
                Ok(EdgeDelta::Reweight { u: usize_at(1)?, v: usize_at(2)?, w: f64_at(3)? })
            }
            Some("addnodes") => {
                want(2)?;
                Ok(EdgeDelta::AddNodes { count: usize_at(1)? })
            }
            Some(other) => bail!(
                "delta {line:?}: unknown op {other:?} (expected add | remove | reweight | addnodes)"
            ),
            None => bail!("empty delta line"),
        }
    }
}

/// What a delta batch actually changed — the invalidation contract for
/// derived state.
///
/// * `topology_changed` — the adjacency *structure* changed (edge set or
///   node count). Invalidates anything keyed on structure alone: RCM
///   orders, CSR row offsets, bandwidth.
/// * `weights_changed` — some Laplacian entry changed (implies weight
///   edits or topology edits). Invalidates spectral state: cached domain
///   bounds, embeddings. A reweight to the bitwise-identical value counts
///   as no change.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeltaOutcome {
    /// Structural edges created.
    pub edges_added: usize,
    /// Structural edges deleted.
    pub edges_removed: usize,
    /// Surviving edges whose weight changed (bitwise).
    pub edges_reweighted: usize,
    /// Fresh isolated nodes appended.
    pub nodes_added: usize,
    /// Adjacency structure changed (RCM order / offsets now invalid).
    pub topology_changed: bool,
    /// Some Laplacian entry changed (spectral bounds now invalid).
    pub weights_changed: bool,
}

impl DeltaOutcome {
    /// Total structural + weight edits — the "delta volume" the streaming
    /// session accumulates to decide warm-start vs cold-solve fallback.
    pub fn volume(&self) -> usize {
        self.edges_added + self.edges_removed + self.edges_reweighted
    }
}

impl Graph {
    /// Weight of canonical edge `(u, v)` (`u < v`), if present. Binary
    /// search over the sorted, duplicate-free canonical edge list.
    fn edge_weight_canonical(&self, u: u32, v: u32) -> Option<f64> {
        self.edges
            .binary_search_by(|e| (e.u, e.v).cmp(&(u, v)))
            .ok()
            .map(|i| self.edges[i].w)
    }

    /// Apply a batch of edge deltas in place.
    ///
    /// The whole batch is validated and resolved before any mutation, so
    /// on `Err` the graph is untouched (a NaN weight or bad node id in
    /// the middle of a batch never leaves half-applied state). On `Ok`
    /// the edge list, degrees, and CSR adjacency are patched in place —
    /// bitwise-identical to `Graph::from_edges(n', final_edges)` (checked
    /// by a debug assertion) — and the returned [`DeltaOutcome`] reports
    /// which derived-state validity conditions actually broke.
    ///
    /// Cost: `O(D log E)` resolution + `O(E + n)` merge/refill, where `D`
    /// is the batch size. Reweight-only batches keep the row structure
    /// and skip the degree-count/prefix-sum rebuild.
    pub fn apply_deltas(&mut self, deltas: &[EdgeDelta]) -> Result<DeltaOutcome> {
        // Phase 1: resolve the batch into a final pending value per
        // touched edge key (None = removed), validating as we go.
        let mut pending: BTreeMap<(u32, u32), Option<f64>> = BTreeMap::new();
        let mut new_n = self.n;
        let mut nodes_added = 0usize;
        let canon = |i: usize, u: usize, v: usize, n: usize| -> Result<(u32, u32)> {
            if u == v {
                bail!("delta #{i}: self-loop at node {u}");
            }
            if u >= n || v >= n {
                bail!("delta #{i}: edge ({u},{v}) out of range for n = {n}");
            }
            Ok(if u < v { (u as u32, v as u32) } else { (v as u32, u as u32) })
        };
        for (i, d) in deltas.iter().enumerate() {
            match *d {
                EdgeDelta::AddNodes { count } => {
                    new_n += count;
                    nodes_added += count;
                }
                EdgeDelta::Add { u, v, w } => {
                    if !w.is_finite() {
                        bail!("delta #{i}: non-finite weight {w} for edge ({u},{v})");
                    }
                    let key = canon(i, u, v, new_n)?;
                    let cur = match pending.get(&key) {
                        Some(&p) => p,
                        None => self.edge_weight_canonical(key.0, key.1),
                    };
                    pending.insert(key, Some(cur.map_or(w, |c| c + w)));
                }
                EdgeDelta::Remove { u, v } => {
                    let key = canon(i, u, v, new_n)?;
                    let exists = match pending.get(&key) {
                        Some(p) => p.is_some(),
                        None => self.edge_weight_canonical(key.0, key.1).is_some(),
                    };
                    if !exists {
                        bail!("delta #{i}: remove of absent edge ({u},{v})");
                    }
                    pending.insert(key, None);
                }
                EdgeDelta::Reweight { u, v, w } => {
                    if !w.is_finite() {
                        bail!("delta #{i}: non-finite weight {w} for edge ({u},{v})");
                    }
                    let key = canon(i, u, v, new_n)?;
                    let exists = match pending.get(&key) {
                        Some(p) => p.is_some(),
                        None => self.edge_weight_canonical(key.0, key.1).is_some(),
                    };
                    if !exists {
                        bail!("delta #{i}: reweight of absent edge ({u},{v})");
                    }
                    pending.insert(key, Some(w));
                }
            }
        }

        // Phase 2: merge the sorted pending edits into the sorted
        // canonical edge list (both ascending by (u, v)) and tally what
        // actually changed.
        let pend: Vec<((u32, u32), Option<f64>)> = pending.into_iter().collect();
        let mut outcome = DeltaOutcome { nodes_added, ..Default::default() };
        let mut merged: Vec<Edge> = Vec::with_capacity(self.edges.len() + pend.len());
        let mut pi = 0usize;
        let mut push_new = |p: &((u32, u32), Option<f64>), out: &mut DeltaOutcome,
                            merged: &mut Vec<Edge>| {
            // A key absent from the graph whose final state is "removed"
            // (added then removed within one batch) is a no-op.
            if let Some(w) = p.1 {
                merged.push(Edge { u: p.0 .0, v: p.0 .1, w });
                out.edges_added += 1;
            }
        };
        for e in &self.edges {
            let key = (e.u, e.v);
            while pi < pend.len() && pend[pi].0 < key {
                push_new(&pend[pi], &mut outcome, &mut merged);
                pi += 1;
            }
            if pi < pend.len() && pend[pi].0 == key {
                match pend[pi].1 {
                    Some(w) => {
                        if w.to_bits() != e.w.to_bits() {
                            outcome.edges_reweighted += 1;
                        }
                        merged.push(Edge { u: e.u, v: e.v, w });
                    }
                    None => outcome.edges_removed += 1,
                }
                pi += 1;
            } else {
                merged.push(*e);
            }
        }
        while pi < pend.len() {
            push_new(&pend[pi], &mut outcome, &mut merged);
            pi += 1;
        }
        outcome.topology_changed =
            outcome.edges_added > 0 || outcome.edges_removed > 0 || nodes_added > 0;
        outcome.weights_changed = outcome.topology_changed || outcome.edges_reweighted > 0;

        // Phase 3: commit. The CSR refill replays the exact operation
        // sequence of `from_edges` (integer offsets, weights copied
        // verbatim — no arithmetic on stored values), so bitwise identity
        // with a from-scratch rebuild is structural, not approximate.
        self.n = new_n;
        self.edges = merged;
        if outcome.topology_changed {
            let mut degree_count = vec![0usize; self.n];
            for e in &self.edges {
                degree_count[e.u as usize] += 1;
                degree_count[e.v as usize] += 1;
            }
            self.offsets.clear();
            self.offsets.reserve(self.n + 1);
            self.offsets.push(0);
            for i in 0..self.n {
                self.offsets.push(self.offsets[i] + degree_count[i]);
            }
        }
        if outcome.weights_changed {
            let mut cursor = self.offsets.clone();
            self.neighbors.clear();
            self.neighbors.resize(self.offsets[self.n], (0u32, 0.0f64));
            for e in &self.edges {
                self.neighbors[cursor[e.u as usize]] = (e.v, e.w);
                cursor[e.u as usize] += 1;
                self.neighbors[cursor[e.v as usize]] = (e.u, e.w);
                cursor[e.v as usize] += 1;
            }
        }
        #[cfg(debug_assertions)]
        self.debug_assert_matches_rebuild();
        Ok(outcome)
    }

    /// Debug-build check of the tentpole invariant: the patched graph is
    /// bitwise-identical — edges, CSR adjacency, and both CSR Laplacians —
    /// to a from-scratch rebuild on the final edge set.
    #[cfg(debug_assertions)]
    fn debug_assert_matches_rebuild(&self) {
        let raw: Vec<(usize, usize, f64)> = self
            .edges
            .iter()
            .map(|e| (e.u as usize, e.v as usize, e.w))
            .collect();
        let rebuilt = Graph::from_edges(self.n, &raw).expect("patched edge list must rebuild");
        debug_assert_eq!(self.offsets, rebuilt.offsets, "delta patch broke CSR offsets");
        debug_assert!(
            self.neighbors.len() == rebuilt.neighbors.len()
                && self
                    .neighbors
                    .iter()
                    .zip(rebuilt.neighbors.iter())
                    .all(|(a, b)| a.0 == b.0 && a.1.to_bits() == b.1.to_bits()),
            "delta patch broke CSR neighbors"
        );
        for (ours, theirs) in [
            (self.laplacian_csr(), rebuilt.laplacian_csr()),
            (self.normalized_laplacian_csr(), rebuilt.normalized_laplacian_csr()),
        ] {
            // Structural CSR validation (sorted strictly-ascending columns,
            // consistent indptr) of the Laplacian built from the *patched*
            // adjacency — the invariant every SpMM kernel assumes.
            ours.debug_assert_valid();
            debug_assert!(
                ours.values().len() == theirs.values().len()
                    && ours
                        .values()
                        .iter()
                        .zip(theirs.values().iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                "delta patch broke CSR Laplacian bitwise parity"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn square() -> Graph {
        Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 2.0), (2, 3, 0.5), (0, 3, 1.0)]).unwrap()
    }

    #[test]
    fn add_remove_reweight_roundtrip() {
        let mut g = square();
        let out = g
            .apply_deltas(&[
                EdgeDelta::Add { u: 0, v: 2, w: 3.0 },
                EdgeDelta::Remove { u: 2, v: 3 },
                EdgeDelta::Reweight { u: 0, v: 1, w: 4.0 },
            ])
            .unwrap();
        assert_eq!(out.edges_added, 1);
        assert_eq!(out.edges_removed, 1);
        assert_eq!(out.edges_reweighted, 1);
        assert!(out.topology_changed && out.weights_changed);
        assert_eq!(out.volume(), 3);
        let expect = Graph::from_edges(4, &[(0, 1, 4.0), (0, 2, 3.0), (1, 2, 2.0)]).unwrap();
        assert_eq!(g.edges(), expect.edges());
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn add_merges_weight_like_from_edges_duplicates() {
        let mut g = square();
        g.apply_deltas(&[EdgeDelta::Add { u: 1, v: 0, w: 0.5 }]).unwrap();
        assert_eq!(g.edges()[0].w, 1.5);
        // Within-batch sequencing: create, bump, then remove → no-op edge.
        let out = g
            .apply_deltas(&[
                EdgeDelta::Add { u: 1, v: 3, w: 1.0 },
                EdgeDelta::Add { u: 3, v: 1, w: 1.0 },
                EdgeDelta::Remove { u: 1, v: 3 },
            ])
            .unwrap();
        assert_eq!((out.edges_added, out.edges_removed), (0, 0));
        assert!(!out.topology_changed);
    }

    #[test]
    fn reweight_only_batch_keeps_structure_flags() {
        let mut g = square();
        let before = g.edges().to_vec();
        let out = g.apply_deltas(&[EdgeDelta::Reweight { u: 1, v: 2, w: 7.0 }]).unwrap();
        assert!(!out.topology_changed);
        assert!(out.weights_changed);
        assert_eq!(g.edges()[1].w, 7.0);
        // Bitwise-identical reweight is reported as no change at all.
        let out2 = g.apply_deltas(&[EdgeDelta::Reweight { u: 1, v: 2, w: 7.0 }]).unwrap();
        assert!(!out2.weights_changed && out2.volume() == 0);
        assert_ne!(before[1].w, g.edges()[1].w);
    }

    #[test]
    fn addnodes_grows_and_new_ids_usable_in_same_batch() {
        let mut g = square();
        let out = g
            .apply_deltas(&[
                EdgeDelta::AddNodes { count: 2 },
                EdgeDelta::Add { u: 3, v: 5, w: 1.0 },
            ])
            .unwrap();
        assert_eq!(g.num_nodes(), 6);
        assert_eq!(out.nodes_added, 2);
        assert!(out.topology_changed);
        // Node 4 is isolated: structural diagonal zero in the Laplacian.
        let (cols, vals) = g.laplacian_csr().row(4);
        assert_eq!((cols, vals), (&[4u32][..], &[0.0][..]));
    }

    #[test]
    fn bad_deltas_are_rejected_transactionally() {
        let mut g = square();
        let snapshot = g.edges().to_vec();
        for (deltas, needle) in [
            (vec![EdgeDelta::Add { u: 0, v: 0, w: 1.0 }], "self-loop"),
            (vec![EdgeDelta::Add { u: 0, v: 9, w: 1.0 }], "out of range"),
            (vec![EdgeDelta::Add { u: 0, v: 2, w: f64::NAN }], "non-finite"),
            (vec![EdgeDelta::Reweight { u: 0, v: 1, w: f64::INFINITY }], "non-finite"),
            (vec![EdgeDelta::Remove { u: 0, v: 2 }], "absent"),
            (vec![EdgeDelta::Reweight { u: 1, v: 3, w: 2.0 }], "absent"),
            (
                // Valid first delta, bad second: nothing may stick.
                vec![
                    EdgeDelta::Add { u: 0, v: 2, w: 1.0 },
                    EdgeDelta::Add { u: 1, v: 3, w: f64::NAN },
                ],
                "non-finite",
            ),
        ] {
            let err = g.apply_deltas(&deltas).unwrap_err().to_string();
            assert!(err.contains(needle), "{err:?} missing {needle:?}");
            assert!(err.contains("delta #"), "{err:?} missing delta index");
            assert_eq!(g.edges(), snapshot.as_slice(), "failed batch mutated the graph");
        }
    }

    #[test]
    fn removal_down_to_isolated_vertices() {
        let mut g = square();
        g.apply_deltas(&[
            EdgeDelta::Remove { u: 0, v: 1 },
            EdgeDelta::Remove { u: 1, v: 2 },
            EdgeDelta::Remove { u: 2, v: 3 },
            EdgeDelta::Remove { u: 0, v: 3 },
        ])
        .unwrap();
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.num_components(), 4);
        for v in 0..4 {
            assert_eq!(g.degree(v), 0);
        }
        // Laplacians of the edgeless graph: all-zero structural diagonal.
        assert!(g.laplacian_csr().values().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn parse_event_lines() {
        assert_eq!(
            EdgeDelta::parse("add 0 3 1.5").unwrap(),
            EdgeDelta::Add { u: 0, v: 3, w: 1.5 }
        );
        assert_eq!(EdgeDelta::parse("remove 2 1").unwrap(), EdgeDelta::Remove { u: 2, v: 1 });
        assert_eq!(
            EdgeDelta::parse("reweight 0 1 0.25").unwrap(),
            EdgeDelta::Reweight { u: 0, v: 1, w: 0.25 }
        );
        assert_eq!(EdgeDelta::parse("addnodes 8").unwrap(), EdgeDelta::AddNodes { count: 8 });
        // NaN parses at the tokenizer; apply_deltas is the validator.
        match EdgeDelta::parse("add 0 1 nan").unwrap() {
            EdgeDelta::Add { w, .. } => assert!(w.is_nan()),
            other => panic!("unexpected {other:?}"),
        }
        for bad in ["", "frob 1 2", "add 0 1", "remove 1", "add 0 1 2 3", "addnodes x"] {
            assert!(EdgeDelta::parse(bad).is_err(), "{bad:?} should fail");
        }
    }
}
