//! Graph substrate: undirected weighted graphs in CSR form, the incidence
//! representation of §2, Laplacians, and workload generators.

pub mod delta;
pub mod gen;
pub mod incidence;
pub mod io;

use crate::linalg::sparse::CsrMat;
use crate::linalg::DMat;
use anyhow::{bail, Result};

/// How CSR rows are ordered before the matrix-free solve
/// (`PipelineConfig::reorder`, CLI `--reorder`).
///
/// Reordering relabels nodes — it changes *where* each nonzero lives, not
/// the spectrum or the clustering. On bandwidth-reducible graphs
/// (power-law, meshes) [`Reorder::Rcm`] clusters the nonzeros around the
/// diagonal so each SpMM row sweep reads `B` nearly sequentially instead
/// of gathering from all over the bundle.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Reorder {
    /// Keep the input node order.
    #[default]
    None,
    /// Reverse Cuthill–McKee ([`Graph::rcm_permutation`]).
    Rcm,
}

impl Reorder {
    /// Parse from a CLI/config name (`none` | `rcm`).
    pub fn parse(s: &str) -> Result<Reorder> {
        Ok(match s {
            "none" | "off" => Reorder::None,
            "rcm" | "cuthill-mckee" | "cuthill_mckee" => Reorder::Rcm,
            other => bail!("unknown reorder {other:?} (expected none | rcm)"),
        })
    }

    /// Canonical display name.
    pub fn name(&self) -> &'static str {
        match self {
            Reorder::None => "none",
            Reorder::Rcm => "rcm",
        }
    }
}

impl std::fmt::Display for Reorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Invert a permutation given in `order[new] = old` form: returns `inv`
/// with `inv[old] = new`. Panics if `order` is not a permutation.
pub fn invert_permutation(order: &[usize]) -> Vec<usize> {
    try_invert_permutation(order, order.len()).expect("not a permutation")
}

/// Fallible core shared by [`invert_permutation`] and [`Graph::permute`]:
/// the one place the "is this a permutation of `0..n`" validation lives.
fn try_invert_permutation(order: &[usize], n: usize) -> Result<Vec<usize>> {
    if order.len() != n {
        bail!("permutation length {} != n = {n}", order.len());
    }
    let mut inv = vec![usize::MAX; n];
    for (new, &old) in order.iter().enumerate() {
        if old >= n || inv[old] != usize::MAX {
            bail!("order is not a permutation of 0..{n}");
        }
        inv[old] = new;
    }
    Ok(inv)
}

/// CSR adjacency for a canonical edge list: counting pass over the
/// degrees, prefix-sum offsets, then a scatter pass. Shared by every
/// [`Graph`] construction path so the neighbor order (edge-list order per
/// row) is identical no matter how the edges were produced.
fn build_adjacency(n: usize, edges: &[Edge]) -> (Vec<usize>, Vec<(u32, f64)>) {
    let mut degree_count = vec![0usize; n];
    for e in edges {
        degree_count[e.u as usize] += 1;
        degree_count[e.v as usize] += 1;
    }
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0);
    for i in 0..n {
        offsets.push(offsets[i] + degree_count[i]);
    }
    let mut cursor = offsets.clone();
    let mut neighbors = vec![(0u32, 0.0f64); offsets[n]];
    for e in edges {
        neighbors[cursor[e.u as usize]] = (e.v, e.w);
        cursor[e.u as usize] += 1;
        neighbors[cursor[e.v as usize]] = (e.u, e.w);
        cursor[e.v as usize] += 1;
    }
    (offsets, neighbors)
}

/// An undirected, optionally weighted graph.
///
/// Edges are stored once in canonical orientation `(u, v)` with `u < v`
/// (matching the paper's incidence-vector convention: `x_e` has `+1` at
/// `min(i,j)` and `−1` at `max(i,j)`), plus a CSR adjacency index for
/// neighbor iteration.
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    edges: Vec<Edge>,
    offsets: Vec<usize>,
    neighbors: Vec<(u32, f64)>,
}

/// A canonical undirected edge `u < v` with weight `w`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub u: u32,
    pub v: u32,
    pub w: f64,
}

impl Graph {
    /// Build from an edge list. Edges are canonicalized (`u < v`),
    /// duplicate edges have their weights summed, self-loops are rejected.
    pub fn from_edges(n: usize, raw: &[(usize, usize, f64)]) -> Result<Graph> {
        let mut canon: Vec<(u32, u32, f64)> = Vec::with_capacity(raw.len());
        for &(a, b, w) in raw {
            if a == b {
                bail!("self-loop at node {a}");
            }
            if a >= n || b >= n {
                bail!("edge ({a},{b}) out of range for n={n}");
            }
            if !(w.is_finite()) {
                bail!("non-finite edge weight {w}");
            }
            let (u, v) = if a < b { (a, b) } else { (b, a) };
            canon.push((u as u32, v as u32, w));
        }
        canon.sort_by_key(|&(u, v, _)| (u, v));
        let mut edges: Vec<Edge> = Vec::with_capacity(canon.len());
        for (u, v, w) in canon {
            match edges.last_mut() {
                Some(last) if last.u == u && last.v == v => last.w += w,
                _ => edges.push(Edge { u, v, w }),
            }
        }
        let (offsets, neighbors) = build_adjacency(n, &edges);
        Ok(Graph { n, edges, offsets, neighbors })
    }

    /// Build with unit weights.
    pub fn from_pairs(n: usize, pairs: &[(usize, usize)]) -> Result<Graph> {
        let raw: Vec<(usize, usize, f64)> = pairs.iter().map(|&(a, b)| (a, b, 1.0)).collect();
        Graph::from_edges(n, &raw)
    }

    /// Build from an **already-canonical** edge list: each edge `u < v`,
    /// strictly ascending `(u, v)` order (hence no duplicates), finite
    /// weights. Validates those invariants in `O(E)` and takes ownership —
    /// no intermediate sort or merge buffer, so a streaming generator can
    /// hand over its edges with exactly one `Vec<Edge>` live (plus the
    /// `2E` CSR adjacency every construction path builds). The invariants
    /// are precisely what [`Graph::from_edges`] would have produced, so
    /// graphs built either way are interchangeable bit for bit.
    pub fn from_canonical_edges(n: usize, edges: Vec<Edge>) -> Result<Graph> {
        let mut prev: Option<(u32, u32)> = None;
        for e in &edges {
            if e.u >= e.v {
                bail!("edge ({},{}) is not canonical (need u < v)", e.u, e.v);
            }
            if e.v as usize >= n {
                bail!("edge ({},{}) out of range for n={n}", e.u, e.v);
            }
            if !e.w.is_finite() {
                bail!("non-finite edge weight {}", e.w);
            }
            if let Some(p) = prev {
                if p >= (e.u, e.v) {
                    bail!(
                        "edges not strictly ascending: ({},{}) after ({},{})",
                        e.u, e.v, p.0, p.1
                    );
                }
            }
            prev = Some((e.u, e.v));
        }
        let (offsets, neighbors) = build_adjacency(n, &edges);
        Ok(Graph { n, edges, offsets, neighbors })
    }

    pub fn num_nodes(&self) -> usize {
        self.n
    }
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }
    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Neighbor list of `v` as `(neighbor, weight)` pairs.
    pub fn neighbors(&self, v: usize) -> &[(u32, f64)] {
        &self.neighbors[self.offsets[v]..self.offsets[v + 1]]
    }

    /// Unweighted degree (neighbor count).
    pub fn degree(&self, v: usize) -> usize {
        self.offsets[v + 1] - self.offsets[v]
    }

    /// Weighted degree `Σ_u w(v,u)`.
    pub fn weighted_degree(&self, v: usize) -> f64 {
        self.neighbors(v).iter().map(|&(_, w)| w).sum()
    }

    /// Maximum unweighted degree.
    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Total edge weight.
    pub fn total_weight(&self) -> f64 {
        self.edges.iter().map(|e| e.w).sum()
    }

    /// Dense graph Laplacian `L = D − A` (weighted: `L = XᵀWX`).
    pub fn laplacian(&self) -> DMat {
        let mut l = DMat::zeros(self.n, self.n);
        for e in &self.edges {
            let (u, v, w) = (e.u as usize, e.v as usize, e.w);
            l[(u, u)] += w;
            l[(v, v)] += w;
            l[(u, v)] -= w;
            l[(v, u)] -= w;
        }
        l
    }

    /// Shared CSR Laplacian assembly: one row per node, columns strictly
    /// ascending, the diagonal always structurally present (isolated nodes
    /// store an explicit `0.0`) so spectral shifts can edit it in place.
    ///
    /// This scaffold carries the bitwise-parity invariant with the dense
    /// builders: `neighbors(v)` is ascending (ids < v first, then ids > v —
    /// the incident-edge order the dense build accumulates in), and the
    /// diagonal is spliced in at its sorted position. `diag(v)` and
    /// `offdiag(v, u, w)` supply the entry values.
    fn assemble_laplacian_csr(
        &self,
        diag: impl Fn(usize) -> f64,
        offdiag: impl Fn(usize, usize, f64) -> f64,
    ) -> CsrMat {
        let n = self.n;
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices: Vec<u32> = Vec::with_capacity(self.neighbors.len() + n);
        let mut values: Vec<f64> = Vec::with_capacity(self.neighbors.len() + n);
        indptr.push(0);
        for v in 0..n {
            let mut placed_diag = false;
            for &(u, w) in self.neighbors(v) {
                if !placed_diag && (u as usize) > v {
                    indices.push(v as u32);
                    values.push(diag(v));
                    placed_diag = true;
                }
                indices.push(u);
                values.push(offdiag(v, u as usize, w));
            }
            if !placed_diag {
                indices.push(v as u32);
                values.push(diag(v));
            }
            indptr.push(indices.len());
        }
        CsrMat::new(n, n, indptr, indices, values)
    }

    /// Sparse (CSR) graph Laplacian `L = D − A` — the matrix-free
    /// counterpart of [`Self::laplacian`]: `O(n + nnz)` memory instead of
    /// `O(n²)`, with entries bitwise identical to the dense build, which is
    /// what makes [`crate::linalg::sparse::spmm`] bitwise-equal to the
    /// dense product.
    pub fn laplacian_csr(&self) -> CsrMat {
        // `0.0 - w`, not `-w`: the dense build subtracts from a zeroed
        // matrix, and for a (legal) zero-weight edge `-0.0 != +0.0` bitwise.
        self.assemble_laplacian_csr(|v| self.weighted_degree(v), |_, _, w| 0.0 - w)
    }

    /// Sparse (CSR) *normalized* Laplacian `D^{-1/2} L D^{-1/2}` — entries
    /// bitwise identical to [`Self::normalized_laplacian`]; diagonal always
    /// structurally present (isolated nodes store `0.0`).
    pub fn normalized_laplacian_csr(&self) -> CsrMat {
        let d: Vec<f64> = (0..self.n)
            .map(|v| {
                let wd = self.weighted_degree(v);
                if wd > 0.0 {
                    1.0 / wd.sqrt()
                } else {
                    0.0
                }
            })
            .collect();
        self.assemble_laplacian_csr(
            |v| if self.weighted_degree(v) > 0.0 { 1.0 } else { 0.0 },
            |v, u, w| {
                // Multiply in canonical (smaller-endpoint-first) order and
                // subtract from zero — the exact f64 operation sequence of
                // the dense build, so the representations agree bitwise.
                let (lo, hi) = if u < v { (u, v) } else { (v, u) };
                0.0 - w * d[lo] * d[hi]
            },
        )
    }

    /// Dense *normalized* Laplacian `D^{-1/2} L D^{-1/2}` (isolated nodes
    /// contribute zero rows/cols).
    pub fn normalized_laplacian(&self) -> DMat {
        let d: Vec<f64> = (0..self.n)
            .map(|v| {
                let wd = self.weighted_degree(v);
                if wd > 0.0 {
                    1.0 / wd.sqrt()
                } else {
                    0.0
                }
            })
            .collect();
        let mut l = DMat::zeros(self.n, self.n);
        for e in &self.edges {
            let (u, v, w) = (e.u as usize, e.v as usize, e.w);
            let nw = w * d[u] * d[v];
            l[(u, v)] -= nw;
            l[(v, u)] -= nw;
        }
        for v in 0..self.n {
            l[(v, v)] = if self.weighted_degree(v) > 0.0 { 1.0 } else { 0.0 };
        }
        l
    }

    /// Laplacian quadratic form `vᵀLv = Σ_e w_e (v_u − v_v)²` (eq 1) without
    /// materializing `L`.
    pub fn quadratic_form(&self, v: &[f64]) -> f64 {
        assert_eq!(v.len(), self.n);
        self.edges
            .iter()
            .map(|e| {
                let d = v[e.u as usize] - v[e.v as usize];
                e.w * d * d
            })
            .sum()
    }

    /// Cut weight between `s` and its complement (eq 1 semantics: the
    /// number/weight of crossing edges).
    pub fn cut_weight(&self, in_s: &[bool]) -> f64 {
        assert_eq!(in_s.len(), self.n);
        self.edges
            .iter()
            .filter(|e| in_s[e.u as usize] != in_s[e.v as usize])
            .map(|e| e.w)
            .sum()
    }

    /// Volume of a node set: total weighted degree (eq 3 denominator).
    pub fn volume(&self, in_s: &[bool]) -> f64 {
        (0..self.n)
            .filter(|&v| in_s[v])
            .map(|v| self.weighted_degree(v))
            .sum()
    }

    /// Conductance φ(S) = cut(S, S̄) / vol(S) (eq 3). Returns `None` for
    /// empty or zero-volume sets.
    pub fn conductance(&self, in_s: &[bool]) -> Option<f64> {
        let vol = self.volume(in_s);
        if vol == 0.0 {
            return None;
        }
        Some(self.cut_weight(in_s) / vol)
    }

    /// Number of connected components (unweighted, via BFS).
    pub fn num_components(&self) -> usize {
        let mut seen = vec![false; self.n];
        let mut comps = 0;
        let mut queue = std::collections::VecDeque::new();
        for start in 0..self.n {
            if seen[start] {
                continue;
            }
            comps += 1;
            seen[start] = true;
            queue.push_back(start);
            while let Some(v) = queue.pop_front() {
                for &(u, _) in self.neighbors(v) {
                    if !seen[u as usize] {
                        seen[u as usize] = true;
                        queue.push_back(u as usize);
                    }
                }
            }
        }
        comps
    }

    /// Bandwidth of the node ordering: `max_e |u − v|` over edges (0 for
    /// edgeless graphs). The quantity RCM minimizes heuristically — small
    /// bandwidth means every CSR row's column accesses land in a narrow,
    /// cache-resident window of the dense bundle.
    pub fn bandwidth(&self) -> usize {
        self.edges.iter().map(|e| (e.v - e.u) as usize).max().unwrap_or(0)
    }

    /// Reverse Cuthill–McKee node ordering, returned as `order` with
    /// `order[new] = old` (feed it to [`Self::permute`] to materialize the
    /// relabeled graph, and [`invert_permutation`] to map old → new).
    ///
    /// Deterministic: each component is seeded from the unvisited node of
    /// minimum `(degree, id)` and BFS enqueues neighbors in ascending
    /// `(degree, id)`; the visitation order is then reversed (the
    /// "Reverse" in RCM — it tightens the profile over plain
    /// Cuthill–McKee). `O(n log n + Σ_v deg(v) log deg(v))`.
    pub fn rcm_permutation(&self) -> Vec<usize> {
        let n = self.n;
        let mut order = Vec::with_capacity(n);
        let mut seen = vec![false; n];
        // Component seeds: ascending (degree, id) — low-degree peripheral
        // starts give the narrow BFS levels RCM wants.
        let mut seeds: Vec<usize> = (0..n).collect();
        seeds.sort_by_key(|&v| (self.degree(v), v));
        let mut queue = std::collections::VecDeque::new();
        let mut nbrs: Vec<usize> = Vec::new();
        for &start in &seeds {
            if seen[start] {
                continue;
            }
            seen[start] = true;
            queue.push_back(start);
            while let Some(v) = queue.pop_front() {
                order.push(v);
                nbrs.clear();
                nbrs.extend(
                    self.neighbors(v)
                        .iter()
                        .map(|&(u, _)| u as usize)
                        .filter(|&u| !seen[u]),
                );
                nbrs.sort_by_key(|&u| (self.degree(u), u));
                for &u in &nbrs {
                    seen[u] = true;
                    queue.push_back(u);
                }
            }
        }
        order.reverse();
        order
    }

    /// Relabeled copy: node `i` of the result is node `order[i]` of `self`
    /// (`order[new] = old`, the [`Self::rcm_permutation`] convention).
    /// Topology and weights are preserved; only node ids change, so the
    /// Laplacian spectrum — and with it the clustering — is untouched. The
    /// result's CSR builders ([`Self::laplacian_csr`] /
    /// [`Self::normalized_laplacian_csr`]) are the permuted-CSR assembly
    /// path the reordered pipeline runs on.
    pub fn permute(&self, order: &[usize]) -> Result<Graph> {
        let inv = try_invert_permutation(order, self.n)?;
        let raw: Vec<(usize, usize, f64)> = self
            .edges
            .iter()
            .map(|e| (inv[e.u as usize], inv[e.v as usize], e.w))
            .collect();
        Graph::from_edges(self.n, &raw)
    }

    /// Re-weighted copy with the same topology.
    pub fn with_weights(&self, weights: &[f64]) -> Result<Graph> {
        if weights.len() != self.edges.len() {
            bail!("weight count mismatch");
        }
        let raw: Vec<(usize, usize, f64)> = self
            .edges
            .iter()
            .zip(weights)
            .map(|(e, &w)| (e.u as usize, e.v as usize, w))
            .collect();
        Graph::from_edges(self.n, &raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        Graph::from_pairs(3, &[(0, 1), (1, 2), (0, 2)]).unwrap()
    }

    #[test]
    fn canonical_construction_matches_from_edges_bitwise() {
        let raw = [(2usize, 0usize, 0.5), (1, 3, 2.0), (0, 1, 1.25)];
        let a = Graph::from_edges(4, &raw).unwrap();
        let b = Graph::from_canonical_edges(4, a.edges().to_vec()).unwrap();
        assert_eq!(a.edges(), b.edges());
        assert_eq!(a.offsets, b.offsets);
        assert_eq!(a.neighbors, b.neighbors);
        // O(E) validation: non-canonical orientation, duplicates /
        // out-of-order, out-of-range endpoints, non-finite weights.
        let e = |u, v, w| Edge { u, v, w };
        assert!(Graph::from_canonical_edges(4, vec![e(2, 1, 1.0)]).is_err());
        assert!(Graph::from_canonical_edges(4, vec![e(1, 1, 1.0)]).is_err());
        assert!(Graph::from_canonical_edges(4, vec![e(0, 1, 1.0), e(0, 1, 1.0)]).is_err());
        assert!(Graph::from_canonical_edges(4, vec![e(1, 2, 1.0), e(0, 1, 1.0)]).is_err());
        assert!(Graph::from_canonical_edges(3, vec![e(0, 3, 1.0)]).is_err());
        assert!(Graph::from_canonical_edges(4, vec![e(0, 1, f64::NAN)]).is_err());
        // Empty list is a valid (edgeless) graph.
        assert_eq!(Graph::from_canonical_edges(2, Vec::new()).unwrap().num_edges(), 0);
    }

    #[test]
    fn construction_canonicalizes() {
        let g = Graph::from_pairs(4, &[(2, 0), (3, 1)]).unwrap();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.edges()[0], Edge { u: 0, v: 2, w: 1.0 });
        assert_eq!(g.edges()[1], Edge { u: 1, v: 3, w: 1.0 });
    }

    #[test]
    fn duplicates_merge_weights() {
        let g = Graph::from_edges(3, &[(0, 1, 1.0), (1, 0, 2.5)]).unwrap();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.edges()[0].w, 3.5);
    }

    #[test]
    fn rejects_bad_edges() {
        assert!(Graph::from_pairs(3, &[(0, 0)]).is_err());
        assert!(Graph::from_pairs(3, &[(0, 5)]).is_err());
        assert!(Graph::from_edges(3, &[(0, 1, f64::NAN)]).is_err());
    }

    #[test]
    fn degrees_and_neighbors() {
        let g = triangle();
        for v in 0..3 {
            assert_eq!(g.degree(v), 2);
            assert_eq!(g.weighted_degree(v), 2.0);
        }
        assert_eq!(g.max_degree(), 2);
        let mut nb: Vec<u32> = g.neighbors(0).iter().map(|&(u, _)| u).collect();
        nb.sort_unstable();
        assert_eq!(nb, vec![1, 2]);
    }

    #[test]
    fn laplacian_rows_sum_to_zero() {
        let g = triangle();
        let l = g.laplacian();
        for i in 0..3 {
            let s: f64 = l.row(i).iter().sum();
            assert!(s.abs() < 1e-12);
        }
        assert_eq!(l[(0, 0)], 2.0);
        assert_eq!(l[(0, 1)], -1.0);
    }

    #[test]
    fn laplacian_equals_incidence_gram() {
        let g = gen::cliques(&gen::CliqueSpec { n: 30, k: 3, max_short_circuit: 5, seed: 1 }).graph;
        let l = g.laplacian();
        let x = incidence::incidence_matrix(&g);
        let xtx = crate::linalg::matmul::matmul(&x.t(), &x);
        assert!((&l - &xtx).max_abs() < 1e-12);
    }

    #[test]
    fn quadratic_form_counts_cut() {
        // v = ±1 indicator: vᵀLv = 4 × cut (eq 1 remark).
        let g = Graph::from_pairs(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]).unwrap();
        let v = [1.0, 1.0, -1.0, -1.0];
        let in_s = [true, true, false, false];
        assert_eq!(g.quadratic_form(&v), 4.0 * g.cut_weight(&in_s));
    }

    #[test]
    fn conductance_basics() {
        let g = triangle();
        let s = [true, false, false];
        // cut = 2, vol = 2 → φ = 1
        assert_eq!(g.conductance(&s), Some(1.0));
        assert_eq!(g.conductance(&[false, false, false]), None);
    }

    #[test]
    fn components() {
        let g = Graph::from_pairs(5, &[(0, 1), (2, 3)]).unwrap();
        assert_eq!(g.num_components(), 3);
        assert_eq!(triangle().num_components(), 1);
    }

    #[test]
    fn normalized_laplacian_unit_diagonal() {
        let g = triangle();
        let nl = g.normalized_laplacian();
        for i in 0..3 {
            assert!((nl[(i, i)] - 1.0).abs() < 1e-12);
        }
        // Normalized Laplacian of a graph has eigenvalues in [0, 2].
        let e = crate::linalg::eigh(&nl).unwrap();
        assert!(e.values[0] > -1e-10);
        assert!(e.lambda_max() <= 2.0 + 1e-10);
    }

    #[test]
    fn csr_laplacians_bitwise_match_dense() {
        // Both Laplacian variants, with weights, short circuits, and an
        // isolated node (n=7 below only wires 0..=5).
        let weighted = Graph::from_edges(
            7,
            &[(0, 1, 0.5), (1, 2, 2.0), (0, 2, 1.25), (3, 4, 0.75), (4, 5, 1.0)],
        )
        .unwrap();
        let generated =
            gen::cliques(&gen::CliqueSpec { n: 30, k: 3, max_short_circuit: 5, seed: 2 }).graph;
        // Duplicate edges summing to exactly 0.0: the dense build writes
        // +0.0 (0.0 − 0.0), and the CSR build must too, not −0.0.
        let zero_weight =
            Graph::from_edges(3, &[(0, 1, 1.0), (0, 1, -1.0), (1, 2, 0.5)]).unwrap();
        for g in [&weighted, &generated, &zero_weight] {
            for (dense, sparse) in [
                (g.laplacian(), g.laplacian_csr()),
                (g.normalized_laplacian(), g.normalized_laplacian_csr()),
            ] {
                let densified = sparse.to_dense();
                let identical = dense
                    .data()
                    .iter()
                    .zip(densified.data().iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(identical, "CSR/dense Laplacian mismatch");
                // Diagonal is structurally present in every row.
                for i in 0..g.num_nodes() {
                    let (cols, _) = sparse.row(i);
                    assert!(cols.contains(&(i as u32)), "row {i} missing diagonal");
                }
            }
        }
        // Isolated node 6: an explicit structural zero on the diagonal.
        let lcsr = weighted.laplacian_csr();
        let (cols, vals) = lcsr.row(6);
        assert_eq!(cols, &[6]);
        assert_eq!(vals, &[0.0]);
    }

    #[test]
    fn csr_laplacian_quadratic_form_consistency() {
        // vᵀ(Lv) through the sparse product equals the edge-sum form (eq 1).
        let g = gen::cliques(&gen::CliqueSpec { n: 24, k: 2, max_short_circuit: 3, seed: 8 }).graph;
        let l = g.laplacian_csr();
        let mut rng = crate::util::rng::Rng::new(5);
        let v: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let lv = crate::linalg::sparse::spmv(&l, &v, 1);
        let quad: f64 = v.iter().zip(lv.iter()).map(|(a, b)| a * b).sum();
        assert!((quad - g.quadratic_form(&v)).abs() < 1e-9);
    }

    #[test]
    fn reorder_parse_and_display() {
        assert_eq!(Reorder::parse("none").unwrap(), Reorder::None);
        assert_eq!(Reorder::parse("rcm").unwrap(), Reorder::Rcm);
        assert_eq!(Reorder::parse("cuthill-mckee").unwrap(), Reorder::Rcm);
        assert!(Reorder::parse("bogus").is_err());
        assert_eq!(Reorder::default(), Reorder::None);
        assert_eq!(Reorder::Rcm.to_string(), "rcm");
    }

    #[test]
    fn permute_relabels_and_roundtrips() {
        let g = Graph::from_edges(4, &[(0, 1, 2.0), (1, 2, 0.5), (0, 3, 1.0)]).unwrap();
        // order[new] = old: new node 0 is old node 3, etc.
        let order = vec![3usize, 1, 0, 2];
        let p = g.permute(&order).unwrap();
        assert_eq!(p.num_edges(), 3);
        // Old edge (0,3,1.0) → new (2,0): weighted degree moves with it.
        assert_eq!(p.weighted_degree(0), g.weighted_degree(3));
        assert_eq!(p.weighted_degree(2), g.weighted_degree(0));
        // Round trip through the inverse recovers the original edge list.
        let back = p.permute(&invert_permutation(&order)).unwrap();
        assert_eq!(back.edges(), g.edges());
        // Non-permutations are rejected.
        assert!(g.permute(&[0, 0, 1, 2]).is_err());
        assert!(g.permute(&[0, 1]).is_err());
    }

    #[test]
    fn permutation_preserves_spectrum() {
        let g = gen::cliques(&gen::CliqueSpec { n: 18, k: 2, max_short_circuit: 2, seed: 4 }).graph;
        let order = g.rcm_permutation();
        let p = g.permute(&order).unwrap();
        let e_g = crate::linalg::eigh(&g.laplacian()).unwrap();
        let e_p = crate::linalg::eigh(&p.laplacian()).unwrap();
        for (a, b) in e_g.values.iter().zip(e_p.values.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn rcm_is_a_permutation_and_reduces_path_bandwidth() {
        // A path graph scrambled by an affine relabeling has bandwidth
        // near n; RCM must recover (a rotation of) the natural order with
        // bandwidth exactly 1.
        let n = 31usize;
        let natural = gen::path(n).graph;
        assert_eq!(natural.bandwidth(), 1);
        let scramble: Vec<usize> = (0..n).map(|i| (i * 13) % n).collect(); // gcd(13,31)=1
        let scrambled = natural.permute(&scramble).unwrap();
        assert!(scrambled.bandwidth() > 10, "scramble too weak: {}", scrambled.bandwidth());
        let order = scrambled.rcm_permutation();
        let inv = invert_permutation(&order);
        for i in 0..n {
            assert_eq!(inv[order[i]], i);
        }
        assert_eq!(scrambled.permute(&order).unwrap().bandwidth(), 1);
    }

    #[test]
    fn rcm_handles_disconnected_and_isolated_nodes() {
        // Two components plus isolated node 6: every node appears exactly
        // once in the ordering.
        let g = Graph::from_pairs(7, &[(0, 1), (1, 2), (3, 4), (4, 5)]).unwrap();
        let order = g.rcm_permutation();
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..7).collect::<Vec<_>>());
        // Permuted CSR Laplacian still bitwise-matches its dense build.
        let p = g.permute(&order).unwrap();
        let densified = p.laplacian_csr().to_dense();
        let dense = p.laplacian();
        assert!(dense
            .data()
            .iter()
            .zip(densified.data().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn reweighting_preserves_topology() {
        let g = triangle();
        let w = vec![0.5, 0.25, 2.0];
        let g2 = g.with_weights(&w).unwrap();
        assert_eq!(g2.num_edges(), 3);
        assert!((g2.total_weight() - 2.75).abs() < 1e-12);
    }
}
