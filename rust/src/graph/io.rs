//! Edge-list file I/O.
//!
//! Format: one edge per line, `u v [w]`, `#` comments, blank lines ignored.
//! Node count is `max id + 1` unless a `# nodes: N` header is present.

use super::Graph;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Parse a graph from edge-list text.
pub fn parse_edge_list(text: &str) -> Result<Graph> {
    let mut edges: Vec<(usize, usize, f64)> = Vec::new();
    let mut declared_n: Option<usize> = None;
    let mut max_id = 0usize;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            if let Some(n) = rest.trim().strip_prefix("nodes:") {
                declared_n = Some(
                    n.trim()
                        .parse()
                        .with_context(|| format!("line {}: bad node count", lineno + 1))?,
                );
            }
            continue;
        }
        let mut parts = line.split_whitespace();
        let u: usize = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing u", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad node id", lineno + 1))?;
        let v: usize = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing v", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad node id", lineno + 1))?;
        let w: f64 = match parts.next() {
            Some(s) => s
                .parse()
                .with_context(|| format!("line {}: bad weight", lineno + 1))?,
            None => 1.0,
        };
        if parts.next().is_some() {
            bail!("line {}: trailing tokens", lineno + 1);
        }
        max_id = max_id.max(u).max(v);
        edges.push((u, v, w));
    }
    let n = declared_n.unwrap_or(if edges.is_empty() { 0 } else { max_id + 1 });
    Graph::from_edges(n, &edges)
}

/// Load a graph from an edge-list file.
pub fn load_edge_list<P: AsRef<Path>>(path: P) -> Result<Graph> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse_edge_list(&text)
}

/// Save a graph as an edge list (with a `# nodes:` header so isolated
/// trailing nodes round-trip).
pub fn save_edge_list<P: AsRef<Path>>(g: &Graph, path: P) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    writeln!(f, "# nodes: {}", g.num_nodes())?;
    for e in g.edges() {
        if (e.w - 1.0).abs() < 1e-15 {
            writeln!(f, "{} {}", e.u, e.v)?;
        } else {
            writeln!(f, "{} {} {}", e.u, e.v, e.w)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let g = parse_edge_list("0 1\n1 2 0.5\n# comment\n\n2 3\n").unwrap();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.edges()[1].w, 0.5);
    }

    #[test]
    fn declared_nodes_header() {
        let g = parse_edge_list("# nodes: 10\n0 1\n").unwrap();
        assert_eq!(g.num_nodes(), 10);
    }

    #[test]
    fn errors_on_garbage() {
        assert!(parse_edge_list("0\n").is_err());
        assert!(parse_edge_list("a b\n").is_err());
        assert!(parse_edge_list("0 1 2 3\n").is_err());
    }

    #[test]
    fn roundtrip() {
        let g = crate::graph::gen::cliques(&crate::graph::gen::CliqueSpec {
            n: 20,
            k: 2,
            max_short_circuit: 3,
            seed: 5,
        })
        .graph;
        let dir = std::env::temp_dir().join("sped_io_test");
        let path = dir.join("g.edges");
        save_edge_list(&g, &path).unwrap();
        let g2 = load_edge_list(&path).unwrap();
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.edges(), g2.edges());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_graph() {
        let g = parse_edge_list("").unwrap();
        assert_eq!(g.num_nodes(), 0);
        assert_eq!(g.num_edges(), 0);
    }
}
