//! Edge-list file I/O.
//!
//! Format: one edge per line, `u v [w]`, `#` comments, blank lines ignored.
//! Node count is `max id + 1` unless a `# nodes: N` header is present.
//!
//! An optional `# order: i0 i1 …` header persists a node ordering
//! (`order[new] = old`, the [`Graph::rcm_permutation`] convention)
//! alongside the graph, so repeated solves on the same file can skip the
//! `O(E log E)` RCM rebuild (`PipelineConfig::rcm_order`). The order is
//! validated as a permutation of `0..n` at parse time.

use super::Graph;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Parse a graph from edge-list text, returning the persisted node order
/// (the `# order:` header) when one is present.
pub fn parse_edge_list_with_order(text: &str) -> Result<(Graph, Option<Vec<usize>>)> {
    let mut edges: Vec<(usize, usize, f64)> = Vec::new();
    let mut edge_lines: Vec<usize> = Vec::new();
    let mut declared_n: Option<usize> = None;
    let mut order: Option<Vec<usize>> = None;
    let mut order_line = 0usize;
    let mut max_id = 0usize;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            if let Some(n) = rest.trim().strip_prefix("nodes:") {
                declared_n = Some(
                    n.trim()
                        .parse()
                        .with_context(|| format!("line {}: bad node count", lineno + 1))?,
                );
            } else if let Some(ids) = rest.trim().strip_prefix("order:") {
                let parsed: Result<Vec<usize>> = ids
                    .split_whitespace()
                    .map(|s| {
                        s.parse::<usize>()
                            .with_context(|| format!("line {}: bad order id {s:?}", lineno + 1))
                    })
                    .collect();
                order = Some(parsed?);
                order_line = lineno + 1;
            }
            continue;
        }
        let mut parts = line.split_whitespace();
        let u: usize = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing u", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad node id", lineno + 1))?;
        let v: usize = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {}: missing v", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad node id", lineno + 1))?;
        let w: f64 = match parts.next() {
            Some(s) => s
                .parse()
                .with_context(|| format!("line {}: bad weight", lineno + 1))?,
            None => 1.0,
        };
        if parts.next().is_some() {
            bail!("line {}: trailing tokens", lineno + 1);
        }
        // Validate each edge where it is written, not deep inside
        // `from_edges` where the line number is gone: a NaN or negative
        // weight, or a self-loop, names the offending line.
        if !w.is_finite() {
            bail!("line {}: non-finite weight {w}", lineno + 1);
        }
        if w < 0.0 {
            bail!("line {}: negative weight {w}", lineno + 1);
        }
        if u == v {
            bail!("line {}: self-loop at node {u}", lineno + 1);
        }
        max_id = max_id.max(u).max(v);
        edges.push((u, v, w));
        edge_lines.push(lineno + 1);
    }
    let n = declared_n.unwrap_or(if edges.is_empty() { 0 } else { max_id + 1 });
    // Out-of-range endpoints only exist relative to a declared node
    // count; report them with the line they came from.
    for (&(u, v, _), &ln) in edges.iter().zip(&edge_lines) {
        if u >= n || v >= n {
            bail!("line {ln}: edge ({u},{v}) out of range for n = {n}");
        }
    }
    let g = Graph::from_edges(n, &edges)?;
    if let Some(ord) = &order {
        // Validate eagerly so a corrupt header fails at load, not deep in
        // the pipeline: must be a permutation of 0..n. A length mismatch
        // is the stale-order signature (order saved for a different edge
        // set), so say so.
        if ord.len() != n {
            bail!(
                "line {order_line}: # order: header has {} ids for n = {n} nodes \
                 (stale order from a mutated graph?)",
                ord.len()
            );
        }
        let mut seen = vec![false; n];
        for &v in ord {
            if v >= n || seen[v] {
                bail!("line {order_line}: # order: header is not a permutation of 0..{n}");
            }
            seen[v] = true;
        }
    }
    Ok((g, order))
}

/// Parse a graph from edge-list text (node order, if any, discarded).
pub fn parse_edge_list(text: &str) -> Result<Graph> {
    Ok(parse_edge_list_with_order(text)?.0)
}

/// Load a graph and its optional persisted node order from a file.
pub fn load_edge_list_with_order<P: AsRef<Path>>(path: P) -> Result<(Graph, Option<Vec<usize>>)> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse_edge_list_with_order(&text)
}

/// Load a graph from an edge-list file.
pub fn load_edge_list<P: AsRef<Path>>(path: P) -> Result<Graph> {
    Ok(load_edge_list_with_order(path)?.0)
}

/// Save a graph as an edge list (with a `# nodes:` header so isolated
/// trailing nodes round-trip), optionally persisting a node ordering
/// (`order[new] = old` — e.g. [`Graph::rcm_permutation`]) as a
/// `# order:` header so later loads skip recomputing it.
pub fn save_edge_list_with_order<P: AsRef<Path>>(
    g: &Graph,
    path: P,
    order: Option<&[usize]>,
) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    if let Some(ord) = order {
        // Refuse to persist an order that cannot belong to this graph —
        // the cheap half of the stale-order guard (callers that mutate a
        // graph after computing an order must refresh or drop it; see
        // `coordinator::stream::StreamSession::save`).
        if ord.len() != g.num_nodes() {
            bail!("order has {} ids for n = {} nodes", ord.len(), g.num_nodes());
        }
        let mut seen = vec![false; g.num_nodes()];
        for &v in ord {
            if v >= g.num_nodes() || seen[v] {
                bail!("order is not a permutation of 0..{}", g.num_nodes());
            }
            seen[v] = true;
        }
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    writeln!(f, "# nodes: {}", g.num_nodes())?;
    if let Some(ord) = order {
        let ids: Vec<String> = ord.iter().map(|v| v.to_string()).collect();
        writeln!(f, "# order: {}", ids.join(" "))?;
    }
    for e in g.edges() {
        if (e.w - 1.0).abs() < 1e-15 {
            writeln!(f, "{} {}", e.u, e.v)?;
        } else {
            writeln!(f, "{} {} {}", e.u, e.v, e.w)?;
        }
    }
    Ok(())
}

/// Save a graph as an edge list without a persisted order.
pub fn save_edge_list<P: AsRef<Path>>(g: &Graph, path: P) -> Result<()> {
    save_edge_list_with_order(g, path, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let g = parse_edge_list("0 1\n1 2 0.5\n# comment\n\n2 3\n").unwrap();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.edges()[1].w, 0.5);
    }

    #[test]
    fn declared_nodes_header() {
        let g = parse_edge_list("# nodes: 10\n0 1\n").unwrap();
        assert_eq!(g.num_nodes(), 10);
    }

    #[test]
    fn errors_on_garbage() {
        assert!(parse_edge_list("0\n").is_err());
        assert!(parse_edge_list("a b\n").is_err());
        assert!(parse_edge_list("0 1 2 3\n").is_err());
    }

    #[test]
    fn rejects_bad_edges_with_line_numbers() {
        for (text, needle) in [
            ("0 1\n1 2 nan\n", "line 2: non-finite weight"),
            ("0 1 inf\n", "line 1: non-finite weight"),
            ("0 1\n\n# pad\n2 3 -0.5\n", "line 4: negative weight"),
            ("0 1\n2 2\n", "line 2: self-loop"),
            ("# nodes: 3\n0 1\n1 5\n", "line 3: edge (1,5) out of range for n = 3"),
        ] {
            let err = format!("{:#}", parse_edge_list(text).unwrap_err());
            assert!(err.contains(needle), "{err:?} missing {needle:?}");
        }
        // Zero weights stay legal (duplicate-merge semantics rely on them).
        assert_eq!(parse_edge_list("0 1 0.0\n").unwrap().num_edges(), 1);
    }

    #[test]
    fn roundtrip() {
        let g = crate::graph::gen::cliques(&crate::graph::gen::CliqueSpec {
            n: 20,
            k: 2,
            max_short_circuit: 3,
            seed: 5,
        })
        .graph;
        let dir = std::env::temp_dir().join("sped_io_test");
        let path = dir.join("g.edges");
        save_edge_list(&g, &path).unwrap();
        let g2 = load_edge_list(&path).unwrap();
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.edges(), g2.edges());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_graph() {
        let g = parse_edge_list("").unwrap();
        assert_eq!(g.num_nodes(), 0);
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn order_header_roundtrips() {
        let g = crate::graph::gen::cliques(&crate::graph::gen::CliqueSpec {
            n: 24,
            k: 3,
            max_short_circuit: 2,
            seed: 7,
        })
        .graph;
        let order = g.rcm_permutation();
        let dir = std::env::temp_dir().join("sped_io_order_test");
        let path = dir.join("g.edges");
        save_edge_list_with_order(&g, &path, Some(&order)).unwrap();
        let (g2, loaded) = load_edge_list_with_order(&path).unwrap();
        assert_eq!(g.edges(), g2.edges());
        assert_eq!(loaded.as_deref(), Some(order.as_slice()));
        // The legacy loader ignores the header transparently.
        let g3 = load_edge_list(&path).unwrap();
        assert_eq!(g.edges(), g3.edges());
        // Saving without an order yields None on load.
        let plain = dir.join("plain.edges");
        save_edge_list(&g, &plain).unwrap();
        assert_eq!(load_edge_list_with_order(&plain).unwrap().1, None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn order_header_validation() {
        // Wrong length.
        assert!(parse_edge_list_with_order("# nodes: 3\n# order: 0 1\n0 1\n").is_err());
        // Duplicate id.
        assert!(parse_edge_list_with_order("# nodes: 3\n# order: 0 0 1\n0 1\n").is_err());
        // Out-of-range id.
        assert!(parse_edge_list_with_order("# nodes: 3\n# order: 0 1 5\n0 1\n").is_err());
        // Garbage id.
        assert!(parse_edge_list_with_order("# nodes: 3\n# order: a b c\n0 1\n").is_err());
        // A valid header parses.
        let (g, ord) = parse_edge_list_with_order("# nodes: 3\n# order: 2 0 1\n0 1\n").unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(ord, Some(vec![2, 0, 1]));
        // Mismatched save is rejected before writing.
        let dir = std::env::temp_dir().join("sped_io_order_bad");
        assert!(save_edge_list_with_order(&g, dir.join("x.edges"), Some(&[0, 1])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
