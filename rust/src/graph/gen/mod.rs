//! Graph generators for the paper's workloads.
//!
//! * [`cliques`] — §5.4: `n` nodes split into `k` cliques joined by a random
//!   number (0–25) of "short-circuit" edges.
//! * [`sbm`] — stochastic block model (Holland et al. 1983; the related-work
//!   setting of Saade et al.).
//! * [`erdos_renyi`], [`grid2d`], [`path`], [`ring`], [`barbell`],
//!   [`ring_of_cliques`] — supporting topologies for tests and ablations.
//!
//! Generators that imply a ground-truth clustering return it as `labels`.

use super::{Edge, Graph};
use crate::util::rng::Rng;

/// A generated graph plus its ground-truth cluster labels (when defined).
#[derive(Clone, Debug)]
pub struct GeneratedGraph {
    pub graph: Graph,
    /// Ground-truth cluster id per node (empty when undefined).
    pub labels: Vec<usize>,
}

/// Parameters for the §5.4 well-clustered clique construction.
#[derive(Clone, Copy, Debug)]
pub struct CliqueSpec {
    /// Total node count; split as evenly as possible across cliques.
    pub n: usize,
    /// Number of cliques.
    pub k: usize,
    /// Max "short-circuit" edges between each pair of cliques (paper: 25).
    pub max_short_circuit: usize,
    pub seed: u64,
}

/// §5.4 generator: `k` cliques connected by `U{0..=max_short_circuit}`
/// random inter-clique edges per clique pair.
pub fn cliques(spec: &CliqueSpec) -> GeneratedGraph {
    assert!(spec.k >= 1 && spec.n >= spec.k, "need n ≥ k ≥ 1");
    let mut rng = Rng::new(spec.seed);
    let mut labels = vec![0usize; spec.n];
    // Split nodes: first (n % k) cliques get one extra node.
    let base = spec.n / spec.k;
    let extra = spec.n % spec.k;
    let mut ranges = Vec::with_capacity(spec.k);
    let mut start = 0;
    for c in 0..spec.k {
        let size = base + usize::from(c < extra);
        ranges.push(start..start + size);
        for v in start..start + size {
            labels[v] = c;
        }
        start += size;
    }
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    // Intra-clique: complete subgraphs.
    for r in &ranges {
        let nodes: Vec<usize> = r.clone().collect();
        for i in 0..nodes.len() {
            for j in (i + 1)..nodes.len() {
                pairs.push((nodes[i], nodes[j]));
            }
        }
    }
    // Inter-clique short circuits.
    for a in 0..spec.k {
        for b in (a + 1)..spec.k {
            let count = rng.below(spec.max_short_circuit + 1);
            for _ in 0..count {
                let u = rng.range(ranges[a].start, ranges[a].end);
                let v = rng.range(ranges[b].start, ranges[b].end);
                pairs.push((u, v));
            }
        }
    }
    let graph = Graph::from_pairs(spec.n, &pairs).expect("valid clique graph");
    GeneratedGraph { graph, labels }
}

/// Stochastic block model: `sizes[c]` nodes per block, edge probability
/// `p_in` within a block and `p_out` across blocks.
pub fn sbm(sizes: &[usize], p_in: f64, p_out: f64, seed: u64) -> GeneratedGraph {
    let n: usize = sizes.iter().sum();
    let mut labels = Vec::with_capacity(n);
    for (c, &s) in sizes.iter().enumerate() {
        labels.extend(std::iter::repeat(c).take(s));
    }
    let mut rng = Rng::new(seed);
    let mut pairs = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let p = if labels[i] == labels[j] { p_in } else { p_out };
            if rng.bernoulli(p) {
                pairs.push((i, j));
            }
        }
    }
    GeneratedGraph { graph: Graph::from_pairs(n, &pairs).unwrap(), labels }
}

/// Erdős–Rényi G(n, p).
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> GeneratedGraph {
    let mut rng = Rng::new(seed);
    let mut pairs = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.bernoulli(p) {
                pairs.push((i, j));
            }
        }
    }
    GeneratedGraph { graph: Graph::from_pairs(n, &pairs).unwrap(), labels: vec![] }
}

/// 2-D 4-connected grid graph `rows × cols`.
pub fn grid2d(rows: usize, cols: usize) -> GeneratedGraph {
    let at = |r: usize, c: usize| r * cols + c;
    let mut pairs = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                pairs.push((at(r, c), at(r, c + 1)));
            }
            if r + 1 < rows {
                pairs.push((at(r, c), at(r + 1, c)));
            }
        }
    }
    GeneratedGraph { graph: Graph::from_pairs(rows * cols, &pairs).unwrap(), labels: vec![] }
}

/// Path graph P_n.
pub fn path(n: usize) -> GeneratedGraph {
    let pairs: Vec<(usize, usize)> = (0..n.saturating_sub(1)).map(|i| (i, i + 1)).collect();
    GeneratedGraph { graph: Graph::from_pairs(n, &pairs).unwrap(), labels: vec![] }
}

/// Cycle graph C_n.
pub fn ring(n: usize) -> GeneratedGraph {
    assert!(n >= 3);
    let mut pairs: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
    pairs.push((n - 1, 0));
    GeneratedGraph { graph: Graph::from_pairs(n, &pairs).unwrap(), labels: vec![] }
}

/// Barbell: two cliques of size `m` joined by a single bridge edge —
/// the canonical tiny-λ₂ example.
pub fn barbell(m: usize) -> GeneratedGraph {
    assert!(m >= 2);
    let mut pairs = Vec::new();
    for i in 0..m {
        for j in (i + 1)..m {
            pairs.push((i, j));
            pairs.push((m + i, m + j));
        }
    }
    pairs.push((m - 1, m));
    let mut labels = vec![0; m];
    labels.extend(std::iter::repeat(1).take(m));
    GeneratedGraph { graph: Graph::from_pairs(2 * m, &pairs).unwrap(), labels }
}

/// Barabási–Albert preferential attachment: seed with a complete graph on
/// `m + 1` nodes, then each new node attaches `m` edges to distinct
/// existing nodes with probability ∝ current degree (sampling uniformly
/// from the edge-endpoint multiset). Produces the power-law degree tail —
/// the workload class where RCM row reordering
/// ([`crate::graph::Graph::rcm_permutation`]) pays off for the sparse
/// solver kernels.
///
/// Streaming construction: the endpoint multiset read pairwise **is** the
/// edge list in generation order, so the CSR is built straight from it by
/// a two-pass counting scatter ([`Graph::from_canonical_edges`]) with no
/// intermediate pair/triple `Vec`s — peak transient memory is the `2E`
/// endpoint multiset plus the `E` canonical edges, which is what lets the
/// `n ≥ 10⁶` power-law benchmarks fit. Bitwise-identical to the historical
/// `from_pairs` path (pinned by the structure test below).
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> GeneratedGraph {
    assert!(m >= 1 && n > m, "need n > m ≥ 1");
    let mut rng = Rng::new(seed);
    // Each edge contributes both endpoints, so a uniform draw from this
    // multiset is exactly degree-proportional sampling.
    let e_total = m * (m + 1) / 2 + (n - m - 1) * m;
    let mut endpoints: Vec<usize> = Vec::with_capacity(2 * e_total);
    for i in 0..=m {
        for j in (i + 1)..=m {
            endpoints.push(i);
            endpoints.push(j);
        }
    }
    let mut chosen: Vec<usize> = Vec::with_capacity(m);
    for v in (m + 1)..n {
        chosen.clear();
        while chosen.len() < m {
            let t = endpoints[rng.below(endpoints.len())];
            if !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &t in &chosen {
            endpoints.push(v);
            endpoints.push(t);
        }
    }
    // Counting scatter into per-source buckets (canonical source = the
    // smaller endpoint), then an in-bucket sort by target. Every generated
    // edge is unique — the seed clique enumerates distinct pairs and each
    // growth step draws `m` *distinct* targets for a fresh `v` — so the
    // result is exactly the strictly-ascending dedup-free edge list
    // `Graph::from_edges` would have produced.
    let mut bucket = vec![0usize; n];
    for p in endpoints.chunks_exact(2) {
        bucket[p[0].min(p[1])] += 1;
    }
    let mut starts = Vec::with_capacity(n + 1);
    starts.push(0usize);
    for i in 0..n {
        starts.push(starts[i] + bucket[i]);
    }
    let mut cursor = starts.clone();
    let mut edges = vec![Edge { u: 0, v: 0, w: 0.0 }; e_total];
    for p in endpoints.chunks_exact(2) {
        let (u, v) = (p[0].min(p[1]), p[0].max(p[1]));
        edges[cursor[u]] = Edge { u: u as u32, v: v as u32, w: 1.0 };
        cursor[u] += 1;
    }
    drop(endpoints);
    for u in 0..n {
        edges[starts[u]..starts[u + 1]].sort_unstable_by_key(|e| e.v);
    }
    let graph = Graph::from_canonical_edges(n, edges)
        .expect("counting scatter yields a canonical edge list");
    GeneratedGraph { graph, labels: vec![] }
}

/// Ring of `k` cliques of size `m`, adjacent cliques joined by one edge.
pub fn ring_of_cliques(k: usize, m: usize, _seed: u64) -> GeneratedGraph {
    assert!(k >= 3 && m >= 2);
    let mut pairs = Vec::new();
    let mut labels = vec![0usize; k * m];
    for c in 0..k {
        let base = c * m;
        for i in 0..m {
            labels[base + i] = c;
            for j in (i + 1)..m {
                pairs.push((base + i, base + j));
            }
        }
        let next = ((c + 1) % k) * m;
        pairs.push((base + m - 1, next));
    }
    GeneratedGraph { graph: Graph::from_pairs(k * m, &pairs).unwrap(), labels }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigh;

    #[test]
    fn cliques_structure() {
        let spec = CliqueSpec { n: 40, k: 4, max_short_circuit: 5, seed: 1 };
        let g = cliques(&spec);
        assert_eq!(g.graph.num_nodes(), 40);
        assert_eq!(g.labels.len(), 40);
        // Each clique of 10 contributes C(10,2)=45 intra edges.
        assert!(g.graph.num_edges() >= 4 * 45);
        // Short circuits bounded: ≤ C(4,2)·5 extra.
        assert!(g.graph.num_edges() <= 4 * 45 + 6 * 5);
        // All 4 labels used.
        let mut seen = g.labels.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen, vec![0, 1, 2, 3]);
    }

    #[test]
    fn cliques_uneven_split() {
        let g = cliques(&CliqueSpec { n: 10, k: 3, max_short_circuit: 0, seed: 2 });
        // Sizes 4,3,3 — zero short circuits → 3 components.
        assert_eq!(g.graph.num_components(), 3);
        let counts = (0..3)
            .map(|c| g.labels.iter().filter(|&&l| l == c).count())
            .collect::<Vec<_>>();
        assert_eq!(counts, vec![4, 3, 3]);
    }

    #[test]
    fn well_clustered_graph_has_small_bottom_eigenvalues() {
        // The premise of the paper: k clusters → k eigenvalues ≪ 1.
        let g = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 7 });
        let l = g.graph.laplacian();
        let e = eigh(&l).unwrap();
        assert!(e.values[0].abs() < 1e-9); // λ₁ = 0 always
        assert!(e.values[1] < 1.0, "λ₂ = {}", e.values[1]);
        assert!(e.values[2] < 1.0, "λ₃ = {}", e.values[2]);
        assert!(e.values[3] > 1.0, "λ₄ = {} should jump", e.values[3]);
    }

    #[test]
    fn sbm_respects_block_structure() {
        let g = sbm(&[20, 20], 0.9, 0.02, 3);
        assert_eq!(g.graph.num_nodes(), 40);
        let intra = g
            .graph
            .edges()
            .iter()
            .filter(|e| g.labels[e.u as usize] == g.labels[e.v as usize])
            .count();
        let inter = g.graph.num_edges() - intra;
        assert!(intra > 5 * inter, "intra={intra} inter={inter}");
    }

    #[test]
    fn grid_and_path_and_ring_counts() {
        assert_eq!(grid2d(3, 4).graph.num_edges(), 3 * 3 + 2 * 4);
        assert_eq!(path(5).graph.num_edges(), 4);
        assert_eq!(ring(5).graph.num_edges(), 5);
        assert_eq!(ring(5).graph.num_components(), 1);
    }

    #[test]
    fn barbell_bottleneck() {
        let g = barbell(6);
        assert_eq!(g.graph.num_nodes(), 12);
        assert_eq!(g.graph.num_edges(), 2 * 15 + 1);
        let e = eigh(&g.graph.laplacian()).unwrap();
        // λ₂ is tiny relative to λ_max — the eigengap problem in miniature.
        assert!(e.values[1] / e.lambda_max() < 0.05);
    }

    #[test]
    fn ring_of_cliques_connected() {
        let g = ring_of_cliques(4, 5, 0);
        assert_eq!(g.graph.num_nodes(), 20);
        assert_eq!(g.graph.num_components(), 1);
    }

    #[test]
    fn barabasi_albert_structure() {
        let g = barabasi_albert(200, 3, 5);
        assert_eq!(g.graph.num_nodes(), 200);
        // Seed clique C(4,2)=6 edges + 3 per subsequent node.
        assert_eq!(g.graph.num_edges(), 6 + 196 * 3);
        assert_eq!(g.graph.num_components(), 1);
        // Power-law tail: the max degree dwarfs the mean (2·E/n ≈ 6).
        assert!(g.graph.max_degree() >= 15, "max degree {}", g.graph.max_degree());
        // Deterministic per seed.
        assert_eq!(g.graph.edges(), barabasi_albert(200, 3, 5).graph.edges());
        assert_valid(&g.graph);
    }

    #[test]
    fn barabasi_albert_streamed_build_matches_from_pairs() {
        // Replay the generator's exact RNG walk into the historical
        // pairs + from_pairs path: the streamed counting-scatter build
        // must reproduce it bitwise (edge order included).
        let (n, m, seed) = (150usize, 3usize, 9u64);
        let mut rng = Rng::new(seed);
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        let mut endpoints: Vec<usize> = Vec::new();
        for i in 0..=m {
            for j in (i + 1)..=m {
                pairs.push((i, j));
                endpoints.push(i);
                endpoints.push(j);
            }
        }
        for v in (m + 1)..n {
            let mut chosen: Vec<usize> = Vec::with_capacity(m);
            while chosen.len() < m {
                let t = endpoints[rng.below(endpoints.len())];
                if !chosen.contains(&t) {
                    chosen.push(t);
                }
            }
            for &t in &chosen {
                pairs.push((v, t));
                endpoints.push(v);
                endpoints.push(t);
            }
        }
        let historical = Graph::from_pairs(n, &pairs).unwrap();
        let streamed = barabasi_albert(n, m, seed);
        assert_eq!(historical.edges(), streamed.graph.edges());
        for v in 0..n {
            assert_eq!(historical.neighbors(v), streamed.graph.neighbors(v), "node {v}");
        }
    }

    fn assert_valid(g: &Graph) {
        let e = eigh(&g.laplacian()).unwrap();
        assert!(e.values[0] > -1e-9);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = cliques(&CliqueSpec { n: 30, k: 3, max_short_circuit: 8, seed: 11 });
        let b = cliques(&CliqueSpec { n: 30, k: 3, max_short_circuit: 8, seed: 11 });
        assert_eq!(a.graph.num_edges(), b.graph.num_edges());
        assert_eq!(a.graph.edges(), b.graph.edges());
    }

    #[test]
    fn property_laplacian_psd_over_generators() {
        use crate::testkit::{check, SizeGen};
        check(21, 8, &SizeGen { lo: 8, hi: 40 }, |&n| {
            let g = cliques(&CliqueSpec { n, k: (n / 8).max(1), max_short_circuit: 3, seed: n as u64 });
            let e = eigh(&g.graph.laplacian()).unwrap();
            // PSD + ones-vector kernel.
            e.values[0] > -1e-9 && e.values[0].abs() < 1e-9
        });
    }
}
