//! Property-based testing mini-framework (offline stand-in for `proptest`).
//!
//! A property is a closure over values drawn from a [`Gen`]; the runner
//! executes `cases` random trials and, on failure, greedily **shrinks** the
//! failing input before reporting. Generators compose with `map`/`filter`
//! and tuple helpers. Used across the crate's test suites for invariants
//! such as "every generated Laplacian is PSD with row sums 0" or "walk
//! acceptance probabilities are in (0, 1]".

use crate::util::rng::Rng;

/// A value generator: produces a random instance and can propose shrinks.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate simpler values (tried in order during shrinking).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Integers in `[lo, hi]` shrinking toward `lo`.
pub struct IntGen {
    pub lo: i64,
    pub hi: i64,
}

impl Gen for IntGen {
    type Value = i64;
    fn generate(&self, rng: &mut Rng) -> i64 {
        self.lo + rng.below((self.hi - self.lo + 1) as usize) as i64
    }
    fn shrink(&self, v: &i64) -> Vec<i64> {
        let mut out = Vec::new();
        if *v != self.lo {
            out.push(self.lo);
            let mid = self.lo + (*v - self.lo) / 2;
            if mid != *v && mid != self.lo {
                out.push(mid);
            }
            if *v - 1 >= self.lo {
                out.push(*v - 1);
            }
        }
        out
    }
}

/// `usize` in `[lo, hi]` shrinking toward `lo`.
pub struct SizeGen {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for SizeGen {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range(self.lo, self.hi + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            let mid = self.lo + (*v - self.lo) / 2;
            if mid != *v && mid != self.lo {
                out.push(mid);
            }
            out.push(*v - 1);
        }
        out
    }
}

/// Uniform floats in `[lo, hi)` shrinking toward zero / lo.
pub struct FloatGen {
    pub lo: f64,
    pub hi: f64,
}

impl Gen for FloatGen {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.uniform(self.lo, self.hi)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if self.lo <= 0.0 && 0.0 <= *v && *v != 0.0 {
            out.push(0.0);
        }
        if *v != self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2.0);
        }
        out
    }
}

/// Vectors of a base generator with length in `[min_len, max_len]`;
/// shrinks by halving length, then element-wise.
pub struct VecGen<G: Gen> {
    pub elem: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<G::Value> {
        let len = rng.range(self.min_len, self.max_len + 1);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // Halve, drop-first, drop-last.
            let half = self.min_len.max(v.len() / 2);
            out.push(v[..half].to_vec());
            out.push(v[1..].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        // Shrink one element at a time (first few positions only — cheap).
        for i in 0..v.len().min(4) {
            for s in self.elem.shrink(&v[i]) {
                let mut w = v.clone();
                w[i] = s;
                out.push(w);
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairGen<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(a).into_iter().map(|a2| (a2, b.clone())).collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

/// Mapped generator (no shrinking through the map).
pub struct MapGen<G: Gen, T, F: Fn(G::Value) -> T> {
    pub base: G,
    pub f: F,
}

impl<G: Gen, T: Clone + std::fmt::Debug, F: Fn(G::Value) -> T> Gen for MapGen<G, T, F> {
    type Value = T;
    fn generate(&self, rng: &mut Rng) -> T {
        (self.f)(self.base.generate(rng))
    }
}

/// Outcome of a property check.
pub enum PropResult {
    Pass,
    Fail(String),
}

impl From<bool> for PropResult {
    fn from(ok: bool) -> Self {
        if ok {
            PropResult::Pass
        } else {
            PropResult::Fail("property returned false".into())
        }
    }
}

impl From<Result<(), String>> for PropResult {
    fn from(r: Result<(), String>) -> Self {
        match r {
            Ok(()) => PropResult::Pass,
            Err(e) => PropResult::Fail(e),
        }
    }
}

/// Run `cases` random trials of `prop` on values from `gen`, shrinking any
/// failure. Panics with the minimal counterexample.
pub fn check<G, P, R>(seed: u64, cases: usize, gen: &G, prop: P)
where
    G: Gen,
    P: Fn(&G::Value) -> R,
    R: Into<PropResult>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if let PropResult::Fail(msg) = prop(&value).into() {
            let (min_value, min_msg, steps) = shrink_failure(gen, &prop, value, msg);
            panic!(
                "property failed (case {case}/{cases}, {steps} shrink steps)\n  input: {min_value:?}\n  error: {min_msg}"
            );
        }
    }
}

fn shrink_failure<G, P, R>(gen: &G, prop: &P, mut value: G::Value, mut msg: String) -> (G::Value, String, usize)
where
    G: Gen,
    P: Fn(&G::Value) -> R,
    R: Into<PropResult>,
{
    let mut steps = 0;
    'outer: loop {
        if steps > 200 {
            break;
        }
        for cand in gen.shrink(&value) {
            if let PropResult::Fail(m) = prop(&cand).into() {
                value = cand;
                msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (value, msg, steps)
}

/// Helper: assert two floats are close (absolute + relative tolerance),
/// returning a `Result` usable inside properties.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 100, &IntGen { lo: 0, hi: 100 }, |&x| x >= 0);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(2, 100, &IntGen { lo: 0, hi: 100 }, |&x| x < 90);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        let result = std::panic::catch_unwind(|| {
            check(3, 200, &IntGen { lo: 0, hi: 1000 }, |&x| x < 500);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The minimal failing input for x < 500 is exactly 500.
        assert!(msg.contains("input: 500"), "msg: {msg}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecGen { elem: IntGen { lo: -5, hi: 5 }, min_len: 2, max_len: 8 };
        check(4, 100, &g, |v: &Vec<i64>| {
            v.len() >= 2 && v.len() <= 8 && v.iter().all(|&x| (-5..=5).contains(&x))
        });
    }

    #[test]
    fn pair_gen_and_close() {
        let g = PairGen(FloatGen { lo: 0.1, hi: 2.0 }, FloatGen { lo: 0.1, hi: 2.0 });
        check(5, 100, &g, |&(a, b)| close((a * b).ln(), a.ln() + b.ln(), 1e-9));
    }
}
