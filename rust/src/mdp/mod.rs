//! MDP substrate: the 3-room grid world of §5.3 / Figure 1 and
//! proto-value functions (Mahadevan 2005).
//!
//! Geometry (paper): the world is `10s+1` cells tall and `30s+1` cells wide,
//! three rooms separated by two interior walls; each wall has a doorway
//! occupying `1/h` of the vertical space (`(10s+1)/h` cells tall), centered
//! vertically. States are free cells; undirected edges connect 4-adjacent
//! free cells (both transition directions, as the paper notes).
//!
//! Proto-value functions are the bottom-k eigenvectors of the state-graph
//! Laplacian; [`pvf_value_fit`] demonstrates the downstream use: least-
//! squares fitting of a value function in the PVF basis.

use crate::graph::Graph;
use crate::linalg::dmat::DMat;
use anyhow::Result;

/// 3-room grid world (Figure 1). `s` scales the geometry; `h` controls the
/// door height fraction.
#[derive(Clone, Copy, Debug)]
pub struct ThreeRoomSpec {
    pub s: usize,
    pub h: usize,
}

impl Default for ThreeRoomSpec {
    fn default() -> Self {
        // Paper's Figure 1 uses s=2, h=10; s=1 is the single-core-friendly
        // default (341 → 321 free cells).
        ThreeRoomSpec { s: 1, h: 10 }
    }
}

/// Built grid world: the state graph plus the cell geometry.
#[derive(Clone, Debug)]
pub struct GridWorld {
    pub spec: ThreeRoomSpec,
    pub rows: usize,
    pub cols: usize,
    /// `cell_state[r][c]` = Some(state-id) for free cells, None for walls.
    pub cell_state: Vec<Vec<Option<usize>>>,
    /// (row, col) of each state.
    pub coords: Vec<(usize, usize)>,
    pub graph: Graph,
}

impl GridWorld {
    /// Build the 3-room world.
    pub fn three_rooms(spec: ThreeRoomSpec) -> Result<GridWorld> {
        anyhow::ensure!(spec.s >= 1 && spec.h >= 1, "need s ≥ 1, h ≥ 1");
        let rows = 10 * spec.s + 1;
        let cols = 30 * spec.s + 1;
        // Interior walls at the two columns splitting the width in thirds.
        let wall_cols = [cols / 3, 2 * cols / 3];
        // Door: (10s+1)/h cells tall (≥1), vertically centered.
        let door_h = (rows / spec.h).max(1);
        let door_top = (rows - door_h) / 2;
        let door_rows = door_top..door_top + door_h;
        let mut cell_state = vec![vec![None; cols]; rows];
        let mut coords = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let is_wall = wall_cols.contains(&c) && !door_rows.contains(&r);
                if !is_wall {
                    cell_state[r][c] = Some(coords.len());
                    coords.push((r, c));
                }
            }
        }
        // 4-adjacency among free cells.
        let mut pairs = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if let Some(a) = cell_state[r][c] {
                    if c + 1 < cols {
                        if let Some(b) = cell_state[r][c + 1] {
                            pairs.push((a, b));
                        }
                    }
                    if r + 1 < rows {
                        if let Some(b) = cell_state[r + 1][c] {
                            pairs.push((a, b));
                        }
                    }
                }
            }
        }
        let graph = Graph::from_pairs(coords.len(), &pairs)?;
        Ok(GridWorld { spec, rows, cols, cell_state, coords, graph })
    }

    pub fn num_states(&self) -> usize {
        self.coords.len()
    }

    /// Room index (0, 1, 2) of a state by its column.
    pub fn room_of(&self, state: usize) -> usize {
        let (_, c) = self.coords[state];
        let w1 = self.cols / 3;
        let w2 = 2 * self.cols / 3;
        if c < w1 {
            0
        } else if c < w2 {
            1
        } else {
            2
        }
    }

    /// ASCII rendering (Figure 1): `#` wall, `.` free.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity((self.cols + 1) * self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.push(if self.cell_state[r][c].is_some() { '.' } else { '#' });
            }
            out.push('\n');
        }
        out
    }

    /// Overlay a per-state scalar field (e.g. a PVF) on the grid as
    /// quantized characters (space=low … '@'=high), walls as '#'.
    pub fn render_field(&self, field: &[f64]) -> String {
        assert_eq!(field.len(), self.num_states());
        let (lo, hi) = field
            .iter()
            .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
        let ramp: &[u8] = b" .:-=+*%@";
        let mut out = String::new();
        for r in 0..self.rows {
            for c in 0..self.cols {
                match self.cell_state[r][c] {
                    None => out.push('#'),
                    Some(s) => {
                        let t = if hi > lo { (field[s] - lo) / (hi - lo) } else { 0.5 };
                        let idx = ((t * (ramp.len() - 1) as f64).round() as usize)
                            .min(ramp.len() - 1);
                        out.push(ramp[idx] as char);
                    }
                }
            }
            out.push('\n');
        }
        out
    }
}

/// Proto-value functions: the bottom-`k` eigenvectors of the state-graph
/// Laplacian (exact, via the dense eigensolver — the oracle the SPED
/// pipeline accelerates).
pub fn proto_value_functions(world: &GridWorld, k: usize) -> Result<DMat> {
    let l = world.graph.laplacian();
    let e = crate::linalg::eigh(&l)?;
    Ok(e.bottom_k(k))
}

/// Least-squares fit of a target value function in the PVF basis; returns
/// (fitted values, normalized RMSE). Demonstrates the §5.3 use case.
pub fn pvf_value_fit(basis: &DMat, target: &[f64]) -> (Vec<f64>, f64) {
    let (n, k) = (basis.rows(), basis.cols());
    assert_eq!(target.len(), n);
    // Basis columns are orthonormal → coefficients = Bᵀ t.
    let coeffs = crate::linalg::matmul::gemv_t(basis, target);
    let fitted = crate::linalg::matmul::gemv(basis, &coeffs);
    let mut err = 0.0;
    let mut scale = 0.0;
    for i in 0..n {
        err += (fitted[i] - target[i]).powi(2);
        scale += target[i].powi(2);
    }
    let _ = k;
    (fitted, (err / scale.max(1e-300)).sqrt())
}

/// Simple value function for demos: negated shortest-path distance (BFS) to
/// a goal state under the random-walk MDP.
pub fn negative_distance_value(world: &GridWorld, goal: usize) -> Vec<f64> {
    let n = world.num_states();
    let mut dist = vec![usize::MAX; n];
    let mut q = std::collections::VecDeque::new();
    dist[goal] = 0;
    q.push_back(goal);
    while let Some(v) = q.pop_front() {
        for &(u, _) in world.graph.neighbors(v) {
            if dist[u as usize] == usize::MAX {
                dist[u as usize] = dist[v] + 1;
                q.push_back(u as usize);
            }
        }
    }
    dist.into_iter()
        .map(|d| if d == usize::MAX { -1e9 } else { -(d as f64) })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigh;

    #[test]
    fn geometry_matches_paper() {
        // Figure 1's caption: s=2, h=10 → 21 × 61 grid.
        let w = GridWorld::three_rooms(ThreeRoomSpec { s: 2, h: 10 }).unwrap();
        assert_eq!(w.rows, 21);
        assert_eq!(w.cols, 61);
        // Two wall columns minus the door cells.
        let door_h = (21 / 10).max(1); // 2
        let expected_states = 21 * 61 - 2 * (21 - door_h);
        assert_eq!(w.num_states(), expected_states);
    }

    #[test]
    fn default_world_connected() {
        let w = GridWorld::three_rooms(ThreeRoomSpec::default()).unwrap();
        assert_eq!(w.graph.num_components(), 1, "doors must connect rooms");
        assert_eq!(w.rows, 11);
        assert_eq!(w.cols, 31);
    }

    #[test]
    fn rooms_partition_states() {
        let w = GridWorld::three_rooms(ThreeRoomSpec::default()).unwrap();
        let mut counts = [0usize; 3];
        for s in 0..w.num_states() {
            counts[w.room_of(s)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0));
        // Rooms roughly equal size.
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min < 1.3, "{counts:?}");
    }

    #[test]
    fn render_shows_walls_and_door() {
        let w = GridWorld::three_rooms(ThreeRoomSpec::default()).unwrap();
        let pic = w.render();
        let lines: Vec<&str> = pic.lines().collect();
        assert_eq!(lines.len(), 11);
        // Top row contains wall characters at the wall columns.
        assert_eq!(&lines[0][10..11], "#");
        // Middle row is all free (door).
        assert!(!lines[5].contains('#'));
    }

    #[test]
    fn pvf_first_is_constant_second_separates_rooms() {
        let w = GridWorld::three_rooms(ThreeRoomSpec::default()).unwrap();
        let pvf = proto_value_functions(&w, 4).unwrap();
        // First PVF = constant (kernel of L).
        let c0 = pvf.col(0);
        let spread = c0.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| {
            (l.min(x), h.max(x))
        });
        assert!((spread.1 - spread.0).abs() < 1e-6, "first PVF not constant");
        // Second PVF (Fiedler) separates room 0 from room 2 by sign.
        let c1 = pvf.col(1);
        let avg_room: Vec<f64> = (0..3)
            .map(|room| {
                let (mut s, mut n) = (0.0, 0);
                for st in 0..w.num_states() {
                    if w.room_of(st) == room {
                        s += c1[st];
                        n += 1;
                    }
                }
                s / n as f64
            })
            .collect();
        assert!(
            avg_room[0] * avg_room[2] < 0.0,
            "Fiedler vector must split outer rooms: {avg_room:?}"
        );
    }

    #[test]
    fn spectrum_has_three_small_eigenvalues() {
        // 3 rooms → 3 eigenvalues ≪ rest (the paper's premise for Fig 2).
        let w = GridWorld::three_rooms(ThreeRoomSpec::default()).unwrap();
        let e = eigh(&w.graph.laplacian()).unwrap();
        assert!(e.values[0].abs() < 1e-9);
        assert!(e.values[1] < 0.02, "λ₂ = {}", e.values[1]);
        assert!(e.values[2] < 0.05, "λ₃ = {}", e.values[2]);
        assert!(e.values[3] > 2.0 * e.values[2], "λ₄ = {} no jump", e.values[3]);
    }

    #[test]
    fn value_fit_improves_with_basis_size() {
        let w = GridWorld::three_rooms(ThreeRoomSpec::default()).unwrap();
        let goal = w.num_states() / 2;
        let target = negative_distance_value(&w, goal);
        let errs: Vec<f64> = [2usize, 8, 24]
            .iter()
            .map(|&k| {
                let basis = proto_value_functions(&w, k).unwrap();
                pvf_value_fit(&basis, &target).1
            })
            .collect();
        assert!(errs[1] < errs[0] && errs[2] < errs[1], "{errs:?}");
        assert!(errs[2] < 0.2, "24 PVFs should fit well: {errs:?}");
    }

    #[test]
    fn render_field_runs() {
        let w = GridWorld::three_rooms(ThreeRoomSpec::default()).unwrap();
        let pvf = proto_value_functions(&w, 2).unwrap();
        let pic = w.render_field(&pvf.col(1));
        assert_eq!(pic.lines().count(), w.rows);
    }

    #[test]
    fn invalid_spec_rejected() {
        assert!(GridWorld::three_rooms(ThreeRoomSpec { s: 0, h: 10 }).is_err());
    }
}
