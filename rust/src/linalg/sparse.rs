//! Sparse (CSR) matrices and the matrix-free operator kernels.
//!
//! The paper's premise (§4) is that the dilated operator `M = λ*I − p(L)`
//! never needs to exist as a dense matrix: iterative solvers only consume
//! products `M·V`, and each such product is `deg(p)` sparse multiplies
//! against the Laplacian — `O(ℓ·nnz·k)` work instead of the `O(ℓ·n³)`
//! dense build plus `O(n²·k)` per step. This module supplies the substrate:
//!
//! * [`CsrMat`] — compressed sparse rows, columns sorted strictly
//!   ascending within each row (built from [`crate::graph::Graph`] via
//!   `laplacian_csr` / `normalized_laplacian_csr`, which reuse the
//!   already-sorted CSR adjacency arrays).
//! * [`spmm`] — sparse × dense-bundle multiply, row-sharded across
//!   `util::pool` workers. Bundle widths `k ≤ 16` (the solver's skinny
//!   regime) dispatch to a register-blocked kernel family — one
//!   monomorphized inner loop per width that accumulates all `k` columns
//!   in a `[f64; K]` register array while sweeping each CSR row's
//!   nonzeros once ([`spmm_streaming`] keeps the generic streaming kernel
//!   callable as the reference).
//! * [`spmm_step_into`] — the **fused solver-step kernel**: one pass over
//!   the bundle computing `C = α·W + β·(A·W) + γ·U`, the exact shape of
//!   every polynomial-operator recurrence step (Horner's
//!   `B·R + c_i·V`, the NegPower `(I − L/ℓ)·W`, and the Chebyshev
//!   three-term `2Y·T_j − T_{j−1}`). Replaces the three-pass
//!   SpMM + `scale` + `axpy` composition — same register-blocked kernel
//!   family, ~⅓ the bundle memory traffic, bit-for-bit the same result.
//! * [`spmv`], [`power_lambda_max_csr`] — sparse matrix–vector product and
//!   the λ_max power iteration on top of it (the dense-free replacement for
//!   `linalg::funcs::power_lambda_max` in operator construction).
//!
//! ## Determinism contract
//!
//! Same contract as [`super::par`]: output is **bitwise identical** to the
//! serial path for every worker count, because shards partition output rows
//! and each row is an independent reduction executed by the one shared
//! row-range kernel.
//!
//! ## Bitwise compatibility with the dense kernels
//!
//! [`spmm`] is additionally bitwise identical to `matmul(A_dense, B)` when
//! `A_dense` is the densification of the CSR matrix. Both kernels reduce
//! each output element over the contribution index `k` in ascending order
//! and skip zero-valued `A` entries, so the floating-point operation
//! sequence per output element is the same — the property the
//! generator-sweep tests in `tests/properties.rs` pin down.

use super::dmat::DMat;
use super::par::{row_shards, shard_starts};
use crate::util::pool::parallel_shards;

/// A sparse matrix in compressed-sparse-row form.
///
/// Invariants (validated on construction): `indptr` has `rows + 1`
/// monotonically non-decreasing entries ending at `nnz`; within each row
/// the column `indices` are strictly increasing and `< cols`. Values may be
/// zero (structural entries such as an isolated node's Laplacian diagonal
/// are kept so in-place diagonal edits stay O(1) per row).
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMat {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl CsrMat {
    /// Build from raw CSR arrays, validating the invariants.
    pub fn new(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> CsrMat {
        let m = CsrMat { rows, cols, indptr, indices, values };
        m.assert_valid();
        m
    }

    /// The full invariant validation pass `new` runs: indptr shape and
    /// monotonicity, strictly increasing (hence duplicate-free) column
    /// indices within each row, and columns `< cols`. Panic messages name
    /// the offending row. Exposed so build paths that patch the arrays in
    /// place (e.g. `graph::delta`) can re-check the invariants they are
    /// responsible for preserving.
    pub fn assert_valid(&self) {
        assert_eq!(self.indptr.len(), self.rows + 1, "indptr length");
        assert_eq!(self.indptr[0], 0, "indptr must start at 0");
        assert_eq!(*self.indptr.last().unwrap(), self.indices.len(), "indptr must end at nnz");
        assert_eq!(self.indices.len(), self.values.len(), "indices/values length mismatch");
        for r in 0..self.rows {
            assert!(self.indptr[r] <= self.indptr[r + 1], "indptr not monotone at row {r}");
            let row = &self.indices[self.indptr[r]..self.indptr[r + 1]];
            for w in row.windows(2) {
                assert!(w[0] < w[1], "row {r}: columns not strictly increasing");
            }
            if let Some(&last) = row.last() {
                assert!((last as usize) < self.cols, "row {r}: column {last} out of range");
            }
        }
    }

    /// [`Self::assert_valid`] in debug builds only — the form the in-place
    /// patching hot paths call per batch (release builds skip the `O(nnz)`
    /// sweep).
    #[inline]
    pub fn debug_assert_valid(&self) {
        if cfg!(debug_assertions) {
            self.assert_valid();
        }
    }

    /// Build from `(row, col, value)` triplets; duplicates have their
    /// values summed (in triplet-sorted order), rows come out sorted.
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f64)]) -> CsrMat {
        let mut t: Vec<(usize, usize, f64)> = triplets.to_vec();
        t.sort_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<u32> = Vec::with_capacity(t.len());
        let mut values: Vec<f64> = Vec::with_capacity(t.len());
        let mut entries: Vec<(usize, usize, f64)> = Vec::with_capacity(t.len());
        for (r, c, v) in t {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of range");
            match entries.last_mut() {
                Some(last) if last.0 == r && last.1 == c => last.2 += v,
                _ => entries.push((r, c, v)),
            }
        }
        for &(r, c, v) in &entries {
            indptr[r + 1] += 1;
            indices.push(c as u32);
            values.push(v);
        }
        for r in 0..rows {
            indptr[r + 1] += indptr[r];
        }
        CsrMat::new(rows, cols, indptr, indices, values)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }
    /// Number of stored entries (structural zeros included).
    pub fn nnz(&self) -> usize {
        self.values.len()
    }
    pub fn indptr(&self) -> &[usize] {
        &self.indptr
    }
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// One row as parallel `(columns, values)` slices.
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let span = self.indptr[i]..self.indptr[i + 1];
        (&self.indices[span.clone()], &self.values[span])
    }

    /// Scale every stored value in place (`A ← a·A`).
    pub fn scale_values(&mut self, a: f64) {
        for v in &mut self.values {
            *v *= a;
        }
    }

    /// Add `delta` to every *structurally present* diagonal entry. Panics
    /// if some diagonal entry is missing (the graph CSR builders always
    /// store the full diagonal).
    pub fn add_diag(&mut self, delta: f64) {
        assert!(self.is_square());
        for i in 0..self.rows {
            let span = self.indptr[i]..self.indptr[i + 1];
            let row_cols = &self.indices[span.clone()];
            let pos = row_cols
                .binary_search(&(i as u32))
                .unwrap_or_else(|_| panic!("row {i} has no stored diagonal"));
            self.values[span.start + pos] += delta;
        }
    }

    /// Densify (tests, small problems, diagnostics).
    pub fn to_dense(&self) -> DMat {
        let mut m = DMat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                m[(i, j as usize)] = v;
            }
        }
        m
    }

    /// Gershgorin upper bound on the spectral radius (symmetric matrices):
    /// `max_i Σ_j |a_ij|`. Sparse counterpart of
    /// [`crate::linalg::funcs::gershgorin_bound`].
    pub fn gershgorin_bound(&self) -> f64 {
        (0..self.rows)
            .map(|i| self.row(i).1.iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    /// Guaranteed two-sided Gershgorin eigenvalue interval
    /// `[min_i (a_ii − r_i), max_i (a_ii + r_i)]`, `r_i = Σ_{j≠i} |a_ij|`
    /// (a missing structural diagonal counts as 0). Bitwise identical to
    /// [`crate::linalg::funcs::gershgorin_interval`] on the densified
    /// matrix: the off-diagonal radius accumulates the stored entries in
    /// the same ascending-column order, and the dense path's extra zero
    /// entries contribute exact `+0.0` terms.
    pub fn gershgorin_interval(&self) -> (f64, f64) {
        assert!(self.is_square(), "gershgorin_interval needs a square matrix");
        if self.rows == 0 {
            return (0.0, 0.0);
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            let mut diag = 0.0;
            let mut radius = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                if c as usize == i {
                    diag = v;
                } else {
                    radius += v.abs();
                }
            }
            lo = lo.min(diag - radius);
            hi = hi.max(diag + radius);
        }
        (lo, hi)
    }

    /// Fraction of stored entries relative to a dense matrix.
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }
}

/// The one sparse row-accumulation primitive: visit every stored entry
/// `(value, column)` of row `i` in ascending-column CSR order, skipping
/// zero values to match the dense kernels' `aik == 0.0` skip. Every sparse
/// kernel in this module — streaming SpMM, the register-blocked SpMM
/// family, SpMV, and the [`super::simd`] backend — reduces through this
/// helper, so there is exactly one reference semantics (entry order + zero
/// skip) for the bitwise contracts to pin down.
#[inline(always)]
pub(crate) fn for_each_nonzero(a: &CsrMat, i: usize, mut visit: impl FnMut(f64, usize)) {
    for idx in a.indptr[i]..a.indptr[i + 1] {
        let v = a.values[idx];
        if v == 0.0 {
            continue;
        }
        visit(v, a.indices[idx] as usize);
    }
}

/// Streaming row-range SpMM kernel: C rows `r0..r1` into `c_rows` (a buffer
/// holding exactly those rows), accumulating through memory one contiguous
/// axpy per nonzero. Handles any bundle width; the reference semantics the
/// blocked kernels must match bitwise.
fn spmm_row_range_streaming(a: &CsrMat, b: &DMat, c_rows: &mut [f64], r0: usize, r1: usize) {
    let n = b.cols();
    debug_assert_eq!(a.cols, b.rows());
    debug_assert_eq!(c_rows.len(), (r1 - r0) * n);
    c_rows.fill(0.0);
    let bd = b.data();
    for i in r0..r1 {
        let crow = &mut c_rows[(i - r0) * n..(i - r0 + 1) * n];
        for_each_nonzero(a, i, |v, j| {
            let brow = &bd[j * n..(j + 1) * n];
            // contiguous axpy: crow += v * brow
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += v * bv;
            }
        });
    }
}

/// Register-blocked row-range SpMM kernel for a fixed bundle width `K`
/// (monomorphized per width, mirroring `matmul_skinny_range`'s split): all
/// `K` output columns of a row accumulate in a `[f64; K]` register array
/// across the whole nonzero sweep, so each CSR entry is loaded once and C
/// is written once per row instead of read-modify-written per nonzero.
///
/// Bitwise identical to [`spmm_row_range_streaming`]: per output element
/// the floating-point reduction is the same CSR-order, zero-skipping
/// sequence (via [`for_each_nonzero`]) — only the residence of the
/// accumulator changes.
fn spmm_row_range_blocked<const K: usize>(
    a: &CsrMat,
    b: &DMat,
    c_rows: &mut [f64],
    r0: usize,
    r1: usize,
) {
    debug_assert_eq!(b.cols(), K);
    debug_assert_eq!(a.cols, b.rows());
    debug_assert_eq!(c_rows.len(), (r1 - r0) * K);
    let bd = b.data();
    for i in r0..r1 {
        let mut acc = [0.0f64; K];
        for_each_nonzero(a, i, |v, j| {
            let brow: &[f64; K] = bd[j * K..(j + 1) * K].try_into().unwrap();
            for t in 0..K {
                acc[t] += v * brow[t];
            }
        });
        c_rows[(i - r0) * K..(i - r0 + 1) * K].copy_from_slice(&acc);
    }
}

/// A row-range SpMM kernel (the unit of work the serial and sharded
/// dispatch paths share).
pub(crate) type RowRangeKernel = fn(&CsrMat, &DMat, &mut [f64], usize, usize);

/// Kernel selection by bundle width: a monomorphized register-blocked
/// kernel for each k ∈ 1..=16 (the solver's `k ≤ 16` skinny regime, same
/// split as the dense `matmul_skinny_range`), streaming above that. Under
/// `--features simd` the blocked widths come from the [`super::simd`]
/// portable-SIMD family instead — bitwise-identical, so callers cannot
/// observe which backend the build selected except through throughput.
pub(crate) fn kernel_for_width(k: usize) -> RowRangeKernel {
    if let Some(kernel) = super::simd::spmm_kernel(k) {
        return kernel;
    }
    macro_rules! blocked_widths {
        ($($w:literal),*) => {
            match k {
                $($w => spmm_row_range_blocked::<$w>,)*
                _ => spmm_row_range_streaming,
            }
        };
    }
    blocked_widths!(1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16)
}

/// `C = A · B` for sparse `A` and a dense bundle `B`, with output rows
/// sharded across `threads` workers. `O(nnz · B.cols())`. Dispatches to a
/// register-blocked kernel for `B.cols() ≤ 16`, streaming otherwise.
///
/// Bitwise identical to the serial path for every worker count, bitwise
/// identical to [`spmm_streaming`] for every bundle width, and bitwise
/// identical to [`super::matmul::matmul`]`(A.to_dense(), B)`.
pub fn spmm(a: &CsrMat, b: &DMat, threads: usize) -> DMat {
    let mut c = DMat::zeros(a.rows, b.cols());
    spmm_into(a, b, &mut c, threads);
    c
}

/// [`spmm`] into an existing buffer (`C` is overwritten) — the
/// allocation-free form the solver hot loop ping-pongs between two
/// preallocated bundles (ℓ SpMMs per operator apply would otherwise mean
/// ℓ fresh `n×k` allocations per solver step).
pub fn spmm_into(a: &CsrMat, b: &DMat, c: &mut DMat, threads: usize) {
    spmm_into_with(a, b, c, threads, kernel_for_width(b.cols()));
}

/// [`spmm`] forced onto the streaming kernel for every bundle width — the
/// reference implementation the blocked family is tested and benchmarked
/// against (`tests/kernel_equivalence.rs`, the `perf_hotpath`
/// blocked-vs-streaming group). Production callers want [`spmm`].
pub fn spmm_streaming(a: &CsrMat, b: &DMat, threads: usize) -> DMat {
    let mut c = DMat::zeros(a.rows, b.cols());
    spmm_streaming_into(a, b, &mut c, threads);
    c
}

/// [`spmm_streaming`] into an existing buffer.
pub fn spmm_streaming_into(a: &CsrMat, b: &DMat, c: &mut DMat, threads: usize) {
    spmm_into_with(a, b, c, threads, spmm_row_range_streaming);
}

/// Shared shard dispatch: every public SpMM entry point funnels here, so
/// the row partition (and with it the determinism contract) cannot drift
/// between the blocked and streaming paths.
fn spmm_into_with(a: &CsrMat, b: &DMat, c: &mut DMat, threads: usize, kernel: RowRangeKernel) {
    assert_eq!(a.cols, b.rows(), "spmm shape mismatch");
    let (m, n) = (a.rows, b.cols());
    assert_eq!((c.rows(), c.cols()), (m, n), "spmm output shape mismatch");
    let shards = row_shards(m, threads);
    if shards.len() <= 1 {
        kernel(a, b, c.data_mut(), 0, m);
        return;
    }
    let starts = shard_starts(&shards);
    let elem_lens: Vec<usize> = shards.iter().map(|&len| len * n).collect();
    parallel_shards(c.data_mut(), &elem_lens, |idx, chunk| {
        let r0 = starts[idx];
        kernel(a, b, chunk, r0, r0 + shards[idx]);
    });
}

/// Streaming row-range kernel for the fused solver step (any bundle
/// width): the SpMM accumulation of [`spmm_row_range_streaming`] followed
/// by the in-register combine `c = c·β + α·w + γ·u` per row — the same
/// floating-point sequence as SpMM, then `scale(β)`, then `axpy(α, W)`,
/// then `axpy(γ, U)`, with the α/γ terms conditionally skipped exactly
/// like the unfused callers skip zero-coefficient axpys.
#[allow(clippy::too_many_arguments)]
fn spmm_step_row_range_streaming(
    a: &CsrMat,
    w: &DMat,
    u: &DMat,
    c_rows: &mut [f64],
    r0: usize,
    r1: usize,
    alpha: f64,
    beta: f64,
    gamma: f64,
) {
    let n = w.cols();
    debug_assert_eq!(a.cols, w.rows());
    debug_assert_eq!(c_rows.len(), (r1 - r0) * n);
    c_rows.fill(0.0);
    let wd = w.data();
    let ud = u.data();
    for i in r0..r1 {
        let crow = &mut c_rows[(i - r0) * n..(i - r0 + 1) * n];
        for_each_nonzero(a, i, |v, j| {
            let wrow = &wd[j * n..(j + 1) * n];
            for (cv, &wv) in crow.iter_mut().zip(wrow.iter()) {
                *cv += v * wv;
            }
        });
        let wrow = &wd[i * n..(i + 1) * n];
        let urow = &ud[i * n..(i + 1) * n];
        for t in 0..n {
            let mut x = crow[t] * beta;
            if alpha != 0.0 {
                x += alpha * wrow[t];
            }
            if gamma != 0.0 {
                x += gamma * urow[t];
            }
            crow[t] = x;
        }
    }
}

/// Register-blocked row-range kernel for the fused solver step, fixed
/// bundle width `K` (the same monomorphized family as
/// [`spmm_row_range_blocked`]): the whole step — SpMM accumulation *and*
/// the α/β/γ combine — happens in the `[f64; K]` register array, so the
/// bundle is read once and `C` written once per row, versus the three
/// read-modify-write passes of the unfused SpMM + `scale` + `axpy`
/// composition. Bitwise identical to that composition: per output element
/// the reduction is the same [`for_each_nonzero`] sequence and the
/// combine applies the identical operations in the identical order.
#[allow(clippy::too_many_arguments)]
fn spmm_step_row_range_blocked<const K: usize>(
    a: &CsrMat,
    w: &DMat,
    u: &DMat,
    c_rows: &mut [f64],
    r0: usize,
    r1: usize,
    alpha: f64,
    beta: f64,
    gamma: f64,
) {
    debug_assert_eq!(w.cols(), K);
    debug_assert_eq!(a.cols, w.rows());
    debug_assert_eq!(c_rows.len(), (r1 - r0) * K);
    let wd = w.data();
    let ud = u.data();
    for i in r0..r1 {
        let mut acc = [0.0f64; K];
        for_each_nonzero(a, i, |v, j| {
            let wrow: &[f64; K] = wd[j * K..(j + 1) * K].try_into().unwrap();
            for t in 0..K {
                acc[t] += v * wrow[t];
            }
        });
        let wrow: &[f64; K] = wd[i * K..(i + 1) * K].try_into().unwrap();
        let urow: &[f64; K] = ud[i * K..(i + 1) * K].try_into().unwrap();
        for t in 0..K {
            let mut x = acc[t] * beta;
            if alpha != 0.0 {
                x += alpha * wrow[t];
            }
            if gamma != 0.0 {
                x += gamma * urow[t];
            }
            acc[t] = x;
        }
        c_rows[(i - r0) * K..(i - r0 + 1) * K].copy_from_slice(&acc);
    }
}

/// A row-range fused-step kernel (see [`spmm_step_into`]).
pub(crate) type StepRowRangeKernel =
    fn(&CsrMat, &DMat, &DMat, &mut [f64], usize, usize, f64, f64, f64);

/// Fused-step kernel selection by bundle width — the same 1..=16 blocked /
/// streaming-above split as [`kernel_for_width`], with the same
/// build-time [`super::simd`] backend substitution.
pub(crate) fn step_kernel_for_width(k: usize) -> StepRowRangeKernel {
    if let Some(kernel) = super::simd::step_kernel(k) {
        return kernel;
    }
    macro_rules! blocked_widths {
        ($($w:literal),*) => {
            match k {
                $($w => spmm_step_row_range_blocked::<$w>,)*
                _ => spmm_step_row_range_streaming,
            }
        };
    }
    blocked_widths!(1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16)
}

/// Fused solver-step product: `C = α·W + β·(A·W) + γ·U` in **one pass**
/// over the bundle, row-sharded across `threads` workers.
///
/// This is the shape of every polynomial-operator recurrence step the
/// matrix-free solvers take:
///
/// * Horner (`SeriesForm::apply_bundle`): `R ← (A − shift·I)·R + c_i·V`
///   is `α = −shift, β = 1, γ = c_i`;
/// * NegPower (`SparsePolyOp`'s `(I − L/ℓ)·W`): `α = 1, β = −1/ℓ, γ = 0`;
/// * Chebyshev three-term (`ChebSeries::apply_bundle`):
///   `T_{j+1}V = 2b·T_jV + 2a·(A·T_jV) − T_{j−1}V` is
///   `α = 2b, β = 2a, γ = −1`.
///
/// The unfused composition makes three full read-modify-write passes over
/// the `n×k` output (SpMM, `scale`, `axpy`); the fused kernel makes one.
/// **Bitwise identical** to that composition (with zero-valued `α`/`γ`
/// terms skipped exactly as the unfused callers skip zero-coefficient
/// axpys), to the serial path for every worker count, and across the
/// blocked/streaming kernel split — pinned by
/// `tests/basis_equivalence.rs` over k ∈ 1..=17 × 1/2/8 workers.
///
/// `A` must be square (the α·W term pairs output row `i` with bundle row
/// `i`); `U` must have the output's shape. `γ = 0` skips `U` entirely, so
/// callers without a third operand can pass `w` again.
#[allow(clippy::too_many_arguments)]
pub fn spmm_step_into(
    a: &CsrMat,
    w: &DMat,
    u: &DMat,
    alpha: f64,
    beta: f64,
    gamma: f64,
    c: &mut DMat,
    threads: usize,
) {
    assert!(a.is_square(), "spmm_step needs a square operator");
    assert_eq!(a.cols, w.rows(), "spmm_step shape mismatch");
    let (m, n) = (a.rows, w.cols());
    assert_eq!((u.rows(), u.cols()), (m, n), "spmm_step U shape mismatch");
    assert_eq!((c.rows(), c.cols()), (m, n), "spmm_step output shape mismatch");
    let kernel = step_kernel_for_width(n);
    let shards = row_shards(m, threads);
    if shards.len() <= 1 {
        kernel(a, w, u, c.data_mut(), 0, m, alpha, beta, gamma);
        return;
    }
    let starts = shard_starts(&shards);
    let elem_lens: Vec<usize> = shards.iter().map(|&len| len * n).collect();
    parallel_shards(c.data_mut(), &elem_lens, |idx, chunk| {
        let r0 = starts[idx];
        kernel(a, w, u, chunk, r0, r0 + shards[idx], alpha, beta, gamma);
    });
}

/// [`spmm_step_into`] into a fresh buffer (tests, one-shot callers).
pub fn spmm_step(
    a: &CsrMat,
    w: &DMat,
    u: &DMat,
    alpha: f64,
    beta: f64,
    gamma: f64,
    threads: usize,
) -> DMat {
    let mut c = DMat::zeros(a.rows, w.cols());
    spmm_step_into(a, w, u, alpha, beta, gamma, &mut c, threads);
    c
}

// ---------------------------------------------------------------------------
// Mixed precision: f32 storage, f64 accumulation.
// ---------------------------------------------------------------------------

/// CSR matrix with `f32` stored values — the mixed-precision operand for
/// the inexact iterative stages (`Precision::Mixed`). Skinny SpMM is
/// memory-bandwidth-bound, so halving the bytes behind both the matrix
/// values and the bundle panels roughly doubles effective bandwidth; the
/// per-entry products and the α/β/γ combine still run in `f64` (an
/// `f32 × f32` product is exact in `f64`), so the only new rounding is one
/// `f32` store per element per sweep — the term
/// [`crate::transforms::mixed_error_budget`] documents.
///
/// Structural invariants are inherited from the source [`CsrMat`], which
/// validated them on construction.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatF32 {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatF32 {
    /// Demote a validated f64 CSR matrix to f32 storage (one rounding per
    /// stored value).
    pub fn from_f64(a: &CsrMat) -> CsrMatF32 {
        CsrMatF32 {
            rows: a.rows,
            cols: a.cols,
            indptr: a.indptr.clone(),
            indices: a.indices.clone(),
            values: a.values.iter().map(|&v| v as f32).collect(),
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }
    pub fn nnz(&self) -> usize {
        self.values.len()
    }
}

/// Mixed-precision counterpart of [`for_each_nonzero`]: same ascending-CSR
/// entry order, same zero skip (an f64 value that rounded to `0.0f32`
/// contributes nothing either way).
#[inline(always)]
fn for_each_nonzero_f32(a: &CsrMatF32, i: usize, mut visit: impl FnMut(f32, usize)) {
    for idx in a.indptr[i]..a.indptr[i + 1] {
        let v = a.values[idx];
        if v == 0.0 {
            continue;
        }
        visit(v, a.indices[idx] as usize);
    }
}

/// A row-range mixed-precision fused-step kernel: f32 matrix values and
/// bundle panels, f64 accumulators and combine, one f32 rounding on store.
type MixedStepKernel =
    fn(&CsrMatF32, &[f32], &[f32], &mut [f32], usize, usize, usize, f64, f64, f64);

/// Streaming mixed-precision fused step (any bundle width `k`).
#[allow(clippy::too_many_arguments)]
fn spmm_step_mixed_row_range_streaming(
    a: &CsrMatF32,
    w: &[f32],
    u: &[f32],
    c_rows: &mut [f32],
    k: usize,
    r0: usize,
    r1: usize,
    alpha: f64,
    beta: f64,
    gamma: f64,
) {
    debug_assert_eq!(w.len(), a.cols * k);
    debug_assert_eq!(c_rows.len(), (r1 - r0) * k);
    let mut acc = vec![0.0f64; k];
    for i in r0..r1 {
        acc.fill(0.0);
        for_each_nonzero_f32(a, i, |v, j| {
            let wrow = &w[j * k..(j + 1) * k];
            for t in 0..k {
                acc[t] += v as f64 * wrow[t] as f64;
            }
        });
        let wrow = &w[i * k..(i + 1) * k];
        let urow = &u[i * k..(i + 1) * k];
        let crow = &mut c_rows[(i - r0) * k..(i - r0 + 1) * k];
        for t in 0..k {
            let mut x = acc[t] * beta;
            if alpha != 0.0 {
                x += alpha * wrow[t] as f64;
            }
            if gamma != 0.0 {
                x += gamma * urow[t] as f64;
            }
            crow[t] = x as f32;
        }
    }
}

/// Register-blocked mixed-precision fused step for a fixed width `K` —
/// the same monomorphized family shape as [`spmm_step_row_range_blocked`],
/// with `[f64; K]` accumulators over f32 operands.
#[allow(clippy::too_many_arguments)]
fn spmm_step_mixed_row_range_blocked<const K: usize>(
    a: &CsrMatF32,
    w: &[f32],
    u: &[f32],
    c_rows: &mut [f32],
    k: usize,
    r0: usize,
    r1: usize,
    alpha: f64,
    beta: f64,
    gamma: f64,
) {
    debug_assert_eq!(k, K);
    debug_assert_eq!(w.len(), a.cols * K);
    debug_assert_eq!(c_rows.len(), (r1 - r0) * K);
    for i in r0..r1 {
        let mut acc = [0.0f64; K];
        for_each_nonzero_f32(a, i, |v, j| {
            let wrow: &[f32; K] = w[j * K..(j + 1) * K].try_into().unwrap();
            for t in 0..K {
                acc[t] += v as f64 * wrow[t] as f64;
            }
        });
        let wrow: &[f32; K] = w[i * K..(i + 1) * K].try_into().unwrap();
        let urow: &[f32; K] = u[i * K..(i + 1) * K].try_into().unwrap();
        let crow = &mut c_rows[(i - r0) * K..(i - r0 + 1) * K];
        for t in 0..K {
            let mut x = acc[t] * beta;
            if alpha != 0.0 {
                x += alpha * wrow[t] as f64;
            }
            if gamma != 0.0 {
                x += gamma * urow[t] as f64;
            }
            crow[t] = x as f32;
        }
    }
}

/// Mixed-step kernel selection — the same 1..=16 blocked / streaming-above
/// split as [`step_kernel_for_width`].
fn mixed_step_kernel_for_width(k: usize) -> MixedStepKernel {
    macro_rules! blocked_widths {
        ($($w:literal),*) => {
            match k {
                $($w => spmm_step_mixed_row_range_blocked::<$w>,)*
                _ => spmm_step_mixed_row_range_streaming,
            }
        };
    }
    blocked_widths!(1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16)
}

/// Mixed-precision fused solver step: `C = α·W + β·(A·W) + γ·U` with f32
/// storage (matrix values and all three panels) and f64 accumulation, in
/// one pass, row-sharded across `threads` workers.
///
/// Same shape and operand conventions as [`spmm_step_into`] (`A` square,
/// panels row-major `n×k`, `γ = 0` skips `U` so callers may pass `w`
/// again). The determinism contract carries over: output is **bitwise
/// identical for every worker count** — shards partition output rows and
/// each row reduces in the same ascending-CSR order. What mixed precision
/// gives up is agreement with the f64 kernels, bounded by one f32
/// rounding per element per sweep
/// ([`crate::transforms::mixed_error_budget`]); it is therefore only
/// reachable from the inexact iterative stages, never the exact
/// transforms or ground-truth paths.
#[allow(clippy::too_many_arguments)]
pub fn spmm_step_mixed_into(
    a: &CsrMatF32,
    w: &[f32],
    u: &[f32],
    k: usize,
    alpha: f64,
    beta: f64,
    gamma: f64,
    c: &mut [f32],
    threads: usize,
) {
    assert!(a.is_square(), "spmm_step_mixed needs a square operator");
    assert_eq!(w.len(), a.cols * k, "spmm_step_mixed W shape mismatch");
    assert_eq!(u.len(), a.rows * k, "spmm_step_mixed U shape mismatch");
    assert_eq!(c.len(), a.rows * k, "spmm_step_mixed output shape mismatch");
    let kernel = mixed_step_kernel_for_width(k);
    let m = a.rows;
    let shards = row_shards(m, threads);
    if shards.len() <= 1 {
        kernel(a, w, u, c, k, 0, m, alpha, beta, gamma);
        return;
    }
    let starts = shard_starts(&shards);
    let elem_lens: Vec<usize> = shards.iter().map(|&len| len * k).collect();
    parallel_shards(c, &elem_lens, |idx, chunk| {
        let r0 = starts[idx];
        kernel(a, w, u, chunk, k, r0, r0 + shards[idx], alpha, beta, gamma);
    });
}

/// Row-range SpMV kernel (shared serial/sharded inner loop) — the width-1
/// reduction through [`for_each_nonzero`], so SpMV shares the SpMM entry
/// order and zero-skip semantics instead of duplicating the loop.
fn spmv_row_range(a: &CsrMat, x: &[f64], y_rows: &mut [f64], r0: usize, r1: usize) {
    debug_assert_eq!(a.cols, x.len());
    debug_assert_eq!(y_rows.len(), r1 - r0);
    for i in r0..r1 {
        let mut s = 0.0;
        for_each_nonzero(a, i, |v, j| s += v * x[j]);
        y_rows[i - r0] = s;
    }
}

/// `y = A·x` row-sharded. Bitwise identical to serial for every worker
/// count. `O(nnz)`.
pub fn spmv(a: &CsrMat, x: &[f64], threads: usize) -> Vec<f64> {
    assert_eq!(a.cols, x.len(), "spmv shape mismatch");
    let m = a.rows;
    let mut y = vec![0.0; m];
    let shards = row_shards(m, threads);
    if shards.len() <= 1 {
        spmv_row_range(a, x, &mut y, 0, m);
        return y;
    }
    let starts = shard_starts(&shards);
    parallel_shards(&mut y, &shards, |idx, chunk| {
        let r0 = starts[idx];
        spmv_row_range(a, x, chunk, r0, r0 + chunk.len());
    });
    y
}

/// Largest-eigenvalue estimate of a symmetric PSD sparse matrix by power
/// iteration — the shared recurrence of
/// [`super::par::power_lambda_max_par`] (one implementation, dispatched by
/// matvec), with the matrix–vector product in `O(nnz)` instead of `O(n²)`.
/// Bitwise identical across worker counts. Errors on non-finite iterates
/// instead of propagating poison into λ*.
pub fn power_lambda_max_csr(a: &CsrMat, iters: usize, threads: usize) -> anyhow::Result<f64> {
    assert!(a.is_square());
    super::par::power_iteration_with(a.rows, iters, |v| spmv(a, v, threads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{gemv, matmul};
    use crate::util::rng::Rng;

    fn random_bundle(seed: u64, r: usize, c: usize) -> DMat {
        let mut rng = Rng::new(seed);
        DMat::from_fn(r, c, |_, _| rng.normal())
    }

    /// A random symmetric sparse matrix with a full structural diagonal.
    fn random_sym_csr(seed: u64, n: usize, fill: f64) -> CsrMat {
        let mut rng = Rng::new(seed);
        let mut trips: Vec<(usize, usize, f64)> = Vec::new();
        for i in 0..n {
            trips.push((i, i, rng.normal().abs() + 0.1));
            for j in (i + 1)..n {
                if rng.uniform(0.0, 1.0) < fill {
                    let w = rng.normal();
                    trips.push((i, j, w));
                    trips.push((j, i, w));
                }
            }
        }
        CsrMat::from_triplets(n, n, &trips)
    }

    fn bitwise_eq(a: &DMat, b: &DMat) -> bool {
        a.rows() == b.rows()
            && a.cols() == b.cols()
            && a
                .data()
                .iter()
                .zip(b.data().iter())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn from_triplets_merges_and_sorts() {
        let m = CsrMat::from_triplets(
            3,
            3,
            &[(1, 2, 1.0), (0, 0, 2.0), (1, 2, 0.5), (1, 0, -1.0)],
        );
        assert_eq!(m.nnz(), 3);
        let (cols, vals) = m.row(1);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[-1.0, 1.5]);
        let d = m.to_dense();
        assert_eq!(d[(0, 0)], 2.0);
        assert_eq!(d[(1, 2)], 1.5);
        assert_eq!(d[(2, 2)], 0.0);
    }

    #[test]
    fn dense_roundtrip_and_accessors() {
        let m = random_sym_csr(1, 12, 0.3);
        let d = m.to_dense();
        assert!(d.is_symmetric(0.0));
        assert_eq!(m.indptr().len(), 13);
        assert_eq!(m.indices().len(), m.nnz());
        assert!(m.density() > 0.0 && m.density() <= 1.0);
        // Gershgorin bound from CSR equals the dense one.
        let gd = crate::linalg::funcs::gershgorin_bound(&d);
        assert_eq!(m.gershgorin_bound().to_bits(), gd.to_bits());
    }

    #[test]
    fn scale_and_add_diag() {
        let mut m = random_sym_csr(2, 8, 0.4);
        let before = m.to_dense();
        m.scale_values(0.5);
        m.add_diag(1.25);
        let after = m.to_dense();
        for i in 0..8 {
            for j in 0..8 {
                let want = before[(i, j)] * 0.5 + if i == j { 1.25 } else { 0.0 };
                assert!((after[(i, j)] - want).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn spmm_bitwise_matches_dense_matmul_both_kernels() {
        // B widths straddle the dense skinny/blocked kernel split (16).
        for &(n, k) in &[(1usize, 1usize), (7, 3), (40, 8), (40, 20), (65, 33), (90, 17)] {
            let a = random_sym_csr(n as u64 + 10, n, 0.25);
            let ad = a.to_dense();
            let b = random_bundle(n as u64 ^ 0xB0, n, k);
            let dense = matmul(&ad, &b);
            for &workers in &[1usize, 2, 8] {
                let s = spmm(&a, &b, workers);
                assert!(bitwise_eq(&s, &dense), "(n={n},k={k}) at {workers} workers");
            }
        }
    }

    #[test]
    fn blocked_kernels_bitwise_match_streaming_for_every_width() {
        // Every dispatch width 1..=16 plus the first streaming fallback
        // width (17), serial and sharded: the blocked family must be
        // indistinguishable from the streaming reference, bit for bit.
        let a = random_sym_csr(31, 29, 0.3);
        for k in 1..=17usize {
            let b = random_bundle(k as u64 + 77, 29, k);
            let reference = spmm_streaming(&a, &b, 1);
            for &workers in &[1usize, 2, 8] {
                assert!(
                    bitwise_eq(&spmm(&a, &b, workers), &reference),
                    "blocked k={k} diverged from streaming at {workers} workers"
                );
                assert!(
                    bitwise_eq(&spmm_streaming(&a, &b, workers), &reference),
                    "streaming k={k} not worker-invariant at {workers} workers"
                );
            }
        }
    }

    #[test]
    fn blocked_kernels_handle_empty_rows() {
        // Rows with no stored entries at all (not even a structural zero):
        // blocked, streaming, and dense all agree the output row is zero.
        let m = CsrMat::from_triplets(
            5,
            5,
            &[(0, 0, 0.0), (2, 1, 1.5), (2, 3, -2.0), (4, 4, 3.0)],
        );
        let dense = m.to_dense();
        for k in 1..=17usize {
            let b = random_bundle(k as u64 ^ 0xE0, 5, k);
            let want = matmul(&dense, &b);
            for &workers in &[1usize, 2, 8] {
                let got = spmm(&m, &b, workers);
                assert!(bitwise_eq(&got, &want), "k={k}, {workers} workers");
                assert!(bitwise_eq(&spmm_streaming(&m, &b, workers), &want));
                // Empty rows 1 and 3 (and the structurally-zero row 0).
                for row in [0usize, 1, 3] {
                    assert!(got.row(row).iter().all(|x| x.to_bits() == 0), "row {row}");
                }
            }
        }
    }

    #[test]
    fn spmm_skips_structural_zeros_like_dense() {
        // A structurally-present zero (isolated-node diagonal) must not
        // perturb the product relative to the dense kernel's zero skip.
        let m = CsrMat::from_triplets(
            3,
            3,
            &[(0, 0, 0.0), (1, 1, 2.0), (1, 2, -1.0), (2, 1, -1.0), (2, 2, 1.0)],
        );
        let b = random_bundle(3, 3, 5);
        let dense = matmul(&m.to_dense(), &b);
        assert!(bitwise_eq(&spmm(&m, &b, 1), &dense));
        assert_eq!(spmm(&m, &b, 4).row(0), &[0.0; 5]);
    }

    /// The unfused reference composition for the fused step kernel: SpMM,
    /// then scale(β), then the conditionally-skipped axpys — exactly what
    /// the solver hot loops did before fusion.
    fn unfused_step(
        a: &CsrMat,
        w: &DMat,
        u: &DMat,
        alpha: f64,
        beta: f64,
        gamma: f64,
        threads: usize,
    ) -> DMat {
        let mut c = spmm(a, w, threads);
        c.scale(beta);
        if alpha != 0.0 {
            c.axpy(alpha, w);
        }
        if gamma != 0.0 {
            c.axpy(gamma, u);
        }
        c
    }

    #[test]
    fn fused_step_bitwise_matches_unfused_composition() {
        // Every blocked width plus the first streaming-fallback width, a
        // scalar grid that includes the solver hot-loop shapes (Horner,
        // NegPower, Chebyshev) and the skip-triggering zeros.
        let a = random_sym_csr(41, 23, 0.3);
        let cases: &[(f64, f64, f64)] = &[
            (-0.95, 1.0, 0.04),  // Horner: α = −shift, β = 1, γ = c_i
            (1.0, -1.0 / 51.0, 0.0), // NegPower: γ = 0 skips U
            (-1.3, 0.7, -1.0),   // Chebyshev: α = 2b, β = 2a, γ = −1
            (0.0, 2.0, 0.0),     // both skips
            (0.0, 1.0, 1.5),     // α skip only
        ];
        for k in 1..=17usize {
            let w = random_bundle(k as u64 + 900, 23, k);
            let u = random_bundle(k as u64 + 901, 23, k);
            for &(alpha, beta, gamma) in cases {
                let want = unfused_step(&a, &w, &u, alpha, beta, gamma, 1);
                for &workers in &[1usize, 2, 8] {
                    let got = spmm_step(&a, &w, &u, alpha, beta, gamma, workers);
                    assert!(
                        bitwise_eq(&got, &want),
                        "fused step diverged: k={k}, workers={workers}, \
                         (α,β,γ)=({alpha},{beta},{gamma})"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_step_handles_empty_rows_and_structural_zeros() {
        let m = CsrMat::from_triplets(
            5,
            5,
            &[(0, 0, 0.0), (2, 1, 1.5), (2, 3, -2.0), (4, 4, 3.0)],
        );
        for k in [1usize, 8, 17] {
            let w = random_bundle(k as u64 + 70, 5, k);
            let u = random_bundle(k as u64 + 71, 5, k);
            let want = unfused_step(&m, &w, &u, 0.5, -2.0, 1.25, 1);
            for &workers in &[1usize, 4] {
                let got = spmm_step(&m, &w, &u, 0.5, -2.0, 1.25, workers);
                assert!(bitwise_eq(&got, &want), "k={k}, {workers} workers");
            }
        }
    }

    #[test]
    fn spmv_matches_dense_gemv() {
        let a = random_sym_csr(5, 37, 0.3);
        let ad = a.to_dense();
        let mut rng = Rng::new(99);
        let x: Vec<f64> = (0..37).map(|_| rng.normal()).collect();
        let dense = gemv(&ad, &x);
        for &workers in &[1usize, 2, 8] {
            let y = spmv(&a, &x, workers);
            for (got, want) in y.iter().zip(dense.iter()) {
                assert!((got - want).abs() < 1e-12);
            }
            // Worker-count determinism is exact.
            let serial = spmv(&a, &x, 1);
            assert!(y.iter().zip(serial.iter()).all(|(p, q)| p.to_bits() == q.to_bits()));
        }
    }

    #[test]
    fn power_iteration_matches_dense_path() {
        let g = crate::graph::gen::cliques(&crate::graph::gen::CliqueSpec {
            n: 40,
            k: 4,
            max_short_circuit: 3,
            seed: 3,
        })
        .graph;
        let lc = g.laplacian_csr();
        let ld = g.laplacian();
        let sparse = power_lambda_max_csr(&lc, 100, 1).unwrap();
        let dense = crate::linalg::funcs::power_lambda_max(&ld, 100).unwrap();
        assert!(
            (sparse - dense).abs() <= 1e-9 * dense.max(1.0),
            "sparse {sparse} vs dense {dense}"
        );
        // And across worker counts, bitwise.
        for &workers in &[2usize, 8] {
            assert_eq!(
                power_lambda_max_csr(&lc, 100, workers).unwrap().to_bits(),
                sparse.to_bits()
            );
        }
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let m = CsrMat::from_triplets(0, 0, &[]);
        assert_eq!(m.nnz(), 0);
        assert_eq!(power_lambda_max_csr(&m, 10, 4).unwrap(), 0.0);
        let one = CsrMat::from_triplets(1, 1, &[(0, 0, 3.0)]);
        let b = DMat::from_vec(1, 2, vec![2.0, -1.0]);
        let c = spmm(&one, &b, 4);
        assert_eq!(c.row(0), &[6.0, -3.0]);
        assert_eq!(spmv(&one, &[2.0], 4), vec![6.0]);
    }

    #[test]
    #[should_panic(expected = "row 1: columns not strictly increasing")]
    fn unsorted_columns_panic_names_the_row() {
        // Row 1 carries [2, 1] — out of order. The validation pass must
        // say *which* row, not just that something is wrong.
        CsrMat::new(2, 3, vec![0, 1, 3], vec![0, 2, 1], vec![1.0, 1.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "row 0: columns not strictly increasing")]
    fn duplicate_columns_panic_names_the_row() {
        // Duplicates fail the same strict-< check as unsorted columns.
        CsrMat::new(1, 3, vec![0, 2], vec![1, 1], vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "row 2: column 5 out of range")]
    fn out_of_range_column_panics_naming_the_row() {
        CsrMat::new(3, 3, vec![0, 0, 0, 1], vec![5], vec![1.0]);
    }

    #[test]
    fn assert_valid_accepts_every_builder_output() {
        // The validation pass is re-runnable on matrices the builders
        // produced (the contract the in-place delta patching relies on).
        random_sym_csr(9, 17, 0.3).assert_valid();
        CsrMat::from_triplets(0, 0, &[]).assert_valid();
        let mut m = random_sym_csr(10, 8, 0.4);
        m.scale_values(0.5);
        m.add_diag(1.0);
        m.debug_assert_valid();
    }

    #[test]
    fn mixed_step_tracks_f64_within_f32_budget() {
        // The mixed kernel agrees with the f64 fused step to f32-rounding
        // accuracy: operands are rounded once to f32, products/combine run
        // in f64, and one f32 rounding lands on the store.
        let a = random_sym_csr(51, 23, 0.3);
        let a32 = CsrMatF32::from_f64(&a);
        assert_eq!((a32.rows(), a32.cols(), a32.nnz()), (23, 23, a.nnz()));
        for k in [1usize, 8, 17] {
            let w = random_bundle(k as u64 + 500, 23, k);
            let u = random_bundle(k as u64 + 501, 23, k);
            let (alpha, beta, gamma) = (-1.3, 0.7, -1.0);
            let want = spmm_step(&a, &w, &u, alpha, beta, gamma, 1);
            let (w32, u32) = (w.to_f32(), u.to_f32());
            let mut c32 = vec![0.0f32; 23 * k];
            spmm_step_mixed_into(&a32, &w32, &u32, k, alpha, beta, gamma, &mut c32, 1);
            let scale = want.data().iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1.0);
            for (got, wv) in c32.iter().zip(want.data()) {
                assert!(
                    ((*got as f64) - wv).abs() <= 256.0 * f32::EPSILON as f64 * scale,
                    "k={k}: mixed {got} vs f64 {wv}"
                );
            }
        }
    }

    #[test]
    fn mixed_step_is_bitwise_worker_invariant() {
        let a32 = CsrMatF32::from_f64(&random_sym_csr(52, 29, 0.3));
        for k in [1usize, 8, 17] {
            let w: Vec<f32> = random_bundle(k as u64 + 600, 29, k).to_f32();
            let u: Vec<f32> = random_bundle(k as u64 + 601, 29, k).to_f32();
            let mut serial = vec![0.0f32; 29 * k];
            spmm_step_mixed_into(&a32, &w, &u, k, 2.0, -0.5, 1.0, &mut serial, 1);
            for workers in [2usize, 8] {
                let mut c = vec![0.0f32; 29 * k];
                spmm_step_mixed_into(&a32, &w, &u, k, 2.0, -0.5, 1.0, &mut c, workers);
                assert!(
                    c.iter().zip(serial.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "mixed step not worker-invariant at k={k}, {workers} workers"
                );
            }
        }
    }
}
