//! The paper's §5.2 convergence metrics.
//!
//! * **Normalized subspace error** (eq 15):
//!   `δᵗ = 1 − tr(U* Pᵗ)/k`, where `U* = V*V*ᵀ` is the ground-truth
//!   projector and `Pᵗ = V V†` the projector of the current estimate.
//! * **Longest eigenvector streak**: the number of *consecutive* leading
//!   eigenvector estimates whose absolute alignment with the corresponding
//!   ground-truth eigenvector exceeds `1 − ε` — a harsher metric that checks
//!   the individual eigenvectors and their order, not just the subspace.

use super::dmat::{dot, norm, DMat};
use super::matmul::matmul;
use super::qr::qr_thin;

/// Normalized subspace error (eq 15). `v_star` and `v` are `n×k` column
/// bundles; neither needs to be orthonormal (`v` is orthonormalized
/// internally via thin QR, matching the pseudo-inverse definition
/// `P = V V†` for full-column-rank V).
pub fn subspace_error(v_star: &DMat, v: &DMat) -> f64 {
    assert_eq!(v_star.rows(), v.rows());
    assert_eq!(v_star.cols(), v.cols());
    let k = v.cols();
    if k == 0 {
        return 0.0;
    }
    let (q, _) = qr_thin(v);
    let (qs, _) = qr_thin(v_star);
    // tr(U* P) = ‖Qsᵀ Q‖_F² — avoids forming n×n projectors.
    let m = matmul(&qs.t(), &q);
    let fro2: f64 = m.data().iter().map(|x| x * x).sum();
    (1.0 - fro2 / k as f64).max(0.0)
}

/// Per-vector absolute alignments `|⟨v_i, v*_i⟩| / (‖v_i‖‖v*_i‖)`.
pub fn alignments(v_star: &DMat, v: &DMat) -> Vec<f64> {
    assert_eq!(v_star.rows(), v.rows());
    assert_eq!(v_star.cols(), v.cols());
    (0..v.cols())
        .map(|j| {
            let a = v.col(j);
            let b = v_star.col(j);
            let na = norm(&a);
            let nb = norm(&b);
            if na == 0.0 || nb == 0.0 {
                0.0
            } else {
                (dot(&a, &b) / (na * nb)).abs()
            }
        })
        .collect()
}

/// Longest eigenvector streak: largest `s` such that the first `s` columns
/// of `v` all align with the matching columns of `v_star` within `eps`
/// (i.e. `|cos angle| ≥ 1 − eps`).
pub fn eigenvector_streak(v_star: &DMat, v: &DMat, eps: f64) -> usize {
    alignments(v_star, v)
        .iter()
        .take_while(|&&a| a >= 1.0 - eps)
        .count()
}

/// Degeneracy-aware eigenvector streak.
///
/// Symmetric workloads (e.g. the 3-room MDP, whose per-room vertical modes
/// are *exactly* degenerate when doors sit on a nodal row) make individual
/// eigenvectors inside an eigenvalue group non-identifiable — any rotation
/// of the group is equally correct, so the plain streak stalls at the first
/// group boundary no matter the solver. Here column `i`'s alignment is the
/// norm of its projection onto the span of the ground-truth vectors whose
/// eigenvalues tie with `values[i]` (within `group_tol` relative): exactly
/// the plain streak when the spectrum is simple.
pub fn eigenvector_streak_grouped(
    v_star: &DMat,
    values: &[f64],
    v: &DMat,
    eps: f64,
    group_tol: f64,
) -> usize {
    let k = v.cols();
    assert!(values.len() >= k, "need an eigenvalue per tracked column");
    let scale = values
        .iter()
        .take(k)
        .fold(1e-12f64, |m, &x| m.max(x.abs()));
    // Group boundaries over the first k eigenvalues (consecutive ties).
    let mut group_of = vec![0usize; k];
    let mut ranges: Vec<(usize, usize)> = Vec::new();
    let mut start = 0;
    for i in 1..=k {
        let tied = i < k && (values[i] - values[i - 1]).abs() <= group_tol * scale;
        if !tied {
            for g in start..i {
                group_of[g] = ranges.len();
            }
            ranges.push((start, i));
            start = i;
        }
    }
    let mut streak = 0;
    for i in 0..k {
        let (a, b) = ranges[group_of[i]];
        // ‖projection of v_i onto span(v*_a..v*_b)‖ / ‖v_i‖
        let vi = v.col(i);
        let nvi = norm(&vi);
        if nvi == 0.0 {
            break;
        }
        let mut proj2 = 0.0;
        for j in a..b {
            let c = dot(&v_star.col(j), &vi) / nvi;
            proj2 += c * c;
        }
        if proj2.sqrt() >= 1.0 - eps {
            streak += 1;
        } else {
            break;
        }
    }
    streak
}

/// A convergence-curve record: one sampled point during training.
#[derive(Clone, Debug)]
pub struct ConvergencePoint {
    pub step: usize,
    pub subspace_error: f64,
    pub streak: usize,
}

/// A full convergence history for one (solver, transform) pair.
#[derive(Clone, Debug, Default)]
pub struct ConvergenceHistory {
    pub label: String,
    pub points: Vec<ConvergencePoint>,
}

impl ConvergenceHistory {
    pub fn new(label: impl Into<String>) -> Self {
        ConvergenceHistory { label: label.into(), points: Vec::new() }
    }

    pub fn push(&mut self, step: usize, subspace_error: f64, streak: usize) {
        self.points.push(ConvergencePoint { step, subspace_error, streak });
    }

    /// First step at which the streak reached `target`, if ever.
    pub fn steps_to_streak(&self, target: usize) -> Option<usize> {
        self.points.iter().find(|p| p.streak >= target).map(|p| p.step)
    }

    /// First step at which subspace error dropped below `target`, if ever.
    pub fn steps_to_error(&self, target: f64) -> Option<usize> {
        self.points
            .iter()
            .find(|p| p.subspace_error <= target)
            .map(|p| p.step)
    }

    /// Final recorded values.
    pub fn last(&self) -> Option<&ConvergencePoint> {
        self.points.last()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::mgs_orthonormalize;
    use crate::util::rng::Rng;

    fn random_orthonormal(rng: &mut Rng, n: usize, k: usize) -> DMat {
        let mut v = DMat::from_fn(n, k, |_, _| rng.normal());
        mgs_orthonormalize(&mut v);
        v
    }

    #[test]
    fn zero_error_for_same_subspace() {
        let mut rng = Rng::new(1);
        let v = random_orthonormal(&mut rng, 20, 4);
        assert!(subspace_error(&v, &v) < 1e-12);
        // Any rotation of the columns spans the same subspace.
        let rot = {
            let mut r = DMat::from_fn(4, 4, |_, _| rng.normal());
            mgs_orthonormalize(&mut r);
            r
        };
        let vr = matmul(&v, &rot);
        assert!(subspace_error(&v, &vr) < 1e-10);
    }

    #[test]
    fn orthogonal_subspaces_have_error_one() {
        let n = 10;
        let v1 = DMat::from_fn(n, 2, |i, j| if i == j { 1.0 } else { 0.0 });
        let v2 = DMat::from_fn(n, 2, |i, j| if i == j + 5 { 1.0 } else { 0.0 });
        assert!((subspace_error(&v1, &v2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn partial_overlap_error() {
        // Share one of two directions → error 0.5.
        let n = 8;
        let v1 = DMat::from_fn(n, 2, |i, j| if i == j { 1.0 } else { 0.0 });
        let v2 = DMat::from_fn(n, 2, |i, j| {
            if (i, j) == (0, 0) {
                1.0
            } else if (i, j) == (5, 1) {
                1.0
            } else {
                0.0
            }
        });
        assert!((subspace_error(&v1, &v2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn streak_counts_consecutive_prefix() {
        let mut rng = Rng::new(2);
        let v_star = random_orthonormal(&mut rng, 30, 5);
        // Perfect on 0,1; wrong on 2; perfect on 3,4 → streak 2.
        let mut v = v_star.clone();
        let wrong = random_orthonormal(&mut rng, 30, 1);
        v.set_col(2, &wrong.col(0));
        // Remove overlap with v*_2 to ensure misalignment.
        let s = eigenvector_streak(&v_star, &v, 1e-3);
        assert!(s <= 2, "streak {s}");
        let full = eigenvector_streak(&v_star, &v_star, 1e-6);
        assert_eq!(full, 5);
    }

    #[test]
    fn streak_sign_invariant() {
        let mut rng = Rng::new(3);
        let v_star = random_orthonormal(&mut rng, 12, 3);
        let mut v = v_star.clone();
        let negated: Vec<f64> = v.col(1).iter().map(|x| -x).collect();
        v.set_col(1, &negated);
        assert_eq!(eigenvector_streak(&v_star, &v, 1e-6), 3);
    }

    #[test]
    fn alignment_of_unnormalized_vectors() {
        let mut rng = Rng::new(4);
        let v_star = random_orthonormal(&mut rng, 12, 2);
        let mut v = v_star.clone();
        let scaled: Vec<f64> = v.col(0).iter().map(|x| 5.0 * x).collect();
        v.set_col(0, &scaled);
        let a = alignments(&v_star, &v);
        assert!((a[0] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn history_thresholds() {
        let mut h = ConvergenceHistory::new("test");
        h.push(0, 0.9, 0);
        h.push(10, 0.5, 1);
        h.push(20, 0.05, 3);
        h.push(30, 0.01, 5);
        assert_eq!(h.steps_to_streak(3), Some(20));
        assert_eq!(h.steps_to_streak(6), None);
        assert_eq!(h.steps_to_error(0.1), Some(20));
        assert_eq!(h.last().unwrap().step, 30);
    }

    #[test]
    fn grouped_streak_equals_plain_on_simple_spectrum() {
        let mut rng = Rng::new(7);
        let v_star = random_orthonormal(&mut rng, 20, 4);
        let values = [0.0, 0.5, 1.0, 2.0];
        let mut v = v_star.clone();
        let wrong = random_orthonormal(&mut rng, 20, 1);
        v.set_col(2, &wrong.col(0));
        let plain = eigenvector_streak(&v_star, &v, 1e-2);
        let grouped = eigenvector_streak_grouped(&v_star, &values, &v, 1e-2, 1e-9);
        assert_eq!(plain, grouped);
    }

    #[test]
    fn grouped_streak_accepts_rotations_within_degenerate_group() {
        // Columns 1 and 2 share an eigenvalue; rotate them by 45°.
        let mut rng = Rng::new(8);
        let v_star = random_orthonormal(&mut rng, 16, 4);
        let values = [0.0, 1.0, 1.0, 3.0];
        let mut v = v_star.clone();
        let (c1, c2) = (v_star.col(1), v_star.col(2));
        let r = std::f64::consts::FRAC_1_SQRT_2;
        let rot1: Vec<f64> = (0..16).map(|i| r * (c1[i] + c2[i])).collect();
        let rot2: Vec<f64> = (0..16).map(|i| r * (c2[i] - c1[i])).collect();
        v.set_col(1, &rot1);
        v.set_col(2, &rot2);
        // Plain streak breaks at column 1; grouped sees the subspace match.
        assert_eq!(eigenvector_streak(&v_star, &v, 1e-2), 1);
        assert_eq!(
            eigenvector_streak_grouped(&v_star, &values, &v, 1e-2, 1e-9),
            4
        );
        // But a vector outside the group still fails.
        let stray = random_orthonormal(&mut rng, 16, 1);
        v.set_col(1, &stray.col(0));
        assert!(eigenvector_streak_grouped(&v_star, &values, &v, 1e-2, 1e-9) <= 1);
    }

    #[test]
    fn property_error_in_unit_interval() {
        use crate::testkit::{check, SizeGen};
        check(9, 20, &SizeGen { lo: 4, hi: 30 }, |&n| {
            let mut rng = Rng::new(n as u64 * 3);
            let k = (n / 3).max(1);
            let a = random_orthonormal(&mut rng, n, k);
            let b = DMat::from_fn(n, k, |_, _| rng.normal());
            let e = subspace_error(&a, &b);
            (0.0..=1.0 + 1e-9).contains(&e)
        });
    }
}
