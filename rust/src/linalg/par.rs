//! Row-sharded parallel execution layer for the dense hot paths.
//!
//! The paper's headline word is *parallelizable*: every transform build is
//! a chain of dense multiplies (Horner terms, matpow squarings) and every
//! solver step is one `M·V`. This module shards those kernels by **rows of
//! the output** across `util::pool` workers.
//!
//! ## Determinism contract
//!
//! Output is **bitwise identical** to the serial path for every worker
//! count. This falls out of the design rather than being patched in:
//!
//! * the shard boundaries partition output *rows*, and a dense-multiply
//!   row is an independent reduction — no cross-shard accumulation exists;
//! * each shard runs the *same* row-range kernel the serial path runs
//!   ([`matmul::matmul_row_range`] / [`matmul::gemv_row_range`]), so each
//!   row's floating-point reduction order never depends on the partition.
//!
//! Anything built on these primitives (Horner polynomial apply, binary
//! matrix powers, power iteration) is therefore deterministic too — the
//! property the determinism tests below pin down for 1, 2, and 8 workers.

use super::dmat::DMat;
use super::matmul::{gemv_row_range, matmul_row_range};
use crate::util::pool::parallel_shards;
use anyhow::{bail, Result};

/// Below this many multiply-adds a row-sharded dispatch runs serial: the
/// scoped spawn/join overhead of a per-call shard rivals the FLOPs. Shared
/// by every operator call site through [`effective_threads`] so the latency
/// heuristic cannot drift between them.
pub const SERIAL_WORK_THRESHOLD: usize = 1_000_000;

/// The one work-size guard for "is this product worth sharding": returns
/// `1` (serial) when `work` (multiply-add count) is below
/// [`SERIAL_WORK_THRESHOLD`], else `threads`. Output is bitwise identical
/// either way (the determinism contract), so this is purely a latency
/// decision — used by `DenseOp::apply`, `SparsePolyOp::apply`, and
/// `SeriesForm::eval_matrix_threads`.
pub fn effective_threads(work: usize, threads: usize) -> usize {
    if work < SERIAL_WORK_THRESHOLD {
        1
    } else {
        threads.max(1)
    }
}

/// Starting offset of each shard (prefix sums of the shard lengths), so a
/// worker knows which row range it owns. Shared by every row-sharded
/// dispatch site (dense and sparse).
pub(crate) fn shard_starts(shards: &[usize]) -> Vec<usize> {
    let mut starts = Vec::with_capacity(shards.len());
    let mut acc = 0usize;
    for &len in shards {
        starts.push(acc);
        acc += len;
    }
    starts
}

/// Split `rows` into at most `threads` contiguous shards (first shards get
/// the remainder), returned as per-shard row counts.
pub(crate) fn row_shards(rows: usize, threads: usize) -> Vec<usize> {
    let threads = threads.max(1).min(rows.max(1));
    let base = rows / threads;
    let extra = rows % threads;
    (0..threads)
        .map(|t| base + usize::from(t < extra))
        .filter(|&len| len > 0)
        .collect()
}

/// `C = A · B` with output rows sharded across `threads` workers.
/// Bitwise identical to [`super::matmul::matmul`] for any `threads`.
pub fn matmul_par(a: &DMat, b: &DMat, threads: usize) -> DMat {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let mut c = DMat::zeros(a.rows(), b.cols());
    matmul_into_par(a, b, &mut c, threads);
    c
}

/// `C = A · B` into an existing buffer, row-sharded. `threads ≤ 1` is the
/// serial path itself.
pub fn matmul_into_par(a: &DMat, b: &DMat, c: &mut DMat, threads: usize) {
    let (m, kk, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(kk, b.rows());
    assert_eq!((c.rows(), c.cols()), (m, n));
    let shards = row_shards(m, threads);
    if shards.len() <= 1 {
        matmul_row_range(a, b, c.data_mut(), 0, m);
        return;
    }
    let starts = shard_starts(&shards);
    let elem_lens: Vec<usize> = shards.iter().map(|&len| len * n).collect();
    parallel_shards(c.data_mut(), &elem_lens, |idx, chunk| {
        let r0 = starts[idx];
        let r1 = r0 + shards[idx];
        matmul_row_range(a, b, chunk, r0, r1);
    });
}

/// `y = A·x` row-sharded. Bitwise identical to [`super::matmul::gemv`].
pub fn gemv_par(a: &DMat, x: &[f64], threads: usize) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let m = a.rows();
    let mut y = vec![0.0; m];
    let shards = row_shards(m, threads);
    if shards.len() <= 1 {
        gemv_row_range(a, x, &mut y, 0, m);
        return y;
    }
    let starts = shard_starts(&shards);
    parallel_shards(&mut y, &shards, |idx, chunk| {
        let r0 = starts[idx];
        gemv_row_range(a, x, chunk, r0, r0 + chunk.len());
    });
    y
}

/// Matrix polynomial `p(A) = Σ_i c_i A^i` by Horner's rule with every dense
/// multiply row-sharded across `threads` workers. Exactly `deg(p)`
/// multiplies; bitwise identical to [`super::funcs::poly_horner`].
pub fn poly_horner_par(a: &DMat, coeffs: &[f64], threads: usize) -> DMat {
    assert!(a.is_square());
    let n = a.rows();
    if coeffs.is_empty() {
        return DMat::zeros(n, n);
    }
    let d = coeffs.len() - 1;
    // R = c_d · I
    let mut r = DMat::eye(n);
    r.scale(coeffs[d]);
    let mut tmp = DMat::zeros(n, n);
    for i in (0..d).rev() {
        // R = R·A + c_i·I
        matmul_into_par(&r, a, &mut tmp, threads);
        std::mem::swap(&mut r, &mut tmp);
        r.add_diag(coeffs[i]);
    }
    r
}

/// `A^p` by binary exponentiation with row-sharded multiplies. Bitwise
/// identical to [`super::funcs::matpow`].
pub fn matpow_par(a: &DMat, p: u64, threads: usize) -> DMat {
    assert!(a.is_square());
    let n = a.rows();
    if p == 0 {
        return DMat::eye(n);
    }
    let mut base = a.clone();
    let mut acc: Option<DMat> = None;
    let mut e = p;
    loop {
        if e & 1 == 1 {
            acc = Some(match acc {
                None => base.clone(),
                Some(m) => matmul_par(&m, &base, threads),
            });
        }
        e >>= 1;
        if e == 0 {
            break;
        }
        base = matmul_par(&base, &base, threads);
    }
    acc.unwrap()
}

/// The deterministic unit start vector shared by the power iteration and
/// the Lanczos tridiagonalization ([`super::lanczos`]): index-salted away
/// from any single eigenvector, identical for the dense and sparse
/// estimators so their bounds can never drift apart.
pub(crate) fn deterministic_start(n: usize) -> Vec<f64> {
    let mut v: Vec<f64> = (0..n)
        .map(|i| 1.0 + 0.01 * ((i * 2654435761 % 97) as f64 / 97.0))
        .collect();
    super::dmat::normalize(&mut v);
    v
}

/// The one power-iteration recurrence, parameterized by the matrix–vector
/// product. The dense ([`power_lambda_max_par`]) and sparse
/// (`sparse::power_lambda_max_csr`) λ_max estimates both dispatch here, so
/// their start vector and recurrence can never drift apart — which is what
/// keeps `--op dense` and `--op sparse` operator builds (λ*, pre-scale)
/// agreeing on the same graph.
pub(crate) fn power_iteration_with(
    n: usize,
    iters: usize,
    matvec: impl Fn(&[f64]) -> Vec<f64>,
) -> Result<f64> {
    if n == 0 {
        return Ok(0.0);
    }
    let mut v = deterministic_start(n);
    let mut lambda = 0.0;
    for it in 0..iters {
        let mut w = matvec(&v);
        lambda = super::dmat::dot(&v, &w);
        // A NaN/Inf Rayleigh quotient means the matrix (or an upstream
        // build) is poisoned; every later iterate would be too. Name the
        // iteration instead of letting the poison reach λ*/domain scaling.
        if !lambda.is_finite() {
            bail!(
                "power iteration: non-finite Rayleigh quotient {lambda} at iteration {} of {iters}",
                it + 1
            );
        }
        let nrm = super::dmat::normalize(&mut w);
        if !nrm.is_finite() {
            bail!(
                "power iteration: non-finite iterate norm {nrm} at iteration {} of {iters}",
                it + 1
            );
        }
        if nrm == 0.0 {
            return Ok(0.0);
        }
        v = w;
    }
    Ok(lambda.max(0.0))
}

/// Largest-eigenvalue estimate by power iteration with the matrix–vector
/// product row-sharded. Bitwise identical to
/// [`super::funcs::power_lambda_max`]. Errors on non-finite iterates (see
/// [`power_iteration_with`]).
pub fn power_lambda_max_par(a: &DMat, iters: usize, threads: usize) -> Result<f64> {
    power_iteration_with(a.rows(), iters, |v| gemv_par(a, v, threads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::funcs::{matpow, poly_horner, power_lambda_max};
    use crate::linalg::matmul::{gemv, matmul};
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> DMat {
        DMat::from_fn(r, c, |_, _| rng.normal())
    }

    /// Bitwise equality — the contract is exact, not within-tolerance.
    fn bitwise_eq(a: &DMat, b: &DMat) -> bool {
        a.rows() == b.rows()
            && a.cols() == b.cols()
            && a
                .data()
                .iter()
                .zip(b.data().iter())
                .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn matmul_par_bitwise_matches_serial_across_worker_counts() {
        let mut rng = Rng::new(41);
        // Shapes straddling the 64-wide block edge, plus skinny-B (n ≤ 16)
        // and degenerate single-row cases.
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 4, 5),
            (64, 64, 64),
            (65, 33, 17),
            (130, 70, 129),
            (97, 128, 8), // skinny kernel
            (5, 200, 3),  // skinny, fewer rows than workers
        ] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let serial = matmul(&a, &b);
            for &workers in &[1usize, 2, 8] {
                let par = matmul_par(&a, &b, workers);
                assert!(
                    bitwise_eq(&par, &serial),
                    "({m},{k},{n}) diverged at {workers} workers"
                );
            }
        }
    }

    #[test]
    fn poly_horner_par_bitwise_matches_serial() {
        let mut rng = Rng::new(42);
        for &n in &[1usize, 7, 65, 96] {
            let mut a = random_mat(&mut rng, n, n);
            a.symmetrize();
            a.scale(0.25);
            let coeffs = [0.5, -1.0, 2.0, 0.25, -0.125];
            let serial = poly_horner(&a, &coeffs);
            for &workers in &[1usize, 2, 8] {
                let par = poly_horner_par(&a, &coeffs, workers);
                assert!(bitwise_eq(&par, &serial), "n={n}, {workers} workers");
            }
        }
        // Edge cases mirror the serial ones.
        let a = DMat::eye(3);
        assert_eq!(poly_horner_par(&a, &[], 4).max_abs(), 0.0);
        assert!(bitwise_eq(&poly_horner_par(&a, &[7.0], 4), &poly_horner(&a, &[7.0])));
    }

    #[test]
    fn matpow_par_bitwise_matches_serial() {
        let mut rng = Rng::new(43);
        let mut a = random_mat(&mut rng, 48, 48);
        a.symmetrize();
        a.scale(0.3);
        for &p in &[1u64, 2, 7, 251] {
            let serial = matpow(&a, p);
            for &workers in &[2usize, 8] {
                assert!(bitwise_eq(&matpow_par(&a, p, workers), &serial), "p={p}");
            }
        }
        assert!(bitwise_eq(&matpow_par(&a, 0, 4), &DMat::eye(48)));
    }

    #[test]
    fn gemv_and_power_iteration_bitwise_match_serial() {
        let mut rng = Rng::new(44);
        let x = random_mat(&mut rng, 80, 50);
        let g = crate::linalg::matmul::gram(&x);
        let v: Vec<f64> = (0..g.cols()).map(|_| rng.normal()).collect();
        let serial = gemv(&g, &v);
        for &workers in &[1usize, 2, 8] {
            let par = gemv_par(&g, &v, workers);
            assert!(serial
                .iter()
                .zip(par.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()));
            let lam_s = power_lambda_max(&g, 60).unwrap();
            let lam_p = power_lambda_max_par(&g, 60, workers).unwrap();
            assert_eq!(lam_s.to_bits(), lam_p.to_bits(), "{workers} workers");
        }
    }

    #[test]
    fn effective_threads_guard() {
        assert_eq!(effective_threads(0, 8), 1);
        assert_eq!(effective_threads(SERIAL_WORK_THRESHOLD - 1, 8), 1);
        assert_eq!(effective_threads(SERIAL_WORK_THRESHOLD, 8), 8);
        assert_eq!(effective_threads(usize::MAX, 0), 1, "threads floor is 1");
    }

    #[test]
    fn property_determinism_over_random_shapes() {
        // The satellite determinism property: random shapes, random worker
        // counts ∈ {1, 2, 8}, always bitwise equal.
        use crate::testkit::{check, SizeGen};
        check(45, 12, &SizeGen { lo: 1, hi: 90 }, |&m| {
            let mut rng = Rng::new(m as u64 + 500);
            let k = (m % 37) + 1;
            let n = (m % 23) + 1;
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let serial = matmul(&a, &b);
            [1usize, 2, 8]
                .iter()
                .all(|&w| bitwise_eq(&matmul_par(&a, &b, w), &serial))
        });
    }
}
