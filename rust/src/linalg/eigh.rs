//! Symmetric eigendecomposition: Householder tridiagonalization (`tred2`)
//! followed by implicit-shift QL iteration (`tql2`) — the classic
//! EISPACK-lineage pair, O(n³) with a small constant.
//!
//! Provides the crate's ground truth: the exact bottom-k eigenvectors used
//! by the paper's metrics (eq 15, streak), and the eigenbasis for *exact*
//! spectral transforms `f(L) = V f(Λ) Vᵀ` (eq 10).

use super::dmat::DMat;
use anyhow::{bail, Result};

/// Eigendecomposition of a symmetric matrix: `A = V Λ Vᵀ`.
///
/// `values` are sorted ascending; `vectors` holds the matching eigenvectors
/// as columns (`vectors.col(i)` pairs with `values[i]`).
#[derive(Clone, Debug)]
pub struct Eigh {
    pub values: Vec<f64>,
    pub vectors: DMat,
}

impl Eigh {
    /// Reconstruct `f(A) = V diag(f(λ)) Vᵀ` for a scalar spectrum map `f`.
    pub fn apply_spectrum(&self, f: impl Fn(f64) -> f64) -> DMat {
        let n = self.values.len();
        let v = &self.vectors;
        let mut out = DMat::zeros(n, n);
        // out = Σ_i f(λ_i) v_i v_iᵀ  — rank-1 accumulation, exploits symmetry.
        for idx in 0..n {
            let fi = f(self.values[idx]);
            if fi == 0.0 {
                continue;
            }
            for r in 0..n {
                let vr = v[(r, idx)] * fi;
                if vr == 0.0 {
                    continue;
                }
                for c in r..n {
                    out[(r, c)] += vr * v[(c, idx)];
                }
            }
        }
        for r in 0..n {
            for c in 0..r {
                out[(r, c)] = out[(c, r)];
            }
        }
        out
    }

    /// The `k` eigenvectors with smallest eigenvalues, as an `n×k` matrix.
    pub fn bottom_k(&self, k: usize) -> DMat {
        self.vectors.take_cols(k)
    }

    /// Largest eigenvalue (spectral radius for PSD matrices).
    pub fn lambda_max(&self) -> f64 {
        *self.values.last().expect("non-empty spectrum")
    }
}

/// Compute the full symmetric eigendecomposition of `a`.
///
/// `a` must be square and (numerically) symmetric; it is symmetrized
/// defensively before reduction. Errors if QL fails to converge (does not
/// happen for finite symmetric input in practice).
pub fn eigh(a: &DMat) -> Result<Eigh> {
    if !a.is_square() {
        bail!("eigh: matrix must be square, got {}x{}", a.rows(), a.cols());
    }
    let n = a.rows();
    if n == 0 {
        return Ok(Eigh { values: vec![], vectors: DMat::zeros(0, 0) });
    }
    let mut z = a.clone();
    z.symmetrize();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    tred2(&mut z, &mut d, &mut e);
    tql2(&mut z, &mut d, &mut e)?;
    // Sort eigenpairs ascending.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut vectors = DMat::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..n {
            vectors[(i, new_j)] = z[(i, old_j)];
        }
    }
    Ok(Eigh { values, vectors })
}

/// Householder reduction of a real symmetric matrix to tridiagonal form.
/// On exit `z` holds the accumulated orthogonal transform, `d` the diagonal
/// and `e` the subdiagonal. (Numerical Recipes `tred2`, 0-indexed.)
fn tred2(z: &mut DMat, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    let v = z[(i, k)] / scale;
                    z[(i, k)] = v;
                    h += v * v;
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let delta = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= delta;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        let l = i;
        if d[i] != 0.0 {
            for j in 0..l {
                let mut g = 0.0;
                for k in 0..l {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..l {
                    z[(k, j)] -= g * z[(k, i)];
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..l {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
}

/// QL with implicit shifts on a symmetric tridiagonal matrix, accumulating
/// eigenvectors into `z`. (Numerical Recipes `tqli`, 0-indexed.)
fn tql2(z: &mut DMat, d: &mut [f64], e: &mut [f64]) -> Result<()> {
    let n = d.len();
    if n == 1 {
        return Ok(());
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find a small subdiagonal element to split the matrix.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                bail!("tql2: no convergence after 50 iterations");
            }
            // Form implicit shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sign_r = if g >= 0.0 { r.abs() } else { -r.abs() };
            g = d[m] - d[l] + e[l] / (g + sign_r);
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate the rotation into the eigenvector matrix.
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::util::rng::Rng;

    fn random_symmetric(rng: &mut Rng, n: usize) -> DMat {
        let mut m = DMat::from_fn(n, n, |_, _| rng.normal());
        m.symmetrize();
        m
    }

    fn check_decomposition(a: &DMat, eig: &Eigh, tol: f64) {
        let n = a.rows();
        // A v_i == λ_i v_i
        for i in 0..n {
            let v = eig.vectors.col(i);
            let av = crate::linalg::matmul::gemv(a, &v);
            for r in 0..n {
                assert!(
                    (av[r] - eig.values[i] * v[r]).abs() < tol,
                    "eigenpair {i} residual at row {r}"
                );
            }
        }
        // VᵀV == I
        let vtv = matmul(&eig.vectors.t(), &eig.vectors);
        assert!((&vtv - &DMat::eye(n)).max_abs() < tol, "not orthonormal");
        // ascending order
        for i in 1..n {
            assert!(eig.values[i] >= eig.values[i - 1] - 1e-12);
        }
    }

    #[test]
    fn diagonal_matrix() {
        let a = DMat::diag(&[3.0, 1.0, 2.0]);
        let e = eigh(&a).unwrap();
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
        check_decomposition(&a, &e, 1e-10);
    }

    #[test]
    fn two_by_two_known() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = DMat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = eigh(&a).unwrap();
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
        check_decomposition(&a, &e, 1e-10);
    }

    #[test]
    fn random_matrices_various_sizes() {
        let mut rng = Rng::new(42);
        for &n in &[1, 2, 3, 5, 10, 32, 64] {
            let a = random_symmetric(&mut rng, n);
            let e = eigh(&a).unwrap();
            check_decomposition(&a, &e, 1e-8);
            // trace preserved
            let tr: f64 = e.values.iter().sum();
            assert!((tr - a.trace()).abs() < 1e-8 * (n as f64));
        }
    }

    #[test]
    fn path_graph_laplacian_spectrum() {
        // Known spectrum of the path graph P_n Laplacian:
        // λ_j = 2 - 2cos(πj/n), j=0..n-1.
        let n = 16;
        let mut a = DMat::zeros(n, n);
        for i in 0..n {
            if i > 0 {
                a[(i, i - 1)] = -1.0;
                a[(i, i)] += 1.0;
            }
            if i + 1 < n {
                a[(i, i + 1)] = -1.0;
                a[(i, i)] += 1.0;
            }
        }
        let e = eigh(&a).unwrap();
        for j in 0..n {
            let expected = 2.0 - 2.0 * (std::f64::consts::PI * j as f64 / n as f64).cos();
            assert!((e.values[j] - expected).abs() < 1e-9, "j={j}");
        }
    }

    #[test]
    fn apply_spectrum_exponential() {
        let mut rng = Rng::new(9);
        let a = random_symmetric(&mut rng, 12);
        let e = eigh(&a).unwrap();
        // f == identity reproduces A.
        let back = e.apply_spectrum(|x| x);
        assert!((&back - &a).max_abs() < 1e-9);
        // exp(A) has spectrum exp(λ) with the same eigenvectors.
        let expa = e.apply_spectrum(f64::exp);
        let e2 = eigh(&expa).unwrap();
        let mut expected: Vec<f64> = e.values.iter().map(|&x| x.exp()).collect();
        expected.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for i in 0..12 {
            assert!((e2.values[i] - expected[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn repeated_eigenvalues_ok() {
        // Identity: all eigenvalues 1; vectors may be any orthonormal basis.
        let e = eigh(&DMat::eye(8)).unwrap();
        for v in &e.values {
            assert!((v - 1.0).abs() < 1e-12);
        }
        check_decomposition(&DMat::eye(8), &e, 1e-10);
    }

    #[test]
    fn property_psd_gram_has_nonneg_spectrum() {
        use crate::testkit::{check, SizeGen};
        check(11, 15, &SizeGen { lo: 1, hi: 20 }, |&n| {
            let mut rng = Rng::new(n as u64 * 7 + 1);
            let x = DMat::from_fn(n + 3, n, |_, _| rng.normal());
            let g = crate::linalg::matmul::gram(&x);
            let e = eigh(&g).unwrap();
            e.values.iter().all(|&v| v > -1e-8)
        });
    }

    #[test]
    fn non_square_rejected() {
        assert!(eigh(&DMat::zeros(2, 3)).is_err());
    }

    #[test]
    fn empty_matrix() {
        let e = eigh(&DMat::zeros(0, 0)).unwrap();
        assert!(e.values.is_empty());
    }
}
