//! Build-time SIMD backend for the skinny-SpMM kernel family.
//!
//! The register-blocked kernels in [`super::sparse`] keep all `k ≤ 16`
//! output columns of a row in a `[f64; K]` accumulator while sweeping the
//! row's nonzeros. That inner loop is embarrassingly lane-parallel **across
//! the bundle-width dimension**: each output column is an independent
//! accumulator chain, so packing four of them into a `std::simd` vector
//! (`Simd<f64, 4>`) preserves the exact per-element floating-point
//! reduction — same CSR entry order, same zero skip, one multiply and one
//! add per entry per lane. No FMA is ever emitted (`mul_add`'s single
//! rounding would diverge from the scalar mul-then-add sequence), so the
//! SIMD family is **bitwise identical** to the unrolled kernels and to the
//! streaming reference.
//!
//! Selection happens at build time, not run time:
//!
//! * `--features simd` (nightly toolchains; the `portable_simd` feature
//!   gate) — [`spmm_kernel`] / [`step_kernel`] return the `Simd<f64, 4>`
//!   implementations and `sparse::{kernel_for_width, step_kernel_for_width}`
//!   dispatch to them for every blocked width.
//! * default (stable) — both hooks return `None` and the existing unrolled
//!   kernels run; those compile to good autovectorized code on their own.
//!
//! [`backend_name`] reports which backend a binary carries (`sped info`,
//! bench JSON metadata), because the two are indistinguishable by output.

use super::sparse::{RowRangeKernel, StepRowRangeKernel};

/// Which SpMM kernel backend this build carries: `"portable-simd"` under
/// `--features simd`, `"unrolled"` otherwise. Purely informational — both
/// backends are bitwise-identical.
pub fn backend_name() -> &'static str {
    if cfg!(feature = "simd") {
        "portable-simd"
    } else {
        "unrolled"
    }
}

/// SIMD SpMM kernel for bundle width `k`, or `None` when this build (or
/// this width — only 1..=16 are blocked) has no SIMD kernel and the caller
/// should fall back to the unrolled/streaming family.
#[cfg(not(feature = "simd"))]
pub(crate) fn spmm_kernel(_k: usize) -> Option<RowRangeKernel> {
    None
}

/// SIMD fused-step kernel for bundle width `k` (see [`spmm_kernel`]).
#[cfg(not(feature = "simd"))]
pub(crate) fn step_kernel(_k: usize) -> Option<StepRowRangeKernel> {
    None
}

#[cfg(feature = "simd")]
pub(crate) fn spmm_kernel(k: usize) -> Option<RowRangeKernel> {
    macro_rules! widths {
        ($($w:literal),*) => {
            match k {
                $($w => Some(vec_impl::spmm_row_range_simd::<$w> as RowRangeKernel),)*
                _ => None,
            }
        };
    }
    widths!(1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16)
}

#[cfg(feature = "simd")]
pub(crate) fn step_kernel(k: usize) -> Option<StepRowRangeKernel> {
    macro_rules! widths {
        ($($w:literal),*) => {
            match k {
                $($w => Some(vec_impl::spmm_step_row_range_simd::<$w> as StepRowRangeKernel),)*
                _ => None,
            }
        };
    }
    widths!(1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16)
}

#[cfg(feature = "simd")]
mod vec_impl {
    use crate::linalg::dmat::DMat;
    use crate::linalg::sparse::{for_each_nonzero, CsrMat};
    use std::simd::Simd;

    /// Lane count: 4 × f64 (AVX2 / NEON-pair width). Portable SIMD lowers
    /// wider or narrower targets to the same lane-wise operation sequence,
    /// so the bitwise contract does not depend on the host ISA.
    const LANES: usize = 4;
    /// `K ≤ 16` ⇒ at most `16 / LANES` full vectors per row.
    const MAX_CHUNKS: usize = 16 / LANES;

    /// SIMD SpMM row-range kernel for fixed width `K`: the `[f64; K]`
    /// accumulator of the unrolled kernel becomes `K / 4` vector
    /// accumulators plus a `K % 4` scalar tail. Per output element the
    /// reduction is the identical [`for_each_nonzero`] sequence — vector
    /// lanes never interact, and mul/add stay separate (no FMA).
    pub(super) fn spmm_row_range_simd<const K: usize>(
        a: &CsrMat,
        b: &DMat,
        c_rows: &mut [f64],
        r0: usize,
        r1: usize,
    ) {
        debug_assert_eq!(b.cols(), K);
        debug_assert_eq!(a.cols(), b.rows());
        debug_assert_eq!(c_rows.len(), (r1 - r0) * K);
        let bd = b.data();
        let chunks = K / LANES;
        let rem = K % LANES;
        debug_assert!(chunks <= MAX_CHUNKS && rem < LANES);
        for i in r0..r1 {
            let mut acc = [Simd::<f64, LANES>::splat(0.0); MAX_CHUNKS];
            let mut tail = [0.0f64; LANES - 1];
            for_each_nonzero(a, i, |v, j| {
                let brow = &bd[j * K..(j + 1) * K];
                let vs = Simd::<f64, LANES>::splat(v);
                for c in 0..chunks {
                    let bv = Simd::<f64, LANES>::from_slice(&brow[c * LANES..]);
                    acc[c] = acc[c] + vs * bv;
                }
                for t in 0..rem {
                    tail[t] += v * brow[chunks * LANES + t];
                }
            });
            let out = &mut c_rows[(i - r0) * K..(i - r0 + 1) * K];
            for c in 0..chunks {
                acc[c].copy_to_slice(&mut out[c * LANES..(c + 1) * LANES]);
            }
            for t in 0..rem {
                out[chunks * LANES + t] = tail[t];
            }
        }
    }

    /// SIMD fused-step row-range kernel for fixed width `K`: the SpMM
    /// accumulation above plus the `c = c·β + α·w + γ·u` combine, both in
    /// vector registers, matching the scalar kernel's conditional skips
    /// (zero-valued `α`/`γ` terms are not applied at all).
    #[allow(clippy::too_many_arguments)]
    pub(super) fn spmm_step_row_range_simd<const K: usize>(
        a: &CsrMat,
        w: &DMat,
        u: &DMat,
        c_rows: &mut [f64],
        r0: usize,
        r1: usize,
        alpha: f64,
        beta: f64,
        gamma: f64,
    ) {
        debug_assert_eq!(w.cols(), K);
        debug_assert_eq!(a.cols(), w.rows());
        debug_assert_eq!(c_rows.len(), (r1 - r0) * K);
        let wd = w.data();
        let ud = u.data();
        let chunks = K / LANES;
        let rem = K % LANES;
        debug_assert!(chunks <= MAX_CHUNKS && rem < LANES);
        let alpha_v = Simd::<f64, LANES>::splat(alpha);
        let beta_v = Simd::<f64, LANES>::splat(beta);
        let gamma_v = Simd::<f64, LANES>::splat(gamma);
        for i in r0..r1 {
            let mut acc = [Simd::<f64, LANES>::splat(0.0); MAX_CHUNKS];
            let mut tail = [0.0f64; LANES - 1];
            for_each_nonzero(a, i, |v, j| {
                let wrow = &wd[j * K..(j + 1) * K];
                let vs = Simd::<f64, LANES>::splat(v);
                for c in 0..chunks {
                    let wv = Simd::<f64, LANES>::from_slice(&wrow[c * LANES..]);
                    acc[c] = acc[c] + vs * wv;
                }
                for t in 0..rem {
                    tail[t] += v * wrow[chunks * LANES + t];
                }
            });
            let wrow = &wd[i * K..(i + 1) * K];
            let urow = &ud[i * K..(i + 1) * K];
            let out = &mut c_rows[(i - r0) * K..(i - r0 + 1) * K];
            for c in 0..chunks {
                let mut x = acc[c] * beta_v;
                if alpha != 0.0 {
                    x = x + alpha_v * Simd::<f64, LANES>::from_slice(&wrow[c * LANES..]);
                }
                if gamma != 0.0 {
                    x = x + gamma_v * Simd::<f64, LANES>::from_slice(&urow[c * LANES..]);
                }
                x.copy_to_slice(&mut out[c * LANES..(c + 1) * LANES]);
            }
            for t in 0..rem {
                let idx = chunks * LANES + t;
                let mut x = tail[t] * beta;
                if alpha != 0.0 {
                    x += alpha * wrow[idx];
                }
                if gamma != 0.0 {
                    x += gamma * urow[idx];
                }
                out[idx] = x;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn backend_matches_compiled_features() {
        let want = if cfg!(feature = "simd") { "portable-simd" } else { "unrolled" };
        assert_eq!(super::backend_name(), want);
    }

    #[test]
    fn dispatch_agrees_with_backend() {
        // Blocked widths carry a SIMD kernel exactly when the feature is
        // on; everything else always falls back.
        for k in 1..=16usize {
            assert_eq!(super::spmm_kernel(k).is_some(), cfg!(feature = "simd"), "k={k}");
            assert_eq!(super::step_kernel(k).is_some(), cfg!(feature = "simd"), "k={k}");
        }
        for k in [0usize, 17, 64] {
            assert!(super::spmm_kernel(k).is_none(), "k={k}");
            assert!(super::step_kernel(k).is_none(), "k={k}");
        }
    }
}
