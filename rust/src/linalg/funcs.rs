//! Matrix functions: exact spectral application (via [`eigh`]), matrix
//! exponential / logarithm, Horner polynomial evaluation, and binary matrix
//! powers — the numerical machinery behind the Table 2 transforms.

use super::dmat::DMat;
use super::eigh::eigh;
use anyhow::Result;

/// Exact `f(A)` for symmetric `A` via full eigendecomposition (eq 10 of the
/// paper: `f(A) = V diag(f(λ)) Vᵀ`). O(n³); the thing SPED's series
/// approximations avoid — kept as the oracle/baseline.
pub fn spectral_apply(a: &DMat, f: impl Fn(f64) -> f64) -> Result<DMat> {
    Ok(eigh(a)?.apply_spectrum(f))
}

/// Exact matrix exponential of a symmetric matrix.
pub fn expm(a: &DMat) -> Result<DMat> {
    spectral_apply(a, f64::exp)
}

/// Exact matrix logarithm of a symmetric positive-definite matrix
/// (the paper uses `log(L + εI)` to keep the spectrum positive).
pub fn logm(a: &DMat) -> Result<DMat> {
    spectral_apply(a, |x| x.max(f64::MIN_POSITIVE).ln())
}

/// Evaluate the matrix polynomial `p(A) = Σ_i c_i A^i` by Horner's rule:
/// `((c_d A + c_{d-1} I) A + …) + c_0 I`. Exactly `deg(p)` dense multiplies.
///
/// This mirrors the L1 Pallas kernel `poly_horner` (same recurrence, same
/// coefficient order) so the native and AOT paths are interchangeable.
///
/// One implementation serves serial and parallel: this is the
/// single-worker case of [`super::par::poly_horner_par`], so the two can
/// never drift apart (the bitwise-identity contract of `linalg::par`).
pub fn poly_horner(a: &DMat, coeffs: &[f64]) -> DMat {
    super::par::poly_horner_par(a, coeffs, 1)
}

/// `A^p` by binary exponentiation (square-and-multiply): ⌈log₂ p⌉ squarings
/// plus popcount multiplies. Used for the paper's best-performing transform,
/// the limit approximation `−(I − L/ℓ)^ℓ`, where expanding to monomial
/// coefficients would be catastrophically ill-conditioned.
/// Single-worker case of [`super::par::matpow_par`].
pub fn matpow(a: &DMat, p: u64) -> DMat {
    super::par::matpow_par(a, p, 1)
}

/// Taylor coefficients of `−e^{−x}` of degree `ell`:
/// `−Σ_{i=0}^{ℓ} (−x)^i / i!` → `c_i = −(−1)^i / i!` (Table 2, row 4).
pub fn taylor_neg_exp_coeffs(ell: usize) -> Vec<f64> {
    let mut coeffs = Vec::with_capacity(ell + 1);
    let mut fact = 1.0f64;
    for i in 0..=ell {
        if i > 0 {
            fact *= i as f64;
        }
        let sign = if i % 2 == 0 { -1.0 } else { 1.0 };
        coeffs.push(sign / fact);
    }
    coeffs
}

/// Taylor coefficients of `log(x + ε)` around `x + ε = 1`, degree `ell`:
/// `Σ_{i=1}^{ℓ} (−1)^{i+1} (x + ε − 1)^i / i` (Table 2, row 2), expanded to
/// monomials in `x`. Convergent only for `|x + ε − 1| < 1` i.e. ρ(L) < 2.
pub fn taylor_log_coeffs(ell: usize, eps: f64) -> Vec<f64> {
    // p(x) = Σ_i a_i (x - s)^i with s = 1 - eps; expand via binomials.
    let s = 1.0 - eps;
    let mut mono = vec![0.0f64; ell + 1];
    // (x - s)^i coefficients built iteratively: start with [1] for i=0.
    let mut shifted = vec![0.0f64; ell + 1];
    shifted[0] = 1.0;
    for i in 1..=ell {
        // shifted ← shifted * (x - s)
        for j in (1..=i).rev() {
            shifted[j] = shifted[j - 1] - s * shifted[j];
        }
        shifted[0] *= -s;
        let a_i = if i % 2 == 1 { 1.0 } else { -1.0 } / i as f64;
        for j in 0..=i {
            mono[j] += a_i * shifted[j];
        }
    }
    mono
}

/// Estimate the largest eigenvalue of a symmetric PSD matrix by power
/// iteration (with a deterministic start vector salted by the diagonal).
/// Returns an estimate within `tol` relative error for well-separated tops,
/// and is always an underestimate ≤ λ_max; callers multiply by a safety
/// factor. Single-worker case of [`super::par::power_lambda_max_par`].
/// Errors on non-finite iterates instead of propagating poison into λ*.
pub fn power_lambda_max(a: &DMat, iters: usize) -> Result<f64> {
    super::par::power_lambda_max_par(a, iters, 1)
}

/// Gershgorin upper bound on the spectral radius of a symmetric matrix:
/// `max_i Σ_j |a_ij|`. For a graph Laplacian this gives ≤ 2·deg_max, the
/// bound the paper quotes in §5.4.
pub fn gershgorin_bound(a: &DMat) -> f64 {
    (0..a.rows())
        .map(|i| a.row(i).iter().map(|x| x.abs()).sum::<f64>())
        .fold(0.0, f64::max)
}

/// Guaranteed two-sided Gershgorin eigenvalue interval of a symmetric
/// matrix: every eigenvalue lies in `[min_i (a_ii − r_i), max_i (a_ii +
/// r_i)]` with `r_i = Σ_{j≠i} |a_ij|`. For a graph Laplacian the lower
/// edge is exactly 0 (each diagonal equals its off-diagonal row sum) — the
/// guaranteed interval the Lanczos domain estimate is clipped to. Sparse
/// counterpart: [`crate::linalg::sparse::CsrMat::gershgorin_interval`]
/// (bitwise-identical on the densified matrix).
pub fn gershgorin_interval(a: &DMat) -> (f64, f64) {
    assert!(a.is_square(), "gershgorin_interval needs a square matrix");
    let n = a.rows();
    if n == 0 {
        return (0.0, 0.0);
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for i in 0..n {
        let row = a.row(i);
        let mut radius = 0.0;
        for (j, &x) in row.iter().enumerate() {
            if j != i {
                radius += x.abs();
            }
        }
        lo = lo.min(row[i] - radius);
        hi = hi.max(row[i] + radius);
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::util::rng::Rng;

    fn random_symmetric(rng: &mut Rng, n: usize) -> DMat {
        let mut m = DMat::from_fn(n, n, |_, _| rng.normal());
        m.symmetrize();
        m
    }

    #[test]
    fn horner_matches_explicit_powers() {
        let mut rng = Rng::new(1);
        let a = random_symmetric(&mut rng, 10);
        let coeffs = [0.5, -1.0, 2.0, 0.25]; // 0.5 I - A + 2A² + 0.25A³
        let p = poly_horner(&a, &coeffs);
        let a2 = matmul(&a, &a);
        let a3 = matmul(&a2, &a);
        let mut expected = DMat::eye(10);
        expected.scale(0.5);
        expected.axpy(-1.0, &a);
        expected.axpy(2.0, &a2);
        expected.axpy(0.25, &a3);
        assert!((&p - &expected).max_abs() < 1e-9);
    }

    #[test]
    fn horner_edge_cases() {
        let a = DMat::eye(3);
        assert_eq!(poly_horner(&a, &[]).max_abs(), 0.0);
        let c0 = poly_horner(&a, &[7.0]);
        assert!((&c0 - &{ let mut m = DMat::eye(3); m.scale(7.0); m }).max_abs() < 1e-12);
    }

    #[test]
    fn matpow_matches_repeated_multiplication() {
        let mut rng = Rng::new(2);
        let mut a = random_symmetric(&mut rng, 8);
        a.scale(0.3); // keep powers bounded
        for &p in &[0u64, 1, 2, 3, 7, 11, 251] {
            let fast = matpow(&a, p);
            let mut slow = DMat::eye(8);
            for _ in 0..p.min(20) {
                slow = matmul(&slow, &a);
            }
            if p <= 20 {
                assert!((&fast - &slow).max_abs() < 1e-9, "p={p}");
            } else {
                // spot-check via spectrum: eig(A^p) == eig(A)^p
                let ea = eigh(&a).unwrap();
                let ep = eigh(&fast).unwrap();
                let mut expect: Vec<f64> = ea.values.iter().map(|&l| l.powi(p as i32)).collect();
                expect.sort_by(|x, y| x.partial_cmp(y).unwrap());
                for (got, want) in ep.values.iter().zip(expect.iter()) {
                    assert!((got - want).abs() < 1e-6 * (1.0 + want.abs()), "p={p}");
                }
            }
        }
    }

    #[test]
    fn expm_logm_inverse_on_spd() {
        let mut rng = Rng::new(3);
        let x = DMat::from_fn(12, 6, |_, _| rng.normal());
        let mut g = crate::linalg::matmul::gram(&x);
        g.add_diag(0.5); // strictly PD
        let lg = logm(&g).unwrap();
        let back = expm(&lg).unwrap();
        assert!((&back - &g).max_abs() < 1e-7);
    }

    #[test]
    fn taylor_neg_exp_matches_scalar_function() {
        // Evaluate the polynomial at scalar points and compare to -e^{-x}.
        let coeffs = taylor_neg_exp_coeffs(30);
        for &x in &[0.0f64, 0.1, 0.5, 1.0, 1.9] {
            let mut p = 0.0;
            for (i, &c) in coeffs.iter().enumerate() {
                p += c * x.powi(i as i32);
            }
            assert!((p - (-(-x as f64).exp())).abs() < 1e-10, "x={x}");
        }
    }

    #[test]
    fn taylor_log_matches_scalar_function() {
        // NOTE: ℓ=25 is near the usable limit of the *monomial* expansion —
        // binomial coefficients grow ~C(ℓ,ℓ/2) and f64 cancellation destroys
        // accuracy beyond ℓ≈30. High-degree series must use the shifted
        // SeriesForm evaluation (transforms::SeriesForm) instead.
        let eps = 0.05;
        let ell = 25;
        let coeffs = taylor_log_coeffs(ell, eps);
        for &x in &[0.0f64, 0.1, 0.5, 1.0, 1.5] {
            let mut p = 0.0;
            for (i, &c) in coeffs.iter().enumerate() {
                p += c * x.powi(i as i32);
            }
            // Truncation bound of the alternating series at r = |x+ε−1|:
            // |tail| ≤ r^{ℓ+1} / ((ℓ+1)(1−r)).
            let r = (x + eps - 1.0f64).abs();
            let bound = r.powi(ell as i32 + 1) / ((ell + 1) as f64 * (1.0 - r)) + 1e-9;
            assert!(
                (p - (x + eps).ln()).abs() < bound.max(1e-6),
                "x={x}: {p} vs {} (bound {bound})",
                (x + eps).ln()
            );
        }
    }

    #[test]
    fn taylor_log_diverges_outside_radius() {
        // Sanity: the series must be inaccurate for x+eps-1 >= 1 (paper §5.3).
        let coeffs = taylor_log_coeffs(60, 0.05);
        let x: f64 = 2.5;
        let mut p = 0.0;
        for (i, &c) in coeffs.iter().enumerate() {
            p += c * x.powi(i as i32);
        }
        assert!((p - (x + 0.05).ln()).abs() > 1.0);
    }

    #[test]
    fn power_iteration_close_to_eigh() {
        let mut rng = Rng::new(4);
        let x = DMat::from_fn(30, 20, |_, _| rng.normal());
        let g = crate::linalg::matmul::gram(&x);
        let exact = eigh(&g).unwrap().lambda_max();
        let approx = power_lambda_max(&g, 200).unwrap();
        assert!((approx - exact).abs() < 1e-6 * exact);
        assert!(approx <= exact + 1e-9);
    }

    #[test]
    fn gershgorin_is_upper_bound() {
        use crate::testkit::{check, SizeGen};
        check(6, 15, &SizeGen { lo: 1, hi: 16 }, |&n| {
            let mut rng = Rng::new(n as u64 + 50);
            let a = random_symmetric(&mut rng, n);
            let bound = gershgorin_bound(&a);
            let e = eigh(&a).unwrap();
            e.values
                .iter()
                .all(|&l| l.abs() <= bound + 1e-9)
        });
    }
}
