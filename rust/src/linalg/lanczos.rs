//! m-step symmetric Lanczos tridiagonalization — tight two-sided spectral
//! bounds for the Chebyshev domain policy.
//!
//! The Chebyshev filters behind `--basis chebyshev` are fitted on a domain
//! that must cover the spectrum of the (pre-scaled) Laplacian. The
//! historical policy ([`crate::transforms::cheb_domain`]) widens a one-sided
//! power-iteration λ_max estimate to the guaranteed Gershgorin bound —
//! safe, but **loose**: on a normalized Laplacian the Gershgorin bound is 2
//! while the true spectrum often ends well below it, and the domain's lower
//! edge is pinned at 0 even when nothing forces it to be. A loose domain is
//! free for a *full-degree* fit (the interpolant of a degree-ℓ polynomial
//! is exact on any domain) but directly wastes SpMM sweeps once the series
//! is **truncated** (`Degree::Auto`): Chebyshev coefficients decay at a
//! rate set by the domain half-width, so halving the interval roughly
//! squares the tail decay — the same tolerance is met at a visibly lower
//! degree.
//!
//! This module supplies the tight estimate: `m` steps of symmetric Lanczos
//! with **full reorthogonalization** against the (small) Krylov block,
//! started from the same deterministic index-salted vector as the power
//! iteration ([`crate::linalg::par`]). The extreme Ritz values of the
//! tridiagonal matrix converge to the extreme eigenvalues far faster than
//! power iteration (they minimize/maximize the Rayleigh quotient over the
//! whole Krylov space, not a single direction), and each extreme Ritz pair
//! `(θ, y)` carries a computable **residual bound**: some eigenvalue lies
//! within `β_{k+1}·|y_k|` of `θ` (the classical Lanczos residual identity
//! `‖A·Vy − θ·Vy‖ = β_{k+1}|y_k|`). The domain policy
//! ([`crate::transforms::DomainEstimate::Lanczos`]) widens the Ritz
//! interval by a padding scaled with that residual — a large residual
//! (slow convergence: near-degenerate spectra, tight clusters) widens the
//! padding instead of silently under-covering — and clips the result to
//! the guaranteed two-sided Gershgorin interval.
//!
//! ## Determinism contract
//!
//! Same contract as the rest of `linalg`: the start vector is
//! deterministic, every vector operation is a fixed serial reduction, and
//! the matrix–vector product is the worker-invariant [`spmv`] /
//! [`gemv_par`] — so the result is **bitwise identical** for every worker
//! count, and the dense and CSR paths are bitwise identical to each other
//! (the dense `gemv` reduction visits the same entries in the same order;
//! explicit zeros contribute `±0.0`, which never perturbs an IEEE partial
//! sum under round-to-nearest).

use super::dmat::{dot, normalize, vec_axpy, DMat};
use super::eigh::eigh;
use super::par::{deterministic_start, gemv_par};
use super::sparse::{spmv, CsrMat};
use anyhow::{bail, Result};

/// Default Lanczos step count for the domain policy: enough for the
/// extreme Ritz values of the graph spectra SPED meets to converge to well
/// below the padding, while the tridiagonalization itself stays `O(m·nnz +
/// m²·n)` — negligible next to a single ℓ-sweep operator application.
pub const DEFAULT_STEPS: usize = 32;

/// Two-sided Ritz-value bounds from a Lanczos run.
///
/// `lo`/`hi` are the extreme Ritz values — always **inside** the true
/// spectral interval `[λ_min, λ_max]`, converging to its ends. `residual`
/// is the larger of the two extreme Ritz pairs' residual bounds
/// `β_{k+1}·|y_k|`: the radius within which each extreme Ritz value is
/// guaranteed to have an eigenvalue, and the convergence diagnostic the
/// domain policy scales its safety padding by.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LanczosBounds {
    /// Smallest Ritz value (λ_min estimate, from above).
    pub lo: f64,
    /// Largest Ritz value (λ_max estimate, from below).
    pub hi: f64,
    /// Max residual bound of the two extreme Ritz pairs (`0` ⇒ exact to
    /// rounding — the Krylov space became invariant).
    pub residual: f64,
    /// Lanczos steps actually taken (< requested on breakdown).
    pub steps: usize,
}

/// The one Lanczos recurrence, parameterized by the matrix–vector product —
/// the dense ([`lanczos_bounds`]) and sparse ([`lanczos_bounds_csr`])
/// estimators both dispatch here, so their start vector, reorthogonalization
/// and Ritz extraction can never drift apart (mirroring
/// [`super::par::power_iteration_with`]).
///
/// Full reorthogonalization: after the classical three-term subtraction the
/// new direction is explicitly orthogonalized against **every** stored
/// Krylov vector. At the `m ≈ 32` block sizes the domain policy uses this
/// costs `O(m²·n)` — trivial — and removes the ghost-eigenvalue drift that
/// makes plain Lanczos bounds untrustworthy at exactly the near-degenerate
/// spectra the padding logic cares about.
pub fn lanczos_bounds_with(
    n: usize,
    steps: usize,
    matvec: impl Fn(&[f64]) -> Vec<f64>,
) -> Result<LanczosBounds> {
    if n == 0 {
        return Ok(LanczosBounds { lo: 0.0, hi: 0.0, residual: 0.0, steps: 0 });
    }
    let m = steps.max(1).min(n);
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(m);
    basis.push(deterministic_start(n));
    let mut alphas: Vec<f64> = Vec::with_capacity(m);
    let mut betas: Vec<f64> = Vec::with_capacity(m.saturating_sub(1));
    // β_{k+1}: the norm of the residual direction after the last completed
    // step — the scale of the Ritz residual bounds below.
    let mut resid_beta = 0.0;
    // Running magnitude of the recurrence coefficients: the relative scale
    // breakdown is detected against (an absolute cutoff would misfire on
    // heavily pre-scaled inputs).
    let mut coeff_scale = 0.0f64;
    for j in 0..m {
        let mut w = matvec(&basis[j]);
        let alpha = dot(&w, &basis[j]);
        // A poisoned matrix (NaN/Inf entries, e.g. a normalized Laplacian
        // built from a graph with a NaN weight) surfaces here first. Bail
        // naming the step instead of handing eigh a NaN tridiagonal and
        // silently corrupting the Chebyshev domain downstream.
        if !alpha.is_finite() {
            bail!("lanczos: non-finite diagonal coefficient α = {alpha} at step {} of {m}", j + 1);
        }
        alphas.push(alpha);
        coeff_scale = coeff_scale.max(alpha.abs());
        vec_axpy(&mut w, -alpha, &basis[j]);
        if j > 0 {
            vec_axpy(&mut w, -betas[j - 1], &basis[j - 1]);
        }
        // Full reorthogonalization against the whole Krylov block.
        for q in &basis {
            let c = dot(&w, q);
            if c != 0.0 {
                vec_axpy(&mut w, -c, q);
            }
        }
        let beta = normalize(&mut w);
        if !beta.is_finite() {
            bail!(
                "lanczos: non-finite off-diagonal coefficient β = {beta} at step {} of {m}",
                j + 1
            );
        }
        if j + 1 == m || beta <= 1e-12 * coeff_scale {
            // Requested depth reached, or breakdown: the Krylov space is
            // (numerically) invariant, so the Ritz values are exact to the
            // residual scale. Either way `beta` is β_{k+1}.
            resid_beta = beta;
            break;
        }
        coeff_scale = coeff_scale.max(beta);
        betas.push(beta);
        basis.push(w);
    }
    let k = alphas.len();
    let mut t = DMat::zeros(k, k);
    for (i, &a) in alphas.iter().enumerate() {
        t[(i, i)] = a;
    }
    for (i, &b) in betas.iter().enumerate() {
        t[(i, i + 1)] = b;
        t[(i + 1, i)] = b;
    }
    let e = eigh(&t)?;
    // Residual identity: ‖A·(V·y_i) − θ_i·(V·y_i)‖ = β_{k+1}·|y_i[k−1]|.
    let tail_lo = e.vectors[(k - 1, 0)].abs();
    let tail_hi = e.vectors[(k - 1, k - 1)].abs();
    Ok(LanczosBounds {
        lo: e.values[0],
        hi: e.values[k - 1],
        residual: resid_beta * tail_lo.max(tail_hi),
        steps: k,
    })
}

/// [`lanczos_bounds_with`] on a dense symmetric matrix, the matrix–vector
/// product row-sharded across `threads` workers. Bitwise identical to the
/// CSR path on the same matrix and for every worker count.
pub fn lanczos_bounds(a: &DMat, steps: usize, threads: usize) -> Result<LanczosBounds> {
    assert!(a.is_square(), "lanczos_bounds needs a square matrix");
    lanczos_bounds_with(a.rows(), steps, |v| gemv_par(a, v, threads))
}

/// [`lanczos_bounds_with`] on a CSR matrix — `O(m·nnz + m²·n)`, never
/// anything dense. Bitwise identical to [`lanczos_bounds`] on the
/// densified matrix and for every worker count.
pub fn lanczos_bounds_csr(a: &CsrMat, steps: usize, threads: usize) -> Result<LanczosBounds> {
    assert!(a.is_square(), "lanczos_bounds_csr needs a square matrix");
    lanczos_bounds_with(a.rows(), steps, |v| spmv(a, v, threads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, CliqueSpec};

    #[test]
    fn exact_on_diagonal_matrices() {
        // Full Krylov depth on a diagonal matrix: Ritz extremes are the
        // exact extremes, residual collapses.
        let d = DMat::diag(&[0.25, -1.5, 3.0, 0.0, 2.0]);
        let b = lanczos_bounds(&d, 16, 1).unwrap();
        assert!((b.lo - (-1.5)).abs() < 1e-10, "lo {}", b.lo);
        assert!((b.hi - 3.0).abs() < 1e-10, "hi {}", b.hi);
        assert!(b.residual < 1e-8, "residual {}", b.residual);
        assert!(b.steps <= 5);
    }

    #[test]
    fn converges_on_laplacian_and_bounds_are_interior() {
        let g = cliques(&CliqueSpec { n: 40, k: 4, max_short_circuit: 3, seed: 7 }).graph;
        let ld = g.laplacian();
        let e = crate::linalg::eigh(&ld).unwrap();
        let b = lanczos_bounds(&ld, DEFAULT_STEPS, 1).unwrap();
        // Ritz values are Rayleigh quotients: always inside the true
        // spectral interval…
        assert!(b.lo >= e.values[0] - 1e-9, "lo {} vs λ_min {}", b.lo, e.values[0]);
        assert!(b.hi <= e.lambda_max() + 1e-9, "hi {} vs λ_max {}", b.hi, e.lambda_max());
        // …and converged to its ends within the padding the domain policy
        // applies (residual-scaled plus the 1%-width floor).
        let slack = 3.0 * b.residual + 0.01 * (b.hi - b.lo) + 1e-8;
        assert!(b.lo <= e.values[0] + slack, "lo {} residual {}", b.lo, b.residual);
        assert!(b.hi >= e.lambda_max() - slack, "hi {} residual {}", b.hi, b.residual);
    }

    #[test]
    fn dense_and_csr_paths_bitwise_identical_and_worker_invariant() {
        let g = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 11 }).graph;
        let ld = g.laplacian();
        let lc = g.laplacian_csr();
        let dense = lanczos_bounds(&ld, 24, 1).unwrap();
        let sparse = lanczos_bounds_csr(&lc, 24, 1).unwrap();
        assert_eq!(dense.lo.to_bits(), sparse.lo.to_bits());
        assert_eq!(dense.hi.to_bits(), sparse.hi.to_bits());
        assert_eq!(dense.residual.to_bits(), sparse.residual.to_bits());
        assert_eq!(dense.steps, sparse.steps);
        for workers in [2usize, 8] {
            let pd = lanczos_bounds(&ld, 24, workers).unwrap();
            let ps = lanczos_bounds_csr(&lc, 24, workers).unwrap();
            assert_eq!(pd.lo.to_bits(), dense.lo.to_bits(), "{workers} workers");
            assert_eq!(pd.hi.to_bits(), dense.hi.to_bits(), "{workers} workers");
            assert_eq!(ps.lo.to_bits(), dense.lo.to_bits(), "{workers} workers");
            assert_eq!(ps.hi.to_bits(), dense.hi.to_bits(), "{workers} workers");
        }
    }

    #[test]
    fn degenerate_shapes() {
        // Empty and zero matrices: defined, zero bounds, no panic.
        let empty = CsrMat::from_triplets(0, 0, &[]);
        let b = lanczos_bounds_csr(&empty, 8, 4).unwrap();
        assert_eq!((b.lo, b.hi, b.steps), (0.0, 0.0, 0));
        let zero = DMat::zeros(3, 3);
        let b = lanczos_bounds(&zero, 8, 1).unwrap();
        assert_eq!(b.lo, 0.0);
        assert_eq!(b.hi, 0.0);
        assert!(b.residual <= 1e-300);
        // n = 1: the single Rayleigh quotient.
        let one = DMat::diag(&[2.5]);
        let b = lanczos_bounds(&one, 8, 1).unwrap();
        assert!((b.lo - 2.5).abs() < 1e-12);
        assert!((b.hi - 2.5).abs() < 1e-12);
    }

    #[test]
    fn non_finite_matvec_is_named_not_propagated() {
        // A poisoned operator must produce a contextual error naming the
        // offending step, never a LanczosBounds full of NaN.
        let err = lanczos_bounds_with(8, 16, |v| vec![f64::NAN; v.len()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("non-finite"), "{err:?}");
        assert!(err.contains("step 1"), "{err:?}");
        // Poison arriving after clean steps still names its step: a
        // diagonal matvec (no premature breakdown) that turns NaN on the
        // third application.
        let cell = std::cell::Cell::new(0usize);
        let err2 = lanczos_bounds_with(8, 16, |v| {
            cell.set(cell.get() + 1);
            v.iter()
                .enumerate()
                .map(|(i, &x)| if cell.get() >= 3 { f64::NAN } else { (i as f64 + 1.0) * x })
                .collect()
        })
        .unwrap_err()
        .to_string();
        assert!(err2.contains("non-finite"), "{err2:?}");
        assert!(err2.contains("step 3"), "{err2:?}");
    }

    #[test]
    fn tighter_than_power_iteration_on_clustered_spectra() {
        // The motivating comparison: on a community graph the power
        // estimate needs its Gershgorin widening, while the padded Lanczos
        // interval ends near the true λ_max — far below Gershgorin.
        let g = cliques(&CliqueSpec { n: 96, k: 6, max_short_circuit: 2, seed: 3 }).graph;
        let lc = g.laplacian_csr();
        let e = crate::linalg::eigh(&g.laplacian()).unwrap();
        let b = lanczos_bounds_csr(&lc, DEFAULT_STEPS, 1).unwrap();
        let gersh = lc.gershgorin_bound();
        assert!(
            b.hi + b.residual < 0.75 * gersh,
            "lanczos hi {} (+{}) not meaningfully tighter than gershgorin {gersh}",
            b.hi,
            b.residual
        );
        assert!((b.hi - e.lambda_max()).abs() < 1e-4 * e.lambda_max().max(1.0));
    }
}
