//! Orthonormalization: modified Gram–Schmidt (the re-orthogonalization step
//! inside Oja's algorithm) and a thin-QR built on it.

use super::dmat::{dot, norm, normalize, vec_axpy, DMat};

/// Orthonormalize the columns of `v` in place via modified Gram–Schmidt
/// with one re-orthogonalization pass (MGS2 — numerically sufficient for
/// the k ≤ 32 panels used here). Columns that become numerically zero are
/// replaced with fresh unit basis vectors orthogonal to the rest.
pub fn mgs_orthonormalize(v: &mut DMat) {
    let (n, k) = (v.rows(), v.cols());
    let mut cols: Vec<Vec<f64>> = (0..k).map(|j| v.col(j)).collect();
    for j in 0..k {
        // Two passes of projection-removal against previous columns.
        for _pass in 0..2 {
            for i in 0..j {
                let (head, tail) = cols.split_at_mut(j);
                let r = dot(&head[i], &tail[0]);
                vec_axpy(&mut tail[0], -r, &head[i]);
            }
        }
        if normalize(&mut cols[j]) < 1e-12 {
            // Degenerate column: substitute a canonical basis vector made
            // orthogonal to the already-fixed columns.
            for basis in 0..n {
                let mut cand = vec![0.0; n];
                cand[basis] = 1.0;
                for i in 0..j {
                    let r = dot(&cols[i], &cand);
                    vec_axpy(&mut cand, -r, &cols[i]);
                }
                if normalize(&mut cand) > 0.5 {
                    cols[j] = cand;
                    break;
                }
            }
        }
    }
    for (j, c) in cols.iter().enumerate() {
        v.set_col(j, c);
    }
}

/// Thin QR: returns `(Q, R)` with `Q` n×k orthonormal and `R` k×k upper
/// triangular such that `A = Q R` (MGS; assumes full column rank for exact
/// reconstruction, still returns a valid orthonormal Q otherwise).
pub fn qr_thin(a: &DMat) -> (DMat, DMat) {
    let (n, k) = (a.rows(), a.cols());
    let mut q_cols: Vec<Vec<f64>> = (0..k).map(|j| a.col(j)).collect();
    let mut r = DMat::zeros(k, k);
    for j in 0..k {
        for i in 0..j {
            let (head, tail) = q_cols.split_at_mut(j);
            let rij = dot(&head[i], &tail[0]);
            r[(i, j)] += rij;
            vec_axpy(&mut tail[0], -rij, &head[i]);
        }
        let nrm = normalize(&mut q_cols[j]);
        r[(j, j)] = nrm;
    }
    let mut q = DMat::zeros(n, k);
    for (j, c) in q_cols.iter().enumerate() {
        q.set_col(j, c);
    }
    (q, r)
}

/// Column-wise norm check: max |1 − ‖v_j‖|.
pub fn max_col_norm_deviation(v: &DMat) -> f64 {
    (0..v.cols())
        .map(|j| (1.0 - norm(&v.col(j))).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::util::rng::Rng;

    #[test]
    fn mgs_produces_orthonormal_columns() {
        let mut rng = Rng::new(1);
        let mut v = DMat::from_fn(40, 6, |_, _| rng.normal());
        mgs_orthonormalize(&mut v);
        let g = matmul(&v.t(), &v);
        assert!((&g - &DMat::eye(6)).max_abs() < 1e-10);
    }

    #[test]
    fn mgs_handles_dependent_columns() {
        // Second column is a multiple of the first.
        let mut v = DMat::from_fn(10, 3, |i, j| match j {
            0 => (i + 1) as f64,
            1 => 2.0 * (i + 1) as f64,
            _ => if i == 3 { 1.0 } else { 0.0 },
        });
        mgs_orthonormalize(&mut v);
        let g = matmul(&v.t(), &v);
        assert!((&g - &DMat::eye(3)).max_abs() < 1e-10);
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(2);
        let a = DMat::from_fn(20, 5, |_, _| rng.normal());
        let (q, r) = qr_thin(&a);
        let qr = matmul(&q, &r);
        assert!((&qr - &a).max_abs() < 1e-10);
        let g = matmul(&q.t(), &q);
        assert!((&g - &DMat::eye(5)).max_abs() < 1e-10);
        // R upper triangular
        for i in 0..5 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn orthonormal_input_is_fixed_point() {
        let mut rng = Rng::new(3);
        let mut v = DMat::from_fn(15, 4, |_, _| rng.normal());
        mgs_orthonormalize(&mut v);
        let before = v.clone();
        mgs_orthonormalize(&mut v);
        assert!((&v - &before).max_abs() < 1e-10);
    }

    #[test]
    fn property_projector_idempotent() {
        use crate::testkit::{check, SizeGen};
        check(5, 15, &SizeGen { lo: 2, hi: 25 }, |&n| {
            let mut rng = Rng::new(n as u64);
            let k = (n / 2).max(1);
            let mut v = DMat::from_fn(n, k, |_, _| rng.normal());
            mgs_orthonormalize(&mut v);
            // P = VVᵀ must satisfy P² == P.
            let p = matmul(&v, &v.t());
            let p2 = matmul(&p, &p);
            (&p2 - &p).max_abs() < 1e-8
        });
    }
}
