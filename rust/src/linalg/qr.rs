//! Orthonormalization: modified Gram–Schmidt (the re-orthogonalization step
//! inside Oja's algorithm) and a thin-QR built on it.
//!
//! Both entry points detect Gram–Schmidt *breakdown* — a column whose norm
//! collapses under projection because it was (numerically) linearly
//! dependent on its predecessors — and rescue it with a deterministic
//! replacement direction. Without the rescue, a duplicated or zero column
//! silently yields a zero (or cancellation-noise) Q column, which poisons
//! every consumer downstream: `subspace_error` runs `qr_thin` on its
//! inputs, and the Ritz solver's filtered basis `orth(M·V)` is routinely
//! rank-deficient when the polynomial filter annihilates guard directions.

use super::dmat::{dot, norm, normalize, vec_axpy, DMat};

/// Breakdown threshold, *relative* to the column's pre-projection norm: a
/// post-projection norm at or below `BREAKDOWN_REL · ‖a_j‖` means the
/// surviving direction is cancellation noise (≥ ten digits lost), not
/// signal. A relative test is scale-invariant — the absolute `1e-12`
/// cutoff this replaces missed duplicates at large column scales and
/// falsely rescued tiny-but-independent columns.
const BREAKDOWN_REL: f64 = 1e-10;

/// Deterministic replacement for a broken-down column: SplitMix64-hashed
/// candidates salted by the column index and attempt number, orthogonalized
/// twice against the already-fixed columns `prev`; a canonical-basis sweep
/// as fallback; the zero vector only when `prev` already spans ℝⁿ (no
/// orthogonal direction exists). A pure function of `(prev, n)` — bitwise
/// reproducible, so the crate's worker-invariance contracts survive a
/// rescue.
fn rescue_column(prev: &[Vec<f64>], n: usize) -> Vec<f64> {
    let j = prev.len() as u64;
    let orthogonalize = |mut cand: Vec<f64>| -> Option<Vec<f64>> {
        for _pass in 0..2 {
            for q in prev {
                let r = dot(q, &cand);
                vec_axpy(&mut cand, -r, q);
            }
        }
        if normalize(&mut cand) > 1e-6 {
            Some(cand)
        } else {
            None
        }
    };
    for attempt in 0..4u64 {
        let cand: Vec<f64> = (0..n)
            .map(|i| {
                let mut s = (i as u64).wrapping_mul(0xA24B_AED4_963E_E407)
                    ^ (j + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ attempt.wrapping_mul(0xD1B5_4A32_D192_ED03);
                let h = crate::util::rng::splitmix64(&mut s);
                (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64) - 0.5
            })
            .collect();
        if let Some(fixed) = orthogonalize(cand) {
            return fixed;
        }
    }
    for basis in 0..n {
        let mut cand = vec![0.0; n];
        cand[basis] = 1.0;
        if let Some(fixed) = orthogonalize(cand) {
            return fixed;
        }
    }
    vec![0.0; n]
}

/// Orthonormalize the columns of `v` in place via modified Gram–Schmidt
/// with one re-orthogonalization pass (MGS2 — numerically sufficient for
/// the k ≤ 32 panels used here). Columns that break down (norm collapsing
/// relative to their pre-projection scale) are replaced with deterministic
/// rescue directions orthogonal to the rest.
pub fn mgs_orthonormalize(v: &mut DMat) {
    mgs_orthonormalize_against(&[], v);
}

/// [`mgs_orthonormalize`] with a fixed **locked panel**: the columns of
/// `v` are additionally projected against `locked` (assumed orthonormal —
/// the Ritz solver's frozen converged pairs), which is never modified.
/// The breakdown rescue also spans the locked panel, so a rescued column
/// stays orthogonal to the deflated directions. With an empty `locked`
/// this *is* `mgs_orthonormalize` — the same operations in the same
/// order, bitwise.
pub fn mgs_orthonormalize_against(locked: &[Vec<f64>], v: &mut DMat) {
    let (n, k) = (v.rows(), v.cols());
    let l = locked.len();
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(l + k);
    cols.extend(locked.iter().cloned());
    cols.extend((0..k).map(|j| v.col(j)));
    for j in l..l + k {
        let orig = norm(&cols[j]);
        // Two passes of projection-removal against previous columns
        // (locked panel first, then the already-fixed columns of `v`).
        for _pass in 0..2 {
            for i in 0..j {
                let (head, tail) = cols.split_at_mut(j);
                let r = dot(&head[i], &tail[0]);
                vec_axpy(&mut tail[0], -r, &head[i]);
            }
        }
        if normalize(&mut cols[j]) <= BREAKDOWN_REL * orig {
            let fixed = rescue_column(&cols[..j], n);
            cols[j] = fixed;
        }
    }
    for j in 0..k {
        v.set_col(j, &cols[l + j]);
    }
}

/// Thin QR: returns `(Q, R)` with `Q` n×k orthonormal and `R` k×k upper
/// triangular such that `A = Q R`. On rank-deficient input, broken-down
/// columns get `R[j][j] = 0` (their true coefficient) and a deterministic
/// rescue direction in `Q` — so `Q` stays orthonormal *and* `Q·R`
/// reconstructs `A` to round-off either way.
pub fn qr_thin(a: &DMat) -> (DMat, DMat) {
    let (n, k) = (a.rows(), a.cols());
    let mut q_cols: Vec<Vec<f64>> = (0..k).map(|j| a.col(j)).collect();
    let mut r = DMat::zeros(k, k);
    for j in 0..k {
        let orig = norm(&q_cols[j]);
        for i in 0..j {
            let (head, tail) = q_cols.split_at_mut(j);
            let rij = dot(&head[i], &tail[0]);
            r[(i, j)] += rij;
            vec_axpy(&mut tail[0], -rij, &head[i]);
        }
        let nrm = normalize(&mut q_cols[j]);
        if nrm <= BREAKDOWN_REL * orig {
            // Breakdown: whatever direction survived the projection is
            // cancellation noise, orthogonalized only once (MGS1) — not a
            // trustworthy basis vector. Record the honest coefficient and
            // substitute a rescue direction.
            r[(j, j)] = 0.0;
            let fixed = rescue_column(&q_cols[..j], n);
            q_cols[j] = fixed;
        } else {
            r[(j, j)] = nrm;
        }
    }
    let mut q = DMat::zeros(n, k);
    for (j, c) in q_cols.iter().enumerate() {
        q.set_col(j, c);
    }
    (q, r)
}

/// Column-wise norm check: max |1 − ‖v_j‖|.
pub fn max_col_norm_deviation(v: &DMat) -> f64 {
    (0..v.cols())
        .map(|j| (1.0 - norm(&v.col(j))).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::util::rng::Rng;

    #[test]
    fn mgs_produces_orthonormal_columns() {
        let mut rng = Rng::new(1);
        let mut v = DMat::from_fn(40, 6, |_, _| rng.normal());
        mgs_orthonormalize(&mut v);
        let g = matmul(&v.t(), &v);
        assert!((&g - &DMat::eye(6)).max_abs() < 1e-10);
    }

    #[test]
    fn mgs_handles_dependent_columns() {
        // Second column is a multiple of the first.
        let mut v = DMat::from_fn(10, 3, |i, j| match j {
            0 => (i + 1) as f64,
            1 => 2.0 * (i + 1) as f64,
            _ => if i == 3 { 1.0 } else { 0.0 },
        });
        mgs_orthonormalize(&mut v);
        let g = matmul(&v.t(), &v);
        assert!((&g - &DMat::eye(3)).max_abs() < 1e-10);
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(2);
        let a = DMat::from_fn(20, 5, |_, _| rng.normal());
        let (q, r) = qr_thin(&a);
        let qr = matmul(&q, &r);
        assert!((&qr - &a).max_abs() < 1e-10);
        let g = matmul(&q.t(), &q);
        assert!((&g - &DMat::eye(5)).max_abs() < 1e-10);
        // R upper triangular
        for i in 0..5 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn qr_thin_rescues_duplicated_and_zero_columns() {
        // Column 1 duplicates column 0 and column 2 is all-zero — before
        // the breakdown rescue, Q kept a cancellation-noise column (MGS1
        // orthogonality only, ~1e-8) and a zero column respectively.
        let a = DMat::from_fn(12, 4, |i, j| match j {
            0 => ((i + 1) as f64).sin(),
            1 => ((i + 1) as f64).sin(),
            2 => 0.0,
            _ => {
                if i % 3 == 0 {
                    1.0
                } else {
                    -0.25
                }
            }
        });
        let (q, r) = qr_thin(&a);
        let g = matmul(&q.t(), &q);
        assert!((&g - &DMat::eye(4)).max_abs() < 1e-10, "Q not orthonormal");
        // Broken-down columns carry an exact zero diagonal in R, and the
        // factorization still reconstructs A.
        assert_eq!(r[(1, 1)], 0.0);
        assert_eq!(r[(2, 2)], 0.0);
        let qr = matmul(&q, &r);
        assert!((&qr - &a).max_abs() < 1e-9);
        // The rescue is a pure function: bitwise identical on a second run.
        let (q2, _) = qr_thin(&a);
        assert!(q
            .data()
            .iter()
            .zip(q2.data().iter())
            .all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn mgs_rescue_is_relative_to_column_scale() {
        // A duplicate at scale 1e8 cancels down to ~1e-8 — far above the
        // old absolute 1e-12 cutoff, so the breakdown went undetected. The
        // relative test rescues it; tiny-but-independent columns (scale
        // 1e-30) must conversely NOT be rescued away.
        let mut v = DMat::from_fn(16, 3, |i, j| {
            let base = 1e8 * (((i * i + 3) as f64).sqrt() + 1.0);
            match j {
                0 => base,
                1 => base,
                _ => (i as f64).cos(),
            }
        });
        mgs_orthonormalize(&mut v);
        let g = matmul(&v.t(), &v);
        assert!((&g - &DMat::eye(3)).max_abs() < 1e-10);

        let mut tiny = DMat::from_fn(8, 2, |i, j| {
            1e-30 * if j == 0 { (i + 1) as f64 } else { ((i * i) % 5) as f64 }
        });
        let want_dir = {
            let mut c = tiny.col(0);
            normalize(&mut c);
            c
        };
        mgs_orthonormalize(&mut tiny);
        let g2 = matmul(&tiny.t(), &tiny);
        assert!((&g2 - &DMat::eye(2)).max_abs() < 1e-10);
        // Column 0's direction survived (no spurious rescue).
        let align = dot(&tiny.col(0), &want_dir).abs();
        assert!(align > 1.0 - 1e-10, "independent tiny column was clobbered: {align}");
    }

    #[test]
    fn mgs_against_locked_panel_keeps_both_orthogonal() {
        let mut rng = Rng::new(7);
        // Build an orthonormal locked panel of 3 columns.
        let mut lk = DMat::from_fn(30, 3, |_, _| rng.normal());
        mgs_orthonormalize(&mut lk);
        let locked: Vec<Vec<f64>> = (0..3).map(|j| lk.col(j)).collect();
        // Active block deliberately contaminated with locked directions
        // plus a column duplicating locked[0] exactly (breakdown path).
        let mut v = DMat::from_fn(30, 4, |i, j| match j {
            0 => locked[0][i],
            _ => rng.normal() + 0.5 * locked[j % 3][i],
        });
        mgs_orthonormalize_against(&locked, &mut v);
        // Active columns are orthonormal among themselves...
        let g = matmul(&v.t(), &v);
        assert!((&g - &DMat::eye(4)).max_abs() < 1e-10);
        // ...and orthogonal to every locked column (duplicate included —
        // the rescue spans the locked panel).
        for j in 0..4 {
            for q in &locked {
                assert!(dot(q, &v.col(j)).abs() < 1e-10, "col {j} not ⊥ locked");
            }
        }
        // Locked panel untouched, and the empty-panel form is the plain
        // orthonormalizer bitwise.
        let mut a = DMat::from_fn(20, 3, |_, _| rng.normal());
        let mut b = a.clone();
        mgs_orthonormalize(&mut a);
        mgs_orthonormalize_against(&[], &mut b);
        assert!(a
            .data()
            .iter()
            .zip(b.data().iter())
            .all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn mgs_rescues_all_zero_block_to_full_orthonormal_basis() {
        let mut v = DMat::zeros(9, 4);
        mgs_orthonormalize(&mut v);
        let g = matmul(&v.t(), &v);
        assert!((&g - &DMat::eye(4)).max_abs() < 1e-10);
    }

    #[test]
    fn orthonormal_input_is_fixed_point() {
        let mut rng = Rng::new(3);
        let mut v = DMat::from_fn(15, 4, |_, _| rng.normal());
        mgs_orthonormalize(&mut v);
        let before = v.clone();
        mgs_orthonormalize(&mut v);
        assert!((&v - &before).max_abs() < 1e-10);
    }

    #[test]
    fn property_projector_idempotent() {
        use crate::testkit::{check, SizeGen};
        check(5, 15, &SizeGen { lo: 2, hi: 25 }, |&n| {
            let mut rng = Rng::new(n as u64);
            let k = (n / 2).max(1);
            let mut v = DMat::from_fn(n, k, |_, _| rng.normal());
            mgs_orthonormalize(&mut v);
            // P = VVᵀ must satisfy P² == P.
            let p = matmul(&v, &v.t());
            let p2 = matmul(&p, &p);
            (&p2 - &p).max_abs() < 1e-8
        });
    }
}
