//! Cache-blocked dense matrix multiplication.
//!
//! This is the L3 hot path when spectral transforms are built natively
//! (each Horner term is one `n×n` multiply). The kernel packs nothing but
//! iterates in an i-k-j loop order over `BLOCK`-sized tiles so the innermost
//! loop is a contiguous `axpy` over rows of `B` — autovectorizes well and is
//! friendly to a single-core cache hierarchy. See EXPERIMENTS.md §Perf for
//! measured before/after of the blocking.

use super::dmat::DMat;

/// Tile edge (f64 elements). 64×64 tiles → 3 × 32 KiB working set, fits L1+L2.
const BLOCK: usize = 64;

/// `C = A · B`.
pub fn matmul(a: &DMat, b: &DMat) -> DMat {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let mut c = DMat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A · B` into an existing buffer (C is overwritten).
pub fn matmul_into(a: &DMat, b: &DMat, c: &mut DMat) {
    let (m, kk, n) = (a.rows(), a.cols(), b.cols());
    assert_eq!(kk, b.rows());
    assert_eq!((c.rows(), c.cols()), (m, n));
    matmul_row_range(a, b, c.data_mut(), 0, m);
}

/// Row-range kernel: compute C rows `r0..r1` into `c_rows`, a buffer
/// holding exactly those rows (`(r1 − r0) × B.cols()` elements, row-major).
///
/// This is the unit of work both the serial path (full range) and the
/// row-sharded parallel path ([`super::par`]) dispatch — one shared inner
/// loop is what makes the parallel output *bitwise identical* to serial:
/// each C row is a sum accumulated in exactly the same order regardless of
/// which shard computes it.
pub(crate) fn matmul_row_range(a: &DMat, b: &DMat, c_rows: &mut [f64], r0: usize, r1: usize) {
    let (kk, n) = (a.cols(), b.cols());
    debug_assert_eq!(kk, b.rows());
    debug_assert!(r0 <= r1 && r1 <= a.rows());
    debug_assert_eq!(c_rows.len(), (r1 - r0) * n);
    if n <= 16 {
        // Skinny right-hand side (the solver hot loop: V has k ≤ 8
        // columns). The generic 64-wide j-blocking wastes its tile there;
        // this path keeps a C-row accumulator in registers and streams A's
        // row and B contiguously — measured ~2× over the blocked kernel at
        // n=8 (EXPERIMENTS.md §Perf).
        matmul_skinny_range(a, b, c_rows, r0, r1);
        return;
    }
    c_rows.fill(0.0);
    let ad = a.data();
    let bd = b.data();
    for i0 in (r0..r1).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(r1);
        for k0 in (0..kk).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(kk);
            for j0 in (0..n).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(n);
                for i in i0..i1 {
                    let arow = &ad[i * kk..(i + 1) * kk];
                    let crow = &mut c_rows[(i - r0) * n + j0..(i - r0) * n + j1];
                    for k in k0..k1 {
                        let aik = arow[k];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &bd[k * n + j0..k * n + j1];
                        // contiguous axpy: crow += aik * brow
                        for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
}

/// Skinny-B kernel over rows `r0..r1`: `B.cols() ≤ 16`. One C-row
/// accumulator lives in registers across the whole k-reduction; B rows are
/// contiguous.
pub(crate) fn matmul_skinny_range(a: &DMat, b: &DMat, c_rows: &mut [f64], r0: usize, r1: usize) {
    let (kk, n) = (a.cols(), b.cols());
    debug_assert!(n <= 16);
    let ad = a.data();
    let bd = b.data();
    let mut acc = [0.0f64; 16];
    for i in r0..r1 {
        acc[..n].fill(0.0);
        let arow = &ad[i * kk..(i + 1) * kk];
        for k in 0..kk {
            let aik = arow[k];
            if aik == 0.0 {
                continue;
            }
            let brow = &bd[k * n..(k + 1) * n];
            for (t, &bv) in brow.iter().enumerate() {
                acc[t] += aik * bv;
            }
        }
        c_rows[(i - r0) * n..(i - r0 + 1) * n].copy_from_slice(&acc[..n]);
    }
}

/// `C = Aᵀ · A` (Gram matrix), exploiting symmetry (half the FLOPs).
pub fn gram(a: &DMat) -> DMat {
    let (m, n) = (a.rows(), a.cols());
    let mut c = DMat::zeros(n, n);
    for r in 0..m {
        let row = a.row(r);
        for i in 0..n {
            let ri = row[i];
            if ri == 0.0 {
                continue;
            }
            for j in i..n {
                c[(i, j)] += ri * row[j];
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            c[(i, j)] = c[(j, i)];
        }
    }
    c
}

/// `y = A · x` (matrix–vector).
pub fn gemv(a: &DMat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    let mut y = vec![0.0; a.rows()];
    gemv_row_range(a, x, &mut y, 0, a.rows());
    y
}

/// Row-range gemv kernel: `y_rows[i − r0] = A[i,:]·x` for `i ∈ r0..r1`.
/// Shared by the serial path and the row-sharded parallel path so both
/// produce bitwise-identical results.
pub(crate) fn gemv_row_range(a: &DMat, x: &[f64], y_rows: &mut [f64], r0: usize, r1: usize) {
    debug_assert_eq!(a.cols(), x.len());
    debug_assert_eq!(y_rows.len(), r1 - r0);
    for i in r0..r1 {
        y_rows[i - r0] = super::dmat::dot(a.row(i), x);
    }
}

/// `y = Aᵀ · x`.
pub fn gemv_t(a: &DMat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for i in 0..a.rows() {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        super::dmat::vec_axpy(&mut y, xi, a.row(i));
    }
    y
}

/// Reference (naive) multiply — used only by tests to validate the blocked
/// kernel.
pub fn matmul_naive(a: &DMat, b: &DMat) -> DMat {
    assert_eq!(a.cols(), b.rows());
    let (m, kk, n) = (a.rows(), a.cols(), b.cols());
    let mut c = DMat::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..kk {
                s += a[(i, k)] * b[(k, j)];
            }
            c[(i, j)] = s;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> DMat {
        DMat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (64, 64, 64), (65, 33, 17), (130, 70, 129)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let c1 = matmul(&a, &b);
            let c2 = matmul_naive(&a, &b);
            assert!((&c1 - &c2).max_abs() < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(2);
        let a = random_mat(&mut rng, 20, 20);
        let i = DMat::eye(20);
        assert!((&matmul(&a, &i) - &a).max_abs() < 1e-12);
        assert!((&matmul(&i, &a) - &a).max_abs() < 1e-12);
    }

    #[test]
    fn gram_matches_full() {
        let mut rng = Rng::new(3);
        let a = random_mat(&mut rng, 30, 7);
        let g1 = gram(&a);
        let g2 = matmul(&a.t(), &a);
        assert!((&g1 - &g2).max_abs() < 1e-10);
        assert!(g1.is_symmetric(1e-12));
    }

    #[test]
    fn gemv_consistency() {
        let mut rng = Rng::new(4);
        let a = random_mat(&mut rng, 12, 9);
        let x: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let y = gemv(&a, &x);
        let xm = DMat::from_vec(9, 1, x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..12 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-12);
        }
        // gemv_t(a, y) == aᵀ y
        let z = gemv_t(&a, &y);
        let zm = matmul(&a.t(), &ym);
        for j in 0..9 {
            assert!((z[j] - zm[(j, 0)]).abs() < 1e-10);
        }
    }

    #[test]
    fn associativity_property() {
        // (AB)C == A(BC) — property-test over random shapes.
        use crate::testkit::{check, SizeGen};
        check(7, 20, &SizeGen { lo: 1, hi: 24 }, |&n| {
            let mut rng = Rng::new(n as u64 + 100);
            let a = random_mat(&mut rng, n, n + 1);
            let b = random_mat(&mut rng, n + 1, n / 2 + 1);
            let c = random_mat(&mut rng, n / 2 + 1, n);
            let lhs = matmul(&matmul(&a, &b), &c);
            let rhs = matmul(&a, &matmul(&b, &c));
            (&lhs - &rhs).max_abs() < 1e-8
        });
    }
}
