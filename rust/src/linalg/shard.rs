//! Graph-sharded SpMM with explicit halo exchange.
//!
//! The row-sharded kernels in [`super::sparse`] assume every worker can
//! read the whole bundle — true for threads in one address space, false
//! for anything distributed. This module is the stepping stone from
//! threads-on-one-box to multi-process execution (the distributed
//! dimension of the Block Chebyshev–Davidson line of work): CSR rows are
//! partitioned into `S` contiguous shards, and each shard's matrix block
//! is rewritten against a **local panel** containing only the bundle rows
//! the shard actually touches — its own row range plus the **halo** of
//! boundary rows owned by other shards. An apply is then two phases:
//!
//! 1. **Halo exchange** — every shard gathers its local panel from the
//!    owning shards' slices of the bundle ([`HaloPlan`] says exactly which
//!    rows cross shard boundaries; with RCM reordering the graph bandwidth
//!    is small, so halos are thin).
//! 2. **Independent per-shard SpMM** — each shard multiplies its local
//!    block against its local panel into its own output rows, with zero
//!    shared reads. In-process the phases are function calls; across
//!    processes phase 1 becomes the only message traffic.
//!
//! ## Bitwise contract
//!
//! The local column remap is **order-preserving** (global columns map to
//! their rank in the sorted own ∪ halo set), so each local row stores the
//! same values in the same ascending order as the unsharded matrix, and
//! the per-shard kernel is the same [`super::sparse`] row-range kernel.
//! Per output element the floating-point reduction is therefore the
//! identical sequence — [`ShardedCsr::apply`] is **bitwise equal** to
//! [`super::sparse::spmm`] at every (shard count, worker count)
//! combination, empty shards and isolated nodes included (pinned by
//! `tests/kernel_equivalence.rs`).

use super::dmat::DMat;
use super::par::{row_shards, shard_starts};
use super::sparse::{kernel_for_width, spmm_step_into, CsrMat};
use crate::util::pool::parallel_shards;

/// Which bundle rows each shard must receive from outside its own row
/// range before it can run its local SpMM — the message plan a
/// multi-process transport would execute.
#[derive(Clone, Debug)]
pub struct HaloPlan {
    /// `recv[s]`: the global bundle-row indices shard `s` needs but does
    /// not own, ascending. In-process these are gathered by copy; across
    /// processes each index names one row-of-k-floats message.
    pub recv: Vec<Vec<usize>>,
}

impl HaloPlan {
    /// Total halo rows exchanged per apply (the transport volume is this
    /// many `k`-float rows).
    pub fn halo_rows(&self) -> usize {
        self.recv.iter().map(|r| r.len()).sum()
    }
}

/// One shard: a contiguous output-row range and its matrix block rewritten
/// against the local panel index space.
#[derive(Clone, Debug)]
struct Shard {
    /// First global row this shard owns.
    row_start: usize,
    /// Rows owned (possibly 0 — shards stay addressable even when the
    /// partition hands them nothing, unlike the thread-pool row split).
    rows: usize,
    /// `rows × panel_rows.len()` block with columns remapped into local
    /// panel space, order-preservingly.
    local: CsrMat,
    /// Global bundle-row index of each local panel row, ascending:
    /// the sorted union of the own range and the halo.
    panel_rows: Vec<usize>,
}

impl Shard {
    /// Phase 1 for this shard: gather the local panel (own rows + halo
    /// rows) out of the global bundle.
    fn gather_panel(&self, b: &DMat) -> DMat {
        let k = b.cols();
        let mut p = DMat::zeros(self.panel_rows.len(), k);
        let (bd, pd) = (b.data(), p.data_mut());
        for (li, &gi) in self.panel_rows.iter().enumerate() {
            pd[li * k..(li + 1) * k].copy_from_slice(&bd[gi * k..(gi + 1) * k]);
        }
        p
    }
}

/// A square CSR matrix partitioned into `S` row shards with an explicit
/// halo-exchange plan (see the module docs).
#[derive(Clone, Debug)]
pub struct ShardedCsr {
    n: usize,
    shards: Vec<Shard>,
    /// The boundary-row exchange plan, exposed for diagnostics and for a
    /// future multi-process transport.
    pub halo_plan: HaloPlan,
}

impl ShardedCsr {
    /// Partition `a`'s rows into `s` contiguous shards (first shards take
    /// the remainder; shards past the row count come out empty, so any
    /// `s ≥ 1` is valid for any size) and precompute each shard's local
    /// block + halo plan. `a` must be square — the halo notion pairs
    /// matrix columns with owned bundle rows.
    pub fn partition(a: &CsrMat, s: usize) -> ShardedCsr {
        assert!(s >= 1, "shard count must be at least 1");
        assert!(a.is_square(), "sharding needs a square operator");
        let n = a.rows();
        let base = n / s;
        let rem = n % s;
        let mut shards = Vec::with_capacity(s);
        let mut recv = Vec::with_capacity(s);
        let mut start = 0usize;
        for i in 0..s {
            let rows = base + usize::from(i < rem);
            let end = start + rows;
            // Halo: every column referenced outside the own range.
            let mut halo: Vec<usize> = Vec::new();
            for r in start..end {
                for &c in a.row(r).0 {
                    let c = c as usize;
                    if c < start || c >= end {
                        halo.push(c);
                    }
                }
            }
            halo.sort_unstable();
            halo.dedup();
            // Local panel rows: sorted union of halo and the own range —
            // halo-below, then own, then halo-above keeps global order.
            let split = halo.partition_point(|&c| c < start);
            let mut panel_rows = Vec::with_capacity(halo.len() + rows);
            panel_rows.extend_from_slice(&halo[..split]);
            panel_rows.extend(start..end);
            panel_rows.extend_from_slice(&halo[split..]);
            // Remap columns into panel space. The map is monotone, so the
            // local rows keep strictly-increasing columns and the local
            // block passes `CsrMat::new` validation.
            let mut indptr = Vec::with_capacity(rows + 1);
            indptr.push(0usize);
            let mut indices: Vec<u32> = Vec::new();
            let mut values: Vec<f64> = Vec::new();
            for r in start..end {
                let (cols, vals) = a.row(r);
                for (&c, &v) in cols.iter().zip(vals) {
                    let local = panel_rows
                        .binary_search(&(c as usize))
                        .expect("every referenced column is in the panel");
                    indices.push(local as u32);
                    values.push(v);
                }
                indptr.push(indices.len());
            }
            let local = CsrMat::new(rows, panel_rows.len(), indptr, indices, values);
            shards.push(Shard { row_start: start, rows, local, panel_rows });
            recv.push(halo);
            start = end;
        }
        debug_assert_eq!(start, n, "shards must tile the rows");
        ShardedCsr { n, shards, halo_plan: HaloPlan { recv } }
    }

    pub fn rows(&self) -> usize {
        self.n
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Rows owned per shard (zeros included).
    pub fn shard_lens(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.rows).collect()
    }

    /// `C = A · B` through the two-phase sharded path. Phase 1 gathers
    /// every shard's local panel (the halo exchange); phase 2 runs the
    /// per-shard SpMMs concurrently into disjoint output row ranges, each
    /// shard further row-split across up to `threads` workers. Bitwise
    /// equal to [`super::sparse::spmm`] for every (S, threads).
    pub fn apply(&self, b: &DMat, threads: usize) -> DMat {
        let mut c = DMat::zeros(self.n, b.cols());
        self.apply_into(b, &mut c, threads);
        c
    }

    /// [`Self::apply`] into an existing buffer.
    pub fn apply_into(&self, b: &DMat, c: &mut DMat, threads: usize) {
        assert_eq!(self.n, b.rows(), "sharded spmm shape mismatch");
        let k = b.cols();
        assert_eq!((c.rows(), c.cols()), (self.n, k), "sharded spmm output shape mismatch");
        // Phase 1: halo exchange — assemble each shard's local panel.
        let panels: Vec<DMat> = self.shards.iter().map(|sh| sh.gather_panel(b)).collect();
        // Phase 2: independent per-shard SpMM. Each shard's own rows are
        // further split across `threads` sub-ranges; the flattened
        // (shard, sub-range) spans tile the output exactly, keeping empty
        // shards in the tiling so output rows stay aligned.
        let kernel = kernel_for_width(k);
        let mut lens: Vec<usize> = Vec::new();
        let mut spans: Vec<(usize, usize, usize)> = Vec::new();
        for (si, sh) in self.shards.iter().enumerate() {
            let subs = row_shards(sh.rows, threads);
            if subs.is_empty() {
                lens.push(0);
                spans.push((si, 0, 0));
                continue;
            }
            for (&len, &r0) in subs.iter().zip(shard_starts(&subs).iter()) {
                lens.push(len * k);
                spans.push((si, r0, r0 + len));
            }
        }
        parallel_shards(c.data_mut(), &lens, |idx, chunk| {
            let (si, r0, r1) = spans[idx];
            if r0 == r1 {
                return;
            }
            kernel(&self.shards[si].local, &panels[si], chunk, r0, r1);
        });
    }

    /// First global row owned by shard `s` (diagnostics).
    pub fn shard_row_start(&self, s: usize) -> usize {
        self.shards[s].row_start
    }

    /// Fused solver step `C = α·W + β·(A·W) + γ·U` through the two-phase
    /// sharded path — the sharded counterpart of
    /// [`super::sparse::spmm_step_into`], and **bitwise equal** to it at
    /// every (shard count, worker count): phase 2 runs the same
    /// [`kernel_for_width`] accumulation per row (identical CSR-order,
    /// zero-skipping reduction — the local remap preserves entry order),
    /// and the α/β/γ combine then applies the identical operation sequence
    /// per element. Only the bundle `W` needs a halo exchange; the α·W and
    /// γ·U terms read each shard's *own* rows, which it already holds.
    #[allow(clippy::too_many_arguments)]
    pub fn step_into(
        &self,
        w: &DMat,
        u: &DMat,
        alpha: f64,
        beta: f64,
        gamma: f64,
        c: &mut DMat,
        threads: usize,
    ) {
        assert_eq!(self.n, w.rows(), "sharded step shape mismatch");
        let k = w.cols();
        assert_eq!((u.rows(), u.cols()), (self.n, k), "sharded step U shape mismatch");
        assert_eq!((c.rows(), c.cols()), (self.n, k), "sharded step output shape mismatch");
        // Phase 1: halo exchange — one gather of W per sweep; U never
        // crosses shard boundaries.
        let panels: Vec<DMat> = self.shards.iter().map(|sh| sh.gather_panel(w)).collect();
        // Phase 2: per-shard SpMM accumulation + in-chunk α/β/γ combine.
        let kernel = kernel_for_width(k);
        let mut lens: Vec<usize> = Vec::new();
        let mut spans: Vec<(usize, usize, usize)> = Vec::new();
        for (si, sh) in self.shards.iter().enumerate() {
            let subs = row_shards(sh.rows, threads);
            if subs.is_empty() {
                lens.push(0);
                spans.push((si, 0, 0));
                continue;
            }
            for (&len, &r0) in subs.iter().zip(shard_starts(&subs).iter()) {
                lens.push(len * k);
                spans.push((si, r0, r0 + len));
            }
        }
        let wd = w.data();
        let ud = u.data();
        parallel_shards(c.data_mut(), &lens, |idx, chunk| {
            let (si, r0, r1) = spans[idx];
            if r0 == r1 {
                return;
            }
            let sh = &self.shards[si];
            kernel(&sh.local, &panels[si], chunk, r0, r1);
            // Combine against the globally-indexed own rows of W and U —
            // the same `x = acc·β; x += α·w; x += γ·u` sequence (with the
            // zero-coefficient skips) as the fused unsharded kernel.
            for lr in 0..(r1 - r0) {
                let gi = sh.row_start + r0 + lr;
                let crow = &mut chunk[lr * k..(lr + 1) * k];
                let wrow = &wd[gi * k..(gi + 1) * k];
                let urow = &ud[gi * k..(gi + 1) * k];
                for t in 0..k {
                    let mut x = crow[t] * beta;
                    if alpha != 0.0 {
                        x += alpha * wrow[t];
                    }
                    if gamma != 0.0 {
                        x += gamma * urow[t];
                    }
                    crow[t] = x;
                }
            }
        });
    }
}

/// The operator a polynomial bundle apply iterates against: either the
/// plain CSR matrix (every fused step one [`spmm_step_into`] pass) or a
/// [`ShardedCsr`] (every fused step one halo exchange + per-shard pass).
/// This is the dispatch seam that routes the sharded schedule underneath
/// `SparsePolyOp`'s three series evaluators — Horner, the Chebyshev
/// recurrence, and the NegPower repeated multiply — without touching their
/// recurrence code. The two variants are bitwise-equal, so which one a
/// pipeline runs is observable only through the halo accounting.
#[derive(Clone, Copy)]
pub enum StepOperand<'a> {
    /// The unsharded CSR path.
    Csr(&'a CsrMat),
    /// The shard-partitioned two-phase path.
    Sharded(&'a ShardedCsr),
}

impl StepOperand<'_> {
    /// Operator dimension (rows = cols; both variants are square).
    pub fn rows(&self) -> usize {
        match self {
            StepOperand::Csr(a) => a.rows(),
            StepOperand::Sharded(s) => s.rows(),
        }
    }

    /// Fused step `C = α·W + β·(A·W) + γ·U` on whichever variant this is.
    #[allow(clippy::too_many_arguments)]
    pub fn step_into(
        &self,
        w: &DMat,
        u: &DMat,
        alpha: f64,
        beta: f64,
        gamma: f64,
        c: &mut DMat,
        threads: usize,
    ) {
        match self {
            StepOperand::Csr(a) => spmm_step_into(a, w, u, alpha, beta, gamma, c, threads),
            StepOperand::Sharded(s) => s.step_into(w, u, alpha, beta, gamma, c, threads),
        }
    }

    /// Halo rows one sweep exchanges (0 for the unsharded variant).
    pub fn halo_rows(&self) -> usize {
        match self {
            StepOperand::Csr(_) => 0,
            StepOperand::Sharded(s) => s.halo_plan.halo_rows(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sparse::spmm;
    use crate::util::rng::Rng;

    fn random_bundle(seed: u64, r: usize, c: usize) -> DMat {
        let mut rng = Rng::new(seed);
        DMat::from_fn(r, c, |_, _| rng.normal())
    }

    fn bitwise_eq(a: &DMat, b: &DMat) -> bool {
        a.rows() == b.rows()
            && a.cols() == b.cols()
            && a.data().iter().zip(b.data().iter()).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn sharded_apply_bitwise_matches_unsharded() {
        let g = crate::graph::gen::cliques(&crate::graph::gen::CliqueSpec {
            n: 48,
            k: 4,
            max_short_circuit: 5,
            seed: 11,
        })
        .graph;
        let l = g.laplacian_csr();
        for &s in &[1usize, 2, 3, 7] {
            let sharded = ShardedCsr::partition(&l, s);
            assert_eq!(sharded.shard_lens().iter().sum::<usize>(), 48);
            for k in [1usize, 8, 17] {
                let b = random_bundle(k as u64 + 7, 48, k);
                let want = spmm(&l, &b, 1);
                for &workers in &[1usize, 2, 8] {
                    let got = sharded.apply(&b, workers);
                    assert!(bitwise_eq(&got, &want), "S={s}, k={k}, {workers} workers");
                }
            }
        }
    }

    #[test]
    fn more_shards_than_rows_keeps_empty_shards_addressable() {
        // n = 5, S = 7: shards 5 and 6 own zero rows but stay in the
        // partition (and contribute nothing to the output).
        let l = CsrMat::from_triplets(
            5,
            5,
            &[(0, 0, 1.0), (0, 4, -1.0), (2, 2, 2.0), (4, 0, -1.0), (4, 4, 1.0)],
        );
        let sharded = ShardedCsr::partition(&l, 7);
        assert_eq!(sharded.shard_count(), 7);
        assert_eq!(sharded.shard_lens(), vec![1, 1, 1, 1, 1, 0, 0]);
        let b = random_bundle(3, 5, 4);
        let want = spmm(&l, &b, 1);
        for &workers in &[1usize, 4] {
            assert!(bitwise_eq(&sharded.apply(&b, workers), &want));
        }
    }

    #[test]
    fn halo_plan_names_exactly_the_boundary_rows() {
        // Ring 0-1-2-3: split into two shards of two rows each; each
        // shard's halo is its two cross-boundary neighbours.
        let l = CsrMat::from_triplets(
            4,
            4,
            &[
                (0, 0, 2.0),
                (0, 1, -1.0),
                (0, 3, -1.0),
                (1, 0, -1.0),
                (1, 1, 2.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 2.0),
                (2, 3, -1.0),
                (3, 0, -1.0),
                (3, 2, -1.0),
                (3, 3, 2.0),
            ],
        );
        let sharded = ShardedCsr::partition(&l, 2);
        assert_eq!(sharded.halo_plan.recv, vec![vec![2, 3], vec![0, 1]]);
        assert_eq!(sharded.halo_plan.halo_rows(), 4);
        let b = random_bundle(5, 4, 3);
        assert!(bitwise_eq(&sharded.apply(&b, 2), &spmm(&l, &b, 1)));
    }

    #[test]
    fn isolated_nodes_and_structural_zeros_survive_sharding() {
        // Node 1 is fully isolated (no stored entries at all), node 0
        // carries only a structural zero diagonal.
        let l = CsrMat::from_triplets(
            6,
            6,
            &[(0, 0, 0.0), (2, 2, 1.0), (2, 5, -1.0), (5, 2, -1.0), (5, 5, 1.0)],
        );
        for &s in &[1usize, 2, 7] {
            let sharded = ShardedCsr::partition(&l, s);
            let b = random_bundle(9, 6, 8);
            let want = spmm(&l, &b, 1);
            for &workers in &[1usize, 2, 8] {
                let got = sharded.apply(&b, workers);
                assert!(bitwise_eq(&got, &want), "S={s}, {workers} workers");
                for row in [0usize, 1, 3, 4] {
                    assert!(got.row(row).iter().all(|x| x.to_bits() == 0), "row {row}");
                }
            }
        }
    }

    #[test]
    fn sharded_step_bitwise_matches_fused_kernel() {
        // The full α/β/γ surface the three series evaluators use: Horner
        // (−shift, 1, c), NegPower (1, −1/ℓ, 0), Chebyshev (2b, 2a, −1).
        let g = crate::graph::gen::cliques(&crate::graph::gen::CliqueSpec {
            n: 48,
            k: 4,
            max_short_circuit: 5,
            seed: 11,
        })
        .graph;
        let l = g.laplacian_csr();
        let combos = [(0.0, 1.0, 0.25), (1.0, -1.0 / 51.0, 0.0), (0.8, -1.6, -1.0)];
        for &s in &[1usize, 2, 3, 7] {
            let sharded = ShardedCsr::partition(&l, s);
            for k in [1usize, 8, 17] {
                let w = random_bundle(k as u64 + 7, 48, k);
                let u = random_bundle(k as u64 + 31, 48, k);
                for &(alpha, beta, gamma) in &combos {
                    let want = crate::linalg::sparse::spmm_step(&l, &w, &u, alpha, beta, gamma, 1);
                    for &workers in &[1usize, 2, 8] {
                        let mut got = DMat::zeros(48, k);
                        sharded.step_into(&w, &u, alpha, beta, gamma, &mut got, workers);
                        assert!(
                            bitwise_eq(&got, &want),
                            "S={s}, k={k}, {workers} workers, ({alpha},{beta},{gamma})"
                        );
                        let mut via = DMat::zeros(48, k);
                        StepOperand::Sharded(&sharded)
                            .step_into(&w, &u, alpha, beta, gamma, &mut via, workers);
                        assert!(bitwise_eq(&via, &want), "operand dispatch diverged");
                    }
                }
            }
        }
        // The unsharded operand variant is the fused kernel itself.
        let w = random_bundle(3, 48, 5);
        let u = random_bundle(4, 48, 5);
        let want = crate::linalg::sparse::spmm_step(&l, &w, &u, 0.5, 1.0, -0.25, 1);
        let mut got = DMat::zeros(48, 5);
        let op = StepOperand::Csr(&l);
        assert_eq!(op.rows(), 48);
        assert_eq!(op.halo_rows(), 0);
        op.step_into(&w, &u, 0.5, 1.0, -0.25, &mut got, 4);
        assert!(bitwise_eq(&got, &want));
    }

    #[test]
    fn empty_matrix_partitions() {
        let l = CsrMat::from_triplets(0, 0, &[]);
        let sharded = ShardedCsr::partition(&l, 3);
        assert_eq!(sharded.shard_lens(), vec![0, 0, 0]);
        assert_eq!(sharded.halo_plan.halo_rows(), 0);
        let b = DMat::zeros(0, 4);
        let got = sharded.apply(&b, 2);
        assert_eq!((got.rows(), got.cols()), (0, 4));
    }
}
