//! Row-major dense `f64` matrices.
//!
//! The workhorse container of the L3 coordinator. Eigenvector bundles are
//! stored as `n × k` matrices whose *columns* are eigenvectors, matching the
//! conventions of the paper (V ∈ ℝ^{|V|×k}) and the L2 JAX model.

use std::fmt;

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct DMat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DMat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> DMat {
        DMat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> DMat {
        let mut m = DMat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from an existing buffer (row-major, length must match).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> DMat {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        DMat { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> DMat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        DMat { rows, cols, data }
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f64]) -> DMat {
        let mut m = DMat::zeros(d.len(), d.len());
        for (i, &x) in d.iter().enumerate() {
            m[(i, i)] = x;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }
    pub fn data(&self) -> &[f64] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column copy (rows are contiguous, columns are not).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Transpose.
    pub fn t(&self) -> DMat {
        let mut out = DMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// In-place scale.
    pub fn scale(&mut self, a: f64) {
        for x in &mut self.data {
            *x *= a;
        }
    }

    /// `self + a·other` (element-wise), in place.
    pub fn axpy(&mut self, a: f64, other: &DMat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, &y) in self.data.iter_mut().zip(other.data.iter()) {
            *x += a * y;
        }
    }

    /// Add `a` to the diagonal in place.
    pub fn add_diag(&mut self, a: f64) {
        assert!(self.is_square());
        for i in 0..self.rows {
            self[(i, i)] += a;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }

    /// Symmetry check within tolerance.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if !self.is_square() {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Force exact symmetry: `(A + Aᵀ)/2`, in place.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    /// First `k` columns as a new matrix.
    pub fn take_cols(&self, k: usize) -> DMat {
        assert!(k <= self.cols);
        DMat::from_fn(self.rows, k, |i, j| self[(i, j)])
    }

    /// Trace.
    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Convert to `f32` buffer (for PJRT literals).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Build from an `f32` buffer.
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> DMat {
        assert_eq!(data.len(), rows * cols);
        DMat { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }
}

impl std::ops::Index<(usize, usize)> for DMat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DMat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for DMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "DMat {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(8);
        for i in 0..show_rows {
            write!(f, "  [")?;
            let show_cols = self.cols.min(8);
            for j in 0..show_cols {
                write!(f, "{:>10.4}", self[(i, j)])?;
                if j + 1 < show_cols {
                    write!(f, ", ")?;
                }
            }
            if self.cols > 8 {
                write!(f, ", …")?;
            }
            writeln!(f, "]")?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

// ---- vector helpers (free functions over &[f64]) ----

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `y += a·x`.
#[inline]
pub fn vec_axpy(y: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for i in 0..y.len() {
        y[i] += a * x[i];
    }
}

/// Normalize to unit length in place; returns the original norm.
pub fn normalize(v: &mut [f64]) -> f64 {
    let n = norm(v);
    if n > 0.0 {
        let inv = 1.0 / n;
        for x in v.iter_mut() {
            *x *= inv;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_construction() {
        let m = DMat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(m.col(2), vec![2.0, 5.0]);
    }

    #[test]
    fn eye_and_diag() {
        let i3 = DMat::eye(3);
        assert_eq!(i3.trace(), 3.0);
        let d = DMat::diag(&[1.0, 2.0]);
        assert_eq!(d[(1, 1)], 2.0);
        assert_eq!(d[(0, 1)], 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = DMat::from_fn(3, 5, |i, j| (i as f64) - 2.0 * (j as f64));
        assert_eq!(m.t().t(), m);
        assert_eq!(m.t()[(4, 2)], m[(2, 4)]);
    }

    #[test]
    fn axpy_scale_adddiag() {
        let mut a = DMat::eye(2);
        let b = DMat::from_fn(2, 2, |_, _| 1.0);
        a.axpy(2.0, &b);
        assert_eq!(a[(0, 0)], 3.0);
        assert_eq!(a[(0, 1)], 2.0);
        a.scale(0.5);
        assert_eq!(a[(0, 0)], 1.5);
        a.add_diag(1.0);
        assert_eq!(a[(1, 1)], 2.5);
    }

    #[test]
    fn symmetry_helpers() {
        let mut m = DMat::from_fn(3, 3, |i, j| (i + 2 * j) as f64);
        assert!(!m.is_symmetric(1e-12));
        m.symmetrize();
        assert!(m.is_symmetric(1e-12));
    }

    #[test]
    fn norms() {
        let m = DMat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn f32_roundtrip() {
        let m = DMat::from_fn(4, 4, |i, j| (i as f64) / 3.0 + j as f64);
        let m2 = DMat::from_f32(4, 4, &m.to_f32());
        assert!((&m2 - &m).max_abs() < 1e-6);
    }

    #[test]
    fn vector_helpers() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        let mut y = vec![1.0, 1.0];
        vec_axpy(&mut y, 2.0, &[1.0, 2.0]);
        assert_eq!(y, vec![3.0, 5.0]);
        let mut v = vec![0.0, 3.0, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-12);
        assert!((norm(&v) - 1.0).abs() < 1e-12);
    }
}

impl std::ops::Sub for &DMat {
    type Output = DMat;
    fn sub(self, rhs: &DMat) -> DMat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self
            .data
            .iter()
            .zip(rhs.data.iter())
            .map(|(a, b)| a - b)
            .collect();
        DMat { rows: self.rows, cols: self.cols, data }
    }
}

impl std::ops::Add for &DMat {
    type Output = DMat;
    fn add(self, rhs: &DMat) -> DMat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self
            .data
            .iter()
            .zip(rhs.data.iter())
            .map(|(a, b)| a + b)
            .collect();
        DMat { rows: self.rows, cols: self.cols, data }
    }
}
