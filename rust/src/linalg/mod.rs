//! Dense linear-algebra substrate.
//!
//! Everything the reproduction needs that would normally come from
//! LAPACK/BLAS, implemented from scratch in `f64`:
//!
//! * [`dmat`] — row-major dense matrices and vector ops.
//! * [`matmul`] — cache-blocked matrix multiplication (the L3 hot path for
//!   exact transform construction; see EXPERIMENTS.md §Perf).
//! * [`eigh`] — symmetric eigendecomposition via Householder
//!   tridiagonalization (`tred2`) + implicit-shift QL (`tql2`). Provides the
//!   ground-truth eigensystems for the paper's metrics (eq 15) and the
//!   *exact* spectral transforms (eq 10).
//! * [`qr`] — modified Gram–Schmidt orthonormalization (solver re-orthogonalization).
//! * [`funcs`] — matrix functions: spectral application `f(L)`, matrix
//!   exponential/logarithm, Horner polynomial evaluation, binary matrix
//!   powers.
//! * [`metrics`] — the paper's §5.2 evaluation metrics: normalized subspace
//!   error and longest eigenvector streak.
//! * [`lanczos`] — m-step symmetric Lanczos tridiagonalization (full
//!   reorthogonalization, deterministic start) on dense and CSR matrices:
//!   tight two-sided Ritz bounds `[λ̂_min, λ̂_max]` with residual
//!   diagnostics, behind the `--domain lanczos` Chebyshev-domain policy.
//! * [`par`] — row-sharded parallel execution of the dense hot paths
//!   (matmul, Horner polynomial apply, matpow, power iteration), bitwise
//!   identical to the serial kernels for every worker count.
//! * [`sparse`] — CSR matrices and the matrix-free kernels (row-sharded
//!   SpMM / SpMV / λ_max power iteration) behind `OpMode::MatrixFree`,
//!   with the same determinism contract as [`par`].
//! * [`simd`] — the build-time SpMM kernel backend selection: portable
//!   `std::simd` inner loops under `--features simd` (nightly), the
//!   stable unrolled kernels otherwise. Bitwise-identical either way.
//! * [`shard`] — graph-sharded SpMM ([`shard::ShardedCsr`]): CSR rows
//!   partitioned into shards with explicit halo exchange of boundary
//!   bundle rows, bitwise-equal to the unsharded kernels — the stepping
//!   stone from threads-on-one-box to distributed execution.

pub mod dmat;
pub mod eigh;
pub mod funcs;
pub mod lanczos;
pub mod matmul;
pub mod metrics;
pub mod par;
pub mod qr;
pub mod shard;
pub mod simd;
pub mod sparse;

pub use dmat::DMat;
pub use eigh::{eigh, Eigh};
