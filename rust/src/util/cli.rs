//! Declarative command-line parsing (offline stand-in for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands, typed accessors with defaults, and auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One declared option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
    /// Closed value set ([`ArgSpec::opt_choice`]): values outside it are
    /// rejected at parse time with the full list in the error.
    choices: Option<&'static [&'static str]>,
}

/// A declarative argument parser for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct ArgSpec {
    command: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(&'static str, &'static str)>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl ArgSpec {
    pub fn new(command: &str, about: &str) -> Self {
        ArgSpec { command: command.into(), about: about.into(), ..Default::default() }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: Some(default.into()),
            choices: None,
        });
        self
    }

    /// Declare `--name <value>` restricted to a closed value set: any
    /// other value is rejected at parse time with the allowed list in the
    /// error (instead of surfacing later from a domain parser), and the
    /// help text lists the choices.
    pub fn opt_choice(
        mut self,
        name: &'static str,
        default: &'static str,
        choices: &'static [&'static str],
        help: &'static str,
    ) -> Self {
        debug_assert!(choices.contains(&default), "default not among choices");
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: Some(default.into()),
            choices: Some(choices),
        });
        self
    }

    /// Declare `--name <value>` without a default (optional).
    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default: None, choices: None });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, default: None, choices: None });
        self
    }

    /// Declare a positional argument (documentation only; all positionals
    /// are collected in order).
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.command, self.about);
        let _ = writeln!(s, "\nUSAGE:\n  {} [OPTIONS]{}", self.command,
            self.positionals.iter().map(|(n, _)| format!(" <{n}>")).collect::<String>());
        if !self.positionals.is_empty() {
            let _ = writeln!(s, "\nARGS:");
            for (n, h) in &self.positionals {
                let _ = writeln!(s, "  <{n}>  {h}");
            }
        }
        let _ = writeln!(s, "\nOPTIONS:");
        for o in &self.opts {
            let mut left = format!("--{}", o.name);
            if o.takes_value {
                left.push_str(" <v>");
            }
            let default = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let choices = o
                .choices
                .map(|c| format!(" (one of: {})", c.join(" | ")))
                .unwrap_or_default();
            let _ = writeln!(s, "  {left:<24} {}{choices}{default}", o.help);
        }
        s
    }

    /// Parse a token stream. Returns an error string on unknown options or a
    /// missing value; `--help` produces `Err(help_text)`.
    pub fn parse<I: IntoIterator<Item = String>>(&self, args: I) -> Result<Args, String> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                out.values.insert(o.name.to_string(), d.clone());
            }
        }
        let mut it = args.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.help());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.help()))?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{name} requires a value"))?,
                    };
                    if let Some(choices) = spec.choices {
                        if !choices.contains(&v.as_str()) {
                            return Err(format!(
                                "invalid value {v:?} for --{name} (expected one of: {})",
                                choices.join(" | ")
                            ));
                        }
                    }
                    out.values.insert(name, v);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    out.flags.push(name);
                }
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn str(&self, name: &str) -> String {
        self.get(name).unwrap_or_default().to_string()
    }
    pub fn usize(&self, name: &str) -> usize {
        self.parse_or_die(name)
    }
    pub fn u64(&self, name: &str) -> u64 {
        self.parse_or_die(name)
    }
    pub fn f64(&self, name: &str) -> f64 {
        self.parse_or_die(name)
    }
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }
    /// Comma-separated list of a parseable type.
    pub fn list<T: std::str::FromStr>(&self, name: &str) -> Vec<T> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("bad element {s:?} in --{name}"))
            })
            .collect()
    }

    fn parse_or_die<T: std::str::FromStr>(&self, name: &str) -> T {
        let raw = self
            .get(name)
            .unwrap_or_else(|| panic!("missing required option --{name}"));
        raw.parse()
            .unwrap_or_else(|_| panic!("invalid value {raw:?} for --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test", "test command")
            .opt("n", "100", "node count")
            .opt("name", "foo", "a name")
            .opt_choice("basis", "monomial", &["monomial", "chebyshev"], "poly basis")
            .opt_req("out", "output path")
            .flag("verbose", "chatty")
            .positional("input", "input file")
    }

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(toks("")).unwrap();
        assert_eq!(a.usize("n"), 100);
        assert_eq!(a.str("name"), "foo");
        assert!(a.get("out").is_none());
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn parses_values_flags_positionals() {
        let a = spec()
            .parse(toks("--n 42 --verbose file.txt --name=bar"))
            .unwrap();
        assert_eq!(a.usize("n"), 42);
        assert_eq!(a.str("name"), "bar");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(0), Some("file.txt"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(spec().parse(toks("--bogus 1")).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(spec().parse(toks("--n")).is_err());
    }

    #[test]
    fn help_lists_options() {
        let h = spec().parse(toks("--help")).unwrap_err();
        assert!(h.contains("--n"));
        assert!(h.contains("--verbose"));
        assert!(h.contains("<input>"));
    }

    #[test]
    fn choice_options_validate_and_document() {
        // Defaults and valid values pass.
        let a = spec().parse(toks("")).unwrap();
        assert_eq!(a.str("basis"), "monomial");
        let a = spec().parse(toks("--basis chebyshev")).unwrap();
        assert_eq!(a.str("basis"), "chebyshev");
        let a = spec().parse(toks("--basis=chebyshev")).unwrap();
        assert_eq!(a.str("basis"), "chebyshev");
        // Invalid values fail at parse time with the allowed list.
        let err = spec().parse(toks("--basis legendre")).unwrap_err();
        assert!(err.contains("monomial | chebyshev"), "unhelpful error: {err}");
        // Help lists the choices.
        let h = spec().parse(toks("--help")).unwrap_err();
        assert!(h.contains("one of: monomial | chebyshev"), "{h}");
    }

    #[test]
    fn list_parses_csv() {
        let a = spec().parse(toks("--name 1,2,3")).unwrap();
        let v: Vec<usize> = a.list("name");
        assert_eq!(v, vec![1, 2, 3]);
    }
}
