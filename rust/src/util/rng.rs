//! Pseudo-random number generation.
//!
//! Xoshiro256++ (Blackman & Vigna) seeded through SplitMix64, plus the
//! distributions the rest of the crate needs (uniform ranges, Gaussians via
//! Box–Muller, shuffles, weighted choice). Deterministic across platforms —
//! every experiment in the repo is reproducible from its `seed` parameter.

/// SplitMix64 step: used to expand a single `u64` seed into the 256-bit
/// Xoshiro state (the construction recommended by the Xoshiro authors).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` (53-bit resolution).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `usize` in `[0, n)` using Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal sample (Box–Muller; one value per call, second
    /// discarded for simplicity — not a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index proportionally to non-negative `weights`.
    /// Returns `None` if all weights are zero/empty.
    pub fn weighted(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) {
            return None;
        }
        let mut target = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return Some(i);
            }
        }
        Some(weights.len() - 1)
    }

    /// `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_roughly() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]).unwrap();
            assert_eq!(i, 1);
        }
        assert!(r.weighted(&[0.0, 0.0]).is_none());
        assert!(r.weighted(&[]).is_none());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            let s = r.sample_distinct(50, 10);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 10);
            assert!(t.iter().all(|&x| x < 50));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
