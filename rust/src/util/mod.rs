//! Utility substrate.
//!
//! The build environment is fully offline (no crates.io access beyond the
//! vendored set), so the usual ecosystem crates (`rand`, `rayon`, `clap`,
//! `serde`, `criterion`) are implemented here from scratch at the size this
//! project needs:
//!
//! * [`rng`] — SplitMix64 seeding + Xoshiro256++ PRNG, distributions.
//! * [`pool`] — scoped worker pool (the paper's "d parallel walkers").
//! * [`cli`] — declarative command-line parser.
//! * [`config`] — TOML-subset configuration parser.
//! * [`csv`] — CSV writer for experiment series.
//! * [`stats`] — online/batch statistics used by benches and estimators.
//! * [`bench`] — the custom benchmark harness behind `cargo bench`.
//! * [`log`] — leveled stderr logger (`SPED_LOG=debug|info|warn|error`).

pub mod bench;
pub mod cli;
pub mod config;
pub mod csv;
pub mod log;
pub mod pool;
pub mod rng;
pub mod stats;

pub use rng::Rng;
