//! Scoped worker pool — the execution substrate for the paper's
//! "d graph walkers" (§4.3).
//!
//! Built on `std::thread::scope` + mpsc channels (no rayon in the offline
//! environment). Work is pulled from a shared injector queue with bounded
//! result buffering so a slow consumer applies backpressure to producers —
//! the shape a multi-host walker fleet would have, realised here as threads.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Mutex;

/// Parallel map: applies `f` to every index in `0..n` across `workers`
/// threads, preserving output order. `f` must be `Sync`; per-item state
/// should be derived from the index (e.g. fork an RNG stream per item).
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // Each index is claimed exactly once; the mutex only guards
                // the Vec-of-Options container, not the computation.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|x| x.expect("worker completed")).collect()
}

/// Fold results of a parallel computation: each worker produces a partial
/// accumulator over the indices it claims; partials are merged in the caller.
/// This is the aggregation pattern used by the walk estimator (each walker
/// accumulates its own sum of outer-product contributions).
pub fn parallel_fold<A, F, M>(n: usize, workers: usize, init: impl Fn() -> A + Sync, f: F, merge: M) -> A
where
    A: Send,
    F: Fn(&mut A, usize) + Sync,
    M: Fn(A, A) -> A,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 {
        let mut acc = init();
        for i in 0..n {
            f(&mut acc, i);
        }
        return acc;
    }
    let next = AtomicUsize::new(0);
    let partials = Mutex::new(Vec::with_capacity(workers));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut acc = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(&mut acc, i);
                }
                partials.lock().unwrap().push(acc);
            });
        }
    });
    partials
        .into_inner()
        .unwrap()
        .into_iter()
        .reduce(merge)
        .unwrap_or_else(init)
}

/// Run `f` once per contiguous shard of `data`, each shard on its own
/// worker thread. `shard_lens` gives the length of every shard in order and
/// must sum to `data.len()`; `f` receives the shard index and the shard's
/// mutable slice.
///
/// This is the execution substrate of the deterministic row-sharded dense
/// kernels (`linalg::par`): the *partition* decides what runs where, while
/// each shard's inner loop is the unchanged serial kernel — so results are
/// bitwise identical for every worker count.
pub fn parallel_shards<T, F>(data: &mut [T], shard_lens: &[usize], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    debug_assert_eq!(shard_lens.iter().sum::<usize>(), data.len(), "shards must tile data");
    if shard_lens.len() <= 1 {
        if !data.is_empty() || shard_lens.len() == 1 {
            f(0, data);
        }
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = data;
        for (idx, &len) in shard_lens.iter().enumerate() {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(len);
            rest = tail;
            let f = &f;
            scope.spawn(move || f(idx, chunk));
        }
    });
}

/// A long-lived leader/worker job pool with bounded queues.
///
/// The leader submits `Job`s; workers pull, execute, and push `Out`s into a
/// bounded channel (capacity = `backlog`), which blocks workers when the
/// leader falls behind — explicit backpressure, as a distributed walker
/// fleet would experience from a saturated aggregator.
pub struct JobPool<Job: Send + 'static, Out: Send + 'static> {
    job_tx: Option<SyncSender<Job>>,
    // Mutex makes the pool Sync: any thread may act as the leader/aggregator.
    out_rx: Mutex<Receiver<Out>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl<Job: Send + 'static, Out: Send + 'static> JobPool<Job, Out> {
    /// Spawn `workers` threads each running `work` on jobs pulled from the
    /// shared queue. `work` receives the worker id and the job.
    pub fn new<W>(workers: usize, backlog: usize, work: W) -> Self
    where
        W: Fn(usize, Job) -> Out + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let (job_tx, job_rx) = sync_channel::<Job>(backlog.max(1));
        let (out_tx, out_rx) = sync_channel::<Out>(backlog.max(1));
        let job_rx = std::sync::Arc::new(Mutex::new(job_rx));
        let work = std::sync::Arc::new(work);
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let job_rx = job_rx.clone();
            let out_tx = out_tx.clone();
            let work = work.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = job_rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(j) => {
                        let out = work(wid, j);
                        if out_tx.send(out).is_err() {
                            break; // receiver dropped
                        }
                    }
                    Err(_) => break, // sender dropped: shutdown
                }
            }));
        }
        JobPool { job_tx: Some(job_tx), out_rx: Mutex::new(out_rx), handles }
    }

    /// Submit a job (blocks when the job queue is full).
    pub fn submit(&self, job: Job) {
        self.job_tx
            .as_ref()
            .expect("pool not shut down")
            .send(job)
            .expect("workers alive");
    }

    /// Receive the next completed result (blocks).
    pub fn recv(&self) -> Out {
        self.out_rx.lock().unwrap().recv().expect("workers alive")
    }

    /// Close the job queue and join all workers, draining remaining results.
    pub fn shutdown(mut self) -> Vec<Out> {
        drop(self.job_tx.take());
        let mut rest = Vec::new();
        while let Ok(out) = self.out_rx.lock().unwrap().recv() {
            rest.push(out);
        }
        for h in self.handles.drain(..) {
            h.join().expect("worker panicked");
        }
        rest
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_matches_serial() {
        let serial: Vec<usize> = (0..100).map(|i| i * i).collect();
        let par = parallel_map(100, 4, |i| i * i);
        assert_eq!(par, serial);
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn parallel_shards_tiles_exactly() {
        let mut data: Vec<usize> = vec![0; 103];
        let lens = [40usize, 40, 23];
        parallel_shards(&mut data, &lens, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx + 1;
            }
        });
        assert!(data[..40].iter().all(|&x| x == 1));
        assert!(data[40..80].iter().all(|&x| x == 2));
        assert!(data[80..].iter().all(|&x| x == 3));
        // Degenerate cases: one shard, and empty input.
        let mut one = vec![0u8; 5];
        parallel_shards(&mut one, &[5], |_, c| c.fill(9));
        assert_eq!(one, vec![9; 5]);
        let mut empty: Vec<u8> = vec![];
        parallel_shards(&mut empty, &[], |_, _| panic!("no shards"));
    }

    #[test]
    fn parallel_fold_sums() {
        let total = parallel_fold(
            1000,
            4,
            || 0u64,
            |acc, i| *acc += i as u64,
            |a, b| a + b,
        );
        assert_eq!(total, 499_500);
    }

    #[test]
    fn job_pool_roundtrip() {
        // NOTE: total in-flight capacity is job-backlog + out-backlog +
        // workers; submitting more than that without receiving deadlocks
        // (by design — that's the backpressure). Interleave submit/recv.
        let pool: JobPool<u64, u64> = JobPool::new(3, 8, |_wid, x| x * 2);
        let mut outs: Vec<u64> = Vec::new();
        for i in 0..8 {
            pool.submit(i);
        }
        for i in 8..20 {
            outs.push(pool.recv());
            pool.submit(i);
        }
        for _ in 0..8 {
            outs.push(pool.recv());
        }
        outs.sort_unstable();
        assert_eq!(outs, (0..20).map(|i| i * 2).collect::<Vec<_>>());
        let rest = pool.shutdown();
        assert!(rest.is_empty());
    }

    #[test]
    fn job_pool_backpressure_blocks_then_releases() {
        // Fill every buffer, verify a further submit would block by doing it
        // from a helper thread, then drain and join.
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let pool: Arc<JobPool<u64, u64>> = Arc::new(JobPool::new(1, 2, |_w, x| x));
        let submitted = Arc::new(AtomicBool::new(false));
        let p2 = pool.clone();
        let s2 = submitted.clone();
        let h = std::thread::spawn(move || {
            for i in 0..10 {
                p2.submit(i);
            }
            s2.store(true, Ordering::SeqCst);
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        // 10 > 2+2+1: producer must still be blocked.
        assert!(!submitted.load(Ordering::SeqCst), "backpressure did not engage");
        let mut got = Vec::new();
        for _ in 0..10 {
            got.push(pool.recv());
        }
        h.join().unwrap();
        assert!(submitted.load(Ordering::SeqCst));
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn job_pool_shutdown_drains() {
        let pool: JobPool<u64, u64> = JobPool::new(2, 32, |_wid, x| x + 1);
        for i in 0..10 {
            pool.submit(i);
        }
        let mut rest = pool.shutdown();
        rest.sort_unstable();
        assert_eq!(rest, (1..=10).collect::<Vec<_>>());
    }
}
