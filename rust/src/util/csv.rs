//! Minimal CSV writing for experiment series (`results/*.csv`).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create (truncate) `path` and write the header row. Parent directories
    /// are created as needed.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, columns: header.len() })
    }

    /// Write a row of string fields (must match header arity).
    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        assert_eq!(fields.len(), self.columns, "CSV row arity mismatch");
        let escaped: Vec<String> = fields.iter().map(|f| escape(f)).collect();
        writeln!(self.out, "{}", escaped.join(","))
    }

    /// Write a row of `f64` values.
    pub fn row_f64(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|v| format!("{v}")).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("sped_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "x,y".into()]).unwrap();
            w.row_f64(&[2.5, 3.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "1,\"x,y\"");
        assert_eq!(lines[2], "2.5,3");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let dir = std::env::temp_dir().join("sped_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.row(&["only-one".into()]);
    }

    /// Minimal RFC-4180 line parser (test-only) to round-trip what the
    /// writer escapes.
    fn parse_line(line: &str) -> Vec<String> {
        let mut fields = Vec::new();
        let mut cur = String::new();
        let mut chars = line.chars().peekable();
        let mut in_quotes = false;
        while let Some(c) = chars.next() {
            match c {
                '"' if in_quotes => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        cur.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                '"' => in_quotes = true,
                ',' if !in_quotes => fields.push(std::mem::take(&mut cur)),
                c => cur.push(c),
            }
        }
        fields.push(cur);
        fields
    }

    #[test]
    fn roundtrip_escaped_fields_and_floats() {
        let dir = std::env::temp_dir().join("sped_csv_roundtrip");
        let path = dir.join("rt.csv");
        let rows: Vec<Vec<String>> = vec![
            vec!["plain".into(), "with,comma".into(), "with \"quotes\"".into()],
            vec!["multi\"esc\",x".into(), String::new(), "trailing".into()],
        ];
        let floats = [0.1f64, -3.25e-7, 12345.0];
        {
            let mut w = CsvWriter::create(&path, &["a", "b", "c"]).unwrap();
            for r in &rows {
                w.row(r).unwrap();
            }
            w.row_f64(&floats).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(parse_line(lines[0]), vec!["a", "b", "c"]);
        for (line, want) in lines[1..3].iter().zip(rows.iter()) {
            assert_eq!(&parse_line(line), want);
        }
        // Floats written with Rust's shortest-roundtrip formatting: parsing
        // them back recovers the exact f64.
        let back: Vec<f64> = parse_line(lines[3]).iter().map(|s| s.parse().unwrap()).collect();
        for (b, f) in back.iter().zip(floats.iter()) {
            assert_eq!(b.to_bits(), f.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
