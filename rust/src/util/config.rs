//! TOML-subset configuration parser (offline stand-in for `serde` + `toml`).
//!
//! Supports the subset the launcher needs: `[section]` headers, `key = value`
//! with string / integer / float / boolean / flat-array values, `#` comments.
//! Values are addressed as `"section.key"`; CLI `--set section.key=value`
//! overrides compose on top.

use std::collections::BTreeMap;

/// A scalar or flat-array configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed configuration: a flat map of `section.key` → [`Value`].
#[derive(Clone, Debug, Default)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

impl Config {
    /// Parse from TOML-subset text.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = inner.trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section header", lineno + 1));
                }
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            cfg.entries.insert(full, value);
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&text)
    }

    /// Apply a `section.key=value` override (CLI `--set`).
    pub fn set_override(&mut self, spec: &str) -> Result<(), String> {
        let (key, val) = spec
            .split_once('=')
            .ok_or_else(|| format!("override {spec:?}: expected key=value"))?;
        let value = parse_value(val.trim())?;
        self.entries.insert(key.trim().to_string(), value);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }
    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }
    /// String value without a default: `None` when the key is absent (or
    /// not a string). Lets callers express "config file wins, else fall
    /// back to the CLI flag" precedence explicitly instead of burying the
    /// fallback inside a default argument.
    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.get(key).and_then(|v| v.as_str()).map(|s| s.to_string())
    }
    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.as_i64())
            .map(|i| i as usize)
            .unwrap_or(default)
    }
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string {s:?}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array {s:?}"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // Bare word: treat as string (ergonomic for transform names etc).
    Ok(Value::Str(s.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig4"
[graph]
n = 512
clusters = 4
short_circuit = 25  # max cross edges
weighted = false
[solver]
eta = 0.05
transforms = ["identity", "limit_negexp"]
ells = [11, 51, 151, 251]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name", ""), "fig4");
        assert_eq!(c.usize("graph.n", 0), 512);
        assert_eq!(c.usize("graph.clusters", 0), 4);
        assert!(!c.bool("graph.weighted", true));
        assert!((c.f64("solver.eta", 0.0) - 0.05).abs() < 1e-12);
        let arr = c.get("solver.ells").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[3].as_i64(), Some(251));
    }

    #[test]
    fn comments_and_defaults() {
        let c = Config::parse("x = 1 # trailing").unwrap();
        assert_eq!(c.usize("x", 0), 1);
        assert_eq!(c.usize("missing", 9), 9);
    }

    #[test]
    fn str_opt_distinguishes_absent_keys() {
        let c = Config::parse("[pipeline]\nbasis = \"chebyshev\"\nsteps = 100").unwrap();
        assert_eq!(c.str_opt("pipeline.basis").as_deref(), Some("chebyshev"));
        assert_eq!(c.str_opt("pipeline.missing"), None);
        // Non-string values are not coerced.
        assert_eq!(c.str_opt("pipeline.steps"), None);
    }

    #[test]
    fn hash_inside_string_preserved() {
        let c = Config::parse(r##"s = "a#b""##).unwrap();
        assert_eq!(c.str("s", ""), "a#b");
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse("[a]\nx = 1").unwrap();
        c.set_override("a.x=5").unwrap();
        assert_eq!(c.usize("a.x", 0), 5);
        c.set_override("a.name=\"hello\"").unwrap();
        assert_eq!(c.str("a.name", ""), "hello");
    }

    #[test]
    fn errors_are_reported() {
        assert!(Config::parse("[]\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("x = \"unterminated\n").is_err());
        assert!(Config::parse("x = [1, 2\n").is_err());
    }

    #[test]
    fn empty_array() {
        let c = Config::parse("xs = []").unwrap();
        assert_eq!(c.get("xs").unwrap().as_array().unwrap().len(), 0);
    }

    /// Render a Config back to TOML-subset text (test-only: the crate only
    /// ever writes manifests via templates, but the parser must round-trip
    /// what it accepts).
    fn render(c: &Config) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        // Top-level keys must precede any section header.
        for key in c.keys().filter(|k| !k.contains('.')) {
            let _ = writeln!(out, "{key} = {}", render_value(c.get(key).unwrap()));
        }
        let mut current_section = String::new();
        for key in c.keys().filter(|k| k.contains('.')) {
            let (section, bare) = key.split_once('.').unwrap();
            if section != current_section {
                let _ = writeln!(out, "[{section}]");
                current_section = section.to_string();
            }
            let _ = writeln!(out, "{bare} = {}", render_value(c.get(key).unwrap()));
        }
        out
    }

    fn render_value(v: &Value) -> String {
        match v {
            Value::Str(s) => format!("{s:?}"),
            Value::Int(i) => i.to_string(),
            Value::Float(f) => format!("{f:?}"),
            Value::Bool(b) => b.to_string(),
            Value::Array(items) => {
                let inner: Vec<String> = items.iter().map(render_value).collect();
                format!("[{}]", inner.join(", "))
            }
        }
    }

    #[test]
    fn file_roundtrip_preserves_values() {
        // Parse → render → write → load → compare: the manifest path the
        // runtime depends on (`runtime::read_manifest` goes through
        // `Config::load`).
        let c1 = Config::parse(SAMPLE).unwrap();
        let text = render(&c1);
        let dir = std::env::temp_dir().join("sped_config_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.cfg");
        std::fs::write(&path, &text).unwrap();
        let c2 = Config::load(path.to_str().unwrap()).unwrap();
        let k1: Vec<&str> = c1.keys().collect();
        let k2: Vec<&str> = c2.keys().collect();
        assert_eq!(k1, k2, "key sets differ after roundtrip:\n{text}");
        for key in c1.keys() {
            assert_eq!(c1.get(key), c2.get(key), "value for {key} changed:\n{text}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_shaped_roundtrip() {
        // The exact shape runtime manifests use: sections of string + int
        // fields, several sections, comments.
        let text = "\
# AOT artifact registry
[oja_chunk_n128]
file = \"oja_chunk_n128.hlo.txt\"
kind = \"oja_chunk\"
n = 128
k = 8
t = 25
[poly_horner_n256]
file = \"poly_horner_n256.hlo.txt\"
kind = \"poly_horner\"
n = 256
degree = 256
";
        let c1 = Config::parse(text).unwrap();
        let c2 = Config::parse(&render(&c1)).unwrap();
        assert_eq!(c2.str("oja_chunk_n128.kind", ""), "oja_chunk");
        assert_eq!(c2.usize("oja_chunk_n128.t", 0), 25);
        assert_eq!(c2.usize("poly_horner_n256.degree", 0), 256);
        assert_eq!(c1.keys().count(), c2.keys().count());
    }
}
