//! Statistics helpers: Welford online accumulation, batch summaries,
//! percentiles. Used by the bench harness, the walk estimator's variance
//! tracking, and the experiment reports.

/// Online mean/variance (Welford). Numerically stable, mergeable.
#[derive(Clone, Copy, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Merge two accumulators (Chan et al. parallel update).
    pub fn merge(self, other: OnlineStats) -> OnlineStats {
        if self.n == 0 {
            return other;
        }
        if other.n == 0 {
            return self;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        OnlineStats { n, mean, m2, min: self.min.min(other.min), max: self.max.max(other.max) }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Sample variance (n−1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample (linear interpolation). `q` in `[0, 1]`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Batch summary of a sample.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Summary {
        assert!(!values.is_empty());
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut os = OnlineStats::new();
        for &v in values {
            os.push(v);
        }
        Summary {
            n: values.len(),
            mean: os.mean(),
            stddev: os.stddev(),
            min: sorted[0],
            p50: percentile(&sorted, 0.5),
            p95: percentile(&sorted, 0.95),
            max: *sorted.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.variance() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        let mut all = OnlineStats::new();
        for i in 0..50 {
            let x = (i as f64).sin();
            a.push(x);
            all.push(x);
        }
        for i in 50..100 {
            let x = (i as f64).sin();
            b.push(x);
            all.push(x);
        }
        let m = a.merge(b);
        assert!((m.mean() - all.mean()).abs() < 1e-12);
        assert!((m.variance() - all.variance()).abs() < 1e-12);
        assert_eq!(m.count(), 100);
    }

    #[test]
    fn merge_with_empty() {
        let mut a = OnlineStats::new();
        a.push(2.0);
        let e = OnlineStats::new();
        assert_eq!(a.merge(e).count(), 1);
        assert_eq!(e.merge(a).count(), 1);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
        assert!((percentile(&xs, 0.25) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }
}
