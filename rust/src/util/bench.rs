//! Custom benchmark harness (offline stand-in for `criterion`).
//!
//! Benches are `harness = false` binaries that build a [`BenchSuite`],
//! register cases, and call [`BenchSuite::finish`]. The harness does warmup,
//! adaptive iteration-count selection, and reports mean/p50/p95 wall time
//! plus optional user-defined throughput units. It honours the arguments
//! `cargo bench` passes through (`--bench`, filter strings) and the
//! `SPED_BENCH_FAST=1` env var used by CI-style smoke runs.

use super::stats::Summary;
use std::time::{Duration, Instant};

/// Measurement configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub target_time: Duration,
    pub min_iters: u32,
    pub max_iters: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if fast_mode() {
            BenchConfig {
                warmup: Duration::from_millis(20),
                target_time: Duration::from_millis(120),
                min_iters: 3,
                max_iters: 50,
            }
        } else {
            BenchConfig {
                warmup: Duration::from_millis(200),
                target_time: Duration::from_secs(1),
                min_iters: 5,
                max_iters: 2000,
            }
        }
    }
}

/// `SPED_BENCH_FAST=1` shrinks warmup/measurement budgets (used in smoke
/// runs; full runs leave it unset).
pub fn fast_mode() -> bool {
    std::env::var("SPED_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Problem-size scaling shared by the bench groups: the full size `n` in a
/// real run, `n/8` (floored at 64) under [`fast_mode`] smoke runs. Central
/// so every group shrinks by the same policy instead of hand-rolling
/// per-group constants.
pub fn fast_mode_scale(n: usize) -> usize {
    if fast_mode() {
        (n / 8).max(64)
    } else {
        n
    }
}

/// One-line capability fingerprint of this binary: which SpMM backend it
/// carries ([`crate::linalg::simd::backend_name`]), the machine's effective
/// thread default, the precisions the sparse operator supports, and the
/// crate features compiled in. Printed by `sped info` and embedded in every
/// [`BenchSuite::write_json`] emission so archived bench JSONs record what
/// produced them.
pub fn capability_string() -> String {
    let threads = std::thread::available_parallelism().map_or(1, |p| p.get());
    let mut features: Vec<&str> = Vec::new();
    if cfg!(feature = "xla") {
        features.push("xla");
    }
    if cfg!(feature = "simd") {
        features.push("simd");
    }
    let features = if features.is_empty() { "none".to_string() } else { features.join(",") };
    format!(
        "simd={} threads={} precisions=f64,mixed features={}",
        crate::linalg::simd::backend_name(),
        threads,
        features
    )
}

/// A benchmark suite: named timing cases + free-form report lines.
pub struct BenchSuite {
    name: String,
    cfg: BenchConfig,
    filter: Option<String>,
    results: Vec<String>,
}

impl BenchSuite {
    pub fn new(name: &str) -> BenchSuite {
        // cargo bench passes "--bench" plus any user filter strings.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'));
        BenchSuite { name: name.to_string(), cfg: BenchConfig::default(), filter, results: Vec::new() }
    }

    pub fn with_config(mut self, cfg: BenchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Whether `case` passes the user's bench-name filter (the first
    /// non-flag `cargo bench` argument). Public so bench groups that time
    /// outside [`Self::bench`] (one-shot builds, custom comparisons) can
    /// honor the same filter instead of running unconditionally.
    pub fn selected(&self, case: &str) -> bool {
        match &self.filter {
            Some(f) => case.contains(f.as_str()) || self.name.contains(f.as_str()),
            None => true,
        }
    }

    /// Time `f` adaptively and report. Returns mean seconds per iteration
    /// (0.0 when filtered out).
    pub fn bench<F: FnMut()>(&mut self, case: &str, mut f: F) -> f64 {
        self.bench_with_throughput(case, None, &mut f)
    }

    /// Time `f`; `units_per_iter` (e.g. FLOPs, edges, steps) adds a
    /// throughput column.
    pub fn bench_units<F: FnMut()>(&mut self, case: &str, units_per_iter: f64, unit: &str, mut f: F) -> f64 {
        self.bench_with_throughput(case, Some((units_per_iter, unit.to_string())), &mut f)
    }

    fn bench_with_throughput(
        &mut self,
        case: &str,
        throughput: Option<(f64, String)>,
        f: &mut dyn FnMut(),
    ) -> f64 {
        if !self.selected(case) {
            return 0.0;
        }
        // Warmup + estimate per-iter cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u32;
        while warm_start.elapsed() < self.cfg.warmup || warm_iters == 0 {
            f();
            warm_iters += 1;
            if warm_iters >= self.cfg.max_iters {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let iters = ((self.cfg.target_time.as_secs_f64() / per_iter.max(1e-9)) as u32)
            .clamp(self.cfg.min_iters, self.cfg.max_iters);
        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let s = Summary::of(&samples);
        let tp = throughput
            .map(|(u, name)| format!("  {:>10}/s", human(u / s.mean, &name)))
            .unwrap_or_default();
        self.results.push(format!(
            "{:<44} {:>12} ±{:>9}  p50 {:>10}  p95 {:>10}  n={}{}",
            case,
            human_time(s.mean),
            human_time(s.stddev),
            human_time(s.p50),
            human_time(s.p95),
            s.n,
            tp
        ));
        s.mean
    }

    /// Attach a non-timing line (experiment summaries, table rows).
    pub fn report(&mut self, line: &str) {
        self.results.push(line.to_string());
    }

    /// Emit a machine-readable result file: `rows` of `(key, value)` cells
    /// serialized as `{"suite": <name>, "rows": [{...}, ...]}`. This is how
    /// bench groups publish comparable numbers for CI trend tracking (e.g.
    /// `BENCH_sparse_vs_dense.json` at the repo root) without pulling a
    /// serde dependency into the offline build.
    pub fn write_json(
        &self,
        path: &std::path::Path,
        rows: &[Vec<(String, JsonVal)>],
    ) -> std::io::Result<()> {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\n  \"suite\": {},\n  \"caps\": {},\n  \"rows\": [\n",
            json_string(&self.name),
            json_string(&capability_string())
        ));
        for (i, row) in rows.iter().enumerate() {
            out.push_str("    {");
            for (j, (key, val)) in row.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!("{}: {}", json_string(key), val.render()));
            }
            out.push('}');
            if i + 1 < rows.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("  ]\n}\n");
        std::fs::write(path, out)
    }

    /// Print the suite report.
    pub fn finish(self) {
        println!("\n=== bench: {} ===", self.name);
        for line in &self.results {
            println!("{line}");
        }
        println!("=== end {} ===\n", self.name);
    }
}

/// A scalar cell in a machine-readable bench row (see
/// [`BenchSuite::write_json`]).
#[derive(Clone, Debug)]
pub enum JsonVal {
    Int(u64),
    Num(f64),
    Str(String),
}

impl JsonVal {
    fn render(&self) -> String {
        match self {
            JsonVal::Int(i) => i.to_string(),
            // Non-finite floats have no JSON representation; emit null.
            JsonVal::Num(x) if !x.is_finite() => "null".into(),
            JsonVal::Num(x) => {
                let s = format!("{x}");
                // "1" would parse as an integer; keep floats float-typed.
                if s.contains('.') || s.contains('e') || s.contains('E') {
                    s
                } else {
                    format!("{s}.0")
                }
            }
            JsonVal::Str(s) => json_string(s),
        }
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Human-readable seconds.
pub fn human_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Human-readable count with SI prefix.
pub fn human(x: f64, unit: &str) -> String {
    let (v, p) = if x >= 1e9 {
        (x / 1e9, "G")
    } else if x >= 1e6 {
        (x / 1e6, "M")
    } else if x >= 1e3 {
        (x / 1e3, "k")
    } else {
        (x, "")
    };
    format!("{v:.2} {p}{unit}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_time_ranges() {
        assert!(human_time(2.0).ends_with(" s"));
        assert!(human_time(2e-3).ends_with(" ms"));
        assert!(human_time(2e-6).ends_with(" µs"));
        assert!(human_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn human_prefixes() {
        assert_eq!(human(1.5e9, "F"), "1.50 GF");
        assert_eq!(human(2.5e6, "F"), "2.50 MF");
        assert_eq!(human(3.0e3, "F"), "3.00 kF");
        assert_eq!(human(5.0, "F"), "5.00 F");
    }

    #[test]
    fn json_emission_roundtrip() {
        let suite = BenchSuite {
            name: "jsontest".into(),
            cfg: BenchConfig::default(),
            filter: None,
            results: Vec::new(),
        };
        let rows = vec![
            vec![
                ("n".to_string(), JsonVal::Int(256)),
                ("sparse_step_s".to_string(), JsonVal::Num(0.5)),
                ("speedup".to_string(), JsonVal::Num(3.0)),
                ("bad".to_string(), JsonVal::Num(f64::NAN)),
                ("label".to_string(), JsonVal::Str("clique \"w\"\n".into())),
            ],
            vec![("n".to_string(), JsonVal::Int(1024))],
        ];
        let path = std::env::temp_dir().join("sped_bench_json_test.json");
        suite.write_json(&path, &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(text.contains("\"suite\": \"jsontest\""));
        assert!(text.contains("\"caps\": \"simd="), "capability fingerprint embedded: {text}");
        assert!(text.contains("\"n\": 256"));
        assert!(text.contains("\"sparse_step_s\": 0.5"));
        assert!(text.contains("\"speedup\": 3.0"), "integral floats stay floats: {text}");
        assert!(text.contains("\"bad\": null"));
        assert!(text.contains("\\\"w\\\"\\n"));
        // Balanced braces/brackets — cheap well-formedness check.
        assert_eq!(text.matches('{').count(), text.matches('}').count());
        assert_eq!(text.matches('[').count(), text.matches(']').count());
    }

    #[test]
    fn capability_string_names_backend_and_features() {
        let caps = capability_string();
        assert!(caps.contains(&format!("simd={}", crate::linalg::simd::backend_name())), "{caps}");
        assert!(caps.contains("precisions=f64,mixed"), "{caps}");
        assert!(caps.contains("threads="), "{caps}");
        assert!(caps.contains("features="), "{caps}");
    }

    #[test]
    fn fast_mode_scale_floors_at_64() {
        // 64/8 = 8 floors back up to 64 — invariant in both modes, so this
        // stays race-free against tests that toggle SPED_BENCH_FAST.
        assert_eq!(fast_mode_scale(64), 64);
        assert!([512, 4096].contains(&fast_mode_scale(4096)));
    }

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("SPED_BENCH_FAST", "1");
        let mut suite = BenchSuite::new("selftest");
        suite.filter = None;
        let mean = suite.bench("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(mean >= 0.0);
        assert_eq!(suite.results.len(), 1);
    }
}
