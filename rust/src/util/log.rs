//! Leveled stderr logging controlled by `SPED_LOG` (error|warn|info|debug).
//! Default level is `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();

fn ensure_init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("SPED_LOG") {
            let lvl = match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "debug" => Level::Debug,
                _ => Level::Info,
            };
            LEVEL.store(lvl as u8, Ordering::Relaxed);
        }
    });
}

/// Set the level programmatically (overrides the env var).
pub fn set_level(level: Level) {
    ensure_init();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    ensure_init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[sped {tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warnlog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! debuglog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! errorlog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
