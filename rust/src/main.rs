//! `sped` — the L3 coordinator CLI.
//!
//! Subcommands:
//!
//! * `cluster`     — end-to-end spectral clustering of a generated or loaded
//!                   graph through the SPED pipeline (native or XLA backend).
//! * `pvf`         — proto-value functions of the 3-room MDP (§5.3, Fig 1).
//! * `linkpred`    — the probabilistic-graph experiment (App A.1).
//! * `experiment`  — regenerate the paper's figures (fig2…fig6, walks).
//! * `walk-bench`  — parallel walker-fleet estimator diagnostics (§4.3).
//! * `gaps`        — eigengap-dilation report for a graph (Table 2 effect).
//! * `artifacts`   — list/validate the AOT artifact registry.
//!
//! Configuration: every subcommand accepts `--config file.toml` plus
//! `--set section.key=value` overrides; CLI flags win.

use sped::cluster::{adjusted_rand_index, max_conductance, normalized_mutual_info};
use sped::coordinator::experiments::{self, ExperimentOptions};
use sped::pipeline::{Backend, Pipeline, PipelineConfig};
use sped::transforms::{OpMode, PolyBasis, Precision, TransformKind};
use sped::util::cli::ArgSpec;
use sped::util::config::Config;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = args.remove(0);
    let result = match cmd.as_str() {
        "cluster" => cmd_cluster(args),
        "stream" => cmd_stream(args),
        "serve" => cmd_serve(args),
        "pvf" => cmd_pvf(args),
        "linkpred" => cmd_linkpred(args),
        "experiment" => cmd_experiment(args),
        "walk-bench" => cmd_walk_bench(args),
        "gaps" => cmd_gaps(args),
        "artifacts" => cmd_artifacts(args),
        "info" => cmd_info(args),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "sped — Stochastic Parallelizable Eigengap Dilation\n\
         \n\
         USAGE: sped <SUBCOMMAND> [OPTIONS]\n\
         \n\
         SUBCOMMANDS:\n\
         \x20 cluster     spectral clustering through the SPED pipeline\n\
         \x20 stream      streaming edge deltas with warm-started re-solves\n\
         \x20 serve       batched queries over a cached embedding (solve rarely, serve constantly)\n\
         \x20 pvf         proto-value functions of the 3-room MDP (Fig 1-3)\n\
         \x20 linkpred    probabilistic-graph clustering (Fig 5 / App A.1)\n\
         \x20 experiment  regenerate paper figures (--figure fig2|fig3|fig4|fig5|fig6|walks|all)\n\
         \x20 walk-bench  walker-fleet estimator diagnostics (§4.3)\n\
         \x20 gaps        eigengap-dilation report (Table 2 effect)\n\
         \x20 artifacts   list the AOT artifact registry\n\
         \x20 info        detected capabilities (SIMD backend, threads, precisions, features)\n\
         \n\
         Run `sped <SUBCOMMAND> --help` for options."
    );
}

/// Extract `--config` + `--set` into a Config (applied before flag parsing).
fn load_config(args: &mut Vec<String>) -> anyhow::Result<Config> {
    let mut cfg = Config::default();
    let mut rest = Vec::with_capacity(args.len());
    let drained: Vec<String> = std::mem::take(args);
    let mut it = drained.into_iter().peekable();
    while let Some(tok) = it.next() {
        match tok.as_str() {
            "--config" => {
                let path = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("--config needs a path"))?;
                cfg = Config::load(&path).map_err(|e| anyhow::anyhow!(e))?;
            }
            "--set" => {
                let spec = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("--set needs key=value"))?;
                cfg.set_override(&spec).map_err(|e| anyhow::anyhow!(e))?;
            }
            _ => rest.push(tok),
        }
    }
    *args = rest;
    Ok(cfg)
}

fn graph_spec(name: &'static str) -> ArgSpec {
    ArgSpec::new(name, "SPED workload")
        .opt("graph", "cliques", "cliques | mdp | sbm | <edge-list path>")
        .opt("n", "192", "node count (generators)")
        .opt("clusters", "4", "cluster count (generators)")
        .opt("seed", "1234", "RNG seed")
}

fn pipeline_spec(spec: ArgSpec) -> ArgSpec {
    spec.opt("k", "4", "bottom-k eigenvectors / clusters")
        .opt(
            "transform",
            "limit_negexp:251",
            "identity | log[:eps] | negexp | taylor_negexp[:ell] | taylor_log[:ell[:eps]] | limit_negexp[:ell]",
        )
        .opt(
            "solver",
            "oja",
            "oja | mu-eg | subspace | direct | ritz (block Rayleigh-Ritz on the dilated \
             operator; converges on its own residuals, no oracle needed)",
        )
        .opt("eta", "0", "learning rate (0 = auto 0.5/rho(M); unused by --solver ritz)")
        .opt("steps", "10000", "max solver steps")
        .opt("eval-every", "50", "metric cadence")
        .opt("stop-error", "1e-4", "early-stop subspace error")
        .opt(
            "ritz-tol",
            "1e-8",
            "--solver ritz: relative residual tolerance (converged once the max wanted \
             residual <= tol * rho(M))",
        )
        .opt("ritz-max-iters", "500", "--solver ritz: outer-iteration cap (1 apply each)")
        .opt(
            "block-size",
            "0",
            "--solver ritz: subspace block width (0 = auto: k + 2 guard vectors)",
        )
        .opt_choice(
            "ritz-lock",
            "on",
            &["on", "off"],
            "--solver ritz: locked-convergence deflation — freeze converged Ritz pairs and \
             apply the operator only to the shrinking active block (fewer SpMM columns per \
             sweep; off = historical fixed-block sweeps)",
        )
        .opt(
            "shards",
            "0",
            "row-shard the matrix-free operator into N two-phase (owned + halo) partitions; \
             bitwise-identical to --shards 0 at every shard/worker count (--op sparse, \
             --precision f64 only)",
        )
        .opt("threads", "1", "worker threads for dense kernels (bitwise-identical output)")
        .opt("op", "dense", "dense (materialize p(L)) | sparse (matrix-free CSR operator)")
        .opt_choice(
            "precision",
            "f64",
            &["f64", "double", "mixed", "f32"],
            "SpMM sweep arithmetic: f64 = bitwise-deterministic historical path, \
             mixed = f32 Laplacian/bundle storage with f64 accumulators (~half the \
             kernel memory traffic; iterative sparse solves only, error bounded by \
             the documented budget — requires --op sparse and --no-ground-truth)",
        )
        .opt_choice(
            "basis",
            "monomial",
            &["monomial", "mono", "horner", "chebyshev", "cheb"],
            "polynomial basis for series transforms: monomial = shifted Horner \
             (bitwise-compatible historical path), chebyshev = domain-mapped three-term \
             recurrence (stable at high degree; native backend, series transforms only)",
        )
        .opt_choice(
            "domain",
            "power",
            &["power", "lanczos", "ritz", "gershgorin", "gersh"],
            "spectral-interval estimate for the Chebyshev fit domain and lambda*: \
             power = lambda_max power iteration widened to Gershgorin (historical), \
             lanczos = tight two-sided Ritz bounds with residual-scaled padding, \
             gershgorin = the guaranteed interval alone",
        )
        .opt(
            "degree",
            "native",
            "native | auto[:max] | <N> — Chebyshev filter degree: native = the transform's \
             own ell, auto = truncate the coefficient tail below --cheb-tol (fewer SpMM \
             sweeps per solver step; auto:max additionally caps the kept degree), \
             N = fit at exactly degree N (requires --basis chebyshev)",
        )
        .opt(
            "cheb-tol",
            "1e-9",
            "relative coefficient tolerance for --degree auto (each dropped coefficient \
             is one SpMM sweep saved; on-domain error is bounded by the dropped tail)",
        )
        .opt(
            "reorder",
            "none",
            "none | rcm (Reverse Cuthill-McKee node reordering for cache-local sparse access; \
             outputs are un-permuted back to input node order)",
        )
        .opt("backend", "native", "native | xla")
        .opt("artifacts", "artifacts", "artifacts dir (xla backend)")
        .flag("prescale", "pre-scale L by 1/lambda_max before the transform")
        .flag(
            "no-ground-truth",
            "skip the O(n^3) exact-eigenvector oracle (no convergence metrics / early stop; \
             with --op sparse the pipeline is dense-free end to end)",
        )
}

fn build_pipeline_cfg(a: &sped::util::cli::Args, cfg: &Config) -> anyhow::Result<PipelineConfig> {
    let transform = TransformKind::parse(&a.str("transform"))?;
    let mut build = sped::transforms::BuildOptions::default();
    build.prescale = a.flag("prescale") || cfg.bool("pipeline.prescale", false);
    // Config file wins over the CLI value (which always has a default).
    build.basis = PolyBasis::parse(
        &cfg.str_opt("pipeline.basis").unwrap_or_else(|| a.str("basis")),
    )?;
    build.domain = sped::transforms::DomainEstimate::parse(
        &cfg.str_opt("pipeline.domain").unwrap_or_else(|| a.str("domain")),
    )?;
    build.degree = sped::transforms::Degree::parse(
        &cfg.str_opt("pipeline.degree").unwrap_or_else(|| a.str("degree")),
        cfg.f64("pipeline.cheb_tol", a.f64("cheb-tol")),
    )?;
    build.precision = Precision::parse(
        &cfg.str_opt("pipeline.precision").unwrap_or_else(|| a.str("precision")),
    )?;
    build.shards = cfg.usize("pipeline.shards", a.usize("shards"));
    let ritz_lock = match cfg.str("pipeline.ritz_lock", &a.str("ritz-lock")).as_str() {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--ritz-lock takes on|off, got {other:?}"),
    };
    let backend = match a.str("backend").as_str() {
        "native" => Backend::Native,
        "xla" => Backend::Xla { artifacts_dir: a.str("artifacts") },
        other => anyhow::bail!("unknown backend {other:?}"),
    };
    let op_mode = OpMode::parse(&cfg.str("pipeline.op", &a.str("op")))?;
    let reorder = sped::graph::Reorder::parse(&cfg.str("pipeline.reorder", &a.str("reorder")))?;
    let ground_truth = !a.flag("no-ground-truth") && cfg.bool("pipeline.ground_truth", true);
    Ok(PipelineConfig {
        k: cfg.usize("pipeline.k", a.usize("k")),
        transform,
        solver: a.str("solver"),
        eta: a.f64("eta"), // 0 → auto-resolved by the caller
        steps: cfg.usize("pipeline.steps", a.usize("steps")),
        eval_every: a.usize("eval-every"),
        streak_eps: 1e-2,
        stop_error: a.f64("stop-error"),
        ritz_tol: cfg.f64("pipeline.ritz_tol", a.f64("ritz-tol")),
        ritz_max_iters: cfg.usize("pipeline.ritz_max_iters", a.usize("ritz-max-iters")),
        block_size: cfg.usize("pipeline.block_size", a.usize("block-size")),
        ritz_lock,
        build,
        backend,
        seed: a.u64("seed"),
        do_cluster: true,
        threads: cfg.usize("pipeline.threads", a.usize("threads")).max(1),
        op_mode,
        rcm_order: None, // filled by callers that loaded a persisted order
        reorder,
        warm_start: None, // managed by the stream/serve sessions
        ground_truth,
    })
}

/// Auto learning rate: η = 0.5/ρ(M), ρ(M) = λ* − f(0) analytically, with
/// ρ(L) from the **same** [`sped::transforms::DomainEstimate`] policy the
/// operator build uses (`--domain`), so η is tuned for the λ* the solver
/// actually iterates with. Under `--op sparse` everything runs on the CSR
/// Laplacian so the matrix-free path stays free of n×n allocations even
/// here. (Like the dense arm, this estimate is recomputed once more inside
/// the operator build — an O(nnz) redundancy kept for the simpler Pipeline
/// interface.)
fn auto_eta(graph: &sped::graph::Graph, pcfg: &mut PipelineConfig, verbose: bool) {
    // The Ritz solver has no learning rate — skip the O(nnz) spectral
    // estimate (its operator build performs its own).
    if pcfg.eta > 0.0 || pcfg.solver == "ritz" {
        return;
    }
    let threads = pcfg.threads.max(1);
    let domain = pcfg.build.domain;
    // Only the Power arm reads the hint — skip the 100-matvec power
    // estimate otherwise (the same `need_power` guard the operator
    // builders apply).
    let need_power = domain == sped::transforms::DomainEstimate::Power;
    let rho = match pcfg.op_mode {
        OpMode::MatrixFree => {
            let lc = graph.laplacian_csr();
            let hint = if need_power {
                // Eta is a heuristic: a failed estimate degrades to the
                // domain fallback instead of aborting the run here.
                sped::linalg::sparse::power_lambda_max_csr(&lc, 100, threads)
                    .map_or(0.0, |x| x * 1.01)
            } else {
                0.0
            };
            domain.estimate_csr(&lc, hint, threads).map(|e| e.rho).unwrap_or(hint)
        }
        OpMode::DenseMaterialized => {
            let ld = graph.laplacian();
            let hint = if need_power {
                sped::linalg::par::power_lambda_max_par(&ld, 100, threads)
                    .map_or(0.0, |x| x * 1.01)
            } else {
                0.0
            };
            domain.estimate_dense(&ld, hint, threads).map(|e| e.rho).unwrap_or(hint)
        }
    };
    let rho_m = (pcfg.transform.lambda_star(rho) - pcfg.transform.scalar_map(0.0)).abs();
    pcfg.eta = 0.5 / rho_m.max(1e-9);
    if verbose {
        println!("auto eta = {:.4} (rho(M) ~ {rho_m:.3})", pcfg.eta);
    }
}

/// Build or load the workload graph. The third element is a node order
/// persisted alongside a loaded edge-list file (`# order:` header) — the
/// RCM permutation a previous run saved, letting `--reorder rcm` skip the
/// O(E log E) rebuild; `None` for generators.
fn make_graph(
    a: &sped::util::cli::Args,
) -> anyhow::Result<(sped::graph::Graph, Vec<usize>, Option<Vec<usize>>)> {
    let kind = a.str("graph");
    let n = a.usize("n");
    let c = a.usize("clusters");
    let seed = a.u64("seed");
    if kind == "cliques" {
        let gg = sped::graph::gen::cliques(&sped::graph::gen::CliqueSpec {
            n,
            k: c,
            max_short_circuit: 25,
            seed,
        });
        Ok((gg.graph, gg.labels, None))
    } else if kind == "sbm" {
        let gg = sped::graph::gen::sbm(&vec![n / c.max(1); c.max(1)], 0.8, 0.02, seed);
        Ok((gg.graph, gg.labels, None))
    } else if kind == "mdp" {
        let w = sped::mdp::GridWorld::three_rooms(sped::mdp::ThreeRoomSpec::default())?;
        let rooms = (0..w.num_states()).map(|s| w.room_of(s)).collect();
        Ok((w.graph, rooms, None))
    } else {
        let (g, order) = sped::graph::io::load_edge_list_with_order(&kind)?;
        Ok((g, vec![], order))
    }
}

fn cmd_cluster(mut args: Vec<String>) -> anyhow::Result<()> {
    let cfg = load_config(&mut args)?;
    let spec = pipeline_spec(graph_spec("sped cluster")).opt_req(
        "save-order",
        "write the graph + its RCM node order to this edge-list path \
         (later runs on that file skip the RCM rebuild)",
    );
    let a = spec.parse(args).map_err(|e| anyhow::anyhow!(e))?;
    let (graph, labels, stored_order) = make_graph(&a)?;
    println!(
        "graph: {} nodes, {} edges, max degree {}",
        graph.num_nodes(),
        graph.num_edges(),
        graph.max_degree()
    );
    let mut pcfg = build_pipeline_cfg(&a, &cfg)?;
    auto_eta(&graph, &mut pcfg, true);
    if pcfg.reorder == sped::graph::Reorder::Rcm {
        // A persisted order (the `# order:` header of a loaded edge list)
        // skips the O(E log E) RCM rebuild entirely.
        let order = match stored_order {
            Some(order) => {
                println!("rcm reorder: using stored node order (rebuild skipped)");
                order
            }
            None => graph.rcm_permutation(),
        };
        // Bandwidth under the order straight from the permutation — no
        // need to rebuild the relabeled graph just for this line (the
        // pipeline builds its own copy internally).
        let inv = sped::graph::invert_permutation(&order);
        let rcm_bw = graph
            .edges()
            .iter()
            .map(|e| inv[e.u as usize].abs_diff(inv[e.v as usize]))
            .max()
            .unwrap_or(0);
        println!("rcm reorder: bandwidth {} -> {}", graph.bandwidth(), rcm_bw);
        if let Some(path) = a.get("save-order") {
            sped::graph::io::save_edge_list_with_order(&graph, path, Some(&order))?;
            println!("saved graph + node order to {path}");
        }
        pcfg.rcm_order = Some(order);
    } else if let Some(path) = a.get("save-order") {
        anyhow::bail!("--save-order {path} requires --reorder rcm");
    }
    let out = Pipeline::new(pcfg.clone()).run(&graph)?;
    match out.history.last() {
        Some(last) => println!(
            "\ntransform {} | solver {} | op {} | basis {} | domain {} | degree {} | precision {} | steps {} | subspace err {:.3e} | streak {}/{}",
            pcfg.transform,
            pcfg.solver,
            pcfg.op_mode,
            pcfg.build.basis,
            pcfg.build.domain,
            pcfg.build.degree,
            pcfg.build.precision,
            last.step,
            last.subspace_error,
            last.streak,
            pcfg.k
        ),
        None => println!(
            "\ntransform {} | solver {} | op {} | basis {} | domain {} | degree {} | precision {} | ran {} steps (ground-truth metrics skipped)",
            pcfg.transform,
            pcfg.solver,
            pcfg.op_mode,
            pcfg.build.basis,
            pcfg.build.domain,
            pcfg.build.degree,
            pcfg.build.precision,
            pcfg.steps
        ),
    }
    println!(
        "timings: ground-truth {:.2}s, transform {:.2}s, solve {:.2}s, cluster {:.2}s",
        out.timings.ground_truth,
        out.timings.transform_build,
        out.timings.solve,
        out.timings.cluster
    );
    if let Some(rz) = &out.ritz {
        println!(
            "ritz: {} outer iterations ({}), {} SpMM sweeps/apply, {} total sweeps",
            rz.iterations,
            if rz.converged { "converged" } else { "hit --ritz-max-iters" },
            rz.sweeps_per_apply,
            rz.total_sweeps
        );
        // Deflation/sharding accounting: column sweeps are the honest SpMM
        // cost unit once locking shrinks the active block (fixed-block cost
        // would be total_sweeps * block width).
        println!(
            "ritz: {} locked pairs, {} SpMM column sweeps{}",
            rz.locked,
            rz.col_sweeps,
            if rz.halo_volume > 0 {
                format!(", {} halo bundle rows exchanged", rz.halo_volume)
            } else {
                String::new()
            }
        );
        // Strided residual trace (≤ ~12 lines), always including the last.
        let stride = (rz.residual_history.len() / 10).max(1);
        let first = rz.residual_history_total - rz.residual_history.len();
        for (i, r) in rz.residual_history.iter().enumerate() {
            if i % stride == 0 || i + 1 == rz.residual_history.len() {
                println!(
                    "  iter {:>4}  max residual {:.3e}  sweeps {}  locked {}",
                    first + i + 1,
                    r,
                    (first + i + 1) * rz.sweeps_per_apply,
                    rz.locked_history.get(i).copied().unwrap_or(rz.locked)
                );
            }
        }
    }
    if let Some(cl) = &out.clustering {
        println!("k-means inertia {:.4} ({} iters)", cl.inertia, cl.iterations);
        println!("max conductance phi = {:.4}", max_conductance(&graph, &cl.assignments));
        if !labels.is_empty() {
            println!(
                "vs ground truth: ARI {:.4}, NMI {:.4}",
                adjusted_rand_index(&cl.assignments, &labels),
                normalized_mutual_info(&cl.assignments, &labels)
            );
        }
        let mut sizes = std::collections::BTreeMap::new();
        for &c in &cl.assignments {
            *sizes.entry(c).or_insert(0usize) += 1;
        }
        println!("cluster sizes: {sizes:?}");
    }
    Ok(())
}

fn cmd_stream(mut args: Vec<String>) -> anyhow::Result<()> {
    use sped::coordinator::stream::{parse_event_batches, StreamConfig, StreamSession};
    let cfg = load_config(&mut args)?;
    let spec = pipeline_spec(graph_spec("sped stream"))
        .opt_req(
            "events",
            "event file: one delta per line (add U V W | remove U V | reweight U V W | \
             addnodes K), a `---` line closes a batch",
        )
        .opt("publish-every", "1", "republish embedding + clusters every N batches")
        .opt(
            "warm-frac",
            "0.25",
            "delta volume (fraction of current edge count) above which a publish runs \
             cold instead of warm-starting from the previous embedding (--solver ritz)",
        )
        .opt_req(
            "save-graph",
            "write the final mutated graph to this edge-list path (the `# order:` header \
             is kept only while still valid for the mutated topology)",
        );
    let a = spec.parse(args).map_err(|e| anyhow::anyhow!(e))?;
    let events_path = a
        .get("events")
        .ok_or_else(|| anyhow::anyhow!("--events <file> is required"))?;
    let text = std::fs::read_to_string(&events_path)
        .map_err(|e| anyhow::anyhow!("reading {events_path}: {e}"))?;
    let batches = parse_event_batches(&text)?;
    let publish_every = a.usize("publish-every").max(1);
    let (graph, labels, stored_order) = make_graph(&a)?;
    println!(
        "graph: {} nodes, {} edges | {} delta batches from {events_path}",
        graph.num_nodes(),
        graph.num_edges(),
        batches.len()
    );
    let mut pcfg = build_pipeline_cfg(&a, &cfg)?;
    auto_eta(&graph, &mut pcfg, true);
    let mut session = StreamSession::with_order(
        graph,
        stored_order,
        StreamConfig { pipeline: pcfg, warm_volume_frac: a.f64("warm-frac") },
    );
    let publish = |session: &mut StreamSession, tag: &str| -> anyhow::Result<()> {
        let rep = session.publish()?;
        let drift = match (rep.ari_vs_previous, rep.ari_prefix_vs_previous) {
            (Some(x), _) => format!("{x:.4}"),
            // Node growth: full-vector ARI is undefined; report the
            // common-prefix drift with the reason.
            (None, Some(p)) => format!("prefix {p:.4}"),
            (None, None) => rep.ari_reason.map_or(String::from("-"), |r| format!("- ({r})")),
        };
        let truth = if !labels.is_empty() && labels.len() == rep.assignments.len() {
            format!(" | ARI vs labels {:.4}", adjusted_rand_index(&rep.assignments, &labels))
        } else {
            String::new()
        };
        println!(
            "publish {tag}: path {} | {} iters ({}) | drift ARI {drift}{truth}",
            rep.path,
            rep.iterations,
            if rep.converged { "converged" } else { "unconverged" },
        );
        Ok(())
    };
    publish(&mut session, "baseline")?;
    let mut pending = 0usize;
    for (i, batch) in batches.iter().enumerate() {
        match session.apply_batch(batch) {
            Ok(outcome) => {
                println!(
                    "batch {}: +{} -{} ~{} edges, +{} nodes{}",
                    i + 1,
                    outcome.edges_added,
                    outcome.edges_removed,
                    outcome.edges_reweighted,
                    outcome.nodes_added,
                    if outcome.topology_changed { " (topology changed)" } else { "" }
                );
                pending += 1;
            }
            // Graceful degradation: a bad batch is rejected transactionally
            // (the graph and every cache are untouched); the stream goes on.
            Err(e) => println!("batch {} rejected: {e:#}", i + 1),
        }
        if pending > 0 && (i + 1) % publish_every == 0 {
            publish(&mut session, &format!("after batch {}", i + 1))?;
            pending = 0;
        }
    }
    if pending > 0 {
        publish(&mut session, "final")?;
    }
    if let Some(path) = a.get("save-graph") {
        session.save(&path)?;
        println!("saved mutated graph to {path}");
    }
    Ok(())
}

fn cmd_serve(mut args: Vec<String>) -> anyhow::Result<()> {
    use sped::coordinator::serve::{parse_query_batches, Answer, Query, ServeConfig, ServeSession};
    use sped::coordinator::stream::parse_event_batches;
    let cfg = load_config(&mut args)?;
    let spec = pipeline_spec(graph_spec("sped serve"))
        .opt_req(
            "queries",
            "query file: one query per line (linkpred U V | cluster U | topk U K), \
             a `---` line closes a batch",
        )
        .opt_req(
            "events",
            "delta event file in the `sped stream` grammar; event batch i is ingested \
             before query batch i — the cache invalidates per the delta outcome and the \
             next query batch re-solves lazily (warm-started when the churn allows)",
        )
        .opt(
            "warm-frac",
            "0.25",
            "delta volume (fraction of current edge count) above which a lazy re-solve \
             runs cold instead of warm-starting from the previous embedding (--solver ritz)",
        );
    let a = spec.parse(args).map_err(|e| anyhow::anyhow!(e))?;
    let queries_path = a
        .get("queries")
        .ok_or_else(|| anyhow::anyhow!("--queries <file> is required"))?;
    let qtext = std::fs::read_to_string(&queries_path)
        .map_err(|e| anyhow::anyhow!("reading {queries_path}: {e}"))?;
    let qbatches = parse_query_batches(&qtext)?;
    let ebatches = match a.get("events") {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            parse_event_batches(&text)?
        }
        None => Vec::new(),
    };
    let (graph, _labels, stored_order) = make_graph(&a)?;
    println!(
        "graph: {} nodes, {} edges | {} query batches, {} delta batches",
        graph.num_nodes(),
        graph.num_edges(),
        qbatches.len(),
        ebatches.len()
    );
    let mut pcfg = build_pipeline_cfg(&a, &cfg)?;
    auto_eta(&graph, &mut pcfg, true);
    let mut session = ServeSession::with_order(
        graph,
        stored_order,
        ServeConfig { pipeline: pcfg, warm_volume_frac: a.f64("warm-frac") },
    );
    println!("cache key config: {}", session.fingerprint());
    let qname = |q: &Query| match *q {
        Query::LinkPred { u, v } => format!("linkpred {u} {v}"),
        Query::NearestCluster { u } => format!("cluster {u}"),
        Query::TopK { u, k } => format!("topk {u} {k}"),
    };
    let rounds = qbatches.len().max(ebatches.len());
    for i in 0..rounds {
        if let Some(batch) = ebatches.get(i) {
            // A rejected delta batch leaves the graph and caches intact;
            // serving continues.
            match session.apply_batch(batch) {
                Ok(outcome) => println!(
                    "deltas {}: +{} -{} ~{} edges, +{} nodes{}",
                    i + 1,
                    outcome.edges_added,
                    outcome.edges_removed,
                    outcome.edges_reweighted,
                    outcome.nodes_added,
                    if outcome.topology_changed { " (topology changed)" } else { "" }
                ),
                Err(e) => println!("delta batch {} rejected: {e:#}", i + 1),
            }
        }
        if let Some(qb) = qbatches.get(i) {
            let solves_before = session.solves();
            // A bad query batch errors with the offending query's index;
            // the session stays valid and the next batch is served.
            match session.answer_batch(qb) {
                Ok(answers) => {
                    if session.solves() > solves_before {
                        println!(
                            "queries {}: re-solved ({}) before answering",
                            i + 1,
                            session
                                .last_solve_path()
                                .map(|p| p.to_string())
                                .unwrap_or_default()
                        );
                    }
                    println!("queries {} ({} answered from cache):", i + 1, answers.len());
                    for (q, ans) in qb.iter().zip(answers.iter()) {
                        match ans {
                            Answer::Score(s) => println!("  {:<18} -> score {s:.6}", qname(q)),
                            Answer::Cluster { cluster, distance } => println!(
                                "  {:<18} -> cluster {cluster} (distance {distance:.6})",
                                qname(q)
                            ),
                            Answer::Neighbors(nb) => {
                                let top: Vec<String> = nb
                                    .iter()
                                    .map(|(v, s)| format!("{v}:{s:.4}"))
                                    .collect();
                                println!("  {:<18} -> [{}]", qname(q), top.join(", "));
                            }
                        }
                    }
                }
                Err(e) => println!("query batch {} rejected: {e:#}", i + 1),
            }
        }
    }
    println!(
        "served {rounds} rounds with {} solve(s) ({} query batches answered from a warm cache)",
        session.solves(),
        qbatches.len().saturating_sub(session.solves())
    );
    Ok(())
}

fn cmd_pvf(mut args: Vec<String>) -> anyhow::Result<()> {
    let _cfg = load_config(&mut args)?;
    let spec = ArgSpec::new("sped pvf", "3-room MDP proto-value functions")
        .opt("s", "1", "geometry scale (paper Fig 1: s=2)")
        .opt("h", "10", "door fraction denominator")
        .opt("k", "8", "number of PVFs")
        .flag("render", "ASCII-render the world and the 2nd PVF");
    let a = spec.parse(args).map_err(|e| anyhow::anyhow!(e))?;
    let world = sped::mdp::GridWorld::three_rooms(sped::mdp::ThreeRoomSpec {
        s: a.usize("s"),
        h: a.usize("h"),
    })?;
    println!(
        "3-room MDP: {}x{} grid, {} states, {} transitions",
        world.rows,
        world.cols,
        world.num_states(),
        world.graph.num_edges()
    );
    let k = a.usize("k");
    let pvf = sped::mdp::proto_value_functions(&world, k)?;
    let e = sped::linalg::eigh(&world.graph.laplacian())?;
    println!(
        "bottom-{k} eigenvalues: {:?}",
        &e.values[..k.min(e.values.len())]
    );
    if a.flag("render") {
        println!("\nworld (Fig 1):\n{}", world.render());
        println!(
            "2nd PVF (Fiedler vector — separates outer rooms):\n{}",
            world.render_field(&pvf.col(1))
        );
    }
    let goal = world.num_states() / 2;
    let target = sped::mdp::negative_distance_value(&world, goal);
    let (_, rmse) = sped::mdp::pvf_value_fit(&pvf, &target);
    println!("value-function fit with {k} PVFs: normalized RMSE {rmse:.4}");
    Ok(())
}

fn cmd_linkpred(mut args: Vec<String>) -> anyhow::Result<()> {
    let cfg = load_config(&mut args)?;
    let spec = pipeline_spec(graph_spec("sped linkpred")).opt("drop", "0.2", "edge drop probability");
    let a = spec.parse(args).map_err(|e| anyhow::anyhow!(e))?;
    let (graph, labels, _) = make_graph(&a)?;
    let dropped = sped::linkpred::drop_edges(&graph, a.f64("drop"), a.u64("seed") ^ 0xA1)?;
    let completed = sped::linkpred::complete_graph(&dropped)?;
    println!(
        "dropped {} of {} edges; completion re-added {} weighted predictions",
        dropped.removed.len(),
        graph.num_edges(),
        completed.num_edges() - dropped.graph.num_edges()
    );
    let mut pcfg = build_pipeline_cfg(&a, &cfg)?;
    auto_eta(&completed, &mut pcfg, true);
    let out = Pipeline::new(pcfg).run(&completed)?;
    match out.history.last() {
        Some(last) => println!(
            "converged: subspace err {:.3e}, streak {}",
            last.subspace_error, last.streak
        ),
        None => println!("solver finished (ground-truth metrics skipped)"),
    }
    if let (Some(cl), false) = (&out.clustering, labels.is_empty()) {
        println!(
            "clustering completed graph: ARI {:.4} vs original ground truth",
            adjusted_rand_index(&cl.assignments, &labels)
        );
    }
    Ok(())
}

fn cmd_experiment(mut args: Vec<String>) -> anyhow::Result<()> {
    let _cfg = load_config(&mut args)?;
    let spec = ArgSpec::new("sped experiment", "regenerate paper figures")
        .opt("figure", "all", "fig2 | fig3 | fig4 | fig5 | fig6 | walks | all")
        .opt("out-dir", "results", "CSV output directory")
        .opt("seed", "1234", "RNG seed")
        .flag("fast", "smoke-scale budgets")
        .flag("full-size", "paper-scale graphs (n=1000/2000)");
    let a = spec.parse(args).map_err(|e| anyhow::anyhow!(e))?;
    let opts = ExperimentOptions {
        fast: a.flag("fast") || sped::util::bench::fast_mode(),
        out_dir: a.str("out-dir"),
        seed: a.u64("seed"),
        full_size: a.flag("full-size"),
    };
    std::fs::create_dir_all(&opts.out_dir)?;
    let figure = a.str("figure");
    let run_figs = |f: &str| -> anyhow::Result<()> {
        match f {
            "fig2" | "fig3" => {
                let curves = experiments::fig2_fig3_mdp(&opts)?;
                println!("\n=== Figures 2 & 3 — 3-room MDP (streak target 8) ===");
                for row in experiments::summarize(&curves, 8) {
                    println!("{row}");
                }
            }
            "fig4" => {
                let curves = experiments::fig4_cliques(&opts)?;
                println!("\n=== Figure 4 — clique graphs ===");
                for row in experiments::summarize(&curves, 3) {
                    println!("{row}");
                }
            }
            "fig5" => {
                let curves = experiments::fig5_linkpred(&opts)?;
                println!("\n=== Figure 5 — link prediction ===");
                for row in experiments::summarize(&curves, 3) {
                    println!("{row}");
                }
            }
            "fig6" => {
                let curves = experiments::fig6_series_terms(&opts)?;
                println!("\n=== Figure 6 — series degree sweep ===");
                for row in experiments::summarize(&curves, 3) {
                    println!("{row}");
                }
            }
            "walks" => {
                println!("\n=== §4.3 — walk estimator ===");
                for row in experiments::walk_estimator_experiment(&opts)? {
                    println!("{row}");
                }
            }
            other => anyhow::bail!("unknown figure {other:?}"),
        }
        Ok(())
    };
    if figure == "all" {
        for f in ["fig2", "fig4", "fig5", "fig6", "walks"] {
            run_figs(f)?;
        }
    } else {
        run_figs(&figure)?;
    }
    println!("\nCSV series written to {}/", opts.out_dir);
    Ok(())
}

fn cmd_walk_bench(mut args: Vec<String>) -> anyhow::Result<()> {
    let _cfg = load_config(&mut args)?;
    let spec = graph_spec("sped walk-bench")
        .opt("len", "3", "walk length (edge-incidence nodes)")
        .opt("walks", "50000", "total walk trials")
        .opt("workers", "4", "walker threads")
        .opt("method", "importance", "rejection | importance");
    let a = spec.parse(args).map_err(|e| anyhow::anyhow!(e))?;
    let (graph, _, _) = make_graph(&a)?;
    let method = sped::walks::SampleMethod::parse(&a.str("method"))?;
    let t0 = std::time::Instant::now();
    let pool = sped::coordinator::walkers::WalkerPool::spawn(
        std::sync::Arc::new(graph.clone()),
        sped::coordinator::walkers::WalkerPoolConfig {
            workers: a.usize("workers"),
            backlog: 8,
            method,
        },
    );
    let (est, stats) = pool.estimate_power(
        a.usize("len"),
        a.usize("walks"),
        a.usize("workers") * 4,
        a.u64("seed"),
    );
    pool.shutdown();
    let dt = t0.elapsed().as_secs_f64();
    let truth = sped::linalg::funcs::matpow(&graph.laplacian(), a.usize("len") as u64);
    let rel = (&est - &truth).max_abs() / truth.max_abs();
    println!(
        "L^{} estimate from {} walks ({} workers, {method:?}): rel err {:.4}, acceptance {:.3}, {:.0} walks/s",
        a.usize("len"),
        stats.trials,
        a.usize("workers"),
        rel,
        stats.acceptance_rate(),
        stats.trials as f64 / dt
    );
    Ok(())
}

fn cmd_gaps(mut args: Vec<String>) -> anyhow::Result<()> {
    let _cfg = load_config(&mut args)?;
    let spec = graph_spec("sped gaps").opt("k", "4", "bottom-k gaps to report");
    let a = spec.parse(args).map_err(|e| anyhow::anyhow!(e))?;
    let (graph, _, _) = make_graph(&a)?;
    let l = graph.laplacian();
    println!(
        "eigengap dilation report (max rho/g over bottom-{}):\n",
        a.usize("k")
    );
    for row in experiments::gap_report(&l, a.usize("k"))? {
        println!("{row}");
    }
    Ok(())
}

fn cmd_info(mut args: Vec<String>) -> anyhow::Result<()> {
    let _cfg = load_config(&mut args)?;
    let spec = ArgSpec::new("sped info", "detected capabilities of this binary");
    let _a = spec.parse(args).map_err(|e| anyhow::anyhow!(e))?;
    let threads = std::thread::available_parallelism().map_or(1, |p| p.get());
    println!("sped {} — capability report", env!("CARGO_PKG_VERSION"));
    println!(
        "  SIMD backend     : {} ({})",
        sped::linalg::simd::backend_name(),
        if cfg!(feature = "simd") {
            "portable-SIMD kernels, nightly `--features simd` build"
        } else {
            "stable unrolled register-blocked kernels"
        }
    );
    println!("  thread default   : {threads} (std::thread::available_parallelism)");
    println!("  precisions       : f64 (default, bitwise-deterministic), mixed (f32 storage + f64 accumulators, --op sparse only)");
    println!(
        "  crate features   : xla={} simd={}",
        cfg!(feature = "xla"),
        cfg!(feature = "simd")
    );
    println!("  capability string: {}", sped::util::bench::capability_string());
    Ok(())
}

fn cmd_artifacts(mut args: Vec<String>) -> anyhow::Result<()> {
    let _cfg = load_config(&mut args)?;
    let spec = ArgSpec::new("sped artifacts", "AOT artifact registry")
        .opt("dir", "artifacts", "artifacts directory");
    let a = spec.parse(args).map_err(|e| anyhow::anyhow!(e))?;
    let rt = sped::runtime::Runtime::load_dir(a.str("dir"))?;
    println!(
        "loaded + compiled {} artifacts from {}:",
        rt.names().len(),
        a.str("dir")
    );
    for name in rt.names() {
        let art = rt.get(name)?;
        println!(
            "  {:<22} kind={:<12} n={:<5} k={} t={} degree={} bits={} batch={}",
            name,
            art.meta.kind,
            art.meta.n,
            art.meta.k,
            art.meta.t,
            art.meta.degree,
            art.meta.bits,
            art.meta.batch
        );
    }
    Ok(())
}
