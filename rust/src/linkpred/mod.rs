//! Link prediction substrate (Appendix A.1 of the paper).
//!
//! The paper's probabilistic-graph experiment: drop each edge of a
//! well-clustered graph with probability `p`, score the *missing* pairs with
//! **common-neighbors** (Martínez et al. 2016), normalize scores over the
//! candidate set into probabilities, and rebuild a *weighted* graph =
//! surviving edges (weight 1) ∪ predicted edges (weight = probability).
//! Spectral clustering then runs on the weighted Laplacian `XᵀWX`.

use crate::graph::Graph;
use crate::linalg::DMat;
use crate::util::rng::Rng;
use anyhow::{Context, Result};

/// Result of the drop step.
#[derive(Clone, Debug)]
pub struct DroppedGraph {
    /// Graph with surviving edges only.
    pub graph: Graph,
    /// The removed edges (endpoints).
    pub removed: Vec<(usize, usize)>,
}

/// Remove each edge independently with probability `p`. Errors (instead
/// of panicking) if the surviving edge set cannot form a graph — which a
/// well-formed input never produces, but the contract matters to callers
/// feeding untrusted edge lists through here.
pub fn drop_edges(g: &Graph, p: f64, seed: u64) -> Result<DroppedGraph> {
    let mut rng = Rng::new(seed);
    let mut kept: Vec<(usize, usize, f64)> = Vec::new();
    let mut removed = Vec::new();
    for e in g.edges() {
        if rng.bernoulli(p) {
            removed.push((e.u as usize, e.v as usize));
        } else {
            kept.push((e.u as usize, e.v as usize, e.w));
        }
    }
    let graph = Graph::from_edges(g.num_nodes(), &kept)
        .context("drop_edges: rebuilding the surviving-edge graph")?;
    Ok(DroppedGraph { graph, removed })
}

/// Common-neighbors score for a node pair: `|N(u) ∩ N(v)|` (weighted
/// variant: Σ over common neighbors of min(w_u, w_v) — reduces to the count
/// for unit weights).
pub fn common_neighbors_score(g: &Graph, u: usize, v: usize) -> f64 {
    let nu = g.neighbors(u);
    let nv = g.neighbors(v);
    // CSR neighbor lists are unsorted here; use a small set for the larger.
    if nu.is_empty() || nv.is_empty() {
        return 0.0;
    }
    let (small, large) = if nu.len() <= nv.len() { (nu, nv) } else { (nv, nu) };
    let mut wmap = std::collections::HashMap::with_capacity(small.len());
    for &(x, w) in small {
        wmap.insert(x, w);
    }
    let mut score = 0.0;
    for &(x, w) in large {
        if let Some(&w2) = wmap.get(&x) {
            score += w.min(w2);
        }
    }
    score
}

/// Score a candidate set of missing pairs with common neighbors.
pub fn score_pairs(g: &Graph, pairs: &[(usize, usize)]) -> Vec<f64> {
    pairs
        .iter()
        .map(|&(u, v)| common_neighbors_score(g, u, v))
        .collect()
}

/// Normalize non-negative scores to probabilities scaled into `[0, 1]`
/// (paper: "normalize the scores over all missing edges to produce
/// probabilities"). Max-normalization keeps the strongest prediction at
/// weight 1 (comparable to a surviving edge); all-zero scores → zeros.
pub fn normalize_scores(scores: &[f64]) -> Vec<f64> {
    let max = scores.iter().cloned().fold(0.0f64, f64::max);
    if max <= 0.0 {
        return vec![0.0; scores.len()];
    }
    scores.iter().map(|&s| s / max).collect()
}

/// The full completion pipeline: graph with dropped edges → weighted graph
/// with predictions filled in on the *candidate* pairs (here: the actually
/// removed pairs, matching the paper's protocol of predicting the missing
/// edges).
pub fn complete_graph(dropped: &DroppedGraph) -> Result<Graph> {
    let g = &dropped.graph;
    let scores = normalize_scores(&score_pairs(g, &dropped.removed));
    let mut edges: Vec<(usize, usize, f64)> = g
        .edges()
        .iter()
        .map(|e| (e.u as usize, e.v as usize, e.w))
        .collect();
    for (&(u, v), &s) in dropped.removed.iter().zip(scores.iter()) {
        if s > 0.0 {
            edges.push((u, v, s));
        }
    }
    // A pathological candidate set (self-pair, out-of-range node,
    // duplicate of a surviving edge) surfaces as the `from_edges` error
    // naming the offending pair — never a panic.
    Graph::from_edges(g.num_nodes(), &edges)
        .context("complete_graph: adding predicted edges to the surviving graph")
}

/// Embedding-space link-prediction score: the dot product of two rows of
/// a **row-normalized** embedding (cosine similarity; zero rows score 0).
/// This is the serving-path analogue of [`common_neighbors_score`] — the
/// cached embedding stands in for the raw adjacency structure, so a score
/// depends only on the two rows, never on the rest of the query batch.
pub fn embedding_score(norm_rows: &DMat, u: usize, v: usize) -> f64 {
    let (a, b) = (norm_rows.row(u), norm_rows.row(v));
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{adjusted_rand_index, cluster_embedding};
    use crate::graph::gen::{cliques, CliqueSpec};
    use crate::linalg::eigh;

    #[test]
    fn drop_edges_rate() {
        let g = cliques(&CliqueSpec { n: 60, k: 2, max_short_circuit: 5, seed: 1 }).graph;
        let d = drop_edges(&g, 0.2, 7).unwrap();
        let frac = d.removed.len() as f64 / g.num_edges() as f64;
        assert!((frac - 0.2).abs() < 0.08, "drop rate {frac}");
        assert_eq!(d.graph.num_edges() + d.removed.len(), g.num_edges());
        // p=0 and p=1 extremes
        assert_eq!(drop_edges(&g, 0.0, 1).unwrap().removed.len(), 0);
        assert_eq!(drop_edges(&g, 1.0, 1).unwrap().graph.num_edges(), 0);
    }

    #[test]
    fn common_neighbors_counts() {
        // 0-1, 0-2, 1-2, 1-3, 2-3: CN(0,3) = {1,2} → 2.
        let g = Graph::from_pairs(4, &[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)]).unwrap();
        assert_eq!(common_neighbors_score(&g, 0, 3), 2.0);
        assert_eq!(common_neighbors_score(&g, 0, 1), 1.0); // via node 2
    }

    #[test]
    fn intra_clique_pairs_score_higher() {
        let gg = cliques(&CliqueSpec { n: 40, k: 2, max_short_circuit: 2, seed: 3 });
        let d = drop_edges(&gg.graph, 0.2, 5).unwrap();
        // Removed intra-clique pairs should have high CN; a random
        // inter-clique non-edge should score low.
        let scores = score_pairs(&d.graph, &d.removed);
        let intra_avg: f64 = scores.iter().sum::<f64>() / scores.len() as f64;
        let inter = common_neighbors_score(&d.graph, 0, 39); // different cliques
        assert!(intra_avg > inter + 2.0, "intra {intra_avg} vs inter {inter}");
    }

    #[test]
    fn normalize_scores_bounds() {
        let n = normalize_scores(&[2.0, 4.0, 0.0]);
        assert_eq!(n, vec![0.5, 1.0, 0.0]);
        assert_eq!(normalize_scores(&[0.0, 0.0]), vec![0.0, 0.0]);
        assert!(normalize_scores(&[]).is_empty());
    }

    #[test]
    fn completion_restores_clusterability() {
        // The App A.1 experiment in miniature: drop 20% of edges, complete
        // with common neighbors, cluster the weighted graph — ground truth
        // recovered.
        let gg = cliques(&CliqueSpec { n: 45, k: 3, max_short_circuit: 2, seed: 11 });
        let d = drop_edges(&gg.graph, 0.2, 13).unwrap();
        let completed = complete_graph(&d).unwrap();
        assert!(completed.num_edges() > d.graph.num_edges(), "predictions added");
        // Weighted Laplacian still PSD with zero row sums.
        let l = completed.laplacian();
        for i in 0..l.rows() {
            assert!(l.row(i).iter().sum::<f64>().abs() < 1e-9);
        }
        let e = eigh(&l).unwrap();
        assert!(e.values[0] > -1e-9);
        let emb = e.bottom_k(3);
        let r = cluster_embedding(&emb, 3, 17);
        let ari = adjusted_rand_index(&r.assignments, &gg.labels);
        assert!(ari > 0.9, "ARI after completion {ari}");
    }

    #[test]
    fn pathological_candidate_set_errors_instead_of_panicking() {
        // A self-pair candidate scores positive (a node shares all its
        // neighbors with itself) and used to panic inside from_edges; the
        // Result path must surface the offending pair instead.
        let gg = cliques(&CliqueSpec { n: 20, k: 2, max_short_circuit: 1, seed: 2 });
        let bad = DroppedGraph { graph: gg.graph.clone(), removed: vec![(0, 0)] };
        let err = complete_graph(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("self-loop"), "{err:#}");
    }

    #[test]
    fn embedding_score_is_cosine_on_normalized_rows() {
        use crate::cluster::row_normalize;
        let gg = cliques(&CliqueSpec { n: 30, k: 2, max_short_circuit: 1, seed: 5 });
        let e = eigh(&gg.graph.laplacian()).unwrap();
        let norm = row_normalize(&e.bottom_k(2));
        // Self-similarity is exactly 1 for a unit row; same-clique pairs
        // score far above cross-clique pairs.
        assert!((embedding_score(&norm, 0, 0) - 1.0).abs() < 1e-12);
        let same = embedding_score(&norm, 0, 1);
        let cross = embedding_score(&norm, 0, 29);
        assert!(same > cross + 0.5, "same {same} vs cross {cross}");
        // Zero rows score 0 (row_normalize leaves them untouched).
        let z = DMat::zeros(2, 2);
        assert_eq!(embedding_score(&z, 0, 1), 0.0);
    }

    #[test]
    fn predicted_weights_in_unit_interval() {
        let gg = cliques(&CliqueSpec { n: 30, k: 2, max_short_circuit: 1, seed: 21 });
        let d = drop_edges(&gg.graph, 0.3, 23).unwrap();
        let completed = complete_graph(&d).unwrap();
        for e in completed.edges() {
            assert!(e.w > 0.0 && e.w <= 1.0 + 1e-12, "weight {}", e.w);
        }
    }
}
