//! Spectral transforms — the paper's core contribution (§4, Table 2).
//!
//! A transform is a scalar function `f` applied to the spectrum of the graph
//! Laplacian: `f(L) = V diag(f(λ)) Vᵀ`. Because `f` is monotone increasing
//! (below the cutoff of interest), it **preserves eigenvectors and their
//! rank** while reshaping eigen*values* — chosen so the bottom-of-spectrum
//! eigengaps grow relative to the spectral radius, which is what iterative
//! stochastic solvers' convergence rates depend on.
//!
//! Table 2 of the paper, reproduced here in full:
//!
//! | name | f(x) |
//! |------|------|
//! | [`TransformKind::MatrixLog`]    | `log(x + ε)` (exact, via eigh) |
//! | [`TransformKind::TaylorLog`]    | `Σ_{i=1}^{ℓ} (−1)^{i+1} (x+ε−1)^i / i` |
//! | [`TransformKind::NegExp`]       | `−e^{−x}` (exact, via eigh) |
//! | [`TransformKind::TaylorNegExp`] | `−Σ_{i=0}^{ℓ} (−x)^i / i!` |
//! | [`TransformKind::LimitNegExp`]  | `−(1 − x/ℓ)^ℓ` (ℓ odd) |
//!
//! plus [`TransformKind::Identity`] as the baseline. After transforming, the
//! spectrum is *reversed* (eq 8): `M = λ*I − f(L)` turns the bottom-k
//! eigenvectors of `L` into the top-k of `M`, so any top-k solver applies —
//! the per-vector stochastic updates (Oja, µ-EigenGame) as well as the block
//! Rayleigh–Ritz subspace solver (`--solver ritz`, [`crate::solvers::ritz`]),
//! whose outer-iteration count contracts with the dilated gap ratio.
//! For the `−e^{−x}` family `f < 0` everywhere, so `λ* = 0` works and
//! `ρ(M) ≤ 1` (§4.2).
//!
//! ## Polynomial bases ([`PolyBasis`])
//!
//! Series transforms are polynomials in `L`; the basis their coefficients
//! live in is a knob (`--basis monomial|chebyshev`,
//! [`BuildOptions::basis`]), selected independently of [`OpMode`]:
//!
//! * **Monomial** (default) — polynomials **in the shifted matrix**
//!   `B = L − sI` evaluated by Horner ([`SeriesForm`]; not expanded to
//!   plain monomials — a degree-251 monomial expansion of the log series
//!   would need binomials ~1e74 and is numerically meaningless). This
//!   (shift, coeffs) representation is consumed by the L1 Pallas kernel
//!   `poly_horner` and the AOT artifact, keeping the native and XLA paths
//!   bit-compatible in structure, and it is bitwise-identical to the
//!   pre-basis-knob evaluation. Its limit: the basis itself loses digits
//!   as the degree grows, and `LimitNegExp` has *no* usable shifted-power
//!   form (the coefficient `ℓ^{−ℓ}` underflows f64 at ℓ = 251), which
//!   forces a repeated-multiply special case on the matrix-free path.
//! * **Chebyshev** — coefficients of `Σ c_j T_j(y)` with the spectrum
//!   domain `[0, λ̂_max]` mapped to `y ∈ [−1, 1]` ([`ChebSeries`]),
//!   evaluated by the three-term recurrence
//!   `T_{j+1}(L)V = 2·Y·(T_j V) − T_{j−1}V` with each step one fused
//!   [`crate::linalg::sparse::spmm_step_into`] pass. `|T_j| ≤ 1` on the
//!   domain, so the representation is stable at the ℓ ≈ 251 degrees the
//!   paper's series use, and every polynomial kind — `LimitNegExp`
//!   included — goes through the same principled path, no underflow
//!   special-casing. Native-only (the XLA artifacts encode Horner) and
//!   rejected for the exact (eigh-based) kinds, which are not polynomials.
//!
//! ## Spectral domains & adaptive degrees ([`DomainEstimate`], [`Degree`])
//!
//! The Chebyshev fit interval and the number of kept filter terms are
//! policies ([`domain`] module; `--domain power|lanczos|gershgorin`,
//! `--degree native|auto|N`, `--cheb-tol`), shared verbatim by the dense
//! build and the matrix-free operator. The defaults (`power` + `native`)
//! are bitwise-identical to the historical behavior; `--domain lanczos`
//! fits on a tight two-sided Ritz interval
//! ([`crate::linalg::lanczos`]) and `--degree auto` truncates the
//! coefficient tail below a tolerance ([`ChebSeries::truncated`]) — the
//! combination that evaluates the same dilation in a fraction of the SpMM
//! sweeps (each kept coefficient is one sweep per operator application).
//!
//! ## Dense vs matrix-free evaluation ([`OpMode`])
//!
//! A series transform can reach the solver two ways:
//!
//! * **[`OpMode::DenseMaterialized`]** — build `p(L)` once as an `n×n`
//!   matrix (`O(ℓ·n³)` via [`SeriesForm::eval_matrix_threads`] / matpow),
//!   then every solver step is one `O(n²·k)` dense multiply.
//! * **[`OpMode::MatrixFree`]** — never form anything `n×n`: each solver
//!   step evaluates `p(L)·V` directly through `ℓ` sparse multiplies against
//!   the CSR Laplacian ([`SeriesForm::apply_bundle`] /
//!   `solvers::SparsePolyOp`), `O(ℓ·nnz·k)` per step and `O(n + nnz)`
//!   memory.
//!
//! Crossover guidance: matrix-free wins whenever the dense build does not
//! amortize — per step it wins while `ℓ·nnz ≲ n²` (sparsity below `1/ℓ`),
//! and including the build it wins for any short-to-moderate solve because
//! the `O(ℓ·n³)` build alone costs as much as `ℓ·n/k` matrix-free steps.
//! On large sparse graphs (`nnz ≪ n²`) the dense path additionally needs
//! `8n²` bytes (a 50k-node graph → 20 GB) while CSR needs a few MB, so
//! beyond ~5k nodes matrix-free is effectively the only native option.
//! Exact transforms ([`TransformKind::MatrixLog`], [`TransformKind::NegExp`])
//! are eigendecomposition-based oracles and stay dense-only.

pub mod basis;
pub mod domain;

pub use basis::{
    affine_compose, cheb_domain, chebyshev_to_monomial, monomial_to_chebyshev, ChebSeries,
    PolyBasis, PolySeries,
};
pub use domain::{mixed_error_budget, Degree, DomainEstimate, Precision, SpectrumEstimate};

use crate::linalg::dmat::DMat;
use crate::linalg::funcs::{matpow, poly_horner, power_lambda_max, spectral_apply};
use crate::linalg::shard::StepOperand;
use crate::linalg::sparse::CsrMat;
use anyhow::{anyhow, bail, Result};

/// A spectral transform from Table 2 (or the identity baseline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TransformKind {
    /// Baseline: `f(x) = x`.
    Identity,
    /// Exact `log(x + ε)` via full eigendecomposition.
    MatrixLog { eps: f64 },
    /// Degree-`ell` Taylor series of `log(x + ε)` about `x + ε = 1`.
    TaylorLog { ell: usize, eps: f64 },
    /// Exact `−e^{−x}` via full eigendecomposition.
    NegExp,
    /// Degree-`ell` Taylor series of `−e^{−x}` about 0.
    TaylorNegExp { ell: usize },
    /// Limit approximation `−(1 − x/ℓ)^ℓ`, `ℓ` odd (the paper's best series).
    LimitNegExp { ell: usize },
}

/// How the solver operator `M = λ*I − p(L)` is realized on the native
/// backend (see the module docs for the asymptotics and crossover).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OpMode {
    /// Materialize `p(L)` as a dense `n×n` matrix once, then dense `M·V`
    /// per solver step. The historical default.
    #[default]
    DenseMaterialized,
    /// Never materialize: each solver step evaluates `(λ*I − p(L))·V`
    /// through sparse multiplies against the CSR Laplacian.
    MatrixFree,
}

impl OpMode {
    /// Parse from a CLI/config name (`dense` | `sparse`).
    pub fn parse(s: &str) -> Result<OpMode> {
        Ok(match s {
            "dense" | "materialized" => OpMode::DenseMaterialized,
            "sparse" | "matrix-free" | "matrix_free" | "matrixfree" => OpMode::MatrixFree,
            other => bail!("unknown op mode {other:?} (expected dense | sparse)"),
        })
    }

    /// Canonical display name.
    pub fn name(&self) -> &'static str {
        match self {
            OpMode::DenseMaterialized => "dense",
            OpMode::MatrixFree => "sparse",
        }
    }
}

impl std::fmt::Display for OpMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// A polynomial in the shifted matrix `B = A − shift·I`:
/// `p(A) = Σ_i coeffs[i] · B^i`.
#[derive(Clone, Debug, PartialEq)]
pub struct SeriesForm {
    pub shift: f64,
    pub coeffs: Vec<f64>,
}

impl SeriesForm {
    /// Evaluate at a scalar.
    pub fn eval_scalar(&self, x: f64) -> f64 {
        let b = x - self.shift;
        let mut acc = 0.0;
        for &c in self.coeffs.iter().rev() {
            acc = acc * b + c;
        }
        acc
    }

    /// Evaluate at a matrix via Horner (deg(p) dense multiplies).
    pub fn eval_matrix(&self, a: &DMat) -> DMat {
        self.eval_matrix_threads(a, 1)
    }

    /// Evaluate at a matrix with every Horner multiply row-sharded across
    /// `threads` workers. Bitwise identical to [`Self::eval_matrix`].
    pub fn eval_matrix_threads(&self, a: &DMat, threads: usize) -> DMat {
        let mut b = a.clone();
        b.add_diag(-self.shift);
        // Work per Horner multiply is n³ multiply-adds; the shared guard
        // keeps tiny builds serial (bitwise-identical either way).
        let n = b.rows();
        let threads = crate::linalg::par::effective_threads(
            n.saturating_mul(n).saturating_mul(n),
            threads,
        );
        if threads > 1 {
            crate::linalg::par::poly_horner_par(&b, &self.coeffs, threads)
        } else {
            poly_horner(&b, &self.coeffs)
        }
    }

    /// Matrix-free bundle apply: `p(A)·V` for sparse `A` via Horner on the
    /// *columns* — `deg(p)` sparse multiplies (`R ← A·R − shift·R + c_i·V`),
    /// never an `n×n` intermediate. `O(deg(p)·nnz·k)` work, `O(n·k)` memory.
    ///
    /// This is the monomial-basis solver-step path behind
    /// `OpMode::MatrixFree` (`solvers::SparsePolyOp`); each Horner step is
    /// one fused [`crate::linalg::sparse::spmm_step_into`] pass
    /// (register-blocked for `k ≤ 16`
    /// bundles), bitwise identical to the historical
    /// SpMM + `axpy` + `axpy` composition and for every worker count (the
    /// [`crate::linalg::sparse`] determinism contract).
    pub fn apply_bundle(&self, a: &CsrMat, v: &DMat, threads: usize) -> DMat {
        assert!(a.is_square(), "apply_bundle needs a square operator");
        self.apply_bundle_via(&StepOperand::Csr(a), v, threads)
    }

    /// [`Self::apply_bundle`] generalized over the stepping operand: the
    /// same Horner recurrence runs against either the plain CSR fused
    /// kernel or a [`crate::linalg::shard::ShardedCsr`] two-phase apply
    /// (one halo exchange per sweep). Bitwise-identical across operands
    /// and worker counts.
    pub fn apply_bundle_via(&self, op: &StepOperand<'_>, v: &DMat, threads: usize) -> DMat {
        assert_eq!(op.rows(), v.rows(), "apply_bundle shape mismatch");
        if self.coeffs.is_empty() {
            return DMat::zeros(v.rows(), v.cols());
        }
        let d = self.coeffs.len() - 1;
        let mut r = v.clone();
        r.scale(self.coeffs[d]);
        // Ping-pong between two preallocated bundles: deg(p) fused passes
        // per apply with zero per-iteration allocations.
        let mut t = DMat::zeros(v.rows(), v.cols());
        for i in (0..d).rev() {
            // R ← B·R + c_i·V with B = A − shift·I, in one pass.
            op.step_into(&r, v, -self.shift, 1.0, self.coeffs[i], &mut t, threads);
            std::mem::swap(&mut r, &mut t);
        }
        r
    }

    pub fn degree(&self) -> usize {
        self.coeffs.len().saturating_sub(1)
    }
}

impl TransformKind {
    /// Parse from a CLI/config name, e.g. `identity`, `log:0.05`,
    /// `taylor_log:251:0.05`, `negexp`, `taylor_negexp:251`,
    /// `limit_negexp:251`.
    pub fn parse(s: &str) -> Result<TransformKind> {
        let parts: Vec<&str> = s.split(':').collect();
        let kind = match parts[0] {
            "identity" | "id" => TransformKind::Identity,
            "log" | "matrix_log" => TransformKind::MatrixLog {
                eps: parts.get(1).map(|p| p.parse()).transpose()?.unwrap_or(0.05),
            },
            "taylor_log" => TransformKind::TaylorLog {
                ell: parts.get(1).map(|p| p.parse()).transpose()?.unwrap_or(251),
                eps: parts.get(2).map(|p| p.parse()).transpose()?.unwrap_or(0.05),
            },
            "negexp" | "neg_exp" => TransformKind::NegExp,
            "taylor_negexp" => TransformKind::TaylorNegExp {
                ell: parts.get(1).map(|p| p.parse()).transpose()?.unwrap_or(251),
            },
            "limit_negexp" => TransformKind::LimitNegExp {
                ell: parts.get(1).map(|p| p.parse()).transpose()?.unwrap_or(251),
            },
            other => bail!("unknown transform {other:?}"),
        };
        if let TransformKind::LimitNegExp { ell } = kind {
            if ell % 2 == 0 {
                bail!("limit_negexp requires odd ℓ (got {ell})");
            }
        }
        Ok(kind)
    }

    /// Canonical display name (used in CSV labels and figure legends).
    pub fn name(&self) -> String {
        match self {
            TransformKind::Identity => "identity".into(),
            TransformKind::MatrixLog { eps } => format!("log(L+{eps})"),
            TransformKind::TaylorLog { ell, eps } => format!("taylor_log_T{ell}(eps={eps})"),
            TransformKind::NegExp => "-exp(-L)".into(),
            TransformKind::TaylorNegExp { ell } => format!("taylor_negexp_T{ell}"),
            TransformKind::LimitNegExp { ell } => format!("limit_negexp_T{ell}"),
        }
    }

    /// True for transforms that require a full eigendecomposition (the
    /// expensive oracles the series forms approximate).
    pub fn is_exact(&self) -> bool {
        matches!(self, TransformKind::MatrixLog { .. } | TransformKind::NegExp)
    }

    /// True for transforms expressible as a polynomial apply — i.e. usable
    /// under [`OpMode::MatrixFree`], in **either** polynomial basis
    /// (`--basis monomial|chebyshev`; see [`Self::series`] /
    /// [`Self::cheb_series`]). The exact (eigh-based) kinds are not
    /// polynomials at all, so they support neither matrix-free evaluation
    /// nor the Chebyshev basis — both are rejected with an error, never
    /// silently fallen back from.
    pub fn supports_matrix_free(&self) -> bool {
        !self.is_exact()
    }

    /// The scalar spectrum map this transform applies (for series kinds:
    /// the *truncated* series, which is what actually hits the matrix).
    pub fn scalar_map(&self, x: f64) -> f64 {
        match *self {
            TransformKind::Identity => x,
            TransformKind::MatrixLog { eps } => (x + eps).max(f64::MIN_POSITIVE).ln(),
            TransformKind::NegExp => -(-x).exp(),
            TransformKind::LimitNegExp { ell } => limit_negexp_scalar(x, ell),
            TransformKind::TaylorLog { .. } | TransformKind::TaylorNegExp { .. } => {
                self.series().expect("series kind").eval_scalar(x)
            }
        }
    }

    /// The **monomial-basis** (shifted-power) series representation, for
    /// the polynomial kinds that have a usable one. `LimitNegExp` does not
    /// — its leading coefficient `ℓ^{−ℓ}` underflows f64 — so the monomial
    /// path special-cases it as a repeated matrix power, while the
    /// Chebyshev basis ([`Self::cheb_series`], `--basis chebyshev`)
    /// represents it like any other polynomial.
    pub fn series(&self) -> Option<SeriesForm> {
        match *self {
            TransformKind::TaylorLog { ell, eps } => {
                // Σ_{i=1}^{ℓ} (−1)^{i+1} B^i / i with B = L + εI − I.
                let mut coeffs = vec![0.0; ell + 1];
                for (i, c) in coeffs.iter_mut().enumerate().skip(1) {
                    let sign = if i % 2 == 1 { 1.0 } else { -1.0 };
                    *c = sign / i as f64;
                }
                Some(SeriesForm { shift: 1.0 - eps, coeffs })
            }
            TransformKind::TaylorNegExp { ell } => {
                // −Σ_{i=0}^{ℓ} (−x)^i / i!  →  c_i = −(−1)^i / i!
                let mut coeffs = Vec::with_capacity(ell + 1);
                let mut fact = 1.0f64;
                for i in 0..=ell {
                    if i > 0 {
                        fact *= i as f64;
                    }
                    coeffs.push(if i % 2 == 0 { -1.0 } else { 1.0 } / fact);
                }
                Some(SeriesForm { shift: 0.0, coeffs })
            }
            TransformKind::LimitNegExp { .. } => {
                // Not expanded: evaluated by matpow (binomial monomial
                // coefficients at ℓ=251 would be ~1e74 — ill-conditioned).
                None
            }
            _ => None,
        }
    }

    /// The native polynomial degree of this transform's series — the ℓ the
    /// paper's protocol evaluates (1 for the identity). `None` for the
    /// exact (eigh-based) kinds, which are not polynomials.
    pub fn series_degree(&self) -> Option<usize> {
        match *self {
            TransformKind::Identity => Some(1),
            TransformKind::TaylorLog { ell, .. }
            | TransformKind::TaylorNegExp { ell }
            | TransformKind::LimitNegExp { ell } => Some(ell),
            TransformKind::MatrixLog { .. } | TransformKind::NegExp => None,
        }
    }

    /// The **Chebyshev-basis** representation of the polynomial kinds on
    /// the spectrum domain `[lo, hi]` (typically the [`DomainEstimate`]'s
    /// interval over the transform input), fitted stably by interpolation
    /// of [`Self::scalar_map`] at Chebyshev nodes — exact for these kinds,
    /// whose scalar maps *are* polynomials of the fitted degree. `None`
    /// for the exact (eigh-based) kinds, which are not polynomials.
    pub fn cheb_series(&self, lo: f64, hi: f64) -> Option<ChebSeries> {
        self.cheb_series_deg(self.series_degree()?, lo, hi)
    }

    /// [`Self::cheb_series`] at an explicit fit degree (the [`Degree`]
    /// knob): `degree ≥` native is exact; `degree <` native is the
    /// near-minimax interpolant compression of the filter — the same
    /// dilation shape evaluated in fewer SpMM sweeps.
    pub fn cheb_series_deg(&self, degree: usize, lo: f64, hi: f64) -> Option<ChebSeries> {
        self.series_degree()?;
        Some(ChebSeries::fit(degree, lo, hi, |x| self.scalar_map(x)))
    }

    /// Materialize `f(L)` natively.
    ///
    /// * exact kinds → full eigendecomposition (O(n³), the oracle);
    /// * Taylor kinds → Horner in the shifted matrix (ℓ multiplies);
    /// * limit kind → binary matrix power (≈ 2·log₂ℓ multiplies).
    pub fn build(&self, l: &DMat) -> Result<DMat> {
        self.build_threaded(l, 1)
    }

    /// [`Self::build`] with the series hot paths (Horner / matpow)
    /// row-sharded across `threads` workers. Bitwise identical to the
    /// serial build for every worker count; the exact (eigh-based) kinds
    /// are unaffected by `threads`.
    pub fn build_threaded(&self, l: &DMat, threads: usize) -> Result<DMat> {
        match *self {
            TransformKind::Identity => Ok(l.clone()),
            TransformKind::MatrixLog { eps } => {
                spectral_apply(l, |x| (x + eps).max(f64::MIN_POSITIVE).ln())
            }
            TransformKind::NegExp => spectral_apply(l, |x| -(-x).exp()),
            TransformKind::TaylorLog { .. } | TransformKind::TaylorNegExp { .. } => {
                Ok(self.series().unwrap().eval_matrix_threads(l, threads))
            }
            TransformKind::LimitNegExp { ell } => {
                // −(I − L/ℓ)^ℓ via square-and-multiply.
                let mut b = l.clone();
                b.scale(-1.0 / ell as f64);
                b.add_diag(1.0);
                let mut p = if threads > 1 {
                    crate::linalg::par::matpow_par(&b, ell as u64, threads)
                } else {
                    matpow(&b, ell as u64)
                };
                p.scale(-1.0);
                Ok(p)
            }
        }
    }

    /// The reversal shift `λ*` of eq 8, given `rho` = (an upper bound on)
    /// the spectral radius of the *input* matrix. Must satisfy
    /// `λ* > max_x≤rho f(x)` so that `M = λ*I − f(L)` is PSD-ordered with
    /// the bottom of `L` on top.
    pub fn lambda_star(&self, rho: f64) -> f64 {
        match *self {
            // −e^{−x} family: f < 0 everywhere → λ* = 0 (§4.2).
            TransformKind::NegExp
            | TransformKind::TaylorNegExp { .. }
            | TransformKind::LimitNegExp { .. } => 0.0,
            _ => {
                // Monotone increasing on [0, rho] → max at rho. Pad by 1% of
                // the spread so the top eigenvalue of M stays strictly
                // positive.
                let hi = self.scalar_map(rho);
                let lo = self.scalar_map(0.0);
                hi + 0.01 * (hi - lo).abs().max(1e-6)
            }
        }
    }
}

impl std::fmt::Display for TransformKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Scalar version of LimitNegExp (used by `scalar_map` via this helper to
/// avoid constructing matrices).
pub fn limit_negexp_scalar(x: f64, ell: usize) -> f64 {
    -(1.0 - x / ell as f64).powi(ell as i32)
}

/// The matrix a solver actually iterates on, with provenance.
#[derive(Clone, Debug)]
pub struct SolverMatrix {
    /// `M = λ*I − f(L/scale)` — top-k eigenvectors of `M` are the bottom-k
    /// of `L`.
    pub m: DMat,
    /// Reversal shift used (eq 8).
    pub lambda_star: f64,
    /// Pre-scaling applied to `L` before the transform (`L ← L/scale`).
    pub scale: f64,
    /// The transform that produced `m`.
    pub kind: TransformKind,
}

/// Options for [`build_solver_matrix`].
#[derive(Clone, Copy, Debug)]
pub struct BuildOptions {
    /// Pre-scale `L` by `1/λ̂_max` before transforming (eigenvector
    /// preserving). **Default false**: the dilation benefit of the
    /// `−e^{−x}` family comes precisely from crushing the *raw* large
    /// eigenvalues; compressing the spectrum into `[0,1]` first would make
    /// `−e^{−x}` near-linear and neutralize it. Pre-scaling exists for the
    /// Taylor-log transform, whose series only converges for ρ(L+εI−I) < 1.
    pub prescale: bool,
    /// Power-iteration steps for the λ_max estimate.
    pub power_iters: usize,
    /// Safety factor multiplied onto the λ_max estimate.
    pub safety: f64,
    /// Worker threads for the dense build kernels (Horner / matpow / power
    /// iteration). `1` = serial; any value produces bitwise-identical
    /// output (`linalg::par` determinism contract).
    pub threads: usize,
    /// Polynomial basis the series transforms are evaluated in. **Default
    /// [`PolyBasis::Monomial`]**, which is bitwise-identical to the
    /// pre-basis-knob build; [`PolyBasis::Chebyshev`] switches every
    /// polynomial kind to the domain-mapped three-term recurrence (stable
    /// at high degree, no `LimitNegExp` special case) and is rejected for
    /// the exact (eigh-based) kinds.
    pub basis: PolyBasis,
    /// How the spectral interval (Chebyshev fit domain + the ρ feeding
    /// λ*) is estimated (`--domain power|lanczos|gershgorin`). **Default
    /// [`DomainEstimate::Power`]**, bitwise-identical to the pre-knob
    /// builds; [`DomainEstimate::Lanczos`] fits on a tight two-sided Ritz
    /// interval — the knob that makes [`Self::degree`] truncation bite.
    pub domain: DomainEstimate,
    /// Chebyshev filter degree policy (`--degree native|auto|N`,
    /// `--cheb-tol`). **Default [`Degree::Native`]** (the transform's own
    /// ℓ, bitwise-identical); the other policies reshape the evaluated
    /// polynomial and require [`PolyBasis::Chebyshev`].
    pub degree: Degree,
    /// Arithmetic precision of the matrix-free SpMM sweeps
    /// (`--precision f64|mixed`). **Default [`Precision::F64`]**, the
    /// bitwise-compat path; [`Precision::Mixed`] stores the Laplacian and
    /// bundle panels in `f32` with `f64` accumulators
    /// ([`crate::linalg::sparse::CsrMatF32`]) — inexact iterative stages
    /// only, with the [`mixed_error_budget`] contract. Rejected for the
    /// dense build, exact transforms, and ground-truth paths.
    pub precision: Precision,
    /// Graph-shard count for the matrix-free SpMM sweeps (`--shards N`).
    /// **Default 0** (unsharded fused kernels, the historical path);
    /// `N ≥ 1` partitions the operator into `N` contiguous row shards and
    /// runs every series sweep as a two-phase owned/halo apply
    /// ([`crate::linalg::shard::ShardedCsr`]) with one halo exchange per
    /// sweep — bitwise-equal to the unsharded operator at every
    /// (shard, worker) pair. Sparse path only; rejected with
    /// `--precision mixed` and the dense build.
    pub shards: usize,
}

impl Default for BuildOptions {
    fn default() -> Self {
        BuildOptions {
            prescale: false,
            power_iters: 100,
            safety: 1.01,
            threads: 1,
            basis: PolyBasis::Monomial,
            domain: DomainEstimate::Power,
            degree: Degree::Native,
            precision: Precision::F64,
            shards: 0,
        }
    }
}

/// Full native pipeline from Laplacian to solver matrix:
/// (optionally) pre-scale → `f(·)` → reverse (eq 8).
pub fn build_solver_matrix(l: &DMat, kind: TransformKind, opts: &BuildOptions) -> Result<SolverMatrix> {
    let threads = opts.threads.max(1);
    opts.degree.validate_basis(opts.basis)?;
    if opts.precision.is_mixed() {
        bail!(
            "--precision mixed applies only to the matrix-free (sparse) operator \
             path — the dense materialized build is f64-only; use --op-mode sparse \
             or --precision f64"
        );
    }
    if opts.shards > 0 {
        bail!(
            "--shards applies only to the matrix-free (sparse) operator path — \
             the dense materialized build has no halo schedule; use --op-mode \
             sparse or drop --shards"
        );
    }
    // The power estimate feeds the pre-scale factor and the Power domain's
    // ρ; when neither consumes it (un-prescaled Lanczos/Gershgorin domains,
    // which derive ρ from their own interval) the 100-matvec iteration is
    // skipped entirely.
    let need_power = opts.prescale || opts.domain == DomainEstimate::Power;
    let lam_est = if need_power {
        let lam_raw = if threads > 1 {
            crate::linalg::par::power_lambda_max_par(l, opts.power_iters, threads)?
        } else {
            power_lambda_max(l, opts.power_iters)?
        };
        lam_raw * opts.safety
    } else {
        0.0
    };
    let scale = if opts.prescale && lam_est > 0.0 { lam_est } else { 1.0 };
    let mut scaled = l.clone();
    scaled.scale(1.0 / scale);
    // Spectral radius hint for the transform *input*: 1 after pre-scaling,
    // else the λ_max estimate (safety-padded). The shared [`DomainEstimate`]
    // policy turns it into ρ plus the Chebyshev fit interval — exactly one
    // place decides the ρ-vs-Gershgorin fallback for both the dense and the
    // matrix-free builds.
    let rho_hint = if opts.prescale { 1.0 } else { lam_est };
    let est = opts.domain.estimate_dense(&scaled, rho_hint, threads)?;
    let f_l = match opts.basis {
        PolyBasis::Monomial => kind.build_threaded(&scaled, threads)?,
        PolyBasis::Chebyshev => {
            let native = kind.series_degree().ok_or_else(|| {
                anyhow!(
                    "exact transform {kind} is eigendecomposition-based and has no \
                     polynomial form in any basis — use --basis monomial (series \
                     transforms support both bases)"
                )
            })?;
            let fit = opts.degree.checked_fit_degree(native)?;
            let cheb = kind.cheb_series_deg(fit, est.lo, est.hi).expect("polynomial kind");
            opts.degree.shape(cheb).eval_matrix_threads(&scaled, threads)
        }
    };
    let lambda_star = kind.lambda_star(est.rho);
    // M = λ*I − f(L)
    let mut m = f_l;
    m.scale(-1.0);
    m.add_diag(lambda_star);
    Ok(SolverMatrix { m, lambda_star, scale, kind })
}

/// Relative eigengap diagnostics: for a spectrum `λ` (ascending) returns
/// `ρ / g_i` for the bottom `k` gaps — the quantity the paper argues
/// controls solver convergence (smaller is better).
pub fn gap_ratios(spectrum: &[f64], k: usize) -> Vec<f64> {
    if spectrum.len() < 2 {
        return vec![];
    }
    let rho = spectrum
        .iter()
        .fold(0.0f64, |m, &x| m.max(x.abs()));
    (0..k.min(spectrum.len() - 1))
        .map(|i| {
            let g = (spectrum[i + 1] - spectrum[i]).abs();
            if g > 0.0 {
                rho / g
            } else {
                f64::INFINITY
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, CliqueSpec};
    use crate::linalg::eigh;

    fn test_laplacian() -> DMat {
        cliques(&CliqueSpec { n: 32, k: 4, max_short_circuit: 3, seed: 1 })
            .graph
            .laplacian()
    }

    #[test]
    fn parse_roundtrip() {
        for s in [
            "identity",
            "log:0.05",
            "taylor_log:51:0.1",
            "negexp",
            "taylor_negexp:31",
            "limit_negexp:251",
        ] {
            let t = TransformKind::parse(s).unwrap();
            assert!(!t.name().is_empty());
        }
        assert!(TransformKind::parse("bogus").is_err());
        assert!(TransformKind::parse("limit_negexp:10").is_err(), "even ℓ rejected");
    }

    #[test]
    fn exact_transforms_preserve_eigenvectors() {
        let l = test_laplacian();
        let e_l = eigh(&l).unwrap();
        for kind in [TransformKind::NegExp, TransformKind::MatrixLog { eps: 0.05 }] {
            let fl = kind.build(&l).unwrap();
            let e_f = eigh(&fl).unwrap();
            // Spectrum maps elementwise; since f is monotone increasing the
            // ascending order is preserved, so sorted spectra correspond.
            for i in 0..l.rows() {
                let expected = kind.scalar_map(e_l.values[i]);
                assert!(
                    (e_f.values[i] - expected).abs() < 1e-8,
                    "{kind}: λ_{i} {} vs {}",
                    e_f.values[i],
                    expected
                );
            }
            // Bottom-k eigenvectors span the same subspace.
            let k = 4;
            let err = crate::linalg::metrics::subspace_error(
                &e_l.bottom_k(k),
                &e_f.bottom_k(k),
            );
            assert!(err < 1e-8, "{kind}: subspace err {err}");
        }
    }

    #[test]
    fn series_transforms_approximate_exact_on_unit_interval() {
        // After pre-scaling, eigenvalues live in [0,1]; both series should
        // track their exact counterparts closely there.
        let te = TransformKind::TaylorNegExp { ell: 31 };
        let le = TransformKind::LimitNegExp { ell: 251 };
        let tl = TransformKind::TaylorLog { ell: 251, eps: 0.05 };
        for i in 0..=20 {
            let x = i as f64 / 20.0;
            assert!((te.scalar_map(x) - (-(-x).exp())).abs() < 1e-10);
            assert!((le.scalar_map(x) - (-(-x).exp())).abs() < 2e-3, "x={x}");
            // Taylor-log truncation is slowest at x=0 (r = 0.95):
            // 0.95^252/(252·0.05) ≈ 2.4e-7.
            assert!((tl.scalar_map(x) - (x + 0.05).ln()).abs() < 1e-5, "x={x}");
        }
    }

    #[test]
    fn limit_negexp_monotone_everywhere_odd_ell() {
        // ℓ odd → monotone increasing on all of ℝ (the reason Table 2
        // requires odd ℓ).
        let t = TransformKind::LimitNegExp { ell: 11 };
        let mut prev = f64::NEG_INFINITY;
        for i in 0..200 {
            let x = -2.0 + i as f64 * 0.05; // range [-2, 8], beyond ℓ scale
            let y = t.scalar_map(x);
            assert!(y >= prev - 1e-12, "not monotone at x={x}");
            prev = y;
        }
    }

    #[test]
    fn matrix_series_matches_scalar_on_spectrum() {
        let l = test_laplacian();
        let mut scaled = l.clone();
        let lam = eigh(&l).unwrap().lambda_max();
        scaled.scale(1.0 / lam);
        let e_s = eigh(&scaled).unwrap();
        for kind in [
            TransformKind::TaylorNegExp { ell: 31 },
            TransformKind::LimitNegExp { ell: 51 },
            TransformKind::TaylorLog { ell: 61, eps: 0.05 },
        ] {
            let fl = kind.build(&scaled).unwrap();
            let e_f = eigh(&fl).unwrap();
            for i in 0..scaled.rows() {
                let expected = kind.scalar_map(e_s.values[i]);
                assert!(
                    (e_f.values[i] - expected).abs() < 1e-6,
                    "{kind} λ_{i}: {} vs {}",
                    e_f.values[i],
                    expected
                );
            }
        }
    }

    #[test]
    fn solver_matrix_reverses_spectrum() {
        let l = test_laplacian();
        let e_l = eigh(&l).unwrap();
        for kind in [
            TransformKind::Identity,
            TransformKind::NegExp,
            TransformKind::LimitNegExp { ell: 51 },
        ] {
            let sm = build_solver_matrix(&l, kind, &BuildOptions::default()).unwrap();
            let e_m = eigh(&sm.m).unwrap();
            // Top eigenvector of M == bottom eigenvector of L (up to sign).
            let top_m = e_m.vectors.col(l.rows() - 1);
            let bot_l = e_l.vectors.col(0);
            let dot = crate::linalg::dmat::dot(&top_m, &bot_l).abs();
            assert!(dot > 1.0 - 1e-6, "{kind}: alignment {dot}");
            // And M's spectrum is bounded: for negexp family ρ(M) ≤ 1.
            if matches!(kind, TransformKind::NegExp | TransformKind::LimitNegExp { .. }) {
                assert!(e_m.lambda_max() <= 1.0 + 1e-9, "{kind}");
                assert!(e_m.values[0] >= -1e-9, "{kind}: M not PSD");
            }
        }
    }

    #[test]
    fn transforms_dilate_relative_gaps() {
        // The headline claim: on a well-clustered graph, ρ/g_k shrinks after
        // the −e^{−x} transform (with pre-scaling).
        let l = test_laplacian();
        let e_l = eigh(&l).unwrap();
        let k = 4;
        let before = gap_ratios(&e_l.values, k);
        let sm = build_solver_matrix(&l, TransformKind::NegExp, &BuildOptions::default()).unwrap();
        // Spectrum of M = λ*I − f(L) in *original L order* (ascending in L
        // = descending in M): gaps then line up with eigenvector indices.
        let e_m = eigh(&sm.m).unwrap();
        let mut m_spec_in_l_order: Vec<f64> = e_m.values.clone();
        m_spec_in_l_order.reverse(); // M-top first = L-bottom first
        let rho = e_m.lambda_max().abs().max(e_m.values[0].abs());
        let after: Vec<f64> = (0..k)
            .map(|i| rho / (m_spec_in_l_order[i] - m_spec_in_l_order[i + 1]).abs())
            .collect();
        // The *binding* constraint on solver convergence is the worst
        // (largest) ratio among the bottom-k gaps; it must improve by a
        // large factor. (Individual bulk gaps may shrink — that's fine and
        // expected: −e^{−x} compresses the top of the spectrum.)
        let worst_before = before.iter().cloned().fold(0.0f64, f64::max);
        let worst_after = after.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            worst_after < worst_before * 0.25,
            "binding gap ratio did not improve ≥4×: before={before:?} after={after:?}"
        );
    }

    #[test]
    fn gap_ratio_helper() {
        let r = gap_ratios(&[0.0, 0.1, 1.0], 2);
        assert!((r[0] - 10.0).abs() < 1e-12);
        assert!((r[1] - 1.0 / 0.9).abs() < 1e-12);
        assert!(gap_ratios(&[1.0], 3).is_empty());
    }

    #[test]
    fn threaded_build_bitwise_matches_serial() {
        let l = test_laplacian();
        for kind in [
            TransformKind::TaylorNegExp { ell: 31 },
            TransformKind::LimitNegExp { ell: 51 },
            TransformKind::TaylorLog { ell: 41, eps: 0.05 },
        ] {
            let serial = kind.build(&l).unwrap();
            for threads in [2usize, 8] {
                let par = kind.build_threaded(&l, threads).unwrap();
                let identical = serial
                    .data()
                    .iter()
                    .zip(par.data().iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(identical, "{kind} diverged at {threads} threads");
            }
        }
        // And the full solver-matrix build, threads knob included.
        let serial = build_solver_matrix(&l, TransformKind::LimitNegExp { ell: 51 }, &BuildOptions::default()).unwrap();
        let opts = BuildOptions { threads: 4, ..BuildOptions::default() };
        let par = build_solver_matrix(&l, TransformKind::LimitNegExp { ell: 51 }, &opts).unwrap();
        assert_eq!(serial.lambda_star.to_bits(), par.lambda_star.to_bits());
        assert!(serial
            .m
            .data()
            .iter()
            .zip(par.m.data().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn op_mode_parse_and_display() {
        assert_eq!(OpMode::parse("dense").unwrap(), OpMode::DenseMaterialized);
        assert_eq!(OpMode::parse("sparse").unwrap(), OpMode::MatrixFree);
        assert_eq!(OpMode::parse("matrix-free").unwrap(), OpMode::MatrixFree);
        assert!(OpMode::parse("bogus").is_err());
        assert_eq!(OpMode::default(), OpMode::DenseMaterialized);
        assert_eq!(OpMode::MatrixFree.to_string(), "sparse");
        assert!(TransformKind::Identity.supports_matrix_free());
        assert!(TransformKind::LimitNegExp { ell: 51 }.supports_matrix_free());
        assert!(!TransformKind::NegExp.supports_matrix_free());
        assert!(!TransformKind::MatrixLog { eps: 0.05 }.supports_matrix_free());
    }

    #[test]
    fn apply_bundle_matches_materialized_series() {
        // p(L)·V through sparse Horner-on-columns vs. the dense p(L) build
        // followed by a multiply — same polynomial, different association;
        // agreement to ~machine precision on a prescaled spectrum.
        let g = cliques(&CliqueSpec { n: 32, k: 4, max_short_circuit: 3, seed: 1 }).graph;
        let mut l = g.laplacian();
        let lam = crate::linalg::funcs::power_lambda_max(&l, 100).unwrap() * 1.01;
        l.scale(1.0 / lam);
        let mut lc = g.laplacian_csr();
        lc.scale_values(1.0 / lam);
        let mut rng = crate::util::rng::Rng::new(9);
        let v = DMat::from_fn(32, 5, |_, _| rng.normal());
        for kind in [
            TransformKind::TaylorNegExp { ell: 31 },
            TransformKind::TaylorLog { ell: 61, eps: 0.05 },
        ] {
            let series = kind.series().expect("series kind");
            let dense = crate::linalg::matmul::matmul(&series.eval_matrix(&l), &v);
            for threads in [1usize, 2, 8] {
                let sparse = series.apply_bundle(&lc, &v, threads);
                let err = (&sparse - &dense).max_abs();
                assert!(err < 1e-9, "{kind} @ {threads} threads: err {err}");
            }
            // Worker-count determinism is bitwise.
            let serial = series.apply_bundle(&lc, &v, 1);
            let par = series.apply_bundle(&lc, &v, 8);
            assert!(serial
                .data()
                .iter()
                .zip(par.data().iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()));
        }
        // Degenerate polynomials.
        let empty = SeriesForm { shift: 0.0, coeffs: vec![] };
        assert_eq!(empty.apply_bundle(&lc, &v, 4).max_abs(), 0.0);
        let constant = SeriesForm { shift: 0.3, coeffs: vec![2.5] };
        let cv = constant.apply_bundle(&lc, &v, 4);
        let mut want = v.clone();
        want.scale(2.5);
        assert!((&cv - &want).max_abs() == 0.0);
    }

    #[test]
    fn chebyshev_build_matches_monomial_and_rejects_exact() {
        // The dense build in the Chebyshev basis evaluates the same
        // polynomial as the monomial build — different association, ≤1e-9
        // agreement on a prescaled spectrum — including LimitNegExp, which
        // the monomial path must special-case through matpow.
        let l = test_laplacian();
        let mono_opts = BuildOptions { prescale: true, ..BuildOptions::default() };
        let cheb_opts = BuildOptions {
            prescale: true,
            basis: PolyBasis::Chebyshev,
            ..BuildOptions::default()
        };
        for kind in [
            TransformKind::Identity,
            TransformKind::TaylorNegExp { ell: 31 },
            TransformKind::TaylorLog { ell: 61, eps: 0.05 },
            TransformKind::LimitNegExp { ell: 51 },
        ] {
            let mono = build_solver_matrix(&l, kind, &mono_opts).unwrap();
            let cheb = build_solver_matrix(&l, kind, &cheb_opts).unwrap();
            assert_eq!(mono.lambda_star.to_bits(), cheb.lambda_star.to_bits(), "{kind}");
            let err = (&mono.m - &cheb.m).max_abs();
            assert!(err < 1e-9, "{kind}: basis divergence {err}");
        }
        // Exact (eigh-based) kinds have no polynomial form: clear error,
        // no silent fallback.
        for kind in [TransformKind::NegExp, TransformKind::MatrixLog { eps: 0.05 }] {
            let err = build_solver_matrix(&l, kind, &cheb_opts).unwrap_err();
            assert!(
                format!("{err:#}").contains("--basis monomial"),
                "{kind}: unhelpful error {err:#}"
            );
            assert!(build_solver_matrix(&l, kind, &mono_opts).is_ok());
        }
        // Default basis is monomial (the bitwise-compat path).
        assert_eq!(BuildOptions::default().basis, PolyBasis::Monomial);
    }

    #[test]
    fn cheb_series_matches_scalar_map_at_high_degree() {
        // The acceptance degrees: ℓ ∈ {15, 251} on [0, 1], every series
        // kind, ≤1e-9 against the truncated-series scalar map.
        for ell in [15usize, 251] {
            for kind in [
                TransformKind::TaylorNegExp { ell },
                TransformKind::TaylorLog { ell, eps: 0.05 },
                TransformKind::LimitNegExp { ell },
            ] {
                let cheb = kind.cheb_series(0.0, 1.0).expect("polynomial kind");
                assert_eq!(cheb.degree(), ell);
                for i in 0..=40 {
                    let x = i as f64 / 40.0;
                    let err = (cheb.eval_scalar(x) - kind.scalar_map(x)).abs();
                    assert!(err < 1e-9, "{kind} at x={x}: err {err}");
                }
            }
        }
        assert!(TransformKind::NegExp.cheb_series(0.0, 1.0).is_none());
        assert!(TransformKind::MatrixLog { eps: 0.05 }.cheb_series(0.0, 1.0).is_none());
        assert_eq!(TransformKind::Identity.cheb_series(0.0, 2.0).unwrap().degree(), 1);
    }

    #[test]
    fn lanczos_domain_build_matches_power_domain_at_full_degree() {
        // A full-degree interpolant is exact on any covering domain, so the
        // tight Lanczos interval must realize the same operator as the
        // loose power/Gershgorin one — different fit domains, same
        // polynomial. λ* is exactly 0 for the −e^{−x} family either way.
        let l = test_laplacian();
        let mk = |domain| BuildOptions {
            prescale: true,
            basis: PolyBasis::Chebyshev,
            domain,
            ..BuildOptions::default()
        };
        for kind in [
            TransformKind::TaylorNegExp { ell: 31 },
            TransformKind::LimitNegExp { ell: 51 },
        ] {
            let power = build_solver_matrix(&l, kind, &mk(DomainEstimate::Power)).unwrap();
            let lanczos = build_solver_matrix(&l, kind, &mk(DomainEstimate::Lanczos)).unwrap();
            let gersh = build_solver_matrix(&l, kind, &mk(DomainEstimate::Gershgorin)).unwrap();
            assert_eq!(power.lambda_star, 0.0, "{kind}");
            assert_eq!(lanczos.lambda_star, 0.0, "{kind}");
            let err = (&power.m - &lanczos.m).max_abs();
            assert!(err < 1e-9, "{kind}: power-vs-lanczos domain divergence {err}");
            let err = (&power.m - &gersh.m).max_abs();
            assert!(err < 1e-9, "{kind}: power-vs-gershgorin domain divergence {err}");
        }
    }

    #[test]
    fn degree_knob_shrinks_the_filter_and_rejects_monomial() {
        let l = test_laplacian();
        let kind = TransformKind::LimitNegExp { ell: 251 };
        // Reshaping degrees need the Chebyshev basis — clear error, no
        // silent fallback (matching the basis/exact-transform idiom).
        let bad = BuildOptions {
            degree: Degree::Auto { tol: 1e-9, max: usize::MAX },
            ..BuildOptions::default()
        };
        let err = build_solver_matrix(&l, kind, &bad).unwrap_err();
        assert!(format!("{err:#}").contains("--basis chebyshev"), "{err:#}");
        // A degree-0 filter (M a multiple of I) is rejected on both
        // operator paths, not silently built.
        let zero = BuildOptions {
            basis: PolyBasis::Chebyshev,
            degree: Degree::Fixed(0),
            ..BuildOptions::default()
        };
        let err = build_solver_matrix(&l, kind, &zero).unwrap_err();
        assert!(format!("{err:#}").contains("constant filter"), "{err:#}");
        let g = cliques(&CliqueSpec { n: 16, k: 2, max_short_circuit: 1, seed: 3 }).graph;
        let err = crate::solvers::SparsePolyOp::from_graph(&g, kind, &zero).unwrap_err();
        assert!(format!("{err:#}").contains("constant filter"), "{err:#}");
        // Auto degree on the tight domain realizes (nearly) the same
        // operator as the full-degree build.
        let full = build_solver_matrix(
            &l,
            kind,
            &BuildOptions { basis: PolyBasis::Chebyshev, ..BuildOptions::default() },
        )
        .unwrap();
        let auto = build_solver_matrix(
            &l,
            kind,
            &BuildOptions {
                basis: PolyBasis::Chebyshev,
                domain: DomainEstimate::Lanczos,
                degree: Degree::Auto { tol: 1e-9, max: usize::MAX },
                ..BuildOptions::default()
            },
        )
        .unwrap();
        let err = (&full.m - &auto.m).max_abs();
        assert!(err < 1e-6, "adaptive-degree operator divergence {err}");
        // Fixed(d) with d ≥ native is exact as well.
        let fixed = build_solver_matrix(
            &l,
            TransformKind::TaylorNegExp { ell: 31 },
            &BuildOptions {
                prescale: true,
                basis: PolyBasis::Chebyshev,
                degree: Degree::Fixed(40),
                ..BuildOptions::default()
            },
        )
        .unwrap();
        let full31 = build_solver_matrix(
            &l,
            TransformKind::TaylorNegExp { ell: 31 },
            &BuildOptions {
                prescale: true,
                basis: PolyBasis::Chebyshev,
                ..BuildOptions::default()
            },
        )
        .unwrap();
        assert!((&fixed.m - &full31.m).max_abs() < 1e-9);
    }

    #[test]
    fn mixed_precision_rejected_on_the_dense_build() {
        // The dense materialized path is f64-only: mixed precision is a
        // matrix-free knob, and like every other unsupported combination
        // it errors clearly instead of silently falling back.
        let l = test_laplacian();
        let opts = BuildOptions { precision: Precision::Mixed, ..BuildOptions::default() };
        let err =
            build_solver_matrix(&l, TransformKind::LimitNegExp { ell: 51 }, &opts).unwrap_err();
        assert!(format!("{err:#}").contains("--precision f64"), "{err:#}");
        assert_eq!(BuildOptions::default().precision, Precision::F64);
    }

    #[test]
    fn property_series_scalar_matrix_consistency() {
        use crate::testkit::{check, SizeGen};
        check(31, 6, &SizeGen { lo: 3, hi: 12 }, |&ell| {
            let ell = ell * 2 + 1; // odd
            let t = TransformKind::LimitNegExp { ell };
            let x = 0.37;
            let m = DMat::diag(&[x, 0.9, 0.0]);
            let fm = t.build(&m).unwrap();
            (fm[(0, 0)] - t.scalar_map(x)).abs() < 1e-9
        });
    }
}
