//! Polynomial bases for the series transforms: monomial (shifted-Horner)
//! vs Chebyshev (three-term recurrence).
//!
//! The paper's series transforms are polynomials in `L`; *which basis* the
//! coefficients live in decides how `p(L)·V` is evaluated and how well it
//! is conditioned:
//!
//! * **[`PolyBasis::Monomial`]** — `p(A) = Σ c_i (A − shift·I)^i`, applied
//!   by Horner ([`SeriesForm`]). Exact and fast at low degree, but the
//!   monomial basis is exponentially ill-conditioned as the degree grows:
//!   at ℓ = 251 some Table-2 transforms need coefficients like `ℓ^{−ℓ}`
//!   (underflows f64) or alternating terms with catastrophic cancellation.
//! * **[`PolyBasis::Chebyshev`]** — `p(x) = Σ c_j T_j(y)` with the domain
//!   `[lo, hi]` mapped to `y ∈ [−1, 1]` ([`ChebSeries`]). `|T_j(y)| ≤ 1`
//!   on the domain, so coefficients are bounded by the function's size and
//!   the three-term recurrence `T_{j+1}(A)V = 2Y·(T_j(A)V) − T_{j−1}(A)V`
//!   is numerically stable at any degree — this is the basis production
//!   spectral solvers (Chebyshev–Davidson, filtered LOBPCG) run their
//!   polynomial filters in.
//!
//! Both bases evaluate at scalars, dense matrices, and matrix-free CSR
//! bundles ([`PolySeries`] dispatches); the matrix-free Chebyshev path
//! drives each recurrence step through the fused solver-step kernel
//! [`crate::linalg::sparse::spmm_step_into`] — one pass over the bundle
//! instead of the three (SpMM + scale + axpy) of the unfused composition.
//!
//! Coefficient conversions between the bases ([`monomial_to_chebyshev`] /
//! [`chebyshev_to_monomial`]) are exact algebra (dyadic-rational basis
//! matrices) and round-trip exactly at the low degrees where the monomial
//! basis is usable at all; production Chebyshev coefficients come from
//! [`ChebSeries::fit`] (interpolation at Chebyshev nodes — stable at any
//! degree, and *exact* for polynomials of degree ≤ the fit degree, which
//! every series transform is).

use super::SeriesForm;
use crate::linalg::dmat::DMat;
use crate::linalg::shard::StepOperand;
use crate::linalg::sparse::CsrMat;
use anyhow::{bail, Result};

/// Which polynomial basis a series' coefficients are expressed in
/// (`--basis monomial|chebyshev`, `BuildOptions::basis`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PolyBasis {
    /// Shifted-power coefficients evaluated by Horner ([`SeriesForm`]).
    /// The historical default; bitwise-identical to the pre-basis-knob
    /// evaluation path.
    #[default]
    Monomial,
    /// Chebyshev coefficients on a `[lo, hi]` domain evaluated by the
    /// three-term recurrence ([`ChebSeries`]). Stable at high degree.
    Chebyshev,
}

impl PolyBasis {
    /// Parse from a CLI/config name (`monomial` | `chebyshev`).
    pub fn parse(s: &str) -> Result<PolyBasis> {
        Ok(match s {
            "monomial" | "mono" | "horner" => PolyBasis::Monomial,
            "chebyshev" | "cheb" => PolyBasis::Chebyshev,
            other => bail!("unknown polynomial basis {other:?} (expected monomial | chebyshev)"),
        })
    }

    /// Canonical display name.
    pub fn name(&self) -> &'static str {
        match self {
            PolyBasis::Monomial => "monomial",
            PolyBasis::Chebyshev => "chebyshev",
        }
    }
}

impl std::fmt::Display for PolyBasis {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Chebyshev→monomial coefficient conversion **in the mapped variable**:
/// given `c` with `p(y) = Σ_j c[j]·T_j(y)`, returns `m` with
/// `p(y) = Σ_i m[i]·yⁱ`. Exact algebra via the `T_{j+1} = 2y·T_j − T_{j−1}`
/// recurrence on coefficient vectors (the basis matrix is integer, so the
/// conversion is exact in f64 whenever the products don't round — in
/// particular for the low degrees where a monomial target is usable).
pub fn chebyshev_to_monomial(cheb: &[f64]) -> Vec<f64> {
    let n = cheb.len();
    let mut out = vec![0.0; n];
    if n == 0 {
        return out;
    }
    let mut t_prev = vec![0.0; n]; // T_0 = 1
    t_prev[0] = 1.0;
    out[0] += cheb[0];
    if n == 1 {
        return out;
    }
    let mut t_cur = vec![0.0; n]; // T_1 = y
    t_cur[1] = 1.0;
    out[1] += cheb[1];
    for &c in cheb.iter().skip(2) {
        // T_next = 2y·T_cur − T_prev (coefficient shift-and-scale).
        let mut t_next = vec![0.0; n];
        for i in 0..n - 1 {
            t_next[i + 1] = 2.0 * t_cur[i];
        }
        for (tn, &tp) in t_next.iter_mut().zip(t_prev.iter()) {
            *tn -= tp;
        }
        if c != 0.0 {
            for (o, &t) in out.iter_mut().zip(t_next.iter()) {
                *o += c * t;
            }
        }
        t_prev = t_cur;
        t_cur = t_next;
    }
    out
}

/// Monomial→Chebyshev coefficient conversion **in the mapped variable**:
/// the inverse of [`chebyshev_to_monomial`]. Uses
/// `y·T_j = (T_{j+1} + T_{j−1})/2` (with `T_{−1} = T_1`) to build the
/// Chebyshev expansion of each power `yⁱ`; all basis entries are dyadic
/// rationals, so the conversion is exact under the same conditions.
pub fn monomial_to_chebyshev(mono: &[f64]) -> Vec<f64> {
    let n = mono.len();
    let mut out = vec![0.0; n];
    if n == 0 {
        return out;
    }
    // Chebyshev coefficients of y⁰ = T_0.
    let mut pw = vec![0.0; n];
    pw[0] = 1.0;
    for (i, &m) in mono.iter().enumerate() {
        if m != 0.0 {
            for (o, &p) in out.iter_mut().zip(pw.iter()) {
                *o += m * p;
            }
        }
        if i + 1 < n {
            // pw ← Chebyshev coefficients of y^{i+1}.
            let mut next = vec![0.0; n];
            for (j, &a) in pw.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                if j == 0 {
                    next[1] += a;
                } else {
                    if j + 1 < n {
                        next[j + 1] += 0.5 * a;
                    }
                    next[j - 1] += 0.5 * a;
                }
            }
            pw = next;
        }
    }
    out
}

/// Affine substitution on monomial coefficients: given `p(y) = Σ p[i]·yⁱ`,
/// returns the coefficients of `q(x) = p(a·x + b)` (Horner on coefficient
/// vectors, `O(d²)`). Exact when the scale/shift products don't round.
pub fn affine_compose(p: &[f64], a: f64, b: f64) -> Vec<f64> {
    let n = p.len();
    if n == 0 {
        return vec![];
    }
    let mut q = vec![0.0; n];
    q[0] = p[n - 1];
    let mut len = 1usize;
    for &c in p.iter().rev().skip(1) {
        // q ← q·(a·x + b) + c, done high-to-low so q can grow in place.
        for i in (0..len).rev() {
            let v = q[i];
            q[i + 1] += a * v;
            q[i] = b * v;
        }
        q[0] += c;
        len += 1;
    }
    q
}

/// The Chebyshev fit domain for a PSD spectrum, given the λ_max power-
/// iteration estimate `rho` and a *guaranteed* upper bound `bound`
/// (Gershgorin): `[0, max(rho, bound)]`. The guaranteed bound matters —
/// any eigenvalue past the domain edge maps to `|y| > 1`, where `T_ℓ(y)`
/// grows like `cosh(ℓ·acosh y)` and the recurrence diverges, while a
/// wider domain is free for these transforms (the interpolant of a
/// degree-ℓ polynomial is exact on any domain). A zero spectrum
/// (edgeless graph) falls back to `[0, 1]`, where any domain evaluates
/// `f(0)`. This is the single domain policy shared by the dense build
/// (`build_solver_matrix`), the matrix-free operator (`SparsePolyOp`),
/// and the `poly-basis` bench — they must agree or the dense and sparse
/// Chebyshev paths would evaluate different coefficient sets.
pub fn cheb_domain(rho: f64, bound: f64) -> (f64, f64) {
    let hi = rho.max(bound);
    (0.0, if hi > 0.0 { hi } else { 1.0 })
}

/// A polynomial in Chebyshev form on an explicit domain:
/// `p(x) = Σ_j coeffs[j]·T_j(y)` with `y = (2x − (hi + lo)) / (hi − lo)`
/// mapping `[lo, hi]` onto `[−1, 1]`.
///
/// For spectral filters the domain is `[0, λ̂_max]` of the (possibly
/// pre-scaled) Laplacian — the existing power-iteration estimate, safety
/// padded so the true spectrum stays inside the well-conditioned region.
#[derive(Clone, Debug, PartialEq)]
pub struct ChebSeries {
    /// Domain lower edge (0 for PSD Laplacians).
    pub lo: f64,
    /// Domain upper edge (the λ_max estimate).
    pub hi: f64,
    /// Chebyshev coefficients `c_j` of `Σ_j c_j·T_j(y(x))`.
    pub coeffs: Vec<f64>,
}

impl ChebSeries {
    /// The affine domain map `y = a·x + b`. Hard-asserts the domain is
    /// non-degenerate: the fields are public, and a hand-built series
    /// with `hi ≤ lo` would otherwise yield silent inf/NaN evaluations
    /// in release builds (the constructors validate the same condition).
    #[inline]
    fn affine(&self) -> (f64, f64) {
        assert!(
            self.hi > self.lo,
            "degenerate Chebyshev domain [{}, {}]",
            self.lo,
            self.hi
        );
        let a = 2.0 / (self.hi - self.lo);
        (a, -(self.hi + self.lo) / (self.hi - self.lo))
    }

    /// Fit a degree-`degree` Chebyshev expansion of `f` on `[lo, hi]` by
    /// interpolation at the `degree + 1` Chebyshev nodes (discrete cosine
    /// projection, `O(d²)`). For `f` a polynomial of degree ≤ `degree` —
    /// every series transform — the interpolant *is* `f`, to rounding;
    /// this is the numerically stable route to Chebyshev coefficients
    /// (never through the ill-conditioned monomial basis).
    pub fn fit(degree: usize, lo: f64, hi: f64, f: impl Fn(f64) -> f64) -> ChebSeries {
        assert!(
            lo.is_finite() && hi.is_finite() && hi > lo,
            "ChebSeries::fit needs a finite non-degenerate domain (got [{lo}, {hi}])"
        );
        let n = degree + 1;
        let center = 0.5 * (hi + lo);
        let half = 0.5 * (hi - lo);
        let fx: Vec<f64> = (0..n)
            .map(|k| {
                let theta = std::f64::consts::PI * (k as f64 + 0.5) / n as f64;
                f(center + half * theta.cos())
            })
            .collect();
        let mut coeffs = vec![0.0; n];
        for (j, c) in coeffs.iter_mut().enumerate() {
            let mut s = 0.0;
            for (k, &fv) in fx.iter().enumerate() {
                s += fv
                    * (std::f64::consts::PI * j as f64 * (k as f64 + 0.5) / n as f64).cos();
            }
            *c = s * if j == 0 { 1.0 } else { 2.0 } / n as f64;
        }
        ChebSeries { lo, hi, coeffs }
    }

    /// Exact algebraic basis change from the shifted-monomial form (for
    /// the conversion/round-trip contracts; production fitting should use
    /// [`Self::fit`] — this path inherits the monomial form's conditioning).
    pub fn from_series_form(s: &SeriesForm, lo: f64, hi: f64) -> ChebSeries {
        assert!(lo.is_finite() && hi.is_finite() && hi > lo, "degenerate Chebyshev domain");
        let a = 2.0 / (hi - lo);
        let center = 0.5 * (hi + lo);
        // b_var = x − shift and y = a·(x − center) ⇒ b_var = y/a + (center − shift).
        let in_y = affine_compose(&s.coeffs, 1.0 / a, center - s.shift);
        ChebSeries { lo, hi, coeffs: monomial_to_chebyshev(&in_y) }
    }

    /// Exact algebraic basis change to the shifted-monomial form. Only
    /// well-conditioned at low degree — the monomial basis itself is the
    /// limitation, not the conversion.
    pub fn to_series_form(&self) -> SeriesForm {
        let (a, _) = self.affine();
        let center = 0.5 * (self.hi + self.lo);
        // y = a·(x − center) ⇒ p(x) = Σ m_j·aʲ·(x − center)ʲ.
        let mono_y = chebyshev_to_monomial(&self.coeffs);
        let coeffs = mono_y
            .iter()
            .enumerate()
            .map(|(j, &m)| m * a.powi(j as i32))
            .collect();
        SeriesForm { shift: center, coeffs }
    }

    /// Plain (shift-free) monomial coefficients `q` with
    /// `p(x) = Σ q[i]·xⁱ` — the form the walk estimator consumes
    /// (`StochasticPolyOp`). Same low-degree conditioning caveat as
    /// [`Self::to_series_form`].
    pub fn to_plain_monomial(&self) -> Vec<f64> {
        let (a, b) = self.affine();
        affine_compose(&chebyshev_to_monomial(&self.coeffs), a, b)
    }

    /// Evaluate at a scalar (Clenshaw recurrence).
    pub fn eval_scalar(&self, x: f64) -> f64 {
        if self.coeffs.is_empty() {
            return 0.0;
        }
        let (a, b) = self.affine();
        let y = a * x + b;
        let mut bk1 = 0.0;
        let mut bk2 = 0.0;
        for &c in self.coeffs.iter().skip(1).rev() {
            let bk = 2.0 * y * bk1 - bk2 + c;
            bk2 = bk1;
            bk1 = bk;
        }
        self.coeffs[0] + y * bk1 - bk2
    }

    /// Evaluate at a dense matrix (serial).
    pub fn eval_matrix(&self, m: &DMat) -> DMat {
        self.eval_matrix_threads(m, 1)
    }

    /// Evaluate at a dense matrix via the forward three-term recurrence,
    /// each multiply row-sharded across `threads` workers. Bitwise
    /// identical for every worker count (`linalg::par` contract).
    pub fn eval_matrix_threads(&self, m: &DMat, threads: usize) -> DMat {
        assert!(m.is_square(), "ChebSeries::eval_matrix needs a square matrix");
        let n = m.rows();
        let mut out = DMat::zeros(n, n);
        if self.coeffs.is_empty() {
            return out;
        }
        let (a, b) = self.affine();
        for i in 0..n {
            out[(i, i)] = self.coeffs[0];
        }
        if self.coeffs.len() == 1 {
            return out;
        }
        // Y = a·M + b·I, the domain-mapped operator.
        let mut y = m.clone();
        y.scale(a);
        y.add_diag(b);
        let threads = crate::linalg::par::effective_threads(
            n.saturating_mul(n).saturating_mul(n),
            threads,
        );
        let mut t_prev = DMat::eye(n);
        let mut t_cur = y.clone();
        out.axpy(self.coeffs[1], &t_cur);
        for &c in self.coeffs.iter().skip(2) {
            // T_next = 2·Y·T_cur − T_prev.
            let mut t_next = crate::linalg::par::matmul_par(&y, &t_cur, threads);
            t_next.scale(2.0);
            t_next.axpy(-1.0, &t_prev);
            if c != 0.0 {
                out.axpy(c, &t_next);
            }
            t_prev = t_cur;
            t_cur = t_next;
        }
        out
    }

    /// Matrix-free bundle apply `p(A)·V` for sparse `A` via the three-term
    /// recurrence, each step one fused
    /// [`crate::linalg::sparse::spmm_step_into`] pass:
    /// `T_{j+1}V = 2a·(A·T_jV) + 2b·T_jV − T_{j−1}V`. `deg(p)` SpMM-sized
    /// passes, three preallocated bundles, no `n×n` intermediate. Stable
    /// at the ℓ ≈ 251 degrees where shifted-Horner loses digits. Output is
    /// bitwise identical for every worker count.
    pub fn apply_bundle(&self, l: &CsrMat, v: &DMat, threads: usize) -> DMat {
        assert!(l.is_square(), "apply_bundle needs a square operator");
        self.apply_bundle_via(&StepOperand::Csr(l), v, threads)
    }

    /// [`Self::apply_bundle`] generalized over the stepping operand: the
    /// same three-term recurrence runs against either the plain CSR fused
    /// kernel or a [`crate::linalg::shard::ShardedCsr`] two-phase apply
    /// (one halo exchange per sweep). Bitwise-identical across operands
    /// and worker counts.
    pub fn apply_bundle_via(&self, op: &StepOperand<'_>, v: &DMat, threads: usize) -> DMat {
        assert_eq!(op.rows(), v.rows(), "apply_bundle shape mismatch");
        let (n, k) = (v.rows(), v.cols());
        let mut out = DMat::zeros(n, k);
        if self.coeffs.is_empty() {
            return out;
        }
        let (a, b) = self.affine();
        out.axpy(self.coeffs[0], v); // c_0·T_0·V = c_0·V
        if self.coeffs.len() == 1 {
            return out;
        }
        // T_1·V = Y·V = a·(A·V) + b·V — one fused pass.
        let mut t_prev = v.clone();
        let mut t_cur = DMat::zeros(n, k);
        op.step_into(v, v, b, a, 0.0, &mut t_cur, threads);
        out.axpy(self.coeffs[1], &t_cur);
        let mut t_next = DMat::zeros(n, k);
        for &c in self.coeffs.iter().skip(2) {
            // T_{j+1}V = 2a·(A·T_jV) + 2b·T_jV − T_{j−1}V — one fused pass.
            op.step_into(&t_cur, &t_prev, 2.0 * b, 2.0 * a, -1.0, &mut t_next, threads);
            if c != 0.0 {
                out.axpy(c, &t_next);
            }
            // Rotate: prev ← cur, cur ← next, next ← scratch (old prev).
            std::mem::swap(&mut t_prev, &mut t_cur);
            std::mem::swap(&mut t_cur, &mut t_next);
        }
        out
    }

    pub fn degree(&self) -> usize {
        self.coeffs.len().saturating_sub(1)
    }

    /// Adaptive-degree truncation: drop every trailing coefficient whose
    /// magnitude is below `tol` relative to the largest coefficient. Each
    /// dropped coefficient is one SpMM sweep the matrix-free apply never
    /// takes, and because `|T_j(y)| ≤ 1` on the domain the on-domain error
    /// introduced is bounded by the dropped tail mass `Σ |c_j|` — this is
    /// the textbook near-minimax compression of a Chebyshev expansion, and
    /// the engine behind `Degree::Auto` (`--degree auto`).
    ///
    /// The payoff scales with the fit domain: coefficients decay at a rate
    /// set by the domain half-width (the reason the tight
    /// `--domain lanczos` interval and adaptive truncation compound).
    /// Interior coefficients are never touched (dropping those is not
    /// error-bounded); at least the constant term is always kept.
    pub fn truncated(&self, tol: f64) -> ChebSeries {
        assert!(tol >= 0.0, "truncation tolerance must be non-negative");
        let cmax = self.coeffs.iter().fold(0.0f64, |m, c| m.max(c.abs()));
        if self.coeffs.is_empty() || cmax == 0.0 {
            return self.clone();
        }
        let cut = tol * cmax;
        let keep = self
            .coeffs
            .iter()
            .rposition(|c| c.abs() > cut)
            .map_or(1, |i| i + 1);
        ChebSeries { lo: self.lo, hi: self.hi, coeffs: self.coeffs[..keep].to_vec() }
    }
}

/// A series transform's polynomial in either basis — the basis-generic
/// object [`crate::solvers::SparsePolyOp`] evaluates through.
#[derive(Clone, Debug, PartialEq)]
pub enum PolySeries {
    /// Shifted-monomial coefficients, Horner evaluation.
    Monomial(SeriesForm),
    /// Chebyshev coefficients on `[lo, hi]`, recurrence evaluation.
    Chebyshev(ChebSeries),
}

impl PolySeries {
    pub fn basis(&self) -> PolyBasis {
        match self {
            PolySeries::Monomial(_) => PolyBasis::Monomial,
            PolySeries::Chebyshev(_) => PolyBasis::Chebyshev,
        }
    }

    pub fn degree(&self) -> usize {
        match self {
            PolySeries::Monomial(s) => s.degree(),
            PolySeries::Chebyshev(c) => c.degree(),
        }
    }

    /// Evaluate at a scalar.
    pub fn eval_scalar(&self, x: f64) -> f64 {
        match self {
            PolySeries::Monomial(s) => s.eval_scalar(x),
            PolySeries::Chebyshev(c) => c.eval_scalar(x),
        }
    }

    /// Evaluate at a dense matrix, row-sharded across `threads` workers.
    pub fn eval_matrix_threads(&self, m: &DMat, threads: usize) -> DMat {
        match self {
            PolySeries::Monomial(s) => s.eval_matrix_threads(m, threads),
            PolySeries::Chebyshev(c) => c.eval_matrix_threads(m, threads),
        }
    }

    /// Matrix-free bundle apply `p(A)·V` (both bases run their recurrence
    /// steps through the fused `spmm_step_into` kernel).
    pub fn apply_bundle(&self, a: &CsrMat, v: &DMat, threads: usize) -> DMat {
        match self {
            PolySeries::Monomial(s) => s.apply_bundle(a, v, threads),
            PolySeries::Chebyshev(c) => c.apply_bundle(a, v, threads),
        }
    }

    /// [`Self::apply_bundle`] over an arbitrary stepping operand (plain
    /// CSR or sharded) — dispatches to the per-basis `apply_bundle_via`.
    pub fn apply_bundle_via(&self, op: &StepOperand<'_>, v: &DMat, threads: usize) -> DMat {
        match self {
            PolySeries::Monomial(s) => s.apply_bundle_via(op, v, threads),
            PolySeries::Chebyshev(c) => c.apply_bundle_via(op, v, threads),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn basis_parse_and_display() {
        assert_eq!(PolyBasis::parse("monomial").unwrap(), PolyBasis::Monomial);
        assert_eq!(PolyBasis::parse("horner").unwrap(), PolyBasis::Monomial);
        assert_eq!(PolyBasis::parse("chebyshev").unwrap(), PolyBasis::Chebyshev);
        assert_eq!(PolyBasis::parse("cheb").unwrap(), PolyBasis::Chebyshev);
        assert!(PolyBasis::parse("legendre").is_err());
        assert_eq!(PolyBasis::default(), PolyBasis::Monomial);
        assert_eq!(PolyBasis::Chebyshev.to_string(), "chebyshev");
    }

    #[test]
    fn cheb_domain_policy() {
        // Estimate below the guaranteed bound → widen; above → keep.
        assert_eq!(cheb_domain(1.0, 2.5), (0.0, 2.5));
        assert_eq!(cheb_domain(3.0, 2.5), (0.0, 3.0));
        // Zero spectrum (edgeless graph) → the [0, 1] fallback.
        assert_eq!(cheb_domain(0.0, 0.0), (0.0, 1.0));
    }

    #[test]
    fn conversion_reproduces_chebyshev_polynomials() {
        // T_4(y) = 8y⁴ − 8y² + 1: the j-th unit Chebyshev vector must map
        // to the textbook monomial coefficients, exactly.
        let m = chebyshev_to_monomial(&[0.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(m, vec![1.0, 0.0, -8.0, 0.0, 8.0]);
        // And back: y⁴ = (3·T_0 + 4·T_2 + T_4)/8.
        let c = monomial_to_chebyshev(&[0.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(c, vec![0.375, 0.0, 0.5, 0.0, 0.125]);
    }

    #[test]
    fn conversion_roundtrip_exact_low_degrees() {
        // Dyadic coefficients: the round-trip is *exact* (bit-for-bit) for
        // every degree 0..=8, both directions.
        for d in 0..=8usize {
            let mono: Vec<f64> = (0..=d).map(|i| ((i as f64) - 3.0) * 0.5).collect();
            let back = chebyshev_to_monomial(&monomial_to_chebyshev(&mono));
            assert_eq!(back.len(), mono.len());
            for (a, b) in mono.iter().zip(back.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "degree {d} monomial round-trip");
            }
            let cheb: Vec<f64> = (0..=d).map(|i| 1.0 - (i as f64) * 0.25).collect();
            let back = monomial_to_chebyshev(&chebyshev_to_monomial(&cheb));
            for (a, b) in cheb.iter().zip(back.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "degree {d} chebyshev round-trip");
            }
        }
    }

    #[test]
    fn affine_compose_is_substitution() {
        // p(y) = 1 + 2y + 3y², y = 2x − 1 ⇒ q(x) = 2 − 8x + 12x².
        let q = affine_compose(&[1.0, 2.0, 3.0], 2.0, -1.0);
        assert_eq!(q, vec![2.0, -8.0, 12.0]);
        assert!(affine_compose(&[], 2.0, 1.0).is_empty());
        // Identity map round-trips exactly.
        let p = vec![0.5, -1.25, 2.0, 0.75];
        assert_eq!(affine_compose(&p, 1.0, 0.0), p);
    }

    #[test]
    fn fit_reproduces_polynomials_and_clenshaw_matches() {
        // Fitting a cubic at degree 3 recovers it exactly (to rounding),
        // on an asymmetric domain.
        let f = |x: f64| 2.0 - x + 0.5 * x * x * x;
        let cheb = ChebSeries::fit(3, -0.5, 3.0, f);
        for i in 0..=20 {
            let x = -0.5 + 3.5 * i as f64 / 20.0;
            assert!((cheb.eval_scalar(x) - f(x)).abs() < 1e-12, "x={x}");
        }
        // Round-trip through the monomial form agrees everywhere.
        let sf = cheb.to_series_form();
        let back = ChebSeries::from_series_form(&sf, -0.5, 3.0);
        for (a, b) in cheb.coeffs.iter().zip(back.coeffs.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
        // Plain-monomial export evaluates identically.
        let plain = cheb.to_plain_monomial();
        let horner = |x: f64| plain.iter().rev().fold(0.0, |acc, &c| acc * x + c);
        for i in 0..=10 {
            let x = -0.5 + 3.5 * i as f64 / 10.0;
            assert!((horner(x) - f(x)).abs() < 1e-11, "x={x}");
        }
    }

    #[test]
    fn fit_is_stable_at_degree_251() {
        // The motivating case: −(1 − x/ℓ)^ℓ at ℓ = 251 has no usable
        // monomial form (the leading coefficient ℓ^{−ℓ} underflows f64),
        // but its Chebyshev fit reproduces the scalar map to near machine
        // precision across the domain.
        let ell = 251usize;
        let f = |x: f64| crate::transforms::limit_negexp_scalar(x, ell);
        let cheb = ChebSeries::fit(ell, 0.0, 1.0, f);
        for i in 0..=50 {
            let x = i as f64 / 50.0;
            assert!((cheb.eval_scalar(x) - f(x)).abs() < 1e-12, "x={x}");
        }
        // Coefficients are bounded by the function size — no underflow or
        // blowup anywhere in the representation.
        assert!(cheb.coeffs.iter().all(|c| c.is_finite() && c.abs() <= 2.0));
    }

    #[test]
    fn matrix_and_bundle_eval_agree_with_scalar_on_diagonals() {
        // On a diagonal matrix every evaluation route must reproduce the
        // scalar map entry-wise.
        let xs = [0.0, 0.2, 0.55, 0.9, 1.0];
        let f = |x: f64| -(-x).exp();
        let cheb = ChebSeries::fit(16, 0.0, 1.0, f);
        let d = DMat::diag(&xs);
        let dense = cheb.eval_matrix(&d);
        let trips: Vec<(usize, usize, f64)> =
            xs.iter().enumerate().map(|(i, &x)| (i, i, x)).collect();
        let csr = CsrMat::from_triplets(xs.len(), xs.len(), &trips);
        let v = DMat::eye(xs.len());
        let sparse = cheb.apply_bundle(&csr, &v, 1);
        for (i, &x) in xs.iter().enumerate() {
            assert!((dense[(i, i)] - cheb.eval_scalar(x)).abs() < 1e-12);
            assert!((sparse[(i, i)] - cheb.eval_scalar(x)).abs() < 1e-12);
            assert!((cheb.eval_scalar(x) - f(x)).abs() < 1e-10, "fit error at {x}");
        }
        // Dense recurrence is worker-invariant, bitwise.
        let serial = cheb.eval_matrix_threads(&d, 1);
        for threads in [2usize, 8] {
            let par = cheb.eval_matrix_threads(&d, threads);
            assert!(serial
                .data()
                .iter()
                .zip(par.data().iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn bundle_apply_worker_invariant_and_degenerate_shapes() {
        let mut rng = Rng::new(17);
        let trips: Vec<(usize, usize, f64)> = {
            let mut t = vec![];
            for i in 0..20usize {
                t.push((i, i, rng.normal().abs() + 0.2));
                for j in (i + 1)..20 {
                    if rng.uniform(0.0, 1.0) < 0.2 {
                        let w = rng.normal() * 0.1;
                        t.push((i, j, w));
                        t.push((j, i, w));
                    }
                }
            }
            t
        };
        let a = CsrMat::from_triplets(20, 20, &trips);
        let hi = a.gershgorin_bound().max(1.0);
        let cheb = ChebSeries::fit(31, 0.0, hi, |x| x * x - 0.5 * x);
        let v = DMat::from_fn(20, 5, |_, _| rng.normal());
        let serial = cheb.apply_bundle(&a, &v, 1);
        for threads in [2usize, 8] {
            let par = cheb.apply_bundle(&a, &v, threads);
            assert!(serial
                .data()
                .iter()
                .zip(par.data().iter())
                .all(|(x, y)| x.to_bits() == y.to_bits()));
        }
        // Empty and constant polynomials.
        let empty = ChebSeries { lo: 0.0, hi: 1.0, coeffs: vec![] };
        assert_eq!(empty.apply_bundle(&a, &v, 4).max_abs(), 0.0);
        assert_eq!(empty.eval_scalar(0.3), 0.0);
        let constant = ChebSeries { lo: 0.0, hi: 1.0, coeffs: vec![2.5] };
        let cv = constant.apply_bundle(&a, &v, 4);
        let mut want = v.clone();
        want.scale(2.5);
        assert_eq!((&cv - &want).max_abs(), 0.0);
    }

    #[test]
    fn truncation_is_error_bounded_and_tail_only() {
        // e^{-x} on [0, 1]: fast-decaying tail, truncation keeps accuracy.
        let f = |x: f64| (-x).exp();
        let cheb = ChebSeries::fit(40, 0.0, 1.0, f);
        let t = cheb.truncated(1e-10);
        assert!(t.degree() < cheb.degree(), "tail should truncate");
        assert_eq!((t.lo, t.hi), (cheb.lo, cheb.hi));
        // Error bounded by the dropped tail mass.
        let tail: f64 = cheb.coeffs[t.coeffs.len()..].iter().map(|c| c.abs()).sum();
        for i in 0..=50 {
            let x = i as f64 / 50.0;
            let err = (t.eval_scalar(x) - cheb.eval_scalar(x)).abs();
            assert!(err <= tail + 1e-15, "x={x}: err {err} vs tail {tail}");
        }
        // Kept coefficients are untouched (prefix, bit for bit).
        for (a, b) in t.coeffs.iter().zip(cheb.coeffs.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // tol = 0 drops only exact-zero trailing coefficients.
        let padded = ChebSeries { lo: 0.0, hi: 1.0, coeffs: vec![1.0, 0.5, 0.0, 0.0] };
        assert_eq!(padded.truncated(0.0).coeffs, vec![1.0, 0.5]);
        // Degenerate inputs survive.
        let zero = ChebSeries { lo: 0.0, hi: 1.0, coeffs: vec![0.0, 0.0] };
        assert_eq!(zero.truncated(1e-9).coeffs.len(), 2);
        let empty = ChebSeries { lo: 0.0, hi: 1.0, coeffs: vec![] };
        assert!(empty.truncated(1e-9).coeffs.is_empty());
        // Everything below tolerance keeps at least the constant term.
        let tiny = ChebSeries { lo: 0.0, hi: 1.0, coeffs: vec![1.0, 1e-12, 1e-13] };
        assert_eq!(tiny.truncated(1e-6).coeffs, vec![1.0]);
    }

    #[test]
    fn poly_series_dispatch() {
        let sf = SeriesForm { shift: 0.0, coeffs: vec![1.0, 2.0] };
        let cf = ChebSeries::fit(1, 0.0, 1.0, |x| 1.0 + 2.0 * x);
        let pm = PolySeries::Monomial(sf);
        let pc = PolySeries::Chebyshev(cf);
        assert_eq!(pm.basis(), PolyBasis::Monomial);
        assert_eq!(pc.basis(), PolyBasis::Chebyshev);
        assert_eq!(pm.degree(), 1);
        assert_eq!(pc.degree(), 1);
        for x in [0.0, 0.25, 1.0] {
            assert!((pm.eval_scalar(x) - pc.eval_scalar(x)).abs() < 1e-12);
        }
    }
}
