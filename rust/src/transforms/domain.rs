//! Spectral-domain and filter-degree policy for the series transforms —
//! the one place the "what interval do we fit on, and how many SpMM sweeps
//! do we spend" decisions live.
//!
//! Before this module existed, `build_solver_matrix` (dense) and
//! `SparsePolyOp::from_csr` (matrix-free) each hand-rolled the same
//! ρ-vs-Gershgorin fallback around [`cheb_domain`]; both now dispatch
//! through [`DomainEstimate`], so the dense and sparse Chebyshev paths fit
//! the *same* coefficient set by construction.
//!
//! ## Domain policies ([`DomainEstimate`], CLI `--domain`)
//!
//! * **[`DomainEstimate::Power`]** (default) — the historical policy,
//!   bitwise-identical to the pre-knob builds: the power-iteration λ_max
//!   estimate (safety-padded) as ρ, widened to the guaranteed Gershgorin
//!   radius for the Chebyshev domain `[0, max(ρ, Gershgorin)]`. Safe by
//!   construction (the domain always covers a PSD spectrum) but **loose**:
//!   Gershgorin overshoots λ_max by ~2× on typical community graphs, and
//!   the lower edge is pinned at 0.
//! * **[`DomainEstimate::Lanczos`]** — tight two-sided Ritz bounds from an
//!   m-step Lanczos run ([`crate::linalg::lanczos`]), padded by a margin
//!   **scaled with the Ritz residual** (a slowly-converging, near-degenerate
//!   spectrum widens the padding instead of silently under-covering — the
//!   convergence check the bare 100-iteration power estimate never had) and
//!   clipped to the guaranteed two-sided Gershgorin interval. The tight
//!   interval is what makes adaptive truncation bite: Chebyshev coefficient
//!   tails decay at a rate set by the domain half-width.
//! * **[`DomainEstimate::Gershgorin`]** — the guaranteed two-sided interval
//!   alone, no iteration at all. The conservative fallback (what the other
//!   two degrade toward), useful when even `O(m·nnz)` estimation is
//!   unwanted.
//!
//! ## Degree policies ([`Degree`], CLI `--degree` / `--cheb-tol`)
//!
//! * **[`Degree::Native`]** (default) — honor the transform's own series
//!   degree ℓ exactly (the paper's protocol; bitwise-identical historical
//!   behavior).
//! * **[`Degree::Fixed`]`(d)`** — fit the Chebyshev interpolant of the
//!   transform's scalar map at exactly degree `d` (`d < ℓ` is a principled
//!   near-minimax compression of the filter; `d ≥ ℓ` is exact).
//! * **[`Degree::Auto`]`{ tol, max }`** — fit at the native degree, then
//!   drop the trailing coefficients below `tol` relative to the largest
//!   ([`ChebSeries::truncated`]) and cap at `max`: every dropped
//!   coefficient is one SpMM sweep the operator application never takes,
//!   at an on-domain error bounded by the dropped tail mass.
//!
//! Both non-default degree policies reshape the evaluated polynomial, so
//! they require `--basis chebyshev` (in the monomial basis the shifted
//! Horner coefficients are not ordered by magnitude — truncation there is
//! meaningless) and are rejected with a clear error otherwise.

use crate::linalg::dmat::DMat;
use crate::linalg::lanczos;
use crate::linalg::sparse::CsrMat;
use anyhow::{bail, Result};

use super::basis::{cheb_domain, ChebSeries, PolyBasis};

/// How the spectral interval (and with it ρ for the eq-8 reversal shift
/// λ*) of the transform input is estimated. See the module docs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DomainEstimate {
    /// Power-iteration λ_max widened to the Gershgorin radius — the
    /// bitwise-compatible historical policy.
    #[default]
    Power,
    /// Two-sided Lanczos Ritz bounds, residual-scaled padding, clipped to
    /// the guaranteed Gershgorin interval.
    Lanczos,
    /// The guaranteed two-sided Gershgorin interval, no iteration.
    Gershgorin,
}

/// Padding multiplier on the Lanczos residual bound: each extreme Ritz
/// value is guaranteed an eigenvalue within one residual, so a few
/// residuals of margin cover the estimate error with room to spare.
const LANCZOS_RESIDUAL_PAD: f64 = 3.0;

/// Minimum padding as a fraction of the estimated interval width — the
/// two-sided counterpart of the 1% `safety` idiom the power estimate uses.
const LANCZOS_MIN_PAD_FRAC: f64 = 0.01;

impl DomainEstimate {
    /// Parse from a CLI/config name (`power` | `lanczos` | `gershgorin`).
    pub fn parse(s: &str) -> Result<DomainEstimate> {
        Ok(match s {
            "power" => DomainEstimate::Power,
            "lanczos" | "ritz" => DomainEstimate::Lanczos,
            "gershgorin" | "gersh" => DomainEstimate::Gershgorin,
            other => {
                bail!("unknown domain estimate {other:?} (expected power | lanczos | gershgorin)")
            }
        })
    }

    /// Canonical display name.
    pub fn name(&self) -> &'static str {
        match self {
            DomainEstimate::Power => "power",
            DomainEstimate::Lanczos => "lanczos",
            DomainEstimate::Gershgorin => "gershgorin",
        }
    }

    /// Estimate on a dense symmetric matrix (the `build_solver_matrix`
    /// path). `rho_hint > 0` is the caller's trusted λ_max-style estimate
    /// of the input — the safety-padded power estimate, or exactly 1.0
    /// after pre-scaling — consumed verbatim by [`DomainEstimate::Power`]
    /// for bitwise compatibility.
    pub fn estimate_dense(
        &self,
        l: &DMat,
        rho_hint: f64,
        threads: usize,
    ) -> Result<SpectrumEstimate> {
        // The radius is eager (the Power arm's widening consumes it, and
        // it is one sweep next to the caller's 100-iteration power
        // estimate); the two-sided interval is computed only by the arms
        // that use it.
        let radius = crate::linalg::funcs::gershgorin_bound(l);
        self.estimate_with(
            rho_hint,
            || crate::linalg::funcs::gershgorin_interval(l),
            radius,
            || lanczos::lanczos_bounds(l, lanczos::DEFAULT_STEPS, threads),
        )
    }

    /// Estimate on a CSR matrix (the `SparsePolyOp` path) — `O(nnz)`-only,
    /// nothing dense. Bitwise identical to [`Self::estimate_dense`] on the
    /// densified matrix.
    pub fn estimate_csr(
        &self,
        l: &CsrMat,
        rho_hint: f64,
        threads: usize,
    ) -> Result<SpectrumEstimate> {
        let radius = l.gershgorin_bound();
        self.estimate_with(
            rho_hint,
            || l.gershgorin_interval(),
            radius,
            || lanczos::lanczos_bounds_csr(l, lanczos::DEFAULT_STEPS, threads),
        )
    }

    /// The one policy body both wrappers dispatch (dense/CSR differ only in
    /// how the Gershgorin terms and the Lanczos run are computed).
    fn estimate_with(
        &self,
        rho_hint: f64,
        gersh_interval: impl FnOnce() -> (f64, f64),
        gersh_radius: f64,
        run_lanczos: impl FnOnce() -> Result<lanczos::LanczosBounds>,
    ) -> Result<SpectrumEstimate> {
        Ok(match self {
            DomainEstimate::Power => {
                // The historical policy, value-for-value: ρ is the caller's
                // estimate when positive (else the guaranteed radius), and
                // the domain is ρ widened to the radius.
                let rho = if rho_hint > 0.0 { rho_hint } else { gersh_radius };
                let (lo, hi) = cheb_domain(rho, gersh_radius);
                SpectrumEstimate { rho, lo, hi, residual: 0.0 }
            }
            DomainEstimate::Gershgorin => {
                let (g_lo, g_hi) = gersh_interval();
                let (lo, hi) = safe_interval(g_lo, g_hi, gersh_radius);
                SpectrumEstimate { rho: hi, lo, hi, residual: 0.0 }
            }
            DomainEstimate::Lanczos => {
                let gersh = gersh_interval();
                let b = run_lanczos()?;
                let width = b.hi - b.lo;
                // Residual-scaled safety padding — the under-coverage fix:
                // an unconverged run (large residual) widens the domain
                // instead of silently trusting a bad estimate.
                let pad = (b.residual * LANCZOS_RESIDUAL_PAD).max(width * LANCZOS_MIN_PAD_FRAC);
                // Clip to the *guaranteed* interval: padding can never push
                // the domain past bounds no eigenvalue can cross.
                let (lo, hi) = safe_interval(
                    (b.lo - pad).max(gersh.0),
                    (b.hi + pad).min(gersh.1),
                    gersh_radius,
                );
                SpectrumEstimate { rho: hi, lo, hi, residual: b.residual }
            }
        })
    }
}

impl std::fmt::Display for DomainEstimate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Degenerate-interval guard shared by the two-sided policies: a zero or
/// inverted interval (edgeless graph, zero matrix) falls back to the same
/// `[0, max(radius, 1)]` shape as [`cheb_domain`], on which any fit simply
/// evaluates `f` near 0.
fn safe_interval(lo: f64, hi: f64, radius: f64) -> (f64, f64) {
    if hi > lo {
        (lo, hi)
    } else {
        (0.0, if radius > 0.0 { radius } else { 1.0 })
    }
}

/// What a [`DomainEstimate`] produced: the Chebyshev fit domain, the ρ
/// upper estimate feeding the eq-8 reversal shift λ*, and the estimator's
/// convergence diagnostic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpectrumEstimate {
    /// Upper estimate of the input's spectral radius (feeds
    /// `TransformKind::lambda_star`).
    pub rho: f64,
    /// Chebyshev fit domain, lower edge.
    pub lo: f64,
    /// Chebyshev fit domain, upper edge.
    pub hi: f64,
    /// Estimator residual diagnostic: the Lanczos Ritz residual bound the
    /// padding was scaled by; `0` for the guaranteed-cover policies
    /// (Power's Gershgorin-widened domain, Gershgorin itself).
    pub residual: f64,
}

impl SpectrumEstimate {
    /// Interval width — the quantity the adaptive-degree payoff scales
    /// with (Chebyshev tails decay at a rate set by the half-width).
    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }
}

/// How many Chebyshev terms — i.e. SpMM sweeps per operator application —
/// the fitted filter keeps. See the module docs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum Degree {
    /// The transform's own series degree ℓ (historical behavior).
    #[default]
    Native,
    /// Fit the interpolant at exactly this degree.
    Fixed(usize),
    /// Fit at the native degree, then truncate the coefficient tail below
    /// `tol` (relative to the largest coefficient) and cap at `max`.
    Auto {
        /// Relative coefficient tolerance (`--cheb-tol`).
        tol: f64,
        /// Hard cap on the kept degree (`usize::MAX` = uncapped).
        max: usize,
    },
}

impl Degree {
    /// Parse from a CLI/config value: `native` | `auto` | `auto:<max>` | a
    /// literal degree. `tol` seeds [`Degree::Auto`]'s tolerance (the
    /// `--cheb-tol` flag); `auto:<max>` additionally caps the kept degree
    /// ("truncate by tolerance, but never spend more than `max` sweeps").
    pub fn parse(s: &str, tol: f64) -> Result<Degree> {
        if s == "native" || s == "full" {
            return Ok(Degree::Native);
        }
        if s == "auto" || s == "adaptive" || s.starts_with("auto:") {
            if !(tol > 0.0 && tol < 1.0) {
                bail!("--degree auto needs 0 < --cheb-tol < 1 (got {tol})");
            }
            let max = match s.strip_prefix("auto:") {
                None => usize::MAX,
                Some(m) => match m.parse::<usize>() {
                    Ok(0) | Err(_) => {
                        bail!("bad degree cap in {s:?} (expected auto:<max> with max ≥ 1)")
                    }
                    Ok(d) => d,
                },
            };
            return Ok(Degree::Auto { tol, max });
        }
        match s.parse::<usize>() {
            Ok(0) => bail!(
                "--degree 0 would build a constant filter (M a multiple of I, \
                 every vector an eigenvector) — use native | auto | N ≥ 1"
            ),
            Ok(d) => Ok(Degree::Fixed(d)),
            Err(_) => bail!("unknown degree {s:?} (expected native | auto[:max] | <N>)"),
        }
    }

    /// Canonical display name — always a string [`Self::parse`] accepts
    /// back (the CLI summary line prints it, and users copy it into config
    /// files), so `Fixed(d)` is the bare degree and `Auto` is `auto` (its
    /// tolerance travels separately as `--cheb-tol` / `pipeline.cheb_tol`).
    pub fn name(&self) -> String {
        match *self {
            Degree::Native => "native".into(),
            Degree::Fixed(d) => d.to_string(),
            Degree::Auto { max: usize::MAX, .. } => "auto".into(),
            Degree::Auto { max, .. } => format!("auto:{max}"),
        }
    }

    /// The degree the Chebyshev interpolant is *fitted* at, given the
    /// transform's native degree. [`Degree::Auto`] fits at the native
    /// degree (truncation happens afterwards on the fitted coefficients —
    /// dropping a converged tail, not aliasing the fit).
    pub fn fit_degree(&self, native: usize) -> usize {
        match *self {
            Degree::Native | Degree::Auto { .. } => native,
            Degree::Fixed(d) => d,
        }
    }

    /// Reject non-native policies outside the Chebyshev basis — the one
    /// place this rule lives; both operator builders call it before doing
    /// any work.
    pub fn validate_basis(&self, basis: PolyBasis) -> Result<()> {
        if !self.is_native() && basis != PolyBasis::Chebyshev {
            bail!(
                "--degree {} reshapes the evaluated polynomial, which is only \
                 error-bounded in the Chebyshev basis — combine it with --basis chebyshev",
                self
            );
        }
        Ok(())
    }

    /// [`Self::fit_degree`] with the degree-0 guard — the one place the
    /// constant-filter rule lives.
    pub fn checked_fit_degree(&self, native: usize) -> Result<usize> {
        let fit = self.fit_degree(native);
        if fit == 0 {
            bail!(
                "degree 0 builds a constant filter (M a multiple of I, every \
                 vector an eigenvector) — use a degree ≥ 1"
            );
        }
        Ok(fit)
    }

    /// Post-fit shaping: [`Degree::Auto`] drops the sub-tolerance tail and
    /// applies the cap; the other policies pass the series through. The
    /// shaped series always keeps degree ≥ 1 (when the fit has one): a
    /// degree-0 filter would make `M = λ*I − c₀I` a multiple of the
    /// identity — every vector an eigenvector, a silently-garbage solve —
    /// so a coarse tolerance or cap floors at the linear term instead.
    pub fn shape(&self, cheb: ChebSeries) -> ChebSeries {
        match *self {
            Degree::Native | Degree::Fixed(_) => cheb,
            Degree::Auto { tol, max } => {
                let floor = cheb.coeffs.len().min(2);
                let mut t = cheb.truncated(tol);
                if t.degree() > max {
                    t.coeffs.truncate((max + 1).max(floor));
                }
                if t.coeffs.len() < floor {
                    t.coeffs = cheb.coeffs[..floor].to_vec();
                }
                t
            }
        }
    }

    /// True for the policies that reshape the evaluated polynomial —
    /// meaningful only in the Chebyshev basis, rejected elsewhere.
    pub fn is_native(&self) -> bool {
        matches!(self, Degree::Native)
    }
}

impl std::fmt::Display for Degree {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Arithmetic precision of the matrix-free bundle sweeps (CLI
/// `--precision`).
///
/// * **[`Precision::F64`]** (default) — the historical kernels, bitwise
///   identical across worker counts and to every reference path.
/// * **[`Precision::Mixed`]** — f32 storage (CSR values and bundle
///   panels) with f64 accumulation
///   ([`crate::linalg::sparse::spmm_step_mixed_into`]). Skinny SpMM is
///   memory-bandwidth-bound, so halving the bytes is close to doubling
///   throughput; the price is one f32 rounding per element per sweep,
///   bounded by [`mixed_error_budget`]. Only the inexact iterative stages
///   may take it: exact (eigh-based) transforms, dense-materialized
///   operators, and the ground-truth metric oracle are rejected — their
///   contracts are exactness, not a budget.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Full f64 storage and arithmetic (bitwise-contract default).
    #[default]
    F64,
    /// f32 storage, f64 accumulation, for the iterative sweeps only.
    Mixed,
}

impl Precision {
    /// Parse from a CLI/config name (`f64` | `mixed`).
    pub fn parse(s: &str) -> Result<Precision> {
        Ok(match s {
            "f64" | "double" => Precision::F64,
            "mixed" | "f32" => Precision::Mixed,
            other => bail!("unknown precision {other:?} (expected f64 | mixed)"),
        })
    }

    /// Canonical display name.
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::Mixed => "mixed",
        }
    }

    pub fn is_mixed(&self) -> bool {
        matches!(self, Precision::Mixed)
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// The documented f32 term of the mixed-precision error budget: an
/// absolute bound (in spectrum-map units) on how far a mixed-precision
/// operator application can drift from the f64 one, per unit bundle norm.
///
/// Derivation: the mixed kernels round operands to f32 once up front and
/// round each panel element to f32 once per sweep; products and the
/// α/β/γ combine run in f64 (an f32 × f32 product is exact in f64), so
/// each of the `sweeps` recurrence steps contributes at most a relative
/// `f32::EPSILON` perturbation to a quantity bounded by the filter's
/// size. For a Chebyshev series `Σ c_j T_j` on its fit domain
/// `|p| ≤ Σ|c_j| = coeff_l1` (and the NegPower product is bounded by 1,
/// its `coeff_l1`), giving
///
/// ```text
/// budget = (sweeps + 1) · coeff_l1 · 8 · f32::EPSILON
/// ```
///
/// with the `+1` covering the initial demotion of the inputs and the 8 a
/// deliberate slack factor for the accumulated worst case. The total
/// `--degree auto --precision mixed` map-error contract is then
/// `cheb-tol budget + this budget` — pinned by the operator-level
/// contract test and the `spmm-simd` bench group's `map_err_mixed`.
pub fn mixed_error_budget(sweeps: usize, coeff_l1: f64) -> f64 {
    (sweeps as f64 + 1.0) * coeff_l1.max(1.0) * 8.0 * f32::EPSILON as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, CliqueSpec};

    #[test]
    fn parse_and_display() {
        assert_eq!(DomainEstimate::parse("power").unwrap(), DomainEstimate::Power);
        assert_eq!(DomainEstimate::parse("lanczos").unwrap(), DomainEstimate::Lanczos);
        assert_eq!(DomainEstimate::parse("gershgorin").unwrap(), DomainEstimate::Gershgorin);
        assert!(DomainEstimate::parse("magic").is_err());
        assert_eq!(DomainEstimate::default(), DomainEstimate::Power);
        assert_eq!(DomainEstimate::Lanczos.to_string(), "lanczos");

        assert_eq!(Degree::parse("native", 1e-9).unwrap(), Degree::Native);
        assert_eq!(
            Degree::parse("auto", 1e-9).unwrap(),
            Degree::Auto { tol: 1e-9, max: usize::MAX }
        );
        assert_eq!(Degree::parse("31", 1e-9).unwrap(), Degree::Fixed(31));
        assert_eq!(
            Degree::parse("auto:64", 1e-9).unwrap(),
            Degree::Auto { tol: 1e-9, max: 64 }
        );
        assert!(Degree::parse("auto", 0.0).is_err(), "auto needs a usable tol");
        assert!(Degree::parse("auto:0", 1e-9).is_err(), "zero cap rejected");
        assert!(Degree::parse("auto:lots", 1e-9).is_err());
        assert!(Degree::parse("sideways", 1e-9).is_err());
        // Degree 0 is a constant filter — rejected at parse time with the
        // reason in the error, never a silently-garbage solve.
        let err = Degree::parse("0", 1e-9).unwrap_err();
        assert!(format!("{err:#}").contains("constant filter"), "{err:#}");
        assert_eq!(Degree::default(), Degree::Native);
        assert!(Degree::Fixed(7).to_string().contains('7'));
        assert!(!Degree::Fixed(7).is_native());
        // Display round-trips through parse: the summary line the CLI
        // prints is valid as a config/CLI value.
        for d in [
            Degree::Native,
            Degree::Fixed(31),
            Degree::Auto { tol: 1e-9, max: usize::MAX },
            Degree::Auto { tol: 1e-9, max: 64 },
        ] {
            assert_eq!(Degree::parse(&d.to_string(), 1e-9).unwrap(), d);
        }
        for d in [DomainEstimate::Power, DomainEstimate::Lanczos, DomainEstimate::Gershgorin] {
            assert_eq!(DomainEstimate::parse(d.name()).unwrap(), d);
        }
    }

    #[test]
    fn precision_parse_display_and_budget() {
        assert_eq!(Precision::parse("f64").unwrap(), Precision::F64);
        assert_eq!(Precision::parse("mixed").unwrap(), Precision::Mixed);
        assert_eq!(Precision::parse("f32").unwrap(), Precision::Mixed);
        assert!(Precision::parse("f16").is_err());
        assert_eq!(Precision::default(), Precision::F64);
        assert!(!Precision::F64.is_mixed() && Precision::Mixed.is_mixed());
        for p in [Precision::F64, Precision::Mixed] {
            assert_eq!(Precision::parse(p.name()).unwrap(), p);
            assert_eq!(p.to_string(), p.name());
        }
        // Budget grows with sweeps and filter mass, floors at coeff_l1 = 1,
        // and sits far above f64 noise but far below any useful tolerance's
        // complement.
        let b = mixed_error_budget(51, 2.0);
        assert!(b > mixed_error_budget(15, 2.0));
        assert!(b > mixed_error_budget(51, 0.5) - 1e-18);
        assert_eq!(mixed_error_budget(51, 0.5), mixed_error_budget(51, 1.0));
        assert!(b > 1e-12 && b < 1e-3, "budget {b}");
    }

    #[test]
    fn power_policy_reproduces_the_historical_fallback_bitwise() {
        // The exact value flow `build_solver_matrix`/`SparsePolyOp` used to
        // hand-roll: ρ_hint when positive else the Gershgorin radius, and
        // cheb_domain(ρ, radius) for the fit interval.
        let g = cliques(&CliqueSpec { n: 30, k: 3, max_short_circuit: 2, seed: 5 }).graph;
        let lc = g.laplacian_csr();
        let radius = lc.gershgorin_bound();
        for rho_hint in [7.5f64, 0.0, -1.0] {
            let est = DomainEstimate::Power.estimate_csr(&lc, rho_hint, 1).unwrap();
            let rho_old = if rho_hint > 0.0 { rho_hint } else { radius };
            let (lo_old, hi_old) = cheb_domain(rho_old, radius);
            assert_eq!(est.rho.to_bits(), rho_old.to_bits());
            assert_eq!(est.lo.to_bits(), lo_old.to_bits());
            assert_eq!(est.hi.to_bits(), hi_old.to_bits());
            assert_eq!(est.residual, 0.0);
        }
        // Dense and CSR agree bitwise.
        let ed = DomainEstimate::Power.estimate_dense(&g.laplacian(), 7.5, 1).unwrap();
        let ec = DomainEstimate::Power.estimate_csr(&lc, 7.5, 1).unwrap();
        assert_eq!(ed.hi.to_bits(), ec.hi.to_bits());
    }

    #[test]
    fn lanczos_policy_is_tight_covering_and_clipped() {
        let g = cliques(&CliqueSpec { n: 64, k: 4, max_short_circuit: 2, seed: 9 }).graph;
        let lc = g.laplacian_csr();
        let e = crate::linalg::eigh(&g.laplacian()).unwrap();
        let est = DomainEstimate::Lanczos.estimate_csr(&lc, 0.0, 1).unwrap();
        let (glo, ghi) = lc.gershgorin_interval();
        // Covers the true spectrum (the padded-bracket contract)…
        assert!(est.lo <= e.values[0] + 1e-9, "lo {} vs λ_min {}", est.lo, e.values[0]);
        assert!(est.hi >= e.lambda_max() - 1e-9, "hi {} vs λ_max {}", est.hi, e.lambda_max());
        // …within the guaranteed interval…
        assert!(est.lo >= glo - 1e-12 && est.hi <= ghi + 1e-12);
        // …and meaningfully tighter than the one-sided Gershgorin domain.
        let loose = DomainEstimate::Power.estimate_csr(&lc, 0.0, 1).unwrap();
        assert!(
            est.width() < 0.8 * loose.width(),
            "lanczos width {} vs power width {}",
            est.width(),
            loose.width()
        );
        assert_eq!(est.rho, est.hi);
    }

    #[test]
    fn degenerate_spectra_fall_back_safely() {
        // Edgeless graph: zero Laplacian, zero Gershgorin — every policy
        // lands on the same [0, 1] fallback domain as cheb_domain.
        let zero = crate::linalg::sparse::CsrMat::from_triplets(
            4,
            4,
            &[(0, 0, 0.0), (1, 1, 0.0), (2, 2, 0.0), (3, 3, 0.0)],
        );
        for policy in [DomainEstimate::Power, DomainEstimate::Lanczos, DomainEstimate::Gershgorin] {
            let est = policy.estimate_csr(&zero, 0.0, 2).unwrap();
            assert_eq!((est.lo, est.hi), (0.0, 1.0), "{policy}");
        }
    }

    #[test]
    fn auto_degree_shapes_and_caps() {
        let f = |x: f64| (-x).exp();
        let cheb = ChebSeries::fit(60, 0.0, 1.0, f);
        let auto = Degree::Auto { tol: 1e-9, max: usize::MAX };
        let shaped = auto.shape(cheb.clone());
        assert!(shaped.degree() < 60, "e^{{-x}} tail should truncate well below 60");
        for i in 0..=40 {
            let x = i as f64 / 40.0;
            assert!((shaped.eval_scalar(x) - f(x)).abs() < 1e-7, "x={x}");
        }
        let capped = Degree::Auto { tol: 1e-9, max: 4 }.shape(cheb.clone());
        assert_eq!(capped.degree(), 4);
        // The degree-≥1 floor: a coarse tolerance (or a zero cap) keeps the
        // linear term instead of collapsing to a constant filter, and the
        // kept prefix is the fitted one, bit for bit.
        let floored = Degree::Auto { tol: 0.9, max: usize::MAX }.shape(cheb.clone());
        assert_eq!(floored.degree(), 1);
        for (a, b) in floored.coeffs.iter().zip(cheb.coeffs.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(Degree::Auto { tol: 1e-9, max: 0 }.shape(cheb.clone()).degree(), 1);
        // Native / Fixed pass the fitted series through untouched.
        assert_eq!(Degree::Native.shape(cheb.clone()), cheb);
        assert_eq!(Degree::Fixed(60).fit_degree(251), 60);
        assert_eq!(Degree::Native.fit_degree(251), 251);
        assert_eq!(auto.fit_degree(251), 251);
    }
}
