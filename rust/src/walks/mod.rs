//! The stochastic heart of SPED (§4.3): unbiased estimation of Laplacian
//! powers `L^ℓ` — and of whole polynomials `Σ_i γ_i L^i` — from random walks
//! on the **edge-incidence graph**.
//!
//! Eq 12 of the paper rewrites `L^ℓ = Σ_{c ∈ E^ℓ} α_c · x_{e₁} x_{e_ℓ}ᵀ`,
//! where `α_c = Π_j x_{e_j}ᵀ x_{e_{j+1}}` is non-zero only when consecutive
//! edges share an endpoint — i.e. only *walks in the edge-incidence graph*
//! contribute, with per-step factors given by Table 1 (±1, +2).
//!
//! Two estimators are provided:
//!
//! * [`SampleMethod::Rejection`] — the paper's scheme (eqs 13–14): walks are
//!   sampled naturally (uniform start edge, uniform incident-edge steps) and
//!   accepted with probability `p_min/p_walk`, making every chain equally
//!   likely to be sampled-and-accepted (probability exactly `p_min` per
//!   trial); a trial contributes `α_c x_{e₁} x_{e_ℓ}ᵀ / p_min` when accepted
//!   and 0 otherwise — unbiased.
//! * [`SampleMethod::Importance`] — the variance-reduction alternative the
//!   paper lists as future work: no rejection, each walk contributes
//!   `α_c x_{e₁} x_{e_ℓ}ᵀ / p_walk`. Same expectation, no wasted samples.
//!
//! **Sub-walk harvesting** (linearity of expectation, §4.3): every prefix of
//! a length-ℓ walk is a valid walk of its own length, so one walk yields
//! simultaneous unbiased estimates of *all* `L^i, i ≤ ℓ` — and hence of any
//! polynomial `Σ γ_i L^i` — correlated across powers but still unbiased.
//!
//! Convention note: the paper's eq 13 writes `p_ℓ = (1/|E|) Π_{i=1}^{ℓ}
//! 1/deg(e_i)`; we index *transitions*, so a walk visiting `ℓ` edge-nodes
//! makes `ℓ−1` uniform neighbor choices and `p = (1/|E|) Π_{i=1}^{ℓ−1}
//! 1/deg(e_i)`. `p_min` (eq 14) uses the matching exponent; acceptance
//! ratios and unbiasedness are unchanged.

use crate::graph::incidence::{incidence_degree_bound, EdgeIncidenceGraph};
use crate::graph::Graph;
use crate::linalg::DMat;
use crate::util::pool::parallel_fold;
use crate::util::rng::Rng;
use crate::util::stats::OnlineStats;

/// How walk trials are converted into unbiased contributions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SampleMethod {
    /// Paper's rejection scheme (eqs 13–14).
    Rejection,
    /// Importance-weighted (future-work variance reduction).
    Importance,
}

impl SampleMethod {
    pub fn parse(s: &str) -> anyhow::Result<SampleMethod> {
        match s {
            "rejection" => Ok(SampleMethod::Rejection),
            "importance" => Ok(SampleMethod::Importance),
            other => anyhow::bail!("unknown sample method {other:?}"),
        }
    }
}

/// One sampled walk in the edge-incidence graph, with per-prefix
/// chain-weight and probability bookkeeping for sub-walk harvesting.
#[derive(Clone, Debug)]
pub struct WalkSample {
    /// Visited edge ids `e₁ … e_ℓ`.
    pub edges: Vec<u32>,
    /// `alpha[j]` = chain weight `α` of the length-`j+1` prefix
    /// (`alpha[0] = 1`).
    pub alpha: Vec<f64>,
    /// `prob[j]` = sampling probability of the length-`j+1` prefix.
    pub prob: Vec<f64>,
}

/// Walk engine bound to one graph: owns the edge-incidence CSR.
pub struct WalkEngine<'g> {
    graph: &'g Graph,
    inc: EdgeIncidenceGraph,
    deg_star_inc: usize,
}

impl<'g> WalkEngine<'g> {
    pub fn new(graph: &'g Graph) -> Self {
        let inc = EdgeIncidenceGraph::build(graph);
        let deg_star_inc = incidence_degree_bound(graph.max_degree());
        WalkEngine { graph, inc, deg_star_inc }
    }

    pub fn graph(&self) -> &Graph {
        self.graph
    }
    pub fn incidence(&self) -> &EdgeIncidenceGraph {
        &self.inc
    }

    /// Minimum probability of any walk visiting `len` edge-nodes (eq 14,
    /// transition-count convention).
    pub fn p_min(&self, len: usize) -> f64 {
        let m = self.graph.num_edges() as f64;
        (1.0 / m) * (self.deg_star_inc as f64).powi(-(len as i32 - 1))
    }

    /// Sample one walk visiting `len` edge-nodes into a reusable buffer.
    pub fn sample_walk_into(&self, len: usize, rng: &mut Rng, out: &mut WalkSample) {
        assert!(len >= 1);
        let m = self.graph.num_edges();
        assert!(m > 0, "cannot walk an edgeless graph");
        out.edges.clear();
        out.alpha.clear();
        out.prob.clear();
        let start = rng.below(m) as u32;
        out.edges.push(start);
        out.alpha.push(1.0);
        out.prob.push(1.0 / m as f64);
        let all_edges = self.graph.edges();
        for _ in 1..len {
            let cur = *out.edges.last().unwrap() as usize;
            let nbrs = self.inc.neighbors(cur);
            let next = *rng.choose(nbrs);
            let ip = crate::graph::incidence::inner_product(
                all_edges[cur],
                all_edges[next as usize],
            );
            out.edges.push(next);
            out.alpha.push(out.alpha.last().unwrap() * ip);
            out.prob.push(out.prob.last().unwrap() / nbrs.len() as f64);
        }
    }

    /// Sample one walk (allocating convenience wrapper).
    pub fn sample_walk(&self, len: usize, rng: &mut Rng) -> WalkSample {
        let mut w = WalkSample { edges: vec![], alpha: vec![], prob: vec![] };
        self.sample_walk_into(len, rng, &mut w);
        w
    }

    /// One prefix's unbiased sparse contribution to `L^{prefix_len}`:
    /// `Some((e_first, e_last, weight))` means add
    /// `weight · x_{e_first} x_{e_last}ᵀ`; `None` means a rejected trial
    /// (rejection method only; contributes zero).
    pub fn prefix_contribution(
        &self,
        walk: &WalkSample,
        prefix_len: usize,
        method: SampleMethod,
        rng: &mut Rng,
    ) -> Option<(u32, u32, f64)> {
        let j = prefix_len - 1;
        let a = walk.alpha[j];
        match method {
            SampleMethod::Importance => Some((
                walk.edges[0],
                walk.edges[j],
                if a == 0.0 { 0.0 } else { a / walk.prob[j] },
            )),
            SampleMethod::Rejection => {
                let p_min = self.p_min(prefix_len);
                let accept_p = p_min / walk.prob[j];
                debug_assert!(accept_p <= 1.0 + 1e-12, "p_min exceeded a walk probability");
                if rng.bernoulli(accept_p) {
                    Some((walk.edges[0], walk.edges[j], a / p_min))
                } else {
                    None
                }
            }
        }
    }
}

/// Add `weight · x_a x_bᵀ` (±1 incidence vectors) into a dense accumulator.
#[inline]
fn add_outer(acc: &mut DMat, g: &Graph, ea: u32, eb: u32, weight: f64) {
    if weight == 0.0 {
        return;
    }
    let a = g.edges()[ea as usize];
    let b = g.edges()[eb as usize];
    let (ai, aj) = (a.u as usize, a.v as usize);
    let (bi, bj) = (b.u as usize, b.v as usize);
    acc[(ai, bi)] += weight;
    acc[(ai, bj)] -= weight;
    acc[(aj, bi)] -= weight;
    acc[(aj, bj)] += weight;
}

/// Estimator statistics.
#[derive(Clone, Debug, Default)]
pub struct EstimatorStats {
    pub trials: u64,
    pub accepted: u64,
    /// Online stats over nonzero contribution weights (variance proxy).
    pub weight_stats: OnlineStats,
}

impl EstimatorStats {
    pub fn acceptance_rate(&self) -> f64 {
        if self.trials == 0 {
            0.0
        } else {
            self.accepted as f64 / self.trials as f64
        }
    }

    pub fn merge(self, o: EstimatorStats) -> EstimatorStats {
        EstimatorStats {
            trials: self.trials + o.trials,
            accepted: self.accepted + o.accepted,
            weight_stats: self.weight_stats.merge(o.weight_stats),
        }
    }
}

/// Unbiased estimate of `L^len` from `num_walks` trials split across
/// `workers` parallel walkers (each walker owns one engine + RNG stream —
/// the paper's "d graph walkers"). Returns `(estimate, stats)`.
pub fn estimate_l_power(
    g: &Graph,
    len: usize,
    num_walks: usize,
    workers: usize,
    method: SampleMethod,
    seed: u64,
) -> (DMat, EstimatorStats) {
    let n = g.num_nodes();
    let workers = workers.max(1);
    let chunk = num_walks.div_ceil(workers);
    let (mut acc, stats, done) = parallel_fold(
        workers,
        workers,
        || (DMat::zeros(n, n), EstimatorStats::default(), 0usize),
        |(acc, stats, done), widx| {
            let engine = WalkEngine::new(g);
            let mut rng = Rng::new(seed ^ (widx as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
            let todo = chunk.min(num_walks - (widx * chunk).min(num_walks));
            let mut walk = WalkSample { edges: vec![], alpha: vec![], prob: vec![] };
            for _ in 0..todo {
                engine.sample_walk_into(len, &mut rng, &mut walk);
                stats.trials += 1;
                if let Some((ea, eb, w)) =
                    engine.prefix_contribution(&walk, len, method, &mut rng)
                {
                    stats.accepted += 1;
                    if w != 0.0 {
                        stats.weight_stats.push(w);
                    }
                    add_outer(acc, g, ea, eb, w);
                }
            }
            *done += todo;
        },
        |(mut a1, s1, d1), (a2, s2, d2)| {
            a1.axpy(1.0, &a2);
            (a1, s1.merge(s2), d1 + d2)
        },
    );
    debug_assert_eq!(done, num_walks);
    acc.scale(1.0 / num_walks as f64);
    (acc, stats)
}

/// A reusable estimator owning its engine — the hot-path object used by the
/// coordinator's walker pool and the stochastic solver oracle.
pub struct WalkEstimator<'g> {
    pub engine: WalkEngine<'g>,
    pub method: SampleMethod,
}

impl<'g> WalkEstimator<'g> {
    pub fn new(g: &'g Graph, method: SampleMethod) -> Self {
        WalkEstimator { engine: WalkEngine::new(g), method }
    }

    /// Accumulate `batch` trials of `L^len` mass into `acc` (caller divides
    /// by total trials). Returns `(trials, accepted)`.
    pub fn accumulate_power(
        &self,
        len: usize,
        batch: usize,
        acc: &mut DMat,
        rng: &mut Rng,
    ) -> (u64, u64) {
        let g = self.engine.graph;
        let mut accepted = 0;
        let mut walk = WalkSample { edges: vec![], alpha: vec![], prob: vec![] };
        for _ in 0..batch {
            self.engine.sample_walk_into(len, rng, &mut walk);
            if let Some((ea, eb, w)) =
                self.engine.prefix_contribution(&walk, len, self.method, rng)
            {
                accepted += 1;
                add_outer(acc, g, ea, eb, w);
            }
        }
        (batch as u64, accepted)
    }

    /// Unbiased estimate of `p(L)·V` for `p(x) = Σ_i coeffs[i] xⁱ` applied
    /// to an `n×k` matrix `V`, from `num_walks` walks of length `deg(p)`,
    /// with sub-walk harvesting (one walk feeds every power). The constant
    /// term `coeffs[0]·V` is added exactly.
    ///
    /// Never materializes an `n×n` matrix: each prefix contributes
    /// `w · x_{e₁}(x_{e_j}ᵀ V)` — two row reads and two row updates of the
    /// output. This is the native twin of the L1 `stoch_apply` Pallas
    /// kernel.
    pub fn estimate_poly_apply(
        &self,
        coeffs: &[f64],
        v: &DMat,
        num_walks: usize,
        rng: &mut Rng,
    ) -> DMat {
        let g = self.engine.graph;
        let k = v.cols();
        let mut out = DMat::zeros(v.rows(), k);
        let maxdeg = coeffs.len().saturating_sub(1);
        if maxdeg > 0 && num_walks > 0 {
            let inv_walks = 1.0 / num_walks as f64;
            let mut walk = WalkSample { edges: vec![], alpha: vec![], prob: vec![] };
            let mut row_buf = vec![0.0f64; k];
            for _ in 0..num_walks {
                self.engine.sample_walk_into(maxdeg, rng, &mut walk);
                for (i, &c) in coeffs.iter().enumerate().skip(1) {
                    if c == 0.0 {
                        continue;
                    }
                    if let Some((ea, eb, w)) =
                        self.engine.prefix_contribution(&walk, i, self.method, rng)
                    {
                        if w == 0.0 {
                            continue;
                        }
                        let scale = c * w * inv_walks;
                        let b = g.edges()[eb as usize];
                        for (t, rb) in row_buf.iter_mut().enumerate() {
                            *rb = v[(b.u as usize, t)] - v[(b.v as usize, t)];
                        }
                        let a = g.edges()[ea as usize];
                        for (t, rb) in row_buf.iter().enumerate() {
                            let val = scale * rb;
                            out[(a.u as usize, t)] += val;
                            out[(a.v as usize, t)] -= val;
                        }
                    }
                }
            }
        }
        if !coeffs.is_empty() && coeffs[0] != 0.0 {
            out.axpy(coeffs[0], v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, ring, CliqueSpec};
    use crate::linalg::funcs::matpow;
    use crate::linalg::matmul::matmul;

    fn small_graph() -> Graph {
        // Two triangles joined by one edge: 6 nodes, 7 edges.
        Graph::from_pairs(6, &[(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5), (2, 3)]).unwrap()
    }

    #[test]
    fn walk_probabilities_are_consistent() {
        let g = small_graph();
        let engine = WalkEngine::new(&g);
        let mut rng = Rng::new(1);
        for len in 1..=4 {
            let p_min = engine.p_min(len);
            for _ in 0..200 {
                let w = engine.sample_walk(len, &mut rng);
                assert_eq!(w.edges.len(), len);
                assert!(w.prob[len - 1] >= p_min - 1e-15, "p_min not a lower bound");
                for j in 1..len {
                    assert!(w.prob[j] <= w.prob[j - 1]);
                    // consecutive edges genuinely incident
                    let ip = crate::graph::incidence::inner_product(
                        g.edges()[w.edges[j - 1] as usize],
                        g.edges()[w.edges[j] as usize],
                    );
                    assert!(ip != 0.0);
                }
            }
        }
    }

    #[test]
    fn alpha_tracks_inner_products() {
        let g = small_graph();
        let engine = WalkEngine::new(&g);
        let mut rng = Rng::new(2);
        let w = engine.sample_walk(5, &mut rng);
        let mut expect = 1.0;
        for j in 1..5 {
            expect *= crate::graph::incidence::inner_product(
                g.edges()[w.edges[j - 1] as usize],
                g.edges()[w.edges[j] as usize],
            );
            assert_eq!(w.alpha[j], expect);
        }
    }

    #[test]
    fn l1_estimate_is_unbiased() {
        // L¹: every importance trial contributes w·x_e x_eᵀ with E[·] = L.
        let g = small_graph();
        let l = g.laplacian();
        for method in [SampleMethod::Importance, SampleMethod::Rejection] {
            let (est, stats) = estimate_l_power(&g, 1, 20_000, 2, method, 7);
            assert_eq!(stats.trials, 20_000);
            let err = (&est - &l).max_abs() / l.max_abs();
            assert!(err < 0.05, "{method:?}: rel err {err}");
        }
    }

    #[test]
    fn l2_and_l3_estimates_converge() {
        let g = small_graph();
        let l = g.laplacian();
        let l2 = matmul(&l, &l);
        let l3 = matmul(&l2, &l);
        for (len, truth) in [(2usize, &l2), (3usize, &l3)] {
            let (est, _) = estimate_l_power(&g, len, 60_000, 2, SampleMethod::Importance, 11);
            let err = (&est - truth).max_abs() / truth.max_abs();
            assert!(err < 0.15, "len={len}: rel err {err}");
        }
    }

    #[test]
    fn rejection_and_importance_agree_in_expectation() {
        // Non-regular graph: the bridge between triangles gives the
        // incidence graph varying degrees, so rejection actually rejects.
        let g = small_graph();
        let l = g.laplacian();
        let l2 = matmul(&l, &l);
        let (est_r, stats_r) = estimate_l_power(&g, 2, 80_000, 2, SampleMethod::Rejection, 3);
        let (est_i, _) = estimate_l_power(&g, 2, 20_000, 2, SampleMethod::Importance, 4);
        assert!((&est_r - &l2).max_abs() / l2.max_abs() < 0.2, "rejection biased?");
        assert!((&est_i - &l2).max_abs() / l2.max_abs() < 0.1, "importance biased?");
        assert!(stats_r.acceptance_rate() < 1.0, "non-regular graph must reject some walks");
        assert!(stats_r.acceptance_rate() > 0.0);
    }

    #[test]
    fn rejection_accepts_everything_on_regular_graphs() {
        // On a degree-regular graph every walk has probability exactly
        // p_min → acceptance rate 1 (rejection sampling degenerates to
        // uniform sampling, as eq 13-14 predict).
        let g = ring(8).graph;
        let (_, stats) = estimate_l_power(&g, 2, 5_000, 2, SampleMethod::Rejection, 5);
        assert!((stats.acceptance_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn error_decreases_with_more_walks() {
        let g = small_graph();
        let l = g.laplacian();
        let l2 = matmul(&l, &l);
        let errs: Vec<f64> = [2_000usize, 64_000]
            .iter()
            .map(|&n| {
                let (est, _) = estimate_l_power(&g, 2, n, 2, SampleMethod::Importance, 5);
                (&est - &l2).max_abs() / l2.max_abs()
            })
            .collect();
        assert!(errs[1] < errs[0] * 0.6, "no ~1/√n decay: {errs:?}");
    }

    #[test]
    fn poly_apply_estimate_unbiased() {
        // p(L)·V for p(x) = 0.5 + x − 0.2x² vs exact.
        let g = small_graph();
        let l = g.laplacian();
        let coeffs = [0.5, 1.0, -0.2];
        let v = DMat::from_fn(6, 3, |i, j| ((i * 3 + j) as f64).sin());
        let exact = matmul(&crate::linalg::funcs::poly_horner(&l, &coeffs), &v);
        let est = WalkEstimator::new(&g, SampleMethod::Importance);
        let mut rng = Rng::new(13);
        let approx = est.estimate_poly_apply(&coeffs, &v, 60_000, &mut rng);
        let err = (&approx - &exact).max_abs() / exact.max_abs().max(1e-12);
        assert!(err < 0.15, "rel err {err}");
    }

    #[test]
    fn estimator_scales_with_clique_graph() {
        let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 2, seed: 9 }).graph;
        let l = g.laplacian();
        let l2 = matpow(&l, 2);
        let (est, stats) = estimate_l_power(&g, 2, 40_000, 3, SampleMethod::Importance, 21);
        assert_eq!(stats.trials, 40_000);
        let rel = (est.trace() - l2.trace()).abs() / l2.trace();
        assert!(rel < 0.2, "trace rel err {rel}");
    }

    #[test]
    fn parallel_and_serial_estimates_both_unbiased() {
        let g = ring(6).graph;
        let l = g.laplacian();
        let (e1, _) = estimate_l_power(&g, 1, 30_000, 1, SampleMethod::Importance, 42);
        let (e4, _) = estimate_l_power(&g, 1, 30_000, 4, SampleMethod::Importance, 42);
        assert!((&e1 - &l).max_abs() / l.max_abs() < 0.08);
        assert!((&e4 - &l).max_abs() / l.max_abs() < 0.08);
    }

    #[test]
    fn property_acceptance_probability_valid() {
        use crate::testkit::{check, SizeGen};
        check(17, 10, &SizeGen { lo: 6, hi: 24 }, |&n| {
            let g = cliques(&CliqueSpec { n, k: 2, max_short_circuit: 2, seed: n as u64 }).graph;
            let engine = WalkEngine::new(&g);
            let mut rng = Rng::new(n as u64);
            for len in 1..=4 {
                let p_min = engine.p_min(len);
                for _ in 0..50 {
                    let w = engine.sample_walk(len, &mut rng);
                    let ratio = p_min / w.prob[len - 1];
                    if !(ratio > 0.0 && ratio <= 1.0 + 1e-12) {
                        return false;
                    }
                }
            }
            true
        });
    }
}
