//! Stochastic [`MatVecOp`] oracles — the paper's optimization model, where
//! the solver never sees the full matrix, only minibatch estimates.
//!
//! * [`MinibatchLaplacianOp`] — the classic streaming-PCA model (§3): each
//!   step samples a batch of edges and applies the unbiased estimate
//!   `L̂ = (|E|/B) Σ_{e∈batch} w_e x_e x_eᵀ` (reversed per eq 8) to `V`
//!   without materializing anything dense.
//! * [`StochasticPolyOp`] — the full stochastic SPED operator: each step
//!   draws fresh random walks on the edge-incidence graph and applies an
//!   unbiased estimate of `λ*I − p(L)` (sub-walk harvesting; §4.3).
//!
//! The `--precision mixed` knob ([`crate::transforms::Precision`])
//! deliberately does **not** reach these oracles: their per-application
//! error is Monte-Carlo variance (`~1/√walks`), orders of magnitude above
//! any f32 rounding term, so demoting their arithmetic would change
//! trajectories without a measurable speedup. Both oracles therefore keep
//! the default [`MatVecOp::precision_floor`] of zero — their noise floor
//! is statistical, not arithmetic, and the solvers that drive them (Oja,
//! µ-EigenGame) average across steps rather than certifying residuals.

use super::MatVecOp;
use crate::graph::Graph;
use crate::linalg::DMat;
use crate::transforms::{ChebSeries, PolyBasis, TransformKind};
use crate::util::rng::Rng;
use crate::walks::{SampleMethod, WalkEstimator};

/// Minibatch edge-sampling oracle for `M = λ*I − L` (identity transform).
pub struct MinibatchLaplacianOp<'g> {
    graph: &'g Graph,
    pub lambda_star: f64,
    pub batch: usize,
    rng: Rng,
}

impl<'g> MinibatchLaplacianOp<'g> {
    pub fn new(graph: &'g Graph, lambda_star: f64, batch: usize, seed: u64) -> Self {
        assert!(graph.num_edges() > 0);
        MinibatchLaplacianOp { graph, lambda_star, batch, rng: Rng::new(seed) }
    }
}

impl MatVecOp for MinibatchLaplacianOp<'_> {
    fn apply(&mut self, v: &DMat) -> DMat {
        let (n, k) = (v.rows(), v.cols());
        let m = self.graph.num_edges();
        let mut out = v.clone();
        out.scale(self.lambda_star);
        let scale = -(m as f64) / self.batch as f64;
        let edges = self.graph.edges();
        for _ in 0..self.batch {
            let e = edges[self.rng.below(m)];
            let (u, w) = (e.u as usize, e.v as usize);
            // x_e x_eᵀ V = x_e · (V[u,:] − V[v,:])
            for t in 0..k {
                let d = (v[(u, t)] - v[(w, t)]) * e.w * scale;
                out[(u, t)] += d;
                out[(w, t)] -= d;
            }
        }
        let _ = n;
        out
    }
    fn dim(&self) -> usize {
        self.graph.num_nodes()
    }
    fn label(&self) -> String {
        format!("minibatch[B={}]", self.batch)
    }
}

/// Stochastic SPED oracle: `M̂V = λ*·V − p̂(L)·V` with `p̂` estimated from
/// `walks_per_step` fresh random walks each application.
///
/// The walk estimator is **monomial-native**: sub-walk harvesting
/// estimates matrix *powers* `Lⁱ·V`, so whatever basis the caller hands
/// coefficients in ([`StochasticPolyOp::new_in_basis`]), they are
/// converted to plain monomial form once at construction. The exact
/// algebraic conversion is well-conditioned at the low degrees where walk
/// variance is manageable — exactly the stochastic oracle's regime (the
/// high-degree filters where the monomial basis breaks down are the
/// deterministic `SparsePolyOp`'s territory, where the Chebyshev
/// recurrence applies directly).
pub struct StochasticPolyOp<'g> {
    estimator: WalkEstimator<'g>,
    /// Monomial coefficients of `p` (`p(x) = Σ coeffs[i] xⁱ`) — the form
    /// the walk estimator consumes, post-conversion.
    pub coeffs: Vec<f64>,
    /// The basis the caller supplied coefficients in (label/provenance).
    pub basis: PolyBasis,
    pub lambda_star: f64,
    pub walks_per_step: usize,
    rng: Rng,
}

impl<'g> StochasticPolyOp<'g> {
    /// Dense-free reversal shift for the stochastic oracle: λ* of eq 8 for
    /// `kind` with ρ(L) estimated by **CSR** power iteration
    /// (`O(power_iters·nnz)`, bitwise worker-invariant) — never an `n×n`
    /// Laplacian. This is the stochastic path's counterpart of the
    /// deterministic builders' [`crate::transforms::DomainEstimate`]
    /// policy: the whole point of the walk oracle is that nothing dense is
    /// ever formed, so its λ* must not be the one place that materializes
    /// `graph.laplacian()` just to run the dense `power_lambda_max`.
    pub fn auto_lambda_star(
        graph: &Graph,
        kind: TransformKind,
        power_iters: usize,
        safety: f64,
        threads: usize,
    ) -> anyhow::Result<f64> {
        let rho = crate::linalg::sparse::power_lambda_max_csr(
            &graph.laplacian_csr(),
            power_iters,
            threads.max(1),
        )? * safety;
        Ok(kind.lambda_star(rho))
    }

    /// Monomial-coefficient constructor (the historical interface).
    pub fn new(
        graph: &'g Graph,
        coeffs: Vec<f64>,
        lambda_star: f64,
        walks_per_step: usize,
        method: SampleMethod,
        seed: u64,
    ) -> Self {
        StochasticPolyOp {
            estimator: WalkEstimator::new(graph, method),
            coeffs,
            basis: PolyBasis::Monomial,
            lambda_star,
            walks_per_step,
            rng: Rng::new(seed),
        }
    }

    /// Construct with coefficients expressed in `basis`. Chebyshev-form
    /// coefficients are interpreted on `domain = (lo, hi)` and converted
    /// exactly to the monomial form the walk estimator consumes; the
    /// domain is ignored for [`PolyBasis::Monomial`].
    #[allow(clippy::too_many_arguments)]
    pub fn new_in_basis(
        graph: &'g Graph,
        basis: PolyBasis,
        coeffs: Vec<f64>,
        domain: (f64, f64),
        lambda_star: f64,
        walks_per_step: usize,
        method: SampleMethod,
        seed: u64,
    ) -> Self {
        let mono = match basis {
            PolyBasis::Monomial => coeffs,
            PolyBasis::Chebyshev => {
                // Same hard guard as ChebSeries::fit: a degenerate domain
                // would make the affine map (and thus every converted
                // coefficient) inf/NaN with no error until the solve.
                let (lo, hi) = domain;
                assert!(
                    lo.is_finite() && hi.is_finite() && hi > lo,
                    "Chebyshev coefficients need a finite non-degenerate domain (got [{lo}, {hi}])"
                );
                ChebSeries { lo, hi, coeffs }.to_plain_monomial()
            }
        };
        StochasticPolyOp {
            estimator: WalkEstimator::new(graph, method),
            coeffs: mono,
            basis,
            lambda_star,
            walks_per_step,
            rng: Rng::new(seed),
        }
    }
}

impl MatVecOp for StochasticPolyOp<'_> {
    fn apply(&mut self, v: &DMat) -> DMat {
        let est =
            self.estimator
                .estimate_poly_apply(&self.coeffs, v, self.walks_per_step, &mut self.rng);
        let mut out = v.clone();
        out.scale(self.lambda_star);
        out.axpy(-1.0, &est);
        out
    }
    fn dim(&self) -> usize {
        self.estimator.engine.graph().num_nodes()
    }
    fn label(&self) -> String {
        format!(
            "stoch-poly[deg={},W={},{}]",
            self.coeffs.len().saturating_sub(1),
            self.walks_per_step,
            self.basis
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, CliqueSpec};
    use crate::linalg::eigh;
    use crate::linalg::matmul::matmul;
    use crate::solvers::{run_convergence, DenseOp, Oja, RunConfig};

    fn small() -> Graph {
        cliques(&CliqueSpec { n: 18, k: 2, max_short_circuit: 1, seed: 2 }).graph
    }

    #[test]
    fn minibatch_op_unbiased() {
        let g = small();
        let l = g.laplacian();
        let lam_star = 1.1 * crate::linalg::funcs::power_lambda_max(&l, 100).unwrap();
        let v = crate::solvers::random_init(g.num_nodes(), 3, 7);
        // Average many applications ≈ (λ*I − L)V.
        let mut op = MinibatchLaplacianOp::new(&g, lam_star, 8, 3);
        let mut acc = DMat::zeros(g.num_nodes(), 3);
        let reps = 3000;
        for _ in 0..reps {
            acc.axpy(1.0 / reps as f64, &op.apply(&v));
        }
        let mut expect = v.clone();
        expect.scale(lam_star);
        expect.axpy(-1.0, &matmul(&l, &v));
        let err = (&acc - &expect).max_abs() / expect.max_abs();
        assert!(err < 0.12, "rel err {err}"); // ~1/√(reps·B) Monte-Carlo noise
    }

    #[test]
    fn stochastic_poly_op_unbiased() {
        let g = small();
        let l = g.laplacian();
        let coeffs = vec![0.0, 1.0, 0.05]; // p(x) = x + 0.05x²
        let v = crate::solvers::random_init(g.num_nodes(), 2, 11);
        let mut op =
            StochasticPolyOp::new(&g, coeffs.clone(), 2.0, 2000, SampleMethod::Importance, 5);
        let mut acc = DMat::zeros(g.num_nodes(), 2);
        let reps = 60;
        for _ in 0..reps {
            acc.axpy(1.0 / reps as f64, &op.apply(&v));
        }
        let p = crate::linalg::funcs::poly_horner(&l, &coeffs);
        let mut expect = v.clone();
        expect.scale(2.0);
        expect.axpy(-1.0, &matmul(&p, &v));
        let err = (&acc - &expect).max_abs() / expect.max_abs();
        assert!(err < 0.1, "rel err {err}");
    }

    #[test]
    fn stochastic_poly_op_chebyshev_basis_matches_monomial() {
        // The same quadratic handed over in Chebyshev form on [0, 4] must
        // produce the identical estimator trajectory: the conversion to
        // monomial coefficients is exact at low degree, and the RNG seeds
        // match, so outputs agree to conversion rounding.
        let g = small();
        let mono = vec![0.5, 1.0, 0.25]; // p(x) = 0.5 + x + 0.25x²
        let domain = (0.0, 4.0);
        let cheb_coeffs = {
            let sf = crate::transforms::SeriesForm { shift: 0.0, coeffs: mono.clone() };
            crate::transforms::ChebSeries::from_series_form(&sf, domain.0, domain.1).coeffs
        };
        let v = crate::solvers::random_init(g.num_nodes(), 2, 4);
        let mut a = StochasticPolyOp::new(&g, mono.clone(), 1.5, 500, SampleMethod::Importance, 9);
        let mut b = StochasticPolyOp::new_in_basis(
            &g,
            PolyBasis::Chebyshev,
            cheb_coeffs,
            domain,
            1.5,
            500,
            SampleMethod::Importance,
            9,
        );
        assert_eq!(b.basis, PolyBasis::Chebyshev);
        assert!(b.label().contains("chebyshev"), "label {}", b.label());
        for (ca, cb) in a.coeffs.iter().zip(b.coeffs.iter()) {
            assert!((ca - cb).abs() < 1e-12, "converted coeff {cb} vs {ca}");
        }
        let out_a = a.apply(&v);
        let out_b = b.apply(&v);
        // Same walks (same seed), near-identical coefficients.
        let err = (&out_a - &out_b).max_abs() / out_a.max_abs().max(1e-12);
        assert!(err < 1e-9, "basis-converted stochastic op diverged: {err}");
        // Monomial-basis new_in_basis is the plain constructor.
        let c = StochasticPolyOp::new_in_basis(
            &g,
            PolyBasis::Monomial,
            mono.clone(),
            (0.0, 1.0),
            1.5,
            500,
            SampleMethod::Importance,
            9,
        );
        assert_eq!(c.coeffs, mono);
    }

    #[test]
    fn auto_lambda_star_is_dense_free_and_matches_dense_estimate() {
        let g = small();
        // Same recurrence as the dense power iteration (shared
        // power_iteration_with core) — the estimates agree to rounding.
        let dense_rho = 1.05 * crate::linalg::funcs::power_lambda_max(&g.laplacian(), 100).unwrap();
        let kind = TransformKind::Identity;
        let lam = StochasticPolyOp::auto_lambda_star(&g, kind, 100, 1.05, 1).unwrap();
        assert!(
            (lam - kind.lambda_star(dense_rho)).abs() <= 1e-9 * dense_rho.max(1.0),
            "csr-routed λ* {lam} vs dense {}",
            kind.lambda_star(dense_rho)
        );
        // Worker-invariant, bitwise (the CSR power-iteration contract).
        for threads in [2usize, 8] {
            assert_eq!(
                StochasticPolyOp::auto_lambda_star(&g, kind, 100, 1.05, threads)
                    .unwrap()
                    .to_bits(),
                lam.to_bits()
            );
        }
        // −e^{−x} family reverses with λ* ≡ 0 — no estimate needed at all.
        assert_eq!(
            StochasticPolyOp::auto_lambda_star(
                &g,
                TransformKind::LimitNegExp { ell: 51 },
                100,
                1.05,
                1
            )
            .unwrap(),
            0.0
        );
    }

    #[test]
    fn oja_converges_under_minibatch_noise() {
        // The stochastic optimization model end-to-end: Oja + minibatch
        // Laplacian reaches a decent subspace estimate of the bottom-k.
        let g = small();
        let l = g.laplacian();
        let e = eigh(&l).unwrap();
        let v_star = e.bottom_k(2);
        let lam_star = 1.1 * e.lambda_max();
        let mut op = MinibatchLaplacianOp::new(&g, lam_star, 16, 9);
        let mut solver = Oja { eta: 0.002 };
        let cfg = RunConfig { steps: 4000, eval_every: 100, ..Default::default() };
        let hist = run_convergence(&mut solver, &mut op, &v_star, &cfg);
        let noisy_err = hist.last().unwrap().subspace_error;
        assert!(noisy_err < 0.2, "stochastic Oja err {noisy_err}");
        // Dense reference should do at least as well — sanity anchor.
        let mut mm = l.clone();
        mm.scale(-1.0);
        mm.add_diag(lam_star);
        let mut dop = DenseOp::new(mm);
        let dense_err = run_convergence(&mut Oja { eta: 0.002 }, &mut dop, &v_star, &cfg)
            .last()
            .unwrap()
            .subspace_error;
        assert!(dense_err <= noisy_err + 1e-6);
    }
}
