//! Iterative top-k eigensolvers — the consumers SPED accelerates (§5.1).
//!
//! Two representative scalable stochastic SVD methods, as in the paper:
//!
//! * [`Oja`] — Oja's algorithm (Shamir 2015): `V ← orth(V + η·M V)`.
//! * [`MuEigenGame`] — µ-EigenGame / "EigenGame Unloaded" (Gemp et al.
//!   2021b): per-vector Riemannian ascent on utilities with upstream-only
//!   penalty terms, which recovers the *ordered* eigenvectors (not just the
//!   subspace).
//! * [`SubspaceIteration`] — classical orthogonal/power iteration baseline.
//!
//! Solvers consume a [`MatVecOp`] oracle so the same code runs against:
//! a dense transformed matrix (native), a fresh stochastic walk-estimate
//! per step (the paper's stochastic optimization model), or an AOT XLA
//! executable (`runtime::XlaDenseOp`).

use crate::linalg::dmat::{dot, normalize, DMat};
use crate::linalg::matmul::matmul;
use crate::linalg::metrics::{eigenvector_streak, subspace_error, ConvergenceHistory};
use crate::linalg::qr::mgs_orthonormalize;
use crate::linalg::shard::{ShardedCsr, StepOperand};
use crate::linalg::sparse::{spmm_step_mixed_into, CsrMat, CsrMatF32};
use crate::transforms::{ChebSeries, PolyBasis, PolySeries, Precision, SeriesForm, TransformKind};

pub mod ritz;
pub mod stochastic;

/// A "multiply by M" oracle: the only access solvers have to the matrix.
pub trait MatVecOp {
    /// `M · V` for an `n×k` bundle `V`.
    fn apply(&mut self, v: &DMat) -> DMat;
    /// Dimension `n`.
    fn dim(&self) -> usize;
    /// Human label for logs/CSV.
    fn label(&self) -> String {
        "op".into()
    }
    /// How many SpMM (or dense-product) sweeps one [`Self::apply`] costs —
    /// the cost unit the Ritz solver's per-iteration accounting reports.
    /// One for plain operators; the matrix-free polynomial operator
    /// overrides this with its evaluated degree.
    fn sweeps_per_apply(&self) -> usize {
        1
    }
    /// The smallest relative residual this operator's arithmetic can
    /// certify — `0` for full-precision operators (the default); the
    /// mixed-precision matrix-free operator reports its documented f32
    /// error budget ([`SparsePolyOp::mixed_budget`]). The Ritz solver
    /// clamps its convergence tolerance to this floor so a mixed run
    /// never spins on residuals below the arithmetic's resolution.
    fn precision_floor(&self) -> f64 {
        0.0
    }
    /// Halo bundle rows one SpMM sweep exchanges between shards — `0` for
    /// unsharded operators (the default); the sharded matrix-free operator
    /// reports its partition's total. The Ritz solver multiplies this by
    /// sweeps × active columns for its per-solve `halo_volume` accounting.
    fn halo_rows_per_sweep(&self) -> usize {
        0
    }
}

/// Dense in-memory operator. `threads > 1` row-shards the `M·V` product
/// across `util::pool` workers — bitwise identical to the serial product
/// (`linalg::par` determinism contract), so solver trajectories do not
/// depend on the worker count.
pub struct DenseOp {
    pub m: DMat,
    pub threads: usize,
}

impl DenseOp {
    /// Serial operator (threads = 1).
    pub fn new(m: DMat) -> DenseOp {
        DenseOp { m, threads: 1 }
    }
}

impl MatVecOp for DenseOp {
    fn apply(&mut self, v: &DMat) -> DMat {
        // Shared work-size guard: below the threshold the scoped spawn/join
        // overhead rivals the FLOPs, so run serial. Output is bitwise
        // identical either way — purely a latency decision.
        let work = self.m.rows() * self.m.cols() * v.cols();
        let threads = crate::linalg::par::effective_threads(work, self.threads);
        crate::linalg::par::matmul_par(&self.m, v, threads)
    }
    fn dim(&self) -> usize {
        self.m.rows()
    }
    fn label(&self) -> String {
        format!("dense[{}]", self.m.rows())
    }
}

/// The matrix-free SPED operator (`OpMode::MatrixFree`): evaluates
/// `M·V = λ*·V − p(L)·V` per solver step through sparse multiplies against
/// the CSR Laplacian — `O(ℓ·nnz·k)` per step, `O(n + nnz)` memory, and no
/// `n×n` intermediate, ever. This is the operator shape the paper's §4
/// premise describes, and what Block Chebyshev–Davidson / LOBPCG-style
/// production solvers drive their polynomial filters through.
///
/// Construction ([`SparsePolyOp::from_graph`]) mirrors
/// [`crate::transforms::build_solver_matrix`] — λ_max power iteration,
/// optional pre-scaling, reversal shift λ* (eq 8) — but entirely in
/// `O(nnz)` primitives. Exact (eigh-based) transforms are rejected: they
/// are the dense oracles the series forms exist to avoid.
///
/// Every recurrence step is one fused
/// [`crate::linalg::sparse::spmm_step_into`] pass, so the `k ≤ 16` bundle
/// widths the solvers actually use run on the register-blocked kernel
/// family (each CSR row's nonzeros swept once, all `k` columns plus the
/// step's scale/axpy terms accumulating in registers) rather than the
/// three-pass SpMM + `scale` + `axpy` composition.
///
/// The polynomial basis is a knob ([`crate::transforms::BuildOptions::basis`],
/// CLI `--basis`): the default monomial basis is bitwise-identical to the
/// historical path (Horner for the Taylor kinds, the repeated-multiply
/// special case for `LimitNegExp`); the Chebyshev basis evaluates every
/// polynomial kind through the domain-mapped three-term recurrence —
/// numerically stable at ℓ ≈ 251 and with no underflow special-casing.
/// The recurrence's fit interval and kept degree are further knobs
/// ([`crate::transforms::DomainEstimate`] / [`crate::transforms::Degree`],
/// CLI `--domain` / `--degree`): `--domain lanczos --degree auto` fits on
/// a tight two-sided Ritz interval and truncates the coefficient tail, so
/// one [`MatVecOp::apply`] takes [`Self::sweeps`] ≪ ℓ fused passes for the
/// same dilation (validated against the scalar map via
/// [`Self::poly_eval`]).
///
/// Output is bitwise identical for every worker count (the
/// [`crate::linalg::sparse`] determinism contract), so solver trajectories
/// do not depend on `threads`.
pub struct SparsePolyOp {
    /// CSR of the (pre-scaled) Laplacian the polynomial is evaluated in.
    l: CsrMat,
    /// f32 copy of `l` for the mixed-precision sweeps ([`Precision::Mixed`]
    /// only; `None` on the default f64 path, which stays bitwise-identical).
    l32: Option<CsrMatF32>,
    form: SparsePolyForm,
    /// Reversal shift λ* of eq 8.
    pub lambda_star: f64,
    /// Pre-scaling applied to `L` before the transform (`L ← L/scale`).
    pub scale: f64,
    /// The transform this operator realizes.
    pub kind: TransformKind,
    /// The polynomial basis `p(L)·V` is evaluated in.
    pub basis: PolyBasis,
    /// Arithmetic precision of the SpMM sweeps (`--precision f64|mixed`).
    /// [`Precision::Mixed`] stores the Laplacian and the recurrence panels
    /// in f32 with f64 accumulators — same recurrences, one f32 rounding
    /// per element per sweep, bounded by [`Self::mixed_budget`].
    pub precision: Precision,
    /// Graph-sharded partition of `l` (`--shards N`, `N ≥ 1`): every series
    /// sweep runs as [`ShardedCsr`]'s two-phase owned/halo apply with one
    /// halo exchange per sweep — bitwise-equal to the unsharded kernels at
    /// every (shard, worker) pair. `None` on the default unsharded path.
    sharded: Option<ShardedCsr>,
    pub threads: usize,
}

/// How `p(L)·V` is evaluated.
enum SparsePolyForm {
    /// A basis-generic polynomial: Horner (monomial) or the three-term
    /// recurrence (Chebyshev), one fused step kernel pass per degree.
    Poly(PolySeries),
    /// `−(I − L/ℓ)^ℓ·V` by `ℓ` repeated fused passes — the monomial-basis
    /// special case for `LimitNegExp`, whose shifted-power coefficient
    /// `ℓ^{−ℓ}` underflows f64 at ℓ = 251. (The Chebyshev basis needs no
    /// such case: `LimitNegExp` goes through [`SparsePolyForm::Poly`].)
    NegPower { ell: usize },
}

impl SparsePolyOp {
    /// Build the matrix-free operator for `kind` directly from a graph —
    /// the dense-free counterpart of `build_solver_matrix`.
    pub fn from_graph(
        graph: &crate::graph::Graph,
        kind: TransformKind,
        opts: &crate::transforms::BuildOptions,
    ) -> anyhow::Result<SparsePolyOp> {
        SparsePolyOp::from_csr(graph.laplacian_csr(), kind, opts)
    }

    /// Build from an already-assembled CSR Laplacian (callers that reuse
    /// one CSR across transforms, or bring a normalized Laplacian).
    pub fn from_csr(
        l: CsrMat,
        kind: TransformKind,
        opts: &crate::transforms::BuildOptions,
    ) -> anyhow::Result<SparsePolyOp> {
        if kind.is_exact() {
            anyhow::bail!(
                "exact transform {kind} needs a full eigendecomposition and has no \
                 polynomial form in any basis (--basis) — use OpMode::DenseMaterialized \
                 with --basis monomial"
            );
        }
        opts.degree.validate_basis(opts.basis)?;
        if opts.shards > 0 && opts.precision.is_mixed() {
            anyhow::bail!(
                "--shards composes with the f64 sweeps only — the mixed-precision \
                 path has no sharded kernel yet; use --precision f64 or drop --shards"
            );
        }
        let threads = opts.threads.max(1);
        // Skip the 100-matvec power estimate when nothing consumes it —
        // see the matching guard in `build_solver_matrix`.
        let need_power =
            opts.prescale || opts.domain == crate::transforms::DomainEstimate::Power;
        let lam_est = if need_power {
            crate::linalg::sparse::power_lambda_max_csr(&l, opts.power_iters, threads)?
                * opts.safety
        } else {
            0.0
        };
        let scale = if opts.prescale && lam_est > 0.0 { lam_est } else { 1.0 };
        let mut l = l;
        if scale != 1.0 {
            l.scale_values(1.0 / scale);
        }
        // Spectral-radius hint for the transform input — handed to the one
        // shared `DomainEstimate` policy (identical to the dense
        // `build_solver_matrix` flow, so both paths see the same ρ and fit
        // the same Chebyshev coefficients on the same interval).
        let rho_hint = if opts.prescale { 1.0 } else { lam_est };
        let est = opts.domain.estimate_csr(&l, rho_hint, threads)?;
        let form = match opts.basis {
            PolyBasis::Monomial => match kind {
                TransformKind::Identity => SparsePolyForm::Poly(PolySeries::Monomial(
                    SeriesForm { shift: 0.0, coeffs: vec![0.0, 1.0] },
                )),
                TransformKind::TaylorLog { .. } | TransformKind::TaylorNegExp { .. } => {
                    SparsePolyForm::Poly(PolySeries::Monomial(
                        kind.series().expect("series kind"),
                    ))
                }
                TransformKind::LimitNegExp { ell } => SparsePolyForm::NegPower { ell },
                TransformKind::MatrixLog { .. } | TransformKind::NegExp => unreachable!(),
            },
            PolyBasis::Chebyshev => {
                let native = kind.series_degree().expect("polynomial kind");
                let fit = opts.degree.checked_fit_degree(native)?;
                let cheb = kind.cheb_series_deg(fit, est.lo, est.hi).expect("polynomial kind");
                SparsePolyForm::Poly(PolySeries::Chebyshev(opts.degree.shape(cheb)))
            }
        };
        let lambda_star = kind.lambda_star(est.rho);
        // Mixed precision demotes the (already scaled) Laplacian to f32
        // once at build time — the f64 CSR stays authoritative for nnz
        // accounting and any exact consumer.
        let l32 = opts.precision.is_mixed().then(|| CsrMatF32::from_f64(&l));
        // Partition AFTER pre-scaling so the shard-local CSRs hold the same
        // values the unsharded sweeps read — the bitwise-equality contract
        // is against this exact matrix.
        let sharded = (opts.shards > 0).then(|| ShardedCsr::partition(&l, opts.shards));
        Ok(SparsePolyOp {
            l,
            l32,
            form,
            lambda_star,
            scale,
            kind,
            basis: opts.basis,
            precision: opts.precision,
            sharded,
            threads,
        })
    }

    /// Shard count of the partitioned operator (`0` when unsharded).
    pub fn shard_count(&self) -> usize {
        self.sharded.as_ref().map_or(0, ShardedCsr::shard_count)
    }

    /// Halo bundle rows one sweep exchanges (`0` when unsharded).
    pub fn halo_rows(&self) -> usize {
        self.sharded.as_ref().map_or(0, |s| s.halo_plan.halo_rows())
    }

    /// Stored entries of the underlying CSR Laplacian.
    pub fn nnz(&self) -> usize {
        self.l.nnz()
    }

    /// SpMM sweeps one operator application takes — the polynomial's
    /// evaluated degree (the repeated-multiply count for the monomial
    /// `LimitNegExp` special case). This is the quantity the
    /// `--domain lanczos --degree auto` combination shrinks: the
    /// `adaptive-degree` bench group's headline metric.
    pub fn sweeps(&self) -> usize {
        match &self.form {
            SparsePolyForm::Poly(p) => p.degree(),
            SparsePolyForm::NegPower { ell } => *ell,
        }
    }

    /// The scalar spectrum map this operator applies to an eigenvalue `x`
    /// of the **original** (un-scaled) Laplacian: `p(x / scale)`, post
    /// domain-fit and degree-shaping — mirroring how [`MatVecOp::apply`]
    /// evaluates `p` on the pre-scaled matrix. Validation compares it
    /// against `kind.scalar_map(x / scale)` at the true eigenvalues
    /// (without the internal division, a pre-scaled operator would be
    /// probed far outside its Chebyshev fit interval, where `T_j` grows
    /// exponentially and the comparison is meaningless).
    pub fn poly_eval(&self, x: f64) -> f64 {
        let y = x / self.scale;
        match &self.form {
            SparsePolyForm::Poly(p) => p.eval_scalar(y),
            SparsePolyForm::NegPower { ell } => {
                crate::transforms::limit_negexp_scalar(y, *ell)
            }
        }
    }

    /// The Chebyshev fit interval the operator's series lives on, in
    /// **pre-scaled** coordinates — the spectrum of `L / scale`, the matrix
    /// the polynomial is evaluated in (`None` for the monomial forms,
    /// which have no domain).
    pub fn fit_domain(&self) -> Option<(f64, f64)> {
        match &self.form {
            SparsePolyForm::Poly(PolySeries::Chebyshev(c)) => Some((c.lo, c.hi)),
            _ => None,
        }
    }

    /// The **documented f32 term** of the mixed-precision error contract:
    /// an upper envelope on `‖mixed apply − f64 apply‖_max` relative to the
    /// bundle scale, via [`crate::transforms::mixed_error_budget`] at this
    /// operator's sweep count and coefficient mass (`Σ|c_j|` for the series
    /// forms; `1` for the norm-bounded `NegPower` special case). The full
    /// `--degree auto --precision mixed` contract is the Chebyshev
    /// truncation tolerance **plus** this term. Meaningful (and nonzero)
    /// regardless of [`Self::precision`], so callers can quote the budget
    /// before opting in.
    pub fn mixed_budget(&self) -> f64 {
        let coeff_l1 = match &self.form {
            SparsePolyForm::Poly(PolySeries::Monomial(s)) => {
                s.coeffs.iter().map(|c| c.abs()).sum()
            }
            SparsePolyForm::Poly(PolySeries::Chebyshev(c)) => {
                c.coeffs.iter().map(|c| c.abs()).sum()
            }
            SparsePolyForm::NegPower { .. } => 1.0,
        };
        crate::transforms::mixed_error_budget(self.sweeps(), coeff_l1)
    }

    /// Mixed-precision apply: the identical recurrences to the f64 path,
    /// with the Laplacian and the recurrence panels stored in f32 and every
    /// per-row reduction accumulating in f64 ([`spmm_step_mixed_into`]).
    /// The final reversal combine `λ*·V − p(L)·V` runs in full f64 against
    /// the original input bundle. Bitwise worker-invariant, but **not**
    /// equal to the f64 path — bounded by [`Self::mixed_budget`].
    fn apply_mixed(&self, v: &DMat, threads: usize) -> DMat {
        let l32 = self.l32.as_ref().expect("mixed operator carries an f32 Laplacian");
        let (n, k) = (v.rows(), v.cols());
        let v32 = v.to_f32();
        let p_v = match &self.form {
            SparsePolyForm::Poly(PolySeries::Monomial(s)) => {
                mixed_horner_bundle(l32, s, &v32, n, k, threads)
            }
            SparsePolyForm::Poly(PolySeries::Chebyshev(c)) => {
                mixed_cheb_bundle(l32, c, v, &v32, threads)
            }
            SparsePolyForm::NegPower { ell } => {
                // W ← (I − L/ℓ)·W, ℓ times; p(L)·V = −W — the f32-panel
                // mirror of the f64 fused loop below.
                let inv = -1.0 / *ell as f64;
                let mut w = v32.clone();
                let mut t = vec![0.0f32; n * k];
                for _ in 0..*ell {
                    spmm_step_mixed_into(l32, &w, &v32, k, 1.0, inv, 0.0, &mut t, threads);
                    std::mem::swap(&mut w, &mut t);
                }
                let mut p = DMat::from_f32(n, k, &w);
                p.scale(-1.0);
                p
            }
        };
        // M·V = λ*·V − p(L)·V, in f64 against the original bundle.
        let mut out = v.clone();
        out.scale(self.lambda_star);
        out.axpy(-1.0, &p_v);
        out
    }
}

/// f32-panel Horner: `R ← c_d·V`, then `d` fused mixed passes
/// `R ← B·R + c_i·V` with `B = A − shift·I` — the mirror of
/// [`SeriesForm::apply_bundle`] with one f32 rounding per element per pass.
fn mixed_horner_bundle(
    l32: &CsrMatF32,
    s: &SeriesForm,
    v32: &[f32],
    n: usize,
    k: usize,
    threads: usize,
) -> DMat {
    if s.coeffs.is_empty() {
        return DMat::zeros(n, k);
    }
    let d = s.coeffs.len() - 1;
    let mut r: Vec<f32> = v32.iter().map(|&x| (s.coeffs[d] * x as f64) as f32).collect();
    let mut t = vec![0.0f32; n * k];
    for i in (0..d).rev() {
        spmm_step_mixed_into(l32, &r, v32, k, -s.shift, 1.0, s.coeffs[i], &mut t, threads);
        std::mem::swap(&mut r, &mut t);
    }
    DMat::from_f32(n, k, &r)
}

/// f32-panel Chebyshev recurrence: the mirror of
/// [`ChebSeries::apply_bundle`] with the `T_j·V` panels in f32. The output
/// accumulation `Σ c_j·(T_j V)` stays in f64 (each panel element is widened
/// once), so the only f32 roundings are the per-sweep panel stores that
/// [`crate::transforms::mixed_error_budget`] accounts for.
fn mixed_cheb_bundle(
    l32: &CsrMatF32,
    c: &ChebSeries,
    v: &DMat,
    v32: &[f32],
    threads: usize,
) -> DMat {
    let (n, k) = (v.rows(), v.cols());
    let mut out = DMat::zeros(n, k);
    if c.coeffs.is_empty() {
        return out;
    }
    out.axpy(c.coeffs[0], v); // c_0·T_0·V in full f64
    if c.coeffs.len() == 1 {
        return out;
    }
    // Domain map y = a·x + b (public-field mirror of the f64 recurrence).
    assert!(c.hi > c.lo, "degenerate Chebyshev domain [{}, {}]", c.lo, c.hi);
    let a = 2.0 / (c.hi - c.lo);
    let b = -(c.hi + c.lo) / (c.hi - c.lo);
    let mut t_prev = v32.to_vec();
    let mut t_cur = vec![0.0f32; n * k];
    spmm_step_mixed_into(l32, v32, v32, k, b, a, 0.0, &mut t_cur, threads);
    axpy_f32_panel(&mut out, c.coeffs[1], &t_cur);
    let mut t_next = vec![0.0f32; n * k];
    for &cj in c.coeffs.iter().skip(2) {
        spmm_step_mixed_into(l32, &t_cur, &t_prev, 2.0 * b, 2.0 * a, -1.0, &mut t_next, threads);
        if cj != 0.0 {
            axpy_f32_panel(&mut out, cj, &t_next);
        }
        std::mem::swap(&mut t_prev, &mut t_cur);
        std::mem::swap(&mut t_cur, &mut t_next);
    }
    out
}

/// `out += c · panel` with each f32 panel element widened to f64 once.
fn axpy_f32_panel(out: &mut DMat, c: f64, panel: &[f32]) {
    for (o, &p) in out.data_mut().iter_mut().zip(panel.iter()) {
        *o += c * p as f64;
    }
}

impl MatVecOp for SparsePolyOp {
    fn apply(&mut self, v: &DMat) -> DMat {
        // Shared work-size guard; work per SpMM is nnz·k multiply-adds.
        let work = self.l.nnz().saturating_mul(v.cols());
        let threads = crate::linalg::par::effective_threads(work, self.threads);
        if self.precision.is_mixed() {
            return self.apply_mixed(v, threads);
        }
        // One stepping operand for every evaluator: the plain fused kernel,
        // or (with --shards) the two-phase owned/halo sharded apply — same
        // recurrences, bitwise-equal output.
        let operand = match &self.sharded {
            Some(s) => StepOperand::Sharded(s),
            None => StepOperand::Csr(&self.l),
        };
        let p_v = match &self.form {
            SparsePolyForm::Poly(series) => series.apply_bundle_via(&operand, v, threads),
            SparsePolyForm::NegPower { ell } => {
                // W ← (I − L/ℓ)·W, ℓ times; p(L)·V = −W. Each step is one
                // fused pass (W + inv·(L·W)) over two preallocated bundles
                // — no per-iteration allocation, one bundle traversal
                // instead of the three of SpMM + scale + axpy.
                let inv = -1.0 / *ell as f64;
                let mut w = v.clone();
                let mut t = DMat::zeros(v.rows(), v.cols());
                for _ in 0..*ell {
                    operand.step_into(&w, v, 1.0, inv, 0.0, &mut t, threads);
                    std::mem::swap(&mut w, &mut t);
                }
                w.scale(-1.0);
                w
            }
        };
        // M·V = λ*·V − p(L)·V
        let mut out = v.clone();
        out.scale(self.lambda_star);
        out.axpy(-1.0, &p_v);
        out
    }
    fn dim(&self) -> usize {
        self.l.rows()
    }
    fn label(&self) -> String {
        let mut label = if self.precision.is_mixed() {
            format!("sparse[{},nnz={},{},mixed]", self.l.rows(), self.l.nnz(), self.basis)
        } else {
            format!("sparse[{},nnz={},{}]", self.l.rows(), self.l.nnz(), self.basis)
        };
        if let Some(s) = &self.sharded {
            label.push_str(&format!(
                "+shards[{},halo={}]",
                s.shard_count(),
                s.halo_plan.halo_rows()
            ));
        }
        label
    }
    fn sweeps_per_apply(&self) -> usize {
        self.sweeps()
    }
    fn precision_floor(&self) -> f64 {
        if self.precision.is_mixed() {
            self.mixed_budget()
        } else {
            0.0
        }
    }
    fn halo_rows_per_sweep(&self) -> usize {
        self.halo_rows()
    }
}

/// A top-k eigensolver iterating on a [`MatVecOp`].
pub trait EigenSolver {
    /// Advance one step; `v` is the current `n×k` estimate (columns =
    /// eigenvector estimates, leading column = top eigenvector of `M`).
    fn step(&mut self, op: &mut dyn MatVecOp, v: &mut DMat);
    fn name(&self) -> &'static str;
}

/// Oja's algorithm: gradient ascent on `tr(VᵀMV)` followed by
/// orthonormalization (`V ← orth(V + ηMV)`).
pub struct Oja {
    pub eta: f64,
}

impl EigenSolver for Oja {
    fn step(&mut self, op: &mut dyn MatVecOp, v: &mut DMat) {
        let g = op.apply(v);
        v.axpy(self.eta, &g);
        mgs_orthonormalize(v);
    }
    fn name(&self) -> &'static str {
        "oja"
    }
}

/// µ-EigenGame ("EigenGame Unloaded", Gemp et al. 2021b).
///
/// Each player `i` ascends the utility
/// `u_i = v_iᵀMv_i − Σ_{j<i} (v_iᵀMv_j)² / (v_jᵀMv_j)`
/// via the *unloaded* gradient `∇_i = Mv_i − Σ_{j<i} (v_iᵀMv_j)·v_j`,
/// projected onto the tangent space of the sphere and renormalized. The
/// hierarchy of penalties orders the eigenvectors.
pub struct MuEigenGame {
    pub eta: f64,
}

impl EigenSolver for MuEigenGame {
    fn step(&mut self, op: &mut dyn MatVecOp, v: &mut DMat) {
        let (n, k) = (v.rows(), v.cols());
        let g = op.apply(v); // G = M·V
        // A = Vᵀ G (k×k): A[j][i] = v_jᵀ M v_i.
        let a = matmul(&v.t(), &g);
        // grad_i = G_i − Σ_{j<i} A[j,i] · v_j  (strictly-upper mask on A).
        let mut grad = g;
        for i in 0..k {
            for j in 0..i {
                let coef = a[(j, i)];
                if coef == 0.0 {
                    continue;
                }
                for r in 0..n {
                    grad[(r, i)] -= coef * v[(r, j)];
                }
            }
        }
        // Riemannian projection + retraction per column.
        for i in 0..k {
            let vi = v.col(i);
            let gi = grad.col(i);
            let vg = dot(&vi, &gi);
            let mut newv: Vec<f64> = (0..n)
                .map(|r| vi[r] + self.eta * (gi[r] - vg * vi[r]))
                .collect();
            normalize(&mut newv);
            v.set_col(i, &newv);
        }
    }
    fn name(&self) -> &'static str {
        "mu-eg"
    }
}

/// Classical subspace (block power) iteration: `V ← orth(MV)`.
pub struct SubspaceIteration;

impl EigenSolver for SubspaceIteration {
    fn step(&mut self, op: &mut dyn MatVecOp, v: &mut DMat) {
        let mut g = op.apply(v);
        mgs_orthonormalize(&mut g);
        *v = g;
    }
    fn name(&self) -> &'static str {
        "subspace"
    }
}

/// Construct a step-driven solver by name (`oja`, `mu-eg`/`eg`,
/// `subspace`/`direct`). The block Rayleigh–Ritz solver is *not* a
/// [`EigenSolver`] — its outer iteration owns convergence measurement — and
/// is dispatched by the pipeline ([`crate::coordinator::pipeline`]) before
/// this table is consulted.
pub fn solver_by_name(name: &str, eta: f64) -> anyhow::Result<Box<dyn EigenSolver>> {
    Ok(match name {
        "oja" => Box::new(Oja { eta }),
        "mu-eg" | "eg" | "mu_eg" => Box::new(MuEigenGame { eta }),
        "subspace" | "power" | "direct" => Box::new(SubspaceIteration),
        "ritz" => anyhow::bail!(
            "the ritz solver is block-structured: drive it through the pipeline \
             (--solver ritz) or solvers::ritz::ritz_solve, not the step interface"
        ),
        other => anyhow::bail!("unknown solver {other:?}"),
    })
}

/// Deterministic random init of an `n×k` orthonormal bundle.
pub fn random_init(n: usize, k: usize, seed: u64) -> DMat {
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut v = DMat::from_fn(n, k, |_, _| rng.normal());
    mgs_orthonormalize(&mut v);
    v
}

/// Configuration for a convergence run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Total solver steps.
    pub steps: usize,
    /// Record metrics every `eval_every` steps (step 0 included).
    pub eval_every: usize,
    /// Streak tolerance ε (paper §5.2; alignment ≥ 1−ε counts).
    pub streak_eps: f64,
    /// Stop early once streak == k and subspace error < `stop_error`
    /// (0 disables early stop).
    pub stop_error: f64,
    pub seed: u64,
    /// Ground-truth eigenvalues for the tracked columns. When present the
    /// streak is degeneracy-aware (`eigenvector_streak_grouped`): exact on
    /// simple spectra, group-projected on tied eigenvalues (symmetric
    /// workloads like the 3-room MDP).
    pub group_values: Option<Vec<f64>>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            steps: 10_000,
            eval_every: 25,
            streak_eps: 1e-2,
            stop_error: 0.0,
            seed: 0,
            group_values: None,
        }
    }
}

/// Run `solver` on `op` for `cfg.steps`, measuring against the ground-truth
/// bundle `v_star` (columns ordered to match the solver's target order:
/// for a SPED-reversed matrix these are the *bottom* eigenvectors of `L`).
/// Returns the metric history and the final estimate.
pub fn run_convergence_full(
    solver: &mut dyn EigenSolver,
    op: &mut dyn MatVecOp,
    v_star: &DMat,
    cfg: &RunConfig,
) -> (ConvergenceHistory, DMat) {
    let (n, k) = (v_star.rows(), v_star.cols());
    assert_eq!(op.dim(), n);
    let mut v = random_init(n, k, cfg.seed);
    let mut hist = ConvergenceHistory::new(format!("{}:{}", solver.name(), op.label()));
    let record = |hist: &mut ConvergenceHistory, step: usize, v: &DMat| {
        let err = subspace_error(v_star, v);
        let streak = match &cfg.group_values {
            Some(vals) => crate::linalg::metrics::eigenvector_streak_grouped(
                v_star,
                vals,
                v,
                cfg.streak_eps,
                1e-9,
            ),
            None => eigenvector_streak(v_star, v, cfg.streak_eps),
        };
        hist.push(step, err, streak);
        (err, streak)
    };
    record(&mut hist, 0, &v);
    for step in 1..=cfg.steps {
        solver.step(op, &mut v);
        if step % cfg.eval_every == 0 || step == cfg.steps {
            let (err, streak) = record(&mut hist, step, &v);
            if cfg.stop_error > 0.0 && streak == k && err < cfg.stop_error {
                break;
            }
        }
    }
    (hist, v)
}

/// Ground-truth-free driver: advance `solver` on `op` for exactly `steps`
/// steps with no metrics and no early stop, returning the final `n×k`
/// estimate. This is the dense-free path (`PipelineConfig::ground_truth =
/// false`): [`run_convergence_full`] needs the exact bottom-k bundle from
/// an `O(n³)` eigendecomposition, which callers who only want cluster
/// assignments never have to pay for.
pub fn run_steps(
    solver: &mut dyn EigenSolver,
    op: &mut dyn MatVecOp,
    k: usize,
    steps: usize,
    seed: u64,
) -> DMat {
    let mut v = random_init(op.dim(), k, seed);
    for _ in 0..steps {
        solver.step(op, &mut v);
    }
    v
}

/// Metrics-only convenience wrapper around [`run_convergence_full`].
pub fn run_convergence(
    solver: &mut dyn EigenSolver,
    op: &mut dyn MatVecOp,
    v_star: &DMat,
    cfg: &RunConfig,
) -> ConvergenceHistory {
    run_convergence_full(solver, op, v_star, cfg).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, CliqueSpec};
    use crate::linalg::eigh;
    use crate::transforms::{build_solver_matrix, BuildOptions, TransformKind};
    // (fixture + headline-claim test share these imports)

    /// Shared fixture: well-clustered graph, reversed-spectrum matrix, and
    /// its ground-truth top-k eigenvectors (= bottom-k of L).
    fn fixture(kind: TransformKind, k: usize) -> (DMat, DMat) {
        let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 1, seed: 5 }).graph;
        let l = g.laplacian();
        let sm = build_solver_matrix(&l, kind, &BuildOptions::default()).unwrap();
        let e = eigh(&l).unwrap();
        (sm.m, e.bottom_k(k))
    }

    #[test]
    fn oja_converges_on_reversed_identity() {
        let (m, v_star) = fixture(TransformKind::Identity, 3);
        let mut op = DenseOp::new(m);
        let mut solver = Oja { eta: 0.05 };
        let cfg = RunConfig { steps: 4000, eval_every: 50, ..Default::default() };
        let hist = run_convergence(&mut solver, &mut op, &v_star, &cfg);
        let last = hist.last().unwrap();
        assert!(last.subspace_error < 1e-3, "err {}", last.subspace_error);
    }

    #[test]
    fn mu_eg_recovers_ordered_eigenvectors() {
        let (m, v_star) = fixture(TransformKind::NegExp, 3);
        let mut op = DenseOp::new(m);
        let mut solver = MuEigenGame { eta: 0.1 };
        let cfg = RunConfig { steps: 6000, eval_every: 100, ..Default::default() };
        let hist = run_convergence(&mut solver, &mut op, &v_star, &cfg);
        let last = hist.last().unwrap();
        assert_eq!(last.streak, 3, "streak {}, err {}", last.streak, last.subspace_error);
    }

    #[test]
    fn subspace_iteration_baseline() {
        let (m, v_star) = fixture(TransformKind::NegExp, 3);
        let mut op = DenseOp::new(m);
        let mut solver = SubspaceIteration;
        let cfg = RunConfig { steps: 500, eval_every: 10, ..Default::default() };
        let hist = run_convergence(&mut solver, &mut op, &v_star, &cfg);
        assert!(hist.last().unwrap().subspace_error < 1e-6);
    }

    #[test]
    fn transform_accelerates_oja_headline_claim() {
        // The paper's core claim at miniature scale: steps-to-convergence
        // is smaller under −e^{−L} than under identity. A hard instance
        // (big cliques → large λ_max, small relative bottom gaps) and
        // per-transform η normalization (η = base/ρ(M), as in the figure
        // harnesses) make the comparison meaningful.
        let k = 3;
        let g = cliques(&CliqueSpec { n: 60, k, max_short_circuit: 4, seed: 17 }).graph;
        let l = g.laplacian();
        let v_star = eigh(&l).unwrap().bottom_k(k);
        let cfg = RunConfig { steps: 20_000, eval_every: 10, ..Default::default() };
        let run = |kind: TransformKind| {
            let sm = build_solver_matrix(&l, kind, &BuildOptions::default()).unwrap();
            let rho_m = (sm.lambda_star - kind.scalar_map(0.0)).abs().max(1e-9);
            let mut op = DenseOp::new(sm.m);
            let mut solver = Oja { eta: 0.5 / rho_m };
            run_convergence(&mut solver, &mut op, &v_star, &cfg)
        };
        let h_id = run(TransformKind::Identity);
        let h_exp = run(TransformKind::NegExp);
        // The discriminating metric is the *streak* (§5.2): recovering the
        // individual ordered eigenvectors requires resolving the tiny
        // bottom gaps, which is where the gap/ρ ratio bites. Subspace error
        // alone only needs the (large) k-th gap on clique graphs.
        let s_id = h_id.steps_to_streak(k).unwrap_or(usize::MAX);
        let s_exp = h_exp.steps_to_streak(k).unwrap_or(usize::MAX);
        assert!(
            s_exp * 2 <= s_id,
            "no ≥2× acceleration: identity {s_id} steps vs negexp {s_exp}"
        );
    }

    #[test]
    fn sparse_poly_op_matches_dense_op_on_series_transforms() {
        // The matrix-free operator must agree with the materialized-dense
        // operator to 1e-9 for every Table-2 series transform (prescaled,
        // the regime where all series converge) plus the identity baseline.
        let g = cliques(&CliqueSpec { n: 40, k: 4, max_short_circuit: 3, seed: 13 }).graph;
        let l = g.laplacian();
        let opts = BuildOptions { prescale: true, ..BuildOptions::default() };
        let v = random_init(40, 6, 21);
        for kind in [
            TransformKind::Identity,
            TransformKind::TaylorNegExp { ell: 31 },
            TransformKind::TaylorLog { ell: 61, eps: 0.05 },
            TransformKind::LimitNegExp { ell: 51 },
        ] {
            let sm = build_solver_matrix(&l, kind, &opts).unwrap();
            let mut dense = DenseOp::new(sm.m);
            let mut sparse = SparsePolyOp::from_graph(&g, kind, &opts).unwrap();
            assert_eq!(sparse.dim(), 40);
            assert!(
                (sparse.lambda_star - sm.lambda_star).abs() < 1e-12,
                "{kind}: λ* {} vs {}",
                sparse.lambda_star,
                sm.lambda_star
            );
            let want = dense.apply(&v);
            let got = sparse.apply(&v);
            let err = (&got - &want).max_abs();
            assert!(err < 1e-9, "{kind}: operator divergence {err}");
        }
    }

    #[test]
    fn sparse_poly_op_deterministic_across_worker_counts() {
        let g = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 7 }).graph;
        let v = random_init(36, 4, 3);
        for kind in [
            TransformKind::TaylorNegExp { ell: 21 },
            TransformKind::LimitNegExp { ell: 31 },
        ] {
            let mk = |threads| {
                let opts = BuildOptions { threads, ..BuildOptions::default() };
                SparsePolyOp::from_graph(&g, kind, &opts).unwrap()
            };
            let serial = mk(1).apply(&v);
            for threads in [2usize, 8] {
                let mut op = mk(threads);
                assert_eq!(op.lambda_star.to_bits(), mk(1).lambda_star.to_bits());
                let par = op.apply(&v);
                let identical = serial
                    .data()
                    .iter()
                    .zip(par.data().iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(identical, "{kind} diverged at {threads} workers");
            }
        }
    }

    #[test]
    fn sharded_op_bitwise_matches_unsharded_all_evaluators() {
        // Horner (TaylorNegExp), NegPower (LimitNegExp) and the Chebyshev
        // recurrence must all route through the sharded two-phase apply
        // without changing a single bit, at every (shards, workers) pair.
        let g = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 7 }).graph;
        let v = random_init(36, 4, 3);
        let cases = [
            (TransformKind::TaylorNegExp { ell: 21 }, PolyBasis::Monomial),
            (TransformKind::LimitNegExp { ell: 31 }, PolyBasis::Monomial),
            (TransformKind::TaylorNegExp { ell: 21 }, PolyBasis::Chebyshev),
        ];
        for (kind, basis) in cases {
            let base = {
                let opts = BuildOptions { basis, ..BuildOptions::default() };
                SparsePolyOp::from_graph(&g, kind, &opts).unwrap().apply(&v)
            };
            for shards in [1usize, 2, 7] {
                for threads in [1usize, 2, 8] {
                    let opts = BuildOptions { basis, shards, threads, ..BuildOptions::default() };
                    let mut op = SparsePolyOp::from_graph(&g, kind, &opts).unwrap();
                    assert_eq!(op.shard_count(), shards);
                    assert!(op.label().contains(&format!("+shards[{shards},")), "{}", op.label());
                    assert_eq!(op.halo_rows_per_sweep(), op.halo_rows());
                    if shards > 1 {
                        assert!(op.halo_rows() > 0, "{kind}/{basis}: expected halo rows");
                    }
                    let got = op.apply(&v);
                    let identical = base
                        .data()
                        .iter()
                        .zip(got.data().iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(identical, "{kind}/{basis} diverged at S={shards}, {threads} workers");
                }
            }
        }
    }

    #[test]
    fn sharded_op_rejects_mixed_precision() {
        let g = cliques(&CliqueSpec { n: 12, k: 2, max_short_circuit: 1, seed: 1 }).graph;
        let opts = BuildOptions {
            shards: 2,
            precision: Precision::Mixed,
            ..BuildOptions::default()
        };
        let err =
            SparsePolyOp::from_graph(&g, TransformKind::TaylorNegExp { ell: 21 }, &opts)
                .unwrap_err();
        assert!(format!("{err:#}").contains("--shards"), "{err:#}");
    }

    #[test]
    fn sparse_poly_op_rejects_exact_transforms() {
        let g = cliques(&CliqueSpec { n: 12, k: 2, max_short_circuit: 1, seed: 1 }).graph;
        for basis in [PolyBasis::Monomial, PolyBasis::Chebyshev] {
            let opts = BuildOptions { basis, ..BuildOptions::default() };
            for kind in [TransformKind::NegExp, TransformKind::MatrixLog { eps: 0.05 }] {
                let err = SparsePolyOp::from_graph(&g, kind, &opts).unwrap_err();
                assert!(
                    format!("{err:#}").contains("--basis"),
                    "{kind}/{basis}: error should mention the basis knob: {err:#}"
                );
            }
        }
    }

    #[test]
    fn chebyshev_op_matches_monomial_op_on_all_series_transforms() {
        // The basis is an evaluation detail: both bases must realize the
        // same operator to ≤1e-9 (different association of the same
        // polynomial), for every polynomial kind — including LimitNegExp,
        // where the monomial path runs the repeated-multiply special case
        // and the Chebyshev path runs the ordinary recurrence.
        let g = cliques(&CliqueSpec { n: 40, k: 4, max_short_circuit: 3, seed: 13 }).graph;
        let v = random_init(40, 6, 21);
        for kind in [
            TransformKind::Identity,
            TransformKind::TaylorNegExp { ell: 31 },
            TransformKind::TaylorLog { ell: 61, eps: 0.05 },
            TransformKind::LimitNegExp { ell: 251 },
        ] {
            let mk = |basis| {
                let opts = BuildOptions { prescale: true, basis, ..BuildOptions::default() };
                SparsePolyOp::from_graph(&g, kind, &opts).unwrap()
            };
            let mut mono = mk(PolyBasis::Monomial);
            let mut cheb = mk(PolyBasis::Chebyshev);
            assert_eq!(mono.lambda_star.to_bits(), cheb.lambda_star.to_bits(), "{kind}");
            assert_eq!(cheb.basis, PolyBasis::Chebyshev);
            assert!(cheb.label().contains("chebyshev"), "label {}", cheb.label());
            let a = mono.apply(&v);
            let b = cheb.apply(&v);
            let err = (&a - &b).max_abs();
            assert!(err < 1e-9, "{kind}: basis divergence {err}");
        }
    }

    #[test]
    fn chebyshev_op_deterministic_across_worker_counts() {
        let g = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 7 }).graph;
        let v = random_init(36, 4, 3);
        for kind in [
            TransformKind::TaylorNegExp { ell: 21 },
            TransformKind::LimitNegExp { ell: 31 },
        ] {
            let mk = |threads| {
                let opts = BuildOptions {
                    threads,
                    basis: PolyBasis::Chebyshev,
                    ..BuildOptions::default()
                };
                SparsePolyOp::from_graph(&g, kind, &opts).unwrap()
            };
            let serial = mk(1).apply(&v);
            for threads in [2usize, 8] {
                let par = mk(threads).apply(&v);
                let identical = serial
                    .data()
                    .iter()
                    .zip(par.data().iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(identical, "{kind} chebyshev diverged at {threads} workers");
            }
        }
    }

    #[test]
    fn adaptive_degree_op_matches_full_operator_with_fewer_sweeps() {
        use crate::transforms::{Degree, DomainEstimate};
        // The headline knob combination: tight Lanczos domain + tail
        // truncation realizes (nearly) the same matrix-free operator in a
        // fraction of the SpMM sweeps.
        let g = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 13 }).graph;
        let v = random_init(48, 6, 21);
        let kind = TransformKind::LimitNegExp { ell: 251 };
        let mk = |domain, degree| {
            let opts = BuildOptions {
                basis: PolyBasis::Chebyshev,
                domain,
                degree,
                ..BuildOptions::default()
            };
            SparsePolyOp::from_graph(&g, kind, &opts).unwrap()
        };
        let mut full = mk(DomainEstimate::Power, Degree::Native);
        let mut auto = mk(
            DomainEstimate::Lanczos,
            Degree::Auto { tol: 1e-9, max: usize::MAX },
        );
        assert_eq!(full.sweeps(), 251);
        assert!(
            auto.sweeps() * 2 <= full.sweeps(),
            "no ≥2× sweep reduction: {} vs {}",
            auto.sweeps(),
            full.sweeps()
        );
        // Tight domain is genuinely tighter than the Gershgorin-widened one.
        let (_, full_hi) = full.fit_domain().unwrap();
        let (auto_lo, auto_hi) = auto.fit_domain().unwrap();
        assert!(auto_hi - auto_lo < full_hi, "domain not tightened");
        // Same λ* (exactly 0 for the negexp family), near-identical action.
        assert_eq!(full.lambda_star, 0.0);
        assert_eq!(auto.lambda_star, 0.0);
        let a = full.apply(&v);
        let b = auto.apply(&v);
        let err = (&a - &b).max_abs();
        assert!(err < 1e-6, "adaptive operator divergence {err}");
        // The evaluated scalar map tracks the transform's map on the true
        // spectrum — the ≤1e-6 acceptance bound.
        let e = eigh(&g.laplacian()).unwrap();
        for &lam in &e.values {
            let err = (auto.poly_eval(lam) - kind.scalar_map(lam)).abs();
            assert!(err < 1e-6, "map error {err} at λ={lam}");
        }
        // Monomial forms have no fit domain; degree reshaping is rejected.
        let mono = SparsePolyOp::from_graph(&g, kind, &BuildOptions::default()).unwrap();
        assert!(mono.fit_domain().is_none());
        assert_eq!(mono.sweeps(), 251);
        let err = SparsePolyOp::from_graph(
            &g,
            kind,
            &BuildOptions {
                degree: Degree::Auto { tol: 1e-9, max: usize::MAX },
                ..BuildOptions::default()
            },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("--basis chebyshev"), "{err:#}");
    }

    #[test]
    fn mixed_op_tracks_f64_within_documented_budget() {
        // The f32 term of the error contract: for every polynomial form
        // (Horner, NegPower repeated-multiply, Chebyshev recurrence) the
        // mixed apply deviates from the f64 apply by at most
        // `mixed_budget()` relative to the bundle scale — and the mixed
        // path itself is bitwise worker-invariant.
        let g = cliques(&CliqueSpec { n: 40, k: 4, max_short_circuit: 3, seed: 13 }).graph;
        let v = random_init(40, 6, 21);
        for (kind, basis) in [
            (TransformKind::TaylorNegExp { ell: 31 }, PolyBasis::Monomial),
            (TransformKind::TaylorLog { ell: 41, eps: 0.05 }, PolyBasis::Monomial),
            (TransformKind::LimitNegExp { ell: 51 }, PolyBasis::Monomial),
            (TransformKind::LimitNegExp { ell: 51 }, PolyBasis::Chebyshev),
        ] {
            let mk = |precision, threads| {
                let opts = BuildOptions {
                    prescale: true,
                    basis,
                    precision,
                    threads,
                    ..BuildOptions::default()
                };
                SparsePolyOp::from_graph(&g, kind, &opts).unwrap()
            };
            let mut exact = mk(Precision::F64, 1);
            let mut mixed = mk(Precision::Mixed, 1);
            assert_eq!(exact.precision_floor(), 0.0, "{kind}: f64 op has no floor");
            assert!(mixed.precision_floor() > 0.0, "{kind}: mixed op must report a floor");
            assert_eq!(mixed.precision_floor(), mixed.mixed_budget(), "{kind}");
            assert!(mixed.label().contains("mixed"), "label {}", mixed.label());
            assert!(!exact.label().contains("mixed"), "label {}", exact.label());
            let want = exact.apply(&v);
            let got = mixed.apply(&v);
            let scale = want.max_abs().max(v.max_abs()).max(1.0);
            let err = (&got - &want).max_abs();
            assert!(
                err <= mixed.mixed_budget() * scale,
                "{kind}/{basis}: mixed error {err} exceeds budget {}",
                mixed.mixed_budget() * scale
            );
            for threads in [2usize, 8] {
                let par = mk(Precision::Mixed, threads).apply(&v);
                let identical = got
                    .data()
                    .iter()
                    .zip(par.data().iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(identical, "{kind}/{basis}: mixed diverged at {threads} workers");
            }
        }
    }

    #[test]
    fn mixed_precision_map_error_within_contract() {
        use crate::transforms::{Degree, DomainEstimate};
        // The `--degree auto --precision mixed` honesty contract: on the
        // true eigenvectors, the mixed operator's action deviates from the
        // ideal scalar map by at most the Chebyshev truncation tolerance
        // plus the documented f32 term.
        let g = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 13 }).graph;
        let e = eigh(&g.laplacian()).unwrap();
        let kind = TransformKind::LimitNegExp { ell: 251 };
        // Truncation-term bound: the adaptive-degree test establishes that
        // the tol=1e-9 truncated filter tracks the scalar map to ≤1e-6.
        let cheb_budget = 1e-6;
        let opts = BuildOptions {
            basis: PolyBasis::Chebyshev,
            domain: DomainEstimate::Lanczos,
            degree: Degree::Auto { tol: 1e-9, max: usize::MAX },
            precision: Precision::Mixed,
            ..BuildOptions::default()
        };
        let mut op = SparsePolyOp::from_graph(&g, kind, &opts).unwrap();
        assert!(op.sweeps() < 251, "auto degree should truncate");
        let k = 4;
        let v = e.bottom_k(k);
        let got = op.apply(&v);
        // Columns are unit eigenvectors: M·v_i = (λ* − p(λ_i))·v_i, so the
        // per-entry residual against the *truncated polynomial's* map is
        // pure mixed-arithmetic error; against the transform's scalar map
        // it additionally carries the truncation term the existing
        // adaptive-degree test bounds by 1e-6.
        for i in 0..k {
            let lam = e.values[i];
            let exact_want = op.lambda_star - op.poly_eval(lam);
            let map_want = op.lambda_star - kind.scalar_map(lam);
            let mut arith_err = 0.0f64;
            let mut map_err = 0.0f64;
            for r in 0..48 {
                arith_err = arith_err.max((got[(r, i)] - exact_want * v[(r, i)]).abs());
                map_err = map_err.max((got[(r, i)] - map_want * v[(r, i)]).abs());
            }
            assert!(
                arith_err <= op.mixed_budget(),
                "λ_{i}: arithmetic error {arith_err} exceeds f32 budget {}",
                op.mixed_budget()
            );
            assert!(
                map_err <= cheb_budget + op.mixed_budget(),
                "λ_{i}: map error {map_err} exceeds cheb-tol + f32 budget {}",
                cheb_budget + op.mixed_budget()
            );
        }
    }

    #[test]
    fn sparse_poly_op_drives_subspace_iteration_to_ground_truth() {
        // Matrix-free end-to-end at the solver level: the dilated sparse
        // operator recovers the exact bottom-k subspace of L.
        let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 1, seed: 5 }).graph;
        let v_star = eigh(&g.laplacian()).unwrap().bottom_k(3);
        let opts = BuildOptions::default();
        let mut op =
            SparsePolyOp::from_graph(&g, TransformKind::LimitNegExp { ell: 51 }, &opts).unwrap();
        assert_eq!(op.lambda_star, 0.0, "negexp family reverses with λ* = 0");
        let mut solver = SubspaceIteration;
        let cfg = RunConfig { steps: 500, eval_every: 10, ..Default::default() };
        let hist = run_convergence(&mut solver, &mut op, &v_star, &cfg);
        assert!(hist.last().unwrap().subspace_error < 1e-6);
        assert!(op.label().starts_with("sparse["));
        assert!(op.nnz() > 0);
    }

    #[test]
    fn run_steps_matches_metric_driver_trajectory() {
        // The ground-truth-free driver advances the identical trajectory —
        // same init, same steps — it just never measures.
        let (m, v_star) = fixture(TransformKind::NegExp, 2);
        let cfg = RunConfig { steps: 120, eval_every: 40, stop_error: 0.0, ..Default::default() };
        let mut op_a = DenseOp::new(m.clone());
        let mut op_b = DenseOp::new(m);
        let (_, with_metrics) =
            run_convergence_full(&mut SubspaceIteration, &mut op_a, &v_star, &cfg);
        let without = run_steps(&mut SubspaceIteration, &mut op_b, 2, 120, cfg.seed);
        assert!(with_metrics
            .data()
            .iter()
            .zip(without.data().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn random_init_is_orthonormal_and_deterministic() {
        let a = random_init(20, 4, 9);
        let b = random_init(20, 4, 9);
        assert!((&a - &b).max_abs() == 0.0);
        let g = matmul(&a.t(), &a);
        assert!((&g - &DMat::eye(4)).max_abs() < 1e-10);
    }

    #[test]
    fn solver_by_name_parses() {
        assert!(solver_by_name("oja", 0.1).is_ok());
        assert!(solver_by_name("mu-eg", 0.1).is_ok());
        assert!(solver_by_name("subspace", 0.1).is_ok());
        assert!(solver_by_name("nope", 0.1).is_err());
    }

    #[test]
    fn early_stop_honored() {
        let (m, v_star) = fixture(TransformKind::NegExp, 2);
        let mut op = DenseOp::new(m);
        let mut solver = SubspaceIteration;
        let cfg = RunConfig {
            steps: 100_000,
            eval_every: 5,
            stop_error: 1e-8,
            ..Default::default()
        };
        let hist = run_convergence(&mut solver, &mut op, &v_star, &cfg);
        assert!(hist.last().unwrap().step < 100_000, "early stop failed");
    }
}
