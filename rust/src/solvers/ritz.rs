//! Matrix-free block Rayleigh–Ritz subspace solver — the "subspace-iteration
//! end game" of ROADMAP item 1, in the Chebyshev–Davidson family (Pang &
//! Yang 2022): a polynomial spectral filter (here the SPED dilated operator
//! `M = λ*I − p(L)` itself) drives a filtered subspace iteration whose small
//! projected eigenproblem is solved exactly each sweep.
//!
//! Per outer iteration the solver costs exactly
//!
//! 1. **one** [`MatVecOp::apply`] bundle product `W = M·V` — for
//!    [`super::SparsePolyOp`] that is [`super::SparsePolyOp::sweeps`] fused
//!    SpMM passes and the only place the matrix is touched;
//! 2. one skinny orthonormalization ([`mgs_orthonormalize`], `O(n·b²)`);
//! 3. one `b×b` Rayleigh–Ritz solve via the dense [`eigh`] (`b ≪ n`).
//!
//! Memory is `O(n·b)`: no `n×n` allocation anywhere, so the solver composes
//! with `--op sparse --no-ground-truth` into a pipeline that is dense-free
//! end to end. Because every kernel it calls is worker-count invariant (the
//! `linalg::par`/`linalg::sparse` determinism contract) and the starting
//! block ([`deterministic_block`]) is a pure function of `(n, b)`, the
//! returned embedding is bitwise identical for every `threads` setting.
//!
//! Convergence semantics: the solver targets the **top**-k eigenpairs of
//! `M` — after eq 8's reversal these are the bottom-k of `L`, and the
//! eigengap dilation of §3 is precisely what widens the Ritz-value gaps
//! this iteration contracts by. Residuals `‖M·x − θ·x‖` are computed from
//! the already-available `W·Y` product (no extra operator application) and
//! honestly bound the eigenvalue error: for symmetric `M`, an eigenvalue of
//! `M` lies within `‖M·x − θ·x‖` of every returned `θ` (Weyl).

use crate::linalg::dmat::{norm, DMat};
use crate::linalg::eigh;
use crate::linalg::matmul::matmul;
use crate::linalg::qr::{mgs_orthonormalize, mgs_orthonormalize_against};
use crate::solvers::MatVecOp;
use anyhow::{bail, Result};

/// Convergence knobs for [`ritz_solve`] (CLI: `--ritz-tol`,
/// `--ritz-max-iters`, `--block-size`).
#[derive(Clone, Debug)]
pub struct RitzConfig {
    /// Wanted eigenpairs = embedding columns (pipeline `k`).
    pub k: usize,
    /// Block width `b` (`0` = auto: `k + 2` guard vectors, clamped to `n`).
    /// Guard vectors tighten the effective convergence ratio from
    /// `θ_{k+1}/θ_k` to `θ_{b+1}/θ_k`.
    pub block: usize,
    /// Relative residual tolerance: converged once
    /// `max_{i≤k} ‖M·x_i − θ_i·x_i‖ ≤ tol · ρ̂(M)` with `ρ̂(M) = max|θ|`
    /// over the current block — scale-free, so "equal tolerance" is
    /// comparable across dilated and undilated operators whose spectral
    /// scales differ by orders of magnitude.
    pub tol: f64,
    /// Outer-iteration cap (each cap unit is one bundle apply).
    pub max_iters: usize,
    /// Seed the starting block from a previous solve's Ritz vectors
    /// instead of the hash-seeded [`deterministic_block`] (see
    /// [`RitzConfig::warm_start`]). `None` = cold start.
    pub warm_start: Option<DMat>,
    /// Bail with [`SolveFailure::Stagnation`] after this many consecutive
    /// outer iterations with no strict residual improvement (`0`
    /// disables). Strict comparison means a slowly-but-genuinely
    /// converging run never trips it; only a frozen iteration — an
    /// operator whose image stopped depending on the basis — does.
    pub stagnation_window: usize,
    /// Locked-convergence deflation (`--ritz-lock on|off`, **default on**):
    /// per outer iteration, freeze the maximal leading prefix of wanted
    /// Ritz pairs whose residual is at tolerance into a locked panel, and
    /// apply the operator only to the shrinking active block (orthogonalized
    /// against the panel each sweep) — so SpMM *column* volume per sweep
    /// decays as pairs converge instead of staying at `b`. Until the first
    /// pair locks the trajectory is bitwise identical to `lock = false`;
    /// locked solves report the savings in [`RitzResult::col_sweeps`] /
    /// [`RitzResult::locked_history`]. `false` restores the fixed-block
    /// iteration exactly.
    pub lock: bool,
}

impl Default for RitzConfig {
    fn default() -> Self {
        RitzConfig {
            k: 4,
            block: 0,
            tol: 1e-8,
            max_iters: 500,
            warm_start: None,
            stagnation_window: 100,
            lock: true,
        }
    }
}

impl RitzConfig {
    /// Builder: warm-start from a previous solve's embedding (`n×k`
    /// Ritz vectors, any column count ≥ 1). The columns are copied into
    /// the leading block positions, guard columns are refilled from the
    /// deterministic hash stream, and the whole block is re-orthonormalized
    /// through [`mgs_orthonormalize`] — whose deterministic rescue path
    /// absorbs rank-deficient or duplicate warm columns. Iteration and
    /// sweep accounting is identical to a cold solve, so a warm-vs-cold
    /// comparison of [`RitzResult::iterations`] is honest.
    pub fn warm_start(mut self, prev: DMat) -> RitzConfig {
        self.warm_start = Some(prev);
        self
    }
}

/// Structured failure from [`ritz_solve`]: the solver detected that
/// continuing to `max_iters` cannot help (poisoned arithmetic or a frozen
/// iteration) and bailed early. Callers that can degrade — e.g. the
/// pipeline's warm-start fall-back — downcast with
/// `err.downcast_ref::<SolveFailure>()` and rerun cold.
#[derive(Clone, Debug, PartialEq)]
pub enum SolveFailureKind {
    /// A Ritz value, residual, or projected Rayleigh-quotient entry went
    /// NaN/Inf — the operator output is poisoned.
    NonFinite,
    /// No strict residual improvement for `stagnation_window` consecutive
    /// outer iterations.
    Stagnation,
}

/// See [`SolveFailureKind`]. Carries honest partial accounting: how many
/// outer iterations and SpMM sweeps were spent before bailing, so
/// fall-back paths can report the true total cost.
#[derive(Clone, Debug)]
pub struct SolveFailure {
    pub kind: SolveFailureKind,
    /// Outer iteration (1-based) at which the failure was detected.
    pub iteration: usize,
    /// Last observed `max_{i≤k}` residual (may be NaN for `NonFinite`).
    pub max_residual: f64,
    /// SpMM sweeps spent before bailing.
    pub sweeps: usize,
}

impl std::fmt::Display for SolveFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.kind {
            SolveFailureKind::NonFinite => write!(
                f,
                "ritz: non-finite Ritz state at outer iteration {} (residual {}, {} sweeps spent)",
                self.iteration, self.max_residual, self.sweeps
            ),
            SolveFailureKind::Stagnation => write!(
                f,
                "ritz: stagnated at outer iteration {} (residual {} frozen, {} sweeps spent)",
                self.iteration, self.max_residual, self.sweeps
            ),
        }
    }
}

impl std::error::Error for SolveFailure {}

/// One recorded outer iteration of [`ritz_solve`].
#[derive(Clone, Debug)]
pub struct RitzIter {
    /// Outer-iteration index (1-based).
    pub iter: usize,
    /// `max_{i≤k} ‖M·x_i − θ_i·x_i‖` over the wanted Ritz pairs (absolute).
    pub max_residual: f64,
    /// Cumulative SpMM sweeps through this iteration.
    pub sweeps: usize,
}

/// The converged (or capped) state [`ritz_solve`] returns.
#[derive(Clone, Debug)]
pub struct RitzResult {
    /// `n×k` Ritz vectors, columns ordered by Ritz value of `M`
    /// **descending** — i.e. bottom-k of `L` first, the embedding
    /// convention of the rest of the crate.
    pub embedding: DMat,
    /// Ritz values of `M` for the embedding columns (descending).
    pub values: Vec<f64>,
    /// Final per-pair absolute residual norms `‖M·x_i − θ_i·x_i‖`.
    pub residuals: Vec<f64>,
    /// Per-outer-iteration residual/sweep history.
    pub history: Vec<RitzIter>,
    /// Outer iterations executed (= bundle applies).
    pub iterations: usize,
    /// Whether the tolerance was met before `max_iters`.
    pub converged: bool,
    /// SpMM sweeps one operator application costs
    /// ([`MatVecOp::sweeps_per_apply`]).
    pub sweeps_per_apply: usize,
    /// `iterations · sweeps_per_apply`.
    pub total_sweeps: usize,
    /// Ritz pairs frozen in the locked panel when the solve finished
    /// (`= k` for a converged locked solve; `0` with `lock = false`).
    pub locked: usize,
    /// Locked-pair count after each outer iteration's locking step —
    /// `history`-aligned, monotone non-decreasing, all zeros with
    /// `lock = false`.
    pub locked_history: Vec<usize>,
    /// SpMM **column** sweeps actually spent:
    /// `Σ_iterations active_width · sweeps_per_apply`. Equals
    /// `total_sweeps · b` for a fixed block; strictly smaller once pairs
    /// lock — the honest unit for the deflation win (`total_sweeps`
    /// deliberately keeps counting bundle applies).
    pub col_sweeps: usize,
    /// Halo bundle-row volume exchanged by a sharded operator:
    /// `Σ_iterations halo_rows · sweeps_per_apply · active_width`
    /// ([`MatVecOp::halo_rows_per_sweep`]); `0` for unsharded operators.
    pub halo_volume: usize,
}

/// Deterministic `n×b` orthonormal starting block, a pure function of
/// `(n, b)` — reproducible across runs and bitwise identical for every
/// worker count. Column 0 is the shared [`crate::linalg::par`]
/// `deterministic_start` vector (near-constant, already well aligned with
/// the Laplacian kernel inside the wanted bottom subspace); the remaining
/// columns are SplitMix64 index hashes, orthonormalized against it.
pub fn deterministic_block(n: usize, b: usize) -> DMat {
    let c0 = crate::linalg::par::deterministic_start(n);
    let mut v = DMat::from_fn(n, b, |i, j| if j == 0 { c0[i] } else { hash_entry(i, j) });
    mgs_orthonormalize(&mut v);
    v
}

/// The SplitMix64 guard-column entry shared by [`deterministic_block`] and
/// the warm-start block, so warm guard columns come from the same
/// deterministic stream as cold ones.
fn hash_entry(i: usize, j: usize) -> f64 {
    let mut s = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (j as u64).wrapping_mul(0xD1B5_4A32_D192_ED03);
    let h = crate::util::rng::splitmix64(&mut s);
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64) - 0.5
}

/// Starting block for a warm-started solve: previous Ritz vectors in the
/// leading columns, deterministic hash guards in the rest, MGS2-cleaned
/// (the rescue path inside [`mgs_orthonormalize`] replaces any
/// rank-deficient warm column deterministically).
fn warm_block(prev: &DMat, n: usize, b: usize) -> Result<DMat> {
    if prev.rows() != n {
        bail!("ritz: warm-start block has {} rows for n = {n}", prev.rows());
    }
    if prev.cols() == 0 {
        bail!("ritz: warm-start block has no columns");
    }
    if prev.data().iter().any(|x| !x.is_finite()) {
        bail!("ritz: warm-start block contains non-finite entries");
    }
    let pc = prev.cols().min(b);
    let mut v =
        DMat::from_fn(n, b, |i, j| if j < pc { prev[(i, j)] } else { hash_entry(i, j) });
    mgs_orthonormalize(&mut v);
    Ok(v)
}

/// Extract the top-k eigenpairs of `op` (= bottom-k of `L` when `op` is the
/// reversed SPED operator) by filtered subspace iteration with an exact
/// Rayleigh–Ritz projection each sweep.
///
/// Loop shape per outer iteration `t`, with `V` the current orthonormal
/// `n×b` basis:
///
/// ```text
/// W  = M·V                     // 1 bundle apply — the only matrix touch
/// H  = VᵀW (symmetrized)       // b×b Rayleigh quotient
/// HY = Y·diag(θ)               // dense eigh, ascending θ
/// X  = V·Y_top  ,  M·X = W·Y_top   // Ritz pairs; residual R = M·X − X·diag(θ)
/// V ← orth(W)                  // the filtered block becomes the next basis
/// ```
///
/// Residuals come from the already-computed `W`, so measuring convergence
/// adds no operator applications. The final `X` (not the raw basis) is the
/// returned embedding: Rayleigh–Ritz aligns its columns with the individual
/// eigenvectors, not an arbitrary rotation of the subspace.
pub fn ritz_solve(op: &mut dyn MatVecOp, cfg: &RitzConfig) -> Result<RitzResult> {
    let n = op.dim();
    let k = cfg.k;
    if k == 0 || k > n {
        bail!("ritz: k={k} out of range for n={n}");
    }
    let b = if cfg.block == 0 { (k + 2).min(n) } else { cfg.block };
    if b < k || b > n {
        bail!("ritz: block size {b} must satisfy k={k} <= block <= n={n}");
    }
    if cfg.max_iters == 0 {
        bail!("ritz: max_iters must be >= 1");
    }
    if !(cfg.tol > 0.0) {
        bail!("ritz: tol must be > 0");
    }
    let sweeps_per_apply = op.sweeps_per_apply();
    let halo_per_sweep = op.halo_rows_per_sweep();
    // Clamp the tolerance to the operator's arithmetic floor
    // ([`MatVecOp::precision_floor`]): a mixed-precision operator cannot
    // certify residuals below its documented f32 budget, so a tighter
    // requested tol would spin to `max_iters` on arithmetic noise. Zero
    // for full-precision operators — the clamp is then a no-op.
    let tol = cfg.tol.max(op.precision_floor());
    let mut v = match &cfg.warm_start {
        Some(prev) => warm_block(prev, n, b)?,
        None => deterministic_block(n, b),
    };
    let mut history: Vec<RitzIter> = Vec::new();
    let mut embedding = DMat::zeros(n, k);
    let mut values = vec![0.0; k];
    let mut residuals = vec![f64::INFINITY; k];
    // The locked panel (soft locking): Ritz vectors frozen at their
    // lock-time values/residuals. Empty until the first pair converges,
    // and permanently empty with `lock = false` — in both states the loop
    // below is bitwise-identical to the historical fixed-block iteration.
    let mut locked_vecs: Vec<Vec<f64>> = Vec::new();
    let mut locked_vals: Vec<f64> = Vec::new();
    let mut locked_res: Vec<f64> = Vec::new();
    let mut locked_history: Vec<usize> = Vec::new();
    let mut col_sweeps = 0usize;
    let mut halo_volume = 0usize;
    let mut iterations = 0;
    let mut converged = false;
    let mut best_res = f64::INFINITY;
    let mut stagnant = 0usize;
    for it in 1..=cfg.max_iters {
        iterations = it;
        // Active block width: b minus the locked panel. Invariant:
        // ba − k_rem = b − k ≥ 0, so ba ≥ 1 whenever pairs remain wanted.
        let ba = v.cols();
        let k_rem = k - locked_vals.len();
        let w = op.apply(&v);
        // Honest per-column accounting: this apply cost ba columns ×
        // sweeps_per_apply SpMM sweeps (and, when sharded, that many
        // halo-row bundles exchanged). `total_sweeps` keeps counting whole
        // bundle applies — `col_sweeps` is where deflation shows up.
        col_sweeps += ba * sweeps_per_apply;
        halo_volume += halo_per_sweep * sweeps_per_apply * ba;
        // Rayleigh–Ritz on span(V): H = VᵀMV, symmetrized so eigh sees an
        // exactly-symmetric input regardless of fp round-off in the product.
        let mut h = matmul(&v.t(), &w);
        h.symmetrize();
        // Poisoned operator output shows up here first (ba×ba, so the scan
        // is free relative to the bundle product): bail with a structured
        // failure instead of feeding NaN to eigh and looping to the cap.
        if h.data().iter().any(|x| !x.is_finite()) {
            return Err(SolveFailure {
                kind: SolveFailureKind::NonFinite,
                iteration: it,
                max_residual: history.last().map_or(f64::NAN, |p| p.max_residual),
                sweeps: it * sweeps_per_apply,
            }
            .into());
        }
        let e = eigh(&h)?;
        // Full active rotation, θ descending (eigh orders ascending):
        // X = V·Y are the active Ritz vectors and M·X = W·Y their images —
        // residuals and the next basis both read off these products, no
        // further operator application. (The guard columns ride along;
        // widening Y beyond the wanted k changes no bits of the leading
        // columns — `matmul` reduces each output element in the same
        // ascending-k order at every output width.)
        let y = DMat::from_fn(ba, ba, |r, c| e.vectors[(r, ba - 1 - c)]);
        let x = matmul(&v, &y);
        let xw = matmul(&w, &y);
        let active_vals: Vec<f64> = (0..ba).map(|c| e.values[ba - 1 - c]).collect();
        // Residuals of the wanted (leading k_rem) active pairs.
        let mut active_res = vec![0.0f64; k_rem];
        for c in 0..k_rem {
            let theta = active_vals[c];
            let mut col = xw.col(c);
            for (row, cv) in col.iter_mut().enumerate() {
                *cv -= theta * x[(row, c)];
            }
            active_res[c] = norm(&col);
        }
        // ρ̂(M) from the locked ∪ active Ritz values (θ_max ≤ ρ(M), tight
        // once the leading pair has locked in — which the near-kernel
        // start column makes immediate for reversed Laplacian operators).
        let scale = locked_vals
            .iter()
            .chain(e.values.iter())
            .fold(0.0f64, |m, &t| m.max(t.abs()))
            .max(1e-300);
        // Deflation step: freeze the maximal leading prefix of wanted
        // active pairs at tolerance. Prefix-only locking keeps the locked
        // θ sequence descending and never locks past an unconverged pair.
        let mut p = 0usize;
        if cfg.lock {
            while p < k_rem && active_res[p] <= tol * scale {
                p += 1;
            }
        }
        // Assemble the k reported pairs — frozen locked + fresh leading
        // active — sorted by θ descending (stable, so the already-ordered
        // unlocked case is untouched bit for bit).
        let ll = locked_vals.len();
        let theta_of = |i: usize| if i < ll { locked_vals[i] } else { active_vals[i - ll] };
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&ia, &ib| {
            theta_of(ib).partial_cmp(&theta_of(ia)).unwrap_or(std::cmp::Ordering::Equal)
        });
        for (dst, &src) in order.iter().enumerate() {
            values[dst] = theta_of(src);
            residuals[dst] = if src < ll { locked_res[src] } else { active_res[src - ll] };
            for row in 0..n {
                embedding[(row, dst)] =
                    if src < ll { locked_vecs[src][row] } else { x[(row, src - ll)] };
            }
        }
        let max_res = residuals.iter().fold(0.0f64, |m, &r| m.max(r));
        // Residuals are norms of real vectors, so NaN here means the
        // arithmetic itself is poisoned (NaN compares false, so the fold
        // above can silently drop it — scan explicitly).
        if residuals.iter().any(|r| !r.is_finite()) || values.iter().any(|t| !t.is_finite()) {
            return Err(SolveFailure {
                kind: SolveFailureKind::NonFinite,
                iteration: it,
                max_residual: max_res,
                sweeps: it * sweeps_per_apply,
            }
            .into());
        }
        history.push(RitzIter {
            iter: it,
            max_residual: max_res,
            sweeps: it * sweeps_per_apply,
        });
        // Commit the freshly locked prefix (vectors, values and residuals
        // freeze at lock time — soft locking).
        for c in 0..p {
            locked_vecs.push(x.col(c));
            locked_vals.push(active_vals[c]);
            locked_res.push(active_res[c]);
        }
        locked_history.push(locked_vals.len());
        // Convergence: a locked solve is done once all k wanted pairs sit
        // in the panel (p = k_rem requires every leading residual at
        // tolerance — exactly the fixed-block `max_res ≤ tol·ρ̂` criterion
        // when nothing was locked before).
        if cfg.lock {
            if locked_vals.len() >= k {
                converged = true;
                break;
            }
        } else if max_res <= tol * scale {
            converged = true;
            break;
        }
        if max_res < best_res {
            best_res = max_res;
            stagnant = 0;
        } else {
            stagnant += 1;
            if cfg.stagnation_window > 0 && stagnant >= cfg.stagnation_window {
                return Err(SolveFailure {
                    kind: SolveFailureKind::Stagnation,
                    iteration: it,
                    max_residual: max_res,
                    sweeps: it * sweeps_per_apply,
                }
                .into());
            }
        }
        if it < cfg.max_iters {
            if locked_vecs.is_empty() {
                // Filtered subspace-iteration step: the next basis is the
                // orthonormalized image orth(M·V). Rank-deficient images
                // (the filter annihilating guard directions) are rescued
                // deterministically inside the orthonormalizer. This is
                // the historical fixed-block update, taken verbatim until
                // the first pair locks.
                let mut next = w;
                mgs_orthonormalize(&mut next);
                v = next;
            } else {
                // Shrunken active block: drop the p freshly locked leading
                // columns of the rotated image M·X (they carry the locked
                // directions) and re-orthonormalize the remainder against
                // the locked panel — MGS2 with the shared deterministic
                // rescue path, so the active block stays an orthonormal
                // complement of the panel every sweep.
                let mut next = DMat::from_fn(n, ba - p, |r, c| xw[(r, p + c)]);
                mgs_orthonormalize_against(&locked_vecs, &mut next);
                v = next;
            }
        }
    }
    let total_sweeps = iterations * sweeps_per_apply;
    Ok(RitzResult {
        embedding,
        values,
        residuals,
        history,
        iterations,
        converged,
        sweeps_per_apply,
        total_sweeps,
        locked: locked_vals.len(),
        locked_history,
        col_sweeps,
        halo_volume,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, CliqueSpec};
    use crate::linalg::metrics::subspace_error;
    use crate::solvers::{DenseOp, MatVecOp, SparsePolyOp};
    use crate::transforms::{build_solver_matrix, BuildOptions, TransformKind};

    #[test]
    fn deterministic_block_is_orthonormal_and_pure() {
        for (n, b) in [(20usize, 5usize), (7, 7), (64, 3)] {
            let v = deterministic_block(n, b);
            let g = matmul(&v.t(), &v);
            assert!((&g - &DMat::eye(b)).max_abs() < 1e-10, "n={n} b={b}");
            let again = deterministic_block(n, b);
            assert!(v
                .data()
                .iter()
                .zip(again.data().iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn ritz_recovers_bottom_k_on_dilated_clique_graph() {
        let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 1, seed: 5 }).graph;
        let v_star = crate::linalg::eigh(&g.laplacian()).unwrap().bottom_k(3);
        let mut op = SparsePolyOp::from_graph(
            &g,
            TransformKind::LimitNegExp { ell: 51 },
            &BuildOptions::default(),
        )
        .unwrap();
        let cfg = RitzConfig { k: 3, tol: 1e-10, max_iters: 300, ..Default::default() };
        let res = ritz_solve(&mut op, &cfg).unwrap();
        assert!(res.converged, "not converged in {} iters", res.iterations);
        assert!(res.iterations >= 1 && res.iterations <= 300);
        let err = subspace_error(&v_star, &res.embedding);
        assert!(err < 1e-8, "subspace err {err}");
        // Ritz values descend, and sweeps accounting is consistent.
        for w in res.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12, "values not descending: {:?}", res.values);
        }
        assert_eq!(res.sweeps_per_apply, op.sweeps());
        assert_eq!(res.total_sweeps, res.iterations * res.sweeps_per_apply);
        assert_eq!(res.history.len(), res.iterations);
        assert_eq!(res.history.last().unwrap().sweeps, res.total_sweeps);
    }

    #[test]
    fn ritz_matches_dense_operator_path() {
        // Same transform realized dense and matrix-free: both operator
        // shapes drive the solver to the same subspace.
        let g = cliques(&CliqueSpec { n: 30, k: 3, max_short_circuit: 2, seed: 9 }).graph;
        let kind = TransformKind::LimitNegExp { ell: 51 };
        let sm = build_solver_matrix(&g.laplacian(), kind, &BuildOptions::default()).unwrap();
        let cfg = RitzConfig { k: 3, tol: 1e-10, max_iters: 300, ..Default::default() };
        let mut dense = DenseOp::new(sm.m);
        let mut sparse = SparsePolyOp::from_graph(&g, kind, &BuildOptions::default()).unwrap();
        assert_eq!(dense.sweeps_per_apply(), 1);
        let a = ritz_solve(&mut dense, &cfg).unwrap();
        let b = ritz_solve(&mut sparse, &cfg).unwrap();
        assert!(a.converged && b.converged);
        let err = subspace_error(&a.embedding, &b.embedding);
        assert!(err < 1e-8, "dense vs sparse ritz err {err}");
    }

    #[test]
    fn ritz_handles_full_width_block_and_rejects_bad_config() {
        let g = cliques(&CliqueSpec { n: 10, k: 2, max_short_circuit: 1, seed: 3 }).graph;
        let mk = || {
            SparsePolyOp::from_graph(
                &g,
                TransformKind::LimitNegExp { ell: 31 },
                &BuildOptions::default(),
            )
            .unwrap()
        };
        // k = n forces block = n (auto clamp): a single Rayleigh–Ritz pass
        // diagonalizes everything.
        let cfg = RitzConfig { k: 10, tol: 1e-9, max_iters: 50, ..Default::default() };
        let res = ritz_solve(&mut mk(), &cfg).unwrap();
        assert!(res.converged);
        assert_eq!(res.embedding.cols(), 10);
        for bad in [
            RitzConfig { k: 0, ..Default::default() },
            RitzConfig { k: 11, ..Default::default() },
            RitzConfig { k: 4, block: 2, ..Default::default() },
            RitzConfig { k: 4, block: 11, ..Default::default() },
            RitzConfig { k: 4, max_iters: 0, ..Default::default() },
            RitzConfig { k: 4, tol: 0.0, ..Default::default() },
        ] {
            assert!(ritz_solve(&mut mk(), &bad).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn warm_start_converges_faster_and_stays_deterministic() {
        let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 1, seed: 5 }).graph;
        let mk = || {
            SparsePolyOp::from_graph(
                &g,
                TransformKind::LimitNegExp { ell: 51 },
                &BuildOptions::default(),
            )
            .unwrap()
        };
        let cold_cfg = RitzConfig { k: 3, tol: 1e-10, max_iters: 300, ..Default::default() };
        let cold = ritz_solve(&mut mk(), &cold_cfg).unwrap();
        assert!(cold.converged && cold.iterations > 1);
        // Warm-starting from the converged embedding must beat the cold
        // iteration count under identical accounting.
        let warm_cfg = cold_cfg.clone().warm_start(cold.embedding.clone());
        let warm = ritz_solve(&mut mk(), &warm_cfg).unwrap();
        assert!(warm.converged);
        assert!(
            warm.iterations < cold.iterations,
            "warm {} !< cold {}",
            warm.iterations,
            cold.iterations
        );
        assert_eq!(warm.total_sweeps, warm.iterations * warm.sweeps_per_apply);
        // Warm solves are as reproducible as cold ones: bitwise.
        let warm2 = ritz_solve(&mut mk(), &warm_cfg).unwrap();
        assert!(warm
            .embedding
            .data()
            .iter()
            .zip(warm2.embedding.data().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        // Degenerate warm blocks are rejected up front...
        assert!(ritz_solve(&mut mk(), &cold_cfg.clone().warm_start(DMat::zeros(5, 3))).is_err());
        assert!(ritz_solve(&mut mk(), &cold_cfg.clone().warm_start(DMat::zeros(24, 0))).is_err());
        let mut poisoned = DMat::zeros(24, 3);
        poisoned[(0, 0)] = f64::NAN;
        assert!(ritz_solve(&mut mk(), &cold_cfg.clone().warm_start(poisoned)).is_err());
        // ...but rank-deficient (duplicate-column) warm blocks ride the
        // MGS rescue path and still converge.
        let dup = DMat::from_fn(24, 3, |i, _| cold.embedding[(i, 0)]);
        let rescued = ritz_solve(&mut mk(), &cold_cfg.clone().warm_start(dup)).unwrap();
        assert!(rescued.converged);
    }

    struct PoisonOp {
        n: usize,
    }
    impl crate::solvers::MatVecOp for PoisonOp {
        fn apply(&mut self, v: &DMat) -> DMat {
            DMat::from_fn(v.rows(), v.cols(), |_, _| f64::NAN)
        }
        fn dim(&self) -> usize {
            self.n
        }
    }

    struct FrozenOp {
        c: DMat,
    }
    impl crate::solvers::MatVecOp for FrozenOp {
        fn apply(&mut self, _v: &DMat) -> DMat {
            self.c.clone()
        }
        fn dim(&self) -> usize {
            self.c.rows()
        }
    }

    #[test]
    fn nan_operator_fails_fast_with_structured_failure() {
        let mut op = PoisonOp { n: 16 };
        let cfg = RitzConfig { k: 3, max_iters: 500, ..Default::default() };
        let err = ritz_solve(&mut op, &cfg).unwrap_err();
        let f = err.downcast_ref::<SolveFailure>().expect("SolveFailure");
        assert_eq!(f.kind, SolveFailureKind::NonFinite);
        // Fails on the first poisoned iteration, not after looping to the cap.
        assert_eq!(f.iteration, 1);
        assert_eq!(f.sweeps, f.iteration * op.sweeps_per_apply());
    }

    #[test]
    fn frozen_iteration_trips_stagnation_detector() {
        // An operator whose image ignores the basis: every iteration from
        // the second onward is bitwise identical, so the residual freezes.
        let c = DMat::from_fn(12, 4, |i, j| super::hash_entry(i, j + 1));
        let mut op = FrozenOp { c };
        let cfg = RitzConfig {
            k: 2,
            block: 4,
            tol: 1e-12,
            max_iters: 200,
            stagnation_window: 5,
            ..Default::default()
        };
        let err = ritz_solve(&mut op, &cfg).unwrap_err();
        let f = err.downcast_ref::<SolveFailure>().expect("SolveFailure");
        assert_eq!(f.kind, SolveFailureKind::Stagnation);
        assert!(f.iteration < 20, "stagnation not detected early: {}", f.iteration);
        assert!(f.max_residual.is_finite() && f.max_residual > 0.0);
    }

    #[test]
    fn mixed_operator_converges_via_precision_floor_clamp() {
        use crate::transforms::Precision;
        let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 1, seed: 5 }).graph;
        let opts = BuildOptions { precision: Precision::Mixed, ..BuildOptions::default() };
        let mut op =
            SparsePolyOp::from_graph(&g, TransformKind::LimitNegExp { ell: 51 }, &opts).unwrap();
        assert!(op.precision_floor() > 0.0);
        // tol far below the f32 floor: without the clamp this run would
        // grind on arithmetic noise it can never certify; with it,
        // convergence is declared at the operator's documented floor.
        let cfg = RitzConfig { k: 3, tol: 1e-14, max_iters: 300, ..Default::default() };
        let res = ritz_solve(&mut op, &cfg).unwrap();
        assert!(res.converged, "mixed run did not converge in {} iters", res.iterations);
        // The embedding still recovers the bottom subspace to well beyond
        // clustering accuracy.
        let v_star = crate::linalg::eigh(&g.laplacian()).unwrap().bottom_k(3);
        let err = subspace_error(&v_star, &res.embedding);
        assert!(err < 1e-2, "subspace err {err}");
    }

    #[test]
    fn locked_solve_matches_fixed_block_with_fewer_column_sweeps() {
        let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 1, seed: 5 }).graph;
        let mk = || {
            SparsePolyOp::from_graph(
                &g,
                TransformKind::LimitNegExp { ell: 51 },
                &BuildOptions::default(),
            )
            .unwrap()
        };
        let locked_cfg = RitzConfig { k: 3, tol: 1e-10, max_iters: 300, ..Default::default() };
        let fixed_cfg = RitzConfig { lock: false, ..locked_cfg.clone() };
        let locked = ritz_solve(&mut mk(), &locked_cfg).unwrap();
        let fixed = ritz_solve(&mut mk(), &fixed_cfg).unwrap();
        assert!(locked.converged && fixed.converged);
        // Same subspace, honest bookkeeping on both sides.
        let err = subspace_error(&fixed.embedding, &locked.embedding);
        assert!(err < 1e-8, "locked vs fixed subspace err {err}");
        assert_eq!(locked.locked, 3);
        assert_eq!(fixed.locked, 0);
        let b = 5; // auto block: k + 2
        assert_eq!(fixed.col_sweeps, fixed.total_sweeps * b);
        assert!(fixed.locked_history.iter().all(|&l| l == 0));
        assert_eq!(fixed.halo_volume, 0);
        // Deflation must have spent strictly fewer SpMM columns.
        assert!(
            locked.col_sweeps < fixed.col_sweeps,
            "locked {} !< fixed {}",
            locked.col_sweeps,
            fixed.col_sweeps
        );
        // locked_history is history-aligned, monotone, and ends at k.
        assert_eq!(locked.locked_history.len(), locked.history.len());
        assert!(locked.locked_history.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*locked.locked_history.last().unwrap(), 3);
        // col_sweeps is exactly the per-iteration active-width sum.
        let mut want_cols = 0;
        for t in 0..locked.iterations {
            let before = if t == 0 { 0 } else { locked.locked_history[t - 1] };
            want_cols += (b - before) * locked.sweeps_per_apply;
        }
        assert_eq!(locked.col_sweeps, want_cols);
        // Values still descend after the locked/active merge.
        for w in locked.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12, "values not descending: {:?}", locked.values);
        }
    }

    #[test]
    fn locked_warm_start_and_block_size_compose() {
        let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 1, seed: 5 }).graph;
        let mk = || {
            SparsePolyOp::from_graph(
                &g,
                TransformKind::LimitNegExp { ell: 51 },
                &BuildOptions::default(),
            )
            .unwrap()
        };
        // Custom block width: locking still converges and accounts in
        // units of the configured width.
        let wide = RitzConfig { k: 3, block: 6, tol: 1e-10, max_iters: 300, ..Default::default() };
        let res = ritz_solve(&mut mk(), &wide).unwrap();
        assert!(res.converged);
        assert_eq!(res.locked, 3);
        let mut want_cols = 0;
        for t in 0..res.iterations {
            let before = if t == 0 { 0 } else { res.locked_history[t - 1] };
            want_cols += (6 - before) * res.sweeps_per_apply;
        }
        assert_eq!(res.col_sweeps, want_cols);
        // Warm-starting from the converged embedding locks everything on
        // the first sweep: one full-width apply, then done.
        let warm = RitzConfig {
            k: 3,
            tol: 1e-10,
            max_iters: 300,
            ..Default::default()
        }
        .warm_start(res.embedding.clone());
        let w = ritz_solve(&mut mk(), &warm).unwrap();
        assert!(w.converged);
        assert_eq!(w.iterations, 1);
        assert_eq!(w.locked, 3);
        assert_eq!(w.col_sweeps, 5 * w.sweeps_per_apply);
        // Locked warm solves stay bitwise-reproducible.
        let w2 = ritz_solve(&mut mk(), &warm).unwrap();
        assert!(w
            .embedding
            .data()
            .iter()
            .zip(w2.embedding.data().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn sharded_operator_reports_halo_volume_and_stays_bitwise() {
        let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 1, seed: 5 }).graph;
        let kind = TransformKind::LimitNegExp { ell: 51 };
        let cfg = RitzConfig { k: 3, tol: 1e-10, max_iters: 300, ..Default::default() };
        let mut plain =
            SparsePolyOp::from_graph(&g, kind, &BuildOptions::default()).unwrap();
        let base = ritz_solve(&mut plain, &cfg).unwrap();
        assert_eq!(base.halo_volume, 0);
        for shards in [2usize, 7] {
            let opts = BuildOptions { shards, ..BuildOptions::default() };
            let mut op = SparsePolyOp::from_graph(&g, kind, &opts).unwrap();
            let halo = op.halo_rows();
            let res = ritz_solve(&mut op, &cfg).unwrap();
            // Sharded solves are bitwise-equal to unsharded — identical
            // trajectory, identical embedding.
            assert_eq!(res.iterations, base.iterations, "S={shards}");
            assert!(res
                .embedding
                .data()
                .iter()
                .zip(base.embedding.data().iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()));
            // Halo accounting: halo_rows bundle rows per sweep per column.
            assert_eq!(res.halo_volume, halo * res.col_sweeps, "S={shards}");
            assert!(res.halo_volume > 0);
        }
    }

    #[test]
    fn unconverged_run_reports_honestly() {
        let g = cliques(&CliqueSpec { n: 24, k: 3, max_short_circuit: 1, seed: 5 }).graph;
        let mut op = SparsePolyOp::from_graph(
            &g,
            TransformKind::Identity,
            &BuildOptions::default(),
        )
        .unwrap();
        // One iteration at an unreachable tolerance: must come back with
        // converged = false and a positive residual, not a panic or a lie.
        let cfg = RitzConfig { k: 3, tol: 1e-300, max_iters: 1, ..Default::default() };
        let res = ritz_solve(&mut op, &cfg).unwrap();
        assert!(!res.converged);
        assert_eq!(res.iterations, 1);
        assert!(res.history[0].max_residual > 0.0);
    }
}
