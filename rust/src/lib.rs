//! # SPED — Stochastic Parallelizable Eigengap Dilation
//!
//! A production-grade reproduction of *"Stochastic Parallelizable Eigengap
//! Dilation for Large Graph Clustering"* (van der Pol, Gemp, Bachrach,
//! Everett; ICML 2022 TAG-ML workshop).
//!
//! SPED accelerates the computation of the bottom-`k` eigenvectors of a
//! graph Laplacian — the core of spectral clustering — by applying cheap,
//! eigenvector-preserving spectral transformations (matrix polynomials that
//! approximate e.g. `−e^{−L}` or `log(L+εI)`) which *dilate the eigengaps*
//! relative to the spectral radius before the matrix is handed to an
//! iterative stochastic SVD solver (Oja's algorithm, µ-EigenGame).
//!
//! The crate is the **L3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for the blocked
//!   Horner step and the stochastic walk-batch apply.
//! * **L2** — JAX compute graphs (`python/compile/model.py`) lowered once,
//!   AOT, to HLO text artifacts (`make artifacts`).
//! * **L3** — this crate: graph substrate, random-walk estimator, transform
//!   builder, solver driver (native or PJRT-backed), clustering, metrics,
//!   CLI, and the experiment harness reproducing every figure of the paper.
//!
//! Python never runs on the request path: the `sped` binary only loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate).
//!
//! With `--features simd` (nightly toolchains only) the skinny-SpMM
//! kernel family is implemented on `std::simd` portable vectors; without
//! it the stable unrolled kernels are used. Both are bitwise-identical
//! to the streaming reference, so the feature changes throughput, never
//! results.
//!
//! ## Quick tour
//!
//! ```no_run
//! use sped::graph::gen::{cliques, CliqueSpec};
//! use sped::pipeline::{Pipeline, PipelineConfig};
//! use sped::transforms::TransformKind;
//!
//! let graph = cliques(&CliqueSpec { n: 256, k: 4, max_short_circuit: 25, seed: 7 });
//! let cfg = PipelineConfig {
//!     k: 8,
//!     transform: TransformKind::LimitNegExp { ell: 251 },
//!     ..PipelineConfig::default()
//! };
//! let out = Pipeline::new(cfg).run(&graph.graph).unwrap();
//! println!("clusters: {:?}", out.clustering.unwrap().assignments);
//! ```
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod cluster;
pub mod coordinator;
pub mod graph;
pub mod linalg;
pub mod linkpred;
pub mod mdp;
pub mod runtime;
pub mod solvers;
pub mod testkit;
pub mod transforms;
pub mod util;
pub mod walks;

pub use coordinator::pipeline;

/// Crate-wide result type (anyhow-backed).
pub type Result<T> = anyhow::Result<T>;
