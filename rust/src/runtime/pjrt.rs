//! The PJRT-backed artifact runtime (compiled only with `--features xla`).
//!
//! See the module-level docs in [`super`] for the manifest format. This
//! file owns everything that touches `xla::` types: the lazy-compiling
//! registry, literal marshalling, and the high-level artifact wrappers.

use super::{read_manifest, ArtifactMeta, ChunkOutput};
use crate::linalg::DMat;
use crate::solvers::MatVecOp;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// A compiled artifact ready to execute.
pub struct Artifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Raw execute: literals in, tuple of literals out.
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact {}", self.meta.name))?;
        let out = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("artifact {} returned no outputs", self.meta.name))?
            .to_literal_sync()?;
        Ok(out.to_tuple()?)
    }
}

/// The artifact registry + PJRT client.
///
/// Artifacts are compiled **lazily** on first use (a registry of 24 HLO
/// modules takes ~10 s to compile eagerly on this single-core image; a
/// pipeline run touches 2–3 of them — see EXPERIMENTS.md §Perf).
pub struct Runtime {
    client: xla::PjRtClient,
    metas: HashMap<String, ArtifactMeta>,
    compiled: Mutex<HashMap<String, Arc<Artifact>>>,
    dir: PathBuf,
}

impl Runtime {
    /// Read `dir/manifest.cfg` and prepare (but do not yet compile) every
    /// listed artifact.
    pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let metas = read_manifest(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, metas, compiled: Mutex::new(HashMap::new()), dir })
    }

    fn compile(&self, meta: &ArtifactMeta) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(&meta.file)
            .with_context(|| format!("parsing HLO text {}", meta.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {}", meta.name))?;
        Ok(Artifact { meta: meta.clone(), exe })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.metas.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Get (compiling on first use) an artifact by name.
    pub fn get(&self, name: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self.compiled.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let meta = self
            .metas
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not found (have: {:?})", self.names()))?;
        let artifact = Arc::new(self.compile(meta)?);
        self.compiled
            .lock()
            .unwrap()
            .insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Find the smallest artifact of `kind` whose size fits `n` nodes
    /// (compiled on first use).
    pub fn best_fit(&self, kind: &str, n: usize) -> Result<Arc<Artifact>> {
        let name = self
            .metas
            .values()
            .filter(|a| a.kind == kind && a.n >= n)
            .min_by_key(|a| a.n)
            .map(|a| a.name.clone())
            .ok_or_else(|| {
                anyhow!(
                    "no {kind:?} artifact fits n={n} (have: {:?})",
                    self.metas
                        .values()
                        .map(|a| format!("{}[n={}]", a.kind, a.n))
                        .collect::<Vec<_>>()
                )
            })?;
        self.get(&name)
    }
}

// ---- literal marshalling ----

/// `DMat` (f64) → f32 literal of shape `[rows, cols]`.
pub fn mat_to_literal(m: &DMat) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&m.to_f32()).reshape(&[m.rows() as i64, m.cols() as i64])?)
}

/// f32 vector literal of shape `[len]`.
pub fn vec_to_literal(v: &[f64]) -> xla::Literal {
    let f: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&f)
}

/// Literal (f32, shape `[rows, cols]`) → `DMat`.
pub fn literal_to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> Result<DMat> {
    let data = lit.to_vec::<f32>()?;
    if data.len() != rows * cols {
        bail!("literal has {} elements, expected {rows}×{cols}", data.len());
    }
    Ok(DMat::from_f32(rows, cols, &data))
}

/// Literal (f32, any shape) → flat f64 vector.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f32>()?.into_iter().map(|x| x as f64).collect())
}

// ---- high-level artifact wrappers ----

/// Driver for `oja_chunk` / `eg_chunk` artifacts: iterates T solver steps
/// per call entirely inside XLA.
pub struct XlaChunkRunner {
    artifact: Arc<Artifact>,
    /// Uploaded once; reused every chunk.
    m_literal: xla::Literal,
    pub n: usize,
    pub k: usize,
    pub t: usize,
}

impl XlaChunkRunner {
    /// `m` must match the artifact's padded size exactly (`pad_matrix`
    /// handles padding).
    pub fn new(artifact: Arc<Artifact>, m: &DMat) -> Result<Self> {
        let (n, k, t) = (artifact.meta.n, artifact.meta.k, artifact.meta.t);
        if m.rows() != n || m.cols() != n {
            bail!("matrix is {}×{}, artifact {} wants {n}×{n}", m.rows(), m.cols(), artifact.meta.name);
        }
        Ok(XlaChunkRunner { artifact, m_literal: mat_to_literal(m)?, n, k, t })
    }

    /// Run one chunk of `t` steps from `v` (n×k), measuring against
    /// `v_star` (n×k).
    pub fn run_chunk(&self, v: &DMat, v_star: &DMat, eta: f64) -> Result<ChunkOutput> {
        if v.rows() != self.n || v.cols() != self.k {
            bail!("v is {}×{}, want {}×{}", v.rows(), v.cols(), self.n, self.k);
        }
        let outs = self.artifact.execute(&[
            self.m_literal.clone(),
            mat_to_literal(v)?,
            mat_to_literal(v_star)?,
            xla::Literal::scalar(eta as f32),
        ])?;
        if outs.len() != 3 {
            bail!("chunk artifact returned {} outputs, want 3", outs.len());
        }
        Ok(ChunkOutput {
            v: literal_to_mat(&outs[0], self.n, self.k)?,
            errors: literal_to_vec(&outs[1])?,
            aligns: literal_to_mat(&outs[2], self.t, self.k)?,
        })
    }
}

/// Dense `MatVecOp` backed by a `matvec` artifact (M·V inside XLA). Used to
/// cross-validate native vs XLA solver paths and by the e2e example.
pub struct XlaDenseOp {
    artifact: Arc<Artifact>,
    m_literal: xla::Literal,
    n: usize,
    k: usize,
}

impl XlaDenseOp {
    pub fn new(artifact: Arc<Artifact>, m: &DMat) -> Result<Self> {
        let (n, k) = (artifact.meta.n, artifact.meta.k);
        if m.rows() != n {
            bail!("matrix size {} != artifact n={n}", m.rows());
        }
        Ok(XlaDenseOp { artifact, m_literal: mat_to_literal(m)?, n, k })
    }
}

impl MatVecOp for XlaDenseOp {
    fn apply(&mut self, v: &DMat) -> DMat {
        let outs = self
            .artifact
            .execute(&[self.m_literal.clone(), mat_to_literal(v).unwrap()])
            .expect("matvec artifact");
        literal_to_mat(&outs[0], self.n, self.k).expect("matvec output")
    }
    fn dim(&self) -> usize {
        self.n
    }
    fn label(&self) -> String {
        format!("xla:{}", self.artifact.meta.name)
    }
}

/// Build `p(L)` through the `poly_horner` artifact (coefficients padded with
/// zeros to the artifact's degree; polynomial is in the *shifted* matrix
/// `B = L − shift·I`, matching `transforms::SeriesForm`).
pub fn xla_poly_build(artifact: &Artifact, l: &DMat, shift: f64, coeffs: &[f64]) -> Result<DMat> {
    let n = artifact.meta.n;
    let d = artifact.meta.degree;
    if l.rows() != n {
        bail!("L size {} != artifact n={n}", l.rows());
    }
    if coeffs.len() > d {
        bail!("{} coefficients > artifact degree {d}", coeffs.len());
    }
    let mut padded = coeffs.to_vec();
    padded.resize(d, 0.0);
    let outs = artifact.execute(&[
        mat_to_literal(l)?,
        vec_to_literal(&padded),
        xla::Literal::scalar(shift as f32),
    ])?;
    literal_to_mat(&outs[0], n, n)
}

/// Compute `B^p` through the `matpow` artifact: the exponent is passed as a
/// binary mask over `bits` square-and-multiply rounds (LSB first).
pub fn xla_matpow(artifact: &Artifact, b: &DMat, p: u64) -> Result<DMat> {
    let n = artifact.meta.n;
    let bits = artifact.meta.bits;
    if b.rows() != n {
        bail!("B size {} != artifact n={n}", b.rows());
    }
    if p == 0 || (64 - p.leading_zeros() as usize) > bits {
        bail!("exponent {p} out of range for {bits}-bit matpow artifact");
    }
    let mask: Vec<f64> = (0..bits).map(|i| ((p >> i) & 1) as f64).collect();
    let outs = artifact.execute(&[mat_to_literal(b)?, vec_to_literal(&mask)])?;
    literal_to_mat(&outs[0], n, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_helpers_fail_gracefully_on_stub_bindings() {
        // With the real bindings this is a lossless f32 roundtrip; with the
        // vendored stub crate every conversion surfaces a descriptive error
        // instead of panicking.
        let m = DMat::from_fn(3, 4, |i, j| (i as f64) - 0.5 * (j as f64));
        match mat_to_literal(&m) {
            Ok(lit) => {
                let back = literal_to_mat(&lit, 3, 4).unwrap();
                assert!((&back - &m).max_abs() < 1e-6);
            }
            Err(e) => assert!(e.to_string().contains("xla"), "unexpected error: {e}"),
        }
    }
}
