//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py` → `artifacts/*.hlo.txt` + `manifest.cfg`) and
//! executes them from the L3 hot path. Python is never involved at runtime.
//!
//! Interchange is **HLO text** — the image's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The PJRT-backed implementation lives behind the optional `xla` cargo
//! feature. Without it (the default for offline checkouts) an in-crate
//! stub with the same API takes its place: every constructor returns a
//! descriptive error, so code paths and tests that *mention* the XLA
//! backend still compile, and the XLA integration tests skip cleanly when
//! no artifacts are present.
//!
//! The manifest is written in the crate's TOML-subset (`util::config`), one
//! section per artifact:
//!
//! ```text
//! [oja_chunk_n128]
//! file = "oja_chunk_n128.hlo.txt"
//! kind = "oja_chunk"
//! n = 128
//! k = 8
//! t = 25
//! ```

use crate::linalg::DMat;
use crate::util::config::Config;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::*;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::*;

/// Metadata for one artifact (from `manifest.cfg`).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub n: usize,
    pub k: usize,
    /// Steps per solver chunk (solver kinds only).
    pub t: usize,
    /// Max polynomial coefficients (poly kinds only).
    pub degree: usize,
    /// Bits of the exponent (matpow kinds only).
    pub bits: usize,
    /// Walk batch size (stoch_apply kinds only).
    pub batch: usize,
}

/// Parse `dir/manifest.cfg` into the artifact registry (no compilation).
/// Shared by the PJRT runtime and the stub (which uses it to distinguish
/// "no artifacts" from "artifacts present but built without `xla`").
pub fn read_manifest(dir: &Path) -> Result<HashMap<String, ArtifactMeta>> {
    let manifest_path = dir.join("manifest.cfg");
    let cfg = Config::load(manifest_path.to_str().unwrap())
        .map_err(|e| anyhow!("loading manifest: {e}"))?;
    let mut metas = HashMap::new();
    // Section names = artifact names; collect them from keys.
    let mut names: Vec<String> = cfg
        .keys()
        .filter_map(|k| k.split_once('.').map(|(s, _)| s.to_string()))
        .collect();
    names.sort();
    names.dedup();
    for name in names {
        let get = |field: &str, d: usize| cfg.usize(&format!("{name}.{field}"), d);
        let file = cfg.str(&format!("{name}.file"), "");
        if file.is_empty() {
            bail!("artifact {name}: missing file field");
        }
        if !dir.join(&file).exists() {
            bail!("artifact {name}: file {file:?} missing from {}", dir.display());
        }
        let meta = ArtifactMeta {
            name: name.clone(),
            file: dir.join(&file),
            kind: cfg.str(&format!("{name}.kind"), ""),
            n: get("n", 0),
            k: get("k", 0),
            t: get("t", 0),
            degree: get("degree", 0),
            bits: get("bits", 0),
            batch: get("batch", 0),
        };
        metas.insert(name, meta);
    }
    Ok(metas)
}

/// Result of one solver chunk: updated estimate + per-step metrics computed
/// *inside* the XLA program (against the padded ground truth).
pub struct ChunkOutput {
    pub v: DMat,
    /// Per-step subspace error (T values).
    pub errors: Vec<f64>,
    /// Per-step per-vector |alignment| (T × k).
    pub aligns: DMat,
}

/// Pad a square matrix up to `size`, placing `diag_fill` on the padded
/// diagonal. For a SPED-reversed matrix `M`, pass `diag_fill` *below* the
/// spectrum floor (e.g. `-1`) so padding eigenpairs rank strictly below all
/// genuine ones and never pollute the top-k.
pub fn pad_matrix(m: &DMat, size: usize, diag_fill: f64) -> DMat {
    assert!(size >= m.rows() && m.is_square());
    let mut out = DMat::zeros(size, size);
    for i in 0..m.rows() {
        for j in 0..m.cols() {
            out[(i, j)] = m[(i, j)];
        }
    }
    for i in m.rows()..size {
        out[(i, i)] = diag_fill;
    }
    out
}

/// Pad an `n×k` bundle with zero rows to `size` rows.
pub fn pad_rows(v: &DMat, size: usize) -> DMat {
    assert!(size >= v.rows());
    let mut out = DMat::zeros(size, v.cols());
    for i in 0..v.rows() {
        for j in 0..v.cols() {
            out[(i, j)] = v[(i, j)];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_matrix_preserves_block_and_fills_diag() {
        let m = DMat::from_fn(2, 2, |i, j| (i * 2 + j) as f64);
        let p = pad_matrix(&m, 4, -1.0);
        assert_eq!(p[(1, 0)], 2.0);
        assert_eq!(p[(2, 2)], -1.0);
        assert_eq!(p[(3, 3)], -1.0);
        assert_eq!(p[(2, 3)], 0.0);
    }

    #[test]
    fn pad_rows_zero_fills() {
        let v = DMat::from_fn(2, 3, |i, j| (i + j) as f64);
        let p = pad_rows(&v, 5);
        assert_eq!(p.rows(), 5);
        assert_eq!(p[(1, 2)], 3.0);
        assert_eq!(p[(4, 0)], 0.0);
    }

    #[test]
    fn padding_eigenpairs_rank_below_spectrum() {
        // Padded diag −1 < all eigenvalues of e^{-L}-style M (≥ 0): top-k
        // eigenvectors of the padded matrix restrict to the original block.
        let g = crate::graph::gen::cliques(&crate::graph::gen::CliqueSpec {
            n: 12,
            k: 2,
            max_short_circuit: 1,
            seed: 3,
        })
        .graph;
        let sm = crate::transforms::build_solver_matrix(
            &g.laplacian(),
            crate::transforms::TransformKind::NegExp,
            &crate::transforms::BuildOptions::default(),
        )
        .unwrap();
        let padded = pad_matrix(&sm.m, 16, -1.0);
        let e_orig = crate::linalg::eigh(&sm.m).unwrap();
        let e_pad = crate::linalg::eigh(&padded).unwrap();
        // Top 2 eigenvalues identical.
        for i in 0..2 {
            let a = e_orig.values[11 - i];
            let b = e_pad.values[15 - i];
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        // And the bottom 4 of the padded matrix are the −1 fills.
        for i in 0..4 {
            assert!((e_pad.values[i] + 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(Runtime::load_dir("/nonexistent/path").is_err());
        assert!(read_manifest(Path::new("/nonexistent/path")).is_err());
    }

    #[test]
    fn manifest_roundtrip_parses_sections() {
        let dir = std::env::temp_dir().join("sped_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("oja_chunk_n128.hlo.txt"), "HloModule stub").unwrap();
        std::fs::write(
            dir.join("manifest.cfg"),
            "[oja_chunk_n128]\nfile = \"oja_chunk_n128.hlo.txt\"\nkind = \"oja_chunk\"\nn = 128\nk = 8\nt = 25\n",
        )
        .unwrap();
        let metas = read_manifest(&dir).unwrap();
        let meta = metas.get("oja_chunk_n128").expect("section parsed");
        assert_eq!(meta.kind, "oja_chunk");
        assert_eq!((meta.n, meta.k, meta.t), (128, 8, 25));
        assert_eq!(meta.degree, 0);
        // A manifest naming a missing file must be rejected.
        std::fs::write(dir.join("manifest.cfg"), "[m]\nfile = \"gone.hlo.txt\"\n").unwrap();
        assert!(read_manifest(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
