//! Offline stand-in for the PJRT runtime (default build, no `xla` feature).
//!
//! Presents the exact API of [`super::pjrt`] so that the pipeline's XLA
//! backend, the benches, and the integration tests all compile without the
//! PJRT bindings. Nothing here can actually execute: [`Runtime::load_dir`]
//! always returns an error (distinguishing "no artifacts" from "artifacts
//! present but built without `xla`"), and every other type carries an
//! uninhabited field, so the remaining methods are statically unreachable.

use super::{read_manifest, ArtifactMeta, ChunkOutput};
use crate::linalg::DMat;
use crate::solvers::MatVecOp;
use anyhow::{bail, Result};
use std::path::Path;
use std::sync::Arc;

/// Uninhabited: makes the stub types impossible to construct.
#[derive(Clone, Copy, Debug)]
enum Never {}

const NO_XLA: &str =
    "sped was built without the `xla` feature; rebuild with `--features xla` \
     (and the real PJRT bindings in rust/vendor/xla) to execute AOT artifacts";

/// A compiled artifact (unconstructible in this build).
pub struct Artifact {
    pub meta: ArtifactMeta,
    _never: Never,
}

/// The artifact registry (unconstructible in this build).
pub struct Runtime {
    _never: Never,
}

impl Runtime {
    /// Always fails: either the artifacts are missing (same behaviour as
    /// the real runtime) or they exist but this build cannot execute them.
    pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        read_manifest(dir.as_ref())?;
        bail!("{NO_XLA}");
    }

    pub fn dir(&self) -> &Path {
        match self._never {}
    }

    pub fn names(&self) -> Vec<&str> {
        match self._never {}
    }

    pub fn get(&self, _name: &str) -> Result<Arc<Artifact>> {
        match self._never {}
    }

    pub fn best_fit(&self, _kind: &str, _n: usize) -> Result<Arc<Artifact>> {
        match self._never {}
    }
}

/// Chunked-solver driver (unconstructible in this build).
pub struct XlaChunkRunner {
    pub n: usize,
    pub k: usize,
    pub t: usize,
    _never: Never,
}

impl XlaChunkRunner {
    pub fn new(_artifact: Arc<Artifact>, _m: &DMat) -> Result<Self> {
        bail!("{NO_XLA}");
    }

    pub fn run_chunk(&self, _v: &DMat, _v_star: &DMat, _eta: f64) -> Result<ChunkOutput> {
        match self._never {}
    }
}

/// Dense XLA-backed operator (unconstructible in this build).
pub struct XlaDenseOp {
    _never: Never,
}

impl XlaDenseOp {
    pub fn new(_artifact: Arc<Artifact>, _m: &DMat) -> Result<Self> {
        bail!("{NO_XLA}");
    }
}

impl MatVecOp for XlaDenseOp {
    fn apply(&mut self, _v: &DMat) -> DMat {
        match self._never {}
    }
    fn dim(&self) -> usize {
        match self._never {}
    }
    fn label(&self) -> String {
        match self._never {}
    }
}

/// Polynomial build through the `poly_horner` artifact — unreachable here
/// because no [`Artifact`] can exist without the `xla` feature.
pub fn xla_poly_build(artifact: &Artifact, _l: &DMat, _shift: f64, _coeffs: &[f64]) -> Result<DMat> {
    match artifact._never {}
}

/// Matrix power through the `matpow` artifact — unreachable here because no
/// [`Artifact`] can exist without the `xla` feature.
pub fn xla_matpow(artifact: &Artifact, _b: &DMat, _p: u64) -> Result<DMat> {
    match artifact._never {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_reports_missing_feature() {
        // With a valid manifest on disk, the stub must fail with a message
        // pointing at the feature flag rather than a confusing I/O error.
        let dir = std::env::temp_dir().join("sped_stub_runtime_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule stub").unwrap();
        std::fs::write(dir.join("manifest.cfg"), "[m]\nfile = \"m.hlo.txt\"\nkind = \"matvec\"\nn = 8\n")
            .unwrap();
        let err = Runtime::load_dir(&dir).unwrap_err().to_string();
        assert!(err.contains("xla"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
