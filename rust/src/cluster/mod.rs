//! Hard clustering on spectral embeddings and cluster-quality metrics.
//!
//! Spectral clustering's final step (§2.1): run k-means on the rows of the
//! bottom-k eigenvector matrix. Includes k-means++ initialisation, Lloyd
//! iterations, and the evaluation suite: Adjusted Rand Index, Normalized
//! Mutual Information, and the conductance / normalized-cut objectives
//! (eqs 3–7) the spectral relaxation approximates.

use crate::graph::Graph;
use crate::linalg::DMat;
use crate::util::rng::Rng;

/// k-means result.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub assignments: Vec<usize>,
    pub centroids: DMat,
    pub inertia: f64,
    pub iterations: usize,
}

/// k-means++ seeding followed by Lloyd iterations on the rows of `points`.
pub fn kmeans(points: &DMat, k: usize, max_iters: usize, seed: u64) -> KMeansResult {
    let (n, d) = (points.rows(), points.cols());
    assert!(k >= 1 && k <= n, "need 1 ≤ k ≤ n");
    let mut rng = Rng::new(seed);
    // --- k-means++ seeding ---
    let mut centroids = DMat::zeros(k, d);
    let first = rng.below(n);
    centroids.row_mut(0).copy_from_slice(points.row(first));
    let mut d2 = vec![f64::INFINITY; n];
    for c in 1..k {
        for i in 0..n {
            let dist = sqdist(points.row(i), centroids.row(c - 1));
            if dist < d2[i] {
                d2[i] = dist;
            }
        }
        let next = rng.weighted(&d2).unwrap_or_else(|| rng.below(n));
        centroids.row_mut(c).copy_from_slice(points.row(next));
    }
    // --- Lloyd ---
    let mut assignments = vec![0usize; n];
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        let mut changed = false;
        for i in 0..n {
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..k {
                let dist = sqdist(points.row(i), centroids.row(c));
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if assignments[i] != best.1 {
                assignments[i] = best.1;
                changed = true;
            }
        }
        // Recompute centroids; re-seed empty clusters from the farthest point.
        let mut counts = vec![0usize; k];
        let mut sums = DMat::zeros(k, d);
        for i in 0..n {
            counts[assignments[i]] += 1;
            let row = points.row(i);
            let srow = sums.row_mut(assignments[i]);
            for j in 0..d {
                srow[j] += row[j];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = sqdist(points.row(a), centroids.row(assignments[a]));
                        let db = sqdist(points.row(b), centroids.row(assignments[b]));
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                centroids.row_mut(c).copy_from_slice(points.row(far));
                changed = true;
            } else {
                let inv = 1.0 / counts[c] as f64;
                for j in 0..d {
                    centroids[(c, j)] = sums[(c, j)] * inv;
                }
            }
        }
        if !changed && it > 0 {
            break;
        }
    }
    let inertia = (0..n)
        .map(|i| sqdist(points.row(i), centroids.row(assignments[i])))
        .sum();
    KMeansResult { assignments, centroids, inertia, iterations }
}

#[inline]
fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Row-normalize an embedding (common spectral-clustering preprocessing;
/// zero rows are left as-is).
pub fn row_normalize(v: &DMat) -> DMat {
    let mut out = v.clone();
    for i in 0..out.rows() {
        let row = out.row_mut(i);
        let n = row.iter().map(|x| x * x).sum::<f64>().sqrt();
        if n > 0.0 {
            for x in row.iter_mut() {
                *x /= n;
            }
        }
    }
    out
}

/// Adjusted Rand Index between two labelings (1 = identical partitions,
/// ~0 = random agreement).
pub fn adjusted_rand_index(a: &[usize], b: &[usize]) -> f64 {
    // ARI is undefined across different node sets — a silent zip would
    // truncate to the shorter slice and report a misleading score.
    // Streaming callers compare the common prefix explicitly instead
    // (see `coordinator::stream::StreamSession::publish`).
    assert_eq!(
        a.len(),
        b.len(),
        "adjusted_rand_index: label slices differ in length ({} vs {})",
        a.len(),
        b.len()
    );
    let n = a.len();
    if n == 0 {
        return 1.0;
    }
    let ka = 1 + *a.iter().max().unwrap();
    let kb = 1 + *b.iter().max().unwrap();
    let mut table = vec![vec![0u64; kb]; ka];
    for i in 0..n {
        table[a[i]][b[i]] += 1;
    }
    let choose2 = |x: u64| (x * x.saturating_sub(1)) / 2;
    let sum_ij: u64 = table.iter().flatten().map(|&x| choose2(x)).sum();
    let sum_a: u64 = table.iter().map(|row| choose2(row.iter().sum())).sum();
    let sum_b: u64 = (0..kb)
        .map(|j| choose2(table.iter().map(|row| row[j]).sum()))
        .sum();
    let total = choose2(n as u64);
    let expected = sum_a as f64 * sum_b as f64 / total as f64;
    let max_index = 0.5 * (sum_a + sum_b) as f64;
    if (max_index - expected).abs() < 1e-12 {
        return 1.0;
    }
    (sum_ij as f64 - expected) / (max_index - expected)
}

/// Normalized Mutual Information (arithmetic normalization).
pub fn normalized_mutual_info(a: &[usize], b: &[usize]) -> f64 {
    // Same contract as `adjusted_rand_index`: no silent truncation.
    assert_eq!(
        a.len(),
        b.len(),
        "normalized_mutual_info: label slices differ in length ({} vs {})",
        a.len(),
        b.len()
    );
    let n = a.len();
    if n == 0 {
        return 1.0;
    }
    let ka = 1 + *a.iter().max().unwrap();
    let kb = 1 + *b.iter().max().unwrap();
    let mut joint = vec![vec![0f64; kb]; ka];
    for i in 0..n {
        joint[a[i]][b[i]] += 1.0;
    }
    let nf = n as f64;
    let pa: Vec<f64> = joint.iter().map(|r| r.iter().sum::<f64>() / nf).collect();
    let pb: Vec<f64> = (0..kb)
        .map(|j| joint.iter().map(|r| r[j]).sum::<f64>() / nf)
        .collect();
    let mut mi = 0.0;
    for i in 0..ka {
        for j in 0..kb {
            let p = joint[i][j] / nf;
            if p > 0.0 {
                mi += p * (p / (pa[i] * pb[j])).ln();
            }
        }
    }
    let ent = |p: &[f64]| -p.iter().filter(|&&x| x > 0.0).map(|&x| x * x.ln()).sum::<f64>();
    let (ha, hb) = (ent(&pa), ent(&pb));
    if ha == 0.0 && hb == 0.0 {
        return 1.0;
    }
    2.0 * mi / (ha + hb)
}

/// Worst-cluster conductance `max_i φ(S_i)` (eq 7's objective evaluated on
/// a concrete k-way partition). Lower is better-clustered.
pub fn max_conductance(g: &Graph, labels: &[usize]) -> f64 {
    assert_eq!(labels.len(), g.num_nodes());
    let k = 1 + labels.iter().copied().max().unwrap_or(0);
    let mut worst: f64 = 0.0;
    for c in 0..k {
        let in_s: Vec<bool> = labels.iter().map(|&l| l == c).collect();
        if let Some(phi) = g.conductance(&in_s) {
            worst = worst.max(phi);
        }
    }
    worst
}

/// Nearest centroid of one point: `(cluster, squared distance)` by strict
/// `<` scan — the lowest-index centroid wins exact ties, so the lookup is
/// deterministic. `point` must live in the same space as the centroids
/// (for [`cluster_embedding`] results that is the row-normalized space).
pub fn nearest_centroid(centroids: &DMat, point: &[f64]) -> (usize, f64) {
    assert!(centroids.rows() >= 1, "need at least one centroid");
    assert_eq!(
        centroids.cols(),
        point.len(),
        "nearest_centroid: point dimension {} vs centroid dimension {}",
        point.len(),
        centroids.cols()
    );
    let mut best = (0usize, sqdist(point, centroids.row(0)));
    for c in 1..centroids.rows() {
        let d = sqdist(point, centroids.row(c));
        if d < best.1 {
            best = (c, d);
        }
    }
    (best.0, best.1)
}

/// End-to-end hard clustering from a spectral embedding: row-normalize,
/// k-means++ with a few restarts, keep the lowest-inertia result.
pub fn cluster_embedding(embedding: &DMat, k: usize, seed: u64) -> KMeansResult {
    let pts = row_normalize(embedding);
    let mut best: Option<KMeansResult> = None;
    for restart in 0..5 {
        let r = kmeans(&pts, k, 100, seed ^ (restart as u64) << 32);
        if best.as_ref().map(|b| r.inertia < b.inertia).unwrap_or(true) {
            best = Some(r);
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, CliqueSpec};
    use crate::linalg::eigh;

    #[test]
    fn kmeans_separates_obvious_blobs() {
        let mut rng = Rng::new(1);
        let pts = DMat::from_fn(60, 2, |i, j| {
            let center = if i < 30 { 0.0 } else { 10.0 };
            center + 0.5 * rng.normal() + j as f64 * 0.0
        });
        let r = kmeans(&pts, 2, 50, 3);
        // All first-30 in one cluster, rest in the other.
        let c0 = r.assignments[0];
        assert!(r.assignments[..30].iter().all(|&c| c == c0));
        assert!(r.assignments[30..].iter().all(|&c| c != c0));
        assert!(r.inertia < 60.0);
    }

    #[test]
    fn kmeans_k_equals_n() {
        let pts = DMat::from_fn(4, 2, |i, j| (i * 2 + j) as f64);
        let r = kmeans(&pts, 4, 20, 1);
        let mut sorted = r.assignments.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
        assert!(r.inertia < 1e-12);
    }

    #[test]
    fn ari_extremes() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-12);
        // Permuted labels: same partition → ARI 1.
        let b = vec![2, 2, 0, 0, 1, 1];
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
        // All-in-one vs discriminating: ARI 0.
        let c = vec![0, 0, 0, 0, 0, 0];
        assert!(adjusted_rand_index(&a, &c).abs() < 1e-12);
    }

    #[test]
    fn nmi_extremes() {
        let a = vec![0, 0, 1, 1];
        assert!((normalized_mutual_info(&a, &a) - 1.0).abs() < 1e-12);
        let b = vec![1, 1, 0, 0];
        assert!((normalized_mutual_info(&a, &b) - 1.0).abs() < 1e-12);
        let c = vec![0, 1, 0, 1];
        assert!(normalized_mutual_info(&a, &c).abs() < 1e-9);
    }

    #[test]
    fn spectral_clustering_end_to_end() {
        // Bottom-k eigenvectors of a well-clustered graph + kmeans recovers
        // the ground-truth cliques.
        let spec = CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 4 };
        let gg = cliques(&spec);
        let e = eigh(&gg.graph.laplacian()).unwrap();
        let emb = e.bottom_k(3);
        let r = cluster_embedding(&emb, 3, 7);
        let ari = adjusted_rand_index(&r.assignments, &gg.labels);
        assert!(ari > 0.95, "ARI {ari}");
        let nmi = normalized_mutual_info(&r.assignments, &gg.labels);
        assert!(nmi > 0.9, "NMI {nmi}");
        // And the recovered partition has low conductance.
        let phi = max_conductance(&gg.graph, &r.assignments);
        assert!(phi < 0.25, "φ {phi}");
    }

    #[test]
    fn conductance_of_ground_truth_lower_than_random() {
        let spec = CliqueSpec { n: 30, k: 3, max_short_circuit: 3, seed: 8 };
        let gg = cliques(&spec);
        let phi_true = max_conductance(&gg.graph, &gg.labels);
        let mut rng = Rng::new(5);
        let random: Vec<usize> = (0..30).map(|_| rng.below(3)).collect();
        let phi_rand = max_conductance(&gg.graph, &random);
        assert!(phi_true < phi_rand, "{phi_true} !< {phi_rand}");
    }

    #[test]
    fn row_normalize_units() {
        let v = DMat::from_fn(3, 2, |i, _| (i + 1) as f64);
        let r = row_normalize(&v);
        for i in 0..3 {
            let n: f64 = r.row(i).iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-12);
        }
        // Zero rows untouched.
        let z = row_normalize(&DMat::zeros(2, 2));
        assert_eq!(z.max_abs(), 0.0);
    }

    #[test]
    #[should_panic(expected = "differ in length")]
    fn ari_rejects_length_mismatch() {
        adjusted_rand_index(&[0, 1], &[0, 1, 1]);
    }

    #[test]
    #[should_panic(expected = "differ in length")]
    fn nmi_rejects_length_mismatch() {
        normalized_mutual_info(&[0, 1, 1], &[0, 1]);
    }

    #[test]
    fn nearest_centroid_agrees_with_kmeans() {
        let mut rng = Rng::new(6);
        let pts = DMat::from_fn(40, 3, |i, _| if i < 20 { 0.0 } else { 8.0 } + rng.normal());
        let r = kmeans(&pts, 2, 50, 9);
        for i in 0..pts.rows() {
            let (c, d2) = nearest_centroid(&r.centroids, pts.row(i));
            assert_eq!(c, r.assignments[i], "point {i}");
            assert!(d2 >= 0.0);
        }
        // Exact tie: equidistant point resolves to the lower centroid id.
        let cents = DMat::from_fn(2, 1, |i, _| if i == 0 { -1.0 } else { 1.0 });
        assert_eq!(nearest_centroid(&cents, &[0.0]).0, 0);
    }

    #[test]
    fn property_ari_symmetric() {
        use crate::testkit::{check, SizeGen};
        check(23, 20, &SizeGen { lo: 4, hi: 40 }, |&n| {
            let mut rng = Rng::new(n as u64);
            let a: Vec<usize> = (0..n).map(|_| rng.below(3)).collect();
            let b: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
            (adjusted_rand_index(&a, &b) - adjusted_rand_index(&b, &a)).abs() < 1e-12
        });
    }
}
