//! Streaming session driver: ingest batched edge deltas, keep the
//! embedding and clustering fresh with warm-started Ritz solves, and
//! degrade to cold solves when the accumulated churn makes the previous
//! subspace a bad seed.
//!
//! The session owns the mutable [`Graph`] plus every piece of derived
//! state the pipeline would otherwise recompute from scratch each publish:
//!
//! * the previous embedding (the warm-start seed),
//! * the previous hard assignments (the drift baseline),
//! * a cached RCM order (valid until a delta changes topology),
//! * a cached spectral-domain estimate (valid until any Laplacian entry
//!   moves; re-estimated `O(nnz)` from the patched CSR, never dense).
//!
//! Invalidation is driven by the exact [`DeltaOutcome`] flags
//! [`Graph::apply_deltas`] reports, so a reweight-only batch keeps the
//! node order and a bitwise no-op batch keeps everything.

use crate::cluster::adjusted_rand_index;
use crate::coordinator::pipeline::{
    Pipeline, PipelineConfig, RitzSummary, SolvePath, RITZ_HISTORY_CAP,
};
use crate::graph::delta::{DeltaOutcome, EdgeDelta};
use crate::graph::{Graph, Reorder};
use crate::linalg::dmat::DMat;
use crate::transforms::SpectrumEstimate;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Streaming-session configuration: the per-publish pipeline plus the
/// warm/cold degradation policy.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// The pipeline each publish runs. `warm_start` and `rcm_order` are
    /// managed by the session (anything set here is overwritten).
    pub pipeline: PipelineConfig,
    /// Degradation threshold: when the edge volume touched since the last
    /// publish exceeds this fraction of the current edge count, the warm
    /// seed is presumed stale and the publish runs cold up front (rather
    /// than paying for a doomed warm attempt). `0` forces every publish
    /// cold; warm starts also require `pipeline.solver == "ritz"`.
    pub warm_volume_frac: f64,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig { pipeline: PipelineConfig::default(), warm_volume_frac: 0.25 }
    }
}

/// What one [`StreamSession::publish`] produced.
#[derive(Clone, Debug)]
pub struct PublishReport {
    /// Which solve produced the embedding (cold / warm / warm-degraded).
    /// Step-driven solvers always report [`SolvePath::Cold`].
    pub path: SolvePath,
    /// Outer iterations of a `ritz` solve (0 for step-driven solvers).
    pub iterations: usize,
    /// Total SpMM sweeps of a `ritz` solve (0 for step-driven solvers) —
    /// the honest cost unit warm-vs-cold comparisons are stated in.
    pub sweeps: usize,
    /// Whether the solver self-reported convergence (`true` for
    /// step-driven solvers, which run a fixed step budget).
    pub converged: bool,
    /// Hard cluster assignments (empty when `do_cluster` is off).
    pub assignments: Vec<usize>,
    /// ARI of the new assignments against the previous publish — the
    /// drift metric. `None` on the first publish, when clustering is off,
    /// or when the node count changed (ARI is undefined across different
    /// node sets) — [`PublishReport::ari_reason`] says which.
    pub ari_vs_previous: Option<f64>,
    /// When the graph *grew* since the last publish (`AddNodes` deltas),
    /// the drift ARI over the common prefix of pre-existing nodes — a
    /// well-defined comparison on the node set both publishes share. The
    /// full-vector metric stays `None`: comparing a grown assignment
    /// vector against the shorter previous one is meaningless.
    pub ari_prefix_vs_previous: Option<f64>,
    /// Why `ari_vs_previous` is `None`, when it is.
    pub ari_reason: Option<&'static str>,
    /// Delta volume accumulated since the last publish, as the fraction
    /// of the current edge count the degradation policy compared against.
    pub volume_frac: f64,
    /// The reversal shift the solve used.
    pub lambda_star: f64,
}

/// A long-lived streaming session over one mutable graph.
pub struct StreamSession {
    graph: Graph,
    cfg: StreamConfig,
    prev_embedding: Option<DMat>,
    prev_assignments: Option<Vec<usize>>,
    /// RCM order for the *current* topology (recomputed lazily after a
    /// topology-changing batch). Doubles as the `# order:` header source
    /// on save — never written stale (see [`StreamSession::save`]).
    cached_order: Option<Vec<usize>>,
    /// Spectral-domain estimate for the current weights, invalidated by
    /// any batch that moves a Laplacian entry.
    cached_domain: Option<SpectrumEstimate>,
    /// Edge volume accumulated since the last publish.
    delta_volume: usize,
    /// Diagnostics of the most recent `ritz` publish, histories capped to
    /// the trailing [`RITZ_HISTORY_CAP`] entries so a long-lived session's
    /// memory stays bounded no matter how many iterations each solve ran.
    last_ritz: Option<RitzSummary>,
    publishes: usize,
}

impl StreamSession {
    pub fn new(graph: Graph, cfg: StreamConfig) -> StreamSession {
        StreamSession {
            graph,
            cfg,
            prev_embedding: None,
            prev_assignments: None,
            cached_order: None,
            cached_domain: None,
            delta_volume: 0,
            last_ritz: None,
            publishes: 0,
        }
    }

    /// Start from a graph loaded with a persisted `# order:` header
    /// ([`crate::graph::io::load_edge_list_with_order`]): the stored order
    /// seeds the cache and is reused until the first topology change.
    pub fn with_order(graph: Graph, order: Option<Vec<usize>>, cfg: StreamConfig) -> StreamSession {
        let mut s = StreamSession::new(graph, cfg);
        s.cached_order = order;
        s
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Embedding of the last publish, if any (input node order).
    pub fn embedding(&self) -> Option<&DMat> {
        self.prev_embedding.as_ref()
    }

    pub fn publishes(&self) -> usize {
        self.publishes
    }

    /// Capped diagnostics of the most recent `ritz` publish (`None` before
    /// the first one, or with a step-driven solver). `residual_history` /
    /// `locked_history` hold at most [`RITZ_HISTORY_CAP`] trailing entries;
    /// `residual_history_total` and the sweep counters stay uncapped.
    pub fn last_ritz(&self) -> Option<&RitzSummary> {
        self.last_ritz.as_ref()
    }

    /// Apply one transactional delta batch and invalidate exactly the
    /// derived state the outcome flags say broke. A failed batch (the
    /// `Err` side of [`Graph::apply_deltas`]) leaves the graph *and* every
    /// cache untouched — faults degrade to a rejected batch, never a
    /// poisoned session.
    pub fn apply_batch(&mut self, deltas: &[EdgeDelta]) -> Result<DeltaOutcome> {
        let outcome = self.graph.apply_deltas(deltas)?;
        self.delta_volume += outcome.volume();
        if outcome.topology_changed {
            // The node order is a topology artifact; a stale one must
            // neither drive a solve nor be written back to disk.
            self.cached_order = None;
        }
        if outcome.topology_changed || outcome.weights_changed {
            self.cached_domain = None;
        }
        Ok(outcome)
    }

    /// The spectral-domain estimate for the current matrix, re-estimated
    /// `O(nnz)` from the patched CSR only when a batch actually moved a
    /// Laplacian entry since the last call.
    pub fn domain(&mut self) -> Result<SpectrumEstimate> {
        if let Some(d) = self.cached_domain {
            return Ok(d);
        }
        let lc = self.graph.laplacian_csr();
        let threads = self.cfg.pipeline.threads.max(1);
        let est = self
            .cfg
            .pipeline
            .build
            .domain
            .estimate_csr(&lc, 0.0, threads)
            .context("re-estimating spectral domain after deltas")?;
        self.cached_domain = Some(est);
        Ok(est)
    }

    /// Run the pipeline on the current graph and refresh the published
    /// state. Warm-starts from the previous embedding when the solver is
    /// `ritz` and the accumulated churn is under
    /// [`StreamConfig::warm_volume_frac`]; the pipeline itself degrades a
    /// failing warm solve to cold, and the report says which path ran.
    pub fn publish(&mut self) -> Result<PublishReport> {
        let volume_frac = self.delta_volume as f64 / self.graph.num_edges().max(1) as f64;
        let mut pcfg = self.cfg.pipeline.clone();
        let force_cold = self.cfg.pipeline.solver != "ritz"
            || self.prev_embedding.is_none()
            // A zero-edge graph (a batch cut every community) has no
            // meaningful churn denominator, and any previous subspace is
            // worthless as a seed for the null Laplacian: always cold.
            || self.graph.num_edges() == 0
            || volume_frac > self.cfg.warm_volume_frac;
        pcfg.warm_start = if force_cold { None } else { self.prev_embedding.clone() };
        if pcfg.reorder == Reorder::Rcm {
            // One RCM rebuild per topology change, not per publish.
            let order = match self.cached_order.take() {
                Some(o) => o,
                None => self.graph.rcm_permutation(),
            };
            pcfg.rcm_order = Some(order.clone());
            self.cached_order = Some(order);
        } else {
            pcfg.rcm_order = None;
        }
        let out = Pipeline::new(pcfg).run(&self.graph)?;

        let (path, iterations, sweeps, converged) = match &out.ritz {
            Some(rz) => (rz.path, rz.iterations, rz.total_sweeps, rz.converged),
            None => (SolvePath::Cold, 0, 0, true),
        };
        let assignments =
            out.clustering.as_ref().map(|c| c.assignments.clone()).unwrap_or_default();
        // Drift accounting: the metrics assert on length mismatch, so the
        // comparison is routed by node-count relation up front. After node
        // growth the common prefix (pre-existing nodes) is still a valid
        // comparison; the full-vector ARI stays None with a reason.
        let (ari_vs_previous, ari_prefix_vs_previous, ari_reason) = match &self.prev_assignments {
            None => (None, None, Some("no previous publish to compare against")),
            Some(_) if assignments.is_empty() => (None, None, Some("clustering is off")),
            Some(prev) if prev.len() == assignments.len() => {
                (Some(adjusted_rand_index(prev, &assignments)), None, None)
            }
            Some(prev) if prev.len() < assignments.len() => (
                None,
                Some(adjusted_rand_index(prev, &assignments[..prev.len()])),
                Some("node count grew since the last publish (prefix ARI reported)"),
            ),
            Some(_) => (None, None, Some("node count shrank since the last publish")),
        };
        self.prev_embedding = Some(out.embedding.clone());
        if !assignments.is_empty() {
            self.prev_assignments = Some(assignments.clone());
        }
        if let Some(rz) = out.ritz {
            self.last_ritz = Some(rz.capped(RITZ_HISTORY_CAP));
        }
        self.delta_volume = 0;
        self.publishes += 1;
        Ok(PublishReport {
            path,
            iterations,
            sweeps,
            converged,
            assignments,
            ari_vs_previous,
            ari_prefix_vs_previous,
            ari_reason,
            volume_frac,
            lambda_star: out.lambda_star,
        })
    }

    /// Persist the current graph. The `# order:` header is written only
    /// when the cached order is still valid for the current topology —
    /// after a topology-changing batch the session either recomputed it
    /// (on an RCM publish) or dropped it, so a stale order is never
    /// saved for a mutated graph.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        crate::graph::io::save_edge_list_with_order(
            &self.graph,
            path,
            self.cached_order.as_deref(),
        )
    }
}

/// Parse a stream event file into delta batches: one delta per line in
/// the [`EdgeDelta::parse`] grammar, blank lines and `#` comments
/// skipped, a `---` line closes the current batch. Errors carry the
/// 1-based line number.
pub fn parse_event_batches(text: &str) -> Result<Vec<Vec<EdgeDelta>>> {
    let mut batches: Vec<Vec<EdgeDelta>> = Vec::new();
    let mut current: Vec<EdgeDelta> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "---" {
            if current.is_empty() {
                bail!("line {}: empty delta batch before `---`", lineno + 1);
            }
            batches.push(std::mem::take(&mut current));
            continue;
        }
        let d = EdgeDelta::parse(line).with_context(|| format!("line {}", lineno + 1))?;
        current.push(d);
    }
    if !current.is_empty() {
        batches.push(current);
    }
    Ok(batches)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, CliqueSpec};
    use crate::transforms::{OpMode, TransformKind};

    fn ritz_stream_cfg() -> StreamConfig {
        StreamConfig {
            pipeline: PipelineConfig {
                k: 3,
                transform: TransformKind::LimitNegExp { ell: 51 },
                solver: "ritz".into(),
                ritz_tol: 1e-8,
                ritz_max_iters: 400,
                op_mode: OpMode::MatrixFree,
                ground_truth: false,
                ..Default::default()
            },
            warm_volume_frac: 0.25,
        }
    }

    #[test]
    fn session_caps_retained_ritz_history() {
        // tol 0 can never be certified by a full-precision operator (the
        // floor clamp is a no-op at f64) and the default stagnation window
        // (100) is wider than max_iters, so this solve runs exactly 80
        // outer iterations — past RITZ_HISTORY_CAP — and stays Ok
        // (running out of iterations is honest non-convergence, not an
        // error).
        let gg = cliques(&CliqueSpec { n: 30, k: 3, max_short_circuit: 2, seed: 4 });
        let mut cfg = ritz_stream_cfg();
        cfg.pipeline.ritz_tol = 0.0;
        cfg.pipeline.ritz_max_iters = 80;
        let mut s = StreamSession::new(gg.graph.clone(), cfg);
        assert!(s.last_ritz().is_none(), "no publish yet");
        let rep = s.publish().unwrap();
        assert!(!rep.converged);
        assert_eq!(rep.iterations, 80);
        let rz = s.last_ritz().expect("ritz publish retains a summary");
        // Histories hold only the trailing window; totals stay honest.
        assert_eq!(rz.residual_history.len(), RITZ_HISTORY_CAP);
        assert_eq!(rz.locked_history.len(), RITZ_HISTORY_CAP);
        assert_eq!(rz.residual_history_total, 80);
        assert_eq!(rz.iterations, 80);
        assert_eq!(rz.total_sweeps, 80 * rz.sweeps_per_apply);
        assert!(rz.residual_history.iter().all(|r| r.is_finite()));
    }

    #[test]
    fn warm_publish_after_small_batch_and_cold_after_large() {
        let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 9 });
        let mut s = StreamSession::new(gg.graph.clone(), ritz_stream_cfg());
        let first = s.publish().unwrap();
        assert_eq!(first.path, SolvePath::Cold);
        assert!(first.ari_vs_previous.is_none());
        // A single reweight is well under the volume threshold → warm.
        let (u, v, w) = {
            let e = &gg.graph.edges()[0];
            (e.u as usize, e.v as usize, e.w)
        };
        s.apply_batch(&[EdgeDelta::Reweight { u, v, w: w * 1.5 }]).unwrap();
        let second = s.publish().unwrap();
        assert_eq!(second.path, SolvePath::Warm);
        assert!(second.converged);
        assert!(second.iterations < first.iterations, "warm should finish faster");
        assert!(
            second.ari_vs_previous.unwrap() > 0.99,
            "tiny reweight must not move clusters: ARI {:?}",
            second.ari_vs_previous
        );
        // A churn burst past the threshold forces the next publish cold.
        let mut big: Vec<EdgeDelta> = Vec::new();
        for e in gg.graph.edges().iter().take(gg.graph.num_edges() / 2) {
            big.push(EdgeDelta::Reweight { u: e.u as usize, v: e.v as usize, w: e.w * 0.9 });
        }
        s.apply_batch(&big).unwrap();
        let third = s.publish().unwrap();
        assert_eq!(third.path, SolvePath::Cold);
        assert!(third.volume_frac > 0.25);
    }

    #[test]
    fn node_growth_degrades_warm_start_instead_of_failing() {
        let gg = cliques(&CliqueSpec { n: 24, k: 2, max_short_circuit: 1, seed: 3 });
        let mut cfg = ritz_stream_cfg();
        cfg.pipeline.k = 2;
        cfg.warm_volume_frac = 10.0; // force the warm attempt even after growth
        let mut s = StreamSession::new(gg.graph, cfg);
        s.publish().unwrap();
        // Grow the graph: the cached embedding is now the wrong height, so
        // the warm attempt must fall back to cold, not error.
        s.apply_batch(&[
            EdgeDelta::AddNodes { count: 2 },
            EdgeDelta::Add { u: 0, v: 24, w: 1.0 },
            EdgeDelta::Add { u: 24, v: 25, w: 1.0 },
        ])
        .unwrap();
        let rep = s.publish().unwrap();
        assert_eq!(rep.path, SolvePath::WarmDegraded);
        assert!(rep.converged);
        assert_eq!(rep.assignments.len(), 26);
        assert!(rep.ari_vs_previous.is_none(), "ARI undefined across node counts");
        assert!(
            rep.ari_prefix_vs_previous.is_some(),
            "growth must still report the prefix drift"
        );
        assert!(rep.ari_reason.unwrap().contains("grew"), "{:?}", rep.ari_reason);
    }

    #[test]
    fn rejected_batch_leaves_session_usable_and_caches_valid() {
        let gg = cliques(&CliqueSpec { n: 24, k: 2, max_short_circuit: 1, seed: 3 });
        let mut cfg = ritz_stream_cfg();
        cfg.pipeline.k = 2;
        let mut s = StreamSession::new(gg.graph, cfg);
        s.publish().unwrap();
        let d0 = s.domain().unwrap();
        let before = s.graph().laplacian_csr();
        // NaN weight and out-of-range id: both rejected transactionally.
        assert!(s.apply_batch(&[EdgeDelta::Add { u: 0, v: 1, w: f64::NAN }]).is_err());
        assert!(s.apply_batch(&[EdgeDelta::Remove { u: 0, v: 999 }]).is_err());
        let after = s.graph().laplacian_csr();
        assert_eq!(before.values().len(), after.values().len());
        assert!(before
            .values()
            .iter()
            .zip(after.values().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        // Domain cache survived (nothing changed) and the next publish is
        // warm — the session was not poisoned.
        let d1 = s.domain().unwrap();
        assert_eq!(d0.rho.to_bits(), d1.rho.to_bits());
        let rep = s.publish().unwrap();
        assert_eq!(rep.path, SolvePath::Warm);
    }

    #[test]
    fn save_drops_order_after_topology_change_and_keeps_it_otherwise() {
        let gg = cliques(&CliqueSpec { n: 24, k: 2, max_short_circuit: 1, seed: 3 });
        let order = gg.graph.rcm_permutation();
        let dir = std::env::temp_dir().join("sped_stream_save_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        let mut cfg = ritz_stream_cfg();
        cfg.pipeline.k = 2;
        let mut s = StreamSession::with_order(gg.graph.clone(), Some(order), cfg);
        // Reweight-only batch: topology unchanged, order still valid.
        let (u0, v0, w0) = {
            let e = &gg.graph.edges()[0];
            (e.u as usize, e.v as usize, e.w)
        };
        s.apply_batch(&[EdgeDelta::Reweight { u: u0, v: v0, w: w0 * 2.0 }]).unwrap();
        s.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("# order:"), "valid order should persist");
        // Topology-changing batch (removing a known edge): the stale order
        // must not be written.
        s.apply_batch(&[EdgeDelta::Remove { u: u0, v: v0 }]).unwrap();
        s.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.contains("# order:"), "stale order must be dropped on save");
        // Round-trip sanity: the saved graph reloads to the mutated one.
        let (loaded, loaded_order) = crate::graph::io::load_edge_list_with_order(&path).unwrap();
        assert!(loaded_order.is_none());
        assert_eq!(loaded.num_edges(), s.graph().num_edges());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn event_batches_parse_with_line_numbered_errors() {
        let text = "# warm-up\nadd 0 5 1.0\nreweight 1 2 0.5\n---\nremove 3 4\n";
        let batches = parse_event_batches(text).unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].len(), 2);
        assert_eq!(batches[1].len(), 1);
        let err = parse_event_batches("add 0 1 1.0\n---\n---\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 3"), "{err:#}");
        let err = parse_event_batches("add 0 1\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 1"), "{err:#}");
    }
}
