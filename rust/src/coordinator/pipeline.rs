//! The end-to-end SPED pipeline: graph → spectral transform → reversed
//! solver matrix → iterative solver → bottom-k embedding → hard clusters.
//!
//! Two execution backends share the same orchestration:
//!
//! * [`Backend::Native`] — everything in-crate (dense f64).
//! * [`Backend::Xla`] — transform construction and solver chunks run as AOT
//!   XLA artifacts through the PJRT runtime (f32), with the graph padded to
//!   the nearest artifact size. This is the production path: Python is
//!   never invoked.

use crate::cluster::{cluster_embedding, KMeansResult};
use crate::graph::{Graph, Reorder};
use crate::linalg::dmat::DMat;
use crate::linalg::eigh;
use crate::linalg::metrics::ConvergenceHistory;
use crate::runtime::{pad_matrix, pad_rows, Runtime, XlaChunkRunner};
use crate::solvers::{solver_by_name, DenseOp, MatVecOp, RunConfig, SparsePolyOp};
use crate::transforms::{
    build_solver_matrix, BuildOptions, DomainEstimate, OpMode, PolyBasis, TransformKind,
};
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// Which engine executes the heavy math.
#[derive(Clone, Debug, Default)]
pub enum Backend {
    #[default]
    Native,
    /// Artifacts directory (usually `artifacts/`).
    Xla {
        artifacts_dir: String,
    },
}

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Number of bottom eigenvectors / clusters.
    pub k: usize,
    pub transform: TransformKind,
    /// `oja`, `mu-eg`, `subspace`/`direct` (step-driven), or `ritz` (block
    /// Rayleigh–Ritz; see [`crate::solvers::ritz`]).
    pub solver: String,
    pub eta: f64,
    pub steps: usize,
    pub eval_every: usize,
    /// Tolerance for the streak metric.
    pub streak_eps: f64,
    /// Early-stop subspace error (0 = run all steps).
    pub stop_error: f64,
    /// `--solver ritz` only: relative residual tolerance (converged once
    /// max wanted residual ≤ tol · ρ̂(M)).
    pub ritz_tol: f64,
    /// `--solver ritz` only: outer-iteration cap (each outer iteration is
    /// one operator bundle apply).
    pub ritz_max_iters: usize,
    /// `--solver ritz` only: block width (0 = auto: k + 2 guard vectors,
    /// clamped to n).
    pub block_size: usize,
    /// `--solver ritz` only: locked-convergence deflation
    /// (`--ritz-lock on|off`, default on) — freeze converged Ritz pairs
    /// and shrink the active block so SpMM column volume decays per sweep
    /// ([`crate::solvers::ritz::RitzConfig::lock`]). `false` restores the
    /// fixed-block iteration bit for bit.
    pub ritz_lock: bool,
    pub build: BuildOptions,
    pub backend: Backend,
    pub seed: u64,
    /// Run k-means on the converged embedding.
    pub do_cluster: bool,
    /// Worker threads for the native dense hot paths (transform build and
    /// the solver's `M·V`). Results are bitwise identical for every value
    /// (`linalg::par` determinism contract); `1` = serial.
    pub threads: usize,
    /// How the native solver operator is realized: materialized dense
    /// `n×n`, or matrix-free sparse (`O(ℓ·nnz·k)` per step, no `n×n`
    /// allocation after graph load).
    pub op_mode: OpMode,
    /// A precomputed node order for [`Reorder::Rcm`] (`order[new] = old`,
    /// the [`crate::graph::Graph::rcm_permutation`] convention), e.g. one
    /// persisted alongside the graph by
    /// [`crate::graph::io::save_edge_list_with_order`] and loaded back via
    /// the `# order:` header. When present the pipeline **skips the
    /// `O(E log E)` RCM rebuild** and relabels with the stored order
    /// directly (validated as a permutation; invalid orders error out).
    /// Ignored under [`Reorder::None`].
    pub rcm_order: Option<Vec<usize>>,
    /// Node reordering applied before the solve (`--reorder none|rcm`).
    /// [`Reorder::Rcm`] relabels nodes by Reverse Cuthill–McKee so the CSR
    /// nonzeros cluster around the diagonal — cache-local bundle access for
    /// the matrix-free SpMM kernels on power-law/mesh graphs. Outputs
    /// (embedding rows, cluster assignments) are un-permuted back to the
    /// input node order. The spectrum — and hence the converged partition —
    /// is relabeling-invariant; λ* is exactly so for the `−e^{−x}` family
    /// (λ* ≡ 0), and agrees to power-iteration precision otherwise (the
    /// λ_max start vector is index-salted, so its trailing bits can move
    /// under relabeling).
    pub reorder: Reorder,
    /// `--solver ritz` only: seed the block from a previous embedding
    /// (`n×k`, **input node order** — under [`Reorder::Rcm`] the pipeline
    /// permutes the rows into solve order itself). The warm columns are
    /// re-orthonormalized before use; if the warm-started solve fails
    /// (structured [`crate::solvers::ritz::SolveFailure`], an unusable warm
    /// block, or running out of iterations unconverged), the pipeline
    /// **degrades to a cold solve automatically** and reports it via
    /// [`RitzSummary::path`]. Ignored by the step-driven solvers.
    pub warm_start: Option<DMat>,
    /// Compute the exact bottom-k eigenvectors (an `O(n³)` dense `eigh`)
    /// as the metric oracle. **Default true** to preserve the historical
    /// output; set false when only cluster assignments are wanted — for
    /// n ≳ 2000 the oracle dominates wall-time, and with
    /// `OpMode::MatrixFree` disabling it makes the pipeline dense-free end
    /// to end. When false, the convergence history is empty and early stop
    /// is unavailable (the solver runs exactly `steps` steps).
    pub ground_truth: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            k: 4,
            transform: TransformKind::LimitNegExp { ell: 251 },
            solver: "oja".into(),
            eta: 0.1,
            steps: 10_000,
            eval_every: 50,
            streak_eps: 1e-2,
            stop_error: 1e-4,
            ritz_tol: 1e-8,
            ritz_max_iters: 500,
            block_size: 0,
            ritz_lock: true,
            build: BuildOptions::default(),
            backend: Backend::Native,
            seed: 0,
            do_cluster: true,
            threads: 1,
            op_mode: OpMode::DenseMaterialized,
            rcm_order: None,
            reorder: Reorder::None,
            warm_start: None,
            ground_truth: true,
        }
    }
}

/// Timings of the pipeline stages (seconds).
#[derive(Clone, Debug, Default)]
pub struct StageTimings {
    pub ground_truth: f64,
    pub transform_build: f64,
    pub solve: f64,
    pub cluster: f64,
}

/// Pipeline output.
pub struct PipelineOutput {
    /// Convergence curve against the exact bottom-k eigenvectors.
    pub history: ConvergenceHistory,
    /// Final `n×k` embedding (bottom-k estimate, original node order).
    pub embedding: DMat,
    /// Hard cluster assignment (if `do_cluster`).
    pub clustering: Option<KMeansResult>,
    pub timings: StageTimings,
    /// The reversal shift used (eq 8).
    pub lambda_star: f64,
    /// Solver-internal diagnostics of a `--solver ritz` run (`None` for
    /// the step-driven solvers).
    pub ritz: Option<RitzSummary>,
}

/// Which solve actually produced a `--solver ritz` embedding — the honest
/// record streaming callers pin their warm-vs-cold accounting on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolvePath {
    /// No warm start was offered; the block was seeded deterministically.
    Cold,
    /// The warm-started solve converged and its result was kept.
    Warm,
    /// A warm start was offered but the warm solve failed (structured
    /// solver failure, unusable warm block, or unconverged at the
    /// iteration cap) — the pipeline fell back to a cold solve.
    WarmDegraded,
}

impl std::fmt::Display for SolvePath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SolvePath::Cold => "cold",
            SolvePath::Warm => "warm",
            SolvePath::WarmDegraded => "warm-degraded",
        })
    }
}

/// Trailing-window size long-lived sessions keep of a
/// [`RitzSummary`]'s per-iteration histories ([`RitzSummary::capped`]):
/// stream/serve retain one summary per publish, so an unbounded history
/// would grow memory linearly in publish count × solve iterations.
pub const RITZ_HISTORY_CAP: usize = 64;

/// What a `--solver ritz` run reports about itself: residual-based
/// convergence (self-measured — available even with `ground_truth` off)
/// and the SpMM-sweep accounting the dilated-vs-undilated comparison is
/// stated in.
#[derive(Clone, Debug)]
pub struct RitzSummary {
    /// Outer iterations executed (= operator bundle applies).
    pub iterations: usize,
    /// Whether `ritz_tol` was met before `ritz_max_iters`.
    pub converged: bool,
    /// SpMM sweeps one bundle apply costs (polynomial degree for the
    /// matrix-free operator, 1 for dense).
    pub sweeps_per_apply: usize,
    /// `iterations · sweeps_per_apply`.
    pub total_sweeps: usize,
    /// SpMM **column** sweeps actually spent
    /// ([`crate::solvers::ritz::RitzResult::col_sweeps`]): equal to
    /// `total_sweeps · block` for a fixed block, strictly smaller once
    /// deflation locks pairs.
    pub col_sweeps: usize,
    /// Halo bundle-row volume a sharded operator exchanged (`--shards N`;
    /// `0` unsharded).
    pub halo_volume: usize,
    /// Ritz pairs locked when the solve finished (`0` with
    /// `--ritz-lock off`).
    pub locked: usize,
    /// Locked-pair count after each outer iteration (aligned with
    /// `residual_history`; capped together with it by [`Self::capped`]).
    pub locked_history: Vec<usize>,
    /// Per-outer-iteration max residual over the k wanted Ritz pairs.
    /// Possibly capped to a trailing window by [`Self::capped`] — check
    /// `residual_history_total` for the uncapped length.
    pub residual_history: Vec<f64>,
    /// Outer iterations the solve actually recorded —
    /// `residual_history.len()` unless [`Self::capped`] dropped a prefix.
    pub residual_history_total: usize,
    /// Final per-pair residual norms `‖M·x_i − θ_i·x_i‖`.
    pub residuals: Vec<f64>,
    /// Ritz values of `M` for the embedding columns (descending).
    pub values: Vec<f64>,
    /// Which solve produced the embedding (cold / warm / warm-degraded).
    pub path: SolvePath,
}

impl RitzSummary {
    /// Bound the per-iteration histories to the trailing `cap` entries,
    /// keeping the honest totals (`residual_history_total`, `iterations`,
    /// sweep counters) intact. Long-running stream/serve sessions retain
    /// one summary per publish — without the cap their memory grows
    /// linearly in solve iterations × publish count. A `cap` of 0 keeps
    /// nothing but the totals.
    pub fn capped(mut self, cap: usize) -> RitzSummary {
        self.residual_history_total = self.residual_history_total.max(self.residual_history.len());
        if self.residual_history.len() > cap {
            self.residual_history.drain(..self.residual_history.len() - cap);
        }
        if self.locked_history.len() > cap {
            self.locked_history.drain(..self.locked_history.len() - cap);
        }
        self
    }
}

/// The pipeline orchestrator.
pub struct Pipeline {
    pub cfg: PipelineConfig,
}

impl Pipeline {
    pub fn new(cfg: PipelineConfig) -> Pipeline {
        Pipeline { cfg }
    }

    /// Run end-to-end on `graph`.
    ///
    /// With [`PipelineConfig::reorder`] set, the solve runs on the
    /// relabeled graph and the outputs are un-permuted back to the input
    /// node order before returning — reordering is a locality optimization,
    /// not a semantic change.
    pub fn run(&self, graph: &Graph) -> Result<PipelineOutput> {
        let cfg = &self.cfg;
        let n = graph.num_nodes();
        if cfg.k == 0 || cfg.k > n {
            bail!("k={} out of range for n={n}", cfg.k);
        }
        match cfg.reorder {
            Reorder::None => self.run_ordered(graph),
            Reorder::Rcm => {
                // A persisted order (graph IO `# order:` header →
                // `PipelineConfig::rcm_order`) skips the O(E log E)
                // rebuild; `permute` validates it is a permutation.
                let order = match &cfg.rcm_order {
                    Some(stored) => stored.clone(),
                    None => graph.rcm_permutation(),
                };
                let permuted = graph.permute(&order)?;
                // A warm embedding arrives in input node order; the solve
                // runs on the relabeled graph, so gather its rows into
                // solve order (`permuted[new] = warm[order[new]]`). A warm
                // block of the wrong height cannot be permuted — pass it
                // through untouched and let the solver-side validation
                // reject it into the cold fallback.
                let mut out = match &cfg.warm_start {
                    Some(warm) if warm.rows() == n => {
                        let mut pw = DMat::zeros(n, warm.cols());
                        for (new, &old) in order.iter().enumerate() {
                            pw.row_mut(new).copy_from_slice(warm.row(old));
                        }
                        let mut sub = self.cfg.clone();
                        sub.warm_start = Some(pw);
                        Pipeline::new(sub).run_ordered(&permuted)?
                    }
                    _ => self.run_ordered(&permuted)?,
                };
                // Permuted row `new` holds node `order[new]`: scatter the
                // embedding rows and hard labels back to input node order.
                let k = out.embedding.cols();
                let mut embedding = DMat::zeros(n, k);
                for (new, &old) in order.iter().enumerate() {
                    embedding.row_mut(old).copy_from_slice(out.embedding.row(new));
                }
                out.embedding = embedding;
                if let Some(cl) = &mut out.clustering {
                    let mut assignments = vec![0usize; n];
                    for (new, &old) in order.iter().enumerate() {
                        assignments[old] = cl.assignments[new];
                    }
                    cl.assignments = assignments;
                }
                Ok(out)
            }
        }
    }

    /// [`Self::run`] on an already-ordered graph (the backend dispatch).
    fn run_ordered(&self, graph: &Graph) -> Result<PipelineOutput> {
        let cfg = &self.cfg;
        let timings = StageTimings::default();

        match &cfg.backend {
            Backend::Native => self.run_native(graph, timings),
            Backend::Xla { artifacts_dir } => {
                if cfg.op_mode == OpMode::MatrixFree {
                    bail!("matrix-free op mode requires the native backend");
                }
                if cfg.build.basis == PolyBasis::Chebyshev {
                    // The AOT artifacts encode the Horner (monomial)
                    // evaluation; no silent fallback.
                    bail!(
                        "--basis chebyshev requires the native backend (the XLA \
                         poly_horner/matpow artifacts are monomial-basis)"
                    );
                }
                if cfg.build.domain != DomainEstimate::Power {
                    // The XLA build hand-rolls the historical power-domain
                    // flow; the tight-domain policies are native-only.
                    bail!(
                        "--domain {} requires the native backend (the XLA build \
                         uses the power-iteration domain)",
                        cfg.build.domain
                    );
                }
                if !cfg.build.degree.is_native() {
                    bail!(
                        "--degree {} requires the native backend with --basis \
                         chebyshev (the XLA artifacts evaluate the native degree)",
                        cfg.build.degree
                    );
                }
                if cfg.build.precision.is_mixed() {
                    // The XLA artifacts already run their own f32 chunk
                    // protocol; the mixed CSR path is native-only.
                    bail!(
                        "--precision mixed requires the native backend (the XLA \
                         artifacts run their own f32 protocol); use --precision f64"
                    );
                }
                if cfg.build.shards > 0 {
                    // The halo-exchange sharded apply lives in the native
                    // matrix-free kernels; the XLA artifacts are dense.
                    bail!(
                        "--shards requires the native backend with --op-mode \
                         sparse (the XLA artifacts have no halo schedule)"
                    );
                }
                if !cfg.ground_truth {
                    // The XLA chunk protocol consumes the oracle bundle.
                    bail!("ground_truth=false requires the native backend");
                }
                let mut timings = timings;
                let l = graph.laplacian();
                // Ground truth for metrics (the oracle; the thing SPED
                // avoids needing *during* iteration — but the experiment
                // protocol of §5.2 measures against it).
                let t0 = Instant::now();
                let e = eigh(&l).context("ground-truth eigendecomposition")?;
                let v_star = e.bottom_k(cfg.k);
                let values = e.values[..cfg.k].to_vec();
                timings.ground_truth = t0.elapsed().as_secs_f64();
                let rt = Runtime::load_dir(artifacts_dir)?;
                self.run_xla(&rt, graph, &l, &v_star, &values, timings)
            }
        }
    }

    fn run_native(&self, graph: &Graph, mut timings: StageTimings) -> Result<PipelineOutput> {
        let cfg = &self.cfg;
        // The pipeline-level knob overrides the build options' default so a
        // single `threads` setting drives both the transform build and the
        // solver's M·V products.
        let mut build = cfg.build;
        build.threads = cfg.threads.max(build.threads).max(1);

        if build.precision.is_mixed() && cfg.ground_truth {
            // Ground truth is the exact f64 oracle; pairing it with a
            // demoted-arithmetic operator would report convergence curves
            // whose floor is the f32 budget, not the solver — reject rather
            // than publish misleading metrics.
            bail!(
                "--precision mixed cannot drive a ground-truth run (the oracle \
                 certifies f64 trajectories); disable ground truth or use \
                 --precision f64"
            );
        }

        // The dense Laplacian is needed by the ground-truth oracle and the
        // dense operator path; the matrix-free path without metrics never
        // materializes it (or any other n×n buffer).
        let need_dense_l = cfg.ground_truth || cfg.op_mode == OpMode::DenseMaterialized;
        let l: Option<DMat> = if need_dense_l { Some(graph.laplacian()) } else { None };

        // Ground truth for metrics (the oracle; the thing SPED avoids
        // needing *during* iteration — the experiment protocol of §5.2
        // measures against it, but callers who only want assignments can
        // skip the O(n³) eigh entirely).
        let t0 = Instant::now();
        let ground: Option<(DMat, Vec<f64>)> = if cfg.ground_truth {
            let e = eigh(l.as_ref().unwrap()).context("ground-truth eigendecomposition")?;
            timings.ground_truth = t0.elapsed().as_secs_f64();
            Some((e.bottom_k(cfg.k), e.values[..cfg.k].to_vec()))
        } else {
            None
        };

        let t0 = Instant::now();
        let (mut op, lambda_star): (Box<dyn MatVecOp>, f64) = match cfg.op_mode {
            OpMode::DenseMaterialized => {
                let sm = build_solver_matrix(l.as_ref().unwrap(), cfg.transform, &build)?;
                let lambda_star = sm.lambda_star;
                let op = Box::new(DenseOp { m: sm.m, threads: build.threads }) as Box<dyn MatVecOp>;
                (op, lambda_star)
            }
            OpMode::MatrixFree => {
                let sp = SparsePolyOp::from_graph(graph, cfg.transform, &build)?;
                let lambda_star = sp.lambda_star;
                (Box::new(sp) as Box<dyn MatVecOp>, lambda_star)
            }
        };
        timings.transform_build = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let (mut history, embedding, ritz) = if cfg.solver == "ritz" {
            // Block Rayleigh–Ritz owns its own convergence measurement
            // (residual norms, no oracle needed), so it bypasses the
            // step-driven run loop entirely.
            let rcfg = crate::solvers::ritz::RitzConfig {
                k: cfg.k,
                block: cfg.block_size,
                tol: cfg.ritz_tol,
                max_iters: cfg.ritz_max_iters,
                lock: cfg.ritz_lock,
                ..Default::default()
            };
            // Graceful degradation: a warm start is an optimization, never
            // a correctness dependency. If the warm-started solve errors
            // (non-finite blowup, stagnation, unusable warm block) or runs
            // out of iterations unconverged, rerun cold and say so — a
            // genuine operator defect will fail the cold solve too and
            // surface as the error it is.
            let (res, path) = match &cfg.warm_start {
                Some(warm) => {
                    let wcfg = crate::solvers::ritz::RitzConfig {
                        warm_start: Some(warm.clone()),
                        ..rcfg.clone()
                    };
                    match crate::solvers::ritz::ritz_solve(op.as_mut(), &wcfg) {
                        Ok(res) if res.converged => (res, SolvePath::Warm),
                        _ => (
                            crate::solvers::ritz::ritz_solve(op.as_mut(), &rcfg)?,
                            SolvePath::WarmDegraded,
                        ),
                    }
                }
                None => (crate::solvers::ritz::ritz_solve(op.as_mut(), &rcfg)?, SolvePath::Cold),
            };
            let mut history = ConvergenceHistory::new("");
            if let Some((v_star, values)) = &ground {
                // With the oracle available, record one endpoint datapoint
                // in the usual metric (subspace error + grouped streak) so
                // downstream reporting/CSV stays uniform.
                let err = crate::linalg::metrics::subspace_error(v_star, &res.embedding);
                let streak = crate::linalg::metrics::eigenvector_streak_grouped(
                    v_star,
                    values,
                    &res.embedding,
                    cfg.streak_eps,
                    1e-9,
                );
                history.push(res.iterations, err, streak);
            }
            let summary = RitzSummary {
                iterations: res.iterations,
                converged: res.converged,
                sweeps_per_apply: res.sweeps_per_apply,
                total_sweeps: res.total_sweeps,
                col_sweeps: res.col_sweeps,
                halo_volume: res.halo_volume,
                locked: res.locked,
                locked_history: res.locked_history,
                residual_history_total: res.history.len(),
                residual_history: res.history.iter().map(|p| p.max_residual).collect(),
                residuals: res.residuals,
                values: res.values,
                path,
            };
            (history, res.embedding, Some(summary))
        } else {
            let mut solver = solver_by_name(&cfg.solver, cfg.eta)?;
            let (history, embedding) = match &ground {
                Some((v_star, values)) => {
                    let run_cfg = RunConfig {
                        steps: cfg.steps,
                        eval_every: cfg.eval_every,
                        streak_eps: cfg.streak_eps,
                        stop_error: cfg.stop_error,
                        seed: cfg.seed,
                        // Degeneracy-aware streak: symmetric workloads
                        // (3-room MDP) have exactly tied eigenvalues.
                        group_values: Some(values.clone()),
                    };
                    crate::solvers::run_convergence_full(
                        solver.as_mut(),
                        op.as_mut(),
                        v_star,
                        &run_cfg,
                    )
                }
                None => {
                    let v = crate::solvers::run_steps(
                        solver.as_mut(),
                        op.as_mut(),
                        cfg.k,
                        cfg.steps,
                        cfg.seed,
                    );
                    (ConvergenceHistory::new(""), v)
                }
            };
            (history, embedding, None)
        };
        history.label = format!("{}:{}", cfg.solver, cfg.transform.name());
        timings.solve = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let clustering = if cfg.do_cluster {
            Some(cluster_embedding(&embedding, cfg.k, cfg.seed ^ 0xC1u64))
        } else {
            None
        };
        timings.cluster = t0.elapsed().as_secs_f64();

        Ok(PipelineOutput { history, embedding, clustering, timings, lambda_star, ritz })
    }

    fn run_xla(
        &self,
        rt: &Runtime,
        graph: &Graph,
        l: &DMat,
        v_star: &DMat,
        values: &[f64],
        mut timings: StageTimings,
    ) -> Result<PipelineOutput> {
        let cfg = &self.cfg;
        let n = graph.num_nodes();

        // ---- transform build (XLA artifacts) ----
        let t0 = Instant::now();
        let m_unpadded = self.build_m_xla(rt, l)?;
        timings.transform_build = t0.elapsed().as_secs_f64();

        // ---- solver chunks (XLA) ----
        let chunk_kind = match cfg.solver.as_str() {
            "oja" => "oja_chunk",
            "mu-eg" | "eg" | "mu_eg" => "eg_chunk",
            other => bail!("XLA backend supports oja / mu-eg, not {other:?}"),
        };
        let artifact = rt.best_fit(chunk_kind, n)?;
        let size = artifact.meta.n;
        let ak = artifact.meta.k;
        if cfg.k > ak {
            bail!("k={} exceeds artifact k={ak}", cfg.k);
        }
        // Pad M with diagonal below its spectrum floor so padding dims rank
        // last; pad V* with zero rows (padded dims have zero ground-truth
        // weight; metrics on the first cfg.k columns are unaffected).
        let m_padded = pad_matrix(&m_unpadded, size, -1.0);
        // Ground truth padded to artifact k: extra columns use the next
        // exact eigenvectors so in-graph metrics stay meaningful.
        let e_full = eigh(l)?;
        let v_star_wide = pad_rows(&e_full.bottom_k(ak.min(n)), size);
        let v_star_wide = if ak <= n {
            v_star_wide
        } else {
            // Degenerate tiny-graph case: right-pad columns with unit axes.
            let mut w = DMat::zeros(size, ak);
            for i in 0..size {
                for j in 0..v_star_wide.cols() {
                    w[(i, j)] = v_star_wide[(i, j)];
                }
            }
            for (extra, j) in (v_star_wide.cols()..ak).enumerate() {
                w[(n + extra, j)] = 1.0;
            }
            w
        };

        let t0 = Instant::now();
        let runner = XlaChunkRunner::new(artifact.clone(), &m_padded)?;
        let mut v = pad_rows(&crate::solvers::random_init(n, ak, cfg.seed), size);
        let mut history = ConvergenceHistory::new(format!(
            "{}:{}:xla{}",
            cfg.solver,
            cfg.transform.name(),
            size
        ));
        // Step-0 metrics (native measurement, first cfg.k columns).
        let v0 = take_embedding(&v, n, cfg.k);
        let streak_of = |vk: &DMat| {
            crate::linalg::metrics::eigenvector_streak_grouped(
                v_star,
                values,
                vk,
                cfg.streak_eps,
                1e-9,
            )
        };
        history.push(
            0,
            crate::linalg::metrics::subspace_error(v_star, &v0),
            streak_of(&v0),
        );
        let t = artifact.meta.t;
        let mut step = 0;
        while step < cfg.steps {
            let out = runner.run_chunk(&v, &v_star_wide, cfg.eta)?;
            v = out.v;
            // In-graph metrics are per chunk-step on the padded/wide bundle;
            // record the k-restricted native metrics at chunk boundaries
            // (cheap: n×k) and keep the in-graph series for diagnostics.
            step += t;
            let vk = take_embedding(&v, n, cfg.k);
            let err = crate::linalg::metrics::subspace_error(v_star, &vk);
            let streak = streak_of(&vk);
            history.push(step, err, streak);
            if cfg.stop_error > 0.0 && streak == cfg.k && err < cfg.stop_error {
                break;
            }
        }
        timings.solve = t0.elapsed().as_secs_f64();

        let embedding = take_embedding(&v, n, cfg.k);
        let t0 = Instant::now();
        let clustering = if cfg.do_cluster {
            Some(cluster_embedding(&embedding, cfg.k, cfg.seed ^ 0xC1u64))
        } else {
            None
        };
        timings.cluster = t0.elapsed().as_secs_f64();
        let lambda_star = cfg.transform.lambda_star(
            crate::linalg::funcs::power_lambda_max(l, cfg.build.power_iters)? * cfg.build.safety,
        );
        Ok(PipelineOutput { history, embedding, clustering, timings, lambda_star, ritz: None })
    }

    /// Build `M = λ*I − f(L)` using XLA artifacts where the transform is a
    /// series (poly_horner / matpow); exact transforms fall back to the
    /// native eigendecomposition (they are the oracle baselines).
    fn build_m_xla(&self, rt: &Runtime, l: &DMat) -> Result<DMat> {
        let cfg = &self.cfg;
        let n = l.rows();
        let lam_est =
            crate::linalg::funcs::power_lambda_max(l, cfg.build.power_iters)? * cfg.build.safety;
        let rho = if lam_est > 0.0 { lam_est } else { 1.0 };
        let lambda_star = cfg.transform.lambda_star(rho);
        let f_l = match cfg.transform {
            TransformKind::Identity => l.clone(),
            TransformKind::MatrixLog { .. } | TransformKind::NegExp => cfg.transform.build(l)?,
            TransformKind::TaylorLog { .. } | TransformKind::TaylorNegExp { .. } => {
                let series = cfg.transform.series().expect("series kind");
                let artifact = rt.best_fit("poly_horner", n)?;
                let l_pad = pad_matrix(l, artifact.meta.n, 0.0);
                let f_pad = crate::runtime::xla_poly_build(
                    &artifact,
                    &l_pad,
                    series.shift,
                    &series.coeffs,
                )?;
                unpad(&f_pad, n)
            }
            TransformKind::LimitNegExp { ell } => {
                let artifact = rt.best_fit("matpow", n)?;
                // B = I − L/ℓ on the padded matrix (pad diag 0 → B pad diag 1
                // → power stays 1; unpad drops it anyway).
                let mut b = pad_matrix(l, artifact.meta.n, 0.0);
                b.scale(-1.0 / ell as f64);
                b.add_diag(1.0);
                let p = crate::runtime::xla_matpow(&artifact, &b, ell as u64)?;
                let mut f = unpad(&p, n);
                f.scale(-1.0);
                f
            }
        };
        let mut m = f_l;
        m.scale(-1.0);
        m.add_diag(lambda_star);
        Ok(m)
    }
}

/// First `k` columns / `n` rows of a padded bundle.
fn take_embedding(v: &DMat, n: usize, k: usize) -> DMat {
    DMat::from_fn(n, k, |i, j| v[(i, j)])
}

/// Top-left `n×n` block.
fn unpad(m: &DMat, n: usize) -> DMat {
    DMat::from_fn(n, n, |i, j| m[(i, j)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::adjusted_rand_index;
    use crate::graph::gen::{cliques, CliqueSpec};
    use crate::transforms::Precision;

    #[test]
    fn native_pipeline_end_to_end() {
        let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 1 });
        let cfg = PipelineConfig {
            k: 3,
            transform: TransformKind::NegExp,
            solver: "oja".into(),
            eta: 0.1,
            steps: 5000,
            eval_every: 50,
            stop_error: 1e-6,
            ..Default::default()
        };
        let out = Pipeline::new(cfg).run(&gg.graph).unwrap();
        let last = out.history.last().unwrap();
        assert!(last.subspace_error < 1e-3, "err {}", last.subspace_error);
        let ari = adjusted_rand_index(
            &out.clustering.as_ref().unwrap().assignments,
            &gg.labels,
        );
        assert!(ari > 0.9, "ARI {ari}");
        assert!(out.timings.ground_truth > 0.0);
    }

    #[test]
    fn threaded_pipeline_bitwise_matches_serial() {
        // The whole native pipeline — transform build AND solver steps —
        // must be invariant to the worker count, bit for bit.
        let gg = cliques(&CliqueSpec { n: 30, k: 3, max_short_circuit: 2, seed: 4 });
        let mk = |threads| PipelineConfig {
            k: 3,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "oja".into(),
            eta: 0.3,
            steps: 400,
            eval_every: 20,
            stop_error: 1e-9,
            threads,
            ..Default::default()
        };
        let serial = Pipeline::new(mk(1)).run(&gg.graph).unwrap();
        let par = Pipeline::new(mk(4)).run(&gg.graph).unwrap();
        assert!(serial
            .embedding
            .data()
            .iter()
            .zip(par.embedding.data().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(serial.history.points.len(), par.history.points.len());
        for (a, b) in serial.history.points.iter().zip(par.history.points.iter()) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.subspace_error.to_bits(), b.subspace_error.to_bits());
            assert_eq!(a.streak, b.streak);
        }
        assert_eq!(serial.lambda_star.to_bits(), par.lambda_star.to_bits());
    }

    #[test]
    fn matrix_free_mode_skips_oracle_and_matches_dense_mode() {
        let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 9 });
        let mk = |op_mode, ground_truth| PipelineConfig {
            k: 3,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "subspace".into(),
            steps: 300,
            eval_every: 20,
            stop_error: 0.0, // fixed step count → comparable endpoints
            op_mode,
            ground_truth,
            ..Default::default()
        };
        let dense = Pipeline::new(mk(OpMode::DenseMaterialized, true)).run(&gg.graph).unwrap();
        let sparse = Pipeline::new(mk(OpMode::MatrixFree, false)).run(&gg.graph).unwrap();
        // Dense-free run: no oracle timing, no history points.
        assert_eq!(sparse.timings.ground_truth, 0.0);
        assert!(sparse.history.points.is_empty());
        assert!(!dense.history.points.is_empty());
        // Same λ* (negexp family: exactly 0) and near-identical embeddings.
        assert_eq!(sparse.lambda_star, 0.0);
        assert_eq!(dense.lambda_star, 0.0);
        let err = crate::linalg::metrics::subspace_error(&dense.embedding, &sparse.embedding);
        assert!(err < 1e-6, "dense vs matrix-free subspace err {err}");
        // And identical hard clusters.
        assert_eq!(
            dense.clustering.as_ref().unwrap().assignments,
            sparse.clustering.as_ref().unwrap().assignments
        );
    }

    #[test]
    fn ritz_solver_pipeline_dense_free_run_matches_dense_path() {
        // The acceptance flow: `--solver ritz --op sparse --no-ground-truth`
        // must produce the same partition as the dense-materialized run,
        // while reporting residual-based diagnostics with no oracle at all.
        let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 9 });
        let mk = |op_mode, ground_truth| PipelineConfig {
            k: 3,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "ritz".into(),
            ritz_tol: 1e-10,
            ritz_max_iters: 300,
            op_mode,
            ground_truth,
            ..Default::default()
        };
        let dense = Pipeline::new(mk(OpMode::DenseMaterialized, true)).run(&gg.graph).unwrap();
        let sparse = Pipeline::new(mk(OpMode::MatrixFree, false)).run(&gg.graph).unwrap();
        // Dense-free: no oracle timing, no history — but the solver's own
        // residual diagnostics are fully populated.
        assert_eq!(sparse.timings.ground_truth, 0.0);
        assert!(sparse.history.points.is_empty());
        let rz = sparse.ritz.as_ref().unwrap();
        assert!(rz.converged, "ritz did not converge in {} iters", rz.iterations);
        assert_eq!(rz.residual_history.len(), rz.iterations);
        assert!(rz.sweeps_per_apply > 1, "matrix-free apply should cost degree sweeps");
        assert_eq!(rz.total_sweeps, rz.iterations * rz.sweeps_per_apply);
        assert_eq!(rz.residuals.len(), 3);
        assert_eq!(rz.values.len(), 3);
        // Dense run records one oracle endpoint, and it is converged.
        let last = dense.history.last().unwrap();
        assert!(last.subspace_error < 1e-8, "oracle err {}", last.subspace_error);
        assert_eq!(dense.ritz.as_ref().unwrap().sweeps_per_apply, 1);
        // Same subspace and identical hard clusters across the two paths.
        let err = crate::linalg::metrics::subspace_error(&dense.embedding, &sparse.embedding);
        assert!(err < 1e-6, "dense vs matrix-free ritz subspace err {err}");
        assert_eq!(
            dense.clustering.as_ref().unwrap().assignments,
            sparse.clustering.as_ref().unwrap().assignments
        );
    }

    #[test]
    fn warm_started_ritz_reuses_embedding_and_degrades_gracefully() {
        let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 9 });
        let mk = |warm_start| PipelineConfig {
            k: 3,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "ritz".into(),
            ritz_tol: 1e-10,
            ritz_max_iters: 300,
            op_mode: OpMode::MatrixFree,
            ground_truth: false,
            warm_start,
            ..Default::default()
        };
        let cold = Pipeline::new(mk(None)).run(&gg.graph).unwrap();
        assert_eq!(cold.ritz.as_ref().unwrap().path, SolvePath::Cold);
        // Seeding from the converged embedding must keep the partition and
        // converge in strictly fewer outer iterations.
        let warm = Pipeline::new(mk(Some(cold.embedding.clone()))).run(&gg.graph).unwrap();
        let (wz, cz) = (warm.ritz.as_ref().unwrap(), cold.ritz.as_ref().unwrap());
        assert_eq!(wz.path, SolvePath::Warm);
        assert!(wz.converged);
        assert!(
            wz.iterations < cz.iterations,
            "warm {} vs cold {} iterations",
            wz.iterations,
            cz.iterations
        );
        assert_eq!(
            warm.clustering.as_ref().unwrap().assignments,
            cold.clustering.as_ref().unwrap().assignments
        );
        // An unusable warm block (wrong height: stale embedding from a graph
        // that has since grown) must silently fall back to the cold solve —
        // same answer, honest path report.
        let degraded = Pipeline::new(mk(Some(DMat::zeros(5, 3)))).run(&gg.graph).unwrap();
        let dz = degraded.ritz.as_ref().unwrap();
        assert_eq!(dz.path, SolvePath::WarmDegraded);
        assert!(dz.converged);
        assert_eq!(
            degraded.clustering.as_ref().unwrap().assignments,
            cold.clustering.as_ref().unwrap().assignments
        );
        // Under RCM reorder the warm rows (input node order) are permuted
        // into solve order — the warm path must still engage and agree.
        let rcm_cfg = PipelineConfig {
            reorder: crate::graph::Reorder::Rcm,
            ..mk(Some(cold.embedding.clone()))
        };
        let rcm = Pipeline::new(rcm_cfg).run(&gg.graph).unwrap();
        assert_eq!(rcm.ritz.as_ref().unwrap().path, SolvePath::Warm);
        let canon = |a: &[usize]| {
            let mut map = std::collections::HashMap::new();
            a.iter()
                .map(|&c| {
                    let next = map.len();
                    *map.entry(c).or_insert(next)
                })
                .collect::<Vec<usize>>()
        };
        assert_eq!(
            canon(&rcm.clustering.as_ref().unwrap().assignments),
            canon(&cold.clustering.as_ref().unwrap().assignments)
        );
    }

    #[test]
    fn matrix_free_rejects_exact_transforms_and_xla_backend() {
        let gg = cliques(&CliqueSpec { n: 12, k: 2, max_short_circuit: 1, seed: 2 });
        let cfg = PipelineConfig {
            k: 2,
            transform: TransformKind::NegExp,
            op_mode: OpMode::MatrixFree,
            ..Default::default()
        };
        assert!(Pipeline::new(cfg).run(&gg.graph).is_err(), "exact transform must be rejected");
        let cfg = PipelineConfig {
            k: 2,
            op_mode: OpMode::MatrixFree,
            backend: Backend::Xla { artifacts_dir: "artifacts".into() },
            ..Default::default()
        };
        assert!(Pipeline::new(cfg).run(&gg.graph).is_err(), "matrix-free is native-only");
    }

    #[test]
    fn rcm_reorder_is_invisible_to_callers() {
        // --reorder rcm must recover the same hard partition (and the same
        // λ*, exactly 0 for the negexp family) as the unreordered run, with
        // outputs already back in input node order.
        let gg = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 11 });
        let mk = |reorder| PipelineConfig {
            k: 3,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "subspace".into(),
            steps: 400,
            eval_every: 20,
            stop_error: 0.0,
            op_mode: OpMode::MatrixFree,
            ground_truth: false,
            reorder,
            ..Default::default()
        };
        let plain = Pipeline::new(mk(crate::graph::Reorder::None)).run(&gg.graph).unwrap();
        let rcm = Pipeline::new(mk(crate::graph::Reorder::Rcm)).run(&gg.graph).unwrap();
        assert_eq!(plain.lambda_star.to_bits(), rcm.lambda_star.to_bits());
        assert_eq!(rcm.embedding.rows(), 48);
        // Same subspace (trajectories differ — the solver init is not
        // permutation-equivariant — but both converge to the bottom-k).
        let err = crate::linalg::metrics::subspace_error(&plain.embedding, &rcm.embedding);
        assert!(err < 1e-6, "reordered subspace err {err}");
        // Identical partition up to cluster-id naming, in input node order.
        let canon = |a: &[usize]| {
            let mut map = std::collections::HashMap::new();
            a.iter()
                .map(|&c| {
                    let next = map.len();
                    *map.entry(c).or_insert(next)
                })
                .collect::<Vec<usize>>()
        };
        assert_eq!(
            canon(&plain.clustering.as_ref().unwrap().assignments),
            canon(&rcm.clustering.as_ref().unwrap().assignments)
        );
        let ari = adjusted_rand_index(&rcm.clustering.as_ref().unwrap().assignments, &gg.labels);
        assert!(ari > 0.9, "ARI {ari}");
    }

    #[test]
    fn chebyshev_basis_pipeline_matches_monomial_partition() {
        // --basis chebyshev is an evaluation detail: same clusters, same
        // λ* (exactly 0 for the negexp family), near-identical embedding
        // subspace as the monomial default, in both op modes.
        let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 9 });
        let mk = |basis, op_mode| PipelineConfig {
            k: 3,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "subspace".into(),
            steps: 300,
            eval_every: 20,
            stop_error: 0.0,
            op_mode,
            ground_truth: false,
            build: BuildOptions { basis, ..BuildOptions::default() },
            ..Default::default()
        };
        for op_mode in [OpMode::DenseMaterialized, OpMode::MatrixFree] {
            let mono = Pipeline::new(mk(PolyBasis::Monomial, op_mode)).run(&gg.graph).unwrap();
            let cheb = Pipeline::new(mk(PolyBasis::Chebyshev, op_mode)).run(&gg.graph).unwrap();
            assert_eq!(mono.lambda_star, 0.0);
            assert_eq!(cheb.lambda_star, 0.0);
            let err =
                crate::linalg::metrics::subspace_error(&mono.embedding, &cheb.embedding);
            assert!(err < 1e-6, "{op_mode:?}: basis subspace err {err}");
            assert_eq!(
                mono.clustering.as_ref().unwrap().assignments,
                cheb.clustering.as_ref().unwrap().assignments,
                "{op_mode:?}: partitions differ across bases"
            );
        }
    }

    #[test]
    fn chebyshev_basis_rejected_on_xla_and_exact_transforms() {
        let gg = cliques(&CliqueSpec { n: 12, k: 2, max_short_circuit: 1, seed: 2 });
        let cheb_build = BuildOptions { basis: PolyBasis::Chebyshev, ..BuildOptions::default() };
        let cfg = PipelineConfig {
            k: 2,
            build: cheb_build,
            backend: Backend::Xla { artifacts_dir: "artifacts".into() },
            ..Default::default()
        };
        let err = Pipeline::new(cfg).run(&gg.graph).unwrap_err();
        assert!(format!("{err:#}").contains("native backend"), "{err:#}");
        // Exact transform + chebyshev: clear error, not a silent fallback.
        let cfg = PipelineConfig {
            k: 2,
            transform: TransformKind::NegExp,
            build: cheb_build,
            ..Default::default()
        };
        let err = Pipeline::new(cfg).run(&gg.graph).unwrap_err();
        assert!(format!("{err:#}").contains("--basis monomial"), "{err:#}");
    }

    #[test]
    fn mixed_precision_pipeline_matches_f64_partition_dense_free() {
        // `--precision mixed` rides the matrix-free ritz path end to end:
        // same hard partition as the f64 run, solver converged via the
        // precision-floor clamp even under an unreachable requested tol.
        let gg = cliques(&CliqueSpec { n: 36, k: 3, max_short_circuit: 2, seed: 9 });
        let mk = |precision| PipelineConfig {
            k: 3,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "ritz".into(),
            ritz_tol: 1e-14, // below the f32 budget → clamp must engage
            ritz_max_iters: 300,
            op_mode: OpMode::MatrixFree,
            ground_truth: false,
            build: BuildOptions { precision, ..BuildOptions::default() },
            ..Default::default()
        };
        let exact = Pipeline::new(mk(Precision::F64)).run(&gg.graph).unwrap();
        let mixed = Pipeline::new(mk(Precision::Mixed)).run(&gg.graph).unwrap();
        let mz = mixed.ritz.as_ref().unwrap();
        assert!(mz.converged, "mixed ritz unconverged after {} iters", mz.iterations);
        let err = crate::linalg::metrics::subspace_error(&exact.embedding, &mixed.embedding);
        assert!(err < 1e-2, "f64 vs mixed subspace err {err}");
        assert_eq!(
            exact.clustering.as_ref().unwrap().assignments,
            mixed.clustering.as_ref().unwrap().assignments
        );
    }

    #[test]
    fn mixed_precision_rejected_off_the_sparse_native_path() {
        let gg = cliques(&CliqueSpec { n: 12, k: 2, max_short_circuit: 1, seed: 2 });
        let mixed_build =
            BuildOptions { precision: Precision::Mixed, ..BuildOptions::default() };
        // XLA backend: native-only knob.
        let cfg = PipelineConfig {
            k: 2,
            build: mixed_build,
            backend: Backend::Xla { artifacts_dir: "artifacts".into() },
            ..Default::default()
        };
        let err = Pipeline::new(cfg).run(&gg.graph).unwrap_err();
        assert!(format!("{err:#}").contains("native backend"), "{err:#}");
        // Ground-truth run: the oracle certifies f64 trajectories.
        let cfg = PipelineConfig {
            k: 2,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "ritz".into(),
            op_mode: OpMode::MatrixFree,
            build: mixed_build,
            ..Default::default() // ground_truth defaults to true
        };
        let err = Pipeline::new(cfg).run(&gg.graph).unwrap_err();
        assert!(format!("{err:#}").contains("ground-truth"), "{err:#}");
        // Dense materialized build: f64-only (build_solver_matrix bails).
        let cfg = PipelineConfig {
            k: 2,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "ritz".into(),
            op_mode: OpMode::DenseMaterialized,
            ground_truth: false,
            build: mixed_build,
            ..Default::default()
        };
        let err = Pipeline::new(cfg).run(&gg.graph).unwrap_err();
        assert!(format!("{err:#}").contains("--precision f64"), "{err:#}");
    }

    #[test]
    fn stored_rcm_order_skips_rebuild_and_matches_computed() {
        // Feeding the pipeline the persisted permutation must reproduce
        // the freshly-computed-RCM run bit for bit (it is the same order),
        // and a corrupt stored order must error, not mis-cluster.
        let gg = cliques(&CliqueSpec { n: 48, k: 3, max_short_circuit: 2, seed: 11 });
        let order = gg.graph.rcm_permutation();
        let mk = |rcm_order| PipelineConfig {
            k: 3,
            transform: TransformKind::LimitNegExp { ell: 51 },
            solver: "subspace".into(),
            steps: 300,
            eval_every: 20,
            stop_error: 0.0,
            op_mode: OpMode::MatrixFree,
            ground_truth: false,
            reorder: crate::graph::Reorder::Rcm,
            rcm_order,
            ..Default::default()
        };
        let fresh = Pipeline::new(mk(None)).run(&gg.graph).unwrap();
        let stored = Pipeline::new(mk(Some(order.clone()))).run(&gg.graph).unwrap();
        assert!(fresh
            .embedding
            .data()
            .iter()
            .zip(stored.embedding.data().iter())
            .all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(
            fresh.clustering.as_ref().unwrap().assignments,
            stored.clustering.as_ref().unwrap().assignments
        );
        // Not a permutation → rejected by the relabeling validation.
        let mut bad = order;
        bad[0] = bad[1];
        assert!(Pipeline::new(mk(Some(bad))).run(&gg.graph).is_err());
        // Under Reorder::None a stored order is ignored entirely.
        let cfg = PipelineConfig {
            reorder: crate::graph::Reorder::None,
            rcm_order: Some(vec![0, 1, 2]), // wrong length, but unused
            ..mk(None)
        };
        assert!(Pipeline::new(cfg).run(&gg.graph).is_ok());
    }

    #[test]
    fn pipeline_rejects_bad_k() {
        let gg = cliques(&CliqueSpec { n: 10, k: 2, max_short_circuit: 1, seed: 2 });
        let cfg = PipelineConfig { k: 0, ..Default::default() };
        assert!(Pipeline::new(cfg).run(&gg.graph).is_err());
        let cfg = PipelineConfig { k: 11, ..Default::default() };
        assert!(Pipeline::new(cfg).run(&gg.graph).is_err());
    }

    #[test]
    fn limit_series_native_pipeline_matches_exact() {
        // Series transform converges to (nearly) the same subspace as exact.
        let gg = cliques(&CliqueSpec { n: 24, k: 2, max_short_circuit: 1, seed: 3 });
        let mk = |transform| PipelineConfig {
            k: 2,
            transform,
            solver: "subspace".into(),
            steps: 300,
            eval_every: 10,
            stop_error: 1e-9,
            do_cluster: false,
            ..Default::default()
        };
        let exact = Pipeline::new(mk(TransformKind::NegExp)).run(&gg.graph).unwrap();
        let series =
            Pipeline::new(mk(TransformKind::LimitNegExp { ell: 251 })).run(&gg.graph).unwrap();
        let err = crate::linalg::metrics::subspace_error(&exact.embedding, &series.embedding);
        assert!(err < 1e-3, "exact vs series subspace err {err}");
    }

    #[test]
    fn ritz_summary_cap_keeps_tail_and_totals() {
        let full = RitzSummary {
            iterations: 10,
            converged: true,
            sweeps_per_apply: 5,
            total_sweeps: 50,
            col_sweeps: 180,
            halo_volume: 0,
            locked: 4,
            locked_history: (0..10).map(|i| (i / 3).min(4)).collect(),
            residual_history: (0..10).map(|i| 1.0 / (i + 1) as f64).collect(),
            residual_history_total: 10,
            residuals: vec![1e-9; 4],
            values: vec![2.0, 1.5, 1.0, 0.5],
            path: SolvePath::Cold,
        };
        let capped = full.clone().capped(3);
        assert_eq!(capped.residual_history, full.residual_history[7..]);
        assert_eq!(capped.locked_history, full.locked_history[7..]);
        assert_eq!(capped.residual_history_total, 10);
        assert_eq!(capped.iterations, 10);
        assert_eq!(capped.col_sweeps, 180);
        // A cap wider than the history is a no-op; capping twice is idempotent.
        let wide = full.clone().capped(64);
        assert_eq!(wide.residual_history, full.residual_history);
        assert_eq!(wide.residual_history_total, 10);
        let twice = full.capped(3).capped(3);
        assert_eq!(twice.residual_history_total, 10);
        assert_eq!(twice.residual_history.len(), 3);
    }
}
