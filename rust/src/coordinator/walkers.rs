//! The parallel walker fleet (§4.3): a leader/worker pool estimating
//! Laplacian powers from random walks, with bounded-queue backpressure.
//!
//! Structure mirrors a distributed deployment: the leader enqueues
//! [`WalkJob`]s (length, batch size, RNG stream), `d` walkers each own a
//! [`WalkEngine`] clone of the graph topology and push partial accumulators
//! back through a bounded channel; the leader merges partials into the
//! running estimate. On this image (1 core) the speedup is structural, not
//! wall-clock; the walk-estimator bench reports per-walker throughput.

use crate::graph::Graph;
use crate::linalg::DMat;
use crate::util::pool::JobPool;
use crate::util::rng::Rng;
use crate::walks::{EstimatorStats, SampleMethod, WalkEngine, WalkSample};
use std::sync::Arc;

/// A unit of walker work: `batch` trials of length-`len` walks.
#[derive(Clone, Copy, Debug)]
pub struct WalkJob {
    pub len: usize,
    pub batch: usize,
    pub seed: u64,
}

/// A walker's partial result: un-normalized accumulator + stats.
pub struct WalkPartial {
    pub acc: DMat,
    pub stats: EstimatorStats,
}

/// Configuration of the fleet.
#[derive(Clone, Copy, Debug)]
pub struct WalkerPoolConfig {
    pub workers: usize,
    /// Bounded queue depth (jobs and results) — the backpressure knob.
    pub backlog: usize,
    pub method: SampleMethod,
}

impl Default for WalkerPoolConfig {
    fn default() -> Self {
        WalkerPoolConfig { workers: 4, backlog: 8, method: SampleMethod::Importance }
    }
}

/// Leader-side handle to the walker fleet for one graph.
pub struct WalkerPool {
    pool: JobPool<WalkJob, WalkPartial>,
    n: usize,
    backlog: usize,
}

impl WalkerPool {
    /// Spawn the fleet. The graph is shared read-only (`Arc`); each worker
    /// builds its own edge-incidence index once at startup — the same
    /// "replicate topology to every walker host" a distributed system does.
    pub fn spawn(graph: Arc<Graph>, cfg: WalkerPoolConfig) -> WalkerPool {
        let n = graph.num_nodes();
        let method = cfg.method;
        let pool = JobPool::new(cfg.workers, cfg.backlog, move |wid, job: WalkJob| {
            // The engine (edge-incidence CSR) is rebuilt per job: O(|E|)
            // construction amortized over ≥1k-trial batches. A longer-lived
            // per-thread cache would need self-referential storage against
            // the Arc'd graph; the bench `walk_estimator` shows construction
            // is <2% of a 1k-walk job.
            let engine = WalkEngine::new(&graph);
            let mut rng = Rng::new(job.seed ^ ((wid as u64 + 1) << 48));
            let mut acc = DMat::zeros(n, n);
            let mut stats = EstimatorStats::default();
            let mut walk = WalkSample { edges: vec![], alpha: vec![], prob: vec![] };
            for _ in 0..job.batch {
                engine.sample_walk_into(job.len, &mut rng, &mut walk);
                stats.trials += 1;
                if let Some((ea, eb, w)) =
                    engine.prefix_contribution(&walk, job.len, method, &mut rng)
                {
                    stats.accepted += 1;
                    if w != 0.0 {
                        stats.weight_stats.push(w);
                    }
                    add_outer(&mut acc, &graph, ea, eb, w);
                }
            }
            WalkPartial { acc, stats }
        });
        WalkerPool { pool, n, backlog: cfg.backlog }
    }

    /// Distribute `total_walks` length-`len` trials over `jobs` jobs, block
    /// for all partials, and return the normalized unbiased estimate of
    /// `L^len` plus merged stats.
    pub fn estimate_power(
        &self,
        len: usize,
        total_walks: usize,
        jobs: usize,
        seed: u64,
    ) -> (DMat, EstimatorStats) {
        let jobs = jobs.max(1);
        let batch = total_walks.div_ceil(jobs);
        let mut submitted = 0usize;
        let mut acc = DMat::zeros(self.n, self.n);
        let mut stats = EstimatorStats::default();
        let mut outstanding = 0usize;
        let mut job_idx = 0u64;
        // Never keep more than `backlog` jobs outstanding: the job and
        // result queues each hold `backlog` entries, so a deeper prime
        // would block `submit` while workers block on full result queues —
        // a leader/worker deadlock.
        let max_outstanding = self.backlog.max(1);
        while submitted < total_walks || outstanding > 0 {
            // Keep the queue primed, then drain one result (backpressure-
            // friendly interleave).
            while submitted < total_walks && outstanding < max_outstanding {
                let this_batch = batch.min(total_walks - submitted);
                self.pool.submit(WalkJob {
                    len,
                    batch: this_batch,
                    seed: seed ^ job_idx.wrapping_mul(0x9E3779B97F4A7C15),
                });
                submitted += this_batch;
                outstanding += 1;
                job_idx += 1;
            }
            let partial = self.pool.recv();
            acc.axpy(1.0, &partial.acc);
            stats = stats.merge(partial.stats);
            outstanding -= 1;
        }
        acc.scale(1.0 / stats.trials.max(1) as f64);
        (acc, stats)
    }

    /// Shut the fleet down, joining all workers.
    pub fn shutdown(self) {
        let _ = self.pool.shutdown();
    }
}

#[inline]
fn add_outer(acc: &mut DMat, g: &Graph, ea: u32, eb: u32, weight: f64) {
    if weight == 0.0 {
        return;
    }
    let a = g.edges()[ea as usize];
    let b = g.edges()[eb as usize];
    acc[(a.u as usize, b.u as usize)] += weight;
    acc[(a.u as usize, b.v as usize)] -= weight;
    acc[(a.v as usize, b.u as usize)] -= weight;
    acc[(a.v as usize, b.v as usize)] += weight;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{cliques, CliqueSpec};
    use crate::linalg::matmul::matmul;

    #[test]
    fn fleet_estimate_matches_truth() {
        let g = Arc::new(
            cliques(&CliqueSpec { n: 16, k: 2, max_short_circuit: 1, seed: 2 }).graph,
        );
        let l = g.laplacian();
        let l2 = matmul(&l, &l);
        let pool = WalkerPool::spawn(g.clone(), WalkerPoolConfig::default());
        let (est, stats) = pool.estimate_power(2, 60_000, 12, 7);
        pool.shutdown();
        assert_eq!(stats.trials, 60_000);
        let err = (&est - &l2).max_abs() / l2.max_abs();
        assert!(err < 0.15, "rel err {err}");
    }

    #[test]
    fn fleet_handles_more_jobs_than_backlog() {
        let g = Arc::new(
            cliques(&CliqueSpec { n: 12, k: 2, max_short_circuit: 1, seed: 3 }).graph,
        );
        let l = g.laplacian();
        let pool = WalkerPool::spawn(
            g.clone(),
            WalkerPoolConfig { workers: 2, backlog: 2, method: SampleMethod::Importance },
        );
        // 40 jobs through a backlog of 2 — exercises the interleave.
        let (est, stats) = pool.estimate_power(1, 40_000, 40, 9);
        pool.shutdown();
        assert_eq!(stats.trials, 40_000);
        let err = (&est - &l).max_abs() / l.max_abs();
        assert!(err < 0.1, "rel err {err}");
    }
}
